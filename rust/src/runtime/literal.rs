//! Host <-> device literal conversion helpers.
//!
//! Keeps all `xla::Literal` construction in one place so the rest of the
//! crate deals only in plain slices and `HostTensor`s.

use crate::data::tensors::{DType, HostTensor};
use anyhow::{bail, Result};

/// f32 literal of the given shape.
pub fn literal_f32(dims: &[usize], vals: &[f32]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != vals.len() {
        bail!("shape {:?} != {} values", dims, vals.len());
    }
    let v = xla::Literal::vec1(vals);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(v.reshape(&dims_i64)?)
}

/// i32 literal of the given shape.
pub fn literal_i32(dims: &[usize], vals: &[i32]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != vals.len() {
        bail!("shape {:?} != {} values", dims, vals.len());
    }
    let v = xla::Literal::vec1(vals);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(v.reshape(&dims_i64)?)
}

/// 0-d f32 scalar literal (runtime bit-width inputs).
pub fn literal_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read back an f32 literal into a host vec.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

impl HostTensor {
    /// Convert to an `xla::Literal` (f32/i32 only — u8 tensors are
    /// build-side metadata and never enter the request path).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self.dtype {
            DType::F32 => literal_f32(&self.dims, &self.as_f32()?),
            DType::I32 => literal_i32(&self.dims, &self.as_i32()?),
            DType::U8 => bail!("u8 tensors are not executable inputs"),
        }
    }
}
