//! Process-wide PJRT runtime service.
//!
//! xla_extension 0.5.1's CPU plugin cannot tolerate multiple PjRtClients
//! per process: destroying one corrupts global TFRT state and later
//! literal uploads crash (observed: `literal.size_bytes() == b->size()`
//! check failures / SIGSEGV). The xla crate's handles are additionally
//! `!Send`.
//!
//! Both constraints are solved by confining ALL PJRT objects to one
//! dedicated service thread, created once per process, never destroyed.
//! Callers interact through a channel API with plain-data messages
//! (paths, token vectors, f32 buffers), so every public handle here is
//! `Send + Sync` and the coordinator's workers can share compiled
//! executables freely. PJRT CPU executions are internally multi-threaded,
//! so serializing *dispatch* costs nothing on this host.

use super::{Engine, Executable};
use crate::data::tensors::{DType, TensorFile};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Mutex, OnceLock};

/// Handle to a compiled executable living on the service thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExeId(u64);

/// Handle to a set of device-resident weight buffers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WeightsId(u64);

/// One output tensor, already copied to host.
#[derive(Debug, Clone)]
pub struct HostOutput {
    pub data: Vec<f32>,
}

enum Cmd {
    LoadHlo(PathBuf, mpsc::Sender<Result<ExeId>>),
    UploadWeights(PathBuf, mpsc::Sender<Result<WeightsId>>),
    /// run(exe, weights, tokens, [batch, seq], ia_bits, w_bits)
    Run {
        exe: ExeId,
        weights: Option<WeightsId>,
        tokens: Vec<i32>,
        dims: (usize, usize),
        ia_bits: f32,
        w_bits: f32,
        reply: mpsc::Sender<Result<Vec<HostOutput>>>,
    },
    Platform(mpsc::Sender<Result<String>>),
}

/// Client-side handle to the service (cheap to clone, Send + Sync).
#[derive(Clone)]
pub struct RuntimeService {
    tx: mpsc::Sender<Cmd>,
}

// SAFETY: Sender<Cmd> is Send; Sync via the global mutex pattern below.
static SERVICE: OnceLock<Mutex<RuntimeService>> = OnceLock::new();

impl RuntimeService {
    /// The process-wide instance (spawns the service thread on first use).
    pub fn global() -> RuntimeService {
        SERVICE
            .get_or_init(|| {
                let (tx, rx) = mpsc::channel::<Cmd>();
                std::thread::Builder::new()
                    .name("muxq-pjrt".into())
                    // XLA compilation recurses deeply; the 2 MiB default
                    // thread stack overflows (observed SIGSEGV), so give
                    // the service thread a main-thread-sized stack.
                    .stack_size(64 << 20)
                    .spawn(move || service_loop(rx))
                    .expect("spawn pjrt service thread");
                Mutex::new(RuntimeService { tx })
            })
            .lock()
            .unwrap()
            .clone()
    }

    fn send(&self, cmd: Cmd) -> Result<()> {
        self.tx.send(cmd).map_err(|_| anyhow!("pjrt service thread died"))
    }

    pub fn platform(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.send(Cmd::Platform(tx))?;
        rx.recv().context("pjrt service dropped reply")?
    }

    pub fn load_hlo(&self, path: impl Into<PathBuf>) -> Result<ExeId> {
        let (tx, rx) = mpsc::channel();
        self.send(Cmd::LoadHlo(path.into(), tx))?;
        rx.recv().context("pjrt service dropped reply")?
    }

    /// Upload every tensor of a container (byte-sorted order — the HLO
    /// input contract) to device buffers, once.
    pub fn upload_weights(&self, path: impl Into<PathBuf>) -> Result<WeightsId> {
        let (tx, rx) = mpsc::channel();
        self.send(Cmd::UploadWeights(path.into(), tx))?;
        rx.recv().context("pjrt service dropped reply")?
    }

    /// Execute: [weights..., tokens, ia_bits, w_bits] -> host outputs.
    pub fn run(
        &self,
        exe: ExeId,
        weights: Option<WeightsId>,
        tokens: Vec<i32>,
        dims: (usize, usize),
        ia_bits: f32,
        w_bits: f32,
    ) -> Result<Vec<HostOutput>> {
        let (tx, rx) = mpsc::channel();
        self.send(Cmd::Run { exe, weights, tokens, dims, ia_bits, w_bits, reply: tx })?;
        rx.recv().context("pjrt service dropped reply")?
    }
}

struct ServiceState {
    engine: Engine,
    exes: HashMap<u64, Executable>,
    weights: HashMap<u64, Vec<xla::PjRtBuffer>>,
    weight_files: HashMap<PathBuf, WeightsId>,
    exe_files: HashMap<PathBuf, ExeId>,
    next_id: u64,
}

fn service_loop(rx: mpsc::Receiver<Cmd>) {
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            // fail every request with a clear message
            while let Ok(cmd) = rx.recv() {
                let msg = format!("PJRT client failed to initialize: {e:#}");
                match cmd {
                    Cmd::LoadHlo(_, tx) => drop(tx.send(Err(anyhow!(msg)))),
                    Cmd::UploadWeights(_, tx) => drop(tx.send(Err(anyhow!(msg)))),
                    Cmd::Run { reply, .. } => drop(reply.send(Err(anyhow!(msg)))),
                    Cmd::Platform(tx) => drop(tx.send(Err(anyhow!(msg)))),
                }
            }
            return;
        }
    };
    let mut st = ServiceState {
        engine,
        exes: HashMap::new(),
        weights: HashMap::new(),
        weight_files: HashMap::new(),
        exe_files: HashMap::new(),
        next_id: 1,
    };
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Platform(tx) => {
                let _ = tx.send(Ok(st.engine.platform_name()));
            }
            Cmd::LoadHlo(path, tx) => {
                let result = if let Some(id) = st.exe_files.get(&path) {
                    Ok(*id)
                } else {
                    st.engine.load_hlo(&path).map(|exe| {
                        let id = ExeId(st.next_id);
                        st.next_id += 1;
                        st.exes.insert(id.0, exe);
                        st.exe_files.insert(path.clone(), id);
                        id
                    })
                };
                let _ = tx.send(result);
            }
            Cmd::UploadWeights(path, tx) => {
                let result = if let Some(id) = st.weight_files.get(&path) {
                    Ok(*id)
                } else {
                    upload_file(&st.engine, &path).map(|bufs| {
                        let id = WeightsId(st.next_id);
                        st.next_id += 1;
                        st.weights.insert(id.0, bufs);
                        st.weight_files.insert(path.clone(), id);
                        id
                    })
                };
                let _ = tx.send(result);
            }
            Cmd::Run { exe, weights, tokens, dims, ia_bits, w_bits, reply } => {
                let _ = reply.send(run_one(&st, exe, weights, &tokens, dims, ia_bits, w_bits));
            }
        }
    }
}

fn upload_file(engine: &Engine, path: &std::path::Path) -> Result<Vec<xla::PjRtBuffer>> {
    let tf = TensorFile::read(path)?;
    let mut bufs = Vec::with_capacity(tf.tensors.len());
    for name in tf.sorted_names() {
        let t = tf.get(name)?;
        let buf = match t.dtype {
            DType::F32 => engine.upload_f32(&t.as_f32()?, &t.dims)?,
            DType::I32 => engine.upload_i32(&t.as_i32()?, &t.dims)?,
            DType::U8 => anyhow::bail!("u8 tensor {name} is not an executable input"),
        };
        bufs.push(buf);
    }
    Ok(bufs)
}

fn run_one(
    st: &ServiceState,
    exe: ExeId,
    weights: Option<WeightsId>,
    tokens: &[i32],
    dims: (usize, usize),
    ia_bits: f32,
    w_bits: f32,
) -> Result<Vec<HostOutput>> {
    let exe = st.exes.get(&exe.0).with_context(|| format!("unknown exe {exe:?}"))?;
    if tokens.len() != dims.0 * dims.1 {
        return Err(anyhow!("tokens len {} != {}x{}", tokens.len(), dims.0, dims.1));
    }
    let tok_buf = st.engine.upload_i32(tokens, &[dims.0, dims.1])?;
    let ia = st.engine.upload_f32(&[ia_bits], &[])?;
    let w = st.engine.upload_f32(&[w_bits], &[])?;
    let mut args: Vec<&xla::PjRtBuffer> = Vec::new();
    if let Some(wid) = weights {
        let bufs = st.weights.get(&wid.0).with_context(|| format!("unknown weights {wid:?}"))?;
        args.extend(bufs.iter());
    }
    args.push(&tok_buf);
    args.push(&ia);
    args.push(&w);
    let outs = exe.run_buffers(&args)?;
    outs.iter()
        .map(|lit| Ok(HostOutput { data: super::to_vec_f32(lit)? }))
        .collect()
}
