//! PJRT runtime: load AOT-compiled HLO text, compile once, execute many.
//!
//! This is the only module that touches the `xla` crate. The interchange
//! format is HLO *text* (see DESIGN.md §6 / python/compile/aot.py): jax >= 0.5
//! emits HloModuleProto with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects, while the text parser reassigns ids and round-trips
//! cleanly.
//!
//! Thread-safety: `PjRtClient`/`PjRtLoadedExecutable` are internally
//! ref-counted C++ objects; we confine execution to worker threads that
//! each own a clone of the `Engine` handle. Compilation is serialized
//! through the variant registry (`coordinator::variants`).

mod literal;
pub mod service;

pub use literal::{literal_f32, literal_i32, literal_scalar_f32, to_vec_f32};

use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Shared PJRT client handle.
#[derive(Clone)]
pub struct Engine {
    client: Arc<xla::PjRtClient>,
}

impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine { client: Arc::new(client) })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Upload host data to a device buffer (weights are uploaded once
    /// per variant and reused across requests — the hot path uses
    /// `Executable::run_buffers`).
    ///
    /// Uses `BufferFromHostBuffer` with ImmutableOnlyDuringCall semantics
    /// (synchronous copy). Do NOT switch to `buffer_from_host_literal`:
    /// TFRT's `BufferFromHostLiteral` copies asynchronously and requires
    /// the literal to outlive the transfer — dropping it races the copy
    /// (observed: size-check aborts / SIGSEGV with garbage literal
    /// metadata).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("upload f32 buffer to device")
    }

    /// i32 variant of [`Engine::upload_f32`].
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("upload i32 buffer to device")
    }

    /// Load an HLO-text module and compile it for this client.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Executable {
            exe: Arc::new(exe),
            name: path.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        })
    }
}

/// A compiled computation. Cheap to clone; `run` is callable from any
/// thread.
#[derive(Clone)]
pub struct Executable {
    exe: Arc<xla::PjRtLoadedExecutable>,
    pub name: String,
}

impl Executable {
    /// Execute with host literals; returns the decomposed output tuple
    /// (aot.py lowers with return_tuple=True).
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("execute {}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = lit.to_tuple().context("decompose output tuple")?;
        Ok(parts)
    }

    /// Execute with borrowed literals (avoids cloning cached weights).
    pub fn run_refs(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<&xla::Literal>(args)
            .with_context(|| format!("execute {}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = lit.to_tuple().context("decompose output tuple")?;
        Ok(parts)
    }

    /// Execute with pre-uploaded device buffers (the zero-host-copy hot
    /// path: weights stay on device across requests).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .with_context(|| format!("execute_b {}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = lit.to_tuple().context("decompose output tuple")?;
        Ok(parts)
    }
}
