//! # MUXQ — Mixed-to-Uniform Precision Matrix Quantization
//!
//! Production reproduction of Lee, Kim & Kim (2026): activation-outlier
//! handling for uniform low-precision INT quantization of LLMs, built as a
//! three-layer rust + JAX + Pallas stack (see DESIGN.md §1; the sim-scale
//! model stand-ins are DESIGN.md §2).
//!
//! Layer map:
//! * [`runtime`] — PJRT client; loads the AOT-compiled HLO artifacts.
//! * [`coordinator`] — serving layer: router, dynamic batcher, workers
//!   (scoring) + continuous-batching token generation (`generation`).
//! * [`quant`] — rust-native quantization engine (MUXQ, naive abs-max,
//!   LLM.int8(), SmoothQuant) mirroring the python/jax reference.
//! * [`gpt2`] — native f32 GPT-2 forward + KV-cache incremental decode
//!   (baseline, Fig.1 capture, and the generation engine).
//! * [`serve`] — HTTP front end over the generation server: hand-rolled
//!   HTTP/1.1 + SSE streaming, multi-tenant QoS admission, load shedding.
//! * [`npusim`] — systolic-array cost model (hardware-efficiency study).
//! * [`data`] — corpus generator, BPE tokenizer, tensor container.
//! * [`util`] — in-repo substrates: CLI parsing, bench harness,
//!   mini-proptest, metrics, config (tokio/clap/criterion are unavailable
//!   in the offline build image).

pub mod coordinator;
pub mod data;
pub mod gpt2;
pub mod harness;
pub mod npusim;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Resolve the artifacts directory: `$MUXQ_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("MUXQ_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
