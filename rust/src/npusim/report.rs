//! Report tables for the hardware-efficiency study (§4.5 / Fig. 4).

use super::{model_cost, NpuConfig};
use crate::quant::Method;

/// One row of the latency/energy comparison.
#[derive(Debug, Clone)]
pub struct Row {
    pub model: String,
    pub method: Method,
    pub bits: u32,
    pub w_bits: u32,
    pub latency_us: f64,
    pub energy_uj: f64,
    pub speedup_vs_fp16: f64,
}

/// Model-stack geometry for the simulator (layers, tokens, width,
/// outlier channels).
#[derive(Debug, Clone, Copy)]
pub struct ModelGeom {
    pub n_layer: usize,
    pub t: usize,
    pub d: usize,
    pub r: usize,
}

/// Geometry of the paper's actual GPT-2 targets (batch*seq = 1024 tokens,
/// outlier channel counts in the single-digit/low-double-digit range per
/// LLM.int8() observations).
pub fn paper_geometries() -> Vec<(&'static str, ModelGeom)> {
    vec![
        ("gpt2-small (0.1B)", ModelGeom { n_layer: 12, t: 1024, d: 768, r: 8 }),
        ("gpt2-medium (0.3B)", ModelGeom { n_layer: 24, t: 1024, d: 1024, r: 12 }),
        ("gpt2-large (0.7B)", ModelGeom { n_layer: 36, t: 1024, d: 1280, r: 16 }),
    ]
}

/// Geometry of the sim models actually shipped in artifacts/.
pub fn sim_geometries() -> Vec<(&'static str, ModelGeom)> {
    vec![
        ("sim-small", ModelGeom { n_layer: 4, t: 1024, d: 128, r: 6 }),
        ("sim-medium", ModelGeom { n_layer: 6, t: 1024, d: 192, r: 6 }),
        ("sim-large", ModelGeom { n_layer: 8, t: 1024, d: 256, r: 6 }),
    ]
}

pub fn compare(cfg: &NpuConfig, name: &str, g: ModelGeom, bits: u32) -> Vec<Row> {
    let fp = model_cost(cfg, Method::Fp16, g.n_layer, g.t, g.d, 0, bits, bits);
    [Method::Fp16, Method::Naive, Method::Muxq, Method::LlmInt8, Method::Resq]
        .into_iter()
        .map(|method| {
            let r = if method == Method::Fp16 || method == Method::Naive { 0 } else { g.r };
            // naive ignores outliers entirely (that's its accuracy bug,
            // not a latency cost); muxq/llmint8 pay their handling cost,
            // and resq's r prices its residual rank. resq deploys at its
            // method-default W4 (the whole point of the method)
            let w_bits = if method == Method::Resq { 4 } else { bits };
            let c = model_cost(cfg, method, g.n_layer, g.t, g.d, r, bits, w_bits);
            Row {
                model: name.to_string(),
                method,
                bits,
                w_bits,
                latency_us: c.latency_us(cfg),
                energy_uj: c.energy_pj / 1e6,
                speedup_vs_fp16: fp.cycles() / c.cycles(),
            }
        })
        .collect()
}

pub fn render_table(rows: &[Row]) -> String {
    let mut s = format!(
        "{:<20} {:<12} {:>6} {:>12} {:>12} {:>14}\n",
        "model", "method", "bits", "latency(us)", "energy(uJ)", "vs fp16"
    );
    for r in rows {
        s.push_str(&format!(
            "{:<20} {:<12} {:>6} {:>12.1} {:>12.1} {:>13.2}x\n",
            r.model,
            r.method.name(),
            format!("w{}a{}", r.w_bits, r.bits),
            r.latency_us,
            r.energy_uj,
            r.speedup_vs_fp16
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_premises_hold() {
        let cfg = NpuConfig::default();
        for (name, g) in paper_geometries() {
            let rows = compare(&cfg, name, g, 8);
            let by = |m: Method| rows.iter().find(|r| r.method == m).unwrap().clone();
            // INT8 GEMM > 2x faster than FP16 (paper §1)
            assert!(by(Method::Naive).speedup_vs_fp16 > 2.0, "{name}");
            // MUXQ within a few % of naive INT8
            assert!(by(Method::Muxq).latency_us < by(Method::Naive).latency_us * 1.15);
            // MUXQ beats the mixed-precision baseline
            assert!(by(Method::Muxq).latency_us < by(Method::LlmInt8).latency_us);
            // ResQ deploys at W4 and still clears the FP16 baseline
            let resq = by(Method::Resq);
            assert_eq!(resq.w_bits, 4, "{name}");
            assert!(resq.speedup_vs_fp16 > 1.0, "{name}");
        }
    }

    #[test]
    fn render_contains_all_methods() {
        let cfg = NpuConfig::default();
        let (name, g) = paper_geometries()[0];
        let t = render_table(&compare(&cfg, name, g, 8));
        for m in ["fp16", "naive", "muxq", "llm.int8()", "resq", "w4a8", "w8a8"] {
            assert!(t.contains(m), "{t}");
        }
    }
}
