//! NPU cost model: a cycle-level systolic-array + DMA simulator.
//!
//! The paper's §4.5 *argues* (without measuring) that MUXQ's uniform INT8
//! pipeline beats LLM.int8()'s mixed-precision decomposition on
//! INT-oriented hardware. This module turns that argument into a
//! reproducible experiment: it prices each method's per-layer GEMM plan on
//! a parameterized accelerator and reports latency + energy.
//!
//! Model (deliberately simple, every term documented):
//! * PE array `array_dim x array_dim`, output-stationary tiling: a tile
//!   computes a `[T_a, T_a]` output block over the full K dimension;
//!   pipeline cost per tile = `K + 2*array_dim` cycles (fill + drain).
//! * The INT datapath retires [`NpuConfig::int_macs_per_cycle`] MACs per
//!   PE per cycle as a function of the accumulator lane width
//!   (`acc_width_bits`): 32-bit lanes do one i8 MAC/cycle; 16-bit
//!   pair-accumulation lanes (the default, matching
//!   `quant::packed`'s i16 pair microkernel) do two. INT4 additionally
//!   runs `int4_speedup`x. FP16 runs at `1/fp16_slowdown` (NPUs are
//!   INT-optimized; the paper's premise) and is unaffected by the INT
//!   accumulator width.
//! * DMA: operands+result move HBM<->SRAM once per GEMM at `dram_gbps`;
//!   compute and DMA overlap (latency = max, not sum).
//! * Mixed-precision decomposition (LLM.int8()) pays a gather/scatter
//!   pass over the activation matrix at `gather_bytes_per_cycle` (it is
//!   not a streaming DMA pattern — the irregular-memory-access penalty
//!   the paper cites) plus a pipeline flush between precision domains.
//! * MUXQ pays the in-stream decompose (fused with quantization: free on
//!   DMA-in), a *skinny* second GEMM over the r outlier channels and the
//!   recombination add (`2^exp - 1` scaling folds into the dequant).

pub mod gemm_plan;
pub mod report;

use crate::quant::Method;

/// Accelerator parameters. Defaults model a mid-size edge NPU
/// (128x128 INT8 array @ 1 GHz, 64 GB/s DRAM).
#[derive(Debug, Clone)]
pub struct NpuConfig {
    pub array_dim: usize,
    pub freq_ghz: f64,
    pub dram_gbps: f64,
    /// FP16 MAC throughput divisor vs INT8 (INT-oriented NPU premise).
    pub fp16_slowdown: f64,
    /// INT4 MAC throughput multiplier vs INT8.
    pub int4_speedup: f64,
    /// bytes/cycle for irregular gather/scatter (mixed-precision split).
    pub gather_bytes_per_cycle: f64,
    /// bytes/cycle for rewriting a weight operand into the panel layout
    /// the MAC array streams (sequential read + strided write; only paid
    /// when weights are NOT pre-packed at load time).
    pub pack_bytes_per_cycle: f64,
    /// cycles to flush/refill the array between precision domains.
    pub domain_switch_cycles: u64,
    /// cycles of DMA descriptor setup per non-contiguous KV page burst
    /// (paged attention reads K then V of each page as separate strided
    /// bursts instead of one streaming transfer).
    pub page_gather_setup_cycles: f64,
    /// cycles of per-tenant scheduler bookkeeping per decode tick:
    /// deficit-weighted round-robin credit accounting, lane rotation and
    /// in-flight cap checks for ONE tenant lane
    /// (`coordinator::batcher::DecodeQueue`'s host-side twin). Paid once
    /// per distinct tenant per batched tick in
    /// [`gemm_plan::ServeTickPlan`].
    pub tenant_sched_cycles: f64,
    /// INT accumulator lane width in bits. 32 models one i8 MAC per lane
    /// per cycle; 16 models i16 pair accumulation — two i8 MACs per lane
    /// before the i32 widening step, the datapath of
    /// `quant::packed`'s pair microkernel (and of `pmaddwd`-class
    /// SIMD / NPU MAC trees).
    pub acc_width_bits: u32,
    /// Hardware dot-product unit width: `Some(d)` models a d-way i8 dot
    /// summed directly into an i32 lane per cycle (`sdot`/VNNI-class
    /// MAC trees: d = 4; `pmaddwd`-class pair units: d = 2), overriding
    /// the accumulator-width derivation above. `None` (the default)
    /// keeps the legacy `acc_width_bits` model. [`NpuConfig::for_kernel`]
    /// maps each runtime-dispatched host kernel onto this knob.
    pub dot_width: Option<u32>,
    /// pJ per INT8 MAC (energy model; FP16 = 4x, SRAM/DRAM per-byte below)
    pub pj_per_int8_mac: f64,
    pub pj_per_fp16_mac: f64,
    pub pj_per_dram_byte: f64,
}

impl Default for NpuConfig {
    fn default() -> Self {
        NpuConfig {
            array_dim: 128,
            freq_ghz: 1.0,
            dram_gbps: 64.0,
            fp16_slowdown: 4.0,
            int4_speedup: 2.0,
            gather_bytes_per_cycle: 16.0,
            pack_bytes_per_cycle: 32.0,
            domain_switch_cycles: 2048,
            page_gather_setup_cycles: 32.0,
            tenant_sched_cycles: 64.0,
            acc_width_bits: 16,
            dot_width: None,
            pj_per_int8_mac: 0.2,
            pj_per_fp16_mac: 0.8,
            pj_per_dram_byte: 20.0,
        }
    }
}

impl NpuConfig {
    /// INT MACs retired per PE per cycle: the explicit dot-unit width
    /// when one is modeled, else derived from the accumulator lane
    /// width (i16 pair accumulation doubles per-lane throughput).
    /// Energy per MAC is unchanged in every case — the same multiplies
    /// happen, only the widening cadence differs.
    pub fn int_macs_per_cycle(&self) -> f64 {
        match self.dot_width {
            Some(d) => d as f64,
            None => {
                if self.acc_width_bits == 16 {
                    2.0
                } else {
                    1.0
                }
            }
        }
    }

    /// Builder-style accumulator-width override (32 models the PR-1
    /// wide-i32 datapath, 16 the pair-accumulation default). Clears any
    /// dot-unit override so the chosen width actually governs.
    pub fn with_acc_width(mut self, bits: u32) -> Self {
        self.acc_width_bits = bits;
        self.dot_width = None;
        self
    }

    /// Builder-style dot-unit width (4 = `sdot`/VNNI-class quad MACs,
    /// 2 = `pmaddwd`-class pair MACs).
    pub fn with_dot_width(mut self, d: u32) -> Self {
        self.dot_width = Some(d);
        self
    }

    /// Builder-style page-gather DMA setup cost (cycles per KV page
    /// burst in paged attention).
    pub fn with_page_gather_setup(mut self, cycles: f64) -> Self {
        self.page_gather_setup_cycles = cycles;
        self
    }

    /// Builder-style per-tenant scheduler bookkeeping cost (cycles per
    /// tenant lane per batched decode tick).
    pub fn with_tenant_sched(mut self, cycles: f64) -> Self {
        self.tenant_sched_cycles = cycles;
        self
    }

    /// The config whose INT datapath mirrors a runtime-dispatched host
    /// kernel (`quant::simd::dispatch`): per-arch widened-MAC lanes, so
    /// simulated latencies track the kernel generation actually
    /// deployed. DMA, energy and array geometry stay at the defaults —
    /// only the MAC cadence differs across kernels. (NEON is modeled at
    /// `sdot` width; ARMv8.0 hosts that fall back to `smlal` pairs run
    /// at the `pair` cadence instead.)
    pub fn for_kernel(k: crate::quant::simd::DispatchKernel) -> NpuConfig {
        use crate::quant::simd::DispatchKernel as K;
        match k {
            K::Scalar => NpuConfig::default().with_acc_width(32),
            K::Pair => NpuConfig::default(), // i16 pair lanes: 2 MACs/cycle
            K::Avx2 => NpuConfig::default().with_dot_width(2), // pmaddwd pairs
            K::Neon => NpuConfig::default().with_dot_width(4), // sdot quads
        }
    }
}

/// Operand precision on the array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Int4,
    Int8,
    Fp16,
}

impl Precision {
    pub fn bytes(self) -> f64 {
        match self {
            Precision::Int4 => 0.5,
            Precision::Int8 => 1.0,
            Precision::Fp16 => 2.0,
        }
    }
}

/// Cost of one dense GEMM `[m,k] @ [k,n]` at a precision.
#[derive(Debug, Clone, Copy, Default)]
pub struct Cost {
    pub compute_cycles: f64,
    pub dma_cycles: f64,
    pub extra_cycles: f64,
    pub energy_pj: f64,
}

impl Cost {
    /// Latency with compute/DMA overlap.
    pub fn cycles(&self) -> f64 {
        self.compute_cycles.max(self.dma_cycles) + self.extra_cycles
    }

    pub fn latency_us(&self, cfg: &NpuConfig) -> f64 {
        self.cycles() / (cfg.freq_ghz * 1e3)
    }

    pub fn add(&mut self, other: Cost) {
        // sequential composition: both phases keep their internal overlap
        self.extra_cycles += other.cycles();
        self.energy_pj += other.energy_pj;
    }
}

/// Price a dense GEMM on the array with one precision for both operands
/// — shorthand for [`gemm_cost_w`] at `act == w` (the pre-W4 model,
/// numerically unchanged).
pub fn gemm_cost(cfg: &NpuConfig, m: usize, k: usize, n: usize, prec: Precision) -> Cost {
    gemm_cost_w(cfg, m, k, n, prec, prec)
}

/// Price a dense GEMM `[m,k] @ [k,n]` with SPLIT operand precisions:
/// `act` for the `[m,k]` activation stream, `w` for the `[k,n]` weight
/// stream — the W4A8 regime streams nibble weights against byte
/// activations, so the byte terms must separate. Compute cadence and MAC
/// energy follow the narrower INT side (the FineQ-style weight-datapath
/// premise: an i4-weight MAC tree retires `int4_speedup`x the i8 rate
/// and spends half the pJ); any FP16 operand drags the whole GEMM onto
/// the FP16 lanes.
pub fn gemm_cost_w(
    cfg: &NpuConfig,
    m: usize,
    k: usize,
    n: usize,
    act: Precision,
    w: Precision,
) -> Cost {
    let a = cfg.array_dim as f64;
    let tiles_m = (m as f64 / a).ceil();
    let tiles_n = (n as f64 / a).ceil();
    let per_tile = k as f64 + 2.0 * a; // stream K + fill/drain
    // pair accumulation widens the INT datapath; FP16 lanes don't pair
    let slow = match (act, w) {
        (Precision::Fp16, _) | (_, Precision::Fp16) => cfg.fp16_slowdown,
        (Precision::Int4, _) | (_, Precision::Int4) => {
            1.0 / (cfg.int4_speedup * cfg.int_macs_per_cycle())
        }
        _ => 1.0 / cfg.int_macs_per_cycle(),
    };
    let compute = tiles_m * tiles_n * per_tile * slow;

    // operand bytes split by side; output fp16 — the ONE formula
    // `Plan::bytes_per_step` mirrors term for term
    let op_bytes =
        (m * k) as f64 * act.bytes() + (k * n) as f64 * w.bytes() + (m * n) as f64 * 2.0;
    let bytes_per_cycle = cfg.dram_gbps * 1e9 / (cfg.freq_ghz * 1e9);
    let dma = op_bytes / bytes_per_cycle;

    let macs = (m * k * n) as f64;
    let pj_mac = match (act, w) {
        (Precision::Fp16, _) | (_, Precision::Fp16) => cfg.pj_per_fp16_mac,
        (Precision::Int4, _) | (_, Precision::Int4) => cfg.pj_per_int8_mac / 2.0,
        _ => cfg.pj_per_int8_mac,
    };
    Cost {
        compute_cycles: compute,
        dma_cycles: dma,
        extra_cycles: 0.0,
        energy_pj: macs * pj_mac + op_bytes * cfg.pj_per_dram_byte,
    }
}

/// Price one projection layer `[t, k] @ [k, n]` for a method.
/// `r` = number of outlier channels (the ResQ residual rank for
/// [`Method::Resq`]), `bits` = activation precision, `w_bits` = weight
/// precision — W4A8 passes (8, 4) and the weight byte terms halve.
#[allow(clippy::too_many_arguments)]
pub fn layer_cost(
    cfg: &NpuConfig,
    method: Method,
    t: usize,
    k: usize,
    n: usize,
    r: usize,
    bits: u32,
    w_bits: u32,
) -> Cost {
    let act_prec = if bits <= 4 { Precision::Int4 } else { Precision::Int8 };
    let w_prec = if w_bits <= 4 { Precision::Int4 } else { Precision::Int8 };
    match method {
        Method::Fp16 => gemm_cost(cfg, t, k, n, Precision::Fp16),
        Method::Naive => gemm_cost_w(cfg, t, k, n, act_prec, w_prec),
        Method::Muxq => {
            // Body and Aux concatenate into ONE uniform-INT GEMM with
            // inner dimension k + r:
            //   Y = [Body | f*Aux] @ [W ; W_outlier_rows]
            // (the (2^exp - 1) factor folds into Aux's dequant scale).
            // Decompose fuses with the quantize-on-DMA-in pass, so the
            // only cost over naive is streaming r extra channels — the
            // "small additional computation" of the paper's conclusion.
            gemm_cost_w(cfg, t, k + r, n, act_prec, w_prec)
        }
        Method::LlmInt8 => {
            // INT GEMM over normal channels + FP16 GEMM over outliers +
            // irregular gather/scatter of the outlier slice + a precision
            // domain switch.
            let mut c = gemm_cost_w(cfg, t, k.saturating_sub(r).max(1), n, act_prec, w_prec);
            if r > 0 {
                c.add(gemm_cost(cfg, t, r, n, Precision::Fp16));
                let gather_bytes = (t * r) as f64 * 2.0 * 2.0; // gather + scatter, fp16
                c.extra_cycles += gather_bytes / cfg.gather_bytes_per_cycle;
                c.extra_cycles += cfg.domain_switch_cycles as f64;
            }
            c
        }
        Method::Resq => {
            // W4 body over the FULL k (nothing is carved out of the
            // nibble-packed W) + a skinny rank-r FP16 residual GEMM over
            // the compact [r, n] residual. The covered activation
            // columns gather at the irregular rate (no scatter — the
            // residual accumulates in place) and the FP leg costs one
            // precision domain switch.
            let mut c = gemm_cost_w(cfg, t, k, n, act_prec, w_prec);
            if r > 0 {
                c.add(gemm_cost(cfg, t, r, n, Precision::Fp16));
                let gather_bytes = (t * r) as f64 * 2.0; // gather only, fp16
                c.extra_cycles += gather_bytes / cfg.gather_bytes_per_cycle;
                c.extra_cycles += cfg.domain_switch_cycles as f64;
            }
            c
        }
    }
}

/// End-to-end cost of a model's projection stack for one batch.
/// Shapes: per block (c_attn [t,d,3d], attn_proj [t,d,d], c_fc [t,d,4d],
/// mlp_proj [t,4d,d]); `r` outliers at the two post-LN sites.
#[allow(clippy::too_many_arguments)]
pub fn model_cost(
    cfg: &NpuConfig,
    method: Method,
    n_layer: usize,
    t: usize,
    d: usize,
    r: usize,
    bits: u32,
    w_bits: u32,
) -> Cost {
    let mut total = Cost::default();
    for _ in 0..n_layer {
        total.add(layer_cost(cfg, method, t, d, 3 * d, r, bits, w_bits)); // c_attn
        total.add(layer_cost(cfg, method, t, d, d, 0, bits, w_bits)); // attn_proj
        total.add(layer_cost(cfg, method, t, d, 4 * d, r, bits, w_bits)); // c_fc
        total.add(layer_cost(cfg, method, t, 4 * d, d, 0, bits, w_bits)); // mlp_proj
    }
    total
}

/// Cost of ONE autoregressive decode step (t = 1) through a model's
/// projection stack — the latency-bound serving regime
/// (`coordinator::generation`). At t=1 every projection is memory-bound
/// (see [`gemm_plan::Plan::decode_step`]): latency ≈ weight bytes /
/// bandwidth, which is exactly why uniform INT8 — half of FP16's bytes —
/// wins decode latency even where it ties on MACs, and why LLM.int8()'s
/// FP16 outlier leg hurts most here.
pub fn decode_cost(
    cfg: &NpuConfig,
    method: Method,
    n_layer: usize,
    d: usize,
    r: usize,
    bits: u32,
    w_bits: u32,
) -> Cost {
    model_cost(cfg, method, n_layer, 1, d, r, bits, w_bits)
}

/// Simulated steady-state decode throughput (tokens/s) implied by
/// [`decode_cost`]. (KV-cache attention traffic is outside the model,
/// consistent with [`model_cost`] pricing projections only.)
pub fn decode_tok_per_s(
    cfg: &NpuConfig,
    method: Method,
    n_layer: usize,
    d: usize,
    r: usize,
    bits: u32,
    w_bits: u32,
) -> f64 {
    let us = decode_cost(cfg, method, n_layer, d, r, bits, w_bits).latency_us(cfg);
    if us <= 0.0 {
        return 0.0;
    }
    1e6 / us
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: usize = 1024;
    const D: usize = 768;

    #[test]
    fn int8_beats_fp16_by_about_fp16_slowdown() {
        let cfg = NpuConfig::default();
        let fp = gemm_cost(&cfg, T, D, D, Precision::Fp16);
        let i8 = gemm_cost(&cfg, T, D, D, Precision::Int8);
        let ratio = fp.cycles() / i8.cycles();
        // ">2x" is the paper's premise; with default params it's ~4x
        // compute-bound, diluted by DMA overlap
        assert!(ratio > 2.0, "ratio {ratio}");
    }

    #[test]
    fn muxq_overhead_small_vs_naive() {
        let cfg = NpuConfig::default();
        let r = 8; // few outlier channels (the paper's premise)
        let naive = model_cost(&cfg, Method::Naive, 12, T, D, r, 8, 8);
        let muxq = model_cost(&cfg, Method::Muxq, 12, T, D, r, 8, 8);
        let overhead = muxq.cycles() / naive.cycles() - 1.0;
        assert!(overhead > 0.0);
        assert!(overhead < 0.15, "muxq overhead {overhead}");
    }

    #[test]
    fn muxq_faster_than_llmint8() {
        let cfg = NpuConfig::default();
        let r = 8;
        let muxq = model_cost(&cfg, Method::Muxq, 12, T, D, r, 8, 8);
        let mixed = model_cost(&cfg, Method::LlmInt8, 12, T, D, r, 8, 8);
        assert!(
            muxq.cycles() < mixed.cycles(),
            "muxq {} vs llmint8 {}",
            muxq.cycles(),
            mixed.cycles()
        );
    }

    #[test]
    fn muxq_faster_than_fp16() {
        let cfg = NpuConfig::default();
        let muxq = model_cost(&cfg, Method::Muxq, 12, T, D, 8, 8, 8);
        let fp = model_cost(&cfg, Method::Fp16, 12, T, D, 0, 8, 8);
        assert!(muxq.cycles() < fp.cycles() / 1.5);
    }

    #[test]
    fn pair_accumulation_halves_int8_compute() {
        // compute-bound shape: the i16 pair datapath (default) must show
        // exactly 2x the MAC throughput of 32-bit lanes, and the latency
        // win must survive the DMA overlap
        let pair = NpuConfig::default();
        let wide = NpuConfig::default().with_acc_width(32);
        assert_eq!(pair.int_macs_per_cycle(), 2.0);
        assert_eq!(wide.int_macs_per_cycle(), 1.0);
        let cp = gemm_cost(&pair, 4096, 4096, 4096, Precision::Int8);
        let cw = gemm_cost(&wide, 4096, 4096, 4096, Precision::Int8);
        assert!((cw.compute_cycles / cp.compute_cycles - 2.0).abs() < 1e-9);
        assert!(cp.cycles() < cw.cycles());
        // energy is unchanged: same MACs, different widening cadence
        assert_eq!(cp.energy_pj, cw.energy_pj);
    }

    #[test]
    fn dot_width_models_sdot_class_quad_macs() {
        // a 4-way dot unit halves INT compute again vs the pair lanes,
        // at identical energy (same multiplies, different cadence)
        let pair = NpuConfig::default();
        let quad = NpuConfig::default().with_dot_width(4);
        assert_eq!(quad.int_macs_per_cycle(), 4.0);
        let cp = gemm_cost(&pair, 4096, 4096, 4096, Precision::Int8);
        let cq = gemm_cost(&quad, 4096, 4096, 4096, Precision::Int8);
        assert!((cp.compute_cycles / cq.compute_cycles - 2.0).abs() < 1e-9);
        assert_eq!(cp.energy_pj, cq.energy_pj);
        // with_acc_width clears the dot override so the width governs
        assert_eq!(quad.with_acc_width(32).int_macs_per_cycle(), 1.0);
    }

    #[test]
    fn for_kernel_maps_dispatch_onto_mac_cadence() {
        use crate::quant::simd::DispatchKernel as K;
        assert_eq!(NpuConfig::for_kernel(K::Scalar).int_macs_per_cycle(), 1.0);
        assert_eq!(NpuConfig::for_kernel(K::Pair).int_macs_per_cycle(), 2.0);
        assert_eq!(NpuConfig::for_kernel(K::Avx2).int_macs_per_cycle(), 2.0);
        assert_eq!(NpuConfig::for_kernel(K::Neon).int_macs_per_cycle(), 4.0);
        // compute-bound ordering follows the cadence; the memory-bound
        // decode regime is kernel-agnostic (bytes don't change)
        let c = |k| gemm_cost(&NpuConfig::for_kernel(k), 4096, 4096, 4096, Precision::Int8)
            .compute_cycles;
        assert!(c(K::Neon) < c(K::Avx2));
        assert_eq!(c(K::Avx2), c(K::Pair));
        assert!(c(K::Avx2) < c(K::Scalar));
        let d =
            |k| decode_cost(&NpuConfig::for_kernel(k), Method::Muxq, 12, D, 8, 8, 8).cycles();
        assert_eq!(d(K::Neon), d(K::Scalar), "M=1 decode is bytes-bound on every kernel");
    }

    #[test]
    fn fp16_unaffected_by_int_accumulator_width() {
        let pair = NpuConfig::default();
        let wide = NpuConfig::default().with_acc_width(32);
        let a = gemm_cost(&pair, T, D, D, Precision::Fp16);
        let b = gemm_cost(&wide, T, D, D, Precision::Fp16);
        assert_eq!(a.compute_cycles, b.compute_cycles);
    }

    #[test]
    fn int4_cheaper_than_int8() {
        let cfg = NpuConfig::default();
        let a = model_cost(&cfg, Method::Naive, 4, T, D, 0, 4, 4);
        let b = model_cost(&cfg, Method::Naive, 4, T, D, 0, 8, 8);
        assert!(a.cycles() < b.cycles());
    }

    #[test]
    fn decode_tok_per_s_ordering() {
        // steady-state decode throughput: uniform INT8 (muxq) pays only
        // the r extra channels vs naive, and beats both the mixed
        // pipeline and fp16 — at decode the gap is byte-driven
        let cfg = NpuConfig::default();
        let r = 8;
        let tps = |m| decode_tok_per_s(&cfg, m, 12, D, r, 8, 8);
        let (naive, muxq, mixed, fp) =
            (tps(Method::Naive), tps(Method::Muxq), tps(Method::LlmInt8), tps(Method::Fp16));
        assert!(naive > 0.0 && muxq > 0.0);
        assert!(naive >= muxq, "naive {naive} vs muxq {muxq}");
        assert!(muxq / naive > 0.95, "muxq decode overhead must be tiny");
        assert!(muxq > mixed, "muxq {muxq} vs llmint8 {mixed}");
        assert!(muxq > fp, "muxq {muxq} vs fp16 {fp}");
    }

    #[test]
    fn energy_ordering() {
        let cfg = NpuConfig::default();
        let r = 8;
        let e_naive = model_cost(&cfg, Method::Naive, 12, T, D, r, 8, 8).energy_pj;
        let e_muxq = model_cost(&cfg, Method::Muxq, 12, T, D, r, 8, 8).energy_pj;
        let e_fp = model_cost(&cfg, Method::Fp16, 12, T, D, r, 8, 8).energy_pj;
        assert!(e_naive < e_muxq); // aux GEMM costs a bit
        assert!(e_muxq < e_fp); // but INT stays well below FP16
    }

    #[test]
    fn outlier_count_scales_gap() {
        // more outlier channels -> llm.int8 pays more vs muxq
        let cfg = NpuConfig::default();
        let gap = |r| {
            let m = model_cost(&cfg, Method::Muxq, 12, T, D, r, 8, 8).cycles();
            let l = model_cost(&cfg, Method::LlmInt8, 12, T, D, r, 8, 8).cycles();
            l / m
        };
        assert!(gap(32) > gap(4));
    }
}
