//! GEMM execution plans: the per-method breakdown of which GEMMs run at
//! which precision — used by the report generator and the exp_factor
//! ablation (recombination cost appears when 2^exp − 1 != 1, paper §3.3).
//!
//! Plans price through [`gemm_cost_w`](super::gemm_cost_w), so they
//! inherit the widened-MAC datapath model: `NpuConfig::acc_width_bits ==
//! 16` (the default) retires two i8 MACs per lane per cycle, matching
//! the rust engine's i16 pair-accumulation microkernel — and the
//! split activation/weight precisions, so W4A8 plans stream nibble
//! weight panels (0.5 B/elem) against full INT8 activations.
//! [`Plan::widened_mac_speedup`] quantifies what the pairing buys one
//! plan end to end.

use super::{gemm_cost_w, model_cost, Cost, NpuConfig, Precision};
use crate::quant::{Method, PreTransform};

/// One GEMM in a plan. Activation operand at `prec`, weight operand at
/// `w_prec` — split so W4A8 plans price the nibble weight stream
/// without touching the activation side.
#[derive(Debug, Clone)]
pub struct PlannedGemm {
    pub label: &'static str,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub prec: Precision,
    pub w_prec: Precision,
}

/// A method's execution plan for one projection.
#[derive(Debug, Clone)]
pub struct Plan {
    pub method: Method,
    pub gemms: Vec<PlannedGemm>,
    /// non-GEMM cycles (gather/scatter, domain switches, recombination)
    pub overhead_cycles: f64,
    /// cycles spent rewriting weight operands into the array's panel
    /// layout. 0 in [`Plan::build`]: the deployment pipeline packs
    /// weights once at load time (`gpt2::quantized` / `quant::packed`),
    /// so no per-call traversal cost remains. [`Plan::with_weight_repack`]
    /// models the pre-packed-layout engine that re-packed per call.
    pub pack_cycles: f64,
}

impl Plan {
    /// Build the plan for projection [t,k]@[k,n] with r outlier channels
    /// (for ResQ, r is the residual rank). `exp_factor` only matters for
    /// MUXQ: when != 1, the recombination needs a scaled add over the
    /// output (t*n fp16 elements through the vector unit) instead of
    /// folding into the accumulate. `bits` sets the activation
    /// precision, `w_bits` the weight-stream precision — `w_bits <= 4`
    /// prices the nibble-packed panels at 0.5 B/elem.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        cfg: &NpuConfig,
        method: Method,
        t: usize,
        k: usize,
        n: usize,
        r: usize,
        bits: u32,
        w_bits: u32,
        exp_factor: u32,
    ) -> Plan {
        let act_prec = if bits <= 4 { Precision::Int4 } else { Precision::Int8 };
        let w_prec = if w_bits <= 4 { Precision::Int4 } else { Precision::Int8 };
        match method {
            Method::Fp16 => Plan {
                method,
                gemms: vec![PlannedGemm {
                    label: "fp16",
                    m: t,
                    k,
                    n,
                    prec: Precision::Fp16,
                    w_prec: Precision::Fp16,
                }],
                overhead_cycles: 0.0,
                pack_cycles: 0.0,
            },
            Method::Naive => Plan {
                method,
                gemms: vec![PlannedGemm { label: "int", m: t, k, n, prec: act_prec, w_prec }],
                overhead_cycles: 0.0,
                pack_cycles: 0.0,
            },
            Method::Muxq => {
                // Preferred lowering: concat into one uniform GEMM
                // (Y = [Body | f*Aux] @ [W ; W_rows]); the 2^exp - 1
                // factor folds into Aux's dequant scale. When the
                // implementation cannot fold (e.g. shared per-tensor
                // scale, the paper's exp_factor != 1 caveat), Aux runs
                // as a separate skinny GEMM + scaled add.
                if exp_factor == 1 || r == 0 {
                    Plan {
                        method,
                        gemms: vec![PlannedGemm {
                            label: "body+aux(concat)",
                            m: t,
                            k: k + r,
                            n,
                            prec: act_prec,
                            w_prec,
                        }],
                        overhead_cycles: 0.0,
                        pack_cycles: 0.0,
                    }
                } else {
                    Plan {
                        method,
                        gemms: vec![
                            PlannedGemm { label: "body", m: t, k, n, prec: act_prec, w_prec },
                            PlannedGemm { label: "aux", m: t, k: r, n, prec: act_prec, w_prec },
                        ],
                        // scaled recombination on the vector unit
                        // (t*n fused multiply-adds, 64 lanes, overlapped
                        // with the aux GEMM drain in practice)
                        overhead_cycles: (t * n) as f64 / 64.0,
                        pack_cycles: 0.0,
                    }
                }
            }
            Method::LlmInt8 => {
                let mut gemms = vec![PlannedGemm {
                    label: "int-normal",
                    m: t,
                    k: k.saturating_sub(r).max(1),
                    n,
                    prec: act_prec,
                    w_prec,
                }];
                let mut overhead = 0.0;
                if r > 0 {
                    gemms.push(PlannedGemm {
                        label: "fp16-outlier",
                        m: t,
                        k: r,
                        n,
                        prec: Precision::Fp16,
                        w_prec: Precision::Fp16,
                    });
                    let gather_bytes = (t * r) as f64 * 2.0 * 2.0;
                    overhead += gather_bytes / cfg.gather_bytes_per_cycle;
                    overhead += cfg.domain_switch_cycles as f64;
                }
                Plan { method, gemms, overhead_cycles: overhead, pack_cycles: 0.0 }
            }
            Method::Resq => {
                // W4 body over the FULL k (the residual is an additive
                // correction, not a column split like LLM.int8()), plus
                // a skinny rank-r FP leg over the compact residual.
                let mut gemms = vec![PlannedGemm {
                    label: "int-body",
                    m: t,
                    k,
                    n,
                    prec: act_prec,
                    w_prec,
                }];
                let mut overhead = 0.0;
                if r > 0 {
                    gemms.push(PlannedGemm {
                        label: "fp-residual",
                        m: t,
                        k: r,
                        n,
                        prec: Precision::Fp16,
                        w_prec: Precision::Fp16,
                    });
                    // gather t*r activation columns into the compact
                    // residual operand; no scatter back — the leg
                    // accumulates straight into the dequant output
                    let gather_bytes = (t * r) as f64 * 2.0;
                    overhead += gather_bytes / cfg.gather_bytes_per_cycle;
                    overhead += cfg.domain_switch_cycles as f64;
                }
                Plan { method, gemms, overhead_cycles: overhead, pack_cycles: 0.0 }
            }
        }
    }

    /// The autoregressive decode-step plan: the same lowering as
    /// [`Plan::build`] at `t = 1` — one token's activations against the
    /// full `[k, n]` weight. At M=1 the array streams the whole weight
    /// matrix to retire only `k·n` MACs, so DMA dominates compute by
    /// roughly the arithmetic-intensity deficit (`array utilization ~
    /// 1/array_dim`): decode latency is **bytes-dominated**, the regime
    /// where the INT8-vs-FP16 operand-size halving buys latency directly
    /// (the rust engine's GEMV path is the kernel-level twin) — and
    /// where `w_bits = 4` halves the dominant weight stream again.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_step(
        cfg: &NpuConfig,
        method: Method,
        k: usize,
        n: usize,
        r: usize,
        bits: u32,
        w_bits: u32,
        exp_factor: u32,
    ) -> Plan {
        Self::build(cfg, method, 1, k, n, r, bits, w_bits, exp_factor)
    }

    /// (compute, dma) cycle totals across the plan's GEMMs — the split
    /// [`Plan::cost`] folds away via sequential composition.
    pub fn compute_dma_split(&self, cfg: &NpuConfig) -> (f64, f64) {
        self.gemms.iter().fold((0.0, 0.0), |(c, d), g| {
            let gc = gemm_cost_w(cfg, g.m, g.k, g.n, g.prec, g.w_prec);
            (c + gc.compute_cycles, d + gc.dma_cycles)
        })
    }

    /// Whether DMA traffic (not MACs) bounds this plan's latency — true
    /// for every decode-step plan on realistic configs.
    pub fn is_memory_bound(&self, cfg: &NpuConfig) -> bool {
        let (compute, dma) = self.compute_dma_split(cfg);
        dma > compute
    }

    /// Bytes moved per execution of this plan (operands + results; at
    /// M=1 the `k·n` weight stream dominates).
    pub fn bytes_per_step(&self) -> f64 {
        self.gemms
            .iter()
            .map(|g| {
                (g.m * g.k) as f64 * g.prec.bytes()
                    + (g.k * g.n) as f64 * g.w_prec.bytes()
                    + (g.m * g.n) as f64 * 2.0
            })
            .sum()
    }

    /// Model a deployment that re-packs weight operands on every call —
    /// what the rust engine did before `PackedMatI8`: each GEMM's [k, n]
    /// weight matrix is rewritten once into the K-major panel layout
    /// before the MAC array can stream it.
    pub fn with_weight_repack(mut self, cfg: &NpuConfig) -> Plan {
        let bytes: f64 =
            self.gemms.iter().map(|g| (g.k * g.n) as f64 * g.w_prec.bytes()).sum();
        self.pack_cycles += bytes / cfg.pack_bytes_per_cycle;
        self
    }

    /// Price the activation-side pre-transform pipeline into this plan:
    /// the weight-side halves are folded at pack time and cost nothing
    /// per call, but each step must touch the live `[t, k]` activation
    /// tile before the quantizer sees it. `Smooth` is an elementwise
    /// divide on the vector unit; `Permute` moves the tile at the
    /// irregular-gather rate (the same penalty the mixed-precision
    /// split pays); `Rotate` is real extra GEMM work — every rotated
    /// channel contracts a `block`-wide sliver of the row, so the tile
    /// prices as one skinny FP GEMM `[t, block] @ [block, k]` on top of
    /// the method's own lowering (the host twin is
    /// [`crate::quant::transform::BlockRot::apply_to_row`]).
    pub fn with_act_pre_transforms(
        mut self,
        cfg: &NpuConfig,
        t: usize,
        k: usize,
        pre: &[PreTransform],
    ) -> Plan {
        for step in pre {
            match step {
                PreTransform::Smooth { .. } => {
                    // per-channel divide: t*k elements, 64 vector lanes
                    self.overhead_cycles += (t * k) as f64 / 64.0;
                }
                PreTransform::Permute { .. } => {
                    // gather the fp16 activation tile through the
                    // channel-order table (non-contiguous by design)
                    self.overhead_cycles +=
                        (t * k) as f64 * 2.0 / cfg.gather_bytes_per_cycle;
                }
                PreTransform::Rotate { block } => {
                    self.gemms.push(PlannedGemm {
                        label: "rot-pre",
                        m: t,
                        k: (*block).max(1),
                        n: k,
                        prec: Precision::Fp16,
                        w_prec: Precision::Fp16,
                    });
                }
            }
        }
        self
    }

    /// K + V bytes of `ctx_rows` live cache rows (f32, d_model wide —
    /// the layout `gpt2::KvCache` stores).
    fn kv_bytes(ctx_rows: usize, d_model: usize) -> f64 {
        (ctx_rows * d_model) as f64 * 2.0 * 4.0
    }

    /// Price the attention read of a CONTIGUOUS (ring) KV cache into
    /// this plan: `ctx_rows` K and V rows stream at full DRAM bandwidth
    /// — the pre-pager baseline [`Plan::with_paged_kv_gather`] is
    /// compared against.
    pub fn with_contiguous_kv(mut self, cfg: &NpuConfig, ctx_rows: usize, d_model: usize) -> Plan {
        if ctx_rows == 0 {
            return self;
        }
        let bytes_per_cycle = cfg.dram_gbps * 1e9 / (cfg.freq_ghz * 1e9);
        self.overhead_cycles += Self::kv_bytes(ctx_rows, d_model) / bytes_per_cycle;
        self
    }

    /// Price the attention read of a PAGED KV cache into this plan:
    /// `ctx_rows` live rows scattered across `page_rows`-sized pages.
    /// The block table makes the access non-contiguous, so the bytes
    /// move at the irregular-gather rate (`gather_bytes_per_cycle`, the
    /// same penalty the mixed-precision split pays) and every page costs
    /// one K burst + one V burst of DMA descriptor setup
    /// (`page_gather_setup_cycles`). Larger pages amortize the setup —
    /// exactly the fill-vs-gather trade the page-size knob tunes.
    pub fn with_paged_kv_gather(
        mut self,
        cfg: &NpuConfig,
        ctx_rows: usize,
        d_model: usize,
        page_rows: usize,
    ) -> Plan {
        if ctx_rows == 0 {
            return self;
        }
        let page_rows = page_rows.max(1);
        let pages = ctx_rows.div_ceil(page_rows);
        self.overhead_cycles += Self::kv_bytes(ctx_rows, d_model) / cfg.gather_bytes_per_cycle
            + (2 * pages) as f64 * cfg.page_gather_setup_cycles;
        self
    }

    /// End-to-end latency ratio of this plan on a 32-bit-lane (one MAC
    /// per cycle) datapath vs the i16 pair-accumulation datapath, same
    /// config otherwise. In [1, 2]: compute-bound INT plans approach 2x;
    /// DMA-bound plans, fixed overheads and FP16 work dilute the ratio
    /// toward — and for pure-FP16 plans exactly to — 1.
    pub fn widened_mac_speedup(&self, cfg: &NpuConfig) -> f64 {
        let wide = self.cost(&cfg.clone().with_acc_width(32)).cycles();
        let pair = self.cost(&cfg.clone().with_acc_width(16)).cycles();
        if pair == 0.0 {
            return 1.0;
        }
        wide / pair
    }

    pub fn cost(&self, cfg: &NpuConfig) -> Cost {
        let mut total = Cost::default();
        for g in &self.gemms {
            total.add(gemm_cost_w(cfg, g.m, g.k, g.n, g.prec, g.w_prec));
        }
        total.extra_cycles += self.overhead_cycles + self.pack_cycles;
        total
    }

    /// Fraction of cycles spent outside the uniform INT dataflow
    /// (the "hardware-unfriendliness" metric for Fig. 4's comparison).
    pub fn non_uniform_fraction(&self, cfg: &NpuConfig) -> f64 {
        let total = self.cost(cfg).cycles();
        if total == 0.0 {
            return 0.0;
        }
        let fp: f64 = self
            .gemms
            .iter()
            .filter(|g| g.prec == Precision::Fp16 && self.method != Method::Fp16)
            .map(|g| gemm_cost_w(cfg, g.m, g.k, g.n, g.prec, g.w_prec).cycles())
            .sum();
        // MUXQ's recombination is an INT vector add (uniform dataflow);
        // LLM.int8()'s gather/scatter + domain switch is irregular, and
        // so is ResQ's residual-leg gather + domain switch.
        let irregular = if matches!(self.method, Method::LlmInt8 | Method::Resq) {
            self.overhead_cycles
        } else {
            0.0
        };
        (fp + irregular) / total
    }
}

/// Pricing of ONE speculative draft-and-verify round vs sequential
/// decode (`gpt2::speculative` is the host twin): the target scores
/// k+1 positions in one `t = k+1` pass, the draft pays `k` of its own
/// decode steps, and the round emits `E[tokens] = Σ_{i=0..k} α^i` for
/// acceptance rate α (i.i.d. acceptance model — the standard expected
/// length of the accepted prefix plus the correction/bonus token).
///
/// Why speculation wins exactly here: decode is **bytes-dominated**
/// ([`Plan::decode_step`] is memory-bound on every INT config), so the
/// (k+1)-row verify streams the same weights as ONE step — its cost
/// barely grows with k — while each accepted token saves a whole
/// sequential step. The sim predicts the speedup before CI measures it.
#[derive(Debug, Clone)]
pub struct SpecRoundPlan {
    /// the (k+1)-row verify pass on the target
    pub verify: Plan,
    /// one draft decode step (same method/shape scaled by `draft_scale`)
    pub draft_step: Plan,
    /// one plain target decode step — the sequential baseline unit
    pub target_step: Plan,
    pub k: usize,
    /// draft cost as a fraction of a target step (depth-truncated draft:
    /// n_draft_layers / n_layers; quantized draft: its plan ratio)
    pub draft_scale: f64,
    /// expected fraction of drafts accepted (α)
    pub accept_rate: f64,
}

impl SpecRoundPlan {
    /// Build from the projection shape `[k_dim, n]` the decode plans
    /// price (per-layer composition is linear, so one projection's ratio
    /// is the model's).
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        cfg: &NpuConfig,
        method: Method,
        k: usize,
        k_dim: usize,
        n: usize,
        r: usize,
        bits: u32,
        w_bits: u32,
        exp_factor: u32,
        draft_scale: f64,
        accept_rate: f64,
    ) -> SpecRoundPlan {
        SpecRoundPlan {
            verify: Plan::build(cfg, method, k + 1, k_dim, n, r, bits, w_bits, exp_factor),
            draft_step: Plan::decode_step(cfg, method, k_dim, n, r, bits, w_bits, exp_factor),
            target_step: Plan::decode_step(cfg, method, k_dim, n, r, bits, w_bits, exp_factor),
            k,
            draft_scale,
            accept_rate,
        }
    }

    /// Expected tokens emitted per round: `Σ_{i=0..k} α^i` (accepted
    /// prefix + the always-emitted correction/bonus).
    pub fn expected_tokens(&self) -> f64 {
        let a = self.accept_rate.clamp(0.0, 1.0);
        (0..=self.k).map(|i| a.powi(i as i32)).sum()
    }

    /// Cycles of one round: the verify pass plus k draft steps at
    /// `draft_scale` of a target step each.
    pub fn round_cycles(&self, cfg: &NpuConfig) -> f64 {
        self.verify.cost(cfg).cycles()
            + self.k as f64 * self.draft_scale * self.draft_step.cost(cfg).cycles()
    }

    /// Predicted tokens/s ratio vs plain sequential decode:
    /// `(E[tokens] / round_cycles) / (1 / step_cycles)`. Above 1 means
    /// speculation pays on this config.
    pub fn tok_s_ratio_vs_sequential(&self, cfg: &NpuConfig) -> f64 {
        let round = self.round_cycles(cfg);
        if round == 0.0 {
            return 1.0;
        }
        self.expected_tokens() * self.target_step.cost(cfg).cycles() / round
    }
}

/// Pricing of ONE multi-tenant batched decode tick — the serving
/// front end's steady state (`coordinator::generation` + the `serve`
/// HTTP layer are the host twins). `batch` live sessions each advance
/// one token in a single `t = batch` forward pass through the
/// projection stack, and before the pass launches the scheduler pays
/// deficit-weighted round-robin bookkeeping
/// ([`NpuConfig::tenant_sched_cycles`]) once per tenant lane with live
/// work.
///
/// Why batching wins exactly here: a `t = 1` decode step is
/// bytes-dominated ([`Plan::decode_step`]), so a `t = G` pass streams
/// the SAME weight bytes to advance G sessions — per-token latency
/// drops nearly G-fold until compute catches the byte stream. The
/// per-tenant overhead is the price of fairness: it grows with lane
/// count, not batch size, so consolidating tenants never beats adding
/// batch rows. The stress harness (`examples/stress.rs`) reports this
/// plan's predicted utilization next to the measured serving numbers.
#[derive(Debug, Clone)]
pub struct ServeTickPlan {
    pub method: Method,
    pub n_layer: usize,
    pub d_model: usize,
    /// outlier channels / residual rank at the post-LN sites
    pub r: usize,
    pub bits: u32,
    pub w_bits: u32,
    /// live sessions advanced per tick (decode batch rows)
    pub batch: usize,
    /// distinct tenant lanes holding those sessions (`<= batch` in any
    /// real schedule; clamped up to 1)
    pub n_tenants: usize,
}

impl ServeTickPlan {
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        method: Method,
        n_layer: usize,
        d_model: usize,
        r: usize,
        bits: u32,
        w_bits: u32,
        batch: usize,
        n_tenants: usize,
    ) -> ServeTickPlan {
        ServeTickPlan {
            method,
            n_layer,
            d_model,
            r,
            bits,
            w_bits,
            batch: batch.max(1),
            n_tenants: n_tenants.clamp(1, batch.max(1)),
        }
    }

    /// DWRR bookkeeping cycles per tick: one credit/rotation pass per
    /// tenant lane.
    pub fn sched_cycles(&self, cfg: &NpuConfig) -> f64 {
        self.n_tenants as f64 * cfg.tenant_sched_cycles
    }

    /// Full cost of one tick: the batched `t = batch` projection pass
    /// plus the per-tenant scheduling overhead (serial with the pass —
    /// admission decides the rows before the DMA queue fills).
    pub fn tick_cost(&self, cfg: &NpuConfig) -> Cost {
        let mut c = model_cost(
            cfg,
            self.method,
            self.n_layer,
            self.batch,
            self.d_model,
            self.r,
            self.bits,
            self.w_bits,
        );
        c.extra_cycles += self.sched_cycles(cfg);
        c
    }

    /// Wall-clock per token emitted: tick latency / batch rows.
    pub fn per_token_latency_us(&self, cfg: &NpuConfig) -> f64 {
        self.tick_cost(cfg).latency_us(cfg) / self.batch as f64
    }

    /// Aggregate serving throughput ceiling (tokens/s across all
    /// tenants) with the array ticking back to back.
    pub fn tok_per_s(&self, cfg: &NpuConfig) -> f64 {
        let us = self.tick_cost(cfg).latency_us(cfg);
        if us <= 0.0 {
            return 0.0;
        }
        self.batch as f64 * 1e6 / us
    }

    /// Fraction of the tick spent on fairness bookkeeping rather than
    /// the forward pass — the QoS tax. Tiny at defaults; grows linearly
    /// with tenant count.
    pub fn sched_overhead_fraction(&self, cfg: &NpuConfig) -> f64 {
        let total = self.tick_cost(cfg).cycles();
        if total <= 0.0 {
            return 0.0;
        }
        self.sched_cycles(cfg) / total
    }

    /// Simulated NPU utilization at an offered aggregate load: the
    /// fraction of wall time the array + DMA is busy if the serving
    /// plane sustains `offered_tok_s` tokens/s. Clamps at 1.0 — offered
    /// load beyond [`ServeTickPlan::tok_per_s`] queues (and eventually
    /// sheds as 429/503), it cannot raise utilization further.
    pub fn utilization(&self, cfg: &NpuConfig, offered_tok_s: f64) -> f64 {
        let cap = self.tok_per_s(cfg);
        if cap <= 0.0 || offered_tok_s <= 0.0 {
            return 0.0;
        }
        (offered_tok_s / cap).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_shapes() {
        let cfg = NpuConfig::default();
        let p = Plan::build(&cfg, Method::Muxq, 512, 768, 768, 12, 8, 8, 2);
        assert_eq!(p.gemms.len(), 2, "exp!=1 falls back to two GEMMs");
        assert_eq!(p.gemms[1].k, 12);
        assert!(p.overhead_cycles > 0.0, "exp=2 pays recombination");
        let p1 = Plan::build(&cfg, Method::Muxq, 512, 768, 768, 12, 8, 8, 1);
        assert_eq!(p1.gemms.len(), 1, "exp=1 concatenates");
        assert_eq!(p1.gemms[0].k, 768 + 12);
        assert_eq!(p1.overhead_cycles, 0.0, "exp=1 is a plain sum");
    }

    #[test]
    fn muxq_stays_uniform_int() {
        let cfg = NpuConfig::default();
        let muxq = Plan::build(&cfg, Method::Muxq, 512, 768, 768, 12, 8, 8, 2);
        let mixed = Plan::build(&cfg, Method::LlmInt8, 512, 768, 768, 12, 8, 8, 2);
        assert!(muxq.non_uniform_fraction(&cfg) < 0.02);
        assert!(mixed.non_uniform_fraction(&cfg) > muxq.non_uniform_fraction(&cfg));
    }

    #[test]
    fn prepacked_weights_beat_per_call_repack() {
        // Plan::build assumes load-time packing (pack_cycles == 0); the
        // per-call repack variant must cost strictly more, by exactly the
        // panel-rewrite traversal of every weight operand.
        let cfg = NpuConfig::default();
        let plan = Plan::build(&cfg, Method::Muxq, 512, 768, 768, 12, 8, 8, 2);
        assert_eq!(plan.pack_cycles, 0.0, "deployment packs at load time");
        let repack = plan.clone().with_weight_repack(&cfg);
        let bytes: f64 = plan.gemms.iter().map(|g| (g.k * g.n) as f64).sum();
        assert!(repack.pack_cycles > 0.0);
        assert_eq!(repack.pack_cycles, bytes / cfg.pack_bytes_per_cycle);
        assert!(repack.cost(&cfg).cycles() > plan.cost(&cfg).cycles());
    }

    #[test]
    fn widened_mac_datapath_tracks_pair_kernel() {
        let cfg = NpuConfig::default();
        // compute-bound INT plan: pairing buys a real speedup, capped at 2x
        let muxq = Plan::build(&cfg, Method::Muxq, 4096, 4096, 4096, 16, 8, 8, 2);
        let s = muxq.widened_mac_speedup(&cfg);
        assert!(s > 1.2 && s <= 2.0 + 1e-9, "speedup {s}");
        // a pure-FP16 plan is untouched by the INT accumulator width
        let fp = Plan::build(&cfg, Method::Fp16, 4096, 4096, 4096, 0, 8, 8, 1);
        assert!((fp.widened_mac_speedup(&cfg) - 1.0).abs() < 1e-9);
        // LLM.int8() keeps an FP16 leg, so its benefit must be smaller
        // than the uniform-INT plan's
        let mixed = Plan::build(&cfg, Method::LlmInt8, 4096, 4096, 4096, 16, 8, 8, 2);
        assert!(mixed.widened_mac_speedup(&cfg) < s);
    }

    #[test]
    fn decode_step_int_is_memory_bound_fp16_is_not() {
        // M=1 INT: the whole weight streams to retire only k·n MACs —
        // DMA dominates. FP16 decode on the INT-oriented NPU stays
        // compute-bound (4x-slow FP16 MACs never reach the bandwidth
        // roof) — the roofline version of the paper's INT8 premise.
        let cfg = NpuConfig::default();
        for method in [Method::Naive, Method::Muxq] {
            let p = Plan::decode_step(&cfg, method, 768, 2304, 12, 8, 8, 2);
            let (compute, dma) = p.compute_dma_split(&cfg);
            assert!(p.is_memory_bound(&cfg), "{method:?}: compute {compute} dma {dma}");
        }
        let fp = Plan::decode_step(&cfg, Method::Fp16, 768, 2304, 0, 16, 16, 1);
        assert!(!fp.is_memory_bound(&cfg), "fp16 decode is MAC-bound here");
        // and a large-batch INT plan is compute-bound: decode is special
        let batch = Plan::build(&cfg, Method::Muxq, 4096, 4096, 4096, 12, 8, 8, 2);
        assert!(!batch.is_memory_bound(&cfg), "big-batch plan must be compute-bound");
    }

    #[test]
    fn paged_kv_gather_pricing() {
        let cfg = NpuConfig::default();
        let base = Plan::decode_step(&cfg, Method::Naive, 768, 2304, 0, 8, 8, 1);
        let flat = base.clone().with_contiguous_kv(&cfg, 96, 768);
        let paged = base.clone().with_paged_kv_gather(&cfg, 96, 768, 16);
        // the same bytes move, but gathered: paged must cost at least as
        // much as the contiguous stream (gather rate < DRAM rate, plus
        // per-page burst setup)
        assert!(flat.overhead_cycles > base.overhead_cycles);
        assert!(
            paged.overhead_cycles > flat.overhead_cycles,
            "paged {} vs contiguous {}",
            paged.overhead_cycles,
            flat.overhead_cycles
        );
        // bigger pages amortize burst setup: overhead monotonically
        // shrinks as page_rows grows
        let coarse = base.clone().with_paged_kv_gather(&cfg, 96, 768, 48);
        assert!(coarse.overhead_cycles < paged.overhead_cycles);
        // empty context is a no-op for both
        assert_eq!(
            base.clone().with_paged_kv_gather(&cfg, 0, 768, 16).overhead_cycles,
            base.overhead_cycles
        );
        assert_eq!(
            base.clone().with_contiguous_kv(&cfg, 0, 768).overhead_cycles,
            base.overhead_cycles
        );
        // the decode step stays memory-bound with the gather priced in
        // (overhead adds latency but is not MAC work)
        assert!(paged.is_memory_bound(&cfg));
        // the setup knob is live: pricier descriptors, pricier plan
        let dearer = cfg.clone().with_page_gather_setup(640.0);
        let p2 = base.clone().with_paged_kv_gather(&dearer, 96, 768, 16);
        assert!(p2.overhead_cycles > paged.overhead_cycles);
    }

    #[test]
    fn act_pre_transform_pricing() {
        // the weight-side halves fold at pack time; only the live
        // activation tile costs per call, and each step's price has the
        // right shape: smooth ~ vector cycles, permute ~ gather bytes,
        // rotate ~ one skinny FP GEMM appended to the plan
        let cfg = NpuConfig::default();
        let (t, k, n) = (8, 768, 2304);
        let base = Plan::build(&cfg, Method::Naive, t, k, n, 0, 8, 4, 1);
        let none = base.clone().with_act_pre_transforms(&cfg, t, k, &[]);
        assert_eq!(none.cost(&cfg).cycles(), base.cost(&cfg).cycles());

        let sq = base.clone().with_act_pre_transforms(
            &cfg,
            t,
            k,
            &[PreTransform::Smooth { alpha: 0.5 }],
        );
        assert_eq!(sq.overhead_cycles, (t * k) as f64 / 64.0);
        assert_eq!(sq.gemms.len(), base.gemms.len(), "smooth adds no GEMM");

        let perm = base.clone().with_act_pre_transforms(
            &cfg,
            t,
            k,
            &[PreTransform::Permute { kind: crate::quant::PermuteKind::Zigzag }],
        );
        assert_eq!(
            perm.overhead_cycles,
            (t * k) as f64 * 2.0 / cfg.gather_bytes_per_cycle
        );

        let rot = base.clone().with_act_pre_transforms(
            &cfg,
            t,
            k,
            &[PreTransform::Rotate { block: 16 }],
        );
        assert_eq!(rot.gemms.len(), base.gemms.len() + 1);
        let leg = rot.gemms.last().unwrap();
        assert_eq!((leg.m, leg.k, leg.n), (t, 16, k));
        assert_eq!(leg.prec, Precision::Fp16);
        assert!(rot.cost(&cfg).cycles() > base.cost(&cfg).cycles());
        // the rotation sliver is skinny: a small tax on the decode-ish
        // plan, nowhere near doubling it
        assert!(rot.cost(&cfg).cycles() < 1.25 * base.cost(&cfg).cycles());

        // composition sums: sq + perm + rot stack their individual costs
        let all = base.clone().with_act_pre_transforms(
            &cfg,
            t,
            k,
            &[
                PreTransform::Smooth { alpha: 0.5 },
                PreTransform::Permute { kind: crate::quant::PermuteKind::Zigzag },
                PreTransform::Rotate { block: 16 },
            ],
        );
        assert_eq!(all.overhead_cycles, sq.overhead_cycles + perm.overhead_cycles);
        assert_eq!(all.gemms.len(), base.gemms.len() + 1);
    }

    #[test]
    fn decode_latency_is_bytes_dominated() {
        // for the INT decode plan, latency IS the byte stream: cycles ==
        // dma == bytes / bandwidth, with compute fully hidden under it
        let cfg = NpuConfig::default();
        let p = Plan::decode_step(&cfg, Method::Naive, 768, 2304, 0, 8, 8, 1);
        let (compute, dma) = p.compute_dma_split(&cfg);
        assert!(dma > 2.0 * compute, "compute {compute} vs dma {dma}");
        let bytes_per_cycle = cfg.dram_gbps * 1e9 / (cfg.freq_ghz * 1e9);
        assert!((dma - p.bytes_per_step() / bytes_per_cycle).abs() < 1e-6);
        assert_eq!(p.cost(&cfg).cycles(), dma, "latency == byte-stream time");
    }

    #[test]
    fn decode_muxq_overhead_tiny_and_beats_llmint8() {
        let cfg = NpuConfig::default();
        let r = 8;
        let naive = Plan::decode_step(&cfg, Method::Naive, 768, 2304, r, 8, 8, 1);
        let muxq = Plan::decode_step(&cfg, Method::Muxq, 768, 2304, r, 8, 8, 1);
        let mixed = Plan::decode_step(&cfg, Method::LlmInt8, 768, 2304, r, 8, 8, 1);
        let overhead = muxq.cost(&cfg).cycles() / naive.cost(&cfg).cycles() - 1.0;
        assert!(overhead >= 0.0 && overhead < 0.05, "muxq decode overhead {overhead}");
        assert!(muxq.cost(&cfg).cycles() < mixed.cost(&cfg).cycles());
    }

    #[test]
    fn expfactor_ablation_cost_order() {
        // exp=1 cheapest recombination; higher exp adds the scaled add
        let cfg = NpuConfig::default();
        let c1 = Plan::build(&cfg, Method::Muxq, 1024, 768, 768, 16, 8, 8, 1).cost(&cfg).cycles();
        let c2 = Plan::build(&cfg, Method::Muxq, 1024, 768, 768, 16, 8, 8, 2).cost(&cfg).cycles();
        assert!(c1 <= c2);
    }

    #[test]
    fn spec_round_beats_sequential_on_int_decode() {
        // decode is bytes-dominated, so the (k+1)-row verify streams the
        // same weights as one step: with a cheap draft (trunc-layer at
        // quarter depth) and a realistic acceptance rate, every INT
        // config must predict tokens/s above plain sequential for k >= 2.
        let cfg = NpuConfig::default();
        for method in [Method::Naive, Method::Muxq] {
            for k in 2..=4 {
                let sp =
                    SpecRoundPlan::build(&cfg, method, k, 768, 2304, 12, 8, 8, 2, 0.25, 0.8);
                let ratio = sp.tok_s_ratio_vs_sequential(&cfg);
                assert!(ratio > 1.0, "{method:?} k={k}: ratio {ratio}");
            }
        }
    }

    #[test]
    fn spec_round_expected_tokens_and_degenerate_rates() {
        let cfg = NpuConfig::default();
        let sp = SpecRoundPlan::build(&cfg, Method::Muxq, 3, 768, 2304, 12, 8, 8, 2, 0.25, 0.8);
        let want = 1.0 + 0.8 + 0.8_f64.powi(2) + 0.8_f64.powi(3);
        assert!((sp.expected_tokens() - want).abs() < 1e-12);
        // alpha=0: every draft rejected, the round still emits the
        // correction token but pays verify + drafts — worse than plain
        let reject =
            SpecRoundPlan::build(&cfg, Method::Muxq, 3, 768, 2304, 12, 8, 8, 2, 0.25, 0.0);
        assert!((reject.expected_tokens() - 1.0).abs() < 1e-12);
        assert!(reject.tok_s_ratio_vs_sequential(&cfg) < 1.0);
        // alpha=1: self-draft limit, k+1 tokens per round
        let perfect =
            SpecRoundPlan::build(&cfg, Method::Muxq, 3, 768, 2304, 12, 8, 8, 2, 0.25, 1.0);
        assert!((perfect.expected_tokens() - 4.0).abs() < 1e-12);
        assert!(
            perfect.tok_s_ratio_vs_sequential(&cfg)
                > reject.tok_s_ratio_vs_sequential(&cfg)
        );
    }

    #[test]
    fn spec_round_cycles_decompose() {
        let cfg = NpuConfig::default();
        let sp = SpecRoundPlan::build(&cfg, Method::Naive, 2, 768, 2304, 0, 8, 8, 1, 0.5, 0.8);
        let want = sp.verify.cost(&cfg).cycles()
            + 2.0 * 0.5 * sp.draft_step.cost(&cfg).cycles();
        assert!((sp.round_cycles(&cfg) - want).abs() < 1e-9);
        // a free draft (scale 0) reduces the round to the verify pass
        let free = SpecRoundPlan::build(&cfg, Method::Naive, 2, 768, 2304, 0, 8, 8, 1, 0.0, 0.8);
        assert_eq!(free.round_cycles(&cfg), free.verify.cost(&cfg).cycles());
    }

    #[test]
    fn w4_decode_halves_weight_bytes() {
        // the tentpole's pricing claim: nibble panels stream at
        // 0.5 B/elem, so the bytes-dominated decode step sheds exactly
        // half the k*n weight stream. W8 and W4 plans differ by NOTHING
        // but the weight term — activations and output are untouched.
        let cfg = NpuConfig::default();
        let (k, n) = (768, 2304);
        let w8 = Plan::decode_step(&cfg, Method::Naive, k, n, 0, 8, 8, 1);
        let w4 = Plan::decode_step(&cfg, Method::Naive, k, n, 0, 8, 4, 1);
        let saved = w8.bytes_per_step() - w4.bytes_per_step();
        assert_eq!(saved, (k * n) as f64 * 0.5, "exactly half the weight stream");
        let ratio = w8.bytes_per_step() / w4.bytes_per_step();
        assert!(ratio > 1.9, "weight-dominated step ~halves: ratio {ratio}");
        // and bytes ARE latency in this regime: W4 decode must be
        // memory-bound and faster than W8 by nearly the byte ratio
        assert!(w4.is_memory_bound(&cfg));
        let speedup = w8.cost(&cfg).cycles() / w4.cost(&cfg).cycles();
        assert!(speedup > 1.8, "decode speedup {speedup}");
        // muxq-w4a8: aux rows ride along in the same nibble panel —
        // still within a few percent of the naive-W4 stream
        let muxq4 = Plan::decode_step(&cfg, Method::Muxq, k, n, 12, 8, 4, 1);
        assert!(muxq4.bytes_per_step() < w4.bytes_per_step() * 1.05);
        // resq: W4 body + rank-r FP residual prices BETWEEN naive-W4
        // and naive-W8 — the residual leg costs real bytes but far
        // fewer than the 4 bits/elem it replaces
        let resq = Plan::decode_step(&cfg, Method::Resq, k, n, 48, 8, 4, 1);
        assert!(resq.bytes_per_step() > w4.bytes_per_step());
        assert!(resq.bytes_per_step() < w8.bytes_per_step());
        // the residual leg is FP work off the uniform INT dataflow
        assert!(resq.non_uniform_fraction(&cfg) > 0.0);
    }

    #[test]
    fn serve_tick_batching_amortizes_the_weight_stream() {
        // decode is bytes-dominated, so a G-row tick streams the same
        // weights as one row: per-token latency must fall steeply with
        // batch, and aggregate tokens/s must rise
        let cfg = NpuConfig::default();
        let solo = ServeTickPlan::build(Method::Muxq, 12, 768, 8, 8, 8, 1, 1);
        let batched = ServeTickPlan::build(Method::Muxq, 12, 768, 8, 8, 8, 8, 4);
        assert!(
            batched.per_token_latency_us(&cfg) < solo.per_token_latency_us(&cfg) / 4.0,
            "batch 8 per-token {} vs solo {}",
            batched.per_token_latency_us(&cfg),
            solo.per_token_latency_us(&cfg)
        );
        assert!(batched.tok_per_s(&cfg) > 4.0 * solo.tok_per_s(&cfg));
        // batch=1, one tenant decomposes to decode_cost + one lane's
        // bookkeeping exactly
        let want = super::super::decode_cost(&cfg, Method::Muxq, 12, 768, 8, 8, 8).cycles()
            + cfg.tenant_sched_cycles;
        assert!((solo.tick_cost(&cfg).cycles() - want).abs() < 1e-9);
    }

    #[test]
    fn serve_tick_tenant_overhead_is_linear_and_small() {
        let cfg = NpuConfig::default();
        let one = ServeTickPlan::build(Method::Muxq, 12, 768, 8, 8, 8, 16, 1);
        let four = ServeTickPlan::build(Method::Muxq, 12, 768, 8, 8, 8, 16, 4);
        assert_eq!(four.sched_cycles(&cfg), 4.0 * one.sched_cycles(&cfg));
        // fairness tax at defaults: well under 1% of the tick
        assert!(four.sched_overhead_fraction(&cfg) < 0.01);
        // the knob is live, and the clamp keeps lanes <= batch rows
        let dear = cfg.clone().with_tenant_sched(1e6);
        assert!(four.sched_overhead_fraction(&dear) > four.sched_overhead_fraction(&cfg));
        let clamped = ServeTickPlan::build(Method::Muxq, 12, 768, 8, 8, 8, 4, 99);
        assert_eq!(clamped.n_tenants, 4);
        assert_eq!(ServeTickPlan::build(Method::Muxq, 12, 768, 8, 8, 8, 0, 0).batch, 1);
    }

    #[test]
    fn serve_tick_utilization_tracks_offered_load() {
        let cfg = NpuConfig::default();
        let plan = ServeTickPlan::build(Method::Muxq, 12, 768, 8, 8, 8, 8, 2);
        let cap = plan.tok_per_s(&cfg);
        assert!(cap > 0.0);
        assert!((plan.utilization(&cfg, cap / 2.0) - 0.5).abs() < 1e-9);
        assert_eq!(plan.utilization(&cfg, cap * 10.0), 1.0, "overload clamps at busy");
        assert_eq!(plan.utilization(&cfg, 0.0), 0.0);
    }
}
