//! GEMM execution plans: the per-method breakdown of which GEMMs run at
//! which precision — used by the report generator and the exp_factor
//! ablation (recombination cost appears when 2^exp − 1 != 1, paper §3.3).
//!
//! Plans price through [`gemm_cost`](super::gemm_cost), so they inherit
//! the widened-MAC datapath model: `NpuConfig::acc_width_bits == 16`
//! (the default) retires two i8 MACs per lane per cycle, matching the
//! rust engine's i16 pair-accumulation microkernel.
//! [`Plan::widened_mac_speedup`] quantifies what the pairing buys one
//! plan end to end.

use super::{gemm_cost, Cost, NpuConfig, Precision};
use crate::quant::Method;

/// One GEMM in a plan.
#[derive(Debug, Clone)]
pub struct PlannedGemm {
    pub label: &'static str,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub prec: Precision,
}

/// A method's execution plan for one projection.
#[derive(Debug, Clone)]
pub struct Plan {
    pub method: Method,
    pub gemms: Vec<PlannedGemm>,
    /// non-GEMM cycles (gather/scatter, domain switches, recombination)
    pub overhead_cycles: f64,
    /// cycles spent rewriting weight operands into the array's panel
    /// layout. 0 in [`Plan::build`]: the deployment pipeline packs
    /// weights once at load time (`gpt2::quantized` / `quant::packed`),
    /// so no per-call traversal cost remains. [`Plan::with_weight_repack`]
    /// models the pre-packed-layout engine that re-packed per call.
    pub pack_cycles: f64,
}

impl Plan {
    /// Build the plan for projection [t,k]@[k,n] with r outlier channels.
    /// `exp_factor` only matters for MUXQ: when != 1, the recombination
    /// needs a scaled add over the output (t*n fp16 elements through the
    /// vector unit) instead of folding into the accumulate.
    pub fn build(
        cfg: &NpuConfig,
        method: Method,
        t: usize,
        k: usize,
        n: usize,
        r: usize,
        bits: u32,
        exp_factor: u32,
    ) -> Plan {
        let int_prec = if bits <= 4 { Precision::Int4 } else { Precision::Int8 };
        match method {
            Method::Fp16 => Plan {
                method,
                gemms: vec![PlannedGemm { label: "fp16", m: t, k, n, prec: Precision::Fp16 }],
                overhead_cycles: 0.0,
                pack_cycles: 0.0,
            },
            Method::Naive => Plan {
                method,
                gemms: vec![PlannedGemm { label: "int", m: t, k, n, prec: int_prec }],
                overhead_cycles: 0.0,
                pack_cycles: 0.0,
            },
            Method::Muxq => {
                // Preferred lowering: concat into one uniform GEMM
                // (Y = [Body | f*Aux] @ [W ; W_rows]); the 2^exp - 1
                // factor folds into Aux's dequant scale. When the
                // implementation cannot fold (e.g. shared per-tensor
                // scale, the paper's exp_factor != 1 caveat), Aux runs
                // as a separate skinny GEMM + scaled add.
                if exp_factor == 1 || r == 0 {
                    Plan {
                        method,
                        gemms: vec![PlannedGemm {
                            label: "body+aux(concat)",
                            m: t,
                            k: k + r,
                            n,
                            prec: int_prec,
                        }],
                        overhead_cycles: 0.0,
                        pack_cycles: 0.0,
                    }
                } else {
                    Plan {
                        method,
                        gemms: vec![
                            PlannedGemm { label: "body", m: t, k, n, prec: int_prec },
                            PlannedGemm { label: "aux", m: t, k: r, n, prec: int_prec },
                        ],
                        // scaled recombination on the vector unit
                        // (t*n fused multiply-adds, 64 lanes, overlapped
                        // with the aux GEMM drain in practice)
                        overhead_cycles: (t * n) as f64 / 64.0,
                        pack_cycles: 0.0,
                    }
                }
            }
            Method::LlmInt8 => {
                let mut gemms = vec![PlannedGemm {
                    label: "int-normal",
                    m: t,
                    k: k.saturating_sub(r).max(1),
                    n,
                    prec: int_prec,
                }];
                let mut overhead = 0.0;
                if r > 0 {
                    gemms.push(PlannedGemm {
                        label: "fp16-outlier",
                        m: t,
                        k: r,
                        n,
                        prec: Precision::Fp16,
                    });
                    let gather_bytes = (t * r) as f64 * 2.0 * 2.0;
                    overhead += gather_bytes / cfg.gather_bytes_per_cycle;
                    overhead += cfg.domain_switch_cycles as f64;
                }
                Plan { method, gemms, overhead_cycles: overhead, pack_cycles: 0.0 }
            }
        }
    }

    /// Model a deployment that re-packs weight operands on every call —
    /// what the rust engine did before `PackedMatI8`: each GEMM's [k, n]
    /// weight matrix is rewritten once into the K-major panel layout
    /// before the MAC array can stream it.
    pub fn with_weight_repack(mut self, cfg: &NpuConfig) -> Plan {
        let bytes: f64 =
            self.gemms.iter().map(|g| (g.k * g.n) as f64 * g.prec.bytes()).sum();
        self.pack_cycles += bytes / cfg.pack_bytes_per_cycle;
        self
    }

    /// End-to-end latency ratio of this plan on a 32-bit-lane (one MAC
    /// per cycle) datapath vs the i16 pair-accumulation datapath, same
    /// config otherwise. In [1, 2]: compute-bound INT plans approach 2x;
    /// DMA-bound plans, fixed overheads and FP16 work dilute the ratio
    /// toward — and for pure-FP16 plans exactly to — 1.
    pub fn widened_mac_speedup(&self, cfg: &NpuConfig) -> f64 {
        let wide = self.cost(&cfg.clone().with_acc_width(32)).cycles();
        let pair = self.cost(&cfg.clone().with_acc_width(16)).cycles();
        if pair == 0.0 {
            return 1.0;
        }
        wide / pair
    }

    pub fn cost(&self, cfg: &NpuConfig) -> Cost {
        let mut total = Cost::default();
        for g in &self.gemms {
            total.add(gemm_cost(cfg, g.m, g.k, g.n, g.prec));
        }
        total.extra_cycles += self.overhead_cycles + self.pack_cycles;
        total
    }

    /// Fraction of cycles spent outside the uniform INT dataflow
    /// (the "hardware-unfriendliness" metric for Fig. 4's comparison).
    pub fn non_uniform_fraction(&self, cfg: &NpuConfig) -> f64 {
        let total = self.cost(cfg).cycles();
        if total == 0.0 {
            return 0.0;
        }
        let fp: f64 = self
            .gemms
            .iter()
            .filter(|g| g.prec == Precision::Fp16 && self.method != Method::Fp16)
            .map(|g| gemm_cost(cfg, g.m, g.k, g.n, g.prec).cycles())
            .sum();
        // MUXQ's recombination is an INT vector add (uniform dataflow);
        // only LLM.int8()'s gather/scatter + domain switch is irregular.
        let irregular = if self.method == Method::LlmInt8 { self.overhead_cycles } else { 0.0 };
        (fp + irregular) / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_shapes() {
        let cfg = NpuConfig::default();
        let p = Plan::build(&cfg, Method::Muxq, 512, 768, 768, 12, 8, 2);
        assert_eq!(p.gemms.len(), 2, "exp!=1 falls back to two GEMMs");
        assert_eq!(p.gemms[1].k, 12);
        assert!(p.overhead_cycles > 0.0, "exp=2 pays recombination");
        let p1 = Plan::build(&cfg, Method::Muxq, 512, 768, 768, 12, 8, 1);
        assert_eq!(p1.gemms.len(), 1, "exp=1 concatenates");
        assert_eq!(p1.gemms[0].k, 768 + 12);
        assert_eq!(p1.overhead_cycles, 0.0, "exp=1 is a plain sum");
    }

    #[test]
    fn muxq_stays_uniform_int() {
        let cfg = NpuConfig::default();
        let muxq = Plan::build(&cfg, Method::Muxq, 512, 768, 768, 12, 8, 2);
        let mixed = Plan::build(&cfg, Method::LlmInt8, 512, 768, 768, 12, 8, 2);
        assert!(muxq.non_uniform_fraction(&cfg) < 0.02);
        assert!(mixed.non_uniform_fraction(&cfg) > muxq.non_uniform_fraction(&cfg));
    }

    #[test]
    fn prepacked_weights_beat_per_call_repack() {
        // Plan::build assumes load-time packing (pack_cycles == 0); the
        // per-call repack variant must cost strictly more, by exactly the
        // panel-rewrite traversal of every weight operand.
        let cfg = NpuConfig::default();
        let plan = Plan::build(&cfg, Method::Muxq, 512, 768, 768, 12, 8, 2);
        assert_eq!(plan.pack_cycles, 0.0, "deployment packs at load time");
        let repack = plan.clone().with_weight_repack(&cfg);
        let bytes: f64 = plan.gemms.iter().map(|g| (g.k * g.n) as f64).sum();
        assert!(repack.pack_cycles > 0.0);
        assert_eq!(repack.pack_cycles, bytes / cfg.pack_bytes_per_cycle);
        assert!(repack.cost(&cfg).cycles() > plan.cost(&cfg).cycles());
    }

    #[test]
    fn widened_mac_datapath_tracks_pair_kernel() {
        let cfg = NpuConfig::default();
        // compute-bound INT plan: pairing buys a real speedup, capped at 2x
        let muxq = Plan::build(&cfg, Method::Muxq, 4096, 4096, 4096, 16, 8, 2);
        let s = muxq.widened_mac_speedup(&cfg);
        assert!(s > 1.2 && s <= 2.0 + 1e-9, "speedup {s}");
        // a pure-FP16 plan is untouched by the INT accumulator width
        let fp = Plan::build(&cfg, Method::Fp16, 4096, 4096, 4096, 0, 8, 1);
        assert!((fp.widened_mac_speedup(&cfg) - 1.0).abs() < 1e-9);
        // LLM.int8() keeps an FP16 leg, so its benefit must be smaller
        // than the uniform-INT plan's
        let mixed = Plan::build(&cfg, Method::LlmInt8, 4096, 4096, 4096, 16, 8, 2);
        assert!(mixed.widened_mac_speedup(&cfg) < s);
    }

    #[test]
    fn expfactor_ablation_cost_order() {
        // exp=1 cheapest recombination; higher exp adds the scaled add
        let cfg = NpuConfig::default();
        let c1 = Plan::build(&cfg, Method::Muxq, 1024, 768, 768, 16, 8, 1).cost(&cfg).cycles();
        let c2 = Plan::build(&cfg, Method::Muxq, 1024, 768, 768, 16, 8, 2).cost(&cfg).cycles();
        assert!(c1 <= c2);
    }
}
