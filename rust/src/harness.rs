//! Experiment harness: shared plumbing for the table/figure regenerators
//! under `examples/` and `rust/benches/` (batched perplexity evaluation
//! over compiled variants, paper-style table rendering).

use crate::coordinator::variants::{VariantKey, VariantRegistry};
use crate::data::eval_set::{perplexity, EvalSet};
use anyhow::{bail, Result};

/// Evaluate perplexity of one variant at given bit-widths over `windows`
/// (batched through the compiled executable, padding the tail batch).
pub fn eval_ppl(
    registry: &VariantRegistry,
    variant: &VariantKey,
    ia_bits: f32,
    w_bits: f32,
    windows: &[Vec<i32>],
) -> Result<f32> {
    if windows.is_empty() {
        bail!("no eval windows");
    }
    let compiled = registry.get(variant)?;
    let (batch, seq) = (compiled.meta.batch, compiled.meta.seq);
    let mut pairs = Vec::with_capacity(windows.len());
    for chunk in windows.chunks(batch) {
        let mut toks = Vec::with_capacity(batch * seq);
        for w in chunk {
            toks.extend_from_slice(w);
        }
        for _ in chunk.len()..batch {
            toks.extend_from_slice(&windows[0]);
        }
        let out = compiled.run(&toks, ia_bits, w_bits)?;
        let nll = &out[0].data;
        let count = &out[1].data;
        for i in 0..chunk.len() {
            pairs.push((nll[i], count[i]));
        }
    }
    Ok(perplexity(&pairs))
}

/// Load the standard eval windows for a model's compiled seq length.
pub fn eval_windows(limit: usize) -> Result<Vec<Vec<i32>>> {
    let eval = EvalSet::load(&crate::artifacts_dir(), "valid")?;
    Ok(eval.windows(128, limit))
}

/// Number of windows used by the table regenerators. Full valid split by
/// default; `MUXQ_EVAL_WINDOWS` overrides for quick runs.
pub fn table_windows() -> usize {
    std::env::var("MUXQ_EVAL_WINDOWS").ok().and_then(|v| v.parse().ok()).unwrap_or(32)
}

/// Render one perplexity cell, flagging blow-ups like the paper's prose
/// ("perplexity rises sharply").
pub fn fmt_ppl(p: f32) -> String {
    if p.is_finite() {
        format!("{p:>10.4}")
    } else {
        format!("{:>10}", "inf")
    }
}

/// An ASCII bar for the figure regenerators.
pub fn bar(value: f32, max: f32, width: usize) -> String {
    let n = if max > 0.0 { ((value / max) * width as f32).round() as usize } else { 0 };
    "#".repeat(n.min(width))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bar_scales() {
        assert_eq!(bar(5.0, 10.0, 10).len(), 5);
        assert_eq!(bar(20.0, 10.0, 10).len(), 10);
        assert_eq!(bar(0.0, 10.0, 10).len(), 0);
    }

    #[test]
    fn fmt_handles_inf() {
        assert!(fmt_ppl(f32::INFINITY).contains("inf"));
        assert!(fmt_ppl(25.1883).contains("25.1883"));
    }
}
