//! In-repo tooling substrates. The offline build image ships only the
//! `xla` crate and its dependencies — no tokio / clap / criterion /
//! proptest — so the pieces a production launcher needs are implemented
//! here (and tested like any other module):
//!
//! * [`cli`] — declarative argument parsing with `--help`
//! * [`config`] — INI-style config files for the launcher
//! * [`bench`] — micro-benchmark harness with warmup + percentiles
//! * [`proptest`] — seeded property testing with shrinking
//! * [`metrics`] — counters + log-bucketed latency histograms
//! * [`threadpool`] — fixed worker pool with bounded queues (the
//!   coordinator's execution substrate)

pub mod bench;
pub mod cli;
pub mod config;
pub mod json;
pub mod metrics;
pub mod proptest;
pub mod threadpool;
