//! Mini property-testing framework (the offline image has no `proptest`).
//!
//! Deterministic (seeded splitmix64), with linear input shrinking on
//! failure. Enough machinery for the coordinator/quant invariants:
//!
//! ```ignore
//! prop(|g| {
//!     let n = g.usize(1, 100);
//!     let v = g.vec_f32(n, -10.0, 10.0);
//!     prop_assert(invariant(&v), "invariant broke");
//! });
//! ```

use crate::data::prng::SplitMix64;

/// Number of cases per property (override with MUXQ_PROPTEST_CASES).
pub fn default_cases() -> u32 {
    std::env::var("MUXQ_PROPTEST_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(64)
}

/// Random-input generator handed to each property case.
pub struct Gen {
    rng: SplitMix64,
    /// shrink level 0..=SHRINK_MAX: higher = smaller generated inputs
    shrink: u32,
    pub case: u32,
}

const SHRINK_MAX: u32 = 4;

impl Gen {
    fn new(seed: u64, case: u32, shrink: u32) -> Self {
        Gen { rng: SplitMix64::new(seed ^ (case as u64).wrapping_mul(0x9E37)), shrink, case }
    }

    fn shrunk(&self, hi: u64, lo: u64) -> u64 {
        // progressively bias ranges toward the minimum as shrink increases
        if self.shrink == 0 || hi <= lo {
            return hi;
        }
        let span = hi - lo;
        lo + span / (1 << self.shrink.min(60))
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let h = self.shrunk(hi as u64, lo as u64).max(lo as u64);
        self.rng.next_range(lo as u64, h) as usize
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        let h = self.shrunk(hi, lo).max(lo);
        self.rng.next_range(lo, h)
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f64() as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.next_below(items.len() as u64) as usize]
    }
}

/// Outcome of one property case.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run a property across `default_cases()` random cases; on failure, retry
/// at increasing shrink levels to report the smallest failing case, then
/// panic with the case seed for reproduction.
pub fn prop(name: &str, f: impl Fn(&mut Gen) -> PropResult) {
    prop_seeded(name, 0xC0FFEE, f)
}

pub fn prop_seeded(name: &str, seed: u64, f: impl Fn(&mut Gen) -> PropResult) {
    let cases = default_cases();
    for case in 0..cases {
        let mut g = Gen::new(seed, case, 0);
        if let Err(msg) = f(&mut g) {
            // try to find a smaller failing input
            let mut final_msg = msg;
            let mut final_level = 0;
            for level in 1..=SHRINK_MAX {
                let mut g2 = Gen::new(seed, case, level);
                if let Err(m2) = f(&mut g2) {
                    final_msg = m2;
                    final_level = level;
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {seed:#x}, \
                 shrink level {final_level}): {final_msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        prop("add commutes", |g| {
            let a = g.u64(0, 1000);
            let b = g.u64(0, 1000);
            prop_assert(a + b == b + a, "commutativity")
        });
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn failing_property_panics_with_context() {
        prop("always fails", |g| {
            let n = g.usize(1, 100);
            prop_assert(n == 0, format!("n = {n}"))
        });
    }

    #[test]
    fn generators_in_range() {
        prop("gen ranges", |g| {
            let n = g.usize(5, 50);
            let v = g.vec_f32(n, -2.0, 2.0);
            prop_assert(v.len() == n, "len")?;
            prop_assert(v.iter().all(|x| (-2.0..=2.0).contains(x)), "bounds")
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed| {
            let out = std::cell::RefCell::new(Vec::new());
            prop_seeded("collect", seed, |g| {
                out.borrow_mut().push(g.u64(0, 1 << 40));
                Ok(())
            });
            out.into_inner()
        };
        assert_eq!(collect(1), collect(1));
        assert_ne!(collect(1), collect(2));
    }
}
