//! Serving metrics: counters, gauges and latency histograms with
//! percentile queries. Lock-free counters (atomics) + a mutex-guarded
//! log-bucketed histogram; cheap enough for the request hot path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-bucketed latency histogram: 4 buckets per octave from 1us to ~1.2h.
/// Records are O(1); percentile queries scan the (fixed, small) bucket
/// array.
#[derive(Debug)]
pub struct Histogram {
    buckets: Mutex<[u64; Self::N_BUCKETS]>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: Mutex::new([0; Self::N_BUCKETS]),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    const N_BUCKETS: usize = 128;
    const BASE_NS: f64 = 1_000.0; // 1us

    fn bucket_of(ns: u64) -> usize {
        if (ns as f64) < Self::BASE_NS {
            return 0;
        }
        // 4 buckets per octave
        let idx = (4.0 * ((ns as f64) / Self::BASE_NS).log2()).floor() as usize;
        idx.min(Self::N_BUCKETS - 1)
    }

    fn bucket_upper(idx: usize) -> Duration {
        Duration::from_nanos((Self::BASE_NS * 2f64.powf((idx + 1) as f64 / 4.0)) as u64)
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos() as u64;
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
        let mut b = self.buckets.lock().unwrap();
        b[Self::bucket_of(ns)] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns.load(Ordering::Relaxed))
    }

    /// Upper bound of the bucket containing the q-quantile (0 < q <= 1).
    pub fn quantile(&self, q: f64) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        let target = ((c as f64) * q).ceil() as u64;
        let b = self.buckets.lock().unwrap();
        let mut acc = 0u64;
        for (i, n) in b.iter().enumerate() {
            acc += n;
            if acc >= target {
                return Self::bucket_upper(i);
            }
        }
        self.max()
    }
}

/// Named metrics registry shared across coordinator components.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters.lock().unwrap().entry(name.into()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms.lock().unwrap().entry(name.into()).or_default().clone()
    }

    /// Snapshot of every counter whose name starts with `prefix`,
    /// name-sorted (BTreeMap order). Dynamic counter families — e.g. the
    /// generation server's per-tenant `tokens_tenant_<name>` — are read
    /// back this way without knowing the tenant set up front.
    pub fn counters_with_prefix(&self, prefix: &str) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .filter(|(n, _)| n.starts_with(prefix))
            .map(|(n, c)| (n.clone(), c.get()))
            .collect()
    }

    /// Human-readable dump (examples print this at exit).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter  {name:<32} {}\n", c.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "latency  {name:<32} n={} mean={:?} p50={:?} p95={:?} p99={:?} max={:?}\n",
                h.count(),
                h.mean(),
                h.quantile(0.50),
                h.quantile(0.95),
                h.quantile(0.99),
                h.max()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::default());
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // p50 of a uniform 1..1000us should be around 500us (log buckets
        // give the upper bucket edge)
        assert!(p50 >= Duration::from_micros(400) && p50 <= Duration::from_micros(700), "{p50:?}");
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::default();
        r.counter("a").inc();
        r.counter("a").inc();
        assert_eq!(r.counter("a").get(), 2);
        assert!(r.render().contains("counter"));
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::default();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }
}
