//! Minimal CLI argument parser (the offline image has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positionals, with
//! typed accessors and a generated `--help`.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct Spec {
    key: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument parser.
#[derive(Debug, Default)]
pub struct Cli {
    name: String,
    about: String,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl Cli {
    pub fn new(name: &str, about: &str) -> Self {
        Cli { name: name.into(), about: about.into(), ..Default::default() }
    }

    /// Declare an option with a default value.
    pub fn opt(mut self, key: &str, default: &str, help: &str) -> Self {
        self.specs.push(Spec {
            key: key.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
        });
        self
    }

    /// Declare a required option.
    pub fn req(mut self, key: &str, help: &str) -> Self {
        self.specs.push(Spec { key: key.into(), help: help.into(), default: None, is_flag: false });
        self
    }

    /// Declare a boolean flag.
    pub fn flag(mut self, key: &str, help: &str) -> Self {
        self.specs.push(Spec { key: key.into(), help: help.into(), default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for spec in &self.specs {
            let d = match (&spec.default, spec.is_flag) {
                (_, true) => " (flag)".to_string(),
                (Some(d), _) => format!(" [default: {d}]"),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", spec.key, spec.help, d));
        }
        s
    }

    /// Parse a raw arg list (without argv[0]). Exits on `--help`.
    pub fn parse(mut self, args: &[String]) -> Result<Parsed> {
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.key == key)
                    .with_context(|| format!("unknown option --{key}\n{}", self.usage()))?
                    .clone();
                if spec.is_flag {
                    if inline_val.is_some() {
                        bail!("flag --{key} takes no value");
                    }
                    self.flags.insert(key, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i).with_context(|| format!("--{key} needs a value"))?.clone()
                        }
                    };
                    self.values.insert(key, val);
                }
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        // fill defaults, check required
        for spec in &self.specs {
            if spec.is_flag {
                self.flags.entry(spec.key.clone()).or_insert(false);
            } else if !self.values.contains_key(&spec.key) {
                match &spec.default {
                    Some(d) => {
                        self.values.insert(spec.key.clone(), d.clone());
                    }
                    None => bail!("missing required --{}\n{}", spec.key, self.usage()),
                }
            }
        }
        Ok(Parsed { values: self.values, flags: self.flags, positionals: self.positionals })
    }

    /// Parse from the process environment.
    pub fn parse_env(self) -> Result<Parsed> {
        let args: Vec<String> = std::env::args().skip(1).collect();
        self.parse(&args)
    }
}

/// Parsed arguments with typed accessors.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, key: &str) -> &str {
        self.values
            .get(key)
            .map(|s| s.as_str())
            .unwrap_or_else(|| panic!("undeclared option {key}"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key).parse().with_context(|| format!("--{key} must be an integer"))
    }

    pub fn get_u32(&self, key: &str) -> Result<u32> {
        self.get(key).parse().with_context(|| format!("--{key} must be an integer"))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64> {
        self.get(key).parse().with_context(|| format!("--{key} must be a number"))
    }

    pub fn get_list(&self, key: &str) -> Vec<String> {
        self.get(key).split(',').filter(|s| !s.is_empty()).map(|s| s.trim().to_string()).collect()
    }

    pub fn flag(&self, key: &str) -> bool {
        *self.flags.get(key).unwrap_or(&false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|v| v.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let p = Cli::new("t", "test")
            .opt("model", "sim-small", "model name")
            .opt("bits", "8", "bits")
            .flag("verbose", "chatty")
            .parse(&args(&["--model", "sim-large", "--verbose", "pos1", "--bits=6"]))
            .unwrap();
        assert_eq!(p.get("model"), "sim-large");
        assert_eq!(p.get_u32("bits").unwrap(), 6);
        assert!(p.flag("verbose"));
        assert_eq!(p.positionals, vec!["pos1"]);
    }

    #[test]
    fn defaults_and_required() {
        let p = Cli::new("t", "test").opt("x", "1", "x").parse(&args(&[])).unwrap();
        assert_eq!(p.get("x"), "1");
        let e = Cli::new("t", "test").req("y", "y").parse(&args(&[]));
        assert!(e.is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(Cli::new("t", "t").parse(&args(&["--nope", "v"])).is_err());
    }

    #[test]
    fn list_accessor() {
        let p = Cli::new("t", "t").opt("bits", "8,7,6", "sweep").parse(&args(&[])).unwrap();
        assert_eq!(p.get_list("bits"), vec!["8", "7", "6"]);
    }
}
