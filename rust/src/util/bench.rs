//! Micro-benchmark harness (the offline image has no `criterion`).
//!
//! Methodology: warmup runs, then timed iterations until both a minimum
//! iteration count and a minimum wall budget are met; reports mean /
//! median / p95 / min plus derived throughput. Used by every target under
//! `rust/benches/` (all declared `harness = false`).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: u32,
    pub min_iters: u32,
    pub min_time: Duration,
    pub max_iters: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            min_time: Duration::from_millis(300),
            max_iters: 10_000,
        }
    }
}

/// Result statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }

    /// Row formatted like `name  mean  median  p95  min  ops/s`.
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} {:>10} {:>10} {:>10} {:>12.1}/s",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.median),
            fmt_dur(self.p95),
            fmt_dur(self.min),
            self.per_sec()
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Benchmark runner; collects rows and prints a criterion-like report.
pub struct Bencher {
    cfg: BenchConfig,
    pub results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(BenchConfig::default())
    }
}

impl Bencher {
    pub fn new(cfg: BenchConfig) -> Self {
        // honor quick mode for CI: MUXQ_BENCH_QUICK=1
        let cfg = if std::env::var_os("MUXQ_BENCH_QUICK").is_some() {
            BenchConfig {
                warmup_iters: 1,
                min_iters: 3,
                min_time: Duration::from_millis(30),
                max_iters: 50,
            }
        } else {
            cfg
        };
        Bencher { cfg, results: Vec::new() }
    }

    /// Time `f`, which must return something observable (guards against
    /// dead-code elimination via `std::hint::black_box`).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        for _ in 0..self.cfg.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while (samples.len() < self.cfg.min_iters as usize
            || start.elapsed() < self.cfg.min_time)
            && samples.len() < self.cfg.max_iters as usize
        {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed());
        }
        samples.sort();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        let stats = BenchStats {
            name: name.to_string(),
            iters: n as u32,
            mean,
            median: samples[n / 2],
            p95: samples[((n as f64 * 0.95) as usize).min(n - 1)],
            min: samples[0],
        };
        println!("{}", stats.row());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn header(title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>10} {:>10} {:>10} {:>10} {:>14}",
            "case", "mean", "median", "p95", "min", "throughput"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane() {
        std::env::set_var("MUXQ_BENCH_QUICK", "1");
        let mut b = Bencher::default();
        let s = b.bench("noop+sum", || (0..1000u64).sum::<u64>()).clone();
        assert!(s.iters >= 3);
        assert!(s.min <= s.median && s.median <= s.p95);
        assert!(s.per_sec() > 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_dur(Duration::from_nanos(10)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(10)).ends_with("us"));
        assert!(fmt_dur(Duration::from_millis(10)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(10)).ends_with('s'));
    }
}
