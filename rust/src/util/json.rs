//! Minimal JSON parser (no serde in the offline image). Supports the full
//! JSON grammar minus exotic number forms; enough to read
//! `artifacts/manifest.json` and the training logs.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).with_context(|| format!("missing key {key:?}")),
            other => bail!("expected object, got {other:?}"),
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(c) if c == b'-' || c == b'+' || c == b'.' || c == b'e' || c == b'E'
                || c.is_ascii_digit()
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse().with_context(|| format!("bad number {s:?}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .context("truncated \\u escape")?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).context("bad codepoint")?);
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(c) => {
                    // copy a full utf-8 sequence
                    let len = utf8_len(c);
                    let chunk = self
                        .bytes
                        .get(self.pos..self.pos + len)
                        .context("truncated utf-8")?;
                    out.push_str(std::str::from_utf8(chunk)?);
                    self.pos += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] got {other:?}"),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} got {other:?}"),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let j = Json::parse(
            r#"[{"model": "sim-small", "batch": 8, "smooth": false,
                 "file": "sim-small-eval-muxq-pt.hlo.txt", "pi": -3.5e-1}]"#,
        )
        .unwrap();
        let e = &j.as_arr().unwrap()[0];
        assert_eq!(e.get("model").unwrap().as_str().unwrap(), "sim-small");
        assert_eq!(e.get("batch").unwrap().as_usize().unwrap(), 8);
        assert!(!e.get("smooth").unwrap().as_bool().unwrap());
        assert!((e.get("pi").unwrap().as_f64().unwrap() + 0.35).abs() < 1e-9);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\"b\\c\ndA");
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, [2, {"b": null}], true]}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
        assert_eq!(*arr[1].as_arr().unwrap()[1].get("b").unwrap(), Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] extra").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }
}
