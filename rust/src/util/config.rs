//! INI-style config parser: `[section]` headers + `key = value` lines,
//! `#`/`;` comments, typed accessors with defaults. Drives the launcher
//! (`muxq serve --config serve.cfg`) so deployments don't need to pass
//! a dozen CLI flags.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Parsed configuration: section -> key -> value.
#[derive(Debug, Default, Clone)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split(['#', ';']).next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').with_context(|| {
                    format!("line {}: unterminated section header", lineno + 1)
                })?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                cfg.sections
                    .entry(section.clone())
                    .or_default()
                    .insert(k.trim().to_string(), v.trim().to_string());
            } else {
                bail!("line {}: expected `key = value` or `[section]`, got {raw:?}", lineno + 1);
            }
        }
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read config {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).unwrap_or(default)
    }

    pub fn get_usize(&self, section: &str, key: &str, default: usize) -> Result<usize> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("[{section}] {key} = {v:?} not integer")),
        }
    }

    pub fn get_f64(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("[{section}] {key} = {v:?} not number")),
        }
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("[{section}] {key} = {v:?} not a bool"),
        }
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# serving config
[server]
max_batch = 8
max_wait_ms = 5     ; coalescing window
model = sim-small

[quant]
method = muxq
granularity = per-tensor
smooth = false
"#;

    #[test]
    fn parse_sections_and_values() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("server", "model"), Some("sim-small"));
        assert_eq!(c.get_usize("server", "max_batch", 0).unwrap(), 8);
        assert_eq!(c.get_bool("quant", "smooth", true).unwrap(), false);
        assert_eq!(c.get_or("quant", "missing", "dflt"), "dflt");
    }

    #[test]
    fn comments_stripped() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_usize("server", "max_wait_ms", 0).unwrap(), 5);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("not a kv line").is_err());
        assert!(Config::parse("[unterminated").is_err());
    }

    #[test]
    fn type_errors_reported() {
        let c = Config::parse("[s]\nx = abc\n").unwrap();
        assert!(c.get_usize("s", "x", 0).is_err());
        assert!(c.get_bool("s", "x", false).is_err());
    }
}
