//! Fixed-size worker pool over a bounded MPMC channel (no tokio in the
//! offline image; the coordinator's request path is thread-based).
//!
//! Bounded submission gives natural backpressure: `submit` blocks when the
//! queue is full, `try_submit` reports `QueueFull` so callers can shed
//! load (the router's admission-control path).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    Shutdown,
}

struct Shared {
    queue: Mutex<State>,
    not_empty: Condvar,
    not_full: Condvar,
}

struct State {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Fixed worker pool with a bounded job queue.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    capacity: usize,
}

impl ThreadPool {
    pub fn new(n_workers: usize, capacity: usize) -> Self {
        assert!(n_workers > 0 && capacity > 0);
        let shared = Arc::new(Shared {
            queue: Mutex::new(State { jobs: VecDeque::new(), shutdown: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        });
        let workers = (0..n_workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("muxq-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers, capacity }
    }

    /// Blocking submit (backpressure: waits while the queue is full).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> Result<(), SubmitError> {
        let mut st = self.shared.queue.lock().unwrap();
        loop {
            if st.shutdown {
                return Err(SubmitError::Shutdown);
            }
            if st.jobs.len() < self.capacity {
                st.jobs.push_back(Box::new(job));
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = self.shared.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking submit; `QueueFull` lets the caller shed load.
    pub fn try_submit(&self, job: impl FnOnce() + Send + 'static) -> Result<(), SubmitError> {
        let mut st = self.shared.queue.lock().unwrap();
        if st.shutdown {
            return Err(SubmitError::Shutdown);
        }
        if st.jobs.len() >= self.capacity {
            return Err(SubmitError::QueueFull);
        }
        st.jobs.push_back(Box::new(job));
        self.shared.not_empty.notify_one();
        Ok(())
    }

    pub fn queue_len(&self) -> usize {
        self.shared.queue.lock().unwrap().jobs.len()
    }

    /// Drain the queue and join all workers.
    pub fn shutdown(mut self) {
        {
            let mut st = self.shared.queue.lock().unwrap();
            st.shutdown = true;
            self.shared.not_empty.notify_all();
            self.shared.not_full.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        let mut st = self.shared.queue.lock().unwrap();
        st.shutdown = true;
        self.shared.not_empty.notify_all();
        drop(st);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut st = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = st.jobs.pop_front() {
                    shared.not_full.notify_one();
                    break job;
                }
                if st.shutdown {
                    return;
                }
                st = shared.not_empty.wait(st).unwrap();
            }
        };
        job();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::Duration;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4, 64);
        let done = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let d = done.clone();
            pool.submit(move || {
                d.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        pool.shutdown();
        assert_eq!(done.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn try_submit_sheds_when_full() {
        let pool = ThreadPool::new(1, 2);
        let gate = Arc::new(Mutex::new(()));
        let hold = gate.lock().unwrap();
        // worker blocks on the first job
        let g = gate.clone();
        pool.submit(move || {
            let _guard = g.lock().unwrap();
        })
        .unwrap();
        std::thread::sleep(Duration::from_millis(50)); // worker picks job 1
        pool.try_submit(|| {}).unwrap();
        pool.try_submit(|| {}).unwrap();
        // queue (cap 2) now full while worker is blocked
        assert_eq!(pool.try_submit(|| {}), Err(SubmitError::QueueFull));
        drop(hold);
        pool.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let pool = ThreadPool::new(2, 128);
        let done = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            let d = done.clone();
            pool.submit(move || {
                std::thread::sleep(Duration::from_micros(100));
                d.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        pool.shutdown();
        assert_eq!(done.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let pool = ThreadPool::new(1, 4);
        let shared = pool.shared.clone();
        shared.queue.lock().unwrap().shutdown = true;
        shared.not_empty.notify_all();
        assert_eq!(pool.try_submit(|| {}), Err(SubmitError::Shutdown));
    }
}
