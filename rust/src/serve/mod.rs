//! L4 — the HTTP serving front end: [`GenerationServer`] exposed over
//! the network with multi-tenant QoS (DESIGN.md §5d).
//!
//! Dependency-free by construction (the crate has no Cargo.toml of its
//! own, so no tokio/hyper): a hand-rolled HTTP/1.1 layer over
//! `std::net::TcpListener`, thread-per-connection behind a bounded
//! worker pool, and chunked-transfer SSE for token streaming. Admission
//! is the coordinator's deficit-weighted round-robin over per-tenant
//! lanes ([`crate::coordinator::QosConfig`]); backpressure surfaces as
//! HTTP 429/503 with `Retry-After` instead of blocking the acceptor.
//!
//! * [`http`] — request parsing, fixed + chunked response writing.
//! * [`api`] — the completions wire format, SSE event grammar, and the
//!   [`crate::coordinator::SubmitError`] → status mapping.
//! * [`server`] — acceptor, worker pool, routing, stream bridging,
//!   disconnect-cancel.
//!
//! # Quickstart
//!
//! Start a server (see `examples/http_serve.rs`, or any test in
//! `rust/tests/serve_http.rs`):
//!
//! ```text
//! let gen = Arc::new(GenerationServer::start(backend, gen_cfg));
//! let srv = HttpServer::start(gen, ServeConfig::default())?;   // port 0 = ephemeral
//! println!("listening on {}", srv.addr());
//! ```
//!
//! Then, with `curl` (prompts are token IDs — the repo has no
//! tokenizer):
//!
//! ```text
//! # stream a completion as SSE events
//! curl -N http://127.0.0.1:PORT/v1/completions \
//!   -d '{"prompt": [464, 3290, 318], "max_tokens": 16, "tenant": "team-a"}'
//! data: {"index":0,"token":257}
//! data: {"index":1,"token":922}
//! ...
//! data: {"finish":"length","generated":16,"latency_ms":3.1}
//! data: [DONE]
//!
//! # buffered (non-streaming) completion
//! curl http://127.0.0.1:PORT/v1/completions \
//!   -d '{"prompt": [464], "max_tokens": 4, "stream": false}'
//! {"tokens": [922, 11, 257, 30], "finish": "length", "generated": 4, ...}
//!
//! # speculative decoding, sampled, per-request
//! curl -N http://127.0.0.1:PORT/v1/completions \
//!   -d '{"prompt": [464], "temperature": 0.8, "seed": 7,
//!        "speculative": {"k": 3, "draft": "naive-int4"}}'
//!
//! # the deployed model + operator tag
//! curl http://127.0.0.1:PORT/v1/models
//!
//! # counters (incl. per-tenant served tokens), latency histograms, gauges
//! curl http://127.0.0.1:PORT/metrics
//! ```
//!
//! Shedding answers carry `Retry-After`: `429` when one tenant's own
//! queue cap is full ([`crate::coordinator::SubmitError::TenantBusy`]),
//! `503` when the whole queue or the worker pool is saturated.

pub mod api;
pub mod http;
pub mod server;

pub use api::{parse_completion, CompletionCall};
pub use server::{HttpServer, ServeConfig};

// re-exported so serve users need only this module + coordinator
pub use crate::coordinator::GenerationServer;
