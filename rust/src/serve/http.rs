//! Hand-rolled HTTP/1.1 primitives over blocking `std::net` streams —
//! the crate builds with no Cargo.toml of its own (see the CI preflight),
//! so there is no tokio/hyper to lean on. Scope is deliberately narrow:
//! one request per connection (`Connection: close`), `Content-Length`
//! bodies on the way in, fixed-length or chunked-transfer bodies on the
//! way out. Chunked writing is what streams SSE tokens: each event is
//! one flushed chunk, and a failed chunk write is the disconnect signal
//! that cancels the generation session.

use std::io::{BufRead, Read, Write};

/// Parse limits: a request line + headers beyond this is a 431, a
/// declared body beyond this is a 413. Token-ID prompts are a few bytes
/// per token, so these bounds fit tens of thousands of prompt tokens.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// A parsed request. Header names are lowercased at parse time.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// path only — any `?query` suffix is split off and kept verbatim
    pub path: String,
    pub query: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

/// Why a request failed to parse; carries the status to answer with.
#[derive(Debug)]
pub struct ParseError {
    pub status: u16,
    pub message: String,
}

impl ParseError {
    fn new(status: u16, message: impl Into<String>) -> ParseError {
        ParseError { status, message: message.into() }
    }
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    /// Read one request off a buffered stream. `Ok(None)` means the
    /// client closed before sending anything (not an error — pools and
    /// health checks do this); `Err` carries the status to answer with.
    pub fn read_from<R: BufRead>(r: &mut R) -> Result<Option<Request>, ParseError> {
        let mut head = 0usize;
        let mut line = String::new();
        let n = r
            .read_line(&mut line)
            .map_err(|e| ParseError::new(400, format!("read request line: {e}")))?;
        if n == 0 {
            return Ok(None);
        }
        head += n;
        let line = line.trim_end();
        let mut parts = line.split(' ');
        let (method, target, version) =
            match (parts.next(), parts.next(), parts.next(), parts.next()) {
                (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
                _ => return Err(ParseError::new(400, format!("malformed request line {line:?}"))),
            };
        if !version.starts_with("HTTP/1.") {
            return Err(ParseError::new(505, format!("unsupported version {version:?}")));
        }
        let (path, query) = match target.split_once('?') {
            Some((p, q)) => (p.to_string(), q.to_string()),
            None => (target.to_string(), String::new()),
        };
        let mut headers = Vec::new();
        loop {
            let mut hl = String::new();
            let n = r
                .read_line(&mut hl)
                .map_err(|e| ParseError::new(400, format!("read header: {e}")))?;
            if n == 0 {
                return Err(ParseError::new(400, "connection closed mid-headers"));
            }
            head += n;
            if head > MAX_HEAD_BYTES {
                return Err(ParseError::new(431, "request head too large"));
            }
            let hl = hl.trim_end();
            if hl.is_empty() {
                break;
            }
            let (name, value) = hl
                .split_once(':')
                .ok_or_else(|| ParseError::new(400, format!("malformed header {hl:?}")))?;
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
        let mut req =
            Request { method: method.to_string(), path, query, headers, body: Vec::new() };
        if let Some(cl) = req.header("content-length") {
            let len: usize = cl
                .parse()
                .map_err(|_| ParseError::new(400, format!("bad content-length {cl:?}")))?;
            if len > MAX_BODY_BYTES {
                return Err(ParseError::new(413, "body too large"));
            }
            let mut body = vec![0u8; len];
            r.read_exact(&mut body)
                .map_err(|e| ParseError::new(400, format!("short body: {e}")))?;
            req.body = body;
        } else if req.header("transfer-encoding").is_some() {
            // inbound chunked bodies are out of scope for this API
            return Err(ParseError::new(411, "length required (chunked uploads unsupported)"));
        }
        Ok(Some(req))
    }
}

pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response (`Connection: close` — one
/// request per connection keeps the server stateless across requests).
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", status, status_reason(status))?;
    write!(w, "Content-Type: {content_type}\r\n")?;
    write!(w, "Content-Length: {}\r\n", body.len())?;
    write!(w, "Connection: close\r\n")?;
    for (name, value) in extra_headers {
        write!(w, "{name}: {value}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Chunked-transfer body writer for streaming responses. Every chunk is
/// flushed immediately so SSE events reach the client as they are
/// produced; the first failed write after the peer closes is how the
/// server learns a stream was abandoned.
pub struct ChunkedWriter<W: Write> {
    w: W,
    finished: bool,
}

impl<W: Write> ChunkedWriter<W> {
    /// Write the streaming response head and return the chunk writer.
    pub fn start(
        mut w: W,
        status: u16,
        content_type: &str,
        extra_headers: &[(&str, &str)],
    ) -> std::io::Result<ChunkedWriter<W>> {
        write!(w, "HTTP/1.1 {} {}\r\n", status, status_reason(status))?;
        write!(w, "Content-Type: {content_type}\r\n")?;
        write!(w, "Transfer-Encoding: chunked\r\n")?;
        write!(w, "Connection: close\r\n")?;
        write!(w, "Cache-Control: no-store\r\n")?;
        for (name, value) in extra_headers {
            write!(w, "{name}: {value}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.flush()?;
        Ok(ChunkedWriter { w, finished: false })
    }

    pub fn write_chunk(&mut self, data: &[u8]) -> std::io::Result<()> {
        if data.is_empty() {
            return Ok(()); // an empty chunk would terminate the body
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// Terminal zero-chunk. Safe to skip on error paths (the connection
    /// closes anyway); calling it twice is a no-op.
    pub fn finish(&mut self) -> std::io::Result<()> {
        if self.finished {
            return Ok(());
        }
        self.finished = true;
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &str) -> Result<Option<Request>, ParseError> {
        Request::read_from(&mut BufReader::new(raw.as_bytes()))
    }

    #[test]
    fn parses_get_with_headers() {
        let r = parse("GET /v1/models?x=1 HTTP/1.1\r\nHost: a\r\nAccept: */*\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/v1/models");
        assert_eq!(r.query, "x=1");
        assert_eq!(r.header("host"), Some("a"));
        assert_eq!(r.header("HOST"), Some("a"), "lookup is case-insensitive");
        assert!(r.body.is_empty());
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let r = parse("POST /v1/completions HTTP/1.1\r\nContent-Length: 9\r\n\r\n{\"a\": 1}x")
            .unwrap()
            .unwrap();
        assert_eq!(r.body, b"{\"a\": 1}x");
    }

    #[test]
    fn empty_connection_is_none_not_error() {
        assert!(parse("").unwrap().is_none());
    }

    #[test]
    fn malformed_inputs_carry_statuses() {
        assert_eq!(parse("GARBAGE\r\n\r\n").unwrap_err().status, 400);
        assert_eq!(parse("GET / SPDY/3\r\n\r\n").unwrap_err().status, 505);
        assert_eq!(
            parse("POST / HTTP/1.1\r\nContent-Length: 99\r\n\r\nshort").unwrap_err().status,
            400
        );
        assert_eq!(
            parse("POST / HTTP/1.1\r\nContent-Length: 9999999999\r\n\r\n").unwrap_err().status,
            413
        );
        assert_eq!(
            parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").unwrap_err().status,
            411
        );
        let huge = format!("GET / HTTP/1.1\r\nX-Big: {}\r\n\r\n", "y".repeat(MAX_HEAD_BYTES));
        assert_eq!(parse(&huge).unwrap_err().status, 431);
    }

    #[test]
    fn fixed_response_shape() {
        let mut out = Vec::new();
        write_response(&mut out, 429, "application/json", &[("Retry-After", "1")], b"{}")
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn chunked_stream_shape() {
        let mut out = Vec::new();
        {
            let mut cw = ChunkedWriter::start(&mut out, 200, "text/event-stream", &[]).unwrap();
            cw.write_chunk(b"data: hi\n\n").unwrap();
            cw.write_chunk(b"").unwrap(); // dropped, not a terminator
            cw.finish().unwrap();
            cw.finish().unwrap(); // idempotent
        }
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Transfer-Encoding: chunked\r\n"));
        assert!(text.contains("\r\n\r\na\r\ndata: hi\n\n\r\n0\r\n\r\n"));
    }
}
