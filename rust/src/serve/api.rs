//! Wire format of the serving front end: the OpenAI-style completions
//! request body, the SSE event grammar streamed back, and the mapping
//! from [`SubmitError`] to HTTP statuses. Kept free of sockets so every
//! piece is unit-testable; `serve::server` does the I/O.
//!
//! # Request body (`POST /v1/completions`)
//!
//! ```json
//! {
//!   "prompt": [464, 3290, 318],      // token IDs (no tokenizer in-repo)
//!   "max_tokens": 32,                 // 0 / absent = server default
//!   "temperature": 0.8,               // absent = greedy
//!   "top_k": 40, "top_p": 0.95,
//!   "repetition_penalty": 1.1,
//!   "seed": 7,
//!   "tenant": "team-a",               // QoS lane; absent = anonymous
//!   "speculative": {"k": 3, "draft": "naive-int4"},
//!   "stream": true                    // false = buffered JSON response
//! }
//! ```
//!
//! # SSE event grammar (`Content-Type: text/event-stream`, chunked)
//!
//! ```text
//! data: {"index":0,"token":464}\n\n        one per generated token
//! data: {"finish":"length","generated":32,"latency_ms":8.2}\n\n
//! data: {"error":"..."}\n\n                terminal on failure
//! data: [DONE]\n\n                          always the last event
//! ```
//!
//! `finish` spells [`FinishReason::as_wire`]: `length`, `shutdown`,
//! `evicted`, `cancelled`.

use crate::coordinator::{FinishReason, GenerateRequest, SubmitError};
use crate::gpt2::DraftKind;
use crate::util::json::Json;
use std::time::Duration;

/// A parsed completions call: the generation request plus transport
/// options that never reach the scheduler.
#[derive(Debug, Clone)]
pub struct CompletionCall {
    pub req: GenerateRequest,
    /// stream SSE events (default) or buffer into one JSON response
    pub stream: bool,
}

/// Parse a completions body. Every failure is a client error (HTTP 400)
/// with the reason in the message.
pub fn parse_completion(body: &[u8]) -> Result<CompletionCall, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not utf-8".to_string())?;
    let j = Json::parse(text).map_err(|e| format!("bad json: {e:#}"))?;
    let prompt_field = j.get("prompt").map_err(|_| "missing \"prompt\"".to_string())?;
    let arr = prompt_field
        .as_arr()
        .map_err(|_| "\"prompt\" must be an array of token ids".to_string())?;
    let mut prompt = Vec::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        let n = t.as_f64().map_err(|_| format!("prompt[{i}] is not a number"))?;
        if n < 0.0 || n.fract() != 0.0 || n > u32::MAX as f64 {
            return Err(format!("prompt[{i}] = {n} is not a token id"));
        }
        prompt.push(n as u32);
    }
    if prompt.is_empty() {
        return Err("empty prompt".to_string());
    }
    let num = |key: &str, default: f64| -> Result<f64, String> {
        match j.get(key) {
            Ok(v) => v.as_f64().map_err(|_| format!("{key:?} must be a number")),
            Err(_) => Ok(default),
        }
    };
    let max_tokens = num("max_tokens", 0.0)?;
    if max_tokens < 0.0 || max_tokens.fract() != 0.0 {
        return Err(format!("\"max_tokens\" = {max_tokens} is not a non-negative integer"));
    }
    let mut req = GenerateRequest::greedy(prompt, max_tokens as usize);
    req.temperature = num("temperature", 0.0)? as f32;
    req.top_k = num("top_k", 0.0)? as usize;
    req.top_p = num("top_p", 1.0)? as f32;
    req.repetition_penalty = num("repetition_penalty", 1.0)? as f32;
    req.seed = num("seed", 0.0)? as u64;
    if req.top_p <= 0.0 || req.top_p > 1.0 {
        return Err(format!("\"top_p\" = {} out of (0, 1]", req.top_p));
    }
    if let Ok(t) = j.get("tenant") {
        req.tenant = t.as_str().map_err(|_| "\"tenant\" must be a string".to_string())?.into();
        if req.tenant.contains(|c: char| c.is_whitespace()) {
            return Err("\"tenant\" must not contain whitespace".to_string());
        }
    }
    if let Ok(sp) = j.get("speculative") {
        let k = sp
            .get("k")
            .and_then(|v| v.as_usize())
            .map_err(|_| "\"speculative.k\" must be an integer".to_string())?;
        if k == 0 {
            return Err("\"speculative.k\" must be >= 1".to_string());
        }
        let tag = sp
            .get("draft")
            .and_then(|v| v.as_str().map(str::to_string))
            .map_err(|_| "\"speculative.draft\" must be a string".to_string())?;
        let draft = DraftKind::parse(&tag).map_err(|e| format!("{e:#}"))?;
        req = req.with_speculative(k, draft);
    }
    let stream = match j.get("stream") {
        Ok(v) => v.as_bool().map_err(|_| "\"stream\" must be a boolean".to_string())?,
        Err(_) => true,
    };
    Ok(CompletionCall { req, stream })
}

/// `(status, Retry-After?)` for an admission outcome. Shedding answers
/// (429/503) always carry `Retry-After` so well-behaved clients back
/// off instead of hammering the acceptor.
pub fn submit_error_status(e: &SubmitError) -> (u16, bool) {
    match e {
        SubmitError::BadRequest(_) => (400, false),
        SubmitError::TenantBusy => (429, true),
        SubmitError::QueueFull | SubmitError::Shutdown => (503, true),
    }
}

pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

pub fn error_body(message: &str) -> String {
    format!("{{\"error\": \"{}\"}}\n", json_escape(message))
}

/// One generated token as an SSE event.
pub fn sse_token(index: usize, token: u32) -> String {
    format!("data: {{\"index\":{index},\"token\":{token}}}\n\n")
}

/// Terminal event for a finished stream.
pub fn sse_done(reason: FinishReason, generated: usize, latency: Duration) -> String {
    format!(
        "data: {{\"finish\":\"{}\",\"generated\":{},\"latency_ms\":{:.3}}}\n\n",
        reason.as_wire(),
        generated,
        latency.as_secs_f64() * 1e3
    )
}

/// Terminal event for a failed stream.
pub fn sse_error(message: &str) -> String {
    format!("data: {{\"error\":\"{}\"}}\n\n", json_escape(message))
}

/// The stream-end sentinel (OpenAI convention).
pub fn sse_terminator() -> &'static str {
    "data: [DONE]\n\n"
}

/// Buffered (`"stream": false`) completion response body.
pub fn completion_body(tokens: &[u32], reason: FinishReason, latency: Duration) -> String {
    let toks: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
    format!(
        "{{\"tokens\": [{}], \"finish\": \"{}\", \"generated\": {}, \"latency_ms\": {:.3}}}\n",
        toks.join(", "),
        reason.as_wire(),
        tokens.len(),
        latency.as_secs_f64() * 1e3
    )
}

/// `GET /v1/models` body.
pub fn models_body(model_id: &str, engine_tag: &str) -> String {
    format!(
        "{{\"object\": \"list\", \"data\": [{{\"id\": \"{}\", \"object\": \"model\", \
         \"owned_by\": \"muxq\", \"engine\": \"{}\"}}]}}\n",
        json_escape(model_id),
        json_escape(engine_tag)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_body() {
        let c = parse_completion(br#"{"prompt": [1, 2, 3]}"#).unwrap();
        assert_eq!(c.req.prompt, vec![1, 2, 3]);
        assert_eq!(c.req.max_new_tokens, 0, "absent max_tokens -> server default");
        assert!(c.req.sampler().is_greedy());
        assert_eq!(c.req.tenant, "");
        assert!(c.req.speculative.is_none());
        assert!(c.stream, "streaming is the default");
    }

    #[test]
    fn parses_every_knob() {
        let c = parse_completion(
            br#"{"prompt": [5], "max_tokens": 9, "temperature": 0.8, "top_k": 40,
                "top_p": 0.95, "repetition_penalty": 1.1, "seed": 7,
                "tenant": "team-a", "speculative": {"k": 3, "draft": "naive-int4"},
                "stream": false}"#,
        )
        .unwrap();
        assert_eq!(c.req.max_new_tokens, 9);
        assert_eq!(c.req.temperature, 0.8);
        assert_eq!((c.req.top_k, c.req.top_p), (40, 0.95));
        assert_eq!(c.req.repetition_penalty, 1.1);
        assert_eq!(c.req.seed, 7);
        assert_eq!(c.req.tenant, "team-a");
        let sp = c.req.speculative.unwrap();
        assert_eq!(sp.k, 3);
        assert_eq!(sp.draft, DraftKind::NaiveInt4);
        assert!(!c.stream);
    }

    #[test]
    fn rejects_malformed_bodies() {
        for bad in [
            &b"not json"[..],
            br#"{}"#,
            br#"{"prompt": "text"}"#,
            br#"{"prompt": []}"#,
            br#"{"prompt": [1.5]}"#,
            br#"{"prompt": [-1]}"#,
            br#"{"prompt": [1], "max_tokens": -3}"#,
            br#"{"prompt": [1], "top_p": 0.0}"#,
            br#"{"prompt": [1], "top_p": 1.5}"#,
            br#"{"prompt": [1], "tenant": 5}"#,
            br#"{"prompt": [1], "tenant": "a b"}"#,
            br#"{"prompt": [1], "speculative": {"k": 0, "draft": "naive-int8"}}"#,
            br#"{"prompt": [1], "speculative": {"k": 2, "draft": "warp-drive"}}"#,
            br#"{"prompt": [1], "stream": "yes"}"#,
        ] {
            assert!(
                parse_completion(bad).is_err(),
                "should reject {:?}",
                String::from_utf8_lossy(bad)
            );
        }
    }

    #[test]
    fn admission_outcomes_map_to_statuses() {
        assert_eq!(submit_error_status(&SubmitError::BadRequest("x".into())), (400, false));
        assert_eq!(submit_error_status(&SubmitError::TenantBusy), (429, true));
        assert_eq!(submit_error_status(&SubmitError::QueueFull), (503, true));
        assert_eq!(submit_error_status(&SubmitError::Shutdown), (503, true));
    }

    #[test]
    fn sse_events_are_well_formed() {
        assert_eq!(sse_token(0, 464), "data: {\"index\":0,\"token\":464}\n\n");
        let done = sse_done(FinishReason::MaxTokens, 4, Duration::from_millis(8));
        assert!(done.starts_with("data: {\"finish\":\"length\",\"generated\":4,"));
        assert!(done.ends_with("\n\n"));
        assert_eq!(sse_error("a\"b"), "data: {\"error\":\"a\\\"b\"}\n\n");
        assert_eq!(sse_terminator(), "data: [DONE]\n\n");
        // every event parses back as json (the sentinel aside)
        for ev in [sse_token(1, 2), done, sse_error("x\n")] {
            let payload = ev.trim_start_matches("data: ").trim_end();
            Json::parse(payload).expect("event payload is valid json");
        }
    }

    #[test]
    fn buffered_and_models_bodies_parse() {
        let b = completion_body(&[7, 9], FinishReason::MaxTokens, Duration::from_millis(1));
        let j = Json::parse(b.trim()).unwrap();
        assert_eq!(j.get("generated").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("finish").unwrap().as_str().unwrap(), "length");
        let m = Json::parse(models_body("tiny", "muxq-w8a8").trim()).unwrap();
        assert_eq!(
            m.get("data").unwrap().as_arr().unwrap()[0].get("id").unwrap().as_str().unwrap(),
            "tiny"
        );
    }
}
