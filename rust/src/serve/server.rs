//! The HTTP front end: a `std::net::TcpListener` acceptor feeding a
//! bounded worker pool, one request per connection. Workers parse with
//! [`serve::http`], map bodies with [`serve::api`], and bridge
//! [`GenerationServer`] token streams onto chunked SSE.
//!
//! ## Admission and shedding
//!
//! Three layers shed load before it can block the acceptor:
//!
//! 1. **acceptor → worker pool**: accepted connections enter a bounded
//!    channel; when every worker is busy and the backlog is full the
//!    acceptor answers `503 + Retry-After` inline and closes (counted
//!    as `http_sheds`).
//! 2. **whole-queue backpressure**: [`SubmitError::QueueFull`] /
//!    [`SubmitError::Shutdown`] → `503 + Retry-After`.
//! 3. **per-tenant caps**: [`SubmitError::TenantBusy`] → `429 +
//!    Retry-After` — one noisy tenant is refused while others admit.
//!
//! ## Disconnect handling
//!
//! Every SSE event is one flushed chunk; the first failed write after
//! the peer closes surfaces as an error here, the worker drops the
//! [`GenerateHandle`], and the decode scheduler cancels the live
//! session at its next step ([`FinishReason::Cancelled`]) — abandoned
//! streams free their KV pages promptly instead of decoding to budget.
//! Counted as `http_disconnects`.
//!
//! [`serve::http`]: super::http
//! [`serve::api`]: super::api
//! [`SubmitError::QueueFull`]: crate::coordinator::SubmitError
//! [`SubmitError::Shutdown`]: crate::coordinator::SubmitError
//! [`SubmitError::TenantBusy`]: crate::coordinator::SubmitError
//! [`FinishReason::Cancelled`]: crate::coordinator::FinishReason

use super::api;
use super::http::{write_response, ChunkedWriter, Request};
use crate::coordinator::{GenerationServer, TokenEvent};
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// bind address; port 0 picks an ephemeral port (tests, CI smoke)
    pub addr: String,
    /// worker threads == max concurrently served connections
    pub workers: usize,
    /// accepted connections waiting for a worker before the acceptor
    /// sheds inline with 503
    pub backlog: usize,
    /// reported by `GET /v1/models`
    pub model_id: String,
    /// the deployed operator tag (`EngineSpec::tag`), reported next to
    /// the model id
    pub engine_tag: String,
    /// `Retry-After` seconds on 429/503 answers
    pub retry_after_secs: u64,
    /// per-connection read timeout (slow or stalled clients release
    /// their worker instead of pinning it)
    pub read_timeout: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 16,
            backlog: 64,
            model_id: "muxq".to_string(),
            engine_tag: "unknown".to_string(),
            retry_after_secs: 1,
            read_timeout: Duration::from_secs(10),
        }
    }
}

/// The running front end. [`HttpServer::shutdown`] (or drop) stops the
/// acceptor, drains the worker pool, and joins every thread; the
/// underlying [`GenerationServer`] is shared and NOT shut down here.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    pub fn start(gen: Arc<GenerationServer>, cfg: ServeConfig) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::sync_channel::<TcpStream>(cfg.backlog.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let cfg = Arc::new(cfg);
        let workers = (0..cfg.workers.max(1))
            .map(|i| {
                let rx = rx.clone();
                let gen = gen.clone();
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("muxq-http-{i}"))
                    .spawn(move || loop {
                        // holding the lock only for recv keeps the pool
                        // work-stealing: any free worker takes the next conn
                        let stream = match rx.lock().unwrap().recv() {
                            Ok(s) => s,
                            Err(_) => return, // acceptor gone, queue drained
                        };
                        handle_connection(&gen, &cfg, stream);
                    })
                    .expect("spawn http worker")
            })
            .collect();
        let acceptor = {
            let stop = stop.clone();
            let gen = gen.clone();
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name("muxq-http-accept".into())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break; // tx drops here; workers drain and exit
                        }
                        let stream = match conn {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        match tx.try_send(stream) {
                            Ok(()) => {}
                            Err(TrySendError::Full(stream)) => {
                                // every worker busy AND backlog full: shed
                                // inline so the acceptor never blocks
                                gen.metrics().counter("http_sheds").inc();
                                shed_overloaded(stream, &cfg);
                            }
                            Err(TrySendError::Disconnected(_)) => break,
                        }
                    }
                })
                .expect("spawn http acceptor")
        };
        Ok(HttpServer { addr, stop, acceptor: Some(acceptor), workers })
    }

    /// The actually-bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.acceptor.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // the acceptor blocks in accept(); a self-connection wakes it to
        // observe the stop flag
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Inline 503 for connections the pool cannot absorb.
fn shed_overloaded(stream: TcpStream, cfg: &ServeConfig) {
    let mut w = BufWriter::new(stream);
    let retry = cfg.retry_after_secs.to_string();
    let _ = write_response(
        &mut w,
        503,
        "application/json",
        &[("Retry-After", retry.as_str())],
        api::error_body("server overloaded (worker pool saturated)").as_bytes(),
    );
}

fn handle_connection(gen: &GenerationServer, cfg: &ServeConfig, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(cfg.read_timeout));
    let _ = stream.set_nodelay(true); // SSE events are tiny; don't batch them
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = BufWriter::new(stream);
    gen.metrics().counter("http_requests").inc();
    let req = match Request::read_from(&mut reader) {
        Ok(Some(req)) => req,
        Ok(None) => return, // closed without a request (probe / pool churn)
        Err(e) => {
            gen.metrics().counter("http_parse_errors").inc();
            let _ = write_response(
                &mut writer,
                e.status,
                "application/json",
                &[],
                api::error_body(&e.message).as_bytes(),
            );
            return;
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/completions") => serve_completion(gen, cfg, &mut writer, &req),
        ("GET", "/v1/models") => {
            let body = api::models_body(&cfg.model_id, &cfg.engine_tag);
            let _ = write_response(&mut writer, 200, "application/json", &[], body.as_bytes());
        }
        ("GET", "/metrics") => {
            let body = metrics_text(gen);
            let _ = write_response(
                &mut writer,
                200,
                "text/plain; charset=utf-8",
                &[],
                body.as_bytes(),
            );
        }
        (_, "/v1/completions") | (_, "/v1/models") | (_, "/metrics") => {
            gen.metrics().counter("http_404").inc();
            let _ = write_response(
                &mut writer,
                405,
                "application/json",
                &[],
                api::error_body(&format!("{} not allowed on {}", req.method, req.path)).as_bytes(),
            );
        }
        _ => {
            gen.metrics().counter("http_404").inc();
            let _ = write_response(
                &mut writer,
                404,
                "application/json",
                &[],
                api::error_body(&format!("no route {}", req.path)).as_bytes(),
            );
        }
    }
}

fn serve_completion<W: Write>(
    gen: &GenerationServer,
    cfg: &ServeConfig,
    writer: &mut W,
    req: &Request,
) {
    let call = match api::parse_completion(&req.body) {
        Ok(c) => c,
        Err(msg) => {
            gen.metrics().counter("http_400").inc();
            let _ = write_response(
                writer,
                400,
                "application/json",
                &[],
                api::error_body(&msg).as_bytes(),
            );
            return;
        }
    };
    let t0 = Instant::now();
    let handle = match gen.try_submit(call.req) {
        Ok(h) => h,
        Err(e) => {
            let (status, retry) = api::submit_error_status(&e);
            gen.metrics().counter(&format!("http_{status}")).inc();
            let retry_secs = cfg.retry_after_secs.to_string();
            let extra: &[(&str, &str)] =
                if retry { &[("Retry-After", retry_secs.as_str())] } else { &[] };
            let _ = write_response(
                writer,
                status,
                "application/json",
                extra,
                api::error_body(&e.to_string()).as_bytes(),
            );
            return;
        }
    };
    if !call.stream {
        // buffered mode: drain the stream, answer once
        let mut tokens = Vec::new();
        loop {
            match handle.recv() {
                Some(TokenEvent::Token { token, .. }) => {
                    if tokens.is_empty() {
                        gen.metrics().histogram("http_ttft").record(t0.elapsed());
                    }
                    tokens.push(token);
                }
                Some(TokenEvent::Done { reason, latency, .. }) => {
                    gen.metrics().counter("http_streams_done").inc();
                    let body = api::completion_body(&tokens, reason, latency);
                    let _ =
                        write_response(writer, 200, "application/json", &[], body.as_bytes());
                    return;
                }
                other => {
                    let e = match other {
                        Some(TokenEvent::Error(e)) => e,
                        _ => "stream closed without a terminal event".to_string(),
                    };
                    gen.metrics().counter("http_stream_errors").inc();
                    let _ = write_response(
                        writer,
                        500,
                        "application/json",
                        &[],
                        api::error_body(&e).as_bytes(),
                    );
                    return;
                }
            }
        }
    }
    // streaming mode: headers first, then one flushed chunk per event
    let mut cw = match ChunkedWriter::start(writer, 200, "text/event-stream", &[]) {
        Ok(cw) => cw,
        Err(_) => {
            gen.metrics().counter("http_disconnects").inc();
            return; // dropping `handle` cancels the session
        }
    };
    let mut first = true;
    loop {
        match handle.recv() {
            Some(TokenEvent::Token { index, token }) => {
                if first {
                    gen.metrics().histogram("http_ttft").record(t0.elapsed());
                    first = false;
                }
                if cw.write_chunk(api::sse_token(index, token).as_bytes()).is_err() {
                    // peer closed: dropping `handle` below cancels the
                    // live session at the scheduler's next step
                    gen.metrics().counter("http_disconnects").inc();
                    return;
                }
            }
            Some(TokenEvent::Done { reason, generated, latency }) => {
                gen.metrics().counter("http_streams_done").inc();
                let _ = cw.write_chunk(api::sse_done(reason, generated, latency).as_bytes());
                break;
            }
            Some(TokenEvent::Error(e)) => {
                gen.metrics().counter("http_stream_errors").inc();
                let _ = cw.write_chunk(api::sse_error(&e).as_bytes());
                break;
            }
            None => {
                gen.metrics().counter("http_stream_errors").inc();
                let _ = cw
                    .write_chunk(api::sse_error("stream closed without a terminal event").as_bytes());
                break;
            }
        }
    }
    let _ = cw.write_chunk(api::sse_terminator().as_bytes());
    let _ = cw.finish();
}

/// `GET /metrics`: the registry dump (counters incl. per-tenant served
/// tokens, latency histograms) plus point-in-time server gauges.
fn metrics_text(gen: &GenerationServer) -> String {
    let st = gen.stats();
    let mut out = gen.metrics().render();
    out.push_str(&format!("gauge    {:<32} {}\n", "queued_now", st.queued_now));
    out.push_str(&format!("gauge    {:<32} {}\n", "pool_pages", st.pool_pages));
    out.push_str(&format!("gauge    {:<32} {}\n", "pool_pages_in_use", st.pool_pages_in_use));
    out.push_str(&format!("gauge    {:<32} {}\n", "pool_pages_free", st.pool_pages_free));
    out.push_str(&format!("gauge    {:<32} {:.4}\n", "batch_fill", st.batch_fill()));
    out.push_str(&format!("gauge    {:<32} {:.4}\n", "spec_accept_rate", st.spec_accept_rate()));
    out.push_str(&format!(
        "gauge    {:<32} {:.4}\n",
        "spec_tokens_per_round",
        st.spec_tokens_per_round()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{GenBackend, GenerationConfig};
    use crate::gpt2::Gpt2Model;
    use std::io::{BufRead, Read};

    fn tiny_server() -> (Arc<GenerationServer>, HttpServer) {
        let gen = Arc::new(GenerationServer::start(
            GenBackend::Fp(Gpt2Model::test_model(2, 16, 2, 12, 32, 7)),
            GenerationConfig { max_new_tokens: 8, ..Default::default() },
        ));
        let srv = HttpServer::start(
            gen.clone(),
            ServeConfig {
                workers: 2,
                model_id: "tiny-fp32".into(),
                engine_tag: "fp32".into(),
                ..Default::default()
            },
        )
        .unwrap();
        (gen, srv)
    }

    fn roundtrip(addr: SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn models_metrics_and_404_routes() {
        let (_gen, srv) = tiny_server();
        let addr = srv.addr();
        let models = roundtrip(addr, "GET /v1/models HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(models.starts_with("HTTP/1.1 200 OK\r\n"), "{models}");
        assert!(models.contains("tiny-fp32") && models.contains("fp32"));
        let metrics = roundtrip(addr, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(metrics.contains("counter") && metrics.contains("queued_now"), "{metrics}");
        let missing = roundtrip(addr, "GET /nope HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(missing.starts_with("HTTP/1.1 404 "), "{missing}");
        let wrong_method = roundtrip(addr, "GET /v1/completions HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(wrong_method.starts_with("HTTP/1.1 405 "), "{wrong_method}");
        let garbage = roundtrip(addr, "TOTAL NONSENSE\r\n\r\n");
        assert!(garbage.starts_with("HTTP/1.1 400 "), "{garbage}");
        srv.shutdown();
    }

    #[test]
    fn streamed_completion_roundtrip() {
        let (_gen, srv) = tiny_server();
        let body = r#"{"prompt": [1, 2, 3], "max_tokens": 4}"#;
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let resp = roundtrip(srv.addr(), &raw);
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        assert!(resp.contains("Transfer-Encoding: chunked"), "{resp}");
        assert_eq!(resp.matches("\"token\":").count(), 4, "{resp}");
        assert!(resp.contains("\"finish\":\"length\""), "{resp}");
        assert!(resp.contains("data: [DONE]"), "{resp}");
        srv.shutdown();
    }

    #[test]
    fn buffered_completion_roundtrip() {
        let (_gen, srv) = tiny_server();
        let body = r#"{"prompt": [1, 2, 3], "max_tokens": 3, "stream": false}"#;
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let resp = roundtrip(srv.addr(), &raw);
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        let json_start = resp.find("\r\n\r\n").unwrap() + 4;
        let j = crate::util::json::Json::parse(resp[json_start..].trim()).unwrap();
        assert_eq!(j.get("generated").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 3);
        srv.shutdown();
    }

    #[test]
    fn bad_body_is_400_with_reason() {
        let (gen, srv) = tiny_server();
        let body = r#"{"prompt": []}"#;
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let resp = roundtrip(srv.addr(), &raw);
        assert!(resp.starts_with("HTTP/1.1 400 "), "{resp}");
        assert!(resp.contains("empty prompt"), "{resp}");
        assert_eq!(gen.metrics().counter("http_400").get(), 1);
        srv.shutdown();
    }

    #[test]
    fn client_disconnect_cancels_session() {
        // a budget far beyond what the client will read: if disconnect
        // did NOT cancel, the session would decode for ages
        let gen = Arc::new(GenerationServer::start(
            GenBackend::Fp(Gpt2Model::test_model(2, 16, 2, 12, 32, 7)),
            GenerationConfig { max_new_tokens: 50_000, ..Default::default() },
        ));
        let srv = HttpServer::start(gen.clone(), ServeConfig::default()).unwrap();
        // a long stream the client abandons after the first token
        let body = r#"{"prompt": [1, 2, 3], "max_tokens": 50000}"#;
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        {
            let mut s = TcpStream::connect(srv.addr()).unwrap();
            s.write_all(raw.as_bytes()).unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            assert!(line.starts_with("HTTP/1.1 200"), "{line}");
            // drop both halves: the next chunk write fails server-side
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        while Instant::now() < deadline {
            if gen.stats().cancelled >= 1 || gen.metrics().counter("http_disconnects").get() >= 1
            {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(
            gen.stats().cancelled >= 1,
            "abandoned stream cancelled the live session (stats: {:?})",
            gen.stats()
        );
        assert!(gen.metrics().counter("http_disconnects").get() >= 1);
        srv.shutdown();
    }

    #[test]
    fn shutdown_is_clean_and_idempotent_under_drop() {
        let (_gen, srv) = tiny_server();
        let addr = srv.addr();
        drop(srv); // Drop path must also join cleanly
        // the port is released: a fresh server can bind it again
        let gen2 = Arc::new(GenerationServer::start(
            GenBackend::Fp(Gpt2Model::test_model(2, 16, 2, 12, 32, 7)),
            GenerationConfig::default(),
        ));
        let srv2 = HttpServer::start(
            gen2.clone(),
            ServeConfig { addr: addr.to_string(), ..Default::default() },
        );
        // (rebinding may race with TIME_WAIT on some kernels; ephemeral
        // bind is the guaranteed path)
        if let Ok(s) = srv2 {
            s.shutdown();
        }
    }
}
