//! Token-level generation serving: continuous batching over the native
//! incremental-decode engine (`gpt2::session`).
//!
//! ```text
//! client ──submit──> GenerationServer (admission, backpressure)
//!    ──> DecodeQueue ──> decode scheduler (one thread, owns the model):
//!          loop {
//!            admit new sessions while slots free (PREFILL, between steps)
//!            decode_step_batch over the PLAIN live sessions <- ONE skinny GEMM
//!            one draft-and-verify round per SPECULATIVE session
//!            per session: sample (greedy/temperature/top-k/top-p) -> stream
//!            TokenEvent, retire at budget
//!          }
//! ```
//!
//! This is the latency-bound regime the paper's uniform-INT argument
//! targets: per-step projections are M=G skinny GEMMs (M=1..4 routes to
//! the packed engine's GEMV path) and memory-bound — see
//! `npusim::decode_cost`. Because the session projection is
//! row-independent (`gpt2::quantized`), coalescing G sessions into one
//! GEMM returns per-session logits bit-identical to stepping each alone:
//! continuous batching changes throughput, never results.
//!
//! Requests carrying a [`super::request::SpeculativeConfig`] are served
//! through [`SpeculativeState`] instead: the scheduler lazily builds one
//! [`DraftModel`] per requested [`DraftKind`] (shared by every session
//! asking for it) and runs one draft-and-verify round per tick, emitting
//! the round's `a + 1` tokens onto the stream. Greedy speculative
//! streams are bit-identical to plain greedy serving (`gpt2::speculative`
//! losslessness), and the server reports acceptance-rate /
//! tokens-per-round under `spec_*` stats.
//!
//! Contrast with the scoring plane (`scheduler`): scoring coalesces
//! one-shot fixed-shape requests and runs them on compiled PJRT
//! variants; generation holds stateful sessions over the native packed
//! INT engine and interleaves prefill admission with decode steps.

use super::batcher::{AdmitError, DecodePop, DecodeQueue, QosConfig, TenantPermit};
use super::request::{FinishReason, GenerateHandle, GenerateRequest, PendingGen, TokenEvent};
use crate::gpt2::kvpool::{KvPool, PrefixCache};
use crate::gpt2::session::{decode_step_batch, Sampler, SessionModel, SessionState, WrapPolicy};
use crate::gpt2::speculative::{DraftKind, DraftModel, SpeculativeState, DRAFT_SEED_SALT};
use crate::gpt2::{Gpt2Model, QuantizedGpt2};
use crate::quant::MatF32;
use crate::util::metrics::Registry;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

/// The model a generation server decodes with (owned; the scheduler
/// thread is the only toucher, sessions borrow it there).
pub enum GenBackend {
    Fp(Gpt2Model),
    Int(QuantizedGpt2),
}

impl GenBackend {
    fn session_model(&self) -> SessionModel<'_> {
        match self {
            GenBackend::Fp(m) => SessionModel::Fp(m),
            GenBackend::Int(q) => SessionModel::Int(q),
        }
    }

    pub fn gpt(&self) -> &Gpt2Model {
        match self {
            GenBackend::Fp(m) => m,
            GenBackend::Int(q) => &q.fp,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GenerationConfig {
    /// live-session cap == the decode batch width ceiling
    pub max_live: usize,
    /// admission backpressure: max requests waiting for a slot
    pub max_queue: usize,
    /// server-side ceiling on tokens per request (requests asking for 0
    /// get exactly this)
    pub max_new_tokens: usize,
    /// context-overflow policy for every session
    pub wrap: WrapPolicy,
    /// KV pool capacity in pages. 0 (the default) keeps ring-per-session
    /// storage; > 0 switches every session to paged KV drawn from one
    /// shared [`KvPool`], with admission priced by actual free pages and
    /// copy-on-write prefix sharing across sessions.
    pub pool_pages: usize,
    /// K/V rows per page (paged mode only; clamped to >= 1)
    pub page_rows: usize,
    /// prefixes the shared [`PrefixCache`] retains (paged mode only)
    pub prefix_cache_entries: usize,
    /// multi-tenant admission policy (weights, quanta, per-tenant caps).
    /// The default is weight-1-for-everyone with no caps, which makes a
    /// single-tenant server FIFO bit-exact. `default_cost_tokens` is
    /// overridden with `max_new_tokens` at start so DWRR costs mirror
    /// the server's actual budget clamp.
    pub qos: QosConfig,
}

impl Default for GenerationConfig {
    fn default() -> Self {
        GenerationConfig {
            max_live: 8,
            max_queue: 256,
            max_new_tokens: 128,
            wrap: WrapPolicy::Reprefill { keep: 0 },
            pool_pages: 0,
            page_rows: 16,
            prefix_cache_entries: 8,
            qos: QosConfig::default(),
        }
    }
}

/// Structured admission outcome for [`GenerationServer::try_submit`] —
/// the HTTP front end maps each variant to a status code (`serve::api`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// whole-queue backpressure — HTTP 503 + `Retry-After`
    QueueFull,
    /// this tenant's own queue cap — HTTP 429 + `Retry-After`
    TenantBusy,
    /// malformed request (e.g. empty prompt) — HTTP 400
    BadRequest(String),
    /// server stopped — HTTP 503
    Shutdown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "generation queue full (backpressure)"),
            SubmitError::TenantBusy => write!(f, "tenant queue full (per-tenant cap)"),
            SubmitError::BadRequest(m) => write!(f, "{m}"),
            SubmitError::Shutdown => write!(f, "generation server is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Point-in-time statistics snapshot.
#[derive(Debug, Clone)]
pub struct GenerationStats {
    pub submitted: u64,
    pub rejected: u64,
    /// requests that reached their token budget
    pub completed: u64,
    /// requests whose client dropped the handle mid-stream (observable
    /// only here — the dropped receiver can't be sent a terminal event)
    pub cancelled: u64,
    /// requests cut by shutdown (queued or live)
    pub shutdown_cut: u64,
    /// prefills that failed admission (bad prompt) — their streams ended
    /// with `TokenEvent::Error`
    pub admit_errors: u64,
    /// coalesced decode steps that failed (poisoning their sessions)
    pub decode_errors: u64,
    pub tokens_generated: u64,
    pub decode_batches: u64,
    /// session-rows across all decode batches (fill = rows / batches)
    pub decode_rows: u64,
    /// prefill passes (admissions + wrap re-prefills)
    pub prefills: u64,
    /// prompts longer than n_ctx, truncated at admission
    pub prompts_truncated: u64,
    /// draft-and-verify rounds run across all speculative sessions
    pub spec_rounds: u64,
    /// draft tokens proposed (k per round)
    pub spec_drafted: u64,
    /// draft tokens the target accepted
    pub spec_accepted: u64,
    /// prefill admissions that seeded shared prefix pages (paged mode)
    pub prefix_hits: u64,
    /// prefill admissions that found no shareable prefix (paged mode)
    pub prefix_misses: u64,
    /// admissions refused because the pool could not cover the prompt
    pub pool_refusals: u64,
    /// live sessions evicted under pool pressure (streams ended with
    /// [`FinishReason::Evicted`])
    pub evicted: u64,
    /// pool capacity in pages (0 = ring mode, no pool)
    pub pool_pages: usize,
    /// pages currently held by live owners
    pub pool_pages_in_use: usize,
    /// pages allocatable right now
    pub pool_pages_free: usize,
    /// PEAK shared-page count observed across scheduler ticks (sessions
    /// retire between ticks, so a last-sample gauge would usually read 0
    /// by the time stats are collected)
    pub shared_pages: u64,
    /// copy-on-write page forks performed
    pub cow_forks: u64,
    pub queued_now: usize,
}

impl GenerationStats {
    /// Mean live sessions per decode step — how full the continuous
    /// batch ran.
    pub fn batch_fill(&self) -> f64 {
        if self.decode_batches == 0 {
            return 0.0;
        }
        self.decode_rows as f64 / self.decode_batches as f64
    }

    /// Fraction of drafted tokens the target accepted, across every
    /// speculative session served.
    pub fn spec_accept_rate(&self) -> f64 {
        if self.spec_drafted == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_drafted as f64
    }

    /// Mean tokens emitted per speculative round (accepted + the
    /// correction/bonus token); plain sequential decode is 1.0.
    pub fn spec_tokens_per_round(&self) -> f64 {
        if self.spec_rounds == 0 {
            return 0.0;
        }
        (self.spec_accepted + self.spec_rounds) as f64 / self.spec_rounds as f64
    }

    /// Fraction of the KV pool currently in use (0.0 in ring mode).
    pub fn paged_fill(&self) -> f64 {
        if self.pool_pages == 0 {
            return 0.0;
        }
        self.pool_pages_in_use as f64 / self.pool_pages as f64
    }

    /// Peak shared pages as a fraction of pool capacity (0.0 in ring
    /// mode) — how much footprint prefix sharing saved at its best.
    pub fn shared_page_ratio(&self) -> f64 {
        if self.pool_pages == 0 {
            return 0.0;
        }
        self.shared_pages as f64 / self.pool_pages as f64
    }
}

/// How a live session decodes: plain sessions coalesce into one skinny
/// batched step per tick; speculative sessions run one draft-and-verify
/// round per tick against a scheduler-owned shared [`DraftModel`].
enum LiveKind {
    Plain(SessionState),
    Spec {
        spec: SpeculativeState,
        /// index into the scheduler's draft-model cache
        draft_idx: usize,
        /// the draft's own decorrelated sampling stream
        /// ([`DRAFT_SEED_SALT`] fork of the request sampler)
        draft_sampler: Sampler,
    },
}

/// One live session inside the scheduler.
struct Live {
    kind: LiveKind,
    /// this request's token selector (greedy or seeded sampling) —
    /// per-session state, so coalescing never couples streams
    sampler: Sampler,
    /// last emitted token == the next decode input
    next: u32,
    produced: usize,
    budget: usize,
    /// session prefill passes already reflected in the metrics registry
    /// (wrap re-prefills happen inside decode steps; the delta is
    /// harvested after each step)
    prefills_seen: u64,
    /// QoS lane this session was admitted under ("" = anonymous);
    /// non-empty tenants get a `tokens_tenant_<name>` served counter
    tenant: String,
    /// the tenant's in-flight slot — dropping the `Live` on ANY retire
    /// path releases it, unblocking the lane's next queued request
    _permit: TenantPermit,
    tx: mpsc::Sender<TokenEvent>,
    t0: Instant,
}

impl Live {
    /// Prefill passes this session has run so far (target + draft for
    /// speculative sessions) — the scheduler harvests the delta into the
    /// metrics registry after each tick.
    fn prefill_count(&self) -> u64 {
        match &self.kind {
            LiveKind::Plain(s) => s.prefills(),
            LiveKind::Spec { spec, .. } => {
                spec.target_state().prefills() + spec.draft_state().prefills()
            }
        }
    }
}

/// Shared (prefix) pages this live session currently holds — summed into
/// the pool's peak-gauge each tick.
fn shared_pages_of(l: &Live) -> usize {
    match &l.kind {
        LiveKind::Plain(s) => s.shared_pages(),
        LiveKind::Spec { spec, .. } => {
            spec.target_state().shared_pages() + spec.draft_state().shared_pages()
        }
    }
}

/// The generation server: spawn with [`GenerationServer::start`], feed
/// it [`GenerateRequest`]s, read streamed tokens off the returned
/// [`GenerateHandle`]s. One server per deployed model/method (the
/// scoring coordinator's multi-variant registry is the other plane).
pub struct GenerationServer {
    queue: Arc<DecodeQueue>,
    metrics: Arc<Registry>,
    running: Arc<AtomicBool>,
    /// shared KV page pool (`Some` iff `pool_pages > 0`); the server
    /// keeps a clone so `stats()` can read live occupancy gauges
    pool: Option<KvPool>,
    worker: Option<JoinHandle<()>>,
}

impl GenerationServer {
    pub fn start(backend: GenBackend, cfg: GenerationConfig) -> GenerationServer {
        // a zero-width batch could never admit, so the scheduler would
        // never reach the queue (or see its shutdown) — clamp like
        // max_queue below
        let cfg = GenerationConfig { max_live: cfg.max_live.max(1), ..cfg };
        // DWRR costs track the budgets the scheduler will actually grant
        let qos = QosConfig {
            default_cost_tokens: cfg.max_new_tokens.max(1) as u64,
            ..cfg.qos.clone()
        };
        let queue = Arc::new(DecodeQueue::with_qos(cfg.max_queue.max(1), qos));
        let metrics = Arc::new(Registry::default());
        let running = Arc::new(AtomicBool::new(true));
        let pool = (cfg.pool_pages > 0)
            .then(|| KvPool::new(cfg.pool_pages, cfg.page_rows.max(1), backend.gpt().cfg.d_model));
        let worker = {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let pool = pool.clone();
            std::thread::Builder::new()
                .name("muxq-decode".into())
                .spawn(move || scheduler_loop(backend, cfg, queue, metrics, pool))
                .expect("spawn decode scheduler")
        };
        GenerationServer { queue, metrics, running, pool, worker: Some(worker) }
    }

    /// Submit a generation request; returns the token stream handle.
    pub fn submit(&self, req: GenerateRequest) -> Result<GenerateHandle> {
        self.try_submit(req).map_err(|e| anyhow!("{e}"))
    }

    /// [`GenerationServer::submit`] with a structured admission outcome,
    /// so callers (the HTTP front end) can distinguish shedding
    /// (429/503 + `Retry-After`) from malformed input (400).
    pub fn try_submit(&self, req: GenerateRequest) -> Result<GenerateHandle, SubmitError> {
        self.metrics.counter("submitted").inc();
        if !self.running.load(Ordering::SeqCst) {
            self.metrics.counter("rejected").inc();
            return Err(SubmitError::Shutdown);
        }
        if req.prompt.is_empty() {
            self.metrics.counter("rejected").inc();
            return Err(SubmitError::BadRequest("empty prompt".into()));
        }
        let (tx, rx) = mpsc::channel();
        match self.queue.push(PendingGen { req, submitted: Instant::now(), tx }) {
            Ok(()) => Ok(GenerateHandle { rx }),
            Err(e) => {
                self.metrics.counter("rejected").inc();
                Err(match e {
                    AdmitError::QueueFull => SubmitError::QueueFull,
                    AdmitError::TenantBusy => SubmitError::TenantBusy,
                    AdmitError::Shutdown => SubmitError::Shutdown,
                })
            }
        }
    }

    /// Convenience: submit + drain the stream.
    pub fn generate(&self, req: GenerateRequest) -> Result<Vec<u32>> {
        self.submit(req)?.collect_tokens()
    }

    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    pub fn stats(&self) -> GenerationStats {
        let c = |name: &str| self.metrics.counter(name).get();
        GenerationStats {
            submitted: c("submitted"),
            rejected: c("rejected"),
            completed: c("completed"),
            cancelled: c("cancelled"),
            shutdown_cut: c("shutdown_cut"),
            admit_errors: c("admit_errors"),
            decode_errors: c("decode_errors"),
            tokens_generated: c("tokens_generated"),
            decode_batches: c("decode_batches"),
            decode_rows: c("decode_rows"),
            prefills: c("prefills"),
            prompts_truncated: c("prompts_truncated"),
            spec_rounds: c("spec_rounds"),
            spec_drafted: c("spec_drafted"),
            spec_accepted: c("spec_accepted"),
            prefix_hits: c("prefix_hits"),
            prefix_misses: c("prefix_misses"),
            pool_refusals: c("pool_refusals"),
            evicted: c("evicted"),
            pool_pages: self.pool.as_ref().map(|p| p.capacity()).unwrap_or(0),
            pool_pages_in_use: self.pool.as_ref().map(|p| p.pages_in_use()).unwrap_or(0),
            pool_pages_free: self.pool.as_ref().map(|p| p.free_pages()).unwrap_or(0),
            shared_pages: self.pool.as_ref().map(|p| p.shared_pages_note()).unwrap_or(0),
            cow_forks: self.pool.as_ref().map(|p| p.cow_forks()).unwrap_or(0),
            queued_now: self.queue.queued(),
        }
    }

    /// Stop admitting, cut live sessions at the next step boundary
    /// (their streams end with `FinishReason::Shutdown`), join the
    /// scheduler.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.running.store(false, Ordering::SeqCst);
        self.queue.shutdown();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for GenerationServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn scheduler_loop(
    backend: GenBackend,
    cfg: GenerationConfig,
    queue: Arc<DecodeQueue>,
    metrics: Arc<Registry>,
    pool: Option<KvPool>,
) {
    let sm = backend.session_model();
    let n_ctx = backend.gpt().cfg.n_ctx;
    let mut live: Vec<Live> = Vec::new();
    // one draft model per kind, built lazily at first admission and
    // shared by every speculative session that asks for that kind
    let mut drafts: Vec<(DraftKind, DraftModel)> = Vec::new();
    // paged mode: the shared prefix cache, plus the last-harvested
    // (hits, misses) pair so counter deltas land in the registry
    let mut prefix = pool
        .as_ref()
        .map(|p| PrefixCache::new(p.clone(), cfg.prefix_cache_entries.max(1)));
    let mut pc_seen = (0u64, 0u64);
    let mut draining = false;
    loop {
        // ---- admission: prefill new sessions between decode steps
        while !draining && live.len() < cfg.max_live {
            match queue.pop(live.is_empty()) {
                DecodePop::Req(p) => {
                    // the in-flight slot is held from pop to retirement;
                    // admit() parks it in the Live (or drops it with the
                    // request on any admission-failure path)
                    let permit = TenantPermit::new(queue.clone(), p.req.tenant.clone());
                    admit(
                        &backend,
                        &cfg,
                        &metrics,
                        p,
                        permit,
                        &mut live,
                        &mut drafts,
                        pool.as_ref(),
                        &mut prefix,
                    )
                }
                DecodePop::Empty => break,
                DecodePop::Shutdown => draining = true,
            }
        }
        if let Some(pc) = &prefix {
            let (h, m) = (pc.hits(), pc.misses());
            metrics.counter("prefix_hits").add(h - pc_seen.0);
            metrics.counter("prefix_misses").add(m - pc_seen.1);
            pc_seen = (h, m);
        }
        if draining {
            for p in queue.drain_remaining() {
                metrics.counter("shutdown_cut").inc();
                let _ = p.tx.send(TokenEvent::Done {
                    reason: FinishReason::Shutdown,
                    generated: 0,
                    latency: p.submitted.elapsed(),
                });
            }
            for l in live.drain(..) {
                metrics.counter("shutdown_cut").inc();
                let _ = l.tx.send(TokenEvent::Done {
                    reason: FinishReason::Shutdown,
                    generated: l.produced,
                    latency: l.t0.elapsed(),
                });
            }
            return;
        }
        if live.is_empty() {
            continue; // next admission pop blocks until work or shutdown
        }

        // ---- paged mode: make sure the upcoming tick's page demand
        // fits the pool. Shed cached prefixes first; if that is not
        // enough, evict the NEWEST live sessions (their streams end
        // cleanly with FinishReason::Evicted, pages return on drop)
        // until the demand fits — always keeping at least one session
        // so the server makes progress.
        if let Some(pool) = &pool {
            let tick_demand = |l: &Live| match &l.kind {
                LiveKind::Plain(s) => s.page_demand(n_ctx, 1),
                LiveKind::Spec { spec, .. } => {
                    // one round extends the target by k+1 (verify) and
                    // the draft by up to k+1 (catch-up + k-1 proposals)
                    spec.target_state().page_demand(n_ctx, spec.k + 1)
                        + spec.draft_state().page_demand(n_ctx, spec.k + 1)
                }
            };
            loop {
                let demand: usize = live.iter().map(tick_demand).sum();
                if demand <= pool.free_pages() {
                    break;
                }
                if let Some(pc) = &mut prefix {
                    pc.shed(demand);
                    if demand <= pool.free_pages() {
                        break;
                    }
                }
                if live.len() <= 1 {
                    break; // the survivor's own failure surfaces per-stream
                }
                let l = live.pop().expect("live checked non-empty");
                metrics.counter("evicted").inc();
                let _ = l.tx.send(TokenEvent::Done {
                    reason: FinishReason::Evicted,
                    generated: l.produced,
                    latency: l.t0.elapsed(),
                });
                // dropping `l` drops its session state, returning pages
            }
            pool.note_shared(live.iter().map(shared_pages_of).sum());
        }

        // ---- one tick: coalesce the plain sessions into one skinny
        // batched step; speculative sessions each run one round below
        let mut plain_logits: Option<MatF32> = None;
        let mut plain_err: Option<String> = None;
        {
            let mut tokens: Vec<u32> = Vec::new();
            let mut refs: Vec<&mut SessionState> = Vec::new();
            for l in live.iter_mut() {
                if let LiveKind::Plain(s) = &mut l.kind {
                    tokens.push(l.next);
                    refs.push(s);
                }
            }
            if !refs.is_empty() {
                metrics.counter("decode_batches").inc();
                metrics.counter("decode_rows").add(refs.len() as u64);
                match decode_step_batch(sm, &mut refs, &tokens) {
                    Ok(l) => plain_logits = Some(l),
                    Err(e) => {
                        // a failed step poisons every coalesced session equally
                        metrics.counter("decode_errors").inc();
                        plain_err = Some(format!("{e:#}"));
                    }
                }
            }
        }
        let mut keep = Vec::with_capacity(live.len());
        let mut row = 0; // this session's row in the coalesced logits
        for mut l in live.drain(..) {
            let emitted: Vec<u32> = match &mut l.kind {
                LiveKind::Plain(s) => {
                    let gi = row;
                    row += 1;
                    if let Some(e) = &plain_err {
                        let _ =
                            l.tx.send(TokenEvent::Error(format!("decode step failed: {e}")));
                        continue;
                    }
                    let logits = plain_logits.as_ref().expect("step ran").row(gi);
                    vec![l.sampler.sample_in_context(logits, s.window())]
                }
                LiveKind::Spec { spec, draft_idx, draft_sampler } => {
                    let dm = &drafts[*draft_idx].1;
                    let k = spec.k;
                    match spec.round(sm, dm.session_model(), l.next, &mut l.sampler, draft_sampler)
                    {
                        Ok(toks) => {
                            metrics.counter("spec_rounds").inc();
                            metrics.counter("spec_drafted").add(k as u64);
                            metrics.counter("spec_accepted").add(toks.len() as u64 - 1);
                            toks
                        }
                        Err(e) => {
                            metrics.counter("decode_errors").inc();
                            let _ = l.tx
                                .send(TokenEvent::Error(format!("spec round failed: {e:#}")));
                            continue;
                        }
                    }
                }
            };
            // harvest wrap re-prefills performed inside this tick
            let p = l.prefill_count();
            if p > l.prefills_seen {
                metrics.counter("prefills").add(p - l.prefills_seen);
                l.prefills_seen = p;
            }
            let mut retired = false;
            for next in emitted {
                l.produced += 1;
                metrics.counter("tokens_generated").inc();
                if !l.tenant.is_empty() {
                    metrics.counter(&format!("tokens_tenant_{}", l.tenant)).inc();
                }
                if l.tx.send(TokenEvent::Token { index: l.produced - 1, token: next }).is_err() {
                    // client dropped the handle (closed socket / abandoned
                    // stream): cancel the session NOW — its KV pages free
                    // on drop instead of decoding to budget. The terminal
                    // event is best-effort (the receiver is gone); the
                    // `cancelled` counter is the observable record.
                    metrics.counter("cancelled").inc();
                    let _ = l.tx.send(TokenEvent::Done {
                        reason: FinishReason::Cancelled,
                        generated: l.produced,
                        latency: l.t0.elapsed(),
                    });
                    retired = true;
                    break;
                }
                if l.produced >= l.budget {
                    metrics.counter("completed").inc();
                    let _ = l.tx.send(TokenEvent::Done {
                        reason: FinishReason::MaxTokens,
                        generated: l.produced,
                        latency: l.t0.elapsed(),
                    });
                    retired = true;
                    break;
                }
                l.next = next;
            }
            if !retired {
                keep.push(l);
            }
        }
        live = keep;
    }
}

/// True when the pool can cover `demand` fresh pages, shedding cached
/// prefixes first if it cannot (their pages are reclaimable cache, not
/// live state).
fn pool_fits(pool: &KvPool, prefix: &mut Option<PrefixCache>, demand: usize) -> bool {
    if demand <= pool.free_pages() {
        return true;
    }
    if let Some(pc) = prefix {
        pc.shed(demand);
    }
    demand <= pool.free_pages()
}

#[allow(clippy::too_many_arguments)]
fn admit(
    backend: &GenBackend,
    cfg: &GenerationConfig,
    metrics: &Registry,
    p: PendingGen,
    permit: TenantPermit,
    live: &mut Vec<Live>,
    drafts: &mut Vec<(DraftKind, DraftModel)>,
    pool: Option<&KvPool>,
    prefix: &mut Option<PrefixCache>,
) {
    let sm = backend.session_model();
    let gcfg = &sm.gpt().cfg;
    let asked = if p.req.max_new_tokens == 0 {
        cfg.max_new_tokens
    } else {
        p.req.max_new_tokens.min(cfg.max_new_tokens)
    };
    let budget = asked.max(1);
    if p.req.prompt.len() > gcfg.n_ctx {
        metrics.counter("prompts_truncated").inc();
    }
    // bad prompt / bad spec config: fail just this stream
    fn admit_err(
        metrics: &Registry,
        tx: &mpsc::Sender<TokenEvent>,
        e: anyhow::Error,
        what: &str,
    ) {
        metrics.counter("admit_errors").inc();
        let _ = tx.send(TokenEvent::Error(format!("{what} failed: {e:#}")));
    }
    // rows the prefill will store per layer (the truncated prompt)
    let used_rows = p.req.prompt.len().min(gcfg.n_ctx);
    let page_rows = pool.map(|pl| pl.page_rows()).unwrap_or(1);
    let pages_per_layer = used_rows.div_ceil(page_rows);
    let mut sampler = p.req.sampler();

    // ---- build the session (plain, or speculative over a shared draft)
    let (kind, logits) = if let Some(sc) = p.req.speculative {
        let draft_idx = match drafts.iter().position(|(dk, _)| *dk == sc.draft) {
            Some(i) => i,
            None => match DraftModel::build(backend.gpt(), sc.draft) {
                Ok(d) => {
                    drafts.push((sc.draft, d));
                    drafts.len() - 1
                }
                Err(e) => return admit_err(metrics, &p.tx, e, "draft build"),
            },
        };
        let dm = &drafts[draft_idx].1;
        let mut spec = match pool {
            Some(pl) => {
                // price the two prefills before building: target + draft
                // both store the full prompt, and spec prefill is never
                // prefix-seeded (draft K/V are model-specific, so the
                // target's shared pages don't apply)
                let demand = (gcfg.n_layer + dm.cfg().n_layer) * pages_per_layer;
                if !pool_fits(pl, prefix, demand) {
                    metrics.counter("pool_refusals").inc();
                    return admit_err(
                        metrics,
                        &p.tx,
                        anyhow!(
                            "kv pool exhausted (need {demand} pages, {} free)",
                            pl.free_pages()
                        ),
                        "pool admission",
                    );
                }
                match SpeculativeState::new_paged(gcfg, dm.cfg(), sc.k, cfg.wrap, pl) {
                    Ok(s) => s,
                    Err(e) => return admit_err(metrics, &p.tx, e, "speculative admit"),
                }
            }
            None => match SpeculativeState::new(gcfg, dm.cfg(), sc.k, cfg.wrap) {
                Ok(s) => s,
                Err(e) => return admit_err(metrics, &p.tx, e, "speculative admit"),
            },
        };
        match spec.prefill(sm, dm.session_model(), &p.req.prompt) {
            Ok(logits) => {
                metrics.counter("prefills").add(2); // target + draft
                let draft_sampler = sampler.fork(DRAFT_SEED_SALT);
                (LiveKind::Spec { spec, draft_idx, draft_sampler }, logits)
            }
            Err(e) => return admit_err(metrics, &p.tx, e, "prefill"),
        }
    } else {
        let mut state = match pool {
            Some(pl) => {
                // shared prefix pages are free (Arc clones); only the
                // uncached tail demands fresh pages
                let cached = prefix
                    .as_ref()
                    .map(|pc| pc.probe_rows(&p.req.prompt[p.req.prompt.len() - used_rows..]))
                    .unwrap_or(0);
                let demand = gcfg.n_layer * (pages_per_layer - cached / page_rows);
                if !pool_fits(pl, prefix, demand) {
                    metrics.counter("pool_refusals").inc();
                    return admit_err(
                        metrics,
                        &p.tx,
                        anyhow!(
                            "kv pool exhausted (need {demand} pages, {} free)",
                            pl.free_pages()
                        ),
                        "pool admission",
                    );
                }
                SessionState::new_paged(gcfg, cfg.wrap, pl)
            }
            None => SessionState::new(gcfg, cfg.wrap),
        };
        let filled = match prefix.as_mut() {
            Some(pc) => state.prefill_cached(sm, &p.req.prompt, pc),
            None => state.prefill(sm, &p.req.prompt),
        };
        match filled {
            Ok(logits) => {
                metrics.counter("prefills").inc();
                (LiveKind::Plain(state), logits)
            }
            Err(e) => return admit_err(metrics, &p.tx, e, "prefill"),
        }
    };

    let window = match &kind {
        LiveKind::Plain(s) => s.window(),
        LiveKind::Spec { spec, .. } => spec.target_state().window(),
    };
    let first = sampler.sample_in_context(&logits, window);
    metrics.counter("tokens_generated").inc();
    if !p.req.tenant.is_empty() {
        metrics.counter(&format!("tokens_tenant_{}", p.req.tenant)).inc();
    }
    if p.tx.send(TokenEvent::Token { index: 0, token: first }).is_err() {
        // abandoned before its first token — retire immediately (the
        // permit drops with this frame, freeing the tenant's slot)
        metrics.counter("cancelled").inc();
        let _ = p.tx.send(TokenEvent::Done {
            reason: FinishReason::Cancelled,
            generated: 1,
            latency: p.submitted.elapsed(),
        });
        return;
    }
    if budget == 1 {
        metrics.counter("completed").inc();
        let _ = p.tx.send(TokenEvent::Done {
            reason: FinishReason::MaxTokens,
            generated: 1,
            latency: p.submitted.elapsed(),
        });
        return;
    }
    let l = Live {
        prefills_seen: 0,
        kind,
        sampler,
        next: first,
        produced: 1,
        budget,
        tenant: p.req.tenant.clone(),
        _permit: permit,
        tx: p.tx,
        t0: p.submitted,
    };
    live.push(Live { prefills_seen: l.prefill_count(), ..l });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpt2::{Sampler, WrapPolicy};
    use crate::quant::EngineSpec;

    fn tiny() -> Gpt2Model {
        Gpt2Model::test_model(2, 16, 2, 12, 32, 7)
    }

    fn toks(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = crate::data::prng::SplitMix64::new(seed);
        (0..n).map(|_| rng.next_below(32) as u32).collect()
    }

    fn req(prompt: Vec<u32>, n: usize) -> GenerateRequest {
        GenerateRequest::greedy(prompt, n)
    }

    #[test]
    fn served_tokens_bit_exact_vs_solo_session() {
        // the server interleaves prefill admissions with batched decode;
        // every stream must still equal a solo greedy session
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let prompts = [toks(3, 1), toks(6, 2), toks(4, 3)];
        let mut want = Vec::new();
        for p in &prompts {
            let mut s = q.session(WrapPolicy::default());
            want.push(s.generate_greedy(p, 6).unwrap());
        }
        let srv = GenerationServer::start(
            GenBackend::Int(QuantizedGpt2::new(tiny(), EngineSpec::muxq())),
            GenerationConfig { max_live: 2, ..Default::default() }, // forces interleaving
        );
        let handles: Vec<_> =
            prompts.iter().map(|p| srv.submit(req(p.clone(), 6)).unwrap()).collect();
        for (h, w) in handles.into_iter().zip(&want) {
            assert_eq!(&h.collect_tokens().unwrap(), w);
        }
        let st = srv.stats();
        assert_eq!(st.completed, 3);
        assert_eq!(st.tokens_generated, 18);
        assert!(st.decode_batches > 0 && st.batch_fill() >= 1.0);
        srv.shutdown();
    }

    #[test]
    fn llmint8_model_serves_tokens_end_to_end() {
        // the redesign's payoff: a method the deployed pipeline could
        // never run before generates tokens through the full serving
        // stack — and matches its own solo session exactly
        let q = QuantizedGpt2::new(tiny(), EngineSpec::llmint8());
        let prompts = [toks(4, 31), toks(6, 32)];
        let mut want = Vec::new();
        for p in &prompts {
            let mut s = q.session(WrapPolicy::default());
            want.push(s.generate_greedy(p, 5).unwrap());
        }
        let srv = GenerationServer::start(
            GenBackend::Int(QuantizedGpt2::new(tiny(), EngineSpec::llmint8())),
            GenerationConfig::default(),
        );
        let handles: Vec<_> =
            prompts.iter().map(|p| srv.submit(req(p.clone(), 5)).unwrap()).collect();
        for (h, w) in handles.into_iter().zip(&want) {
            assert_eq!(&h.collect_tokens().unwrap(), w);
        }
        assert_eq!(srv.stats().completed, 2);
        srv.shutdown();
    }

    #[test]
    fn w4_and_resq_models_serve_tokens_end_to_end() {
        // the nibble-packed engine needs ZERO serving changes: every W4
        // operator family — pre-transformed variants included — generates
        // through the full stack and matches its own solo greedy session
        // exactly (muxq-w4a8-rot and the permuted naive variant are the
        // issue's acceptance specs)
        for spec in [
            EngineSpec::naive().with_bits(8, 4),
            EngineSpec::muxq().with_bits(8, 4),
            EngineSpec::resq(),
            EngineSpec::muxq().with_bits(8, 4).with_rotate(),
            EngineSpec::naive().with_bits(8, 4).with_rotate().with_permute(),
            EngineSpec::resq().with_smooth(0.5).with_resid_rank(2),
        ] {
            let q = QuantizedGpt2::new(tiny(), spec.clone());
            let prompts = [toks(4, 41), toks(6, 42)];
            let mut want = Vec::new();
            for p in &prompts {
                let mut s = q.session(WrapPolicy::default());
                want.push(s.generate_greedy(p, 5).unwrap());
            }
            let srv = GenerationServer::start(
                GenBackend::Int(QuantizedGpt2::new(tiny(), spec.clone())),
                GenerationConfig::default(),
            );
            let handles: Vec<_> =
                prompts.iter().map(|p| srv.submit(req(p.clone(), 5)).unwrap()).collect();
            for (h, w) in handles.into_iter().zip(&want) {
                assert_eq!(&h.collect_tokens().unwrap(), w, "{}", spec.tag());
            }
            assert_eq!(srv.stats().completed, 2, "{}", spec.tag());
            srv.shutdown();
        }
    }

    #[test]
    fn w4_draft_speculative_stream_matches_plain_greedy() {
        // the W4 deployment is the natural cheap draft: same
        // architecture, half the draft's weight traffic — and greedy
        // acceptance keeps the served stream lossless
        use crate::gpt2::DraftKind;
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let prompt = toks(3, 51);
        let mut s = q.session(WrapPolicy::default());
        let want = s.generate_greedy(&prompt, 6).unwrap();
        let srv = GenerationServer::start(
            GenBackend::Int(QuantizedGpt2::new(tiny(), EngineSpec::muxq())),
            GenerationConfig::default(),
        );
        let h = srv
            .submit(req(prompt, 6).with_speculative(2, DraftKind::NaiveInt4))
            .unwrap();
        assert_eq!(h.collect_tokens().unwrap(), want);
        let st = srv.stats();
        assert!(st.spec_rounds > 0, "W4 draft ran speculative rounds");
        srv.shutdown();
    }

    #[test]
    fn sampled_streams_are_seed_reproducible() {
        // temperature/top-k through the server: same seed -> identical
        // stream (across separate servers), equal to a solo sampled
        // session; different seed -> (here) a different stream
        let prompt = toks(5, 41);
        let solo = {
            let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
            let mut s = q.session(WrapPolicy::default());
            s.generate(&prompt, 8, &mut Sampler::new(1.2, 8, 99)).unwrap()
        };
        let served = |seed: u64| {
            let srv = GenerationServer::start(
                GenBackend::Int(QuantizedGpt2::new(tiny(), EngineSpec::muxq())),
                GenerationConfig::default(),
            );
            let out = srv
                .submit(GenerateRequest::sampled(prompt.clone(), 8, 1.2, 8, seed))
                .unwrap()
                .collect_tokens()
                .unwrap();
            srv.shutdown();
            out
        };
        assert_eq!(served(99), solo, "served sampling == solo session sampling");
        assert_eq!(served(99), served(99), "same seed replays");
        assert_ne!(served(99), served(100), "seed changes the stream");
    }

    #[test]
    fn streams_are_ordered_and_terminated() {
        let srv = GenerationServer::start(
            GenBackend::Fp(tiny()),
            GenerationConfig { max_new_tokens: 4, ..Default::default() },
        );
        let h = srv.submit(req(toks(5, 9), 0)).unwrap(); // 0 = server default
        let mut idx = 0;
        let mut done = false;
        while let Some(ev) = h.recv() {
            match ev {
                TokenEvent::Token { index, .. } => {
                    assert_eq!(index, idx);
                    idx += 1;
                }
                TokenEvent::Done { reason, generated, .. } => {
                    assert_eq!(reason, FinishReason::MaxTokens);
                    assert_eq!(generated, 4);
                    done = true;
                }
                TokenEvent::Error(e) => panic!("unexpected error: {e}"),
            }
        }
        assert!(done && idx == 4);
        srv.shutdown();
    }

    #[test]
    fn bad_prompt_fails_only_its_stream() {
        let srv = GenerationServer::start(GenBackend::Fp(tiny()), GenerationConfig::default());
        assert!(srv.submit(req(vec![], 4)).is_err(), "empty prompt rejected at submit");
        let bad = srv.submit(req(vec![999], 4)).unwrap(); // out of vocab
        let good = srv.submit(req(toks(4, 4), 3)).unwrap();
        assert!(bad.collect_tokens().is_err());
        assert_eq!(good.collect_tokens().unwrap().len(), 3);
        let st = srv.stats();
        assert_eq!(st.submitted, 3);
        srv.shutdown();
    }

    #[test]
    fn long_prompts_truncate_and_generation_survives_wrap() {
        let srv = GenerationServer::start(GenBackend::Fp(tiny()), GenerationConfig::default());
        // prompt longer than n_ctx=12, budget far past the window
        let h = srv.submit(req(toks(40, 5), 30)).unwrap();
        assert_eq!(h.collect_tokens().unwrap().len(), 30);
        let st = srv.stats();
        assert_eq!(st.prompts_truncated, 1);
        assert!(st.prefills > 1, "wrap re-prefills counted");
        srv.shutdown();
    }

    #[test]
    fn shutdown_cuts_live_sessions_with_reason() {
        let srv = GenerationServer::start(
            GenBackend::Fp(tiny()),
            GenerationConfig { max_new_tokens: 100_000, ..Default::default() },
        );
        let h = srv.submit(req(toks(4, 6), 0)).unwrap();
        // let it produce a few tokens, then pull the plug
        let first = h.recv();
        assert!(matches!(first, Some(TokenEvent::Token { index: 0, .. })));
        srv.shutdown();
        let mut saw_shutdown = false;
        while let Some(ev) = h.recv() {
            if let TokenEvent::Done { reason, .. } = ev {
                assert_eq!(reason, FinishReason::Shutdown);
                saw_shutdown = true;
            }
        }
        assert!(saw_shutdown);
    }

    #[test]
    fn speculative_streams_match_plain_greedy_served() {
        // mixed batch: spec sessions (both draft kinds) and a plain
        // session interleave on one server; every greedy spec stream
        // must equal the plain greedy stream for the same prompt
        // (budgets sized so neither schedule wraps: prompt+budget+k <= n_ctx)
        use crate::gpt2::DraftKind;
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let prompts = [toks(3, 11), toks(3, 12), toks(4, 13)];
        let mut want = Vec::new();
        for p in &prompts {
            let mut s = q.session(WrapPolicy::default());
            want.push(s.generate_greedy(p, 6).unwrap());
        }
        let srv = GenerationServer::start(
            GenBackend::Int(QuantizedGpt2::new(tiny(), EngineSpec::muxq())),
            GenerationConfig::default(),
        );
        let reqs = [
            req(prompts[0].clone(), 6).with_speculative(2, DraftKind::NaiveInt8),
            req(prompts[1].clone(), 6).with_speculative(2, DraftKind::TruncateLayers(1)),
            req(prompts[2].clone(), 6), // plain, coalesced alongside
        ];
        let handles: Vec<_> = reqs.iter().map(|r| srv.submit(r.clone()).unwrap()).collect();
        for (h, w) in handles.into_iter().zip(&want) {
            assert_eq!(&h.collect_tokens().unwrap(), w);
        }
        let st = srv.stats();
        assert_eq!(st.completed, 3);
        assert!(st.spec_rounds > 0, "spec sessions ran rounds");
        assert_eq!(st.spec_drafted, 2 * st.spec_rounds, "k=2 drafts per round");
        assert!(st.spec_accept_rate() >= 0.0 && st.spec_accept_rate() <= 1.0);
        assert!(st.spec_tokens_per_round() >= 1.0, "every round emits >= 1 token");
        assert!(st.decode_batches > 0, "the plain session still coalesces");
        srv.shutdown();
    }

    #[test]
    fn speculative_survives_wrap_and_reports_rates() {
        // budget far past n_ctx=12: reprefill rollback inside rounds
        use crate::gpt2::DraftKind;
        let srv = GenerationServer::start(
            GenBackend::Fp(tiny()),
            GenerationConfig { max_new_tokens: 64, ..Default::default() },
        );
        let h = srv
            .submit(req(toks(5, 21), 30).with_speculative(3, DraftKind::TruncateLayers(1)))
            .unwrap();
        assert_eq!(h.collect_tokens().unwrap().len(), 30);
        let st = srv.stats();
        assert!(st.prefills > 2, "admission (x2) plus wrap re-prefills");
        assert!(st.spec_rounds > 0);
        srv.shutdown();
    }

    #[test]
    fn speculative_misconfig_fails_only_its_stream() {
        // Slide wrap can't host rollback; a trunc depth past n_layer
        // can't build a draft — both fail at admission, leaving the
        // plain session untouched
        use crate::gpt2::DraftKind;
        let srv = GenerationServer::start(
            GenBackend::Fp(tiny()),
            GenerationConfig { wrap: WrapPolicy::Slide, ..Default::default() },
        );
        let bad = srv
            .submit(req(toks(4, 22), 4).with_speculative(2, DraftKind::NaiveInt8))
            .unwrap();
        let good = srv.submit(req(toks(4, 23), 3)).unwrap();
        assert!(bad.collect_tokens().is_err(), "spec under Slide is an admit error");
        assert_eq!(good.collect_tokens().unwrap().len(), 3);
        assert_eq!(srv.stats().admit_errors, 1);
        srv.shutdown();

        let srv = GenerationServer::start(GenBackend::Fp(tiny()), GenerationConfig::default());
        let bad = srv
            .submit(req(toks(4, 24), 4).with_speculative(2, DraftKind::TruncateLayers(9)))
            .unwrap();
        assert!(bad.collect_tokens().is_err(), "undeep draft fails to build");
        assert_eq!(srv.stats().admit_errors, 1);
        srv.shutdown();
    }

    #[test]
    fn served_top_p_and_repetition_penalty_match_solo() {
        // the new sampler knobs thread end to end: served stream ==
        // solo session with the same sampler settings
        let prompt = toks(5, 51);
        let solo = {
            let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
            let mut s = q.session(WrapPolicy::default());
            let mut smp =
                Sampler::new(1.1, 0, 77).with_top_p(0.9).with_repetition_penalty(1.25);
            s.generate(&prompt, 8, &mut smp).unwrap()
        };
        let srv = GenerationServer::start(
            GenBackend::Int(QuantizedGpt2::new(tiny(), EngineSpec::muxq())),
            GenerationConfig::default(),
        );
        let served = srv
            .submit(
                GenerateRequest::sampled(prompt.clone(), 8, 1.1, 0, 77)
                    .with_top_p(0.9)
                    .with_repetition_penalty(1.25),
            )
            .unwrap()
            .collect_tokens()
            .unwrap();
        assert_eq!(served, solo);
        srv.shutdown();
    }

    #[test]
    fn paged_server_streams_match_ring_serving() {
        // pool-backed serving is a storage change, not a results change:
        // every stream equals the solo ring session, and the pool stats
        // surface occupancy + prefix sharing
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let prompts = [toks(4, 61), toks(4, 61), toks(5, 62)]; // two share a prompt
        let mut want = Vec::new();
        for p in &prompts {
            let mut s = q.session(WrapPolicy::default());
            want.push(s.generate_greedy(p, 6).unwrap());
        }
        let srv = GenerationServer::start(
            GenBackend::Int(QuantizedGpt2::new(tiny(), EngineSpec::muxq())),
            GenerationConfig { pool_pages: 64, page_rows: 2, ..Default::default() },
        );
        let handles: Vec<_> =
            prompts.iter().map(|p| srv.submit(req(p.clone(), 6)).unwrap()).collect();
        for (h, w) in handles.into_iter().zip(&want) {
            assert_eq!(&h.collect_tokens().unwrap(), w);
        }
        let st = srv.stats();
        assert_eq!(st.completed, 3);
        assert_eq!(st.pool_pages, 64);
        assert_eq!(st.pool_pages_in_use + st.pool_pages_free, 64);
        assert_eq!(st.evicted, 0, "a 64-page pool never pressures 3 tiny sessions");
        assert_eq!(st.pool_refusals, 0);
        assert!(st.paged_fill() >= 0.0 && st.paged_fill() <= 1.0);
        srv.shutdown();
    }

    #[test]
    fn submit_after_shutdown_rejected() {
        let srv = GenerationServer::start(GenBackend::Fp(tiny()), GenerationConfig::default());
        let queue = srv.queue.clone();
        srv.shutdown();
        let (tx, _rx) = mpsc::channel();
        let p = PendingGen {
            req: req(vec![1], 1),
            submitted: Instant::now(),
            tx,
        };
        assert!(matches!(queue.push(p), Err(AdmitError::Shutdown)));
    }
}
