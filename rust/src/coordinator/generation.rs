//! Token-level generation serving: continuous batching over the native
//! incremental-decode engine (`gpt2::session`).
//!
//! ```text
//! client ──submit──> GenerationServer (admission, backpressure)
//!    ──> DecodeQueue ──> decode scheduler (one thread, owns the model):
//!          loop {
//!            admit new sessions while slots free (PREFILL, between steps)
//!            decode_step_batch over ALL live sessions   <- ONE skinny GEMM
//!            per session: sample (greedy/temperature/top-k) -> stream
//!            TokenEvent, retire at budget
//!          }
//! ```
//!
//! This is the latency-bound regime the paper's uniform-INT argument
//! targets: per-step projections are M=G skinny GEMMs (M=1..4 routes to
//! the packed engine's GEMV path) and memory-bound — see
//! `npusim::decode_cost`. Because the session projection is
//! row-independent (`gpt2::quantized`), coalescing G sessions into one
//! GEMM returns per-session logits bit-identical to stepping each alone:
//! continuous batching changes throughput, never results.
//!
//! Contrast with the scoring plane (`scheduler`): scoring coalesces
//! one-shot fixed-shape requests and runs them on compiled PJRT
//! variants; generation holds stateful sessions over the native packed
//! INT engine and interleaves prefill admission with decode steps.

use super::batcher::{AdmitError, DecodePop, DecodeQueue};
use super::request::{FinishReason, GenerateHandle, GenerateRequest, PendingGen, TokenEvent};
use crate::gpt2::session::{decode_step_batch, Sampler, SessionModel, SessionState, WrapPolicy};
use crate::gpt2::{Gpt2Model, QuantizedGpt2};
use crate::util::metrics::Registry;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

/// The model a generation server decodes with (owned; the scheduler
/// thread is the only toucher, sessions borrow it there).
pub enum GenBackend {
    Fp(Gpt2Model),
    Int(QuantizedGpt2),
}

impl GenBackend {
    fn session_model(&self) -> SessionModel<'_> {
        match self {
            GenBackend::Fp(m) => SessionModel::Fp(m),
            GenBackend::Int(q) => SessionModel::Int(q),
        }
    }

    pub fn gpt(&self) -> &Gpt2Model {
        match self {
            GenBackend::Fp(m) => m,
            GenBackend::Int(q) => &q.fp,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GenerationConfig {
    /// live-session cap == the decode batch width ceiling
    pub max_live: usize,
    /// admission backpressure: max requests waiting for a slot
    pub max_queue: usize,
    /// server-side ceiling on tokens per request (requests asking for 0
    /// get exactly this)
    pub max_new_tokens: usize,
    /// context-overflow policy for every session
    pub wrap: WrapPolicy,
}

impl Default for GenerationConfig {
    fn default() -> Self {
        GenerationConfig {
            max_live: 8,
            max_queue: 256,
            max_new_tokens: 128,
            wrap: WrapPolicy::Reprefill { keep: 0 },
        }
    }
}

/// Point-in-time statistics snapshot.
#[derive(Debug, Clone)]
pub struct GenerationStats {
    pub submitted: u64,
    pub rejected: u64,
    /// requests that reached their token budget
    pub completed: u64,
    /// requests whose client dropped the handle mid-stream (observable
    /// only here — the dropped receiver can't be sent a terminal event)
    pub cancelled: u64,
    /// requests cut by shutdown (queued or live)
    pub shutdown_cut: u64,
    /// prefills that failed admission (bad prompt) — their streams ended
    /// with `TokenEvent::Error`
    pub admit_errors: u64,
    /// coalesced decode steps that failed (poisoning their sessions)
    pub decode_errors: u64,
    pub tokens_generated: u64,
    pub decode_batches: u64,
    /// session-rows across all decode batches (fill = rows / batches)
    pub decode_rows: u64,
    /// prefill passes (admissions + wrap re-prefills)
    pub prefills: u64,
    /// prompts longer than n_ctx, truncated at admission
    pub prompts_truncated: u64,
    pub queued_now: usize,
}

impl GenerationStats {
    /// Mean live sessions per decode step — how full the continuous
    /// batch ran.
    pub fn batch_fill(&self) -> f64 {
        if self.decode_batches == 0 {
            return 0.0;
        }
        self.decode_rows as f64 / self.decode_batches as f64
    }
}

/// One live session inside the scheduler.
struct Live {
    state: SessionState,
    /// this request's token selector (greedy or seeded sampling) —
    /// per-session state, so coalescing never couples streams
    sampler: Sampler,
    /// last emitted token == the next decode input
    next: u32,
    produced: usize,
    budget: usize,
    /// session prefill passes already reflected in the metrics registry
    /// (wrap re-prefills happen inside decode steps; the delta is
    /// harvested after each step)
    prefills_seen: u64,
    tx: mpsc::Sender<TokenEvent>,
    t0: Instant,
}

/// The generation server: spawn with [`GenerationServer::start`], feed
/// it [`GenerateRequest`]s, read streamed tokens off the returned
/// [`GenerateHandle`]s. One server per deployed model/method (the
/// scoring coordinator's multi-variant registry is the other plane).
pub struct GenerationServer {
    queue: Arc<DecodeQueue>,
    metrics: Arc<Registry>,
    running: Arc<AtomicBool>,
    worker: Option<JoinHandle<()>>,
}

impl GenerationServer {
    pub fn start(backend: GenBackend, cfg: GenerationConfig) -> GenerationServer {
        // a zero-width batch could never admit, so the scheduler would
        // never reach the queue (or see its shutdown) — clamp like
        // max_queue below
        let cfg = GenerationConfig { max_live: cfg.max_live.max(1), ..cfg };
        let queue = Arc::new(DecodeQueue::new(cfg.max_queue.max(1)));
        let metrics = Arc::new(Registry::default());
        let running = Arc::new(AtomicBool::new(true));
        let worker = {
            let queue = queue.clone();
            let metrics = metrics.clone();
            std::thread::Builder::new()
                .name("muxq-decode".into())
                .spawn(move || scheduler_loop(backend, cfg, queue, metrics))
                .expect("spawn decode scheduler")
        };
        GenerationServer { queue, metrics, running, worker: Some(worker) }
    }

    /// Submit a generation request; returns the token stream handle.
    pub fn submit(&self, req: GenerateRequest) -> Result<GenerateHandle> {
        self.metrics.counter("submitted").inc();
        if !self.running.load(Ordering::SeqCst) {
            self.metrics.counter("rejected").inc();
            return Err(anyhow!("generation server is shut down"));
        }
        if req.prompt.is_empty() {
            self.metrics.counter("rejected").inc();
            return Err(anyhow!("empty prompt"));
        }
        let (tx, rx) = mpsc::channel();
        match self.queue.push(PendingGen { req, submitted: Instant::now(), tx }) {
            Ok(()) => Ok(GenerateHandle { rx }),
            Err(AdmitError::QueueFull) => {
                self.metrics.counter("rejected").inc();
                Err(anyhow!("generation queue full (backpressure)"))
            }
            Err(AdmitError::Shutdown) => {
                self.metrics.counter("rejected").inc();
                Err(anyhow!("generation server is shut down"))
            }
        }
    }

    /// Convenience: submit + drain the stream.
    pub fn generate(&self, req: GenerateRequest) -> Result<Vec<u32>> {
        self.submit(req)?.collect_tokens()
    }

    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    pub fn stats(&self) -> GenerationStats {
        let c = |name: &str| self.metrics.counter(name).get();
        GenerationStats {
            submitted: c("submitted"),
            rejected: c("rejected"),
            completed: c("completed"),
            cancelled: c("cancelled"),
            shutdown_cut: c("shutdown_cut"),
            admit_errors: c("admit_errors"),
            decode_errors: c("decode_errors"),
            tokens_generated: c("tokens_generated"),
            decode_batches: c("decode_batches"),
            decode_rows: c("decode_rows"),
            prefills: c("prefills"),
            prompts_truncated: c("prompts_truncated"),
            queued_now: self.queue.queued(),
        }
    }

    /// Stop admitting, cut live sessions at the next step boundary
    /// (their streams end with `FinishReason::Shutdown`), join the
    /// scheduler.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.running.store(false, Ordering::SeqCst);
        self.queue.shutdown();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for GenerationServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn scheduler_loop(
    backend: GenBackend,
    cfg: GenerationConfig,
    queue: Arc<DecodeQueue>,
    metrics: Arc<Registry>,
) {
    let sm = backend.session_model();
    let mut live: Vec<Live> = Vec::new();
    let mut draining = false;
    loop {
        // ---- admission: prefill new sessions between decode steps
        while !draining && live.len() < cfg.max_live {
            match queue.pop(live.is_empty()) {
                DecodePop::Req(p) => admit(sm, &cfg, &metrics, p, &mut live),
                DecodePop::Empty => break,
                DecodePop::Shutdown => draining = true,
            }
        }
        if draining {
            for p in queue.drain_remaining() {
                metrics.counter("shutdown_cut").inc();
                let _ = p.tx.send(TokenEvent::Done {
                    reason: FinishReason::Shutdown,
                    generated: 0,
                    latency: p.submitted.elapsed(),
                });
            }
            for l in live.drain(..) {
                metrics.counter("shutdown_cut").inc();
                let _ = l.tx.send(TokenEvent::Done {
                    reason: FinishReason::Shutdown,
                    generated: l.produced,
                    latency: l.t0.elapsed(),
                });
            }
            return;
        }
        if live.is_empty() {
            continue; // next admission pop blocks until work or shutdown
        }

        // ---- one coalesced decode step over every live session
        let tokens: Vec<u32> = live.iter().map(|l| l.next).collect();
        let step = {
            let mut refs: Vec<&mut SessionState> =
                live.iter_mut().map(|l| &mut l.state).collect();
            decode_step_batch(sm, &mut refs, &tokens)
        };
        match step {
            Ok(logits) => {
                metrics.counter("decode_batches").inc();
                metrics.counter("decode_rows").add(live.len() as u64);
                let mut keep = Vec::with_capacity(live.len());
                for (gi, mut l) in live.drain(..).enumerate() {
                    // harvest wrap re-prefills performed inside this step
                    let p = l.state.prefills();
                    if p > l.prefills_seen {
                        metrics.counter("prefills").add(p - l.prefills_seen);
                        l.prefills_seen = p;
                    }
                    let next = l.sampler.sample(logits.row(gi));
                    l.produced += 1;
                    metrics.counter("tokens_generated").inc();
                    if l.tx.send(TokenEvent::Token { index: l.produced - 1, token: next }).is_err()
                    {
                        // client dropped the handle: cancel the session
                        metrics.counter("cancelled").inc();
                        continue;
                    }
                    if l.produced >= l.budget {
                        metrics.counter("completed").inc();
                        let _ = l.tx.send(TokenEvent::Done {
                            reason: FinishReason::MaxTokens,
                            generated: l.produced,
                            latency: l.t0.elapsed(),
                        });
                        continue;
                    }
                    l.next = next;
                    keep.push(l);
                }
                live = keep;
            }
            Err(e) => {
                // a failed step poisons every coalesced session equally
                metrics.counter("decode_errors").inc();
                for l in live.drain(..) {
                    let _ = l.tx.send(TokenEvent::Error(format!("decode step failed: {e:#}")));
                }
            }
        }
    }
}

fn admit(
    sm: SessionModel<'_>,
    cfg: &GenerationConfig,
    metrics: &Registry,
    p: PendingGen,
    live: &mut Vec<Live>,
) {
    let gcfg = &sm.gpt().cfg;
    let asked = if p.req.max_new_tokens == 0 {
        cfg.max_new_tokens
    } else {
        p.req.max_new_tokens.min(cfg.max_new_tokens)
    };
    let budget = asked.max(1);
    if p.req.prompt.len() > gcfg.n_ctx {
        metrics.counter("prompts_truncated").inc();
    }
    let mut state = SessionState::new(gcfg, cfg.wrap);
    let mut sampler = p.req.sampler();
    match state.prefill(sm, &p.req.prompt) {
        Ok(logits) => {
            metrics.counter("prefills").inc();
            let first = sampler.sample(&logits);
            metrics.counter("tokens_generated").inc();
            if p.tx.send(TokenEvent::Token { index: 0, token: first }).is_err() {
                metrics.counter("cancelled").inc();
                return;
            }
            if budget == 1 {
                metrics.counter("completed").inc();
                let _ = p.tx.send(TokenEvent::Done {
                    reason: FinishReason::MaxTokens,
                    generated: 1,
                    latency: p.submitted.elapsed(),
                });
                return;
            }
            live.push(Live {
                prefills_seen: state.prefills(),
                state,
                sampler,
                next: first,
                produced: 1,
                budget,
                tx: p.tx,
                t0: p.submitted,
            });
        }
        Err(e) => {
            // bad prompt (e.g. out-of-vocab token): fail just this stream
            metrics.counter("admit_errors").inc();
            let _ = p.tx.send(TokenEvent::Error(format!("prefill failed: {e:#}")));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpt2::{Sampler, WrapPolicy};
    use crate::quant::EngineSpec;

    fn tiny() -> Gpt2Model {
        Gpt2Model::test_model(2, 16, 2, 12, 32, 7)
    }

    fn toks(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = crate::data::prng::SplitMix64::new(seed);
        (0..n).map(|_| rng.next_below(32) as u32).collect()
    }

    fn req(prompt: Vec<u32>, n: usize) -> GenerateRequest {
        GenerateRequest::greedy(prompt, n)
    }

    #[test]
    fn served_tokens_bit_exact_vs_solo_session() {
        // the server interleaves prefill admissions with batched decode;
        // every stream must still equal a solo greedy session
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let prompts = [toks(3, 1), toks(6, 2), toks(4, 3)];
        let mut want = Vec::new();
        for p in &prompts {
            let mut s = q.session(WrapPolicy::default());
            want.push(s.generate_greedy(p, 6).unwrap());
        }
        let srv = GenerationServer::start(
            GenBackend::Int(QuantizedGpt2::new(tiny(), EngineSpec::muxq())),
            GenerationConfig { max_live: 2, ..Default::default() }, // forces interleaving
        );
        let handles: Vec<_> =
            prompts.iter().map(|p| srv.submit(req(p.clone(), 6)).unwrap()).collect();
        for (h, w) in handles.into_iter().zip(&want) {
            assert_eq!(&h.collect_tokens().unwrap(), w);
        }
        let st = srv.stats();
        assert_eq!(st.completed, 3);
        assert_eq!(st.tokens_generated, 18);
        assert!(st.decode_batches > 0 && st.batch_fill() >= 1.0);
        srv.shutdown();
    }

    #[test]
    fn llmint8_model_serves_tokens_end_to_end() {
        // the redesign's payoff: a method the deployed pipeline could
        // never run before generates tokens through the full serving
        // stack — and matches its own solo session exactly
        let q = QuantizedGpt2::new(tiny(), EngineSpec::llmint8());
        let prompts = [toks(4, 31), toks(6, 32)];
        let mut want = Vec::new();
        for p in &prompts {
            let mut s = q.session(WrapPolicy::default());
            want.push(s.generate_greedy(p, 5).unwrap());
        }
        let srv = GenerationServer::start(
            GenBackend::Int(QuantizedGpt2::new(tiny(), EngineSpec::llmint8())),
            GenerationConfig::default(),
        );
        let handles: Vec<_> =
            prompts.iter().map(|p| srv.submit(req(p.clone(), 5)).unwrap()).collect();
        for (h, w) in handles.into_iter().zip(&want) {
            assert_eq!(&h.collect_tokens().unwrap(), w);
        }
        assert_eq!(srv.stats().completed, 2);
        srv.shutdown();
    }

    #[test]
    fn sampled_streams_are_seed_reproducible() {
        // temperature/top-k through the server: same seed -> identical
        // stream (across separate servers), equal to a solo sampled
        // session; different seed -> (here) a different stream
        let prompt = toks(5, 41);
        let solo = {
            let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
            let mut s = q.session(WrapPolicy::default());
            s.generate(&prompt, 8, &mut Sampler::new(1.2, 8, 99)).unwrap()
        };
        let served = |seed: u64| {
            let srv = GenerationServer::start(
                GenBackend::Int(QuantizedGpt2::new(tiny(), EngineSpec::muxq())),
                GenerationConfig::default(),
            );
            let out = srv
                .submit(GenerateRequest::sampled(prompt.clone(), 8, 1.2, 8, seed))
                .unwrap()
                .collect_tokens()
                .unwrap();
            srv.shutdown();
            out
        };
        assert_eq!(served(99), solo, "served sampling == solo session sampling");
        assert_eq!(served(99), served(99), "same seed replays");
        assert_ne!(served(99), served(100), "seed changes the stream");
    }

    #[test]
    fn streams_are_ordered_and_terminated() {
        let srv = GenerationServer::start(
            GenBackend::Fp(tiny()),
            GenerationConfig { max_new_tokens: 4, ..Default::default() },
        );
        let h = srv.submit(req(toks(5, 9), 0)).unwrap(); // 0 = server default
        let mut idx = 0;
        let mut done = false;
        while let Some(ev) = h.recv() {
            match ev {
                TokenEvent::Token { index, .. } => {
                    assert_eq!(index, idx);
                    idx += 1;
                }
                TokenEvent::Done { reason, generated, .. } => {
                    assert_eq!(reason, FinishReason::MaxTokens);
                    assert_eq!(generated, 4);
                    done = true;
                }
                TokenEvent::Error(e) => panic!("unexpected error: {e}"),
            }
        }
        assert!(done && idx == 4);
        srv.shutdown();
    }

    #[test]
    fn bad_prompt_fails_only_its_stream() {
        let srv = GenerationServer::start(GenBackend::Fp(tiny()), GenerationConfig::default());
        assert!(srv.submit(req(vec![], 4)).is_err(), "empty prompt rejected at submit");
        let bad = srv.submit(req(vec![999], 4)).unwrap(); // out of vocab
        let good = srv.submit(req(toks(4, 4), 3)).unwrap();
        assert!(bad.collect_tokens().is_err());
        assert_eq!(good.collect_tokens().unwrap().len(), 3);
        let st = srv.stats();
        assert_eq!(st.submitted, 3);
        srv.shutdown();
    }

    #[test]
    fn long_prompts_truncate_and_generation_survives_wrap() {
        let srv = GenerationServer::start(GenBackend::Fp(tiny()), GenerationConfig::default());
        // prompt longer than n_ctx=12, budget far past the window
        let h = srv.submit(req(toks(40, 5), 30)).unwrap();
        assert_eq!(h.collect_tokens().unwrap().len(), 30);
        let st = srv.stats();
        assert_eq!(st.prompts_truncated, 1);
        assert!(st.prefills > 1, "wrap re-prefills counted");
        srv.shutdown();
    }

    #[test]
    fn shutdown_cuts_live_sessions_with_reason() {
        let srv = GenerationServer::start(
            GenBackend::Fp(tiny()),
            GenerationConfig { max_new_tokens: 100_000, ..Default::default() },
        );
        let h = srv.submit(req(toks(4, 6), 0)).unwrap();
        // let it produce a few tokens, then pull the plug
        let first = h.recv();
        assert!(matches!(first, Some(TokenEvent::Token { index: 0, .. })));
        srv.shutdown();
        let mut saw_shutdown = false;
        while let Some(ev) = h.recv() {
            if let TokenEvent::Done { reason, .. } = ev {
                assert_eq!(reason, FinishReason::Shutdown);
                saw_shutdown = true;
            }
        }
        assert!(saw_shutdown);
    }

    #[test]
    fn submit_after_shutdown_rejected() {
        let srv = GenerationServer::start(GenBackend::Fp(tiny()), GenerationConfig::default());
        let queue = srv.queue.clone();
        srv.shutdown();
        let (tx, _rx) = mpsc::channel();
        let p = PendingGen {
            req: req(vec![1], 1),
            submitted: Instant::now(),
            tx,
        };
        assert!(matches!(queue.push(p), Err(AdmitError::Shutdown)));
    }
}
