//! Scheduler: worker threads pull ready batches from the batcher, execute
//! them on the PJRT runtime and fulfil response handles. The public
//! [`Coordinator`] facade owns admission, the batcher and the workers.
//!
//! Threading model: all PJRT objects are confined to the process-wide
//! runtime service thread (see `runtime::service`); the registry is
//! `Send + Sync` and shared by every worker. Workers overlap batch
//! assembly/response handling with execution; execution dispatch itself
//! serializes on the service thread (PJRT CPU executions are internally
//! multi-threaded, so this costs nothing on a small host).

use super::batcher::{AdmitError, BatchKey, Batcher, BatcherConfig, ReadyBatch};
use super::request::{Pending, ResponseHandle, ScoreRequest, ScoreResponse};
use super::variants::{Manifest, VariantRegistry};
use crate::util::metrics::Registry;
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Debug, Clone, Default)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
    /// scheduler workers, each with a private PJRT engine (0 => 1)
    pub n_workers: usize,
}

/// Point-in-time statistics snapshot.
#[derive(Debug, Clone)]
pub struct CoordinatorStats {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub queued_now: usize,
}

/// The serving coordinator (see mod.rs for the dataflow).
pub struct Coordinator {
    manifest: Manifest,
    registry: Arc<VariantRegistry>,
    batcher: Arc<Batcher>,
    metrics: Arc<Registry>,
    workers: Vec<JoinHandle<()>>,
    running: Arc<AtomicBool>,
}

impl Coordinator {
    /// Start over the artifacts directory (usually `crate::artifacts_dir()`).
    pub fn start(root: impl Into<PathBuf>, cfg: CoordinatorConfig) -> Result<Self> {
        let root = root.into();
        let registry = Arc::new(VariantRegistry::load(&root)?);
        let manifest = registry.manifest().clone();
        let n_workers = if cfg.n_workers == 0 { 1 } else { cfg.n_workers };
        let batcher = Arc::new(Batcher::new(cfg.batcher));
        let metrics = Arc::new(Registry::default());
        let running = Arc::new(AtomicBool::new(true));
        let workers = (0..n_workers)
            .map(|i| {
                let batcher = batcher.clone();
                let metrics = metrics.clone();
                let registry = registry.clone();
                std::thread::Builder::new()
                    .name(format!("muxq-sched-{i}"))
                    .spawn(move || worker_loop(batcher, registry, metrics))
                    .expect("spawn scheduler worker")
            })
            .collect();
        Ok(Coordinator { manifest, registry, batcher, metrics, workers, running })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The shared variant registry (direct access for tooling).
    pub fn registry(&self) -> &Arc<VariantRegistry> {
        &self.registry
    }

    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    /// Submit one scoring request; returns a handle to block on.
    pub fn submit(&self, req: ScoreRequest) -> Result<ResponseHandle> {
        if !self.running.load(Ordering::SeqCst) {
            return Err(anyhow!("coordinator is shut down"));
        }
        // admission checks that fail fast (shape, variant existence)
        let meta = self
            .manifest
            .meta(&req.variant)
            .ok_or_else(|| anyhow!("unknown variant {:?}", req.variant))?;
        if req.tokens.len() != meta.seq {
            return Err(anyhow!(
                "sequence length {} != compiled seq {} for {:?}",
                req.tokens.len(),
                meta.seq,
                req.variant
            ));
        }
        if !(2.0..=8.0).contains(&req.ia_bits) || !(2.0..=8.0).contains(&req.w_bits) {
            return Err(anyhow!("bit-widths must be in [2, 8]"));
        }
        let (tx, rx) = mpsc::channel();
        let key = BatchKey::of(&req.variant, req.ia_bits, req.w_bits);
        let pending = Pending { req, submitted: Instant::now(), tx };
        self.metrics.counter("submitted").inc();
        match self.batcher.push(key, pending) {
            Ok(()) => Ok(ResponseHandle { rx }),
            Err(AdmitError::QueueFull) => {
                self.metrics.counter("rejected").inc();
                Err(anyhow!("queue full (backpressure)"))
            }
            Err(AdmitError::Shutdown) => Err(anyhow!("coordinator is shut down")),
        }
    }

    /// Convenience: submit + wait.
    pub fn score(&self, req: ScoreRequest) -> Result<ScoreResponse> {
        self.submit(req)?.wait()
    }

    pub fn stats(&self) -> CoordinatorStats {
        CoordinatorStats {
            submitted: self.metrics.counter("submitted").get(),
            completed: self.metrics.counter("completed").get(),
            rejected: self.metrics.counter("rejected").get(),
            batches: self.metrics.counter("batches").get(),
            padded_rows: self.metrics.counter("padded_rows").get(),
            queued_now: self.batcher.queued(),
        }
    }

    /// Drain queues and join workers.
    pub fn shutdown(mut self) {
        self.running.store(false, Ordering::SeqCst);
        self.batcher.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.running.store(false, Ordering::SeqCst);
        self.batcher.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(batcher: Arc<Batcher>, registry: Arc<VariantRegistry>, metrics: Arc<Registry>) {
    while let Some(batch) = batcher.next_batch() {
        execute_batch(&registry, &metrics, batch);
    }
}

fn execute_batch(registry: &VariantRegistry, metrics: &Registry, batch: ReadyBatch) {
    let exec_hist = metrics.histogram("batch_exec");
    let lat_hist = metrics.histogram("request_latency");
    let result = (|| -> Result<(Vec<f32>, Vec<f32>)> {
        let variant = registry.get(&batch.key.variant)?;
        let meta = &variant.meta;
        let b = meta.batch;
        let s = meta.seq;
        // assemble the padded token block
        let mut tokens = Vec::with_capacity(b * s);
        for p in &batch.requests {
            tokens.extend_from_slice(&p.req.tokens);
        }
        let n_pad = b - batch.requests.len();
        for _ in 0..n_pad {
            // pad with the first row (any valid tokens work; outputs are
            // discarded)
            tokens.extend_from_slice(&batch.requests[0].req.tokens);
        }
        metrics.counter("padded_rows").add(n_pad as u64);
        let ia = f32::from_bits(batch.key.ia_bits);
        let w = f32::from_bits(batch.key.w_bits);
        let t0 = Instant::now();
        let out = variant.run(&tokens, ia, w)?;
        exec_hist.record(t0.elapsed());
        let nll = out[0].data.clone();
        let count = out[1].data.clone();
        Ok((nll, count))
    })();

    metrics.counter("batches").inc();
    match result {
        Ok((nll, count)) => {
            for (i, p) in batch.requests.iter().enumerate() {
                let latency = p.submitted.elapsed();
                lat_hist.record(latency);
                metrics.counter("completed").inc();
                let _ = p.tx.send(Ok(ScoreResponse {
                    nll: nll[i],
                    count: count[i],
                    latency,
                }));
            }
        }
        Err(e) => {
            metrics.counter("batch_errors").inc();
            for p in &batch.requests {
                let _ = p.tx.send(Err(anyhow!("batch execution failed: {e:#}")));
            }
        }
    }
}
