//! Variant registry: discovers AOT artifacts via `manifest.json`, compiles
//! HLO on first use through the process-wide PJRT runtime service, and
//! keeps each model's weights resident on device (uploaded once, shared
//! by every variant of that model).
//!
//! Everything here is `Send + Sync`: PJRT objects never leave the runtime
//! service thread (see `runtime::service` for why that confinement is
//! mandatory with xla_extension 0.5.1).

use crate::quant::EngineSpec;
use crate::runtime::service::{ExeId, RuntimeService, WeightsId};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Identity of one compiled variant.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VariantKey {
    pub model: String,
    /// "eval" (per-seq nll) or "logits"
    pub kind: String,
    /// e.g. "muxq-pt", "naive-pv", "fp16-pt", "muxq-pt-sq", "muxq-pt-e1",
    /// "muxq-pv-rot", "naive-pv-rot-perm-w4a8", "resq-pv-r8"
    pub tag: String,
}

impl VariantKey {
    pub fn eval(model: &str, tag: &str) -> Self {
        VariantKey { model: model.into(), kind: "eval".into(), tag: tag.into() }
    }

    pub fn logits(model: &str, tag: &str) -> Self {
        VariantKey { model: model.into(), kind: "logits".into(), tag: tag.into() }
    }
}

/// Manifest entry (one exported HLO).
#[derive(Debug, Clone)]
pub struct VariantMeta {
    pub key: VariantKey,
    pub method: String,
    pub granularity: String,
    pub smooth: bool,
    /// Pre-transform flags: whether the tag's pipeline carries a
    /// blockwise rotation / zigzag permutation. Optional in the JSON
    /// (older manifests predate the pipeline: absent means "whatever
    /// the tag says"), but when present they must agree with the tag.
    pub rotate: bool,
    pub permute: bool,
    /// Explicit resq residual rank (`-r{N}` tag suffix); `None` means
    /// the operator picks its rank (calibrated or k/16 fallback).
    pub resid_rank: Option<usize>,
    pub exp_factor: u32,
    pub file: String,
    pub batch: usize,
    pub seq: usize,
    pub weights_file: String,
    /// Resolved bit widths: the manifest's explicit `ia_bits`/`w_bits`
    /// when present (checked against the tag), else the tag's own —
    /// `-w{W}a{A}` suffix or the method default.
    pub ia_bits: u32,
    pub w_bits: u32,
}

impl VariantMeta {
    /// The engine spec this variant's tag names — the canonical,
    /// parse-don't-match spelling ([`EngineSpec::tag`] round-trips it).
    pub fn spec(&self) -> Result<EngineSpec> {
        EngineSpec::parse(&self.key.tag)
    }
}

/// Parsed `manifest.json` — engine-independent.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: BTreeMap<VariantKey, VariantMeta>,
}

impl Manifest {
    pub fn load(root: &std::path::Path) -> Result<Self> {
        let mpath = root.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("read {} — run `make artifacts` first", mpath.display()))?;
        let json = Json::parse(&text).context("parse manifest.json")?;
        let mut entries = BTreeMap::new();
        for e in json.as_arr()? {
            let key = VariantKey {
                model: e.get("model")?.as_str()?.to_string(),
                kind: e.get("kind")?.as_str()?.to_string(),
                tag: e.get("tag")?.as_str()?.to_string(),
            };
            // the tag is the canonical spelling (EngineSpec round-trip);
            // the manifest's redundant method/granularity/smooth/exp
            // fields must agree with it — drift here used to surface as
            // silently-wrong table columns, now it fails the load
            let spec = EngineSpec::parse(&key.tag)
                .with_context(|| format!("manifest tag {:?} is not canonical", key.tag))?;
            if spec.tag() != key.tag {
                bail!("manifest tag {:?} does not round-trip (got {:?})", key.tag, spec.tag());
            }
            // explicit bit-width fields are optional (older manifests
            // predate them: the tag is then the only authority), but
            // when present they must not drift from the tag either
            let bits_field = |field: &str, want: u32| -> Result<u32> {
                match e {
                    Json::Obj(m) => match m.get(field) {
                        Some(v) => Ok(v.as_usize()? as u32),
                        None => Ok(want),
                    },
                    _ => Ok(want),
                }
            };
            let ia_bits = bits_field("ia_bits", spec.ia_bits)?;
            let w_bits = bits_field("w_bits", spec.w_bits)?;
            // pre-transform fields are optional the same way: absent
            // defers to the tag, present must not drift from it
            let flag_field = |field: &str, want: bool| -> Result<bool> {
                match e {
                    Json::Obj(m) => match m.get(field) {
                        Some(v) => v.as_bool(),
                        None => Ok(want),
                    },
                    _ => Ok(want),
                }
            };
            let rotate = flag_field("rotate", spec.has_rotate())?;
            let permute = flag_field("permute", spec.has_permute())?;
            let resid_rank = match e {
                Json::Obj(m) => match m.get("resid_rank") {
                    Some(v) => Some(v.as_usize()?),
                    None => spec.resid_rank,
                },
                _ => spec.resid_rank,
            };
            if (ia_bits, w_bits) != (spec.ia_bits, spec.w_bits) {
                bail!(
                    "manifest entry {:?} bits drifted from its tag: manifest w{}a{} vs tag w{}a{}",
                    key.tag,
                    w_bits,
                    ia_bits,
                    spec.w_bits,
                    spec.ia_bits
                );
            }
            let meta = VariantMeta {
                key: key.clone(),
                method: e.get("method")?.as_str()?.to_string(),
                granularity: e.get("granularity")?.as_str()?.to_string(),
                smooth: e.get("smooth")?.as_bool()?,
                rotate,
                permute,
                resid_rank,
                exp_factor: e.get("exp_factor")?.as_usize()? as u32,
                file: e.get("file")?.as_str()?.to_string(),
                batch: e.get("batch")?.as_usize()?,
                seq: e.get("seq")?.as_usize()?,
                weights_file: e.get("weights")?.as_str()?.to_string(),
                ia_bits,
                w_bits,
            };
            if spec.method.tag_name() != meta.method
                || crate::quant::Granularity::parse(&meta.granularity)
                    != Some((spec.act_gran, spec.w_gran))
                || spec.has_smooth() != meta.smooth
                || (spec.method == crate::quant::Method::Muxq
                    && spec.muxq.exp_factor != meta.exp_factor)
            {
                bail!(
                    "manifest entry {:?} drifted from its tag: method {:?} granularity {:?} \
                     smooth {} exp {}",
                    key.tag,
                    meta.method,
                    meta.granularity,
                    meta.smooth,
                    meta.exp_factor
                );
            }
            if (meta.rotate, meta.permute) != (spec.has_rotate(), spec.has_permute()) {
                bail!(
                    "manifest entry {:?} pre-transform drifted from its tag: \
                     manifest rotate {} permute {} vs tag rotate {} permute {}",
                    key.tag,
                    meta.rotate,
                    meta.permute,
                    spec.has_rotate(),
                    spec.has_permute()
                );
            }
            if meta.resid_rank != spec.resid_rank {
                bail!(
                    "manifest entry {:?} resid_rank drifted from its tag: \
                     manifest {:?} vs tag {:?}",
                    key.tag,
                    meta.resid_rank,
                    spec.resid_rank
                );
            }
            entries.insert(key, meta);
        }
        Ok(Manifest { entries })
    }

    pub fn keys(&self) -> Vec<VariantKey> {
        self.entries.keys().cloned().collect()
    }

    pub fn meta(&self, key: &VariantKey) -> Option<&VariantMeta> {
        self.entries.get(key)
    }

    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().map(|k| k.model.clone()).collect();
        v.dedup();
        v
    }
}

/// A compiled, ready-to-run variant (weights already on device).
/// Send + Sync — just handles into the runtime service.
pub struct CompiledVariant {
    pub meta: VariantMeta,
    service: RuntimeService,
    exe: ExeId,
    weights: WeightsId,
}

impl CompiledVariant {
    /// Execute on a full batch of token ids (`batch` x `seq`) with runtime
    /// bit-widths; returns the raw output buffers (host f32).
    pub fn run(
        &self,
        tokens: &[i32],
        ia_bits: f32,
        w_bits: f32,
    ) -> Result<Vec<crate::runtime::service::HostOutput>> {
        let want = self.meta.batch * self.meta.seq;
        if tokens.len() != want {
            bail!("tokens len {} != batch*seq {}", tokens.len(), want);
        }
        self.service.run(
            self.exe,
            Some(self.weights),
            tokens.to_vec(),
            (self.meta.batch, self.meta.seq),
            ia_bits,
            w_bits,
        )
    }
}

/// Registry over the artifacts directory. Send + Sync; shared by all
/// scheduler workers.
pub struct VariantRegistry {
    service: RuntimeService,
    root: PathBuf,
    manifest: Manifest,
    compiled: Mutex<BTreeMap<VariantKey, Arc<CompiledVariant>>>,
}

impl VariantRegistry {
    /// Parse `manifest.json` under `root`.
    pub fn load(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        let manifest = Manifest::load(&root)?;
        Ok(VariantRegistry {
            service: RuntimeService::global(),
            root,
            manifest,
            compiled: Mutex::new(BTreeMap::new()),
        })
    }

    /// Open the default artifacts dir.
    pub fn open_default() -> Result<Self> {
        Self::load(crate::artifacts_dir())
    }

    pub fn service(&self) -> &RuntimeService {
        &self.service
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn keys(&self) -> Vec<VariantKey> {
        self.manifest.keys()
    }

    pub fn meta(&self, key: &VariantKey) -> Option<&VariantMeta> {
        self.manifest.meta(key)
    }

    pub fn models(&self) -> Vec<String> {
        self.manifest.models()
    }

    /// Get (compiling + uploading on first use) a variant.
    pub fn get(&self, key: &VariantKey) -> Result<Arc<CompiledVariant>> {
        if let Some(v) = self.compiled.lock().unwrap().get(key) {
            return Ok(v.clone());
        }
        let meta = self
            .manifest
            .entries
            .get(key)
            .with_context(|| format!("variant {key:?} not in manifest"))?
            .clone();
        // compile OUTSIDE the cache lock (compilation takes seconds);
        // the service dedups concurrent requests for the same file
        let weights = self.service.upload_weights(self.root.join(&meta.weights_file))?;
        let exe = self.service.load_hlo(self.root.join("hlo").join(&meta.file))?;
        let variant =
            Arc::new(CompiledVariant { meta, service: self.service.clone(), exe, weights });
        let mut cache = self.compiled.lock().unwrap();
        Ok(cache.entry(key.clone()).or_insert(variant).clone())
    }

    pub fn compiled_count(&self) -> usize {
        self.compiled.lock().unwrap().len()
    }
}
