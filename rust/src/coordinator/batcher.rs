//! Dynamic batching for both serving planes:
//!
//! * [`Batcher`] — coalesces one-shot scoring requests into the
//!   fixed-shape batches the compiled variants expect (vLLM-style
//!   max-batch / max-wait policy). Batch compatibility: a batch shares
//!   (variant, ia_bits, w_bits) because bit-widths are per-execution
//!   scalars. Underfull batches are padded by repeating the first row;
//!   padded rows are dropped on the way out.
//! * [`DecodeQueue`] — the admission side of *continuous token-level
//!   batching* for generation: requests wait here only until the decode
//!   scheduler (`coordinator::generation`) has a free session slot. The
//!   actual batching is continuous — live sessions coalesce into one
//!   skinny decode GEMM per step, and new sessions are prefill-admitted
//!   *between* steps, never queued behind an in-flight batch — so there
//!   is no max-wait knob, only backpressure ([`AdmitError::QueueFull`]).

use super::request::{Pending, PendingGen};
use super::variants::VariantKey;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Queue key: variant + bit-widths (f32 bit patterns so Eq/Ord work).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BatchKey {
    pub variant: VariantKey,
    pub ia_bits: u32,
    pub w_bits: u32,
}

impl BatchKey {
    pub fn of(variant: &VariantKey, ia_bits: f32, w_bits: f32) -> Self {
        BatchKey { variant: variant.clone(), ia_bits: ia_bits.to_bits(), w_bits: w_bits.to_bits() }
    }
}

/// A batch ready for execution.
pub struct ReadyBatch {
    pub key: BatchKey,
    pub requests: Vec<Pending>,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// max sequences per batch (must match the compiled batch dim)
    pub max_batch: usize,
    /// coalescing window: flush a non-empty queue after this long
    pub max_wait: Duration,
    /// admission control: max queued requests across all queues
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            max_queue: 1024,
        }
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum AdmitError {
    QueueFull,
    Shutdown,
}

struct State {
    queues: BTreeMap<BatchKey, VecDeque<Pending>>,
    total: usize,
    shutdown: bool,
}

/// The batcher. `push` is called by the router, `next_batch` by scheduler
/// workers (blocking with timeout).
pub struct Batcher {
    pub cfg: BatcherConfig,
    state: Mutex<State>,
    nonempty: Condvar,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher {
            cfg,
            state: Mutex::new(State { queues: BTreeMap::new(), total: 0, shutdown: false }),
            nonempty: Condvar::new(),
        }
    }

    pub fn push(&self, key: BatchKey, p: Pending) -> Result<(), AdmitError> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Err(AdmitError::Shutdown);
        }
        if st.total >= self.cfg.max_queue {
            return Err(AdmitError::QueueFull);
        }
        st.queues.entry(key).or_default().push_back(p);
        st.total += 1;
        self.nonempty.notify_one();
        Ok(())
    }

    pub fn queued(&self) -> usize {
        self.state.lock().unwrap().total
    }

    pub fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        self.nonempty.notify_all();
    }

    /// Pull the next ready batch, blocking until one is ready or shutdown
    /// (then drains remaining queues, returning None only when empty).
    ///
    /// Ready = a queue reached `max_batch`, or its oldest entry has waited
    /// `max_wait`.
    pub fn next_batch(&self) -> Option<ReadyBatch> {
        let mut st = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            // find a full queue, else the queue with the oldest deadline
            let mut oldest: Option<(BatchKey, Instant)> = None;
            let mut full: Option<BatchKey> = None;
            for (key, q) in st.queues.iter() {
                if q.len() >= self.cfg.max_batch {
                    full = Some(key.clone());
                    break;
                }
                if let Some(front) = q.front() {
                    let due = front.submitted + self.cfg.max_wait;
                    if oldest.as_ref().map_or(true, |(_, d)| due < *d) {
                        oldest = Some((key.clone(), due));
                    }
                }
            }
            let pick = if let Some(key) = full {
                Some(key)
            } else if st.shutdown {
                // drain: take any non-empty queue immediately
                oldest.as_ref().map(|(k, _)| k.clone())
            } else {
                match &oldest {
                    Some((key, due)) if *due <= now => Some(key.clone()),
                    Some((_, due)) => {
                        let wait = due.saturating_duration_since(now);
                        let (g, _timeout) = self.nonempty.wait_timeout(st, wait).unwrap();
                        st = g;
                        continue;
                    }
                    None => {
                        if st.shutdown {
                            return None;
                        }
                        st = self.nonempty.wait(st).unwrap();
                        continue;
                    }
                }
            };
            let key = pick?;
            let q = st.queues.get_mut(&key).unwrap();
            let n = q.len().min(self.cfg.max_batch);
            let requests: Vec<Pending> = q.drain(..n).collect();
            if q.is_empty() {
                st.queues.remove(&key);
            }
            st.total -= requests.len();
            return Some(ReadyBatch { key, requests });
        }
    }
}

/// Outcome of a [`DecodeQueue::pop`].
pub enum DecodePop {
    /// a request to prefill-admit
    Req(PendingGen),
    /// nothing queued (non-blocking pop, or spurious wake)
    Empty,
    /// queue shut down and fully drained
    Shutdown,
}

/// Admission queue for generation sessions (see module docs). `push` is
/// called by the generation server's submit path; `pop` by the decode
/// scheduler — blocking when it has no live sessions to advance,
/// non-blocking between decode steps.
pub struct DecodeQueue {
    max_queue: usize,
    state: Mutex<GenState>,
    nonempty: Condvar,
}

struct GenState {
    queue: VecDeque<PendingGen>,
    shutdown: bool,
}

impl DecodeQueue {
    pub fn new(max_queue: usize) -> DecodeQueue {
        DecodeQueue {
            max_queue,
            state: Mutex::new(GenState { queue: VecDeque::new(), shutdown: false }),
            nonempty: Condvar::new(),
        }
    }

    pub fn push(&self, p: PendingGen) -> Result<(), AdmitError> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Err(AdmitError::Shutdown);
        }
        if st.queue.len() >= self.max_queue {
            return Err(AdmitError::QueueFull);
        }
        st.queue.push_back(p);
        self.nonempty.notify_one();
        Ok(())
    }

    pub fn queued(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        self.nonempty.notify_all();
    }

    /// Next request to admit. `block == false` (the between-steps probe)
    /// returns immediately; `block == true` (no live sessions) waits for
    /// work or shutdown. Shutdown reports immediately — decode shutdown
    /// stops at the next step boundary; the scheduler fails whatever is
    /// still queued via [`DecodeQueue::drain_remaining`] rather than
    /// paying a prefill per doomed request.
    pub fn pop(&self, block: bool) -> DecodePop {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.shutdown {
                return DecodePop::Shutdown;
            }
            if let Some(p) = st.queue.pop_front() {
                return DecodePop::Req(p);
            }
            if !block {
                return DecodePop::Empty;
            }
            st = self.nonempty.wait(st).unwrap();
        }
    }

    /// Take every request still queued (used by the scheduler after
    /// shutdown to send each a terminal event).
    pub fn drain_remaining(&self) -> Vec<PendingGen> {
        let mut st = self.state.lock().unwrap();
        st.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ScoreRequest;
    use std::sync::mpsc;

    fn pending(
        variant: &VariantKey,
    ) -> (Pending, mpsc::Receiver<anyhow::Result<super::super::request::ScoreResponse>>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                req: ScoreRequest {
                    variant: variant.clone(),
                    tokens: vec![0; 16],
                    ia_bits: 8.0,
                    w_bits: 8.0,
                },
                submitted: Instant::now(),
                tx,
            },
            rx,
        )
    }

    fn key() -> BatchKey {
        BatchKey::of(&VariantKey::eval("sim-small", "muxq-pt"), 8.0, 8.0)
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let b = Batcher::new(BatcherConfig { max_batch: 4, ..Default::default() });
        let v = VariantKey::eval("m", "t");
        for _ in 0..4 {
            let (p, _rx) = pending(&v);
            b.push(key(), p).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn partial_batch_flushes_after_max_wait() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(10),
            ..Default::default()
        });
        let v = VariantKey::eval("m", "t");
        let (p, _rx) = pending(&v);
        b.push(key(), p).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(9), "{:?}", t0.elapsed());
    }

    #[test]
    fn distinct_bits_never_share_a_batch() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        let v = VariantKey::eval("m", "t");
        let (p1, _r1) = pending(&v);
        let (p2, _r2) = pending(&v);
        b.push(BatchKey::of(&v, 8.0, 8.0), p1).unwrap();
        b.push(BatchKey::of(&v, 6.0, 8.0), p2).unwrap();
        let b1 = b.next_batch().unwrap();
        let b2 = b.next_batch().unwrap();
        assert_eq!(b1.requests.len(), 1);
        assert_eq!(b2.requests.len(), 1);
        assert_ne!(b1.key, b2.key);
    }

    #[test]
    fn admission_control() {
        let b = Batcher::new(BatcherConfig { max_queue: 2, ..Default::default() });
        let v = VariantKey::eval("m", "t");
        let (p1, _r1) = pending(&v);
        let (p2, _r2) = pending(&v);
        let (p3, _r3) = pending(&v);
        b.push(key(), p1).unwrap();
        b.push(key(), p2).unwrap();
        assert_eq!(b.push(key(), p3), Err(AdmitError::QueueFull));
    }

    fn pending_gen() -> (PendingGen, mpsc::Receiver<crate::coordinator::request::TokenEvent>) {
        use crate::coordinator::request::GenerateRequest;
        let (tx, rx) = mpsc::channel();
        (
            PendingGen {
                req: GenerateRequest::greedy(vec![1, 2, 3], 4),
                submitted: Instant::now(),
                tx,
            },
            rx,
        )
    }

    #[test]
    fn decode_queue_fifo_and_backpressure() {
        let q = DecodeQueue::new(2);
        let (p1, _r1) = pending_gen();
        let (p2, _r2) = pending_gen();
        let (p3, _r3) = pending_gen();
        q.push(p1).unwrap();
        q.push(p2).unwrap();
        assert!(matches!(q.push(p3), Err(AdmitError::QueueFull)));
        assert_eq!(q.queued(), 2);
        assert!(matches!(q.pop(false), DecodePop::Req(_)));
        assert!(matches!(q.pop(false), DecodePop::Req(_)));
        assert!(matches!(q.pop(false), DecodePop::Empty));
    }

    #[test]
    fn decode_queue_shutdown_is_immediate() {
        let q = DecodeQueue::new(8);
        let (p, _r) = pending_gen();
        q.push(p).unwrap();
        q.shutdown();
        let (p2, _r2) = pending_gen();
        assert!(matches!(q.push(p2), Err(AdmitError::Shutdown)));
        // shutdown wins over queued work (no prefill for doomed requests);
        // the leftover is recovered explicitly for terminal events
        assert!(matches!(q.pop(true), DecodePop::Shutdown));
        assert_eq!(q.drain_remaining().len(), 1);
        assert_eq!(q.queued(), 0);
        assert!(matches!(q.pop(false), DecodePop::Shutdown));
    }

    #[test]
    fn decode_queue_blocking_pop_wakes_on_push() {
        let q = std::sync::Arc::new(DecodeQueue::new(8));
        let q2 = q.clone();
        let waiter = std::thread::spawn(move || matches!(q2.pop(true), DecodePop::Req(_)));
        std::thread::sleep(Duration::from_millis(20));
        let (p, _r) = pending_gen();
        q.push(p).unwrap();
        assert!(waiter.join().unwrap());
    }

    #[test]
    fn shutdown_drains_then_none() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(60), // would block forever
            ..Default::default()
        });
        let v = VariantKey::eval("m", "t");
        let (p, _rx) = pending(&v);
        b.push(key(), p).unwrap();
        b.shutdown();
        assert!(b.next_batch().is_some(), "drain pending on shutdown");
        assert!(b.next_batch().is_none());
    }
}
