//! Dynamic batching for both serving planes:
//!
//! * [`Batcher`] — coalesces one-shot scoring requests into the
//!   fixed-shape batches the compiled variants expect (vLLM-style
//!   max-batch / max-wait policy). Batch compatibility: a batch shares
//!   (variant, ia_bits, w_bits) because bit-widths are per-execution
//!   scalars. Underfull batches are padded by repeating the first row;
//!   padded rows are dropped on the way out.
//! * [`DecodeQueue`] — the admission side of *continuous token-level
//!   batching* for generation: requests wait here only until the decode
//!   scheduler (`coordinator::generation`) has a free session slot. The
//!   actual batching is continuous — live sessions coalesce into one
//!   skinny decode GEMM per step, and new sessions are prefill-admitted
//!   *between* steps, never queued behind an in-flight batch — so there
//!   is no max-wait knob, only backpressure ([`AdmitError::QueueFull`]).
//!
//!   Admission order is **multi-tenant deficit-weighted round-robin**
//!   (DWRR), not FIFO: each [`GenerateRequest::tenant`] gets its own
//!   lane, lanes earn token-credits (`deficit`) in proportion to their
//!   configured [`QosConfig`] weight, and a lane is served while its
//!   deficit covers the front request's token cost. Under saturation,
//!   served-token shares converge to the weight ratio; every backlogged
//!   lane keeps earning credit, so none starves. A single-tenant queue
//!   degenerates to the original FIFO order bit-exactly (one lane, one
//!   front). Per-tenant queue caps shed excess load at `push`
//!   ([`AdmitError::TenantBusy`]); per-tenant in-flight caps hold a
//!   lane's requests in queue until one of its admitted sessions
//!   retires (tracked by RAII [`TenantPermit`]s).
//!
//! [`GenerateRequest::tenant`]: super::request::GenerateRequest

use super::request::{Pending, PendingGen};
use super::variants::VariantKey;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Queue key: variant + bit-widths (f32 bit patterns so Eq/Ord work).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BatchKey {
    pub variant: VariantKey,
    pub ia_bits: u32,
    pub w_bits: u32,
}

impl BatchKey {
    pub fn of(variant: &VariantKey, ia_bits: f32, w_bits: f32) -> Self {
        BatchKey { variant: variant.clone(), ia_bits: ia_bits.to_bits(), w_bits: w_bits.to_bits() }
    }
}

/// A batch ready for execution.
pub struct ReadyBatch {
    pub key: BatchKey,
    pub requests: Vec<Pending>,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// max sequences per batch (must match the compiled batch dim)
    pub max_batch: usize,
    /// coalescing window: flush a non-empty queue after this long
    pub max_wait: Duration,
    /// admission control: max queued requests across all queues
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            max_queue: 1024,
        }
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// whole-queue backpressure (every tenant is shedding)
    QueueFull,
    /// this tenant's own queue cap is full (others may still admit);
    /// the HTTP front end maps it to 429 with a `Retry-After`
    TenantBusy,
    Shutdown,
}

struct State {
    queues: BTreeMap<BatchKey, VecDeque<Pending>>,
    total: usize,
    shutdown: bool,
}

/// The batcher. `push` is called by the router, `next_batch` by scheduler
/// workers (blocking with timeout).
pub struct Batcher {
    pub cfg: BatcherConfig,
    state: Mutex<State>,
    nonempty: Condvar,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher {
            cfg,
            state: Mutex::new(State { queues: BTreeMap::new(), total: 0, shutdown: false }),
            nonempty: Condvar::new(),
        }
    }

    pub fn push(&self, key: BatchKey, p: Pending) -> Result<(), AdmitError> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Err(AdmitError::Shutdown);
        }
        if st.total >= self.cfg.max_queue {
            return Err(AdmitError::QueueFull);
        }
        st.queues.entry(key).or_default().push_back(p);
        st.total += 1;
        self.nonempty.notify_one();
        Ok(())
    }

    pub fn queued(&self) -> usize {
        self.state.lock().unwrap().total
    }

    pub fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        self.nonempty.notify_all();
    }

    /// Pull the next ready batch, blocking until one is ready or shutdown
    /// (then drains remaining queues, returning None only when empty).
    ///
    /// Ready = a queue reached `max_batch`, or its oldest entry has waited
    /// `max_wait`.
    pub fn next_batch(&self) -> Option<ReadyBatch> {
        let mut st = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            // find a full queue, else the queue with the oldest deadline
            let mut oldest: Option<(BatchKey, Instant)> = None;
            let mut full: Option<BatchKey> = None;
            for (key, q) in st.queues.iter() {
                if q.len() >= self.cfg.max_batch {
                    full = Some(key.clone());
                    break;
                }
                if let Some(front) = q.front() {
                    let due = front.submitted + self.cfg.max_wait;
                    if oldest.as_ref().map_or(true, |(_, d)| due < *d) {
                        oldest = Some((key.clone(), due));
                    }
                }
            }
            let pick = if let Some(key) = full {
                Some(key)
            } else if st.shutdown {
                // drain: take any non-empty queue immediately
                oldest.as_ref().map(|(k, _)| k.clone())
            } else {
                match &oldest {
                    Some((key, due)) if *due <= now => Some(key.clone()),
                    Some((_, due)) => {
                        let wait = due.saturating_duration_since(now);
                        let (g, _timeout) = self.nonempty.wait_timeout(st, wait).unwrap();
                        st = g;
                        continue;
                    }
                    None => {
                        if st.shutdown {
                            return None;
                        }
                        st = self.nonempty.wait(st).unwrap();
                        continue;
                    }
                }
            };
            let key = pick?;
            let q = st.queues.get_mut(&key).unwrap();
            let n = q.len().min(self.cfg.max_batch);
            let requests: Vec<Pending> = q.drain(..n).collect();
            if q.is_empty() {
                st.queues.remove(&key);
            }
            st.total -= requests.len();
            return Some(ReadyBatch { key, requests });
        }
    }
}

/// Outcome of a [`DecodeQueue::pop`].
pub enum DecodePop {
    /// a request to prefill-admit
    Req(PendingGen),
    /// nothing queued — or everything queued belongs to tenants at
    /// their in-flight cap (non-blocking pop, or spurious wake)
    Empty,
    /// queue shut down and fully drained
    Shutdown,
}

/// Per-tenant QoS policy for the decode admission queue.
#[derive(Debug, Clone)]
pub struct QosConfig {
    /// `(tenant, weight)` pairs; tenants not listed get
    /// `default_weight`. Weights are clamped to `>= 1` — a zero weight
    /// would starve by construction.
    pub weights: Vec<(String, usize)>,
    /// weight for tenants not in `weights`
    pub default_weight: usize,
    /// DWRR quantum: token-credits a lane earns per crediting round per
    /// unit of weight. Smaller quanta interleave tenants more finely;
    /// the served-share ratio is quantum-independent.
    pub quantum_tokens: u64,
    /// max admitted-but-unretired sessions per tenant (0 = unlimited).
    /// A lane at its cap is held in queue — not shed — until one of its
    /// sessions retires ([`TenantPermit`] drop).
    pub max_inflight_per_tenant: usize,
    /// max queued requests per tenant (0 = no per-tenant cap). The
    /// whole-queue `max_queue` still applies on top.
    pub max_queue_per_tenant: usize,
    /// assumed token cost for requests asking `max_new_tokens == 0`
    /// (the server substitutes its own default budget for those, so the
    /// generation server sets this to that default)
    pub default_cost_tokens: u64,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            weights: Vec::new(),
            default_weight: 1,
            quantum_tokens: 32,
            max_inflight_per_tenant: 0,
            max_queue_per_tenant: 0,
            default_cost_tokens: 128,
        }
    }
}

impl QosConfig {
    /// Weight builder.
    pub fn with_weight(mut self, tenant: &str, weight: usize) -> QosConfig {
        self.weights.push((tenant.to_string(), weight));
        self
    }

    fn weight_of(&self, tenant: &str) -> u64 {
        self.weights
            .iter()
            .find(|(t, _)| t == tenant)
            .map(|(_, w)| *w)
            .unwrap_or(self.default_weight)
            .max(1) as u64
    }

    /// The DWRR token cost of one request: its effective budget under a
    /// server whose default/ceiling budget is `default_cost_tokens`.
    fn cost_of(&self, p: &PendingGen) -> u64 {
        let d = self.default_cost_tokens.max(1);
        if p.req.max_new_tokens == 0 {
            d
        } else {
            (p.req.max_new_tokens as u64).min(d).max(1)
        }
    }
}

/// One tenant's lane. Lanes persist once created (they carry the
/// in-flight count); only *backlogged* lanes sit in the DWRR rotation.
struct TenantLane {
    queue: VecDeque<PendingGen>,
    weight: u64,
    /// DWRR token credit; reset when the lane drains (inactive lanes
    /// must not bank credit)
    deficit: u64,
    /// admitted sessions not yet retired ([`TenantPermit`] outstanding)
    inflight: usize,
}

/// Admission queue for generation sessions (see module docs). `push` is
/// called by the generation server's submit path; `pop` by the decode
/// scheduler — blocking when it has no live sessions to advance,
/// non-blocking between decode steps.
pub struct DecodeQueue {
    max_queue: usize,
    qos: QosConfig,
    state: Mutex<GenState>,
    nonempty: Condvar,
}

struct GenState {
    lanes: BTreeMap<String, TenantLane>,
    /// DWRR rotation: tenants with a non-empty queue, in
    /// became-backlogged order
    order: Vec<String>,
    /// rotation position the next pop scans from
    cursor: usize,
    total: usize,
    shutdown: bool,
}

impl GenState {
    /// Drop `tenant` from the rotation (its queue drained), keeping the
    /// cursor pointing at the same next tenant.
    fn retire_from_order(&mut self, tenant: &str) {
        if let Some(pos) = self.order.iter().position(|t| t == tenant) {
            self.order.remove(pos);
            if pos < self.cursor {
                self.cursor -= 1;
            }
            if self.cursor >= self.order.len() {
                self.cursor = 0;
            }
        }
    }
}

impl DecodeQueue {
    /// FIFO-compatible constructor: one implicit lane per tenant with
    /// the default QoS (all weights 1, no caps). With a single tenant
    /// this is exactly the pre-QoS FIFO queue.
    pub fn new(max_queue: usize) -> DecodeQueue {
        DecodeQueue::with_qos(max_queue, QosConfig::default())
    }

    pub fn with_qos(max_queue: usize, qos: QosConfig) -> DecodeQueue {
        DecodeQueue {
            max_queue,
            qos,
            state: Mutex::new(GenState {
                lanes: BTreeMap::new(),
                order: Vec::new(),
                cursor: 0,
                total: 0,
                shutdown: false,
            }),
            nonempty: Condvar::new(),
        }
    }

    pub fn push(&self, p: PendingGen) -> Result<(), AdmitError> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Err(AdmitError::Shutdown);
        }
        if st.total >= self.max_queue {
            return Err(AdmitError::QueueFull);
        }
        let tenant = p.req.tenant.clone();
        let weight = self.qos.weight_of(&tenant);
        let cap = self.qos.max_queue_per_tenant;
        let lane = st.lanes.entry(tenant.clone()).or_insert(TenantLane {
            queue: VecDeque::new(),
            weight,
            deficit: 0,
            inflight: 0,
        });
        if cap > 0 && lane.queue.len() >= cap {
            return Err(AdmitError::TenantBusy);
        }
        let was_empty = lane.queue.is_empty();
        lane.queue.push_back(p);
        if was_empty {
            st.order.push(tenant);
        }
        st.total += 1;
        self.nonempty.notify_one();
        Ok(())
    }

    pub fn queued(&self) -> usize {
        self.state.lock().unwrap().total
    }

    /// Requests queued for one tenant (its lane backlog, not in-flight).
    pub fn queued_for(&self, tenant: &str) -> usize {
        self.state.lock().unwrap().lanes.get(tenant).map(|l| l.queue.len()).unwrap_or(0)
    }

    /// Admitted-but-unretired sessions for one tenant.
    pub fn inflight_for(&self, tenant: &str) -> usize {
        self.state.lock().unwrap().lanes.get(tenant).map(|l| l.inflight).unwrap_or(0)
    }

    pub fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        self.nonempty.notify_all();
    }

    /// Retire one admitted session of `tenant`, freeing an in-flight
    /// slot (called by [`TenantPermit::drop`]). Wakes poppers: a lane
    /// held at its cap may now be servable.
    fn release(&self, tenant: &str) {
        let mut st = self.state.lock().unwrap();
        if let Some(lane) = st.lanes.get_mut(tenant) {
            lane.inflight = lane.inflight.saturating_sub(1);
        }
        self.nonempty.notify_all();
    }

    /// DWRR service decision over the backlogged, under-cap lanes.
    ///
    /// Classic DWRR visits lanes round-robin, crediting `quantum ×
    /// weight` per visit and serving while the deficit covers the front
    /// cost. Simulating those empty crediting rounds one by one would
    /// make `pop` O(max_cost/quantum); instead the rounds are
    /// fast-forwarded: pick the lane that becomes affordable in the
    /// fewest crediting rounds (ties broken by rotation distance from
    /// the cursor), credit EVERY eligible lane those rounds, serve the
    /// winner. Identical schedule, O(lanes) per pop.
    fn try_pop(&self, st: &mut GenState) -> Option<PendingGen> {
        let cap = self.qos.max_inflight_per_tenant;
        let quantum = self.qos.quantum_tokens.max(1);
        let n = st.order.len();
        // (rounds to afford, rotation distance, order index)
        let mut best: Option<(u64, usize, usize)> = None;
        for dist in 0..n {
            let pos = (st.cursor + dist) % n;
            let lane = &st.lanes[&st.order[pos]];
            if cap > 0 && lane.inflight >= cap {
                continue;
            }
            let front = lane.queue.front().expect("rotation holds only backlogged lanes");
            let cost = self.qos.cost_of(front);
            let need = cost.saturating_sub(lane.deficit);
            let rounds = need.div_ceil(quantum * lane.weight);
            if best.map_or(true, |(r, d, _)| (rounds, dist) < (r, d)) {
                best = Some((rounds, dist, pos));
            }
        }
        let (rounds, _, pos) = best?;
        if rounds > 0 {
            // fast-forward `rounds` crediting visits for every lane
            // still in contention (backlogged + under cap)
            for t in st.order.clone() {
                let lane = st.lanes.get_mut(&t).expect("rotation lane exists");
                if cap > 0 && lane.inflight >= cap {
                    continue;
                }
                lane.deficit = lane.deficit.saturating_add(rounds * quantum * lane.weight);
            }
        }
        let tenant = st.order[pos].clone();
        let lane = st.lanes.get_mut(&tenant).expect("winner lane exists");
        let p = lane.queue.pop_front().expect("winner was backlogged");
        lane.deficit -= self.qos.cost_of(&p).min(lane.deficit);
        lane.inflight += 1;
        st.total -= 1;
        if lane.queue.is_empty() {
            lane.deficit = 0; // drained lanes don't bank credit
            st.retire_from_order(&tenant);
        } else {
            // leave the cursor ON the winner: remaining deficit lets it
            // burst (DWRR serves a lane while its credit lasts)
            st.cursor = pos.min(st.order.len().saturating_sub(1));
        }
        Some(p)
    }

    /// Next request to admit, in DWRR order. `block == false` (the
    /// between-steps probe) returns immediately; `block == true` (no
    /// live sessions) waits for work, an in-flight release, or
    /// shutdown. Shutdown reports immediately — decode shutdown stops
    /// at the next step boundary; the scheduler fails whatever is still
    /// queued via [`DecodeQueue::drain_remaining`] rather than paying a
    /// prefill per doomed request.
    pub fn pop(&self, block: bool) -> DecodePop {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.shutdown {
                return DecodePop::Shutdown;
            }
            if let Some(p) = self.try_pop(&mut st) {
                return DecodePop::Req(p);
            }
            if !block {
                return DecodePop::Empty;
            }
            st = self.nonempty.wait(st).unwrap();
        }
    }

    /// Take every request still queued (used by the scheduler after
    /// shutdown to send each a terminal event). Deterministic tenant
    /// (lexicographic) order, FIFO within a tenant.
    pub fn drain_remaining(&self) -> Vec<PendingGen> {
        let mut st = self.state.lock().unwrap();
        let mut out = Vec::with_capacity(st.total);
        for lane in st.lanes.values_mut() {
            lane.deficit = 0;
            out.extend(lane.queue.drain(..));
        }
        st.order.clear();
        st.cursor = 0;
        st.total = 0;
        out
    }
}

/// RAII in-flight slot for one admitted session: the decode scheduler
/// mints one per popped request and parks it in the live-session record;
/// dropping it (retirement on ANY path — completion, cancel, eviction,
/// admit failure, shutdown) releases the tenant's slot so its next
/// queued request becomes servable.
pub struct TenantPermit {
    queue: Arc<DecodeQueue>,
    tenant: String,
}

impl TenantPermit {
    pub fn new(queue: Arc<DecodeQueue>, tenant: String) -> TenantPermit {
        TenantPermit { queue, tenant }
    }
}

impl Drop for TenantPermit {
    fn drop(&mut self) {
        self.queue.release(&self.tenant);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ScoreRequest;
    use std::sync::mpsc;

    fn pending(
        variant: &VariantKey,
    ) -> (Pending, mpsc::Receiver<anyhow::Result<super::super::request::ScoreResponse>>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                req: ScoreRequest {
                    variant: variant.clone(),
                    tokens: vec![0; 16],
                    ia_bits: 8.0,
                    w_bits: 8.0,
                },
                submitted: Instant::now(),
                tx,
            },
            rx,
        )
    }

    fn key() -> BatchKey {
        BatchKey::of(&VariantKey::eval("sim-small", "muxq-pt"), 8.0, 8.0)
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let b = Batcher::new(BatcherConfig { max_batch: 4, ..Default::default() });
        let v = VariantKey::eval("m", "t");
        for _ in 0..4 {
            let (p, _rx) = pending(&v);
            b.push(key(), p).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn partial_batch_flushes_after_max_wait() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(10),
            ..Default::default()
        });
        let v = VariantKey::eval("m", "t");
        let (p, _rx) = pending(&v);
        b.push(key(), p).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(9), "{:?}", t0.elapsed());
    }

    #[test]
    fn distinct_bits_never_share_a_batch() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        let v = VariantKey::eval("m", "t");
        let (p1, _r1) = pending(&v);
        let (p2, _r2) = pending(&v);
        b.push(BatchKey::of(&v, 8.0, 8.0), p1).unwrap();
        b.push(BatchKey::of(&v, 6.0, 8.0), p2).unwrap();
        let b1 = b.next_batch().unwrap();
        let b2 = b.next_batch().unwrap();
        assert_eq!(b1.requests.len(), 1);
        assert_eq!(b2.requests.len(), 1);
        assert_ne!(b1.key, b2.key);
    }

    #[test]
    fn admission_control() {
        let b = Batcher::new(BatcherConfig { max_queue: 2, ..Default::default() });
        let v = VariantKey::eval("m", "t");
        let (p1, _r1) = pending(&v);
        let (p2, _r2) = pending(&v);
        let (p3, _r3) = pending(&v);
        b.push(key(), p1).unwrap();
        b.push(key(), p2).unwrap();
        assert_eq!(b.push(key(), p3), Err(AdmitError::QueueFull));
    }

    fn pending_gen() -> (PendingGen, mpsc::Receiver<crate::coordinator::request::TokenEvent>) {
        use crate::coordinator::request::GenerateRequest;
        let (tx, rx) = mpsc::channel();
        (
            PendingGen {
                req: GenerateRequest::greedy(vec![1, 2, 3], 4),
                submitted: Instant::now(),
                tx,
            },
            rx,
        )
    }

    #[test]
    fn decode_queue_fifo_and_backpressure() {
        let q = DecodeQueue::new(2);
        let (p1, _r1) = pending_gen();
        let (p2, _r2) = pending_gen();
        let (p3, _r3) = pending_gen();
        q.push(p1).unwrap();
        q.push(p2).unwrap();
        assert!(matches!(q.push(p3), Err(AdmitError::QueueFull)));
        assert_eq!(q.queued(), 2);
        assert!(matches!(q.pop(false), DecodePop::Req(_)));
        assert!(matches!(q.pop(false), DecodePop::Req(_)));
        assert!(matches!(q.pop(false), DecodePop::Empty));
    }

    #[test]
    fn decode_queue_shutdown_is_immediate() {
        let q = DecodeQueue::new(8);
        let (p, _r) = pending_gen();
        q.push(p).unwrap();
        q.shutdown();
        let (p2, _r2) = pending_gen();
        assert!(matches!(q.push(p2), Err(AdmitError::Shutdown)));
        // shutdown wins over queued work (no prefill for doomed requests);
        // the leftover is recovered explicitly for terminal events
        assert!(matches!(q.pop(true), DecodePop::Shutdown));
        assert_eq!(q.drain_remaining().len(), 1);
        assert_eq!(q.queued(), 0);
        assert!(matches!(q.pop(false), DecodePop::Shutdown));
    }

    #[test]
    fn decode_queue_blocking_pop_wakes_on_push() {
        let q = std::sync::Arc::new(DecodeQueue::new(8));
        let q2 = q.clone();
        let waiter = std::thread::spawn(move || matches!(q2.pop(true), DecodePop::Req(_)));
        std::thread::sleep(Duration::from_millis(20));
        let (p, _r) = pending_gen();
        q.push(p).unwrap();
        assert!(waiter.join().unwrap());
    }

    fn pending_gen_for(
        tenant: &str,
        max_new: usize,
    ) -> (PendingGen, mpsc::Receiver<crate::coordinator::request::TokenEvent>) {
        use crate::coordinator::request::GenerateRequest;
        let (tx, rx) = mpsc::channel();
        (
            PendingGen {
                req: GenerateRequest::greedy(vec![1, 2, 3], max_new).with_tenant(tenant),
                submitted: Instant::now(),
                tx,
            },
            rx,
        )
    }

    /// quantum 1 + equal costs make the DWRR schedule fully deterministic
    fn fine_grained_qos() -> QosConfig {
        QosConfig {
            quantum_tokens: 1,
            default_cost_tokens: 4,
            ..QosConfig::default()
        }
    }

    #[test]
    fn decode_queue_dwrr_weighted_ratio() {
        let qos = fine_grained_qos().with_weight("a", 3).with_weight("b", 1);
        let q = DecodeQueue::with_qos(64, qos);
        let mut rxs = Vec::new();
        for _ in 0..8 {
            let (p, r) = pending_gen_for("a", 4);
            q.push(p).unwrap();
            rxs.push(r);
            let (p, r) = pending_gen_for("b", 4);
            q.push(p).unwrap();
            rxs.push(r);
        }
        let mut served = Vec::new();
        while let DecodePop::Req(p) = q.pop(false) {
            served.push(p.req.tenant.clone());
        }
        assert_eq!(served.len(), 16, "every backlogged request drains");
        // weights 3:1 with equal costs → the steady-state schedule is
        // a,a,a,b repeating; check the ratio over the saturated prefix
        let first8_a = served[..8].iter().filter(|t| *t == "a").count();
        assert_eq!(first8_a, 6, "3:1 share in saturation, got {:?}", served);
        assert!(
            served[..4].iter().any(|t| t == "b"),
            "light tenant is not starved: {:?}",
            served
        );
    }

    #[test]
    fn decode_queue_single_tenant_is_fifo() {
        // one lane (the anonymous tenant) must preserve exact push order
        // even with wildly mixed costs — bit-compat with the pre-QoS queue
        let q = DecodeQueue::new(16);
        let costs = [7usize, 1, 200, 3, 50];
        let mut rxs = Vec::new();
        for &c in &costs {
            let (p, r) = pending_gen_for("", c);
            q.push(p).unwrap();
            rxs.push(r);
        }
        for &c in &costs {
            match q.pop(false) {
                DecodePop::Req(p) => assert_eq!(p.req.max_new_tokens, c),
                _ => panic!("expected Req"),
            }
        }
        assert!(matches!(q.pop(false), DecodePop::Empty));
    }

    #[test]
    fn decode_queue_tenant_queue_cap_sheds() {
        let qos = QosConfig { max_queue_per_tenant: 2, ..QosConfig::default() };
        let q = DecodeQueue::with_qos(64, qos);
        let (p1, _r1) = pending_gen_for("a", 4);
        let (p2, _r2) = pending_gen_for("a", 4);
        let (p3, _r3) = pending_gen_for("a", 4);
        let (p4, _r4) = pending_gen_for("b", 4);
        q.push(p1).unwrap();
        q.push(p2).unwrap();
        assert!(matches!(q.push(p3), Err(AdmitError::TenantBusy)));
        // another tenant still admits — the cap is per-lane
        q.push(p4).unwrap();
        assert_eq!(q.queued_for("a"), 2);
        assert_eq!(q.queued_for("b"), 1);
    }

    #[test]
    fn decode_queue_inflight_cap_holds_until_release() {
        let qos = QosConfig { max_inflight_per_tenant: 1, ..QosConfig::default() };
        let q = std::sync::Arc::new(DecodeQueue::with_qos(64, qos));
        let (p1, _r1) = pending_gen_for("a", 4);
        let (p2, _r2) = pending_gen_for("a", 4);
        q.push(p1).unwrap();
        q.push(p2).unwrap();
        assert!(matches!(q.pop(false), DecodePop::Req(_)));
        assert_eq!(q.inflight_for("a"), 1);
        // lane is at its in-flight cap: held in queue, not shed
        assert!(matches!(q.pop(false), DecodePop::Empty));
        assert_eq!(q.queued_for("a"), 1);
        // retiring the admitted session (permit drop) frees the slot
        drop(TenantPermit::new(q.clone(), "a".to_string()));
        assert_eq!(q.inflight_for("a"), 0);
        assert!(matches!(q.pop(false), DecodePop::Req(_)));
    }

    #[test]
    fn shutdown_drains_then_none() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(60), // would block forever
            ..Default::default()
        });
        let v = VariantKey::eval("m", "t");
        let (p, _rx) = pending(&v);
        b.push(key(), p).unwrap();
        b.shutdown();
        assert!(b.next_batch().is_some(), "drain pending on shutdown");
        assert!(b.next_batch().is_none());
    }
}
