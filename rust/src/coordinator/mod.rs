//! L3 — the serving coordinator (rust owns the request path; python never
//! runs after `make artifacts`). Two serving planes share the admission
//! machinery:
//!
//! **Scoring** (one-shot, fixed-shape, PJRT):
//!
//! ```text
//! client ──submit──> Coordinator (admission) ──> Batcher (coalesce by
//!    (variant, bits), max-batch / max-wait) ──> scheduler workers ──>
//!    VariantRegistry (compile-once, weights-on-device) ──> PJRT exec ──>
//!    per-sequence (nll, count) ──> ResponseHandle
//! ```
//!
//! **Generation** (stateful, token-level, native INT engine):
//!
//! ```text
//! client ──submit──> GenerationServer (admission) ──> DecodeQueue ──>
//!    decode scheduler (continuous batching: prefill-admit between steps,
//!    ONE skinny GEMM per step across all live KV-cache sessions) ──>
//!    streamed TokenEvents ──> GenerateHandle
//! ```
//!
//! * [`variants`] — manifest discovery, lazy compile, device-resident
//!   weights shared across variants of a model.
//! * [`batcher`] — dynamic batching ([`batcher::Batcher`]) + decode
//!   admission ([`batcher::DecodeQueue`]).
//! * [`request`] — request/response/handle types for both planes.
//! * [`scheduler`] — worker threads executing ready scoring batches.
//! * [`generation`] — the continuous-batching decode scheduler.

pub mod batcher;
pub mod generation;
pub mod request;
pub mod scheduler;
pub mod variants;

pub use batcher::{AdmitError, BatcherConfig, QosConfig, TenantPermit};
pub use generation::{
    GenBackend, GenerationConfig, GenerationServer, GenerationStats, SubmitError,
};
pub use request::{
    FinishReason, GenerateHandle, GenerateRequest, ResponseHandle, ScoreRequest, ScoreResponse,
    SpeculativeConfig, TokenEvent,
};
pub use scheduler::{Coordinator, CoordinatorConfig, CoordinatorStats};
pub use variants::{VariantKey, VariantRegistry};
