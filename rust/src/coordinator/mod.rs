//! L3 — the serving coordinator (rust owns the request path; python never
//! runs after `make artifacts`).
//!
//! Dataflow:
//!
//! ```text
//! client ──submit──> Coordinator (admission) ──> Batcher (coalesce by
//!    (variant, bits), max-batch / max-wait) ──> scheduler workers ──>
//!    VariantRegistry (compile-once, weights-on-device) ──> PJRT exec ──>
//!    per-sequence (nll, count) ──> ResponseHandle
//! ```
//!
//! * [`variants`] — manifest discovery, lazy compile, device-resident
//!   weights shared across variants of a model.
//! * [`batcher`] — dynamic batching with padding + admission control.
//! * [`request`] — request/response/handle types.
//! * [`scheduler`] — worker threads executing ready batches.

pub mod batcher;
pub mod request;
pub mod scheduler;
pub mod variants;

pub use batcher::{AdmitError, BatcherConfig};
pub use request::{ResponseHandle, ScoreRequest, ScoreResponse};
pub use scheduler::{Coordinator, CoordinatorConfig, CoordinatorStats};
pub use variants::{VariantKey, VariantRegistry};
