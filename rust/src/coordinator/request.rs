//! Request/response types for the serving layer: one-shot scoring
//! (`ScoreRequest` → `ScoreResponse`) and streamed token generation
//! (`GenerateRequest` → a stream of [`TokenEvent`]s through a
//! [`GenerateHandle`]).

use super::variants::VariantKey;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// A scoring request: one token sequence to evaluate under a variant at
/// given bit-widths. Sequences shorter than the compiled `seq` are
/// rejected at admission (the eval graphs are fixed-shape; the client
/// library chunks long texts into windows).
#[derive(Debug, Clone)]
pub struct ScoreRequest {
    pub variant: VariantKey,
    pub tokens: Vec<i32>,
    pub ia_bits: f32,
    pub w_bits: f32,
}

/// Result for one scoring request.
#[derive(Debug, Clone)]
pub struct ScoreResponse {
    /// summed next-token NLL over the sequence
    pub nll: f32,
    /// number of predicted tokens
    pub count: f32,
    /// total time from submit to completion
    pub latency: std::time::Duration,
}

impl ScoreResponse {
    /// Perplexity `exp(nll / count)`. An empty window (`count == 0`)
    /// carries no evidence; report infinite perplexity rather than the
    /// NaN that `0/0` would silently propagate into aggregate stats.
    pub fn ppl(&self) -> f32 {
        if self.count <= 0.0 {
            return f32::INFINITY;
        }
        (self.nll / self.count).exp()
    }
}

/// Handle the caller blocks on.
pub struct ResponseHandle {
    pub(crate) rx: mpsc::Receiver<anyhow::Result<ScoreResponse>>,
}

impl ResponseHandle {
    pub fn wait(self) -> anyhow::Result<ScoreResponse> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("coordinator dropped request"))?
    }
}

/// A request in flight through the batcher (public within the crate's
/// serving pipeline; constructed only by the coordinator).
pub struct Pending {
    pub req: ScoreRequest,
    pub submitted: Instant,
    pub tx: mpsc::Sender<anyhow::Result<ScoreResponse>>,
}

/// Per-request speculative decoding: a cheap draft proposes `k` tokens
/// per round, the target verifies all of them in one skinny batched
/// forward ([`crate::gpt2::SpeculativeState`]). Acceptance is lossless
/// — greedy speculation reproduces plain greedy token for token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpeculativeConfig {
    /// tokens drafted per round (`k >= 1`)
    pub k: usize,
    /// which draft model the server should build for this session
    pub draft: crate::gpt2::DraftKind,
}

/// A generation request: prefill the prompt, then stream decoded tokens
/// — greedy by default, seeded temperature / top-k / top-p sampling with
/// repetition penalty on request, optionally draft-and-verify
/// speculative decoding. Prompts longer than the model context keep
/// their last `n_ctx` tokens (recorded in the server stats); the prompt
/// is processed at its TRUE length — no padding rows.
#[derive(Debug, Clone)]
pub struct GenerateRequest {
    pub prompt: Vec<u32>,
    /// generation stops after this many tokens (clamped to the server's
    /// configured ceiling; 0 means "use the server default")
    pub max_new_tokens: usize,
    /// softmax temperature; `0.0` (the default) means greedy argmax
    pub temperature: f32,
    /// sample only among the k highest logits; `0` means all
    pub top_k: usize,
    /// nucleus cutoff — keep the smallest top-logit prefix whose
    /// probability mass reaches `top_p`; `1.0` disables
    pub top_p: f32,
    /// divide positive / multiply negative logits of seen tokens by
    /// this factor; `1.0` disables
    pub repetition_penalty: f32,
    /// sampling seed — (seed, prompt, model) fully determines the
    /// stream, so sampled generations are replayable
    pub seed: u64,
    /// `Some` routes this session through draft-and-verify decoding
    pub speculative: Option<SpeculativeConfig>,
    /// multi-tenant QoS key: requests are queued per tenant and served
    /// by deficit-weighted round-robin ([`super::batcher::DecodeQueue`]).
    /// `""` (the default) is the anonymous tenant — a single-tenant
    /// server degenerates to the original FIFO order exactly.
    pub tenant: String,
}

impl GenerateRequest {
    /// Greedy request (the default serving mode).
    pub fn greedy(prompt: Vec<u32>, max_new_tokens: usize) -> GenerateRequest {
        GenerateRequest {
            prompt,
            max_new_tokens,
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            repetition_penalty: 1.0,
            seed: 0,
            speculative: None,
            tenant: String::new(),
        }
    }

    /// Seeded temperature / top-k sampling request.
    pub fn sampled(
        prompt: Vec<u32>,
        max_new_tokens: usize,
        temperature: f32,
        top_k: usize,
        seed: u64,
    ) -> GenerateRequest {
        GenerateRequest { temperature, top_k, seed, ..GenerateRequest::greedy(prompt, max_new_tokens) }
    }

    /// Nucleus cutoff (builder).
    pub fn with_top_p(mut self, top_p: f32) -> GenerateRequest {
        self.top_p = top_p;
        self
    }

    /// Repetition penalty (builder).
    pub fn with_repetition_penalty(mut self, rp: f32) -> GenerateRequest {
        self.repetition_penalty = rp;
        self
    }

    /// Route through speculative decoding (builder).
    pub fn with_speculative(mut self, k: usize, draft: crate::gpt2::DraftKind) -> GenerateRequest {
        self.speculative = Some(SpeculativeConfig { k, draft });
        self
    }

    /// Tag this request with a QoS tenant (builder).
    pub fn with_tenant(mut self, tenant: &str) -> GenerateRequest {
        self.tenant = tenant.to_string();
        self
    }

    /// The per-session sampler this request asks for (`Sampler` itself
    /// degrades to greedy argmax when the parameters are degenerate).
    pub fn sampler(&self) -> crate::gpt2::Sampler {
        crate::gpt2::Sampler::new(self.temperature, self.top_k, self.seed)
            .with_top_p(self.top_p)
            .with_repetition_penalty(self.repetition_penalty)
    }
}

/// Why a generation stream ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// produced `max_new_tokens`
    MaxTokens,
    /// server shut down before the budget was reached
    Shutdown,
    /// evicted by the server under KV-pool pressure (paged mode): the
    /// scheduler reclaimed this session's pages so already-admitted
    /// sessions could keep decoding. The stream ends cleanly with the
    /// tokens generated so far.
    Evicted,
    /// the client abandoned the stream (dropped [`GenerateHandle`] /
    /// closed socket) and the server cancelled the live session so its
    /// KV pages free promptly. The dropped receiver can't observe this
    /// event — the scheduler still records it (the `cancelled` stat and
    /// the HTTP front end's `http_disconnects` counter), and the
    /// best-effort `Done` send documents the retirement in one place.
    Cancelled,
}

impl FinishReason {
    /// Wire spelling used by the HTTP front end's SSE `finish` events.
    pub fn as_wire(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "length",
            FinishReason::Shutdown => "shutdown",
            FinishReason::Evicted => "evicted",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

/// One event on a generation stream. Tokens arrive strictly in order
/// (`index` 0, 1, …), terminated by exactly one `Done` or `Error`.
#[derive(Debug, Clone)]
pub enum TokenEvent {
    Token { index: usize, token: u32 },
    Done { reason: FinishReason, generated: usize, latency: Duration },
    Error(String),
}

/// Streaming receiver for one generation request. Dropping it mid-stream
/// cancels the session at the next decode step.
pub struct GenerateHandle {
    pub(crate) rx: mpsc::Receiver<TokenEvent>,
}

impl GenerateHandle {
    /// Next event, blocking; `None` once the stream is closed.
    pub fn recv(&self) -> Option<TokenEvent> {
        self.rx.recv().ok()
    }

    /// Drain the stream to completion, returning the generated tokens.
    /// Errors if the stream ended with [`TokenEvent::Error`] or closed
    /// without a terminal event.
    pub fn collect_tokens(self) -> anyhow::Result<Vec<u32>> {
        let mut out = Vec::new();
        while let Some(ev) = self.recv() {
            match ev {
                TokenEvent::Token { index, token } => {
                    debug_assert_eq!(index, out.len(), "out-of-order token stream");
                    out.push(token);
                }
                TokenEvent::Done { .. } => return Ok(out),
                TokenEvent::Error(e) => anyhow::bail!("generation failed: {e}"),
            }
        }
        anyhow::bail!("generation stream closed without a terminal event")
    }
}

/// A generation request in flight through the decode queue.
pub struct PendingGen {
    pub req: GenerateRequest,
    pub submitted: Instant,
    pub tx: mpsc::Sender<TokenEvent>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppl_math() {
        let r = ScoreResponse { nll: 254.0, count: 127.0, latency: Default::default() };
        assert!((r.ppl() - (2.0f32).exp()).abs() < 1e-4);
    }

    #[test]
    fn ppl_empty_window_is_infinite_not_nan() {
        let r = ScoreResponse { nll: 0.0, count: 0.0, latency: Default::default() };
        assert_eq!(r.ppl(), f32::INFINITY);
        assert!(!r.ppl().is_nan());
        // and it no longer poisons aggregates the way NaN would
        let worst = [r.ppl(), 12.0f32].iter().fold(0.0f32, |m, &v| m.max(v));
        assert_eq!(worst, f32::INFINITY);
    }

    #[test]
    fn request_sampler_mapping() {
        let g = GenerateRequest::greedy(vec![1, 2], 4);
        assert!(g.sampler().is_greedy());
        let s = GenerateRequest::sampled(vec![1, 2], 4, 0.9, 40, 7);
        let sm = s.sampler();
        assert!(!sm.is_greedy());
        assert_eq!((sm.temperature, sm.top_k), (0.9, 40));
        // zero temperature always degrades to greedy, whatever the rest says
        let z = GenerateRequest::sampled(vec![1], 1, 0.0, 40, 7);
        assert!(z.sampler().is_greedy());
    }

    #[test]
    fn request_builders_thread_new_knobs() {
        let r = GenerateRequest::sampled(vec![1], 4, 0.9, 40, 7)
            .with_top_p(0.92)
            .with_repetition_penalty(1.3);
        let sm = r.sampler();
        assert_eq!((sm.top_p, sm.repetition_penalty), (0.92, 1.3));
        // defaults leave both knobs disabled
        let d = GenerateRequest::greedy(vec![1], 4).sampler();
        assert_eq!((d.top_p, d.repetition_penalty), (1.0, 1.0));
        // repetition penalty applies even in greedy mode, so the greedy
        // request with a penalty still maps to a greedy sampler
        let gp = GenerateRequest::greedy(vec![1], 4).with_repetition_penalty(1.5);
        assert!(gp.sampler().is_greedy());
    }

    #[test]
    fn speculative_config_rides_the_request() {
        let r = GenerateRequest::greedy(vec![1, 2], 8)
            .with_speculative(3, crate::gpt2::DraftKind::NaiveInt8);
        let sc = r.speculative.unwrap();
        assert_eq!(sc.k, 3);
        assert_eq!(sc.draft, crate::gpt2::DraftKind::NaiveInt8);
        assert!(GenerateRequest::greedy(vec![1], 1).speculative.is_none());
    }

    #[test]
    fn tenant_rides_the_request() {
        assert_eq!(GenerateRequest::greedy(vec![1], 1).tenant, "");
        assert_eq!(GenerateRequest::greedy(vec![1], 1).with_tenant("team-a").tenant, "team-a");
    }

    #[test]
    fn finish_reason_wire_spellings_are_distinct() {
        use std::collections::BTreeSet;
        let all = [
            FinishReason::MaxTokens,
            FinishReason::Shutdown,
            FinishReason::Evicted,
            FinishReason::Cancelled,
        ];
        let wires: BTreeSet<&str> = all.iter().map(|r| r.as_wire()).collect();
        assert_eq!(wires.len(), all.len());
        assert_eq!(FinishReason::MaxTokens.as_wire(), "length");
    }

    #[test]
    fn generate_handle_collects_in_order() {
        let (tx, rx) = mpsc::channel();
        tx.send(TokenEvent::Token { index: 0, token: 7 }).unwrap();
        tx.send(TokenEvent::Token { index: 1, token: 9 }).unwrap();
        tx.send(TokenEvent::Done {
            reason: FinishReason::MaxTokens,
            generated: 2,
            latency: Duration::from_millis(1),
        })
        .unwrap();
        let h = GenerateHandle { rx };
        assert_eq!(h.collect_tokens().unwrap(), vec![7, 9]);
    }

    #[test]
    fn generate_handle_surfaces_errors() {
        let (tx, rx) = mpsc::channel();
        tx.send(TokenEvent::Error("boom".into())).unwrap();
        let h = GenerateHandle { rx };
        assert!(h.collect_tokens().is_err());
        // a dropped sender without a terminal event is also an error
        let (tx2, rx2) = mpsc::channel::<TokenEvent>();
        drop(tx2);
        assert!(GenerateHandle { rx: rx2 }.collect_tokens().is_err());
    }
}
