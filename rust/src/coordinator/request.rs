//! Request/response types for the serving layer.

use super::variants::VariantKey;
use std::sync::mpsc;
use std::time::Instant;

/// A scoring request: one token sequence to evaluate under a variant at
/// given bit-widths. Sequences shorter than the compiled `seq` are
/// rejected at admission (the eval graphs are fixed-shape; the client
/// library chunks long texts into windows).
#[derive(Debug, Clone)]
pub struct ScoreRequest {
    pub variant: VariantKey,
    pub tokens: Vec<i32>,
    pub ia_bits: f32,
    pub w_bits: f32,
}

/// Result for one scoring request.
#[derive(Debug, Clone)]
pub struct ScoreResponse {
    /// summed next-token NLL over the sequence
    pub nll: f32,
    /// number of predicted tokens
    pub count: f32,
    /// total time from submit to completion
    pub latency: std::time::Duration,
}

impl ScoreResponse {
    pub fn ppl(&self) -> f32 {
        (self.nll / self.count).exp()
    }
}

/// Handle the caller blocks on.
pub struct ResponseHandle {
    pub(crate) rx: mpsc::Receiver<anyhow::Result<ScoreResponse>>,
}

impl ResponseHandle {
    pub fn wait(self) -> anyhow::Result<ScoreResponse> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("coordinator dropped request"))?
    }
}

/// A request in flight through the batcher (public within the crate's
/// serving pipeline; constructed only by the coordinator).
pub struct Pending {
    pub req: ScoreRequest,
    pub submitted: Instant,
    pub tx: mpsc::Sender<anyhow::Result<ScoreResponse>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppl_math() {
        let r = ScoreResponse { nll: 254.0, count: 127.0, latency: Default::default() };
        assert!((r.ppl() - (2.0f32).exp()).abs() < 1e-4);
    }
}
