//! `muxq` — the leader binary: serving launcher + operational tooling.
//!
//! Subcommands:
//! * `muxq serve [--config serve.cfg] [--requests N]` — start the
//!   coordinator and run a synthetic serving workload against it
//!   (or idle-serve when `--requests 0`).
//! * `muxq eval --model M --method muxq --granularity per-tensor
//!    --ia-bits 8 --w-bits 8` — one-off perplexity evaluation.
//! * `muxq variants` — list available compiled variants.
//! * `muxq npusim` — print the hardware-efficiency study tables.

use anyhow::{bail, Result};
use muxq::coordinator::{Coordinator, CoordinatorConfig, ScoreRequest, VariantKey};
use muxq::data::eval_set::{perplexity, EvalSet};
use muxq::util::cli::Cli;
use muxq::util::config::Config;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().cloned().unwrap_or_else(|| "help".into());
    let rest = if args.is_empty() { &[][..] } else { &args[1..] };
    let result = match cmd.as_str() {
        "serve" => cmd_serve(rest),
        "eval" => cmd_eval(rest),
        "variants" => cmd_variants(),
        "npusim" => cmd_npusim(),
        _ => {
            println!(
                "muxq — MUXQ quantized-LLM serving coordinator\n\n\
                 usage: muxq <serve|eval|variants|npusim> [options]\n\
                 run `muxq <cmd> --help` for per-command options"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let p = Cli::new("muxq serve", "start the coordinator + synthetic workload")
        .opt("config", "", "INI config file ([server] section)")
        .opt("model", "sim-small", "model to serve")
        .opt("tag", "muxq-pt", "variant tag (e.g. muxq-pt, naive-pv, fp16-pt)")
        .opt("requests", "64", "number of workload requests (0 = idle)")
        .opt("ia-bits", "8", "activation bits")
        .opt("w-bits", "8", "weight bits")
        .opt("max-batch", "8", "dynamic batch size")
        .opt("max-wait-ms", "5", "batch coalescing window")
        .parse(args)?;

    let mut ccfg = CoordinatorConfig::default();
    let mut model = p.get("model").to_string();
    let mut tag = p.get("tag").to_string();
    if !p.get("config").is_empty() {
        let cfg = Config::load(p.get("config"))?;
        model = cfg.get_or("server", "model", &model).to_string();
        tag = cfg.get_or("server", "tag", &tag).to_string();
        ccfg.batcher.max_batch = cfg.get_usize("server", "max_batch", 8)?;
        ccfg.batcher.max_wait =
            std::time::Duration::from_millis(cfg.get_usize("server", "max_wait_ms", 5)? as u64);
    } else {
        ccfg.batcher.max_batch = p.get_usize("max-batch")?;
        ccfg.batcher.max_wait =
            std::time::Duration::from_millis(p.get_usize("max-wait-ms")? as u64);
    }
    let ia_bits = p.get_f64("ia-bits")? as f32;
    let w_bits = p.get_f64("w-bits")? as f32;
    let n_requests = p.get_usize("requests")?;

    let artifacts = muxq::artifacts_dir();
    let coord = Coordinator::start(&artifacts, ccfg)?;
    let variant = VariantKey::eval(&model, &tag);
    let meta = coord
        .manifest()
        .meta(&variant)
        .ok_or_else(|| anyhow::anyhow!("variant {variant:?} not found; run `muxq variants`"))?
        .clone();
    println!("serving {model} [{tag}] batch={} seq={}", meta.batch, meta.seq);

    if n_requests == 0 {
        println!("idle-serving; ctrl-c to stop");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    let seq = meta.seq;
    let eval = EvalSet::load(&artifacts, "valid")?;
    let windows = eval.windows(seq, n_requests);
    let t0 = Instant::now();
    let handles: Vec<_> = windows
        .iter()
        .cycle()
        .take(n_requests)
        .map(|w| {
            coord.submit(ScoreRequest {
                variant: variant.clone(),
                tokens: w.clone(),
                ia_bits,
                w_bits,
            })
        })
        .collect::<Result<_>>()?;
    let results: Vec<_> = handles.into_iter().map(|h| h.wait()).collect::<Result<_>>()?;
    let wall = t0.elapsed();
    let pairs: Vec<(f32, f32)> = results.iter().map(|r| (r.nll, r.count)).collect();
    let tokens: f32 = pairs.iter().map(|(_, c)| c).sum();
    println!(
        "\n{} requests in {:.2?}  ({:.1} req/s, {:.0} tok/s)  ppl={:.4}",
        n_requests,
        wall,
        n_requests as f64 / wall.as_secs_f64(),
        tokens as f64 / wall.as_secs_f64(),
        perplexity(&pairs)
    );
    println!("\n{}", coord.metrics().render());
    coord.shutdown();
    Ok(())
}

fn cmd_eval(args: &[String]) -> Result<()> {
    let p = Cli::new("muxq eval", "one-off perplexity evaluation")
        .opt("model", "sim-small", "model name")
        .opt("method", "muxq", "fp16|naive|muxq|llmint8")
        .opt("granularity", "per-tensor", "per-tensor|per-vector")
        .opt("smooth", "false", "apply SmoothQuant migration (true|false)")
        .opt("ia-bits", "8", "activation bits")
        .opt("w-bits", "8", "weight bits")
        .opt("windows", "16", "eval windows (0 = full valid split)")
        .parse(args)?;
    let g = if p.get("granularity") == "per-vector" { "pv" } else { "pt" };
    let s = if p.get("smooth") == "true" { "-sq" } else { "" };
    let tag = if p.get("method") == "fp16" {
        "fp16-pt".to_string()
    } else {
        format!("{}-{g}{s}", p.get("method"))
    };
    let variant = VariantKey::eval(p.get("model"), &tag);

    let registry = muxq::coordinator::VariantRegistry::open_default()?;
    let Some(meta) = registry.meta(&variant) else {
        bail!("variant {variant:?} not found; run `muxq variants`");
    };
    let (batch, seq) = (meta.batch, meta.seq);
    let eval = EvalSet::load(&muxq::artifacts_dir(), "valid")?;
    let windows = eval.windows(seq, p.get_usize("windows")?);
    if windows.is_empty() {
        bail!("no eval windows");
    }
    let compiled = registry.get(&variant)?;
    let mut pairs = Vec::new();
    let t0 = Instant::now();
    for chunk in windows.chunks(batch) {
        let mut toks = Vec::with_capacity(batch * seq);
        for w in chunk {
            toks.extend_from_slice(w);
        }
        for _ in chunk.len()..batch {
            toks.extend_from_slice(&windows[0]); // pad
        }
        let out = compiled.run(
            &toks,
            p.get_f64("ia-bits")? as f32,
            p.get_f64("w-bits")? as f32,
        )?;
        let nll = &out[0].data;
        let count = &out[1].data;
        for i in 0..chunk.len() {
            pairs.push((nll[i], count[i]));
        }
    }
    println!(
        "{} [{}] ia={} w={}: ppl = {:.4}  ({} windows, {:.2?})",
        p.get("model"),
        tag,
        p.get("ia-bits"),
        p.get("w-bits"),
        perplexity(&pairs),
        pairs.len(),
        t0.elapsed()
    );
    Ok(())
}

fn cmd_variants() -> Result<()> {
    let manifest = muxq::coordinator::variants::Manifest::load(&muxq::artifacts_dir())?;
    println!(
        "{:<12} {:<8} {:<16} {:<10} {:<12} smooth",
        "model", "kind", "tag", "method", "granularity"
    );
    for key in manifest.keys() {
        let m = manifest.meta(&key).unwrap();
        println!(
            "{:<12} {:<8} {:<16} {:<10} {:<12} {}",
            key.model, key.kind, key.tag, m.method, m.granularity, m.smooth
        );
    }
    Ok(())
}

fn cmd_npusim() -> Result<()> {
    use muxq::npusim::report::{compare, paper_geometries, render_table, sim_geometries};
    use muxq::npusim::NpuConfig;
    let cfg = NpuConfig::default();
    println!("== NPU cost model: paper GPT-2 geometries (batch*seq=1024 tokens) ==");
    let mut rows = Vec::new();
    for (name, g) in paper_geometries() {
        rows.extend(compare(&cfg, name, g, 8));
    }
    println!("{}", render_table(&rows));
    println!("== sim models shipped in artifacts/ ==");
    let mut rows = Vec::new();
    for (name, g) in sim_geometries() {
        rows.extend(compare(&cfg, name, g, 8));
    }
    println!("{}", render_table(&rows));
    Ok(())
}
