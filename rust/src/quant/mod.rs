//! Rust-native quantization engine — the twin of the python/jax reference
//! (`python/compile/kernels/ref.py`), cross-validated against
//! `artifacts/goldens/quant.bin`. Architecture context: DESIGN.md §3–§4.
//!
//! Modules:
//! * [`matrix`] — dense f32/i8/i32 matrices + IEEE rint
//! * [`absmax`] — symmetric abs-max quantization at all granularities
//! * [`gemm`] — blocked f32 and i8→i32 GEMMs, quantize-compute-dequant
//! * [`packed`] — packed-weight parallel INT8 engine (the i8 hot path:
//!   i16 pair-accumulation microkernel, shape-aware MR×NR tiles)
//! * [`muxq`] — the paper's outlier decomposition + uniform-INT two-GEMM
//! * [`llmint8`] — the mixed-precision baseline
//! * [`group`] — per-group scales (the overhead the paper declines to pay)
//! * [`smooth`] — SmoothQuant migration (composable with MUXQ)
//! * [`method`] — unified method dispatch used by examples/benches
//!
//! # Which method routes through which kernel
//!
//! | method | INT pipeline | kernels on the hot path |
//! |---|---|---|
//! | naive abs-max | [`gemm::quant_matmul`] | [`gemm::matmul_i8`] → packed engine for large shapes (pack-on-the-fly), cache-blocked fallback for tiny ones |
//! | MUXQ | [`muxq::muxq_matmul_int`] | Body: [`packed::matmul_i8_packed_into`]; Aux: [`packed::matmul_i8_rows_subset_into`] reading outlier rows out of the ONE packed W (per-col weight scales; other granularities gather + [`gemm::matmul_i8`]) |
//! | LLM.int8() | [`llmint8::llmint8_matmul`] | normal channels [`gemm::matmul_i8`], outlier columns [`gemm::matmul_f32`] (the FP16 stand-in) + gather/scatter |
//! | SmoothQuant | transform only | rescales X and W, then any of the above runs unchanged |
//! | per-group | fake-quant only | no INT GEMM route — scale storage/rescale overhead is the point under test |
//! | any, M ≤ [`packed::TileConfig::gemv_max_m`] (decode steps) | same entry points | [`packed::matmul_i8_gemv_into`] / the rows-subset GEMV twin — A row streamed in place, no tile cascade, pair accumulation kept; auto-routed inside both `_into` entries |
//!
//! The deployment path ([`crate::gpt2::QuantizedGpt2::nll_per_seq`])
//! uses the same packed kernels with weights packed once at load time;
//! the incremental-decode path (`crate::gpt2::session`) runs its
//! per-token projections through the skinny GEMV route.

pub mod absmax;
pub mod gemm;
pub mod group;
pub mod llmint8;
pub mod matrix;
pub mod method;
pub mod muxq;
pub mod packed;
pub mod smooth;

pub use absmax::{fq_naive, qmax_from_bits, Granularity, Scales};
pub use matrix::{MatF32, MatI32, MatI8};
pub use method::{Method, QuantSpec};
pub use muxq::MuxqParams;
pub use packed::{PackedMatI8, ParallelGemm};
