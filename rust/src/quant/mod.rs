//! Rust-native quantization engine — the twin of the python/jax reference
//! (`python/compile/kernels/ref.py`), cross-validated against
//! `artifacts/goldens/quant.bin`.
//!
//! Modules:
//! * [`matrix`] — dense f32/i8/i32 matrices + IEEE rint
//! * [`absmax`] — symmetric abs-max quantization at all granularities
//! * [`gemm`] — blocked f32 and i8→i32 GEMMs, quantize-compute-dequant
//! * [`packed`] — packed-weight parallel INT8 engine (the i8 hot path)
//! * [`muxq`] — the paper's outlier decomposition + uniform-INT two-GEMM
//! * [`llmint8`] — the mixed-precision baseline
//! * [`smooth`] — SmoothQuant migration (composable with MUXQ)
//! * [`method`] — unified method dispatch used by examples/benches

pub mod absmax;
pub mod gemm;
pub mod group;
pub mod llmint8;
pub mod matrix;
pub mod method;
pub mod muxq;
pub mod packed;
pub mod smooth;

pub use absmax::{fq_naive, qmax_from_bits, Granularity, Scales};
pub use matrix::{MatF32, MatI32, MatI8};
pub use method::{Method, QuantSpec};
pub use muxq::MuxqParams;
pub use packed::{PackedMatI8, ParallelGemm};
