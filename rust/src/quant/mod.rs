//! Rust-native quantization engine — the twin of the python/jax reference
//! (`python/compile/kernels/ref.py`), cross-validated against
//! `artifacts/goldens/quant.bin`. Architecture context: DESIGN.md §3–§4;
//! the operator API that fronts all of it is DESIGN.md §3a.
//!
//! Modules:
//! * [`matrix`] — dense f32/i8/i32 matrices + IEEE rint
//! * [`absmax`] — symmetric abs-max quantization at all granularities
//! * [`gemm`] — blocked f32 and i8→i32 GEMMs, quantize-compute-dequant
//! * [`packed`] — packed-weight parallel INT engine (the i8 hot path:
//!   i16 pair-accumulation microkernel, shape-aware MR×NR tiles; plus
//!   the nibble-packed W4 panel format `PackedMatI4` with
//!   unpack-in-register microkernels, DESIGN.md §4a)
//! * [`simd`] — per-arch SIMD microkernels (AVX2 `pmaddwd` / NEON
//!   `sdot`-`smlal`) + the one-time runtime dispatcher
//!   (`MUXQ_FORCE_KERNEL` override) the packed engine routes through
//! * [`linear`] — **the unified operator API**: [`QuantLinear`] trait +
//!   [`EngineSpec`] builder, one pluggable projection object per method
//!   from the packed kernels up to the generation server
//! * [`muxq`] — the paper's outlier decomposition + uniform-INT two-GEMM
//! * [`llmint8`] — the mixed-precision baseline
//! * [`group`] — per-group scales (the overhead the paper declines to pay)
//! * [`smooth`] — SmoothQuant migration (composable with MUXQ)
//! * [`transform`] — the composable pack-time [`PreTransform`] pipeline
//!   (smooth / DuQuant-style blockwise rotation / zigzag permutation)
//!   every operator folds into its weight and applies to activations
//! * [`method`] — method naming + the fake-quant evaluation spec
//!
//! # Which trait impl routes through which kernel
//!
//! Every deployed projection is a [`linear::QuantLinear`] object built by
//! [`linear::EngineSpec::pack`] — weights quantized AND packed once at
//! load time. `forward_into` is the batch path, `forward_row_into` the
//! row-independent session path; both auto-route M ≤
//! [`packed::TileConfig::gemv_max_m`] (the decode regime) to the GEMV
//! kernels.
//!
//! | trait impl (spec tag) | batch `forward_into` | kernels on the hot path |
//! |---|---|---|
//! | any, session multi-row (`forward_rows_into`) | prefill rows + the speculative k-row verify (`gpt2::speculative`) | per-row loop over `forward_row_into`; `MuxqLinear` coalesces consecutive rows sharing an outlier mask into one body GEMM (PerRow act scales ⇒ bit-identical to the loop) |
//! | `Fp32Linear` (`fp16-*`) | plain GEMM + bias | [`gemm::matmul_f32`] (f32 stands in for FP16) |
//! | `NaiveLinear` (`naive-*`) | per-row/tensor abs-max quantize → one INT GEMM | [`packed::matmul_i8_packed_into`] |
//! | `MuxqLinear` (`muxq-*`) | fused decompose+quantize → Body GEMM + skinny Aux | Body: [`packed::matmul_i8_packed_into`]; Aux: [`packed::matmul_i8_rows_subset_into`] reading outlier rows out of the ONE packed W |
//! | `LlmInt8Linear` (`llmint8-*`) | masked quantize → INT GEMM + resident-FP outlier leg | normal channels [`packed::matmul_i8_packed_into`]; outlier columns [`gemm::matmul_f32_rows_gathered_acc`] (blocked gathered-rows accumulation) over the operator's resident FP copy |
//! | `NaiveLinear` (`naive-*-w4a8`) | same as `naive-*`, nibble-packed W4 body | [`packed::matmul_i8w4_packed_into`] — unpack-in-register nibble microkernels, half the weight bytes streamed per token |
//! | `MuxqLinear` (`muxq-*-w4a8`) | same as `muxq-*`, W4 body AND W4 aux against the ONE nibble-packed W | Body: [`packed::matmul_i8w4_packed_into`]; Aux: [`packed::matmul_i8w4_rows_subset_into`] |
//! | `ResqLinear` (`resq-*`) | W4 body GEMM + static rank-r FP residual leg | body [`packed::matmul_i8w4_packed_into`]; residual [`gemm::matmul_f32_rows_gathered_acc`] over a compact `[rank, n]` residual (no resident full FP copy) |
//! | any, smoothed (`*-sq`) | X/s pre-divide, s⊙W folded in at pack time | same kernels as the unsmoothed impl — composition is a pre-transform, not a route |
//! | any, rotated (`*-rot`) | blockwise `x·Rᵀ` pre-GEMM, `R·W` folded in at pack time | same kernels; the rotate itself is a k×[`transform::ROT_BLOCK`] f32 sliver per row ([`transform::BlockRot::apply_to_row`]), priced by npusim as one extra skinny FP GEMM |
//! | any, permuted (`*-perm`) | channel gather `x[perm]` pre-quantize, W rows reordered at pack time | same kernels — a permutation never touches the contraction, only the operand layout |
//! | any composition (`*-sq-rot-perm`, any order) | the ordered [`transform::ActPipeline`] at the two staging seams | transforms stack; the tag spells pipeline order because order is observable |
//!
//! Inside the packed engine every INT contraction above (dense tile,
//! rows-subset Aux, skinny-M GEMV) resolves its microkernel through the
//! one-time [`simd::dispatch`]:
//!
//! | dispatch (`MUXQ_FORCE_KERNEL`) | microkernel | MACs/lane/step |
//! |---|---|---|
//! | `avx2` (x86-64 default) | `simd/avx2.rs`: `pmaddwd` i16 pairs, i32 pair sums | 2 |
//! | `neon` (aarch64 default) | `simd/neon.rs`: `sdot` quads (`dotprod` hosts) or `smlal` pairs | 4 / 2 |
//! | `pair` (portable default) | scalar i16 pair kernel (−128-in-B → wide fallback) | 2 |
//! | `scalar` | scalar wide-i32 (the PR-1 scheme, exact ∀ inputs) | 1 |
//!
//! Outside the operator API: [`gemm::quant_matmul`] /
//! [`muxq::muxq_matmul_int`] / [`llmint8::llmint8_matmul`] remain as the
//! self-contained (quantize-W-per-call) reference pipelines the
//! equivalence tests pin the operators against, and [`group`] stays
//! fake-quant only (no INT route — the scale-storage overhead is the
//! point under test).
//!
//! The deployment path ([`crate::gpt2::QuantizedGpt2`]) holds one boxed
//! operator per projection site; the incremental-decode path
//! (`crate::gpt2::session`) and the `GenerationServer` run the same
//! objects through `forward_row_into`.

pub mod absmax;
pub mod gemm;
pub mod group;
pub mod linear;
pub mod llmint8;
pub mod matrix;
pub mod method;
pub mod muxq;
pub mod packed;
pub mod simd;
pub mod smooth;
pub mod transform;

pub use absmax::{fq_naive, qmax_from_bits, Granularity, Scales};
pub use linear::{EngineSpec, QuantLinear};
pub use matrix::{MatF32, MatI32, MatI8};
pub use method::{Method, QuantSpec};
pub use muxq::MuxqParams;
pub use packed::{PackedMatI4, PackedMatI8, ParallelGemm};
pub use transform::{PermuteKind, PreTransform};
