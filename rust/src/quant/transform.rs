//! Composable pack-time pre-transforms — the `PreTransform` pipeline.
//!
//! MUXQ's outlier decomposition, SmoothQuant's difficulty migration,
//! DuQuant's blockwise rotations (arXiv:2406.01721) and its zigzag
//! channel permutation are all instances of ONE algebraic move: rewrite
//! `y = x·W` as `y = (x·T⁻¹)·(T·W)` for an invertible `T` on the input
//! (k) dimension, fold `T·W` into the weight at pack time, and apply
//! `x·T⁻¹` to every activation before quantization. Each
//! [`PreTransform`] variant contributes one such `T`:
//!
//! * `Smooth{alpha}` — `T = diag(s)`, `s_j = amax_j^α / wmax_j^(1−α)`
//!   (`smooth::smooth_scales`): weight rows scale up, activations divide
//!   down. The inverse is an elementwise divide.
//! * `Rotate{block}` — `T = R`, block-diagonal orthogonal (seeded,
//!   deterministic). `R·Rᵀ = I` so the inverse is the transpose: the
//!   activation side applies `x·Rᵀ`, which spreads an outlier channel's
//!   magnitude across its whole block (the DuQuant observation: rotated
//!   distributions are closer to Gaussian, so abs-max grids waste fewer
//!   levels on a single spike).
//! * `Permute{Zigzag}` — `T = P`, a channel permutation dealing the
//!   calibration-ranked channels serpentine-wise across rotation blocks
//!   so no block hoards the hot channels. Exact (a reordering of the
//!   same products).
//!
//! Transforms COMPOSE IN ORDER: `pre = [T1, T2]` packs `T2·(T1·W)` and
//! the activation path applies T1's inverse then T2's — the pipeline is
//! ordered, and order is observable (rotating then smoothing calibrates
//! the smooth scales in the rotated basis, and vice versa), which is why
//! the tag grammar spells the pipeline out in order (`-sq-rot` vs
//! `-rot-sq`).
//!
//! At pack time each stage also rewrites the calibration abs-max vector
//! so the NEXT stage (and ResQ's calibrated rank selection) sees the
//! activation statistics of its own input space: smooth divides it,
//! permute reorders it, rotate propagates an RMS estimate
//! `amax'_j = sqrt(Σ_i R_{ji}² · amax_i²)` (rows of `R` have unit norm,
//! so a flat vector stays flat and a spike spreads across its block).
//!
//! The activation side is compiled into an [`ActPipeline`] applied at
//! exactly two seams — `IntScratch::stage_row` (the decode row path) and
//! `transformed` (the batch path) in `quant::linear` — through the same
//! per-row slice arithmetic, which is what keeps the row/batch
//! bit-exactness contract intact for every composition.

use super::matrix::MatF32;

/// Default rotation / permutation block width (DuQuant uses small
/// power-of-two blocks; 16 divides every projection width in this repo
/// and keeps the per-call rotate GEMM a k×16 sliver). Not encoded in
/// tags — `-rot` always means this block, like `-sq` always means
/// alpha 0.5.
pub const ROT_BLOCK: usize = 16;

/// How a `Permute` pre-transform orders channels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PermuteKind {
    /// Rank channels by calibration abs-max, deal them serpentine-wise
    /// across the [`ROT_BLOCK`]-sized groups (DuQuant §4.3): every
    /// block receives an even share of hot channels.
    Zigzag,
}

/// One pack-time pre-transform — see the module docs for the algebra.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PreTransform {
    /// SmoothQuant difficulty migration with strength `alpha`.
    Smooth { alpha: f32 },
    /// Blockwise orthogonal rotation with the given block width.
    Rotate { block: usize },
    /// Channel permutation.
    Permute { kind: PermuteKind },
}

impl PreTransform {
    /// The tag suffix this transform is spelled as (`-sq`, `-rot`,
    /// `-perm`) — parameters are not encoded, exactly like the smooth
    /// alpha before the pipeline existed.
    pub fn tag_suffix(&self) -> &'static str {
        match self {
            PreTransform::Smooth { .. } => "-sq",
            PreTransform::Rotate { .. } => "-rot",
            PreTransform::Permute { .. } => "-perm",
        }
    }
}

// ------------------------------------------------------------ rotation

/// A block-diagonal orthogonal rotation on the k dimension: one dense
/// `b×b` orthogonal factor per block (the last block shrinks when
/// `dim % block != 0`). Stored row-major per block; both the weight
/// fold (`R·W`) and the activation side (`x·Rᵀ`) contract against R's
/// ROWS, so one layout serves both.
#[derive(Debug, Clone)]
pub struct BlockRot {
    pub dim: usize,
    pub block: usize,
    /// per-block row-major `b_i × b_i` factors, `Σ b_i = dim`
    blocks: Vec<MatF32>,
}

/// Deterministic xorshift64* stream for rotation construction — the
/// rotation must be a pure function of `(dim, block)` so every pack of
/// the same spec (across processes, across the weight/activation sides)
/// builds the identical matrix.
fn xorshift64(state: &mut u64) -> u64 {
    let mut x = *state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *state = x;
    x
}

/// Uniform in (-1, 1), f64 for the orthonormalization.
fn next_unit(state: &mut u64) -> f64 {
    // 53 mantissa bits of the stream → [0, 1), shifted to (-1, 1)
    (xorshift64(state) >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0
}

impl BlockRot {
    /// Build the seeded random orthogonal factors: fill each block with
    /// uniform noise and orthonormalize with two passes of modified
    /// Gram–Schmidt in f64 (the second pass scrubs the first's rounding,
    /// leaving `R·Rᵀ = I` to well under f32 resolution), then round to
    /// f32. Degenerate draws (a row landing in the span of the previous
    /// rows) are resolved by re-seeding that row from the stream — with
    /// 53-bit draws this is a practically-never branch, kept so the
    /// construction is total.
    pub fn build(dim: usize, block: usize) -> BlockRot {
        assert!(block > 0, "rotation block must be positive");
        let mut blocks = Vec::new();
        let mut start = 0usize;
        let mut bi = 0u64;
        while start < dim {
            let b = block.min(dim - start);
            // seed mixes dim, block index and block width so distinct
            // sites never share a factor by accident
            let mut state = 0x9E37_79B9_7F4A_7C15u64
                ^ (dim as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)
                ^ bi.wrapping_mul(0x94D0_49BB_1331_11EB)
                ^ (b as u64);
            // never let the stream start at 0 (xorshift fixed point)
            if state == 0 {
                state = 1;
            }
            let mut rows: Vec<Vec<f64>> = (0..b)
                .map(|_| (0..b).map(|_| next_unit(&mut state)).collect())
                .collect();
            // two rounds of modified Gram–Schmidt
            for _round in 0..2 {
                for i in 0..b {
                    for j in 0..i {
                        let dot: f64 = (0..b).map(|c| rows[i][c] * rows[j][c]).sum();
                        for c in 0..b {
                            rows[i][c] -= dot * rows[j][c];
                        }
                    }
                    let mut norm: f64 = (0..b).map(|c| rows[i][c] * rows[i][c]).sum::<f64>().sqrt();
                    while norm < 1e-12 {
                        for c in 0..b {
                            rows[i][c] = next_unit(&mut state);
                        }
                        for j in 0..i {
                            let dot: f64 = (0..b).map(|c| rows[i][c] * rows[j][c]).sum();
                            for c in 0..b {
                                rows[i][c] -= dot * rows[j][c];
                            }
                        }
                        norm = (0..b).map(|c| rows[i][c] * rows[i][c]).sum::<f64>().sqrt();
                    }
                    for c in 0..b {
                        rows[i][c] /= norm;
                    }
                }
            }
            let mut m = MatF32::zeros(b, b);
            for i in 0..b {
                for c in 0..b {
                    *m.at_mut(i, c) = rows[i][c] as f32;
                }
            }
            blocks.push(m);
            start += b;
            bi += 1;
        }
        BlockRot { dim, block, blocks }
    }

    /// Apply to one activation row: `dst[j0+j] = Σ_i R[j][i]·src[j0+i]`
    /// per block — the `x·Rᵀ` side. `src` and `dst` must not alias.
    pub fn apply_to_row(&self, src: &[f32], dst: &mut [f32]) {
        debug_assert_eq!(src.len(), self.dim);
        debug_assert_eq!(dst.len(), self.dim);
        let mut j0 = 0usize;
        for m in &self.blocks {
            let b = m.rows;
            for j in 0..b {
                let rrow = m.row(j);
                let mut acc = 0.0f32;
                for i in 0..b {
                    acc += rrow[i] * src[j0 + i];
                }
                dst[j0 + j] = acc;
            }
            j0 += b;
        }
    }

    /// Fold into the weight at pack time: `W' = R·W`, i.e.
    /// `w'[j0+j][c] = Σ_i R[j][i]·w[j0+i][c]` per block.
    pub fn apply_to_weight(&self, w: &MatF32) -> MatF32 {
        assert_eq!(w.rows, self.dim, "rotation dim vs weight k");
        let n = w.cols;
        let mut out = MatF32::zeros(w.rows, n);
        let mut j0 = 0usize;
        for m in &self.blocks {
            let b = m.rows;
            for j in 0..b {
                let rrow = m.row(j);
                let orow = out.row_mut(j0 + j);
                for i in 0..b {
                    let rv = rrow[i];
                    for (ov, wv) in orow.iter_mut().zip(w.row(j0 + i)) {
                        *ov += rv * wv;
                    }
                }
            }
            j0 += b;
        }
        out
    }

    /// Propagate a per-channel abs-max estimate through the rotation:
    /// `amax'_j = sqrt(Σ_i R[j][i]²·amax_i²)` — an RMS bound that treats
    /// channels as independent. Unit-norm rows keep a flat vector flat
    /// and spread a spike across its block, which is all downstream
    /// stages (smooth scales, ResQ rank) need from it.
    pub fn amax_estimate(&self, amax: &[f32]) -> Vec<f32> {
        debug_assert_eq!(amax.len(), self.dim);
        let mut out = vec![0.0f32; self.dim];
        let mut j0 = 0usize;
        for m in &self.blocks {
            let b = m.rows;
            for j in 0..b {
                let rrow = m.row(j);
                let mut acc = 0.0f32;
                for i in 0..b {
                    let t = rrow[i] * amax[j0 + i];
                    acc += t * t;
                }
                out[j0 + j] = acc.sqrt();
            }
            j0 += b;
        }
        out
    }

    /// Deployed bytes of the rotation factors at 2 B/elem (the fp16 the
    /// f32 stands in for, same accounting as the LLM.int8() FP copy).
    pub fn bytes(&self) -> usize {
        self.blocks.iter().map(|m| m.data.len() * 2).sum()
    }
}

// --------------------------------------------------------- permutation

/// The zigzag channel order: rank channels by `amax` (descending,
/// index-ascending tiebreak — fully deterministic), deal them into
/// `ceil(k/block)` groups serpentine-wise (group 0..G−1, then G−1..0,
/// …), concatenate the groups. Returns the new-to-old map `perm`:
/// position `j` of the permuted space holds old channel `perm[j]`.
pub fn zigzag_perm(amax: &[f32], block: usize) -> Vec<usize> {
    let k = amax.len();
    assert!(block > 0, "permutation block must be positive");
    let groups = k.div_ceil(block).max(1);
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| amax[b].total_cmp(&amax[a]).then(a.cmp(&b)));
    let mut bins: Vec<Vec<usize>> = vec![Vec::new(); groups];
    let mut g = 0usize;
    let mut dir = 1isize;
    for c in order {
        bins[g].push(c);
        if groups > 1 {
            if (g == groups - 1 && dir == 1) || (g == 0 && dir == -1) {
                dir = -dir;
            } else {
                g = (g as isize + dir) as usize;
            }
        }
    }
    bins.into_iter().flatten().collect()
}

/// Invert a permutation: `inv[p[j]] == j`.
pub fn invert_perm(p: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; p.len()];
    for (j, &src) in p.iter().enumerate() {
        inv[src] = j;
    }
    inv
}

// ------------------------------------------------- activation pipeline

/// One compiled activation-side step — the inverse/absorbed factor a
/// [`PreTransform`] contributed at pack time.
#[derive(Debug, Clone)]
pub enum ActStep {
    /// elementwise divide by the smooth scales (len k)
    Scale(Vec<f32>),
    /// gather `out[j] = x[perm[j]]` (the same reorder applied to W rows)
    Permute(Vec<usize>),
    /// blockwise `x·Rᵀ`
    Rotate(BlockRot),
}

/// The ordered activation-side pipeline an operator applies to every
/// incoming row before quantization — empty for a bare spec, one
/// `Scale` for classic `-sq`, arbitrary compositions for the full
/// grammar. Applied through [`ActPipeline::apply_row`] at both the
/// batch and the single-row seams of `quant::linear`, with identical
/// per-element arithmetic (the row/batch bit-exactness contract).
#[derive(Debug, Clone, Default)]
pub struct ActPipeline {
    steps: Vec<ActStep>,
}

impl ActPipeline {
    pub fn empty() -> ActPipeline {
        ActPipeline { steps: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn push(&mut self, step: ActStep) {
        self.steps.push(step);
    }

    pub fn steps(&self) -> &[ActStep] {
        &self.steps
    }

    /// Apply the pipeline to one activation row in place. `tmp` is
    /// caller-provided staging (the scratch pool's, on the hot path) so
    /// the steady state allocates nothing; `Scale` runs in place,
    /// `Permute`/`Rotate` stage through `tmp` and copy back.
    pub fn apply_row(&self, row: &mut [f32], tmp: &mut Vec<f32>) {
        for step in &self.steps {
            match step {
                ActStep::Scale(s) => {
                    debug_assert_eq!(s.len(), row.len());
                    for (v, sv) in row.iter_mut().zip(s) {
                        *v /= sv;
                    }
                }
                ActStep::Permute(p) => {
                    debug_assert_eq!(p.len(), row.len());
                    tmp.clear();
                    tmp.extend(p.iter().map(|&src| row[src]));
                    row.copy_from_slice(tmp);
                }
                ActStep::Rotate(rot) => {
                    tmp.clear();
                    tmp.resize(row.len(), 0.0);
                    rot.apply_to_row(row, tmp);
                    row.copy_from_slice(tmp);
                }
            }
        }
    }

    /// Deployed bytes of the pipeline state (`bytes()` honesty): scales
    /// at 4 B, permutation indices at 4 B (u32-sized, like the ResQ row
    /// index list), rotation factors per [`BlockRot::bytes`].
    pub fn bytes(&self) -> usize {
        self.steps
            .iter()
            .map(|s| match s {
                ActStep::Scale(v) => v.len() * 4,
                ActStep::Permute(p) => p.len() * 4,
                ActStep::Rotate(r) => r.bytes(),
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_rot_is_orthogonal_and_deterministic() {
        for (dim, block) in [(16usize, 16usize), (48, 16), (20, 16), (7, 16), (64, 8)] {
            let rot = BlockRot::build(dim, block);
            let rot2 = BlockRot::build(dim, block);
            let mut j0 = 0;
            for (bi, m) in rot.blocks.iter().enumerate() {
                let b = m.rows;
                assert_eq!(m.data, rot2.blocks[bi].data, "deterministic");
                for i in 0..b {
                    for j in 0..b {
                        let dot: f32 = (0..b).map(|c| m.at(i, c) * m.at(j, c)).sum();
                        let want = if i == j { 1.0 } else { 0.0 };
                        assert!(
                            (dot - want).abs() < 1e-4,
                            "R·Rᵀ[{i},{j}] = {dot} (dim {dim} block at {j0})"
                        );
                    }
                }
                j0 += b;
            }
            assert_eq!(j0, dim, "blocks tile the dimension");
        }
    }

    #[test]
    fn rotate_row_then_transpose_recovers_input() {
        // x·Rᵀ·R == x to f32 tolerance — the function-preservation the
        // pack-time fold relies on (exact orthogonality lives in f64)
        let rot = BlockRot::build(32, 16);
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let mut xr = vec![0.0f32; 32];
        rot.apply_to_row(&x, &mut xr);
        // applying R (not Rᵀ) to the rotated row: Σ_j R[j][i]·xr[j] per i
        let mut back = vec![0.0f32; 32];
        let mut j0 = 0;
        for m in &rot.blocks {
            let b = m.rows;
            for i in 0..b {
                let mut acc = 0.0f32;
                for j in 0..b {
                    acc += m.at(j, i) * xr[j0 + j];
                }
                back[j0 + i] = acc;
            }
            j0 += b;
        }
        for (bv, xv) in back.iter().zip(&x) {
            assert!((bv - xv).abs() < 1e-4, "{bv} vs {xv}");
        }
    }

    #[test]
    fn zigzag_deals_hot_channels_across_blocks() {
        // 32 channels, the 4 hottest at the front: after the zigzag each
        // 16-wide block must hold exactly 2 of them
        let mut amax = vec![1.0f32; 32];
        for c in 0..4 {
            amax[c] = 100.0 + c as f32;
        }
        let p = zigzag_perm(&amax, 16);
        let mut seen = p.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..32).collect::<Vec<_>>(), "a permutation");
        for blk in 0..2 {
            let hot = p[blk * 16..(blk + 1) * 16].iter().filter(|&&c| c < 4).count();
            assert_eq!(hot, 2, "block {blk} hot-channel share");
        }
        let inv = invert_perm(&p);
        for j in 0..32 {
            assert_eq!(inv[p[j]], j);
        }
    }

    #[test]
    fn permute_step_round_trips_bit_exact() {
        // permute then inverse-permute is the identity BIT FOR BIT — a
        // permutation only moves values
        let amax: Vec<f32> = (0..24).map(|i| ((i * 7) % 11) as f32).collect();
        let p = zigzag_perm(&amax, 16);
        let x: Vec<f32> = (0..24).map(|i| (i as f32 * 1.37).cos() * 9.0).collect();
        let mut pipe = ActPipeline::empty();
        pipe.push(ActStep::Permute(p.clone()));
        pipe.push(ActStep::Permute(invert_perm(&p)));
        let mut row = x.clone();
        let mut tmp = Vec::new();
        pipe.apply_row(&mut row, &mut tmp);
        assert_eq!(row, x);
    }

    #[test]
    fn pipeline_applies_in_order() {
        // Scale-then-Permute and Permute-then-Scale differ whenever the
        // scales are non-uniform — pins that apply_row honours order
        let s = vec![2.0f32, 4.0, 8.0, 16.0];
        let p = vec![3usize, 2, 1, 0];
        let x = vec![16.0f32, 16.0, 16.0, 16.0];
        let mut tmp = Vec::new();
        let mut a = ActPipeline::empty();
        a.push(ActStep::Scale(s.clone()));
        a.push(ActStep::Permute(p.clone()));
        let mut ra = x.clone();
        a.apply_row(&mut ra, &mut tmp);
        let mut b = ActPipeline::empty();
        b.push(ActStep::Permute(p));
        b.push(ActStep::Scale(s));
        let mut rb = x.clone();
        b.apply_row(&mut rb, &mut tmp);
        assert_eq!(ra, vec![1.0, 2.0, 4.0, 8.0]);
        assert_eq!(rb, vec![8.0, 4.0, 2.0, 1.0]);
    }

    #[test]
    fn rotation_flattens_a_spike() {
        // the point of the rotation: a single huge channel's magnitude
        // spreads across its block, dropping the row abs-max by roughly
        // sqrt(block) — the headroom the abs-max grid gets back
        let rot = BlockRot::build(16, 16);
        let mut x = vec![0.1f32; 16];
        x[3] = 64.0;
        let mut xr = vec![0.0f32; 16];
        rot.apply_to_row(&x, &mut xr);
        let before = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let after = xr.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(after < before * 0.75, "spike must spread: {after} vs {before}");
        // energy is preserved (orthogonality), so the mass moved, not
        // vanished
        let e0: f32 = x.iter().map(|v| v * v).sum();
        let e1: f32 = xr.iter().map(|v| v * v).sum();
        assert!((e0 - e1).abs() / e0 < 1e-3, "energy {e0} vs {e1}");
    }
}
