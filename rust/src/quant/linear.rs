//! The unified linear-operator API: ONE pluggable projection trait from
//! the packed kernels up to the generation server.
//!
//! Before this module the repo carried two disjoint method type systems:
//! the fake-quant evaluation dispatch (`Method`/`QuantSpec`) and a
//! hard-wired `IntMethod { Naive, Muxq }` inside the deployed pipeline —
//! so LLM.int8() and SmoothQuant-composed MUXQ, both central to the
//! paper's Table 1 comparison, could never reach the packed engine, the
//! KV-cache sessions or the `GenerationServer`. Now every method is an
//! object implementing [`QuantLinear`]:
//!
//! * **pack once** — [`EngineSpec::pack`] quantizes + packs the weight at
//!   load time (the zero-copy story of `gpt2::quantized` is preserved:
//!   per-method scratch lives *behind* the operator, the only steady-state
//!   per-call allocation is the output matrix);
//! * **`forward_into`** — the batch GEMM path (one outlier mask per call
//!   where the method has one — the batching semantics);
//! * **`forward_row_into`** — the row-independent session/GEMV path (one
//!   mask per row; M=1 operands auto-route to the packed engine's GEMV
//!   kernels), the semantics decode bit-exactness is built on;
//! * **`bytes`** — honest deployed-memory accounting (LLM.int8() pays for
//!   its resident FP copy, the cost MUXQ's uniform-INT design removes);
//! * **`plan`** — the npusim execution plan of one call, so simulated
//!   hardware pricing flows from the same object that runs on the host.
//!
//! [`EngineSpec`] is the builder that owns method, bits, granularity,
//! [`MuxqParams`] and the ordered [`PreTransform`] pipeline (SmoothQuant
//! scaling, DuQuant-style blockwise rotation, zigzag channel
//! permutation — `quant::transform` owns the algebra), replacing both
//! the old `QuantSpec::matmul` dispatch and `IntMethod`. Its canonical
//! `tag()` / [`EngineSpec::parse`] round-trip is the single spelling of a
//! variant ("muxq-pt-sq", "naive-pv-rot-perm-w4a8", "resq-pv-r8", …)
//! shared with the python build's manifest
//! (`python/compile/config.py QuantConfig.tag`); pre-transform suffixes
//! appear in pipeline order because composition order is observable.
//!
//! Bit-exactness contract: the Naive and MUXQ operators reproduce the
//! pre-redesign `QuantizedGpt2::proj_int` / `proj_session` arithmetic
//! bit for bit (pinned by `tests/quant_linear.rs` against independently
//! reconstructed oracles); new capabilities (LLM.int8() deployment,
//! SmoothQuant composition, per-tensor deployment) are tolerance-tested
//! against their fake-quant oracles.

use super::absmax::{Granularity, Scales, EPS};
use super::gemm::matmul_f32;
use super::matrix::{rint, MatF32, MatI32, MatI8};
use super::method::Method;
use super::muxq::{outlier_mask_into, MuxqParams};
use super::packed::{self, PackedMatI4, PackedMatI8, ParallelGemm};
use super::transform::{
    zigzag_perm, ActPipeline, ActStep, BlockRot, PermuteKind, PreTransform, ROT_BLOCK,
};
use crate::npusim::gemm_plan::Plan;
use crate::npusim::NpuConfig;
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::fmt;

// ---------------------------------------------------------------- spec

/// Full specification of a deployable linear-operator engine: which
/// method, at which bit-widths and granularities, with which MUXQ
/// hyper-parameters, composed with an ordered pack-time
/// [`PreTransform`] pipeline (SmoothQuant scaling, DuQuant-style
/// blockwise rotation, zigzag channel permutation — in any order). The
/// builder half of the [`QuantLinear`] API — `spec.pack(w, bias)`
/// yields the operator object.
#[derive(Debug, Clone)]
pub struct EngineSpec {
    pub method: Method,
    /// activation granularity (PerRow = per-token, the deployment default)
    pub act_gran: Granularity,
    /// weight granularity (PerCol = per-out-channel, the deployment default)
    pub w_gran: Granularity,
    pub ia_bits: u32,
    pub w_bits: u32,
    /// outlier threshold + exponent shift (also LLM.int8()'s theta)
    pub muxq: MuxqParams,
    /// the ORDERED pack-time pre-transform pipeline; empty = none.
    /// Each entry rewrites `(W, calib)` at pack time and contributes
    /// its activation-side inverse to the operator (`quant::transform`
    /// has the algebra). The old `smooth_alpha: Option<f32>` field is
    /// the one-element `[Smooth{alpha}]` pipeline.
    pub pre: Vec<PreTransform>,
    /// ResQ residual rank override (`-r{N}`); `None` = chosen at pack
    /// time (calibrated energy threshold, or the k/16 heuristic when
    /// packing uncalibrated)
    pub resid_rank: Option<usize>,
}

impl EngineSpec {
    /// Deployment defaults: per-token activations, per-out-channel
    /// weights, the method's default bit-widths
    /// ([`EngineSpec::default_bits`]), default MUXQ params, an empty
    /// pre-transform pipeline.
    pub fn new(method: Method) -> EngineSpec {
        let (ia_bits, w_bits) = EngineSpec::default_bits(method);
        EngineSpec {
            method,
            act_gran: Granularity::PerRow,
            w_gran: Granularity::PerCol,
            ia_bits,
            w_bits,
            muxq: MuxqParams::default(),
            pre: Vec::new(),
            resid_rank: None,
        }
    }

    /// Per-method default `(ia_bits, w_bits)`: 8/8 everywhere except
    /// ResQ, whose whole point is the nibble-packed W4 body (8/4). The
    /// tag grammar encodes bit-widths only when they differ from these
    /// defaults, so `naive-pv` still means W8A8 and bare `resq-pv`
    /// already means W4A8.
    pub fn default_bits(method: Method) -> (u32, u32) {
        match method {
            Method::Resq => (8, 4),
            _ => (8, 8),
        }
    }

    pub fn fp16() -> EngineSpec {
        EngineSpec::new(Method::Fp16)
    }

    pub fn naive() -> EngineSpec {
        EngineSpec::new(Method::Naive)
    }

    pub fn muxq() -> EngineSpec {
        EngineSpec::new(Method::Muxq)
    }

    pub fn llmint8() -> EngineSpec {
        EngineSpec::new(Method::LlmInt8)
    }

    /// ResQ-style W4 + rank-r FP residual; defaults to W4A8
    /// ([`EngineSpec::default_bits`]).
    pub fn resq() -> EngineSpec {
        EngineSpec::new(Method::Resq)
    }

    pub fn with_bits(mut self, ia_bits: u32, w_bits: u32) -> EngineSpec {
        self.ia_bits = ia_bits;
        self.w_bits = w_bits;
        self
    }

    pub fn with_granularity(mut self, act: Granularity, w: Granularity) -> EngineSpec {
        self.act_gran = act;
        self.w_gran = w;
        self
    }

    pub fn with_muxq(mut self, p: MuxqParams) -> EngineSpec {
        self.muxq = p;
        self
    }

    /// Compose with SmoothQuant difficulty migration (paper contribution
    /// #2): at pack time the weight rows are scaled by `s` and every
    /// incoming activation is divided by `s` before quantization.
    /// Appends `Smooth{alpha}` to the pipeline — the pre-redesign
    /// `smooth_alpha` field spelled as a transform.
    pub fn with_smooth(self, alpha: f32) -> EngineSpec {
        self.with_pre(PreTransform::Smooth { alpha })
    }

    /// Compose with a DuQuant-style blockwise orthogonal rotation
    /// ([`super::transform::BlockRot`], block width [`ROT_BLOCK`]):
    /// `R·W` folded in at pack time, `x·Rᵀ` applied per activation row.
    pub fn with_rotate(self) -> EngineSpec {
        self.with_pre(PreTransform::Rotate { block: ROT_BLOCK })
    }

    /// Compose with the zigzag channel permutation (calibration-ranked
    /// channels dealt evenly across [`ROT_BLOCK`]-wide groups).
    pub fn with_permute(self) -> EngineSpec {
        self.with_pre(PreTransform::Permute { kind: PermuteKind::Zigzag })
    }

    /// Append one pre-transform to the pipeline (transforms compose in
    /// the order appended — order is observable, and the tag spells it).
    pub fn with_pre(mut self, t: PreTransform) -> EngineSpec {
        self.pre.push(t);
        self
    }

    /// Pin the ResQ residual rank (`-r{N}`) instead of letting pack
    /// time choose it.
    pub fn with_resid_rank(mut self, rank: usize) -> EngineSpec {
        self.resid_rank = Some(rank);
        self
    }

    /// First smooth stage's alpha, if the pipeline smooths — the
    /// back-compat query the manifest's `smooth` field maps to.
    pub fn smooth_alpha(&self) -> Option<f32> {
        self.pre.iter().find_map(|t| match t {
            PreTransform::Smooth { alpha } => Some(*alpha),
            _ => None,
        })
    }

    pub fn has_smooth(&self) -> bool {
        self.smooth_alpha().is_some()
    }

    pub fn has_rotate(&self) -> bool {
        self.pre.iter().any(|t| matches!(t, PreTransform::Rotate { .. }))
    }

    pub fn has_permute(&self) -> bool {
        self.pre.iter().any(|t| matches!(t, PreTransform::Permute { .. }))
    }

    pub fn ia_qmax(&self) -> f32 {
        super::absmax::qmax_from_bits(self.ia_bits)
    }

    pub fn w_qmax(&self) -> f32 {
        super::absmax::qmax_from_bits(self.w_bits)
    }

    /// The canonical variant tag — the ONE spelling shared by the python
    /// build manifest, the coordinator registry, and every example:
    /// `{method}-{pt|pv}[{-sq|-rot|-perm}…][-r{N}][-e{exp}][-w{W}a{A}]`.
    /// The pre-transform suffixes appear in PIPELINE ORDER (order is
    /// observable — `-sq-rot` calibrates the smooth in the unrotated
    /// basis, `-rot-sq` in the rotated one); parameters are not encoded
    /// (`-sq` is alpha 0.5, `-rot`/`-perm` use [`ROT_BLOCK`]). `-r{N}`
    /// pins the ResQ residual rank. The `-e` suffix only appears for
    /// MUXQ with a non-default `exp_factor`; the `-w{W}a{A}` bits
    /// suffix only when the widths differ from the method's defaults
    /// ([`EngineSpec::default_bits`]) — so `naive-pv-w4a8` is the
    /// nibble-packed W4A8 engine while `naive-pv` stays W8A8 and bare
    /// `resq-pv` already means W4A8.
    pub fn tag(&self) -> String {
        let g = match (self.act_gran, self.w_gran) {
            (Granularity::PerTensor, Granularity::PerTensor) => "pt",
            _ => "pv",
        };
        let s: String = self.pre.iter().map(|t| t.tag_suffix()).collect();
        let r = match (self.method, self.resid_rank) {
            (Method::Resq, Some(n)) => format!("-r{n}"),
            _ => String::new(),
        };
        let e = if self.method == Method::Muxq && self.muxq.exp_factor != 2 {
            format!("-e{}", self.muxq.exp_factor)
        } else {
            String::new()
        };
        let b = if (self.ia_bits, self.w_bits) != EngineSpec::default_bits(self.method) {
            format!("-w{}a{}", self.w_bits, self.ia_bits)
        } else {
            String::new()
        };
        format!("{}-{g}{s}{r}{e}{b}", self.method.tag_name())
    }

    /// Parse a canonical tag back into a spec (absent bits suffix means
    /// the method's default widths; transform parameters are not
    /// encoded — `-sq` parses to alpha 0.5, `-rot`/`-perm` to the
    /// [`ROT_BLOCK`] schemes — and the pipeline is rebuilt in suffix
    /// order). Inverse of [`EngineSpec::tag`]; `parse(t).tag() == t`
    /// for every CANONICAL tag, which is what keeps manifest and
    /// examples drift-free. A bits suffix spelling out the method
    /// defaults (e.g. `naive-pv-w8a8`) parses fine but re-tags to the
    /// canonical short form — the manifest canonicality check relies on
    /// exactly that.
    pub fn parse(tag: &str) -> Result<EngineSpec> {
        let mut parts = tag.split('-');
        let Some(m) = parts.next() else { bail!("empty variant tag") };
        let method = Method::parse(m)?;
        let Some(g) = parts.next() else { bail!("variant tag {tag:?} missing granularity") };
        let Some((act_gran, w_gran)) = Granularity::parse(g) else {
            bail!("variant tag {tag:?}: unknown granularity {g:?}");
        };
        let mut spec = EngineSpec::new(method).with_granularity(act_gran, w_gran);
        for p in parts {
            if p == "sq" {
                spec.pre.push(PreTransform::Smooth { alpha: 0.5 });
            } else if p == "rot" {
                spec.pre.push(PreTransform::Rotate { block: ROT_BLOCK });
            } else if p == "perm" {
                spec.pre.push(PreTransform::Permute { kind: PermuteKind::Zigzag });
            } else if let Some(r) = p.strip_prefix('r') {
                let rank: usize = r
                    .parse()
                    .map_err(|_| anyhow::anyhow!("variant tag {tag:?}: bad rank suffix {p:?}"))?;
                if method != Method::Resq {
                    bail!("variant tag {tag:?}: -r suffix is resq-only");
                }
                if rank == 0 {
                    bail!("variant tag {tag:?}: residual rank must be >= 1");
                }
                spec.resid_rank = Some(rank);
            } else if let Some(e) = p.strip_prefix('e') {
                let exp: u32 = e
                    .parse()
                    .map_err(|_| anyhow::anyhow!("variant tag {tag:?}: bad exp suffix {p:?}"))?;
                if method != Method::Muxq {
                    bail!("variant tag {tag:?}: -e suffix is MUXQ-only");
                }
                spec.muxq.exp_factor = exp;
            } else if let Some(rest) = p.strip_prefix('w') {
                let Some((ws, as_)) = rest.split_once('a') else {
                    bail!("variant tag {tag:?}: bad bits suffix {p:?} (want -w{{W}}a{{A}})");
                };
                let w: u32 = ws
                    .parse()
                    .map_err(|_| anyhow::anyhow!("variant tag {tag:?}: bad bits suffix {p:?}"))?;
                let a: u32 = as_
                    .parse()
                    .map_err(|_| anyhow::anyhow!("variant tag {tag:?}: bad bits suffix {p:?}"))?;
                spec.ia_bits = a;
                spec.w_bits = w;
            } else {
                bail!("variant tag {tag:?}: unknown suffix {p:?}");
            }
        }
        Ok(spec)
    }

    /// Build the operator for one weight matrix `w [k, n]` + bias,
    /// quantizing and packing ONCE (load time). Pre-transforms, when
    /// configured, use unit calibration (weight-only equalization for
    /// smooth, rank-order-degenerate zigzag); real deployments
    /// calibrate — see [`EngineSpec::pack_calibrated`].
    pub fn pack(&self, w: &MatF32, bias: &[f32]) -> Box<dyn QuantLinear> {
        self.pack_calibrated(w, bias, None)
    }

    /// [`EngineSpec::pack`] with a per-input-channel activation abs-max
    /// from calibration (len `k`). The ordered [`PreTransform`]
    /// pipeline folds into the weight here: each stage rewrites
    /// `(W, amax)` — smooth scales rows by `s = amax^α/wmax^(1−α)` and
    /// divides `amax`, permute reorders both, rotate folds `R·W` and
    /// propagates an RMS `amax` estimate — and contributes its
    /// activation-side inverse to the [`ActPipeline`] the operator
    /// applies per call. Applied identically by every method (that is
    /// the composability claim). The calibrated `amax` surviving the
    /// pipeline also drives ResQ's energy-threshold rank selection.
    pub fn pack_calibrated(
        &self,
        w: &MatF32,
        bias: &[f32],
        act_absmax: Option<&[f32]>,
    ) -> Box<dyn QuantLinear> {
        assert_eq!(bias.len(), w.cols, "bias length vs output dim");
        let k = w.rows;
        let calibrated = act_absmax.is_some();
        let mut amax: Vec<f32> = match act_absmax {
            Some(a) => {
                assert_eq!(a.len(), k, "calibration abs-max length vs input dim");
                a.to_vec()
            }
            None => vec![1.0f32; k],
        };
        let mut w_eff: std::borrow::Cow<'_, MatF32> = std::borrow::Cow::Borrowed(w);
        let mut pre = ActPipeline::empty();
        for t in &self.pre {
            match *t {
                PreTransform::Smooth { alpha } => {
                    let s = super::smooth::smooth_scales(&amax, &w_eff, alpha);
                    let ws = w_eff.to_mut();
                    for (r, sc) in s.iter().enumerate() {
                        for v in ws.row_mut(r) {
                            *v *= sc;
                        }
                    }
                    for (a, sc) in amax.iter_mut().zip(&s) {
                        *a /= sc;
                    }
                    pre.push(ActStep::Scale(s));
                }
                PreTransform::Permute { kind: PermuteKind::Zigzag } => {
                    let p = zigzag_perm(&amax, ROT_BLOCK);
                    let mut ws = MatF32::zeros(k, w_eff.cols);
                    for (j, &src) in p.iter().enumerate() {
                        ws.row_mut(j).copy_from_slice(w_eff.row(src));
                    }
                    amax = p.iter().map(|&src| amax[src]).collect();
                    w_eff = std::borrow::Cow::Owned(ws);
                    pre.push(ActStep::Permute(p));
                }
                PreTransform::Rotate { block } => {
                    let rot = BlockRot::build(k, block);
                    w_eff = std::borrow::Cow::Owned(rot.apply_to_weight(&w_eff));
                    amax = rot.amax_estimate(&amax);
                    pre.push(ActStep::Rotate(rot));
                }
            }
        }
        let w_eff: &MatF32 = &w_eff;
        match self.method {
            Method::Fp16 => Box::new(Fp32Linear {
                spec: self.clone(),
                w: w_eff.clone(),
                bias: bias.to_vec(),
                pre,
            }),
            Method::Naive => Box::new(NaiveLinear {
                spec: self.clone(),
                qw: PackedWeight::quantize(w_eff, self.w_qmax(), self.w_gran, bias, self.w_bits),
                pre,
            }),
            Method::Muxq => Box::new(MuxqLinear {
                spec: self.clone(),
                qw: PackedWeight::quantize(w_eff, self.w_qmax(), self.w_gran, bias, self.w_bits),
                pre,
            }),
            Method::LlmInt8 => Box::new(LlmInt8Linear {
                spec: self.clone(),
                qw: PackedWeight::quantize(w_eff, self.w_qmax(), self.w_gran, bias, self.w_bits),
                w_fp: w_eff.clone(),
                pre,
            }),
            Method::Resq => Box::new(ResqLinear::build(
                self.clone(),
                w_eff,
                bias,
                pre,
                calibrated.then_some(&amax[..]),
            )),
        }
    }

    /// One-shot projection for the fake-quant evaluation path
    /// (`Gpt2Model::forward` with a `QuantSpec`): build the operator,
    /// run it, drop it. The dispatch that used to live in
    /// `QuantSpec::matmul` now IS this trait. FP16 skips the pack (no
    /// weight copy on the reference path).
    pub fn matmul(&self, x: &MatF32, w: &MatF32) -> MatF32 {
        if self.method == Method::Fp16 && self.pre.is_empty() {
            return matmul_f32(x, w);
        }
        self.pack(w, &vec![0.0f32; w.cols]).forward(x)
    }
}

impl fmt::Display for EngineSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.tag())
    }
}

// ---------------------------------------------------------------- trait

/// One deployed linear operator: a weight matrix quantized + packed at
/// load time behind a method-specific projection. Object-safe so model
/// layers hold `Box<dyn QuantLinear>` — the extension point for new
/// schemes (ResQ-style low-rank residuals, OutlierTune-style channel
/// variants) without touching the model or serving layers.
pub trait QuantLinear: Send + Sync {
    /// The spec this operator was built from.
    fn spec(&self) -> &EngineSpec;

    /// Logical weight shape `(k, n)`.
    fn shape(&self) -> (usize, usize);

    /// Deployed weight bytes (packed panels + scales + bias + any
    /// resident FP copy the method needs — the honest memory claim).
    fn bytes(&self) -> usize;

    /// Whether the batch path already treats rows independently (no
    /// cross-row state like a shared outlier mask). When true the
    /// session layer may batch rows through [`QuantLinear::forward_into`]
    /// without changing results.
    fn row_independent(&self) -> bool;

    /// Batch projection `y = x @ W + bias` (`y` resized in place; every
    /// element overwritten). Batch semantics: methods with an outlier
    /// mask compute ONE mask over all rows of the call.
    fn forward_into(&self, x: &MatF32, y: &mut MatF32);

    /// Row-independent projection of ONE row (the session / decode
    /// path): any outlier mask comes from this row alone, and M=1
    /// operands route through the packed engine's GEMV kernels. For a
    /// 1-row input this must agree with [`QuantLinear::forward_into`]
    /// bit for bit (a single row IS its own batch).
    fn forward_row_into(&self, x: &[f32], y: &mut [f32]);

    /// Many rows through the ROW-INDEPENDENT semantics in one call (`y`
    /// resized): results are defined to be bit-identical to `m`
    /// [`QuantLinear::forward_row_into`] calls — per-row masks, per-row
    /// scales — but methods may coalesce rows into fewer GEMMs when
    /// that provably cannot change the arithmetic (MUXQ batches
    /// mask-sharing runs; see its override). This is the session layer's
    /// multi-row path: prefill and the speculative k-row verify both
    /// route here.
    fn forward_rows_into(&self, x: &MatF32, y: &mut MatF32) {
        let (k, n) = self.shape();
        debug_assert_eq!(x.cols, k);
        y.rows = x.rows;
        y.cols = n;
        y.data.resize(x.rows * n, 0.0);
        for r in 0..x.rows {
            self.forward_row_into(x.row(r), &mut y.data[r * n..(r + 1) * n]);
        }
    }

    /// The npusim execution plan of one `m`-row call with `r` live
    /// outlier channels — simulated-hardware pricing derived from the
    /// same object that runs on the host. The spec's pre-transform
    /// pipeline prices its activation-side work on top
    /// ([`Plan::with_act_pre_transforms`]); the folded weight side is
    /// free per call by construction.
    fn plan(&self, cfg: &NpuConfig, m: usize, r: usize) -> Plan {
        let (k, n) = self.shape();
        let s = self.spec();
        Plan::build(cfg, s.method, m, k, n, r, s.ia_bits, s.w_bits, s.muxq.exp_factor)
            .with_act_pre_transforms(cfg, m, k, &s.pre)
    }

    /// [`QuantLinear::plan`] priced on the NPU config that mirrors the
    /// kernel the runtime dispatcher resolved on THIS host
    /// ([`NpuConfig::for_kernel`]): scalar 1, `pmaddwd`-pair 2 or `sdot`
    /// 4 MACs per lane per cycle — so simulated latencies track the
    /// datapath the deployed operators actually run.
    fn host_plan(&self, m: usize, r: usize) -> Plan {
        self.plan(&NpuConfig::for_kernel(super::simd::dispatch()), m, r)
    }

    /// Allocating convenience wrapper over [`QuantLinear::forward_into`].
    fn forward(&self, x: &MatF32) -> MatF32 {
        let mut y = MatF32::zeros(0, 0);
        self.forward_into(x, &mut y);
        y
    }
}

// ------------------------------------------------------- shared pieces

/// The packed INT body of one weight matrix at either deployed width:
/// byte-per-weight INT8 panels or nibble-per-weight INT4 panels. One
/// enum so every INT operator serves both widths through the same two
/// contractions — the whole-matrix GEMM and the rows-subset aux GEMM —
/// and the skinny-M GEMV routing stays inside the packed engine.
pub enum PackedBody {
    I8(PackedMatI8),
    I4(PackedMatI4),
}

impl PackedBody {
    /// Logical `(k, n)` shape.
    pub fn shape(&self) -> (usize, usize) {
        match self {
            PackedBody::I8(p) => (p.rows, p.cols),
            PackedBody::I4(p) => (p.rows, p.cols),
        }
    }

    /// Stored panel bytes — nibble panels really are half the INT8
    /// bytes, the 0.5 B/elem memory claim `bytes()` passes upward.
    pub fn padded_bytes(&self) -> usize {
        match self {
            PackedBody::I8(p) => p.padded_bytes(),
            PackedBody::I4(p) => p.padded_bytes(),
        }
    }

    fn gemm_into(&self, xq: &MatI8, acc: &mut MatI32) {
        match self {
            PackedBody::I8(p) => packed::matmul_i8_packed_into(xq, p, acc, ParallelGemm::global()),
            PackedBody::I4(p) => {
                packed::matmul_i8w4_packed_into(xq, p, acc, ParallelGemm::global())
            }
        }
    }

    fn rows_subset_into(&self, xq: &MatI8, idx: &[usize], acc: &mut MatI32) {
        match self {
            PackedBody::I8(p) => {
                packed::matmul_i8_rows_subset_into(xq, p, idx, acc, ParallelGemm::global())
            }
            PackedBody::I4(p) => {
                packed::matmul_i8w4_rows_subset_into(xq, p, idx, acc, ParallelGemm::global())
            }
        }
    }
}

/// One weight matrix, pre-quantized and pre-packed (K-major panels) —
/// the INT methods' shared weight half.
pub struct PackedWeight {
    pub body: PackedBody,
    pub scales: Scales,
    pub bias: Vec<f32>,
}

impl PackedWeight {
    /// Quantize + pack once at load time; `w_bits <= 4` selects the
    /// nibble panel format (the quantized grid already fits [-7, 7], so
    /// the pack-time saturation scan never fires on this path).
    pub fn quantize(
        w: &MatF32,
        qmax: f32,
        gran: Granularity,
        bias: &[f32],
        w_bits: u32,
    ) -> PackedWeight {
        let scales = Scales::compute(w, qmax, gran);
        let q = super::absmax::quantize_i8(w, &scales, qmax);
        let body = if w_bits <= 4 {
            PackedBody::I4(PackedMatI4::pack(&q))
        } else {
            PackedBody::I8(PackedMatI8::pack(&q))
        };
        PackedWeight { body, scales, bias: bias.to_vec() }
    }

    /// Packed panels + scale vector + f32 bias.
    pub fn bytes(&self) -> usize {
        self.body.padded_bytes()
            + match &self.scales {
                Scales::Tensor(_) => 4,
                Scales::Rows(v) | Scales::Cols(v) => v.len() * 4,
            }
            + self.bias.len() * 4
    }
}

/// Reusable INT-operator buffers: on the steady-state path the only
/// per-call allocation is the caller's output matrix — quantized
/// operands, accumulators, scale vectors, masks/index lists and the
/// smoothed-activation copy are all resized in place.
///
/// Lives in a PER-THREAD pool ([`with_scratch`]), not per operator:
/// one `IntScratch` per deployed site used to mean 4·n_layer live
/// buffer sets per variant (plus a Mutex acquire on every projection),
/// which scales with model depth exactly where speculative k-row
/// scoring and big-batch serving multiply call rates. Operator forwards
/// never nest, so one scratch per thread serves every operator; each
/// call resizes the buffers it touches.
struct IntScratch {
    /// pre-transformed activations (only touched when the spec has a
    /// pre-transform pipeline)
    xs: MatF32,
    /// single-row staging for the row path
    xrow: MatF32,
    /// pipeline staging for the permute/rotate steps (Scale runs in
    /// place; the other steps stage one row here and copy back)
    tbuf: Vec<f32>,
    /// quantized activations (Body for MUXQ, masked-normal for LLM.int8())
    xq: MatI8,
    /// compact quantized Aux — outlier columns only, [m, r]
    aux_q: MatI8,
    /// compact gathered activation columns for the ResQ residual leg, [m, rank]
    xg: MatF32,
    acc: MatI32,
    acc_aux: MatI32,
    /// per-row activation scales (body, aux)
    sx: Vec<f32>,
    sa: Vec<f32>,
    mask: Vec<bool>,
    idx: Vec<usize>,
}

impl IntScratch {
    fn new() -> IntScratch {
        IntScratch {
            xs: MatF32::zeros(0, 0),
            xrow: MatF32::zeros(0, 0),
            tbuf: Vec::new(),
            xq: MatI8::zeros(0, 0),
            aux_q: MatI8::zeros(0, 0),
            xg: MatF32::zeros(0, 0),
            acc: MatI32::zeros(0, 0),
            acc_aux: MatI32::zeros(0, 0),
            sx: Vec::new(),
            sa: Vec::new(),
            mask: Vec::new(),
            idx: Vec::new(),
        }
    }

    /// Stage one activation row (applying the pre-transform pipeline)
    /// into the reusable single-row buffer — the shared
    /// `forward_row_into` preamble of every operator. ONE implementation
    /// on purpose: this is the seam the decode bit-exactness oracles
    /// stand on, and [`transformed`] (the batch seam) routes every row
    /// through the same [`ActPipeline::apply_row`] arithmetic.
    fn stage_row(&mut self, x: &[f32], pre: &ActPipeline) {
        self.xrow.rows = 1;
        self.xrow.cols = x.len();
        self.xrow.data.resize(x.len(), 0.0);
        self.xrow.data.copy_from_slice(x);
        pre.apply_row(&mut self.xrow.data, &mut self.tbuf);
    }
}

thread_local! {
    /// The shared per-thread scratch pool — see [`IntScratch`].
    static SCRATCH: RefCell<IntScratch> = RefCell::new(IntScratch::new());
}

/// Run `f` with this thread's shared [`IntScratch`]. Panics on
/// re-entrant use (a projection calling a projection), which no
/// operator does — the buffers hold one call's state at a time.
fn with_scratch<R>(f: impl FnOnce(&mut IntScratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Apply the activation-side pre-transform pipeline to every row of `x`
/// into `buf` — the batch twin of [`IntScratch::stage_row`], same
/// per-row [`ActPipeline::apply_row`] arithmetic (the row/batch
/// bit-exactness contract; for a pure smooth pipeline this matches
/// `smooth::migrate`'s X side bit for bit) — or pass `x` through
/// untouched when the pipeline is empty.
fn transformed<'a>(
    x: &'a MatF32,
    pre: &ActPipeline,
    buf: &'a mut MatF32,
    tmp: &mut Vec<f32>,
) -> &'a MatF32 {
    if pre.is_empty() {
        return x;
    }
    buf.rows = x.rows;
    buf.cols = x.cols;
    buf.data.resize(x.rows * x.cols, 0.0);
    buf.data.copy_from_slice(&x.data);
    for r in 0..x.rows {
        pre.apply_row(buf.row_mut(r), tmp);
    }
    buf
}

/// Per-row abs-max quantization straight into reusable scratch (the
/// per-token path), or one shared tensor scale when `gran` is
/// per-tensor (the scale is still materialized per row so the shared
/// dequant path stays branch-free). Bit-identical to
/// `Scales::compute` + `quantize_i8`.
fn quantize_rows_into(
    x: &MatF32,
    qmax: f32,
    gran: Granularity,
    xq: &mut MatI8,
    sx: &mut Vec<f32>,
) {
    let (m, k) = (x.rows, x.cols);
    xq.rows = m;
    xq.cols = k;
    xq.data.resize(m * k, 0);
    sx.clear();
    sx.resize(m, 0.0);
    for r in 0..m {
        sx[r] = x.row(r).iter().fold(0.0f32, |mx, v| mx.max(v.abs()));
    }
    if gran == Granularity::PerTensor {
        let g = sx.iter().fold(0.0f32, |a, &b| a.max(b));
        sx.iter_mut().for_each(|v| *v = g);
    }
    for s in sx.iter_mut() {
        *s = s.max(EPS) / qmax;
    }
    for r in 0..m {
        let s = sx[r];
        for (qv, v) in xq.data[r * k..(r + 1) * k].iter_mut().zip(x.row(r)) {
            *qv = rint(v / s).clamp(-qmax, qmax) as i8;
        }
    }
}

/// Fused MUXQ decompose + quantize: one pass over each row computes the
/// Body and compact-Aux abs-maxes, a second writes the quantized values
/// straight into i8 scratch — no f32 Body/Aux matrices ever exist.
/// Bit-identical to decompose → `Scales::compute` → `quantize_i8` at
/// both granularities (|x·2^-e| == |x|·2^-e exactly: the shift is a
/// power of two; per-tensor just reduces the row maxes once more).
#[allow(clippy::too_many_arguments)]
fn fused_decompose_quantize(
    x: &MatF32,
    mask: &[bool],
    idx: &[usize],
    inv: f32,
    qmax: f32,
    gran: Granularity,
    body_q: &mut MatI8,
    sb: &mut Vec<f32>,
    aux_q: &mut MatI8,
    sa: &mut Vec<f32>,
) {
    let (m, k, r) = (x.rows, x.cols, idx.len());
    debug_assert_eq!(mask.len(), k);
    body_q.rows = m;
    body_q.cols = k;
    body_q.data.resize(m * k, 0);
    aux_q.rows = m;
    aux_q.cols = r;
    aux_q.data.resize(m * r, 0);
    sb.clear();
    sb.resize(m, 0.0);
    sa.clear();
    sa.resize(m, 0.0);
    for row in 0..m {
        let xr = x.row(row);
        let mut bmax = 0.0f32;
        let mut amax = 0.0f32;
        for c in 0..k {
            let v = xr[c].abs();
            if mask[c] {
                let shifted = v * inv;
                bmax = bmax.max(shifted);
                amax = amax.max(shifted);
            } else {
                bmax = bmax.max(v);
            }
        }
        sb[row] = bmax;
        sa[row] = amax;
    }
    if gran == Granularity::PerTensor {
        let gb = sb.iter().fold(0.0f32, |a, &b| a.max(b));
        let ga = sa.iter().fold(0.0f32, |a, &b| a.max(b));
        sb.iter_mut().for_each(|v| *v = gb);
        sa.iter_mut().for_each(|v| *v = ga);
    }
    for v in sb.iter_mut() {
        *v = v.max(EPS) / qmax;
    }
    for v in sa.iter_mut() {
        *v = v.max(EPS) / qmax;
    }
    for row in 0..m {
        let xr = x.row(row);
        let sbv = sb[row];
        let sav = sa[row];
        for (c, bq) in body_q.data[row * k..(row + 1) * k].iter_mut().enumerate() {
            let v = if mask[c] { xr[c] * inv } else { xr[c] };
            *bq = rint(v / sbv).clamp(-qmax, qmax) as i8;
        }
        for (t, aq) in aux_q.data[row * r..(row + 1) * r].iter_mut().enumerate() {
            *aq = rint(xr[idx[t]] * inv / sav).clamp(-qmax, qmax) as i8;
        }
    }
}

/// Dequantize the body accumulator — plus, for MUXQ, the recombination
/// `f · Aux` term — and add the bias, one pass over the output, resized
/// in place.
fn dequant_bias_into(
    acc: &MatI32,
    sx: &[f32],
    sw: &Scales,
    aux: Option<(&MatI32, &[f32], f32)>,
    bias: &[f32],
    y: &mut MatF32,
) {
    let (m, n) = (acc.rows, acc.cols);
    y.rows = m;
    y.cols = n;
    y.data.resize(m * n, 0.0);
    for r in 0..m {
        let yrow = &mut y.data[r * n..(r + 1) * n];
        let arow = &acc.data[r * n..(r + 1) * n];
        let aux_row = aux.map(|(acc2, sa, f)| (&acc2.data[r * n..(r + 1) * n], sa[r], f));
        dequant_bias_row(arow, sx[r], sw, aux_row, bias, yrow);
    }
}

/// One output row of [`dequant_bias_into`] — shared by the batch path
/// and the row-wise session path, so the two are
/// arithmetic-for-arithmetic identical (the decode bit-exactness oracle
/// depends on this).
pub(crate) fn dequant_bias_row(
    arow: &[i32],
    sxr: f32,
    sw: &Scales,
    aux: Option<(&[i32], f32, f32)>,
    bias: &[f32],
    yrow: &mut [f32],
) {
    let n = arow.len();
    match aux {
        None => {
            for j in 0..n {
                yrow[j] = arow[j] as f32 * (sxr * sw.at(0, j)) + bias[j];
            }
        }
        Some((a2, sar, f)) => {
            for j in 0..n {
                let swj = sw.at(0, j);
                yrow[j] =
                    arow[j] as f32 * (sxr * swj) + f * (a2[j] as f32 * (sar * swj)) + bias[j];
            }
        }
    }
}

// ---------------------------------------------------------- fp32 (fp16)

/// The FP reference operator (f32 standing in for FP16, as everywhere in
/// this repo): no quantization, plain GEMM + bias. Gives the fp16 rows
/// of Tables 1–2 the same object shape as the INT methods.
pub struct Fp32Linear {
    spec: EngineSpec,
    w: MatF32,
    bias: Vec<f32>,
    pre: ActPipeline,
}

impl QuantLinear for Fp32Linear {
    fn spec(&self) -> &EngineSpec {
        &self.spec
    }

    fn shape(&self) -> (usize, usize) {
        (self.w.rows, self.w.cols)
    }

    fn bytes(&self) -> usize {
        self.w.data.len() * 4 + self.bias.len() * 4 + self.pre.bytes()
    }

    fn row_independent(&self) -> bool {
        true
    }

    fn forward_into(&self, x: &MatF32, y: &mut MatF32) {
        // pre-transforms are function-preserving in FP (X/s @ s⊙W,
        // X·Rᵀ @ R·W, X·P @ Pᵀ·W all equal X @ W up to rounding);
        // applied anyway so the FP operator is the faithful reference
        // for its transformed INT siblings
        let mut buf = MatF32::zeros(0, 0);
        let mut tmp = Vec::new();
        let xs = transformed(x, &self.pre, &mut buf, &mut tmp);
        *y = matmul_f32(xs, &self.w);
        for r in 0..y.rows {
            for (v, b) in y.row_mut(r).iter_mut().zip(&self.bias) {
                *v += b;
            }
        }
    }

    fn forward_row_into(&self, x: &[f32], y: &mut [f32]) {
        let (k, n) = self.shape();
        debug_assert_eq!(x.len(), k);
        debug_assert_eq!(y.len(), n);
        // k-ascending accumulation with the bias added LAST — the same
        // float summation order as the batch kernel (`matmul_f32` plus
        // the bias pass), so a 1-row batch and the row path agree bit
        // for bit. The zero-skip matches `matmul_f32_rows` too. A
        // pre-transform pipeline stages the row through the same seam
        // the batch path uses, then accumulates identically.
        let acc = |xrow: &[f32], y: &mut [f32]| {
            y.fill(0.0);
            for (c, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                for (yv, wv) in y.iter_mut().zip(self.w.row(c)) {
                    *yv += xv * wv;
                }
            }
            for (yv, b) in y.iter_mut().zip(&self.bias) {
                *yv += b;
            }
        };
        if self.pre.is_empty() {
            acc(x, y);
        } else {
            with_scratch(|sc| {
                sc.stage_row(x, &self.pre);
                acc(&sc.xrow.data, y);
            });
        }
    }
}

// ---------------------------------------------------------------- naive

/// Naive abs-max: quantize activations per row (or tensor), one packed
/// INT8 GEMM, dequantize + bias. Row-independent by construction.
pub struct NaiveLinear {
    spec: EngineSpec,
    qw: PackedWeight,
    pre: ActPipeline,
}

impl NaiveLinear {
    fn project(&self, x: &MatF32, y: &mut MatF32) {
        let qmax = self.spec.ia_qmax();
        with_scratch(|sc| {
            let xs = transformed(x, &self.pre, &mut sc.xs, &mut sc.tbuf);
            quantize_rows_into(xs, qmax, self.spec.act_gran, &mut sc.xq, &mut sc.sx);
            self.qw.body.gemm_into(&sc.xq, &mut sc.acc);
            dequant_bias_into(&sc.acc, &sc.sx, &self.qw.scales, None, &self.qw.bias, y);
        });
    }
}

impl QuantLinear for NaiveLinear {
    fn spec(&self) -> &EngineSpec {
        &self.spec
    }

    fn shape(&self) -> (usize, usize) {
        self.qw.body.shape()
    }

    fn bytes(&self) -> usize {
        self.qw.bytes() + self.pre.bytes()
    }

    fn row_independent(&self) -> bool {
        // per-tensor activation scales couple rows through the shared
        // abs-max; per-row scales do not
        self.spec.act_gran == Granularity::PerRow
    }

    fn forward_into(&self, x: &MatF32, y: &mut MatF32) {
        self.project(x, y);
    }

    fn forward_row_into(&self, x: &[f32], y: &mut [f32]) {
        let (k, n) = self.shape();
        debug_assert_eq!(x.len(), k);
        debug_assert_eq!(y.len(), n);
        let qmax = self.spec.ia_qmax();
        with_scratch(|sc| {
            sc.stage_row(x, &self.pre);
            quantize_rows_into(&sc.xrow, qmax, Granularity::PerRow, &mut sc.xq, &mut sc.sx);
            self.qw.body.gemm_into(&sc.xq, &mut sc.acc);
            dequant_bias_row(&sc.acc.data[..n], sc.sx[0], &self.qw.scales, None, &self.qw.bias, y);
        });
    }
}

// ----------------------------------------------------------------- muxq

/// MUXQ (the paper): outlier decomposition into Body + compact Aux, both
/// uniform INT8, recombined as `Body + (2^exp − 1)·Aux`. The Aux GEMM
/// reads its outlier rows straight out of the ONE packed weight via the
/// rows-subset kernel — zero gather, zero re-pack (DESIGN.md §4).
pub struct MuxqLinear {
    spec: EngineSpec,
    qw: PackedWeight,
    pre: ActPipeline,
}

impl MuxqLinear {
    /// The shared projection body; `sc.mask` is already computed over
    /// `xs` — callers differ only in mask scope (whole batch vs one row).
    fn project_masked(&self, xs: &MatF32, sc: &mut IntScratch, y_row0: &mut [f32]) {
        let qmax = self.spec.ia_qmax();
        let n = self.qw.body.shape().1;
        sc.idx.clear();
        sc.idx.extend(sc.mask.iter().enumerate().filter(|(_, m)| **m).map(|(i, _)| i));
        fused_decompose_quantize(
            xs,
            &sc.mask,
            &sc.idx,
            self.spec.muxq.inv_shift(),
            qmax,
            self.spec.act_gran,
            &mut sc.xq,
            &mut sc.sx,
            &mut sc.aux_q,
            &mut sc.sa,
        );
        self.qw.body.gemm_into(&sc.xq, &mut sc.acc);
        if sc.idx.is_empty() {
            for r in 0..xs.rows {
                dequant_bias_row(
                    &sc.acc.data[r * n..(r + 1) * n],
                    sc.sx[r],
                    &self.qw.scales,
                    None,
                    &self.qw.bias,
                    &mut y_row0[r * n..(r + 1) * n],
                );
            }
        } else {
            self.qw.body.rows_subset_into(&sc.aux_q, &sc.idx, &mut sc.acc_aux);
            let f = self.spec.muxq.aux_weight();
            for r in 0..xs.rows {
                dequant_bias_row(
                    &sc.acc.data[r * n..(r + 1) * n],
                    sc.sx[r],
                    &self.qw.scales,
                    Some((&sc.acc_aux.data[r * n..(r + 1) * n], sc.sa[r], f)),
                    &self.qw.bias,
                    &mut y_row0[r * n..(r + 1) * n],
                );
            }
        }
    }
}

impl QuantLinear for MuxqLinear {
    fn spec(&self) -> &EngineSpec {
        &self.spec
    }

    fn shape(&self) -> (usize, usize) {
        self.qw.body.shape()
    }

    fn bytes(&self) -> usize {
        self.qw.bytes() + self.pre.bytes()
    }

    fn row_independent(&self) -> bool {
        // the batch path computes ONE outlier mask over all rows of a
        // call — a batching artifact the session layer must not inherit
        false
    }

    fn forward_into(&self, x: &MatF32, y: &mut MatF32) {
        let n = self.qw.body.shape().1;
        with_scratch(|sc| {
            y.rows = x.rows;
            y.cols = n;
            y.data.resize(x.rows * n, 0.0);
            if !self.pre.is_empty() {
                // move the transformed copy out of the scratch so the
                // rest of the struct can be borrowed mutably alongside
                // it (put back after; the placeholder is 0-element — no
                // allocation)
                transformed(x, &self.pre, &mut sc.xs, &mut sc.tbuf);
                let xs = std::mem::replace(&mut sc.xs, MatF32::zeros(0, 0));
                outlier_mask_into(&xs, self.spec.muxq.theta, &mut sc.mask);
                self.project_masked(&xs, sc, &mut y.data);
                sc.xs = xs;
            } else {
                outlier_mask_into(x, self.spec.muxq.theta, &mut sc.mask);
                self.project_masked(x, sc, &mut y.data);
            }
        });
    }

    fn forward_row_into(&self, x: &[f32], y: &mut [f32]) {
        let (k, n) = self.shape();
        debug_assert_eq!(x.len(), k);
        debug_assert_eq!(y.len(), n);
        with_scratch(|sc| {
            sc.stage_row(x, &self.pre);
            outlier_mask_into(&sc.xrow, self.spec.muxq.theta, &mut sc.mask);
            let xrow = std::mem::replace(&mut sc.xrow, MatF32::zeros(0, 0));
            self.project_masked(&xrow, sc, y);
            sc.xrow = xrow;
        });
    }

    /// Row-independent multi-row path with MASK-GROUPED body GEMMs: at
    /// per-row activation granularity, consecutive rows whose per-row
    /// outlier masks are identical share one `project_masked` call — one
    /// Body GEMM + one Aux GEMM per run instead of per row. Bit-exact
    /// against the per-row loop because per-row scales decouple the
    /// rows ([`fused_decompose_quantize`] computes scales row-wise) and
    /// the INT GEMMs are exact integer arithmetic at any M. Prefill
    /// activations are temporally smooth, so neighbouring rows share
    /// masks often enough for real coalescing (channel-persistent
    /// outliers — the paper's Fig. 1 observation).
    ///
    /// Per-TENSOR activation granularity couples every row of a call
    /// through the shared abs-max, so grouping would change results —
    /// that configuration keeps the strict per-row loop.
    fn forward_rows_into(&self, x: &MatF32, y: &mut MatF32) {
        let (k, n) = self.shape();
        debug_assert_eq!(x.cols, k);
        y.rows = x.rows;
        y.cols = n;
        y.data.resize(x.rows * n, 0.0);
        if x.rows == 0 {
            return;
        }
        if self.spec.act_gran != Granularity::PerRow {
            for r in 0..x.rows {
                self.forward_row_into(x.row(r), &mut y.data[r * n..(r + 1) * n]);
            }
            return;
        }
        let theta = self.spec.muxq.theta;
        with_scratch(|sc| {
            // transform the whole batch once (per-row pipeline — the
            // same arithmetic `stage_row` applies row by row)
            let xs_owned = if !self.pre.is_empty() {
                transformed(x, &self.pre, &mut sc.xs, &mut sc.tbuf);
                Some(std::mem::replace(&mut sc.xs, MatF32::zeros(0, 0)))
            } else {
                None
            };
            let xs: &MatF32 = xs_owned.as_ref().unwrap_or(x);
            let same_mask = |a: usize, b: usize| {
                xs.row(a)
                    .iter()
                    .zip(xs.row(b))
                    .all(|(va, vb)| (va.abs() > theta) == (vb.abs() > theta))
            };
            let mut run = std::mem::replace(&mut sc.xrow, MatF32::zeros(0, 0));
            let mut r0 = 0;
            while r0 < xs.rows {
                let mut r1 = r0 + 1;
                while r1 < xs.rows && same_mask(r0, r1) {
                    r1 += 1;
                }
                sc.mask.clear();
                sc.mask.extend(xs.row(r0).iter().map(|v| v.abs() > theta));
                run.rows = r1 - r0;
                run.cols = k;
                run.data.clear();
                run.data.extend_from_slice(&xs.data[r0 * k..r1 * k]);
                self.project_masked(&run, sc, &mut y.data[r0 * n..r1 * n]);
                r0 = r1;
            }
            sc.xrow = run;
            if let Some(owned) = xs_owned {
                sc.xs = owned;
            }
        });
    }
}

// ------------------------------------------------------------- llm.int8

/// Deployed LLM.int8() (Dettmers et al., 2022): outlier channels stay FP
/// (f32 standing in for FP16), normal channels run through the packed
/// INT8 engine. The operator must keep an FP copy of the weights
/// resident — the mask is a *runtime* property of the activations, so no
/// load-time quantization can cover the outlier rows. `bytes()` charges
/// that copy at 2 bytes/element (the FP16 it stands in for): deployed
/// LLM.int8() forfeits most of the INT memory saving, exactly the
/// hardware-unfriendliness the paper's uniform-INT design removes.
pub struct LlmInt8Linear {
    spec: EngineSpec,
    qw: PackedWeight,
    /// resident FP weights for the outlier leg (fp16 stand-in)
    w_fp: MatF32,
    pre: ActPipeline,
}

impl LlmInt8Linear {
    /// Quantize with outlier columns zeroed, scales over the normal
    /// channels only (the fq_llmint8_act discipline).
    fn quantize_masked(&self, xs: &MatF32, sc: &mut IntScratch) {
        let qmax = self.spec.ia_qmax();
        let (m, k) = (xs.rows, xs.cols);
        sc.xq.rows = m;
        sc.xq.cols = k;
        sc.xq.data.resize(m * k, 0);
        sc.sx.clear();
        sc.sx.resize(m, 0.0);
        for r in 0..m {
            let xr = xs.row(r);
            let mut amax = 0.0f32;
            for c in 0..k {
                if !sc.mask[c] {
                    amax = amax.max(xr[c].abs());
                }
            }
            sc.sx[r] = amax;
        }
        if self.spec.act_gran == Granularity::PerTensor {
            let g = sc.sx.iter().fold(0.0f32, |a, &b| a.max(b));
            sc.sx.iter_mut().for_each(|v| *v = g);
        }
        for v in sc.sx.iter_mut() {
            *v = v.max(EPS) / qmax;
        }
        for r in 0..m {
            let xr = xs.row(r);
            let s = sc.sx[r];
            for (c, qv) in sc.xq.data[r * k..(r + 1) * k].iter_mut().enumerate() {
                *qv = if sc.mask[c] { 0 } else { rint(xr[c] / s).clamp(-qmax, qmax) as i8 };
            }
        }
    }

    /// INT leg + FP outlier leg over rows of `xs`, writing `y` rows.
    fn project(&self, xs: &MatF32, sc: &mut IntScratch, y: &mut [f32]) {
        let n = self.qw.body.shape().1;
        sc.idx.clear();
        sc.idx.extend(sc.mask.iter().enumerate().filter(|(_, m)| **m).map(|(i, _)| i));
        self.quantize_masked(xs, sc);
        self.qw.body.gemm_into(&sc.xq, &mut sc.acc);
        for r in 0..xs.rows {
            dequant_bias_row(
                &sc.acc.data[r * n..(r + 1) * n],
                sc.sx[r],
                &self.qw.scales,
                None,
                &self.qw.bias,
                &mut y[r * n..(r + 1) * n],
            );
        }
        // FP outlier leg: blocked gathered-rows accumulation on top of
        // the INT leg (the irregular mixed-precision part MUXQ
        // eliminates) — a real kernel, so decode_tok_s_llmint8 measures
        // deployed code rather than a scalar stopgap
        super::gemm::matmul_f32_rows_gathered_acc(xs, &sc.idx, &self.w_fp, &mut y[..xs.rows * n]);
    }
}

impl QuantLinear for LlmInt8Linear {
    fn spec(&self) -> &EngineSpec {
        &self.spec
    }

    fn shape(&self) -> (usize, usize) {
        self.qw.body.shape()
    }

    fn bytes(&self) -> usize {
        // fp16 stand-in for the resident FP copy: 2 bytes per element
        self.qw.bytes() + self.w_fp.data.len() * 2 + self.pre.bytes()
    }

    fn row_independent(&self) -> bool {
        false // shared batch mask, like MUXQ
    }

    fn forward_into(&self, x: &MatF32, y: &mut MatF32) {
        let n = self.qw.body.shape().1;
        with_scratch(|sc| {
            y.rows = x.rows;
            y.cols = n;
            y.data.resize(x.rows * n, 0.0);
            if !self.pre.is_empty() {
                transformed(x, &self.pre, &mut sc.xs, &mut sc.tbuf);
                let xs = std::mem::replace(&mut sc.xs, MatF32::zeros(0, 0));
                outlier_mask_into(&xs, self.spec.muxq.theta, &mut sc.mask);
                self.project(&xs, sc, &mut y.data);
                sc.xs = xs;
            } else {
                outlier_mask_into(x, self.spec.muxq.theta, &mut sc.mask);
                self.project(x, sc, &mut y.data);
            }
        });
    }

    fn forward_row_into(&self, x: &[f32], y: &mut [f32]) {
        let (k, n) = self.shape();
        debug_assert_eq!(x.len(), k);
        debug_assert_eq!(y.len(), n);
        with_scratch(|sc| {
            sc.stage_row(x, &self.pre);
            outlier_mask_into(&sc.xrow, self.spec.muxq.theta, &mut sc.mask);
            let xrow = std::mem::replace(&mut sc.xrow, MatF32::zeros(0, 0));
            self.project(&xrow, sc, y);
            sc.xrow = xrow;
        });
    }
}

// ------------------------------------------------------------------ resq

/// ResQ-style W4 + rank-r FP residual (arXiv:2412.14363): the weight
/// body is nibble-packed INT4 — half the decode weight traffic of W8 —
/// and accuracy is recovered by a LOW-RANK FP correction fixed at pack
/// time. Unlike LLM.int8()'s runtime mask, the residual rows are a
/// static property of the *weight* quantization error, so the operator
/// is row-independent like Naive and carries no per-call mask work.
/// Structurally the correction is the MUXQ aux leg generalized: it
/// reuses the LLM.int8() gathered-rows FP kernel, but against a COMPACT
/// `[rank, n]` residual instead of a resident full-size FP copy —
/// `bytes()` charges the residual at 2 B/elem (fp16 stand-in), which at
/// rank = k/16 is a small fraction of the LLM.int8() overhead.
pub struct ResqLinear {
    spec: EngineSpec,
    /// nibble-packed W4 body (I8 body if the spec overrides `w_bits`)
    qw: PackedWeight,
    /// compact residual rows `R[idx[t], :]` of `R = W − dq(Q(W))`, shape
    /// `[rank, n]`
    resid: MatF32,
    /// the k-rows the residual covers — largest residual row L2 norms
    idx: Vec<usize>,
    /// `0..rank`: row indices into the COMPACT residual for the gathered
    /// kernel (the activation columns are gathered to match)
    idx_all: Vec<usize>,
    pre: ActPipeline,
}

impl ResqLinear {
    /// Uncalibrated fallback rank = max(1, k/16) — the low-rank regime
    /// of the ResQ paper: a few percent of input channels carry most of
    /// the W4 error. Calibrated packs replace this with
    /// [`ResqLinear::calibrated_rank`].
    fn rank_for(k: usize) -> usize {
        (k / 16).max(1)
    }

    /// A residual row only matters as much as the activations that
    /// multiply it: channel `r`'s contribution to the output error is
    /// bounded by `amax[r]·‖res_r‖`, so its ENERGY share is
    /// `amax[r]²·‖res_r‖²`. Keep every channel whose weighted energy
    /// exceeds [`Self::ENERGY_OUTLIER_MULT`]× the uniform share
    /// (total/k) — a flat residual spectrum selects almost nothing
    /// (there is nothing low-rank to correct), a spiky one selects
    /// exactly the spikes. Clamped to `[1, k/4]` so the "low-rank"
    /// claim stays honest even on pathological calibrations.
    const ENERGY_OUTLIER_MULT: f32 = 4.0;

    fn calibrated_rank(weighted: &[(f32, usize)]) -> usize {
        let k = weighted.len();
        let total: f32 = weighted.iter().map(|&(e, _)| e).sum();
        if total <= 0.0 {
            return 1;
        }
        let thresh = Self::ENERGY_OUTLIER_MULT * total / k as f32;
        let picked = weighted.iter().filter(|&&(e, _)| e > thresh).count();
        picked.clamp(1, (k / 4).max(1))
    }

    fn build(
        spec: EngineSpec,
        w: &MatF32,
        bias: &[f32],
        pre: ActPipeline,
        act_absmax: Option<&[f32]>,
    ) -> ResqLinear {
        let (k, n) = (w.rows, w.cols);
        let qmax = spec.w_qmax();
        let qw = PackedWeight::quantize(w, qmax, spec.w_gran, bias, spec.w_bits);
        // the residual of the body quantization, R = W − dq(Q(W)) — the
        // same grid `PackedWeight::quantize` just packed (identical
        // scales + rounding, so body + residual reconstructs W exactly
        // on the covered rows)
        let q = super::absmax::quantize_i8(w, &qw.scales, qmax);
        let res_at = |r: usize, c: usize| w.at(r, c) - q.data[r * n + c] as f32 * qw.scales.at(r, c);
        // rank selection sorts rows by residual energy — weighted by
        // the calibrated activation abs-max when one is available (the
        // POST-pipeline abs-max: the residual lives in transformed space)
        let mut norms: Vec<(f32, usize)> = (0..k)
            .map(|r| {
                let e: f32 = (0..n).map(|c| res_at(r, c) * res_at(r, c)).sum();
                let wgt = act_absmax.map_or(1.0, |a| a[r] * a[r]);
                (e * wgt, r)
            })
            .collect();
        norms.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        let rank = match (spec.resid_rank, act_absmax) {
            (Some(r), _) => r,           // `-r{N}`: the spec pins it
            (None, Some(_)) => Self::calibrated_rank(&norms),
            (None, None) => Self::rank_for(k),
        }
        .min(k);
        let mut idx: Vec<usize> = norms[..rank].iter().map(|&(_, r)| r).collect();
        idx.sort_unstable();
        let mut resid = MatF32::zeros(rank, n);
        for (t, &r) in idx.iter().enumerate() {
            for c in 0..n {
                *resid.at_mut(t, c) = res_at(r, c);
            }
        }
        let idx_all = (0..rank).collect();
        ResqLinear { spec, qw, resid, idx, idx_all, pre }
    }

    /// W4 INT leg + rank-r FP residual leg over rows of `xs`.
    fn project(&self, xs: &MatF32, sc: &mut IntScratch, y: &mut [f32]) {
        let n = self.qw.body.shape().1;
        let qmax = self.spec.ia_qmax();
        quantize_rows_into(xs, qmax, self.spec.act_gran, &mut sc.xq, &mut sc.sx);
        self.qw.body.gemm_into(&sc.xq, &mut sc.acc);
        for r in 0..xs.rows {
            dequant_bias_row(
                &sc.acc.data[r * n..(r + 1) * n],
                sc.sx[r],
                &self.qw.scales,
                None,
                &self.qw.bias,
                &mut y[r * n..(r + 1) * n],
            );
        }
        // residual leg: gather the covered activation columns into a
        // compact [m, rank] operand, then accumulate through the same
        // blocked gathered-rows kernel LLM.int8() deploys — but against
        // the [rank, n] residual, not a full FP weight copy
        let rank = self.idx.len();
        sc.xg.rows = xs.rows;
        sc.xg.cols = rank;
        sc.xg.data.resize(xs.rows * rank, 0.0);
        for i in 0..xs.rows {
            let xr = xs.row(i);
            for (t, &c) in self.idx.iter().enumerate() {
                sc.xg.data[i * rank + t] = xr[c];
            }
        }
        super::gemm::matmul_f32_rows_gathered_acc(
            &sc.xg,
            &self.idx_all,
            &self.resid,
            &mut y[..xs.rows * n],
        );
    }
}

impl QuantLinear for ResqLinear {
    fn spec(&self) -> &EngineSpec {
        &self.spec
    }

    fn shape(&self) -> (usize, usize) {
        self.qw.body.shape()
    }

    fn bytes(&self) -> usize {
        // compact residual at 2 B/elem (fp16 stand-in) + 4 B per covered
        // row index — the honest low-rank overhead on the W4 body
        self.qw.bytes() + self.resid.data.len() * 2 + self.idx.len() * 4 + self.pre.bytes()
    }

    fn row_independent(&self) -> bool {
        // the residual is static (no runtime mask); per-row activation
        // scales decouple rows exactly like Naive
        self.spec.act_gran == Granularity::PerRow
    }

    fn forward_into(&self, x: &MatF32, y: &mut MatF32) {
        let n = self.qw.body.shape().1;
        with_scratch(|sc| {
            y.rows = x.rows;
            y.cols = n;
            y.data.resize(x.rows * n, 0.0);
            if !self.pre.is_empty() {
                transformed(x, &self.pre, &mut sc.xs, &mut sc.tbuf);
                let xs = std::mem::replace(&mut sc.xs, MatF32::zeros(0, 0));
                self.project(&xs, sc, &mut y.data);
                sc.xs = xs;
            } else {
                self.project(x, sc, &mut y.data);
            }
        });
    }

    fn forward_row_into(&self, x: &[f32], y: &mut [f32]) {
        let (k, n) = self.shape();
        debug_assert_eq!(x.len(), k);
        debug_assert_eq!(y.len(), n);
        with_scratch(|sc| {
            sc.stage_row(x, &self.pre);
            let xrow = std::mem::replace(&mut sc.xrow, MatF32::zeros(0, 0));
            self.project(&xrow, sc, y);
            sc.xrow = xrow;
        });
    }

    fn plan(&self, cfg: &NpuConfig, m: usize, _r: usize) -> Plan {
        // the residual rank is a static pack-time property of this
        // operator — price it, not the caller's runtime outlier estimate
        let (k, n) = self.shape();
        let s = self.spec();
        Plan::build(
            cfg,
            s.method,
            m,
            k,
            n,
            self.idx.len(),
            s.ia_bits,
            s.w_bits,
            s.muxq.exp_factor,
        )
        .with_act_pre_transforms(cfg, m, k, &s.pre)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;
    use crate::quant::gemm::quant_matmul;
    use crate::quant::llmint8::llmint8_matmul;
    use crate::quant::muxq::muxq_matmul_int;

    fn mat(rows: usize, cols: usize, seed: u64, out_cols: &[usize], scale: f32) -> MatF32 {
        let mut rng = SplitMix64::new(seed);
        let mut m = MatF32::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
        )
        .unwrap();
        for r in 0..rows {
            for &c in out_cols {
                *m.at_mut(r, c) *= scale;
            }
        }
        m
    }

    #[test]
    fn tag_round_trips() {
        for tag in [
            "fp16-pt", "naive-pv", "naive-pt", "muxq-pv", "muxq-pt", "llmint8-pv",
            "llmint8-pt", "muxq-pt-sq", "naive-pt-sq", "muxq-pt-e1", "muxq-pt-e3",
            "muxq-pt-sq-e3", "naive-pv-w4a8", "muxq-pv-w4a8", "muxq-pt-sq-e3-w4a8",
            "naive-pv-w4a6", "resq-pv", "resq-pt", "resq-pv-w8a8", "llmint8-pv-w4a8",
            // the composable pre-transform pipeline, suffixes in order
            "muxq-pv-rot", "naive-pv-perm", "muxq-pv-rot-perm", "muxq-pv-sq-rot",
            "muxq-pv-rot-sq", "naive-pv-rot-perm-w4a8", "muxq-pt-sq-rot-perm-e3-w4a8",
            "resq-pv-sq-r8", "resq-pv-rot-r16", "llmint8-pv-perm-rot",
        ] {
            let spec = EngineSpec::parse(tag).unwrap();
            assert_eq!(spec.tag(), tag, "round trip");
            assert_eq!(format!("{spec}"), tag, "Display == tag");
        }
        assert!(EngineSpec::parse("frob-pt").is_err());
        assert!(EngineSpec::parse("muxq-pg").is_err());
        assert!(EngineSpec::parse("naive-pt-e3").is_err(), "-e is muxq-only");
        assert!(EngineSpec::parse("muxq-pt-zz").is_err());
        assert!(EngineSpec::parse("naive-pv-w4").is_err(), "bits suffix needs both widths");
        assert!(EngineSpec::parse("naive-pv-w4a").is_err());
        assert!(EngineSpec::parse("naive-pv-wxa8").is_err());
        assert!(EngineSpec::parse("naive-pv-r4").is_err(), "-r{{N}} is resq-only");
        assert!(EngineSpec::parse("resq-pv-r0").is_err(), "rank 0 is meaningless");
        assert!(EngineSpec::parse("muxq-pv-rotate").is_err(), "only the short suffix parses");
        // a bits suffix spelling out the method defaults parses but
        // re-tags canonical-short — the manifest canonicality check
        // rides on this
        assert_eq!(EngineSpec::parse("naive-pv-w8a8").unwrap().tag(), "naive-pv");
        assert_eq!(EngineSpec::parse("resq-pv-w4a8").unwrap().tag(), "resq-pv");
    }

    #[test]
    fn builder_defaults_are_deployment_grade() {
        let s = EngineSpec::muxq();
        assert_eq!(s.act_gran, Granularity::PerRow);
        assert_eq!(s.w_gran, Granularity::PerCol);
        assert_eq!((s.ia_bits, s.w_bits), (8, 8));
        assert_eq!(s.tag(), "muxq-pv");
        let s = EngineSpec::naive().with_bits(6, 8).with_granularity(
            Granularity::PerTensor,
            Granularity::PerTensor,
        );
        assert_eq!(s.ia_qmax(), 31.0);
        assert_eq!(s.tag(), "naive-pt-w8a6");
        // resq defaults to the W4 body — bare tag, no bits suffix
        let s = EngineSpec::resq();
        assert_eq!((s.ia_bits, s.w_bits), (8, 4));
        assert_eq!(s.w_qmax(), 7.0);
        assert_eq!(s.tag(), "resq-pv");
        assert_eq!(EngineSpec::naive().with_bits(8, 4).tag(), "naive-pv-w4a8");
    }

    #[test]
    fn naive_operator_matches_quant_matmul_bitwise() {
        // same scales, same quantized grid, integer-exact GEMM: the
        // operator must equal the legacy pipeline bit for bit (zero bias)
        let x = mat(12, 40, 1, &[], 1.0);
        let w = mat(40, 24, 2, &[], 1.0);
        for (ag, wg) in [
            (Granularity::PerRow, Granularity::PerCol),
            (Granularity::PerTensor, Granularity::PerTensor),
        ] {
            let op = EngineSpec::naive().with_granularity(ag, wg).pack(&w, &vec![0.0; 24]);
            let y = op.forward(&x);
            let want = quant_matmul(&x, &w, 127.0, ag, wg);
            assert_eq!(y.data, want.data, "{ag:?}/{wg:?}");
        }
    }

    #[test]
    fn w4_operator_matches_manual_nibble_pipeline_bitwise() {
        // the W4A8 naive operator must equal an independently written
        // W4 pipeline bit for bit: quantize W on the 4-bit grid, i32
        // reference contraction on the WIDENED values, shared dequant
        let x = mat(5, 40, 21, &[], 1.0);
        let w = mat(40, 24, 22, &[], 1.0);
        let bias: Vec<f32> = (0..24).map(|i| i as f32 * 0.05).collect();
        let op = EngineSpec::naive().with_bits(8, 4).pack(&w, &bias);
        let y = op.forward(&x);
        // oracle: same scale math as the operator, naive i32 loops
        let sw = crate::quant::absmax::Scales::compute(&w, 7.0, Granularity::PerCol);
        let qw = crate::quant::absmax::quantize_i8(&w, &sw, 7.0);
        let mut want = MatF32::zeros(5, 24);
        for r in 0..5 {
            let amax = x.row(r).iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let sx = amax.max(crate::quant::absmax::EPS) / 127.0;
            let qx: Vec<i8> =
                x.row(r).iter().map(|v| rint(v / sx).clamp(-127.0, 127.0) as i8).collect();
            for j in 0..24 {
                let acc: i32 =
                    (0..40).map(|c| qx[c] as i32 * qw.data[c * 24 + j] as i32).sum();
                *want.at_mut(r, j) = acc as f32 * (sx * sw.at(0, j)) + bias[j];
            }
        }
        assert_eq!(y.data, want.data);
        // and the nibble body really stores half the panel bytes of W8
        let op8 = EngineSpec::naive().pack(&w, &bias);
        assert!(op.bytes() < op8.bytes());
    }

    #[test]
    fn resq_operator_recovers_w4_error_with_low_rank_residual() {
        // ResQ = W4 body + rank-r FP residual on the worst rows. On a
        // weight matrix with a few large rows (where the per-col 4-bit
        // grid hurts most), resq must beat plain naive-W4A8 against FP,
        // and the covered rows' residual must reconstruct W exactly
        let x = mat(12, 64, 23, &[], 1.0);
        let mut w = mat(64, 16, 24, &[], 1.0);
        for &r in &[5usize, 33] {
            for v in w.row_mut(r) {
                *v *= 30.0;
            }
        }
        let exact = matmul_f32(&x, &w);
        let bias = vec![0.0f32; 16];
        let w4 = EngineSpec::naive().with_bits(8, 4).pack(&w, &bias).forward(&x);
        let rq = EngineSpec::resq().pack(&w, &bias).forward(&x);
        assert!(
            rq.mean_abs_diff(&exact) < w4.mean_abs_diff(&exact),
            "resq {} vs naive-w4 {}",
            rq.mean_abs_diff(&exact),
            w4.mean_abs_diff(&exact)
        );
    }

    #[test]
    fn muxq_operator_matches_legacy_int_pipeline_per_vector() {
        // per-vector (the deployment granularity): identical mask, fused
        // quantization and one-packed-W aux path → bit-exact vs
        // muxq_matmul_int
        let x = mat(16, 48, 3, &[5, 20], 25.0);
        let w = mat(48, 16, 4, &[], 1.0);
        let op = EngineSpec::muxq().pack(&w, &vec![0.0; 16]);
        let y = op.forward(&x);
        let want = muxq_matmul_int(
            &x,
            &w,
            127.0,
            Granularity::PerRow,
            Granularity::PerCol,
            &MuxqParams::default(),
        );
        assert_eq!(y.data, want.data);
    }

    #[test]
    fn llmint8_operator_tracks_fake_quant_oracle() {
        // deployed llm.int8() packs W once with full-W scales; the oracle
        // re-quantizes W per call with outlier rows zeroed — tolerance,
        // not bit-exactness, is the contract
        let x = mat(24, 48, 5, &[7, 30], 25.0);
        let w = mat(48, 16, 6, &[], 1.0);
        let op = EngineSpec::llmint8().pack(&w, &vec![0.0; 16]);
        let y = op.forward(&x);
        let oracle =
            llmint8_matmul(&x, &w, 127.0, Granularity::PerRow, Granularity::PerCol, 6.0);
        let exact = matmul_f32(&x, &w);
        assert!(y.mean_abs_diff(&oracle) < 0.05, "mae {}", y.mean_abs_diff(&oracle));
        assert!(y.mean_abs_diff(&exact) < 0.1, "vs fp mae {}", y.mean_abs_diff(&exact));
    }

    #[test]
    fn single_row_batch_equals_row_path_all_methods() {
        // a 1-row batch IS its own mask scope, so forward_into and
        // forward_row_into must agree bit for bit — the seam the session
        // layer's bit-exactness oracle rests on
        let x = mat(1, 32, 7, &[3], 30.0);
        let w = mat(32, 12, 8, &[], 1.0);
        let bias: Vec<f32> = (0..12).map(|i| i as f32 * 0.1).collect();
        for spec in [
            EngineSpec::fp16(),
            EngineSpec::naive(),
            EngineSpec::muxq(),
            EngineSpec::llmint8(),
            EngineSpec::muxq().with_smooth(0.5),
            EngineSpec::naive().with_bits(8, 4),
            EngineSpec::muxq().with_bits(8, 4),
            EngineSpec::resq(),
            EngineSpec::resq().with_smooth(0.5),
            EngineSpec::muxq().with_rotate(),
            EngineSpec::naive().with_permute(),
            EngineSpec::muxq().with_smooth(0.5).with_rotate().with_permute(),
            EngineSpec::resq().with_rotate(),
        ] {
            let op = spec.pack(&w, &bias);
            let batch = op.forward(&x);
            let mut row = vec![0.0f32; 12];
            op.forward_row_into(x.row(0), &mut row);
            assert_eq!(batch.data, row, "{}", spec.tag());
        }
    }

    #[test]
    fn forward_rows_into_matches_row_loop_bitwise() {
        // satellite: the MUXQ mask-grouped multi-row path must equal the
        // strict per-row loop bit for bit — per-row scales decouple the
        // rows, integer GEMMs are exact at any M. Rows are built so the
        // mask CHANGES mid-batch (rows 0-2 share outliers in col 3,
        // rows 3-5 in col 9, rows 6-7 have none): multiple runs form.
        let w = mat(32, 12, 15, &[], 1.0);
        let bias: Vec<f32> = (0..12).map(|i| i as f32 * 0.1 - 0.3).collect();
        let mut x = mat(8, 32, 16, &[], 1.0);
        for r in 0..3 {
            *x.at_mut(r, 3) = 30.0 + r as f32;
        }
        for r in 3..6 {
            *x.at_mut(r, 9) = -28.0 - r as f32;
        }
        for spec in [
            EngineSpec::muxq(),
            EngineSpec::muxq().with_smooth(0.5),
            EngineSpec::muxq().with_granularity(Granularity::PerTensor, Granularity::PerTensor),
            EngineSpec::naive(),
            EngineSpec::llmint8(),
            EngineSpec::fp16(),
            EngineSpec::muxq().with_bits(8, 4),
            EngineSpec::naive().with_bits(8, 4),
            EngineSpec::resq(),
            EngineSpec::muxq().with_rotate(),
            EngineSpec::muxq().with_rotate().with_permute(),
            EngineSpec::llmint8().with_permute(),
        ] {
            let op = spec.pack(&w, &bias);
            let mut grouped = MatF32::zeros(0, 0);
            op.forward_rows_into(&x, &mut grouped);
            assert_eq!((grouped.rows, grouped.cols), (8, 12), "{}", spec.tag());
            for r in 0..8 {
                let mut row = vec![0.0f32; 12];
                op.forward_row_into(x.row(r), &mut row);
                assert_eq!(grouped.row(r), &row[..], "{} row {r}", spec.tag());
            }
        }
    }

    #[test]
    fn scratch_pool_is_shared_and_thread_deterministic() {
        // the per-thread pool must (a) give every operator the same
        // results it got with private scratch, (b) keep threads fully
        // isolated: N threads hammering DIFFERENT operators concurrently
        // each reproduce the single-threaded answer exactly
        let x = mat(6, 32, 17, &[4], 28.0);
        let w1 = mat(32, 12, 18, &[], 1.0);
        let w2 = mat(32, 8, 19, &[], 1.0);
        let muxq = EngineSpec::muxq().pack(&w1, &vec![0.0; 12]);
        let naive = EngineSpec::naive().pack(&w2, &vec![0.0; 8]);
        // interleaving two operators on ONE thread shares one scratch
        let a1 = muxq.forward(&x);
        let b1 = naive.forward(&x);
        let a2 = muxq.forward(&x);
        assert_eq!(a1.data, a2.data, "interleaved reuse changes nothing");
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    s.spawn(|| {
                        let mut rows = MatF32::zeros(0, 0);
                        for _ in 0..5 {
                            let a = muxq.forward(&x);
                            assert_eq!(a.data, a1.data);
                            let b = naive.forward(&x);
                            assert_eq!(b.data, b1.data);
                            muxq.forward_rows_into(&x, &mut rows);
                        }
                        rows.data
                    })
                })
                .collect();
            let all: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
            for d in &all[1..] {
                assert_eq!(d, &all[0], "thread results identical");
            }
        });
    }

    #[test]
    fn smooth_composition_is_function_preserving_shape() {
        // smoothing moves difficulty, it must not move the answer: the
        // smoothed INT operator stays close to FP, and beats the
        // unsmoothed one on hostile activations at low bits
        let mut x = mat(32, 32, 9, &[], 1.0);
        for r in 0..32 {
            *x.at_mut(r, 7) *= 40.0;
        }
        let w = mat(32, 16, 10, &[], 1.0);
        let exact = matmul_f32(&x, &w);
        let amax = x.absmax_cols();
        let plain = EngineSpec::naive()
            .with_bits(6, 8)
            .pack(&w, &vec![0.0; 16])
            .forward(&x);
        let smooth = EngineSpec::naive()
            .with_bits(6, 8)
            .with_smooth(0.5)
            .pack_calibrated(&w, &vec![0.0; 16], Some(&amax))
            .forward(&x);
        assert!(
            smooth.mean_abs_diff(&exact) < plain.mean_abs_diff(&exact),
            "smooth {} plain {}",
            smooth.mean_abs_diff(&exact),
            plain.mean_abs_diff(&exact)
        );
    }

    #[test]
    fn bytes_accounting_ranks_methods() {
        let w = mat(64, 64, 11, &[], 1.0);
        let bias = vec![0.0f32; 64];
        let fp = EngineSpec::fp16().pack(&w, &bias).bytes();
        let naive = EngineSpec::naive().pack(&w, &bias).bytes();
        let muxq = EngineSpec::muxq().pack(&w, &bias).bytes();
        let mixed = EngineSpec::llmint8().pack(&w, &bias).bytes();
        let naive4 = EngineSpec::naive().with_bits(8, 4).pack(&w, &bias).bytes();
        let resq = EngineSpec::resq().pack(&w, &bias).bytes();
        assert!(naive < fp, "INT8 beats f32 storage");
        assert_eq!(naive, muxq, "MUXQ stores exactly one packed W");
        assert!(mixed > naive, "llm.int8() pays for its resident FP copy");
        assert!(mixed < fp, "but the int+fp16 pair still beats pure f32");
        // W4: nibble panels halve the packed-panel bytes (scales + bias
        // overhead is identical, so total bytes shrink by the panel half)
        assert!(naive4 < naive, "nibble panels beat byte panels");
        assert!(resq > naive4, "resq pays for its rank-r residual");
        assert!(resq < naive, "but W4 + compact residual still beats W8");
    }

    #[test]
    fn plan_prices_through_the_operator() {
        // decode-shaped weight (big enough that the M=1 weight stream,
        // not the array fill/drain, dominates): the INT plan must be
        // DMA-bound and uniform-INT MUXQ must beat mixed precision
        let cfg = NpuConfig::default();
        let w = mat(256, 1024, 12, &[], 1.0);
        let bias = vec![0.0f32; 1024];
        let muxq = EngineSpec::muxq().pack(&w, &bias);
        let mixed = EngineSpec::llmint8().pack(&w, &bias);
        let pm = muxq.plan(&cfg, 1, 8);
        let px = mixed.plan(&cfg, 1, 8);
        assert_eq!(pm.method, Method::Muxq);
        assert!(
            pm.cost(&cfg).cycles() < px.cost(&cfg).cycles(),
            "uniform INT decode beats mixed precision"
        );
        // decode plans are memory-bound — the regime the serving layer
        // lives in (npusim::decode_cost is the aggregate twin)
        assert!(pm.is_memory_bound(&cfg));
    }

    #[test]
    fn host_plan_prices_the_dispatched_datapath() {
        // host_plan must price on NpuConfig::for_kernel(dispatch()):
        // never slower than the scalar-lane config (dispatch retires
        // >= 1 MAC/lane/cycle), identical DMA bytes, and equal to an
        // explicit plan() against the same config
        let w = mat(256, 1024, 30, &[], 1.0);
        let op = EngineSpec::muxq().pack(&w, &vec![0.0f32; 1024]);
        let host_cfg = NpuConfig::for_kernel(crate::quant::simd::dispatch());
        let scalar_cfg = NpuConfig::for_kernel(crate::quant::simd::DispatchKernel::Scalar);
        let hp = op.host_plan(64, 8);
        let explicit = op.plan(&host_cfg, 64, 8);
        assert_eq!(hp.cost(&host_cfg).cycles(), explicit.cost(&host_cfg).cycles());
        assert!(
            hp.cost(&host_cfg).cycles() <= op.plan(&scalar_cfg, 64, 8).cost(&scalar_cfg).cycles()
        );
    }

    #[test]
    fn spec_matmul_is_the_one_dispatch() {
        // the eval path (QuantSpec::matmul's replacement): every method
        // runs through the same trait objects, and on an outlier-bearing
        // input the outlier-aware methods beat naive — the Table 1 shape
        let x = mat(16, 32, 13, &[3], 25.0);
        let w = mat(32, 8, 14, &[], 1.0);
        let exact = matmul_f32(&x, &w);
        let mae = |spec: EngineSpec| {
            let y = spec.matmul(&x, &w);
            assert_eq!((y.rows, y.cols), (16, 8));
            y.mean_abs_diff(&exact)
        };
        assert_eq!(mae(EngineSpec::fp16()), 0.0);
        let naive = mae(EngineSpec::naive());
        let muxq = mae(EngineSpec::muxq());
        let mixed = mae(EngineSpec::llmint8());
        assert!(naive < 0.5, "naive pays for the outlier row scales: {naive}");
        assert!(muxq < 0.2 && muxq < naive, "muxq {muxq} vs naive {naive}");
        assert!(mixed < 0.2 && mixed < naive, "llm.int8() {mixed} vs naive {naive}");
    }
}
