//! MUXQ — the paper's contribution (§3): outlier-channel decomposition
//! enabling *uniform* INT quantization.
//!
//! Rust twin of `ref.fq_muxq` / `quant.quant_linear_int` (python), used by
//! the native engine, Fig.1/Fig.3 regenerators and the NPU-simulator
//! workloads. Cross-validated against python goldens in
//! `tests/golden_quant.rs`.

use super::absmax::{fake_quant, Granularity, Scales};
use super::gemm::{dequant, matmul_i8};
use super::matrix::{MatF32, MatI32, MatI8};
use super::packed::{self, PackedMatI8, ParallelGemm};

/// MUXQ hyper-parameters (paper §3.3).
#[derive(Debug, Clone, Copy)]
pub struct MuxqParams {
    /// outlier criterion: channel has any |x| > theta (LLM.int8() default 6)
    pub theta: f32,
    /// Body = X_outlier >> exp_factor (divide by 2^exp_factor)
    pub exp_factor: u32,
}

impl Default for MuxqParams {
    fn default() -> Self {
        MuxqParams { theta: 6.0, exp_factor: 2 }
    }
}

impl MuxqParams {
    /// 2^exp − 1, the Aux recombination weight of eq. 6/7.
    pub fn aux_weight(&self) -> f32 {
        (1u32 << self.exp_factor) as f32 - 1.0
    }

    pub fn inv_shift(&self) -> f32 {
        1.0 / (1u32 << self.exp_factor) as f32
    }
}

/// Per-channel outlier mask: `mask[c] == true` iff any row has
/// |x[r][c]| > theta.
pub fn outlier_mask(x: &MatF32, theta: f32) -> Vec<bool> {
    let mut mask = Vec::new();
    outlier_mask_into(x, theta, &mut mask);
    mask
}

/// Buffer-reusing twin of [`outlier_mask`] (the zero-allocation
/// projection path in `gpt2::quantized` calls this per projection).
pub fn outlier_mask_into(x: &MatF32, theta: f32, mask: &mut Vec<bool>) {
    mask.clear();
    mask.resize(x.cols, false);
    for r in 0..x.rows {
        let row = x.row(r);
        for (m, v) in mask.iter_mut().zip(row) {
            *m |= v.abs() > theta;
        }
    }
}

/// Count of outlier channels (Aux GEMM width — the "low-rank" r).
pub fn outlier_count(mask: &[bool]) -> usize {
    mask.iter().filter(|m| **m).count()
}

/// Decompose X into (Body, Aux) per paper eqs. 4–5. Both are full-width;
/// Aux is zero outside outlier columns (the *compact* Aux used by the INT
/// pipeline is built by [`gather_outlier_cols`]).
pub fn decompose(x: &MatF32, mask: &[bool], p: &MuxqParams) -> (MatF32, MatF32) {
    assert_eq!(mask.len(), x.cols);
    let inv = p.inv_shift();
    let mut body = MatF32::zeros(x.rows, x.cols);
    let mut aux = MatF32::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let xr = x.row(r);
        let br = &mut body.data[r * x.cols..(r + 1) * x.cols];
        let ar = &mut aux.data[r * x.cols..(r + 1) * x.cols];
        for c in 0..x.cols {
            if mask[c] {
                let v = xr[c] * inv;
                br[c] = v;
                ar[c] = v;
            } else {
                br[c] = xr[c];
            }
        }
    }
    (body, aux)
}

/// Exact reconstruction (paper eq. 6): X = Body + (2^exp − 1) · Aux.
pub fn reconstruct(body: &MatF32, aux: &MatF32, p: &MuxqParams) -> MatF32 {
    let f = p.aux_weight();
    let mut out = body.clone();
    for (o, a) in out.data.iter_mut().zip(&aux.data) {
        *o += f * a;
    }
    out
}

/// MUXQ fake quantization of activations (python ref.fq_muxq twin).
pub fn fq_muxq(x: &MatF32, qmax: f32, gran: Granularity, p: &MuxqParams) -> MatF32 {
    let mask = outlier_mask(x, p.theta);
    let (body, aux) = decompose(x, &mask, p);
    let sb = Scales::compute(&body, qmax, gran);
    let sa = Scales::compute(&aux, qmax, gran);
    let body_q = fake_quant(&body, &sb, qmax);
    let aux_q = fake_quant(&aux, &sa, qmax);
    reconstruct(&body_q, &aux_q, p)
}

/// Gather the outlier columns of X (shifted) into a compact [rows, r]
/// matrix — the skinny Aux operand of the second GEMM in eq. 7.
pub fn gather_outlier_cols(x: &MatF32, mask: &[bool], inv: f32) -> MatF32 {
    let idx: Vec<usize> = mask.iter().enumerate().filter(|(_, m)| **m).map(|(i, _)| i).collect();
    let mut out = MatF32::zeros(x.rows, idx.len());
    for r in 0..x.rows {
        let xr = x.row(r);
        for (j, &c) in idx.iter().enumerate() {
            *out.at_mut(r, j) = xr[c] * inv;
        }
    }
    out
}

/// Gather the matching weight rows into [r, n].
pub fn gather_outlier_rows(w: &MatF32, mask: &[bool]) -> MatF32 {
    let idx: Vec<usize> = mask.iter().enumerate().filter(|(_, m)| **m).map(|(i, _)| i).collect();
    let mut out = MatF32::zeros(idx.len(), w.cols);
    for (j, &r) in idx.iter().enumerate() {
        out.row_mut(j).copy_from_slice(w.row(r));
    }
    out
}

/// The paper's uniform-INT two-GEMM pipeline (eq. 7):
///
///   Y = Body_q8 · W_q8 + (2^exp − 1) · Aux_q8 · W_outlier_rows_q8
///
/// with the *compact* Aux (rows × r). All operands INT8, all accumulation
/// i32 — no FP16 on the compute path, unlike LLM.int8().
///
/// Per-col weight scales (the deployment granularity) on shapes big
/// enough to amortize an on-the-fly pack take the zero-copy route
/// `QuantizedGpt2::proj_int` pioneered: W is quantized and packed
/// ONCE, the body GEMM streams the packed panels, and the Aux GEMM reads
/// its outlier rows straight out of the same packed layout via
/// [`packed::matmul_i8_rows_subset_into`] — no per-call gather of weight
/// rows, no second quantization pass over W. This is bit-exact to the
/// gather formulation because per-col quantization is elementwise in the
/// column scale: quantizing full W and reading subset rows equals
/// gathering subset rows and quantizing with the same (full-W) scales.
/// Per-tensor weight scales keep the gather path — there the subset's
/// abs-max defines its own scale, so the operands genuinely differ.
pub fn muxq_matmul_int(
    x: &MatF32,
    w: &MatF32,
    qmax: f32,
    gx: Granularity,
    gw: Granularity,
    p: &MuxqParams,
) -> MatF32 {
    let mask = outlier_mask(x, p.theta);
    let (body, _) = decompose(x, &mask, p);

    // main GEMM over the full body
    let sb = Scales::compute(&body, qmax, gx);
    let sw = Scales::compute(w, qmax, gw);
    let bq: MatI8 = super::absmax::quantize_i8(&body, &sb, qmax);
    let wq: MatI8 = super::absmax::quantize_i8(w, &sw, qmax);
    let r = outlier_count(&mask);

    // the zero-copy route packs W on the fly, so it must clear the same
    // amortization bar as matmul_i8's packed routing: enough body MACs
    // (and rows) that the O(K·N) pack is noise. Below the bar the gather
    // path wins on traffic — and for PerCol both paths are bit-exact, so
    // the threshold never changes results.
    let use_packed = r > 0
        && gw == Granularity::PerCol
        && bq.rows >= super::gemm::PACK_ON_THE_FLY_MIN_M
        && bq.rows * bq.cols * wq.cols >= super::gemm::PACK_ON_THE_FLY_MACS;

    // body GEMM; the packed layout is kept so the aux GEMM below can
    // read its outlier rows straight out of it (one pack, two GEMMs)
    let (mut y, wp) = if use_packed {
        let wp = PackedMatI8::pack(&wq);
        let mut acc = MatI32::zeros(0, 0);
        packed::matmul_i8_packed_into(&bq, &wp, &mut acc, ParallelGemm::global());
        (dequant(&acc, &sb, &sw), Some(wp))
    } else {
        (dequant(&matmul_i8(&bq, &wq), &sb, &sw), None)
    };

    // skinny aux GEMM over outlier columns only; shared quantize /
    // dequant / recombination, only the GEMM strategy branches
    if r > 0 {
        let aux = gather_outlier_cols(x, &mask, p.inv_shift());
        let sa = Scales::compute(&aux, qmax, gx);
        let aq = super::absmax::quantize_i8(&aux, &sa, qmax);
        let (acc_aux, swo) = match &wp {
            // zero-copy: outlier rows read out of the packed full W by
            // index; full-W per-col scales ARE the subset scales
            Some(wp) => {
                let idx: Vec<usize> =
                    mask.iter().enumerate().filter(|(_, m)| **m).map(|(i, _)| i).collect();
                let mut acc = MatI32::zeros(0, 0);
                packed::matmul_i8_rows_subset_into(&aq, wp, &idx, &mut acc, ParallelGemm::global());
                (acc, sw.clone())
            }
            // gather path: small PerCol shapes below the amortization
            // bar, and non-PerCol granularities (whose subset re-derives
            // its own scales — for PerCol the full-W scales must be kept
            // so the dequant agrees with the fused fake-quant form)
            None => {
                let w_out = gather_outlier_rows(w, &mask);
                let swo = match gw {
                    Granularity::PerCol => sw.clone(),
                    _ => Scales::compute(&w_out, qmax, gw),
                };
                let woq = super::absmax::quantize_i8(&w_out, &swo, qmax);
                (matmul_i8(&aq, &woq), swo)
            }
        };
        let ya = dequant(&acc_aux, &sa, &swo);
        let f = p.aux_weight();
        for (yv, av) in y.data.iter_mut().zip(&ya.data) {
            *yv += f * av;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;

    fn outlier_mat(rows: usize, cols: usize, seed: u64, out_cols: &[usize], scale: f32) -> MatF32 {
        let mut rng = SplitMix64::new(seed);
        let mut m = MatF32::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
        )
        .unwrap();
        for r in 0..rows {
            for &c in out_cols {
                *m.at_mut(r, c) *= scale;
            }
        }
        m
    }

    #[test]
    fn mask_detects_injected_outliers() {
        let x = outlier_mat(32, 16, 1, &[3, 9], 25.0);
        let mask = outlier_mask(&x, 6.0);
        assert!(mask[3] && mask[9]);
        assert!(outlier_count(&mask) >= 2);
    }

    #[test]
    fn decompose_reconstruct_exact() {
        let x = outlier_mat(16, 16, 2, &[0, 5], 30.0);
        let p = MuxqParams::default();
        let mask = outlier_mask(&x, p.theta);
        let (body, aux) = decompose(&x, &mask, &p);
        let rec = reconstruct(&body, &aux, &p);
        assert!(rec.max_abs_diff(&x) < 1e-5);
    }

    #[test]
    fn body_range_reduced() {
        let x = outlier_mat(16, 16, 3, &[2], 40.0);
        let p = MuxqParams::default();
        let mask = outlier_mask(&x, p.theta);
        let (body, _) = decompose(&x, &mask, &p);
        assert!(body.absmax() <= x.absmax() / 4.0 + 1e-6);
    }

    #[test]
    fn muxq_beats_naive_per_tensor() {
        let x = outlier_mat(64, 64, 4, &[1, 17, 40], 25.0);
        let p = MuxqParams::default();
        let e_muxq = fq_muxq(&x, 127.0, Granularity::PerTensor, &p).mean_abs_diff(&x);
        let e_naive =
            super::super::absmax::fq_naive(&x, 127.0, Granularity::PerTensor).mean_abs_diff(&x);
        assert!(e_muxq < e_naive, "muxq {e_muxq} vs naive {e_naive}");
    }

    #[test]
    fn no_outliers_equals_naive() {
        let mut rng = SplitMix64::new(5);
        let x = MatF32::from_vec(
            8,
            8,
            (0..64).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
        )
        .unwrap();
        let p = MuxqParams::default();
        let a = fq_muxq(&x, 127.0, Granularity::PerTensor, &p);
        let b = super::super::absmax::fq_naive(&x, 127.0, Granularity::PerTensor);
        assert!(a.max_abs_diff(&b) < 1e-7);
    }

    #[test]
    fn int_two_gemm_close_to_fp() {
        let x = outlier_mat(32, 48, 6, &[7, 20], 20.0);
        let mut rng = SplitMix64::new(7);
        let w = MatF32::from_vec(
            48,
            16,
            (0..48 * 16).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
        )
        .unwrap();
        let exact = super::super::gemm::matmul_f32(&x, &w);
        let p = MuxqParams::default();
        let y = muxq_matmul_int(&x, &w, 127.0, Granularity::PerRow, Granularity::PerCol, &p);
        let y_naive = super::super::gemm::quant_matmul(
            &x,
            &w,
            127.0,
            Granularity::PerRow,
            Granularity::PerCol,
        );
        // per-row scales absorb outliers partially; muxq should still not
        // be worse, and both should be near FP at 8 bits
        assert!(y.mean_abs_diff(&exact) <= y_naive.mean_abs_diff(&exact) * 1.05);
        assert!(y.mean_abs_diff(&exact) < 0.5);
    }

    #[test]
    fn int_two_gemm_beats_naive_per_tensor_low_bits() {
        let x = outlier_mat(32, 48, 8, &[3, 30], 30.0);
        let mut rng = SplitMix64::new(9);
        let w = MatF32::from_vec(
            48,
            16,
            (0..48 * 16).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
        )
        .unwrap();
        let exact = super::super::gemm::matmul_f32(&x, &w);
        let qmax = 31.0; // 6-bit
        let p = MuxqParams::default();
        let y_muxq =
            muxq_matmul_int(&x, &w, qmax, Granularity::PerTensor, Granularity::PerTensor, &p);
        let y_naive = super::super::gemm::quant_matmul(
            &x,
            &w,
            qmax,
            Granularity::PerTensor,
            Granularity::PerTensor,
        );
        assert!(
            y_muxq.mean_abs_diff(&exact) < y_naive.mean_abs_diff(&exact),
            "muxq {} naive {}",
            y_muxq.mean_abs_diff(&exact),
            y_naive.mean_abs_diff(&exact)
        );
    }

    #[test]
    fn exp_factor_one_simple_sum() {
        // with exp=1 the recombination weight is exactly 1 (paper §3.3)
        let p = MuxqParams { theta: 6.0, exp_factor: 1 };
        assert_eq!(p.aux_weight(), 1.0);
        assert_eq!(p.inv_shift(), 0.5);
    }
}
