//! aarch64 NEON microkernels: `sdot` quad accumulation (ARMv8.2
//! `dotprod`) with an `smlal` widening-pair fallback, against the
//! K-major packed panels of `super::super::packed`.
//!
//! `sdot` path (per K-quad, per panel): `vdotq_s32` retires FOUR i8
//! MACs per i32 lane, but wants each lane's four bytes to be the four
//! K-values of ONE output column — a 4×N transpose of the panel's
//! row-major quad. The transpose happens in registers with `tbl`
//! (constant index vectors, 1–2 lookups per quad), amortized over the
//! tile's M rows:
//!
//! ```text
//!   rows k..k+4 of the panel (N=8): 32 contiguous bytes
//!   q0 = tbl2[ 0 8 16 24 | 1 9 17 25 | 2 10 18 26 | 3 11 19 27 ]
//!   q1 = tbl2[ 4 12 20 28 | … ]          (column quads j=0..4 / 4..8)
//!   ab = dup32( a[4t..4t+4] )            (A quad broadcast per row)
//!   acc.s[j] += q·ab                     (vdotq_s32: 4 MACs/lane)
//! ```
//!
//! `smlal` path (no `dotprod`): the two B rows of a k-pair are widened
//! to i16 (`sshll`) and `vmlal_s16` accumulates each against a
//! broadcast A element — the pair structure of the scalar kernel, with
//! the sums formed in i32.
//!
//! Exactness: both paths widen products into i32 accumulators
//! (`sdot`'s 4-way sum and `smlal`'s widening MAC are architecturally
//! exact), so like the AVX2 twin they are bit-exact for EVERY i8 input
//! including −128 — no wide-i32 fallback needed. K and index-list
//! tails (k mod 4 / mod 2) take scalar steps; packed zero-pad rows are
//! never read.
//!
//! Safety: NEON is baseline on aarch64; the `sdot` functions
//! additionally require `dotprod`, which `micro_dense`/`micro_idx`
//! check via the cached [`super::host_caps`] probe.

use super::{tail_step, tail_step_w4};
use std::arch::aarch64::*;

/// tbl indices: column quads j=0..4 of a row-major 4×8 byte block.
const TBL8_LO: [u8; 16] = [0, 8, 16, 24, 1, 9, 17, 25, 2, 10, 18, 26, 3, 11, 19, 27];
/// tbl indices: column quads j=4..8 of a row-major 4×8 byte block.
const TBL8_HI: [u8; 16] = [4, 12, 20, 28, 5, 13, 21, 29, 6, 14, 22, 30, 7, 15, 23, 31];
/// tbl indices: column quads of a row-major 4×4 byte block.
const TBL4: [u8; 16] = [0, 4, 8, 12, 1, 5, 9, 13, 2, 6, 10, 14, 3, 7, 11, 15];

/// Dense microkernel: `acc[i][j] += Σ_{kk<k} a[i][kk] · panel[kk·N + j]`.
///
/// # Safety
/// aarch64/NEON only. `panel` must hold at least `k` rows of `N` bytes;
/// every `a[i]` at least `k` elements.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn micro_dense<const M: usize, const N: usize>(
    k: usize,
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    debug_assert!(N == 4 || N == 8);
    debug_assert!(panel.len() >= k * N);
    unsafe {
        if super::host_caps().neon_dot {
            dense_dot::<M, N>(k, a, panel, acc);
        } else {
            dense_mlal::<M, N>(k, a, panel, acc);
        }
    }
}

/// Rows-subset (Aux) microkernel: contraction walks `idx`, B rows read
/// from arbitrary panel offsets.
///
/// # Safety
/// aarch64/NEON only. Every `idx[t]` must be a valid panel row; every
/// `a[i]` at least `idx.len()` elements.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn micro_idx<const M: usize, const N: usize>(
    idx: &[usize],
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    debug_assert!(N == 4 || N == 8);
    unsafe {
        if super::host_caps().neon_dot {
            idx_dot::<M, N>(idx, a, panel, acc);
        } else {
            idx_mlal::<M, N>(idx, a, panel, acc);
        }
    }
}

/// Broadcast the A quad `a[at..at+4]` across all four i32 lanes.
#[target_feature(enable = "neon")]
#[inline]
unsafe fn a_quad(a: &[i8], at: usize) -> int8x16_t {
    unsafe {
        let w = (a.as_ptr().add(at) as *const u32).read_unaligned();
        vreinterpretq_s8_u32(vdupq_n_u32(w))
    }
}

/// Transpose a gathered 4×8 block (two combined row pairs) into column
/// quads for the two output half-registers.
#[target_feature(enable = "neon,dotprod")]
#[inline]
unsafe fn quads8(r01: int8x16_t, r23: int8x16_t) -> (int8x16_t, int8x16_t) {
    unsafe {
        let tb = int8x16x2_t(r01, r23);
        (vqtbl2q_s8(tb, vld1q_u8(TBL8_LO.as_ptr())), vqtbl2q_s8(tb, vld1q_u8(TBL8_HI.as_ptr())))
    }
}

#[target_feature(enable = "neon,dotprod")]
unsafe fn dense_dot<const M: usize, const N: usize>(
    k: usize,
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    let bp = panel.as_ptr();
    let accp = acc as *mut _ as *mut i32;
    unsafe {
        if N == 8 {
            let mut acc0 = [vdupq_n_s32(0); M];
            let mut acc1 = [vdupq_n_s32(0); M];
            for t in 0..k / 4 {
                let (q0, q1) =
                    quads8(vld1q_s8(bp.add(4 * t * 8)), vld1q_s8(bp.add(4 * t * 8 + 16)));
                for i in 0..M {
                    let ab = a_quad(a[i], 4 * t);
                    acc0[i] = vdotq_s32(acc0[i], q0, ab);
                    acc1[i] = vdotq_s32(acc1[i], q1, ab);
                }
            }
            store8::<M>(accp, &acc0, &acc1);
        } else {
            let tq = vld1q_u8(TBL4.as_ptr());
            let mut vacc = [vdupq_n_s32(0); M];
            for t in 0..k / 4 {
                let q = vqtbl1q_s8(vld1q_s8(bp.add(4 * t * 4)), tq);
                for i in 0..M {
                    vacc[i] = vdotq_s32(vacc[i], q, a_quad(a[i], 4 * t));
                }
            }
            store4::<M>(accp, &vacc);
        }
        for kk in (k - k % 4)..k {
            tail_step::<M, N>(kk, kk, a, bp, accp);
        }
    }
}

#[target_feature(enable = "neon,dotprod")]
unsafe fn idx_dot<const M: usize, const N: usize>(
    idx: &[usize],
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    let bp = panel.as_ptr();
    let accp = acc as *mut _ as *mut i32;
    let r = idx.len();
    unsafe {
        if N == 8 {
            let mut acc0 = [vdupq_n_s32(0); M];
            let mut acc1 = [vdupq_n_s32(0); M];
            for t in 0..r / 4 {
                let r01 = vcombine_s8(
                    vld1_s8(bp.add(idx[4 * t] * 8)),
                    vld1_s8(bp.add(idx[4 * t + 1] * 8)),
                );
                let r23 = vcombine_s8(
                    vld1_s8(bp.add(idx[4 * t + 2] * 8)),
                    vld1_s8(bp.add(idx[4 * t + 3] * 8)),
                );
                let (q0, q1) = quads8(r01, r23);
                for i in 0..M {
                    let ab = a_quad(a[i], 4 * t);
                    acc0[i] = vdotq_s32(acc0[i], q0, ab);
                    acc1[i] = vdotq_s32(acc1[i], q1, ab);
                }
            }
            store8::<M>(accp, &acc0, &acc1);
        } else {
            let tq = vld1q_u8(TBL4.as_ptr());
            let mut vacc = [vdupq_n_s32(0); M];
            for t in 0..r / 4 {
                let rows: [u32; 4] = [
                    (bp.add(idx[4 * t] * 4) as *const u32).read_unaligned(),
                    (bp.add(idx[4 * t + 1] * 4) as *const u32).read_unaligned(),
                    (bp.add(idx[4 * t + 2] * 4) as *const u32).read_unaligned(),
                    (bp.add(idx[4 * t + 3] * 4) as *const u32).read_unaligned(),
                ];
                let q = vqtbl1q_s8(vld1q_s8(rows.as_ptr() as *const i8), tq);
                for i in 0..M {
                    vacc[i] = vdotq_s32(vacc[i], q, a_quad(a[i], 4 * t));
                }
            }
            store4::<M>(accp, &vacc);
        }
        for t in (r - r % 4)..r {
            tail_step::<M, N>(t, idx[t], a, bp, accp);
        }
    }
}

#[target_feature(enable = "neon")]
unsafe fn dense_mlal<const M: usize, const N: usize>(
    k: usize,
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    let bp = panel.as_ptr();
    let accp = acc as *mut _ as *mut i32;
    unsafe {
        if N == 8 {
            let mut acc0 = [vdupq_n_s32(0); M];
            let mut acc1 = [vdupq_n_s32(0); M];
            for t in 0..k / 2 {
                let b0 = vmovl_s8(vld1_s8(bp.add(2 * t * 8)));
                let b1 = vmovl_s8(vld1_s8(bp.add((2 * t + 1) * 8)));
                for i in 0..M {
                    let lo = vdup_n_s16(a[i][2 * t] as i16);
                    let hi = vdup_n_s16(a[i][2 * t + 1] as i16);
                    acc0[i] = vmlal_s16(acc0[i], vget_low_s16(b0), lo);
                    acc1[i] = vmlal_s16(acc1[i], vget_high_s16(b0), lo);
                    acc0[i] = vmlal_s16(acc0[i], vget_low_s16(b1), hi);
                    acc1[i] = vmlal_s16(acc1[i], vget_high_s16(b1), hi);
                }
            }
            store8::<M>(accp, &acc0, &acc1);
        } else {
            let mut vacc = [vdupq_n_s32(0); M];
            for t in 0..k / 2 {
                let w0 = (bp.add(2 * t * 4) as *const u32).read_unaligned();
                let w1 = (bp.add((2 * t + 1) * 4) as *const u32).read_unaligned();
                let b0 = vget_low_s16(vmovl_s8(vcreate_s8(w0 as u64)));
                let b1 = vget_low_s16(vmovl_s8(vcreate_s8(w1 as u64)));
                for i in 0..M {
                    vacc[i] = vmlal_s16(vacc[i], b0, vdup_n_s16(a[i][2 * t] as i16));
                    vacc[i] = vmlal_s16(vacc[i], b1, vdup_n_s16(a[i][2 * t + 1] as i16));
                }
            }
            store4::<M>(accp, &vacc);
        }
        if k % 2 == 1 {
            tail_step::<M, N>(k - 1, k - 1, a, bp, accp);
        }
    }
}

#[target_feature(enable = "neon")]
unsafe fn idx_mlal<const M: usize, const N: usize>(
    idx: &[usize],
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    let bp = panel.as_ptr();
    let accp = acc as *mut _ as *mut i32;
    let r = idx.len();
    unsafe {
        if N == 8 {
            let mut acc0 = [vdupq_n_s32(0); M];
            let mut acc1 = [vdupq_n_s32(0); M];
            for t in 0..r / 2 {
                let b0 = vmovl_s8(vld1_s8(bp.add(idx[2 * t] * 8)));
                let b1 = vmovl_s8(vld1_s8(bp.add(idx[2 * t + 1] * 8)));
                for i in 0..M {
                    let lo = vdup_n_s16(a[i][2 * t] as i16);
                    let hi = vdup_n_s16(a[i][2 * t + 1] as i16);
                    acc0[i] = vmlal_s16(acc0[i], vget_low_s16(b0), lo);
                    acc1[i] = vmlal_s16(acc1[i], vget_high_s16(b0), lo);
                    acc0[i] = vmlal_s16(acc0[i], vget_low_s16(b1), hi);
                    acc1[i] = vmlal_s16(acc1[i], vget_high_s16(b1), hi);
                }
            }
            store8::<M>(accp, &acc0, &acc1);
        } else {
            let mut vacc = [vdupq_n_s32(0); M];
            for t in 0..r / 2 {
                let w0 = (bp.add(idx[2 * t] * 4) as *const u32).read_unaligned();
                let w1 = (bp.add(idx[2 * t + 1] * 4) as *const u32).read_unaligned();
                let b0 = vget_low_s16(vmovl_s8(vcreate_s8(w0 as u64)));
                let b1 = vget_low_s16(vmovl_s8(vcreate_s8(w1 as u64)));
                for i in 0..M {
                    vacc[i] = vmlal_s16(vacc[i], b0, vdup_n_s16(a[i][2 * t] as i16));
                    vacc[i] = vmlal_s16(vacc[i], b1, vdup_n_s16(a[i][2 * t + 1] as i16));
                }
            }
            store4::<M>(accp, &vacc);
        }
        if r % 2 == 1 {
            let t = r - 1;
            tail_step::<M, N>(t, idx[t], a, bp, accp);
        }
    }
}

/// Accumulate the vector accumulators into the caller's `acc` rows
/// (N = 8: two i32x4 halves per row).
#[target_feature(enable = "neon")]
#[inline]
unsafe fn store8<const M: usize>(accp: *mut i32, acc0: &[int32x4_t; M], acc1: &[int32x4_t; M]) {
    unsafe {
        for i in 0..M {
            let p0 = accp.add(i * 8);
            vst1q_s32(p0, vaddq_s32(vld1q_s32(p0), acc0[i]));
            let p1 = accp.add(i * 8 + 4);
            vst1q_s32(p1, vaddq_s32(vld1q_s32(p1), acc1[i]));
        }
    }
}

/// Accumulate the vector accumulators into the caller's `acc` rows (N = 4).
#[target_feature(enable = "neon")]
#[inline]
unsafe fn store4<const M: usize>(accp: *mut i32, vacc: &[int32x4_t; M]) {
    unsafe {
        for i in 0..M {
            let p = accp.add(i * 4);
            vst1q_s32(p, vaddq_s32(vld1q_s32(p), vacc[i]));
        }
    }
}

// --------------------------------------------------- W4 (nibble) twins
//
// `PackedMatI4` stores a whole k-pair per byte row (even k low nibble,
// odd k high nibble). Expansion is two shifts: `sshl #4` then `sshr #4`
// sign-extends the low nibble, a bare `sshr #4` the high nibble. The
// expanded bytes feed the SAME `sdot` quad / `smlal` pair bodies as the
// i8 kernels — zips replace the `tbl` transpose because the nibble
// expansion already splits even/odd k rows into separate registers.

/// Sign-extend both nibbles of 8 packed bytes: returns (even-k row,
/// odd-k row) as i8 lanes.
#[target_feature(enable = "neon")]
#[inline]
unsafe fn nibbles8(b: int8x8_t) -> (int8x8_t, int8x8_t) {
    unsafe { (vshr_n_s8::<4>(vshl_n_s8::<4>(b)), vshr_n_s8::<4>(b)) }
}

/// 16-byte (two byte rows = one k-quad at N=8) variant of [`nibbles8`].
#[target_feature(enable = "neon")]
#[inline]
unsafe fn nibbles16(b: int8x16_t) -> (int8x16_t, int8x16_t) {
    unsafe { (vshrq_n_s8::<4>(vshlq_n_s8::<4>(b)), vshrq_n_s8::<4>(b)) }
}

/// Expand the logical k row `krow` of an 8-wide nibble panel.
#[target_feature(enable = "neon")]
#[inline]
unsafe fn nibble_row8(bp: *const u8, krow: usize) -> int8x8_t {
    unsafe {
        let (lo, hi) = nibbles8(vld1_s8(bp.add((krow >> 1) * 8) as *const i8));
        if krow & 1 == 1 {
            hi
        } else {
            lo
        }
    }
}

/// 4-wide panel variant of [`nibble_row8`] (valid data in lanes 0..4).
#[target_feature(enable = "neon")]
#[inline]
unsafe fn nibble_row4(bp: *const u8, krow: usize) -> int8x8_t {
    unsafe {
        let w = (bp.add((krow >> 1) * 4) as *const u32).read_unaligned();
        let (lo, hi) = nibbles8(vcreate_s8(w as u64));
        if krow & 1 == 1 {
            hi
        } else {
            lo
        }
    }
}

/// Transpose a loaded 16-byte nibble block (byte rows 2q, 2q+1 = k rows
/// 4q..4q+4 at N=8) into the column-quad registers `vdotq_s32` wants —
/// zip twice: bytes (pairing k rows 4q/4q+1 and 4q+2/4q+3 per column),
/// then u16 lanes (merging the two pairs into column quads).
#[target_feature(enable = "neon,dotprod")]
#[inline]
unsafe fn quads8_w4(b: int8x16_t) -> (int8x16_t, int8x16_t) {
    unsafe {
        let (lo, hi) = nibbles16(b);
        let z0 = vreinterpretq_u16_s8(vzip1q_s8(lo, hi));
        let z1 = vreinterpretq_u16_s8(vzip2q_s8(lo, hi));
        (vreinterpretq_s8_u16(vzip1q_u16(z0, z1)), vreinterpretq_s8_u16(vzip2q_u16(z0, z1)))
    }
}

/// Column-quad transpose of four gathered k rows (N=8, the idx path):
/// same double-zip as [`quads8_w4`] from separate row registers.
#[target_feature(enable = "neon,dotprod")]
#[inline]
unsafe fn quads8_rows(
    r0: int8x8_t,
    r1: int8x8_t,
    r2: int8x8_t,
    r3: int8x8_t,
) -> (int8x16_t, int8x16_t) {
    unsafe {
        let z01 = vzip_s8(r0, r1);
        let z23 = vzip_s8(r2, r3);
        let a0 = vreinterpret_u16_s8(z01.0);
        let a1 = vreinterpret_u16_s8(z01.1);
        let b0 = vreinterpret_u16_s8(z23.0);
        let b1 = vreinterpret_u16_s8(z23.1);
        let q0 = vzip_u16(a0, b0);
        let q1 = vzip_u16(a1, b1);
        (
            vreinterpretq_s8_u16(vcombine_u16(q0.0, q0.1)),
            vreinterpretq_s8_u16(vcombine_u16(q1.0, q1.1)),
        )
    }
}

/// Column-quad transpose of four k rows at N=4 (lanes 0..4 of each row
/// register valid): one byte zip + one u16 zip fills a single q vector.
#[target_feature(enable = "neon,dotprod")]
#[inline]
unsafe fn quads4_rows(r0: int8x8_t, r1: int8x8_t, r2: int8x8_t, r3: int8x8_t) -> int8x16_t {
    unsafe {
        let z01 = vreinterpret_u16_s8(vzip_s8(r0, r1).0);
        let z23 = vreinterpret_u16_s8(vzip_s8(r2, r3).0);
        let q = vzip_u16(z01, z23);
        vreinterpretq_s8_u16(vcombine_u16(q.0, q.1))
    }
}

/// Dense W4 microkernel: nibble panel, same contract as [`micro_dense`].
///
/// # Safety
/// aarch64/NEON only. `panel` must hold at least `ceil(k/2)` byte rows
/// of `N` bytes; every `a[i]` at least `k` elements.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn micro_dense_w4<const M: usize, const N: usize>(
    k: usize,
    a: &[&[i8]; M],
    panel: &[u8],
    acc: &mut [[i32; N]; M],
) {
    debug_assert!(N == 4 || N == 8);
    debug_assert!(panel.len() >= k.div_ceil(2) * N);
    unsafe {
        if super::host_caps().neon_dot {
            dense_dot_w4::<M, N>(k, a, panel, acc);
        } else {
            dense_mlal_w4::<M, N>(k, a, panel, acc);
        }
    }
}

/// Rows-subset (Aux) W4 microkernel: contraction walks `idx`, each
/// indexed k row expanded from its nibble.
///
/// # Safety
/// aarch64/NEON only. Every `idx[t]` must be a valid logical panel row;
/// every `a[i]` at least `idx.len()` elements.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn micro_idx_w4<const M: usize, const N: usize>(
    idx: &[usize],
    a: &[&[i8]; M],
    panel: &[u8],
    acc: &mut [[i32; N]; M],
) {
    debug_assert!(N == 4 || N == 8);
    unsafe {
        if super::host_caps().neon_dot {
            idx_dot_w4::<M, N>(idx, a, panel, acc);
        } else {
            idx_mlal_w4::<M, N>(idx, a, panel, acc);
        }
    }
}

#[target_feature(enable = "neon,dotprod")]
unsafe fn dense_dot_w4<const M: usize, const N: usize>(
    k: usize,
    a: &[&[i8]; M],
    panel: &[u8],
    acc: &mut [[i32; N]; M],
) {
    let bp = panel.as_ptr();
    let accp = acc as *mut _ as *mut i32;
    unsafe {
        if N == 8 {
            let mut acc0 = [vdupq_n_s32(0); M];
            let mut acc1 = [vdupq_n_s32(0); M];
            for t in 0..k / 4 {
                // 16 bytes = byte rows 2t, 2t+1 = k rows 4t..4t+4
                let (q0, q1) = quads8_w4(vld1q_s8(bp.add(t * 16) as *const i8));
                for i in 0..M {
                    let ab = a_quad(a[i], 4 * t);
                    acc0[i] = vdotq_s32(acc0[i], q0, ab);
                    acc1[i] = vdotq_s32(acc1[i], q1, ab);
                }
            }
            store8::<M>(accp, &acc0, &acc1);
        } else {
            let mut vacc = [vdupq_n_s32(0); M];
            for t in 0..k / 4 {
                // 8 bytes = byte rows 2t, 2t+1 = k rows 4t..4t+4
                let (lo, hi) = nibbles8(vld1_s8(bp.add(t * 8) as *const i8));
                // lo lanes: rows 4t (0..4) and 4t+2 (4..8); hi: 4t+1, 4t+3
                let q = quads4_rows(
                    lo,
                    hi,
                    vreinterpret_s8_u32(vdup_lane_u32::<1>(vreinterpret_u32_s8(lo))),
                    vreinterpret_s8_u32(vdup_lane_u32::<1>(vreinterpret_u32_s8(hi))),
                );
                for i in 0..M {
                    vacc[i] = vdotq_s32(vacc[i], q, a_quad(a[i], 4 * t));
                }
            }
            store4::<M>(accp, &vacc);
        }
        for kk in (k - k % 4)..k {
            tail_step_w4::<M, N>(kk, kk, a, bp, accp);
        }
    }
}

#[target_feature(enable = "neon,dotprod")]
unsafe fn idx_dot_w4<const M: usize, const N: usize>(
    idx: &[usize],
    a: &[&[i8]; M],
    panel: &[u8],
    acc: &mut [[i32; N]; M],
) {
    let bp = panel.as_ptr();
    let accp = acc as *mut _ as *mut i32;
    let r = idx.len();
    unsafe {
        if N == 8 {
            let mut acc0 = [vdupq_n_s32(0); M];
            let mut acc1 = [vdupq_n_s32(0); M];
            for t in 0..r / 4 {
                let (q0, q1) = quads8_rows(
                    nibble_row8(bp, idx[4 * t]),
                    nibble_row8(bp, idx[4 * t + 1]),
                    nibble_row8(bp, idx[4 * t + 2]),
                    nibble_row8(bp, idx[4 * t + 3]),
                );
                for i in 0..M {
                    let ab = a_quad(a[i], 4 * t);
                    acc0[i] = vdotq_s32(acc0[i], q0, ab);
                    acc1[i] = vdotq_s32(acc1[i], q1, ab);
                }
            }
            store8::<M>(accp, &acc0, &acc1);
        } else {
            let mut vacc = [vdupq_n_s32(0); M];
            for t in 0..r / 4 {
                let q = quads4_rows(
                    nibble_row4(bp, idx[4 * t]),
                    nibble_row4(bp, idx[4 * t + 1]),
                    nibble_row4(bp, idx[4 * t + 2]),
                    nibble_row4(bp, idx[4 * t + 3]),
                );
                for i in 0..M {
                    vacc[i] = vdotq_s32(vacc[i], q, a_quad(a[i], 4 * t));
                }
            }
            store4::<M>(accp, &vacc);
        }
        for t in (r - r % 4)..r {
            tail_step_w4::<M, N>(t, idx[t], a, bp, accp);
        }
    }
}

#[target_feature(enable = "neon")]
unsafe fn dense_mlal_w4<const M: usize, const N: usize>(
    k: usize,
    a: &[&[i8]; M],
    panel: &[u8],
    acc: &mut [[i32; N]; M],
) {
    let bp = panel.as_ptr();
    let accp = acc as *mut _ as *mut i32;
    unsafe {
        if N == 8 {
            let mut acc0 = [vdupq_n_s32(0); M];
            let mut acc1 = [vdupq_n_s32(0); M];
            for t in 0..k / 2 {
                let (lo, hi) = nibbles8(vld1_s8(bp.add(t * 8) as *const i8));
                let b0 = vmovl_s8(lo);
                let b1 = vmovl_s8(hi);
                for i in 0..M {
                    let av_lo = vdup_n_s16(a[i][2 * t] as i16);
                    let av_hi = vdup_n_s16(a[i][2 * t + 1] as i16);
                    acc0[i] = vmlal_s16(acc0[i], vget_low_s16(b0), av_lo);
                    acc1[i] = vmlal_s16(acc1[i], vget_high_s16(b0), av_lo);
                    acc0[i] = vmlal_s16(acc0[i], vget_low_s16(b1), av_hi);
                    acc1[i] = vmlal_s16(acc1[i], vget_high_s16(b1), av_hi);
                }
            }
            store8::<M>(accp, &acc0, &acc1);
        } else {
            let mut vacc = [vdupq_n_s32(0); M];
            for t in 0..k / 2 {
                let w = (bp.add(t * 4) as *const u32).read_unaligned();
                let (lo, hi) = nibbles8(vcreate_s8(w as u64));
                let b0 = vget_low_s16(vmovl_s8(lo));
                let b1 = vget_low_s16(vmovl_s8(hi));
                for i in 0..M {
                    vacc[i] = vmlal_s16(vacc[i], b0, vdup_n_s16(a[i][2 * t] as i16));
                    vacc[i] = vmlal_s16(vacc[i], b1, vdup_n_s16(a[i][2 * t + 1] as i16));
                }
            }
            store4::<M>(accp, &vacc);
        }
        if k % 2 == 1 {
            tail_step_w4::<M, N>(k - 1, k - 1, a, bp, accp);
        }
    }
}

#[target_feature(enable = "neon")]
unsafe fn idx_mlal_w4<const M: usize, const N: usize>(
    idx: &[usize],
    a: &[&[i8]; M],
    panel: &[u8],
    acc: &mut [[i32; N]; M],
) {
    let bp = panel.as_ptr();
    let accp = acc as *mut _ as *mut i32;
    let r = idx.len();
    unsafe {
        if N == 8 {
            let mut acc0 = [vdupq_n_s32(0); M];
            let mut acc1 = [vdupq_n_s32(0); M];
            for t in 0..r / 2 {
                let b0 = vmovl_s8(nibble_row8(bp, idx[2 * t]));
                let b1 = vmovl_s8(nibble_row8(bp, idx[2 * t + 1]));
                for i in 0..M {
                    let av_lo = vdup_n_s16(a[i][2 * t] as i16);
                    let av_hi = vdup_n_s16(a[i][2 * t + 1] as i16);
                    acc0[i] = vmlal_s16(acc0[i], vget_low_s16(b0), av_lo);
                    acc1[i] = vmlal_s16(acc1[i], vget_high_s16(b0), av_lo);
                    acc0[i] = vmlal_s16(acc0[i], vget_low_s16(b1), av_hi);
                    acc1[i] = vmlal_s16(acc1[i], vget_high_s16(b1), av_hi);
                }
            }
            store8::<M>(accp, &acc0, &acc1);
        } else {
            let mut vacc = [vdupq_n_s32(0); M];
            for t in 0..r / 2 {
                let b0 = vget_low_s16(vmovl_s8(nibble_row4(bp, idx[2 * t])));
                let b1 = vget_low_s16(vmovl_s8(nibble_row4(bp, idx[2 * t + 1])));
                for i in 0..M {
                    vacc[i] = vmlal_s16(vacc[i], b0, vdup_n_s16(a[i][2 * t] as i16));
                    vacc[i] = vmlal_s16(vacc[i], b1, vdup_n_s16(a[i][2 * t + 1] as i16));
                }
            }
            store4::<M>(accp, &vacc);
        }
        if r % 2 == 1 {
            let t = r - 1;
            tail_step_w4::<M, N>(t, idx[t], a, bp, accp);
        }
    }
}

// K / index scalar tails: `super::tail_step` / `tail_step_w4` (shared
// with AVX2).
