//! Per-arch SIMD microkernels behind one-time runtime dispatch — the
//! "hardware-friendly" half of MUXQ's pitch made literal. The paper's
//! argument (and FineQ/DuQuant's measurements) is that a *uniform* INT8
//! compute path wins only when the kernel actually exploits the integer
//! datapath; until this module the engine leaned on autovectorization of
//! the scalar pair kernel (`super::packed`). Now every hot contraction —
//! the dense MR×NR microkernel, the rows-subset Aux kernel, and the
//! skinny-M GEMV path — has explicit per-arch twins:
//!
//! * **x86-64 AVX2** (`avx2.rs`): `pmaddwd`-class pair accumulation. Each
//!   k-pair of a B panel is byte-interleaved and sign-extended to i16;
//!   `_mm256_madd_epi16` against a broadcast A pair retires two i8 MACs
//!   per lane with the pair sum formed *in i32* — so unlike the scalar
//!   i16 pair kernel the SIMD path is exact for **every** i8 input,
//!   including the `(-128)·(-128)+(-128)·(-128)` corner that forces the
//!   scalar pair kernel's wide fallback. (`_mm256_maddubs_epi16`'s
//!   u8×i8 form was rejected: its i16 saturation breaks bit-exactness.)
//! * **aarch64 NEON** (`neon.rs`): `sdot` quad accumulation when the
//!   `dotprod` extension is present (4 i8 MACs per i32 lane; B panels
//!   are quad-transposed in registers with `tbl`), `smlal` widening pair
//!   accumulation otherwise. Both form sums in i32 — exact for every i8
//!   input, same as AVX2.
//!
//! Every contraction also has a **W4 nibble twin** (`micro_dense_w4` /
//! `micro_idx_w4`): the packed-nibble panels of
//! [`super::packed::PackedMatI4`] are expanded in-register — AVX2 with
//! shift+mask and an XOR-based sign extension feeding the SAME
//! `pmaddwd` pair loop, NEON with `shl`/`sshr` nibble expansion feeding
//! the same `sdot`/`smlal` bodies — so the W4A8 path halves the weight
//! bytes streamed without touching the accumulate math (which is
//! trivially exact at |w| ≤ 8).
//!
//! # Dispatch
//!
//! [`dispatch`] resolves ONCE per process (cached in a `OnceLock`):
//! `MUXQ_FORCE_KERNEL={scalar,pair,avx2,neon}` if set — unknown values
//! warn and fall back to `scalar`; a kernel the host cannot run is a
//! clean panic, never UB — otherwise the best kernel the host supports
//! (`is_x86_feature_detected!("avx2")` / aarch64 NEON baseline). The
//! resolved kernel steers [`super::packed::Kernel::Auto`] routing and
//! the per-arch [`super::packed::TileConfig`] tile tables; explicit
//! `Kernel::{PairI16,WideI32,Simd}` requests bypass the env so every
//! path stays independently selectable under test (the CI matrix runs
//! the whole suite under each forced kernel on both architectures).
//!
//! Exactness contract: every kernel here is pinned bit-exact against
//! the scalar pair kernel and the wide-i32 oracle by proptests
//! (`tests/proptest_invariants.rs`) across the full tile grid, ragged
//! shapes, and the −128 corner.

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;

use std::sync::OnceLock;

/// Which microkernel family the runtime dispatcher resolved. The names
/// are the `MUXQ_FORCE_KERNEL` spellings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchKernel {
    /// Scalar wide-i32 (one MAC per lane per widening; exact for all
    /// inputs — the PR-1 scheme and the universal fallback).
    Scalar,
    /// Scalar i16 pair accumulation (two MACs per lane, autovectorized;
    /// −128-in-B routes to the wide kernel — the PR-2 default).
    Pair,
    /// AVX2 `pmaddwd` pair path (x86-64 only).
    Avx2,
    /// NEON `sdot`/`smlal` path (aarch64 only).
    Neon,
}

impl DispatchKernel {
    /// The canonical spelling (round-trips through [`DispatchKernel::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            DispatchKernel::Scalar => "scalar",
            DispatchKernel::Pair => "pair",
            DispatchKernel::Avx2 => "avx2",
            DispatchKernel::Neon => "neon",
        }
    }

    /// Parse a `MUXQ_FORCE_KERNEL` value (trimmed, case-insensitive).
    pub fn parse(s: &str) -> Option<DispatchKernel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(DispatchKernel::Scalar),
            "pair" => Some(DispatchKernel::Pair),
            "avx2" => Some(DispatchKernel::Avx2),
            "neon" => Some(DispatchKernel::Neon),
            _ => None,
        }
    }

    /// Whether this kernel runs explicit SIMD intrinsics (vs scalar code).
    pub fn is_simd(self) -> bool {
        matches!(self, DispatchKernel::Avx2 | DispatchKernel::Neon)
    }
}

/// What the host can actually run (probed once, see [`host_caps`]).
#[derive(Debug, Clone, Copy)]
pub struct HostCaps {
    /// x86-64 with AVX2.
    pub avx2: bool,
    /// aarch64 NEON (baseline on every aarch64 target).
    pub neon: bool,
    /// aarch64 `dotprod` extension (`sdot`) — selects the quad kernel
    /// inside the NEON path; without it NEON uses `smlal` pairs.
    pub neon_dot: bool,
}

/// Probe the host ISA once (cached; the probes themselves are cheap but
/// the kernels consult this per GEMM call).
pub fn host_caps() -> HostCaps {
    static CAPS: OnceLock<HostCaps> = OnceLock::new();
    *CAPS.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            HostCaps { avx2: is_x86_feature_detected!("avx2"), neon: false, neon_dot: false }
        }
        #[cfg(target_arch = "aarch64")]
        {
            HostCaps {
                avx2: false,
                neon: true,
                neon_dot: std::arch::is_aarch64_feature_detected!("dotprod"),
            }
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            HostCaps { avx2: false, neon: false, neon_dot: false }
        }
    })
}

/// The SIMD kernel this host supports, independent of any env override
/// (the `Kernel::Simd` explicit-selection hook checks this).
pub fn host_simd() -> Option<DispatchKernel> {
    let caps = host_caps();
    if caps.avx2 {
        Some(DispatchKernel::Avx2)
    } else if caps.neon {
        Some(DispatchKernel::Neon)
    } else {
        None
    }
}

/// Best kernel for a host: its SIMD ISA when present, else the portable
/// scalar pair kernel (the pre-SIMD default).
pub fn auto_kernel(caps: &HostCaps) -> DispatchKernel {
    if caps.avx2 {
        DispatchKernel::Avx2
    } else if caps.neon {
        DispatchKernel::Neon
    } else {
        DispatchKernel::Pair
    }
}

/// Validate a forced kernel against host capabilities. `Err` carries the
/// message the dispatcher panics with — a *clean* error: forcing `neon`
/// on x86 must never reach the intrinsics.
pub fn resolve(choice: DispatchKernel, caps: &HostCaps) -> Result<DispatchKernel, String> {
    match choice {
        DispatchKernel::Scalar | DispatchKernel::Pair => Ok(choice),
        DispatchKernel::Avx2 if caps.avx2 => Ok(choice),
        DispatchKernel::Neon if caps.neon => Ok(choice),
        other => Err(format!(
            "kernel {:?} is not supported on this host (caps: avx2={} neon={})",
            other.name(),
            caps.avx2,
            caps.neon
        )),
    }
}

/// How a raw `MUXQ_FORCE_KERNEL` env value parses. Pure (no env read, no
/// caching) so the dispatcher's edge cases are unit-testable without
/// mutating process state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EnvChoice {
    /// Variable absent or empty/whitespace (CI matrices export `""` for
    /// the default leg) — auto-select for the host.
    Unset,
    /// A recognized kernel name.
    Forced(DispatchKernel),
    /// Anything else — warn and fall back to scalar.
    Unknown(String),
}

/// Classify an env value ([`EnvChoice`] docs for the cases).
pub fn env_choice(value: Option<&str>) -> EnvChoice {
    match value {
        None => EnvChoice::Unset,
        Some(v) if v.trim().is_empty() => EnvChoice::Unset,
        Some(v) => match DispatchKernel::parse(v) {
            Some(k) => EnvChoice::Forced(k),
            None => EnvChoice::Unknown(v.to_string()),
        },
    }
}

/// The process-wide kernel dispatch, resolved once: `MUXQ_FORCE_KERNEL`
/// override (unknown → warn + scalar; unsupported-on-host → clean
/// panic), else [`auto_kernel`].
pub fn dispatch() -> DispatchKernel {
    static DISPATCH: OnceLock<DispatchKernel> = OnceLock::new();
    *DISPATCH.get_or_init(|| {
        let caps = host_caps();
        match env_choice(std::env::var("MUXQ_FORCE_KERNEL").ok().as_deref()) {
            EnvChoice::Unset => auto_kernel(&caps),
            EnvChoice::Forced(k) => match resolve(k, &caps) {
                Ok(k) => k,
                Err(e) => panic!("MUXQ_FORCE_KERNEL: {e}"),
            },
            EnvChoice::Unknown(v) => {
                eprintln!(
                    "WARN: MUXQ_FORCE_KERNEL={v:?} is not one of \
                     scalar|pair|avx2|neon; falling back to scalar"
                );
                DispatchKernel::Scalar
            }
        }
    })
}

// ------------------------------------------------------ kernel wrappers
//
// Safe entry points for `packed.rs`. Contract: callers route here only
// when `host_simd()` is `Some` (the dispatcher / `Kernel::Simd` assert
// it), so the `unsafe` target-feature calls are sound; a contract
// violation on a SIMD-less arch falls back to the portable scalar loop
// rather than UB.

/// Dense microkernel: `acc[i][j] += Σ_kk a[i][kk] · panel[kk·N + j]`
/// over `k` contraction steps (accumulating — like the scalar twins).
#[inline]
#[allow(unused_variables, unreachable_code)]
pub(crate) fn micro_dense<const M: usize, const N: usize>(
    k: usize,
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    #[cfg(target_arch = "x86_64")]
    if host_caps().avx2 {
        // SAFETY: AVX2 presence just checked.
        unsafe { avx2::micro_dense::<M, N>(k, a, panel, acc) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::micro_dense::<M, N>(k, a, panel, acc) };
        return;
    }
    portable_dense::<M, N>(k, a, panel, acc);
}

/// Rows-subset (Aux) microkernel: contraction walks `idx`, B rows read
/// from arbitrary panel offsets (`panel[idx[t]·N ..]`).
#[inline]
#[allow(unused_variables, unreachable_code)]
pub(crate) fn micro_idx<const M: usize, const N: usize>(
    idx: &[usize],
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    #[cfg(target_arch = "x86_64")]
    if host_caps().avx2 {
        // SAFETY: AVX2 presence just checked.
        unsafe { avx2::micro_idx::<M, N>(idx, a, panel, acc) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::micro_idx::<M, N>(idx, a, panel, acc) };
        return;
    }
    portable_idx::<M, N>(idx, a, panel, acc);
}

/// Portable fallback (non-x86/aarch64 hosts where the dispatcher never
/// selects SIMD; reachable only on contract violation): delegate to the
/// ONE wide-i32 implementation in `packed.rs` — no second copy of the
/// contraction math to keep in sync.
fn portable_dense<const M: usize, const N: usize>(
    k: usize,
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    super::packed::micro_wide::<M, N>(k, a, panel, acc);
}

fn portable_idx<const M: usize, const N: usize>(
    idx: &[usize],
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    super::packed::micro_wide_idx::<M, N>(idx, a, panel, acc);
}

/// Dense W4 microkernel wrapper: nibble panels, same accumulate
/// contract as [`micro_dense`]. Routes to the host's nibble-expand SIMD
/// kernel; the portable fallback is the ONE scalar W4 pair kernel in
/// `packed.rs` (which is exact for all inputs — W4 has no wide route).
#[inline]
#[allow(unused_variables, unreachable_code)]
pub(crate) fn micro_dense_w4<const M: usize, const N: usize>(
    k: usize,
    a: &[&[i8]; M],
    panel: &[u8],
    acc: &mut [[i32; N]; M],
) {
    #[cfg(target_arch = "x86_64")]
    if host_caps().avx2 {
        // SAFETY: AVX2 presence just checked.
        unsafe { avx2::micro_dense_w4::<M, N>(k, a, panel, acc) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::micro_dense_w4::<M, N>(k, a, panel, acc) };
        return;
    }
    super::packed::micro_pair_w4::<M, N>(k, a, panel, acc);
}

/// Rows-subset (Aux) W4 microkernel wrapper: contraction walks `idx`,
/// each indexed k row is one nibble of byte row `idx[t] / 2`.
#[inline]
#[allow(unused_variables, unreachable_code)]
pub(crate) fn micro_idx_w4<const M: usize, const N: usize>(
    idx: &[usize],
    a: &[&[i8]; M],
    panel: &[u8],
    acc: &mut [[i32; N]; M],
) {
    #[cfg(target_arch = "x86_64")]
    if host_caps().avx2 {
        // SAFETY: AVX2 presence just checked.
        unsafe { avx2::micro_idx_w4::<M, N>(idx, a, panel, acc) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::micro_idx_w4::<M, N>(idx, a, panel, acc) };
        return;
    }
    super::packed::micro_idx_w4::<M, N>(idx, a, panel, acc);
}

/// One scalar wide-i32 contraction step — the shared odd-K / odd-index
/// tail of the AVX2 and NEON kernels (`at` indexes A, `krow` the packed
/// panel row): `acc[i][j] += a[i][at] · panel_row[krow][j]`.
///
/// # Safety
/// `accp` must point at `M·N` writable i32s and `bp` at a panel with at
/// least `krow + 1` rows of `N` bytes; every `a[i]` needs `at + 1`
/// elements (callers pass in-bounds kernel state).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
pub(crate) unsafe fn tail_step<const M: usize, const N: usize>(
    at: usize,
    krow: usize,
    a: &[&[i8]; M],
    bp: *const i8,
    accp: *mut i32,
) {
    unsafe {
        for i in 0..M {
            let av = a[i][at] as i32;
            for j in 0..N {
                *accp.add(i * N + j) += av * *bp.add(krow * N + j) as i32;
            }
        }
    }
}

/// W4 twin of [`tail_step`] against a NIBBLE panel: logical k row
/// `krow` lives in byte row `krow / 2` (`N` bytes per byte row), parity
/// selecting the nibble — unpacked scalar, one MAC per lane.
///
/// # Safety
/// `accp` must point at `M·N` writable i32s and `bp` at a nibble panel
/// with at least `krow/2 + 1` byte rows of `N` bytes; every `a[i]`
/// needs `at + 1` elements.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
pub(crate) unsafe fn tail_step_w4<const M: usize, const N: usize>(
    at: usize,
    krow: usize,
    a: &[&[i8]; M],
    bp: *const u8,
    accp: *mut i32,
) {
    unsafe {
        let odd = krow & 1 == 1;
        for i in 0..M {
            let av = a[i][at] as i32;
            for j in 0..N {
                let w = super::packed::nib(*bp.add((krow >> 1) * N + j), odd);
                *accp.add(i * N + j) += av * w as i32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip_through_parse() {
        use DispatchKernel::{Avx2, Neon, Pair, Scalar};
        for k in [Scalar, Pair, Avx2, Neon] {
            assert_eq!(DispatchKernel::parse(k.name()), Some(k));
        }
        // trimming + case folding (env values come from YAML and shells)
        assert_eq!(DispatchKernel::parse(" AVX2 "), Some(DispatchKernel::Avx2));
        assert_eq!(DispatchKernel::parse("Scalar"), Some(DispatchKernel::Scalar));
        assert_eq!(DispatchKernel::parse("sse2"), None);
        assert_eq!(DispatchKernel::parse("pairi16"), None);
    }

    #[test]
    fn env_choice_classification() {
        // absent and empty both mean "auto" — CI matrices export an
        // empty string for the default leg
        assert_eq!(env_choice(None), EnvChoice::Unset);
        assert_eq!(env_choice(Some("")), EnvChoice::Unset);
        assert_eq!(env_choice(Some("  ")), EnvChoice::Unset);
        assert_eq!(env_choice(Some("neon")), EnvChoice::Forced(DispatchKernel::Neon));
        assert_eq!(env_choice(Some("PAIR")), EnvChoice::Forced(DispatchKernel::Pair));
        assert_eq!(env_choice(Some("frobnicate")), EnvChoice::Unknown("frobnicate".into()));
    }

    #[test]
    fn resolve_rejects_unsupported_kernels_cleanly() {
        // scalar kernels resolve anywhere
        let none = HostCaps { avx2: false, neon: false, neon_dot: false };
        assert_eq!(resolve(DispatchKernel::Scalar, &none), Ok(DispatchKernel::Scalar));
        assert_eq!(resolve(DispatchKernel::Pair, &none), Ok(DispatchKernel::Pair));
        // SIMD kernels only where the caps say so — and the rejection is
        // a value, not UB: the dispatcher turns it into a clean panic
        assert!(resolve(DispatchKernel::Avx2, &none).unwrap_err().contains("avx2"));
        assert!(resolve(DispatchKernel::Neon, &none).unwrap_err().contains("neon"));
        let x86 = HostCaps { avx2: true, neon: false, neon_dot: false };
        assert_eq!(resolve(DispatchKernel::Avx2, &x86), Ok(DispatchKernel::Avx2));
        assert!(resolve(DispatchKernel::Neon, &x86).is_err());
        let arm = HostCaps { avx2: false, neon: true, neon_dot: true };
        assert_eq!(resolve(DispatchKernel::Neon, &arm), Ok(DispatchKernel::Neon));
        assert!(resolve(DispatchKernel::Avx2, &arm).is_err());
    }

    #[test]
    fn auto_kernel_prefers_host_simd() {
        let none = HostCaps { avx2: false, neon: false, neon_dot: false };
        assert_eq!(auto_kernel(&none), DispatchKernel::Pair);
        let x86 = HostCaps { avx2: true, neon: false, neon_dot: false };
        assert_eq!(auto_kernel(&x86), DispatchKernel::Avx2);
        let arm = HostCaps { avx2: false, neon: true, neon_dot: false };
        assert_eq!(auto_kernel(&arm), DispatchKernel::Neon);
    }

    #[test]
    fn host_probe_is_arch_consistent() {
        let caps = host_caps();
        // avx2 and neon are mutually exclusive by construction
        assert!(!(caps.avx2 && caps.neon));
        #[cfg(target_arch = "aarch64")]
        assert!(caps.neon, "NEON is baseline on aarch64");
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        assert_eq!(host_simd(), None);
        match host_simd() {
            Some(k) => assert!(k.is_simd() && resolve(k, &caps).is_ok()),
            None => assert!(!caps.avx2 && !caps.neon),
        }
        // the process-wide dispatch always resolves to something the
        // host can run (whatever env this test suite runs under)
        assert!(resolve(dispatch(), &caps).is_ok());
    }

    #[test]
    fn forcing_foreign_simd_panics_cleanly() {
        // the dispatcher's unsupported-kernel path: pick a SIMD kernel
        // this host cannot run and check the failure is a clean panic
        // with the env var named (not UB, not a silent fallback)
        let caps = host_caps();
        let foreign =
            if caps.avx2 || !caps.neon { DispatchKernel::Neon } else { DispatchKernel::Avx2 };
        assert!(resolve(foreign, &caps).is_err());
        let err = std::panic::catch_unwind(|| {
            // same expression dispatch() evaluates on a forced env value
            match resolve(foreign, &caps) {
                Ok(k) => k,
                Err(e) => panic!("MUXQ_FORCE_KERNEL: {e}"),
            }
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("MUXQ_FORCE_KERNEL"), "panic message {msg:?}");
        assert!(msg.contains("not supported on this host"), "panic message {msg:?}");
    }

    #[test]
    fn portable_fallback_matches_triple_loop() {
        // the contract-violation fallback is itself exact (and on
        // x86/aarch64 hosts this doubles as a smoke test that the SIMD
        // wrappers agree with it — the proptests do the heavy pinning)
        let k = 13;
        let a_rows: Vec<Vec<i8>> = (0..4)
            .map(|i| (0..k).map(|t| ((i * 31 + t * 7) % 255) as i8).collect())
            .collect();
        let panel: Vec<i8> =
            (0..(k + 1) * 4).map(|t| (((t * 13 + 5) % 251) as i32 - 125) as i8).collect();
        let a: [&[i8]; 4] = std::array::from_fn(|i| a_rows[i].as_slice());
        let mut want = [[0i32; 4]; 4];
        for kk in 0..k {
            for i in 0..4 {
                for j in 0..4 {
                    want[i][j] += a[i][kk] as i32 * panel[kk * 4 + j] as i32;
                }
            }
        }
        let mut got = [[0i32; 4]; 4];
        portable_dense::<4, 4>(k, &a, &panel, &mut got);
        assert_eq!(got, want);
        let mut via_wrapper = [[0i32; 4]; 4];
        micro_dense::<4, 4>(k, &a, &panel, &mut via_wrapper);
        assert_eq!(via_wrapper, want);
        // idx twin: identity index list == dense
        let idx: Vec<usize> = (0..k).collect();
        let mut got_idx = [[0i32; 4]; 4];
        portable_idx::<4, 4>(&idx, &a, &panel, &mut got_idx);
        assert_eq!(got_idx, want);
        let mut via_idx = [[0i32; 4]; 4];
        micro_idx::<4, 4>(&idx, &a, &panel, &mut via_idx);
        assert_eq!(via_idx, want);
    }
}
