//! x86-64 AVX2 microkernels: `pmaddwd`-class pair accumulation against
//! the K-major packed panels of `super::super::packed`.
//!
//! Scheme (per k-pair, per panel): the two B rows of the pair are
//! byte-interleaved (`punpcklbw` / `pshufb`) so column `j`'s pair
//! `[b(k,j), b(k+1,j)]` sits in adjacent i16 lanes after sign extension
//! (`pmovsxbw`); one `pmaddwd` against a broadcast A pair
//! `[a(2t), a(2t+1)]` then retires two i8 MACs per i32 lane:
//!
//! ```text
//!   b16  = sx16[ b(k,0) b(k+1,0) b(k,1) b(k+1,1) … b(k,N-1) b(k+1,N-1) ]
//!   av   = set1_epi32( a(2t+1):a(2t) )                 (i16 pair per lane)
//!   acc += madd_epi16(av, b16)   // lane j: a_lo·b(k,j) + a_hi·b(k+1,j)
//! ```
//!
//! Exactness: operands are sign-extended i8 (|v| ≤ 128), so each i16
//! product is bounded by 16384 and `pmaddwd`'s pairwise sum — formed in
//! i32 — by 32768: no overflow for ANY i8 input, including the
//! all-(−128) corner that overflows the scalar i16 pair kernel. The
//! u8×i8 `maddubs` variant was rejected precisely because its i16
//! saturation breaks this bit-exactness contract.
//!
//! The A operand is read directly from the activation rows (the pair
//! `a[2t], a[2t+1]` is adjacent in the row), so the SIMD path skips the
//! scalar pair kernel's A-interleave copy entirely. Odd K and odd
//! index-list tails take one scalar wide-i32 step; packed zero-pad rows
//! are never read.
//!
//! Safety: every `unsafe fn` here requires AVX2; `super::micro_dense` /
//! `super::micro_idx` check `host_caps().avx2` before entering.

use super::{tail_step, tail_step_w4};
use std::arch::x86_64::*;

/// The A pair `[lo, hi]` as one i32: two sign-extended i16 halves,
/// little-endian lane order (lo in the even `pmaddwd` lane).
#[inline(always)]
fn pair_dw(lo: i8, hi: i8) -> i32 {
    (((hi as i16 as u16 as u32) << 16) | (lo as i16 as u16 as u32)) as i32
}

/// Interleave two 8-byte B rows and sign-extend to 16 i16 lanes.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn interleave8(r0: *const i8, r1: *const i8) -> __m256i {
    unsafe {
        let b0 = _mm_loadl_epi64(r0 as *const __m128i);
        let b1 = _mm_loadl_epi64(r1 as *const __m128i);
        _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(b0, b1))
    }
}

/// Interleave two 4-byte B rows (packed into one u64) and sign-extend
/// to 8 i16 lanes.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn interleave4(w0: u32, w1: u32) -> __m128i {
    unsafe {
        // bytes: [r0c0 r0c1 r0c2 r0c3 r1c0 r1c1 r1c2 r1c3] → interleaved
        let b = _mm_set_epi64x(0, (w0 as u64 | ((w1 as u64) << 32)) as i64);
        let shuf = _mm_set_epi8(-1, -1, -1, -1, -1, -1, -1, -1, 7, 3, 6, 2, 5, 1, 4, 0);
        _mm_cvtepi8_epi16(_mm_shuffle_epi8(b, shuf))
    }
}

/// Dense microkernel: `acc[i][j] += Σ_{kk<k} a[i][kk] · panel[kk·N + j]`.
///
/// # Safety
/// Requires AVX2 on the host. `panel` must hold at least `k` rows of
/// `N` bytes; every `a[i]` at least `k` elements.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn micro_dense<const M: usize, const N: usize>(
    k: usize,
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    debug_assert!(N == 4 || N == 8);
    debug_assert!(panel.len() >= k * N);
    let bp = panel.as_ptr();
    let accp = acc as *mut _ as *mut i32;
    unsafe {
        if N == 8 {
            let mut vacc = [_mm256_setzero_si256(); M];
            for t in 0..k / 2 {
                let b16 = interleave8(bp.add(2 * t * 8), bp.add((2 * t + 1) * 8));
                for (i, va) in vacc.iter_mut().enumerate() {
                    let av = _mm256_set1_epi32(pair_dw(a[i][2 * t], a[i][2 * t + 1]));
                    *va = _mm256_add_epi32(*va, _mm256_madd_epi16(av, b16));
                }
            }
            for (i, va) in vacc.iter().enumerate() {
                let p = accp.add(i * 8) as *mut __m256i;
                _mm256_storeu_si256(p, _mm256_add_epi32(_mm256_loadu_si256(p as *const _), *va));
            }
        } else {
            let mut vacc = [_mm_setzero_si128(); M];
            for t in 0..k / 2 {
                let w0 = (bp.add(2 * t * 4) as *const u32).read_unaligned();
                let w1 = (bp.add((2 * t + 1) * 4) as *const u32).read_unaligned();
                let b16 = interleave4(w0, w1);
                for (i, va) in vacc.iter_mut().enumerate() {
                    let av = _mm_set1_epi32(pair_dw(a[i][2 * t], a[i][2 * t + 1]));
                    *va = _mm_add_epi32(*va, _mm_madd_epi16(av, b16));
                }
            }
            for (i, va) in vacc.iter().enumerate() {
                let p = accp.add(i * 4) as *mut __m128i;
                _mm_storeu_si128(p, _mm_add_epi32(_mm_loadu_si128(p as *const _), *va));
            }
        }
        if k % 2 == 1 {
            tail_step::<M, N>(k - 1, k - 1, a, bp, accp);
        }
    }
}

/// Rows-subset (Aux) microkernel: contraction walks `idx`, B rows read
/// from arbitrary panel offsets.
///
/// # Safety
/// Requires AVX2 on the host. Every `idx[t]` must be a valid panel row;
/// every `a[i]` at least `idx.len()` elements.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn micro_idx<const M: usize, const N: usize>(
    idx: &[usize],
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    debug_assert!(N == 4 || N == 8);
    let bp = panel.as_ptr();
    let accp = acc as *mut _ as *mut i32;
    unsafe {
        if N == 8 {
            let mut vacc = [_mm256_setzero_si256(); M];
            for t in 0..idx.len() / 2 {
                let b16 = interleave8(bp.add(idx[2 * t] * 8), bp.add(idx[2 * t + 1] * 8));
                for (i, va) in vacc.iter_mut().enumerate() {
                    let av = _mm256_set1_epi32(pair_dw(a[i][2 * t], a[i][2 * t + 1]));
                    *va = _mm256_add_epi32(*va, _mm256_madd_epi16(av, b16));
                }
            }
            for (i, va) in vacc.iter().enumerate() {
                let p = accp.add(i * 8) as *mut __m256i;
                _mm256_storeu_si256(p, _mm256_add_epi32(_mm256_loadu_si256(p as *const _), *va));
            }
        } else {
            let mut vacc = [_mm_setzero_si128(); M];
            for t in 0..idx.len() / 2 {
                let w0 = (bp.add(idx[2 * t] * 4) as *const u32).read_unaligned();
                let w1 = (bp.add(idx[2 * t + 1] * 4) as *const u32).read_unaligned();
                let b16 = interleave4(w0, w1);
                for (i, va) in vacc.iter_mut().enumerate() {
                    let av = _mm_set1_epi32(pair_dw(a[i][2 * t], a[i][2 * t + 1]));
                    *va = _mm_add_epi32(*va, _mm_madd_epi16(av, b16));
                }
            }
            for (i, va) in vacc.iter().enumerate() {
                let p = accp.add(i * 4) as *mut __m128i;
                _mm_storeu_si128(p, _mm_add_epi32(_mm_loadu_si128(p as *const _), *va));
            }
        }
        if idx.len() % 2 == 1 {
            let t = idx.len() - 1;
            tail_step::<M, N>(t, idx[t], a, bp, accp);
        }
    }
}

// --------------------------------------------------- W4 (nibble) twins
//
// The packed-nibble panels of `PackedMatI4` store a whole k-pair in ONE
// byte row (`N` bytes per byte row: even k in the low nibble, odd k in
// the high nibble). Expansion is shift+mask plus an XOR-based sign
// extension — `(x ^ 8) - 8` sign-extends a 4-bit value held in the low
// bits of a byte lane — after which the bytes feed the IDENTICAL
// interleave → `pmovsxbw` → `pmaddwd` pipeline as the i8 kernels. The
// pair sums are bounded by 2·128·8 = 2048, so exactness is trivial.

/// Expand 16 packed bytes into (low-nibble, high-nibble) signed i8
/// vectors: lane `j` of the outputs holds the even-k / odd-k weight of
/// byte `j`.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn nibbles(b: __m128i) -> (__m128i, __m128i) {
    unsafe {
        let mask = _mm_set1_epi8(0x0f);
        let sign = _mm_set1_epi8(0x08);
        let lo = _mm_and_si128(b, mask);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(b), mask);
        (
            _mm_sub_epi8(_mm_xor_si128(lo, sign), sign),
            _mm_sub_epi8(_mm_xor_si128(hi, sign), sign),
        )
    }
}

/// Expand one 8-byte nibble row (a whole k-pair for 8 columns) into the
/// interleaved-pair i16 layout the `pmaddwd` loop consumes — the W4
/// equivalent of [`interleave8`] from a single byte row.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn interleave8_w4(row: *const u8) -> __m256i {
    unsafe {
        let b = _mm_loadl_epi64(row as *const __m128i);
        let (lo, hi) = nibbles(b);
        _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(lo, hi))
    }
}

/// 4-column variant: one u32 byte row expands to 8 interleaved i16s.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn interleave4_w4(row: *const u8) -> __m128i {
    unsafe {
        let b = _mm_cvtsi32_si128((row as *const u32).read_unaligned() as i32);
        let (lo, hi) = nibbles(b);
        _mm_cvtepi8_epi16(_mm_unpacklo_epi8(lo, hi))
    }
}

/// Expand the logical k row `krow` of an 8-wide nibble panel to signed
/// i8 lanes (byte row `krow / 2`, parity selects the nibble).
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn nibble_row8(bp: *const u8, krow: usize) -> __m128i {
    unsafe {
        let b = _mm_loadl_epi64(bp.add((krow >> 1) * 8) as *const __m128i);
        let (lo, hi) = nibbles(b);
        if krow & 1 == 1 {
            hi
        } else {
            lo
        }
    }
}

/// 4-wide panel variant of [`nibble_row8`].
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn nibble_row4(bp: *const u8, krow: usize) -> __m128i {
    unsafe {
        let b = _mm_cvtsi32_si128((bp.add((krow >> 1) * 4) as *const u32).read_unaligned() as i32);
        let (lo, hi) = nibbles(b);
        if krow & 1 == 1 {
            hi
        } else {
            lo
        }
    }
}

/// Dense W4 microkernel: nibble panel, same contract as [`micro_dense`].
///
/// # Safety
/// Requires AVX2 on the host. `panel` must hold at least `ceil(k/2)`
/// byte rows of `N` bytes; every `a[i]` at least `k` elements.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn micro_dense_w4<const M: usize, const N: usize>(
    k: usize,
    a: &[&[i8]; M],
    panel: &[u8],
    acc: &mut [[i32; N]; M],
) {
    debug_assert!(N == 4 || N == 8);
    debug_assert!(panel.len() >= k.div_ceil(2) * N);
    let bp = panel.as_ptr();
    let accp = acc as *mut _ as *mut i32;
    unsafe {
        if N == 8 {
            let mut vacc = [_mm256_setzero_si256(); M];
            for t in 0..k / 2 {
                let b16 = interleave8_w4(bp.add(t * 8));
                for (i, va) in vacc.iter_mut().enumerate() {
                    let av = _mm256_set1_epi32(pair_dw(a[i][2 * t], a[i][2 * t + 1]));
                    *va = _mm256_add_epi32(*va, _mm256_madd_epi16(av, b16));
                }
            }
            for (i, va) in vacc.iter().enumerate() {
                let p = accp.add(i * 8) as *mut __m256i;
                _mm256_storeu_si256(p, _mm256_add_epi32(_mm256_loadu_si256(p as *const _), *va));
            }
        } else {
            let mut vacc = [_mm_setzero_si128(); M];
            for t in 0..k / 2 {
                let b16 = interleave4_w4(bp.add(t * 4));
                for (i, va) in vacc.iter_mut().enumerate() {
                    let av = _mm_set1_epi32(pair_dw(a[i][2 * t], a[i][2 * t + 1]));
                    *va = _mm_add_epi32(*va, _mm_madd_epi16(av, b16));
                }
            }
            for (i, va) in vacc.iter().enumerate() {
                let p = accp.add(i * 4) as *mut __m128i;
                _mm_storeu_si128(p, _mm_add_epi32(_mm_loadu_si128(p as *const _), *va));
            }
        }
        if k % 2 == 1 {
            tail_step_w4::<M, N>(k - 1, k - 1, a, bp, accp);
        }
    }
}

/// Rows-subset (Aux) W4 microkernel: the contraction walks `idx`; each
/// indexed k row expands from its nibble before the same interleave →
/// `pmaddwd` pairing as [`micro_idx`].
///
/// # Safety
/// Requires AVX2 on the host. Every `idx[t]` must be a valid logical
/// panel row; every `a[i]` at least `idx.len()` elements.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn micro_idx_w4<const M: usize, const N: usize>(
    idx: &[usize],
    a: &[&[i8]; M],
    panel: &[u8],
    acc: &mut [[i32; N]; M],
) {
    debug_assert!(N == 4 || N == 8);
    let bp = panel.as_ptr();
    let accp = acc as *mut _ as *mut i32;
    unsafe {
        if N == 8 {
            let mut vacc = [_mm256_setzero_si256(); M];
            for t in 0..idx.len() / 2 {
                let r0 = nibble_row8(bp, idx[2 * t]);
                let r1 = nibble_row8(bp, idx[2 * t + 1]);
                let b16 = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(r0, r1));
                for (i, va) in vacc.iter_mut().enumerate() {
                    let av = _mm256_set1_epi32(pair_dw(a[i][2 * t], a[i][2 * t + 1]));
                    *va = _mm256_add_epi32(*va, _mm256_madd_epi16(av, b16));
                }
            }
            for (i, va) in vacc.iter().enumerate() {
                let p = accp.add(i * 8) as *mut __m256i;
                _mm256_storeu_si256(p, _mm256_add_epi32(_mm256_loadu_si256(p as *const _), *va));
            }
        } else {
            let mut vacc = [_mm_setzero_si128(); M];
            for t in 0..idx.len() / 2 {
                let r0 = nibble_row4(bp, idx[2 * t]);
                let r1 = nibble_row4(bp, idx[2 * t + 1]);
                let b16 = _mm_cvtepi8_epi16(_mm_unpacklo_epi8(r0, r1));
                for (i, va) in vacc.iter_mut().enumerate() {
                    let av = _mm_set1_epi32(pair_dw(a[i][2 * t], a[i][2 * t + 1]));
                    *va = _mm_add_epi32(*va, _mm_madd_epi16(av, b16));
                }
            }
            for (i, va) in vacc.iter().enumerate() {
                let p = accp.add(i * 4) as *mut __m128i;
                _mm_storeu_si128(p, _mm_add_epi32(_mm_loadu_si128(p as *const _), *va));
            }
        }
        if idx.len() % 2 == 1 {
            let t = idx.len() - 1;
            tail_step_w4::<M, N>(t, idx[t], a, bp, accp);
        }
    }
}

// odd-K / odd-index scalar tails: `super::tail_step` / `tail_step_w4`
// (shared with NEON).
