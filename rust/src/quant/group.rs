//! Per-group quantization (paper §2.1): groups of G consecutive channels
//! within a row share one scale.
//!
//! The paper excludes per-group from its main evaluation because of its
//! overhead ("per-group quantization incurs excessive overhead", citing
//! Q-BERT) — we implement it anyway so that claim is testable: the
//! ablation (`examples/group_ablation.rs` + bench) measures both the
//! accuracy gain and the scale-storage / rescale cost it buys.

use super::absmax::EPS;
use super::matrix::{rint, MatF32};

/// Per-row, per-group scales: `scales[r][g]` covers columns
/// `[g*group, (g+1)*group)` of row r.
#[derive(Debug, Clone)]
pub struct GroupScales {
    pub group: usize,
    pub rows: usize,
    pub cols: usize,
    pub scales: Vec<f32>, // rows * n_groups, row-major
}

impl GroupScales {
    pub fn n_groups(&self) -> usize {
        self.cols.div_ceil(self.group)
    }

    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.scales[r * self.n_groups() + c / self.group]
    }

    /// Extra memory the scales cost, in bytes (the overhead the paper
    /// cites — compare against rows*cols i8 payload).
    pub fn overhead_bytes(&self) -> usize {
        self.scales.len() * 4
    }
}

/// Compute per-group abs-max scales.
pub fn group_scales(x: &MatF32, qmax: f32, group: usize) -> GroupScales {
    assert!(group > 0);
    let n_groups = x.cols.div_ceil(group);
    let mut scales = vec![EPS; x.rows * n_groups];
    for r in 0..x.rows {
        let row = x.row(r);
        for (g, chunk) in row.chunks(group).enumerate() {
            let m = chunk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            scales[r * n_groups + g] = m.max(EPS) / qmax;
        }
    }
    GroupScales { group, rows: x.rows, cols: x.cols, scales }
}

/// Per-group fake quantization.
pub fn fq_group(x: &MatF32, qmax: f32, group: usize) -> MatF32 {
    let s = group_scales(x, qmax, group);
    let mut out = MatF32::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        for c in 0..x.cols {
            let sc = s.at(r, c);
            *out.at_mut(r, c) = rint(x.at(r, c) / sc).clamp(-qmax, qmax) * sc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;
    use crate::quant::absmax::{fq_naive, Granularity};

    fn outlier_mat(seed: u64) -> MatF32 {
        let mut rng = SplitMix64::new(seed);
        let mut m = MatF32::from_vec(
            32,
            64,
            (0..32 * 64).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
        )
        .unwrap();
        for r in 0..m.rows {
            *m.at_mut(r, 9) *= 30.0;
        }
        m
    }

    #[test]
    fn group_of_cols_equals_per_row() {
        let x = outlier_mat(1);
        let per_row = fq_naive(&x, 127.0, Granularity::PerRow);
        let grouped = fq_group(&x, 127.0, 64);
        assert!(per_row.max_abs_diff(&grouped) < 1e-7);
    }

    #[test]
    fn group_of_one_is_lossless_up_to_grid() {
        let x = outlier_mat(2);
        let g1 = fq_group(&x, 127.0, 1);
        // each element is its own group: error is only the rounding of
        // x/|x|*qmax = +-qmax exactly -> zero error
        assert!(g1.max_abs_diff(&x) < 1e-6);
    }

    #[test]
    fn finer_groups_monotonically_reduce_error() {
        let x = outlier_mat(3);
        let mut prev = f32::INFINITY;
        for group in [64usize, 32, 8, 2] {
            let e = fq_group(&x, 127.0, group).mean_abs_diff(&x);
            assert!(e <= prev + 1e-9, "group {group}: {e} vs {prev}");
            prev = e;
        }
    }

    #[test]
    fn group_confines_outlier_damage() {
        // with group=8, the outlier at col 9 only ruins cols 8..16
        let x = outlier_mat(4);
        let y = fq_group(&x, 127.0, 8);
        let per_row = fq_naive(&x, 127.0, Granularity::PerRow);
        // error on columns far from the outlier is smaller than per-row
        let mut e_group = 0.0;
        let mut e_row = 0.0;
        for r in 0..x.rows {
            for c in 32..64 {
                e_group += (y.at(r, c) - x.at(r, c)).abs();
                e_row += (per_row.at(r, c) - x.at(r, c)).abs();
            }
        }
        assert!(e_group < e_row);
    }

    #[test]
    fn overhead_accounting() {
        let x = outlier_mat(5);
        let s = group_scales(&x, 127.0, 8);
        assert_eq!(s.n_groups(), 8);
        assert_eq!(s.overhead_bytes(), 32 * 8 * 4);
        // vs per-row: 32*4 bytes — the paper's "excessive overhead" is
        // the 8x scale blow-up (and the rescale per group on hardware)
        assert!(s.overhead_bytes() > 32 * 4);
    }

    #[test]
    fn ragged_tail_group() {
        let x = MatF32::from_vec(2, 10, (0..20).map(|v| v as f32).collect()).unwrap();
        let y = fq_group(&x, 127.0, 4); // groups of 4,4,2
        assert_eq!((y.rows, y.cols), (2, 10));
        assert!(y.max_abs_diff(&x) < 0.1);
    }
}
