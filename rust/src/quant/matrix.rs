//! Dense row-major f32 / i8 / i32 matrices — the numeric substrate for the
//! rust-native quantization engine.
//!
//! Deliberately minimal (no external linear-algebra crates in the offline
//! image): just enough structure for the quantization transforms, the
//! blocked GEMMs and the GPT-2 forward.

use anyhow::{bail, Result};

/// Row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct MatF32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl MatF32 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatF32 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            bail!("shape {rows}x{cols} != {} elements", data.len());
        }
        Ok(MatF32 { rows, cols, data })
    }

    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Per-matrix absolute maximum.
    pub fn absmax(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    /// Per-row absolute maxima (per-token granularity).
    pub fn absmax_rows(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row(r).iter().fold(0.0f32, |m, v| m.max(v.abs())))
            .collect()
    }

    /// Per-column absolute maxima (per-channel granularity).
    pub fn absmax_cols(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            for (m, v) in out.iter_mut().zip(row) {
                *m = m.max(v.abs());
            }
        }
        out
    }

    pub fn transpose(&self) -> MatF32 {
        let mut t = MatF32::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *t.at_mut(c, r) = self.at(r, c);
            }
        }
        t
    }

    /// Mean absolute difference against another matrix of the same shape.
    pub fn mean_abs_diff(&self, other: &MatF32) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / n as f32
    }

    pub fn max_abs_diff(&self, other: &MatF32) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

/// Row-major i8 matrix (quantized operand storage).
#[derive(Debug, Clone, PartialEq)]
pub struct MatI8 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
}

impl MatI8 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatI8 { rows, cols, data: vec![0; rows * cols] }
    }

    #[inline(always)]
    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// Row-major i32 matrix (integer accumulator).
#[derive(Debug, Clone, PartialEq)]
pub struct MatI32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i32>,
}

impl MatI32 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatI32 { rows, cols, data: vec![0; rows * cols] }
    }
}

/// IEEE round-half-to-even for f32 — matches `jnp.round` / numpy `rint`.
/// (`f32::round` rounds half *away from zero*, which diverges from the
/// python oracle on exact .5 grid points.)
#[inline(always)]
pub fn rint(x: f32) -> f32 {
    x.round_ties_even()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rint_half_to_even() {
        assert_eq!(rint(0.5), 0.0);
        assert_eq!(rint(1.5), 2.0);
        assert_eq!(rint(2.5), 2.0);
        assert_eq!(rint(-0.5), 0.0);
        assert_eq!(rint(-1.5), -2.0);
        assert_eq!(rint(3.2), 3.0);
        assert_eq!(rint(-3.7), -4.0);
    }

    #[test]
    fn absmax_variants() {
        let m = MatF32::from_vec(2, 3, vec![1.0, -5.0, 2.0, -3.0, 4.0, 0.5]).unwrap();
        assert_eq!(m.absmax(), 5.0);
        assert_eq!(m.absmax_rows(), vec![5.0, 4.0]);
        assert_eq!(m.absmax_cols(), vec![3.0, 5.0, 2.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = MatF32::from_vec(2, 3, (0..6).map(|v| v as f32).collect()).unwrap();
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.at(2, 1), m.at(1, 2));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn from_vec_shape_check() {
        assert!(MatF32::from_vec(2, 2, vec![0.0; 3]).is_err());
    }
}
