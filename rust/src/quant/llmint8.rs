//! LLM.int8() baseline (Dettmers et al., 2022): mixed-precision
//! decomposition — outlier channels stay FP16, the rest go INT8.
//!
//! This is the comparison point the paper positions MUXQ against: accurate
//! but hardware-unfriendly (irregular gather/scatter + a second FP GEMM on
//! the accelerator). The `npusim` module prices exactly that difference.

use super::absmax::{fake_quant, fq_naive, Granularity, Scales};
use super::gemm::{dequant, matmul_f32, matmul_i8};
use super::matrix::MatF32;
use super::muxq::{gather_outlier_rows, outlier_mask};

/// LLM.int8() fake quantization of activations: outlier columns bit-exact
/// FP, the rest abs-max fake-quantized with scales over non-outliers only.
/// (python ref.fq_llmint8_act twin)
pub fn fq_llmint8_act(x: &MatF32, qmax: f32, gran: Granularity, theta: f32) -> MatF32 {
    let mask = outlier_mask(x, theta);
    let mut x_norm = x.clone();
    for r in 0..x.rows {
        let row = x_norm.row_mut(r);
        for (c, m) in mask.iter().enumerate() {
            if *m {
                row[c] = 0.0;
            }
        }
    }
    let s = Scales::compute(&x_norm, qmax, gran);
    let mut out = fake_quant(&x_norm, &s, qmax);
    for r in 0..x.rows {
        let xr = x.row(r);
        let or = out.row_mut(r);
        for (c, m) in mask.iter().enumerate() {
            if *m {
                or[c] = xr[c];
            }
        }
    }
    out
}

/// LLM.int8() weight side: rows feeding outlier channels stay FP.
pub fn fq_llmint8_weight(w: &MatF32, qmax: f32, gran: Granularity, mask: &[bool]) -> MatF32 {
    let mut wq = fq_naive(w, qmax, gran);
    for (r, m) in mask.iter().enumerate() {
        if *m {
            wq.row_mut(r).copy_from_slice(w.row(r));
        }
    }
    wq
}

/// The mixed-precision matmul: INT8 GEMM over normal channels + FP GEMM
/// over the outlier slice (the irregular part MUXQ eliminates).
pub fn llmint8_matmul(
    x: &MatF32,
    w: &MatF32,
    qmax: f32,
    gx: Granularity,
    gw: Granularity,
    theta: f32,
) -> MatF32 {
    let mask = outlier_mask(x, theta);

    // normal channels -> INT path (zero out outlier columns / rows)
    let mut x_norm = x.clone();
    for r in 0..x.rows {
        let row = x_norm.row_mut(r);
        for (c, m) in mask.iter().enumerate() {
            if *m {
                row[c] = 0.0;
            }
        }
    }
    let mut w_norm = w.clone();
    for (r, m) in mask.iter().enumerate() {
        if *m {
            for v in w_norm.row_mut(r) {
                *v = 0.0;
            }
        }
    }
    let sx = Scales::compute(&x_norm, qmax, gx);
    let sw = Scales::compute(&w_norm, qmax, gw);
    let xq = super::absmax::quantize_i8(&x_norm, &sx, qmax);
    let wq = super::absmax::quantize_i8(&w_norm, &sw, qmax);
    let mut y = dequant(&matmul_i8(&xq, &wq), &sx, &sw);

    // outlier slice -> FP16 path (gathered, dense-but-skinny)
    let idx: Vec<usize> = mask.iter().enumerate().filter(|(_, m)| **m).map(|(i, _)| i).collect();
    if !idx.is_empty() {
        let x_out = super::muxq::gather_outlier_cols(x, &mask, 1.0);
        let w_out = gather_outlier_rows(w, &mask);
        let y_fp = matmul_f32(&x_out, &w_out);
        for (yv, fv) in y.data.iter_mut().zip(&y_fp.data) {
            *yv += fv;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;

    fn outlier_mat(rows: usize, cols: usize, seed: u64, out_cols: &[usize], scale: f32) -> MatF32 {
        let mut rng = SplitMix64::new(seed);
        let mut m = MatF32::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
        )
        .unwrap();
        for r in 0..rows {
            for &c in out_cols {
                *m.at_mut(r, c) *= scale;
            }
        }
        m
    }

    #[test]
    fn outlier_columns_bit_exact() {
        let x = outlier_mat(16, 16, 1, &[4, 11], 30.0);
        let y = fq_llmint8_act(&x, 127.0, Granularity::PerTensor, 6.0);
        for r in 0..16 {
            assert_eq!(y.at(r, 4), x.at(r, 4));
            assert_eq!(y.at(r, 11), x.at(r, 11));
        }
    }

    #[test]
    fn beats_naive_with_outliers() {
        let x = outlier_mat(64, 64, 2, &[0, 9, 33], 25.0);
        let e_int8 = fq_llmint8_act(&x, 127.0, Granularity::PerTensor, 6.0).mean_abs_diff(&x);
        let e_naive =
            super::super::absmax::fq_naive(&x, 127.0, Granularity::PerTensor).mean_abs_diff(&x);
        assert!(e_int8 < e_naive);
    }

    #[test]
    fn accuracy_order_llmint8_muxq_naive() {
        // the Table 1 ordering at 6 bits per-tensor
        use super::super::muxq::{fq_muxq, MuxqParams};
        let x = outlier_mat(64, 64, 3, &[2, 17, 40, 55], 30.0);
        let qmax = 31.0;
        let e_naive =
            super::super::absmax::fq_naive(&x, qmax, Granularity::PerTensor).mean_abs_diff(&x);
        let e_muxq =
            fq_muxq(&x, qmax, Granularity::PerTensor, &MuxqParams::default()).mean_abs_diff(&x);
        let e_int8 = fq_llmint8_act(&x, qmax, Granularity::PerTensor, 6.0).mean_abs_diff(&x);
        assert!(e_int8 <= e_muxq, "int8 {e_int8} muxq {e_muxq}");
        assert!(e_muxq < e_naive, "muxq {e_muxq} naive {e_naive}");
    }

    #[test]
    fn mixed_matmul_close_to_fp() {
        let x = outlier_mat(32, 48, 4, &[5, 25], 25.0);
        let mut rng = SplitMix64::new(5);
        let w = MatF32::from_vec(
            48,
            16,
            (0..48 * 16).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
        )
        .unwrap();
        let exact = matmul_f32(&x, &w);
        let y = llmint8_matmul(&x, &w, 127.0, Granularity::PerRow, Granularity::PerCol, 6.0);
        assert!(y.mean_abs_diff(&exact) < 0.1, "mae {}", y.mean_abs_diff(&exact));
    }
}
