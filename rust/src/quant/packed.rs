//! Packed, parallel INT8 GEMM engine — the hot path of the uniform-INT
//! pipeline MUXQ argues for (paper §3, eq. 7). Layout details and the
//! panel diagrams live in DESIGN.md §4.
//!
//! Production INT-GEMM stacks (GPTQ/mistralrs-style packed-weight
//! kernels) pre-pack the weight operand ONCE into a layout the
//! microkernel can stream, then tile the output over registers. The
//! rust-native equivalent implemented here:
//!
//! * [`PackedMatI8`] — K-major column panels of a tile-selected width
//!   ([`TileConfig`]), zero-padded to the panel width AND to an even K
//!   (`k_pad`), so a k-pair is one contiguous `2·NR` block the pair
//!   microkernel streams branch-free. Built by a one-time `pack()` (at
//!   model load for the deployment pipeline; amortized against O(M·K·N)
//!   compute when packing on the fly).
//! * An **i16 pair-accumulation microkernel** ([`Kernel::PairI16`], the
//!   default): each lane multiplies two i8×i8 products into i16 and adds
//!   the pair in i16 *before* widening into the i32 accumulator — two
//!   MACs per lane per widening step, the scalar twin of `pmaddwd`-style
//!   SIMD pair accumulation.
//!
//!   No-overflow proof: an i8×i8 product is bounded by 128·128 = 16384,
//!   so each product always fits i16 (the multiply must widen i8→i16
//!   first — widening-before-add). The pair sum is bounded by
//!   2·127·127 = 32258 < `i16::MAX` when operands stay in [-127, 127]
//!   (symmetric quantization clamps to ±qmax ≤ 127 and never emits
//!   -128), and by 128·127·2 = 32512 < `i16::MAX` whenever just ONE side
//!   of each product avoids -128. [`PackedMatI8::pack`] therefore scans
//!   B once: if any weight value is -128 the engine falls back to the
//!   [`Kernel::WideI32`] path, making the pair kernel bit-exact for
//!   every reachable input. (The only unrepresentable pair sum,
//!   (-128·-128)+(-128·-128) = 32768, requires -128 on BOTH sides of
//!   both products.)
//! * [`PackedMatI4`] — the same K-major panel geometry with TWO signed
//!   4-bit weights per byte (even k in the low nibble, odd k in the
//!   high nibble — the k-pair alignment the i8 layout already enforces
//!   IS the nibble alignment). Half the weight bytes of
//!   [`PackedMatI8`]: the decode path is bytes-dominated (npusim), so
//!   nibble panels are a direct ~2× weight-traffic cut. The W4
//!   microkernels ([`matmul_i8w4_packed_into`] and friends) unpack
//!   nibbles in-register; |w| ≤ 8 bounds the i16 pair sum by 2·128·8 =
//!   2048, so the W4 pair kernel is exact for EVERY input — no −128
//!   scan, no wide-i32 fallback. Pack-time saturation clamps to
//!   [-8, 7] and records the event ([`PackedMatI4::saturated`]).
//! * A **shape-aware tile selector** ([`TileConfig`]): the register tile
//!   MR×NR is chosen from (M, N, K) and an L1 size hint instead of the
//!   old hard-coded 4×4 — NR is fixed at pack time (it is baked into the
//!   panel layout), MR per call. `MUXQ_TILE=MRxNR` (e.g. `8x4`) and
//!   `MUXQ_L1_BYTES` override the heuristics.
//! * [`matmul_i8_rows_subset_into`] — the MUXQ Aux GEMM reads its
//!   outlier weight rows *directly out of the full packed layout* via an
//!   index list, so the skinny second GEMM of eq. 7 needs no per-call
//!   weight gather or re-pack. The contraction walks the index list in
//!   pairs, so it pair-accumulates too (odd-length lists take one scalar
//!   tail step).
//! * A **skinny-M GEMV path** ([`matmul_i8_gemv_into`], routed
//!   automatically for M ≤ [`TileConfig::gemv_max_m`]): autoregressive
//!   decode issues M=1 projections every token, where the register-tile
//!   cascade's per-call costs (A-tile interleave copy, tile dispatch,
//!   row-panel thread setup) are comparable to the whole contraction.
//!   The GEMV kernels stream each A row *in place* (no interleave
//!   buffer, no threads) against the same packed panels, keeping the
//!   i16 pair accumulation — so decode reuses the exact packed weights
//!   and overflow proof of the batch path. Both the dense and the
//!   rows-subset (Aux) contractions have GEMV twins.
//! * [`ParallelGemm`] — row-panel parallelism over scoped threads with a
//!   sequential fallback for small shapes (thread spawn costs more than
//!   the GEMM below ~2M MACs).
//! * **Per-arch SIMD routing** ([`super::simd`]): on hosts with AVX2
//!   (x86-64) or NEON (aarch64) the dense, rows-subset and GEMV
//!   contractions run explicit intrinsic kernels (`pmaddwd` pairs /
//!   `sdot` quads) instead of the scalar pair kernel — resolved once at
//!   startup, overridable with `MUXQ_FORCE_KERNEL={scalar,pair,avx2,
//!   neon}`. The SIMD kernels form their pair/quad sums in i32, so they
//!   are exact for every i8 input — the −128 fallback below applies
//!   only to the scalar pair route. [`TileConfig`] carries per-arch
//!   tile tables (SIMD keeps 8-wide panels at any K depth; the scalar
//!   table narrows under the L1 bound).
//!
//! i32 accumulation is exact for K up to 2^31 / 128^2 ≈ 131k — far above
//! any model dimension here; `debug_assert`s guard the operand shapes.
//!
//! Pack-time pre-transforms (SmoothQuant scaling, blockwise rotation,
//! channel permutation — `super::transform`) never reach this layer:
//! [`EngineSpec::pack`](super::linear::EngineSpec::pack) rewrites the
//! f32 weight BEFORE quantization, so the panels packed here always
//! hold the already-transformed weight and the kernels stay
//! transform-oblivious. The per-call inverse lives on the activation
//! staging side (`linear::IntScratch`), upstream of every contraction.
//!
//! Perf numbers live in EXPERIMENTS.md §Perf; `bench_gemm` regenerates
//! them (BENCH_gemm.json, gated by rust/scripts/bench_check.sh, doc and
//! test hygiene by rust/scripts/ci_check.sh).

use super::matrix::{MatI32, MatI8};
use super::simd::{self, DispatchKernel};
use std::cell::Cell;
use std::sync::OnceLock;

/// Portable default register-tile rows (the selector may widen to 8).
pub const MR: usize = 4;
/// Portable default panel width (the selector may widen to 8).
pub const NR: usize = 4;

thread_local! {
    static PACK_COUNT: Cell<usize> = const { Cell::new(0) };
}

/// Number of [`PackedMatI8::pack`] / [`PackedMatI4::pack`] calls made
/// *by this thread*. Test
/// hook: asserts weights are packed once at construction and never on
/// the per-call projection path. Thread-local so concurrently running
/// tests cannot perturb each other's counts.
pub fn pack_count() -> usize {
    PACK_COUNT.with(|c| c.get())
}

/// Microkernel register tile: `mr` output rows × `nr` output columns.
///
/// `nr` is a *layout* parameter — it fixes the packed panel width, so it
/// is chosen at pack time from (K, N) and the L1 hint. `mr` only shapes
/// the per-call register block and is chosen from M at GEMM time. Both
/// are restricted to {4, 8} (the set the const-generic microkernels are
/// instantiated for).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileConfig {
    pub mr: usize,
    pub nr: usize,
}

impl TileConfig {
    /// Parse an `MRxNR` override string (e.g. `"8x4"`). Both factors
    /// must be 4 or 8; anything else is rejected.
    pub fn parse(s: &str) -> Option<TileConfig> {
        let (m, n) = s.trim().split_once(|c| c == 'x' || c == 'X')?;
        let mr: usize = m.trim().parse().ok()?;
        let nr: usize = n.trim().parse().ok()?;
        if (mr == 4 || mr == 8) && (nr == 4 || nr == 8) {
            Some(TileConfig { mr, nr })
        } else {
            None
        }
    }

    /// The `MUXQ_TILE` override, read once per process. Invalid values
    /// are ignored (the heuristics apply).
    fn env_override() -> Option<TileConfig> {
        static OVERRIDE: OnceLock<Option<TileConfig>> = OnceLock::new();
        *OVERRIDE
            .get_or_init(|| std::env::var("MUXQ_TILE").ok().and_then(|s| TileConfig::parse(&s)))
    }

    /// L1 data-cache size hint in bytes: `MUXQ_L1_BYTES` or a 32 KiB
    /// default (the common x86/ARM per-core L1d).
    fn l1_bytes() -> usize {
        static L1: OnceLock<usize> = OnceLock::new();
        *L1.get_or_init(|| {
            std::env::var("MUXQ_L1_BYTES")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(32 * 1024)
        })
    }

    /// Panel width for packing a `[k, n]` weight matrix — the per-arch
    /// tile table (`MUXQ_TILE` still wins over every table).
    ///
    /// * **scalar / pair** rows: wide (8) panels amortize the A-side
    ///   loads over more output columns, but one microkernel call
    ///   streams one B panel (`k_pad · nr` bytes) against one
    ///   interleaved A tile (`k_pad · mr` bytes), so the panel is
    ///   bounded by half the L1 budget (the other half feeds the A
    ///   tile) and deep-K shapes narrow back to 4.
    /// * **avx2 / neon** rows: 8 output columns are exactly one ymm of
    ///   i32 lanes (AVX2) / two NEON q-accumulators — a 4-wide panel
    ///   would idle half the multiplier lanes. The SIMD kernels read A
    ///   as register broadcasts (no interleaved A tile competing for
    ///   L1), so the panel stays 8-wide at ANY K depth; only genuinely
    ///   narrow outputs (n < 8) drop to 4.
    pub fn nr_for(k: usize, n: usize) -> usize {
        if let Some(t) = Self::env_override() {
            return t.nr;
        }
        if simd::dispatch().is_simd() {
            return if n >= 8 { 8 } else { NR };
        }
        let k_pad = k + (k & 1);
        if n >= 8 && k_pad * 8 <= Self::l1_bytes() / 2 {
            8
        } else {
            NR
        }
    }

    /// Register-tile rows for an `m`-row GEMM: 8 when a full 8-row tile
    /// exists (more accumulators per B-panel load), else the portable 4.
    pub fn mr_for(m: usize) -> usize {
        if let Some(t) = Self::env_override() {
            return t.mr;
        }
        if m >= 8 {
            8
        } else {
            MR
        }
    }

    /// Largest M routed to the GEMV path (`MUXQ_GEMV_M` override, default
    /// 4; 0 disables the route). Above this the register-tile cascade
    /// amortizes its A-interleave and dispatch costs; at decode widths it
    /// does not.
    pub fn gemv_max_m() -> usize {
        static GEMV_M: OnceLock<usize> = OnceLock::new();
        *GEMV_M.get_or_init(|| {
            std::env::var("MUXQ_GEMV_M").ok().and_then(|v| v.parse().ok()).unwrap_or(4)
        })
    }

    /// Whether an `m`-row GEMM takes the skinny GEMV route.
    pub fn use_gemv(m: usize) -> bool {
        m <= Self::gemv_max_m()
    }
}

/// Microkernel accumulation scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Honor the process-wide [`simd::dispatch`]: the host's SIMD kernel
    /// where one exists (or is forced), else the scalar pair kernel —
    /// which falls back to [`Kernel::WideI32`] when the packed B
    /// contains -128 (the one value that can overflow the i16 pair sum —
    /// see module docs; the SIMD kernels sum pairs/quads in i32 and need
    /// no such fallback).
    Auto,
    /// Scalar i16 pair accumulation: two i8 MACs per lane per i32
    /// widening. Callers forcing this must guarantee the packed B holds
    /// no -128.
    PairI16,
    /// One i8 MAC per lane, widened straight into i32 (the PR-1 scheme;
    /// the exact-for-all-inputs fallback and the bench comparator).
    WideI32,
    /// The host's SIMD kernel (AVX2 `pmaddwd` / NEON `sdot`-`smlal`),
    /// regardless of `MUXQ_FORCE_KERNEL` — the bench/test hook that
    /// keeps the SIMD path selectable while the env steers `Auto`.
    /// Panics (cleanly) on hosts with no SIMD kernel; gate on
    /// [`simd::host_simd`].
    Simd,
}

/// Resolved microkernel family for one GEMM call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Route {
    Simd,
    Pair,
    Wide,
}

impl Kernel {
    fn route(self, bp: &PackedMatI8) -> Route {
        match self {
            Kernel::Auto => match simd::dispatch() {
                DispatchKernel::Avx2 | DispatchKernel::Neon => Route::Simd,
                DispatchKernel::Scalar => Route::Wide,
                DispatchKernel::Pair => {
                    if bp.has_neg128 {
                        Route::Wide
                    } else {
                        Route::Pair
                    }
                }
            },
            Kernel::PairI16 => {
                debug_assert!(
                    !bp.has_neg128,
                    "pair-i16 exactness requires weight values in [-127, 127]"
                );
                Route::Pair
            }
            Kernel::WideI32 => Route::Wide,
            Kernel::Simd => {
                assert!(
                    simd::host_simd().is_some(),
                    "Kernel::Simd requested but this host has no SIMD kernel \
                     (need x86-64 AVX2 or aarch64 NEON)"
                );
                Route::Simd
            }
        }
    }

    /// Route for the W4 contractions. The scalar W4 pair kernel is exact
    /// for every input (|w| ≤ 8 bounds the i16 pair sum by 2048), so
    /// there is no wide fallback: `PairI16` and `WideI32` both select
    /// the one scalar kernel, and `Auto` only chooses between it and
    /// the host SIMD kernel.
    fn route_w4(self) -> Route {
        match self {
            Kernel::Auto => match simd::dispatch() {
                DispatchKernel::Avx2 | DispatchKernel::Neon => Route::Simd,
                DispatchKernel::Scalar | DispatchKernel::Pair => Route::Pair,
            },
            Kernel::PairI16 | Kernel::WideI32 => Route::Pair,
            Kernel::Simd => {
                assert!(
                    simd::host_simd().is_some(),
                    "Kernel::Simd requested but this host has no SIMD kernel \
                     (need x86-64 AVX2 or aarch64 NEON)"
                );
                Route::Simd
            }
        }
    }
}

/// Weight matrix pre-packed into K-major column panels.
///
/// Layout: `ceil(cols / nr)` panels, each `k_pad * nr` bytes where
/// `k_pad` rounds K up to even. Panel `p` stores columns
/// `p*nr .. p*nr+nr` of B; within a panel the nr column values for each
/// k are contiguous (`panel[k*nr + j]`), so the microkernel streams the
/// panel front-to-back with unit stride and a k-pair is one contiguous
/// `2·nr` block. The last panel is zero-padded to full width and odd K
/// gets one zero row — padding contributes zero to every accumulator, so
/// neither a column-tail nor a K-tail branch is needed in the kernel.
#[derive(Debug, Clone)]
pub struct PackedMatI8 {
    /// K — the inner (contraction) dimension (logical, unpadded).
    pub rows: usize,
    /// N — the output dimension (logical, unpadded).
    pub cols: usize,
    nr: usize,
    k_pad: usize,
    has_neg128: bool,
    data: Vec<i8>,
}

impl PackedMatI8 {
    /// One-time packing pass with the tile-selected panel width: O(K·N),
    /// done at weight-load time in the deployment pipeline.
    pub fn pack(b: &MatI8) -> PackedMatI8 {
        Self::pack_with(b, TileConfig::nr_for(b.rows, b.cols))
    }

    /// Pack with an explicit panel width (bench/test hook; `nr` must be
    /// 4 or 8).
    pub fn pack_with(b: &MatI8, nr: usize) -> PackedMatI8 {
        assert!(nr == 4 || nr == 8, "unsupported panel width {nr}");
        PACK_COUNT.with(|c| c.set(c.get() + 1));
        let (k, n) = (b.rows, b.cols);
        let k_pad = k + (k & 1);
        let panels = n.div_ceil(nr);
        let mut data = vec![0i8; panels * k_pad * nr];
        // the -128 scan (pair-kernel eligibility) rides the copy pass:
        // every element of B is copied exactly once across the panels
        let mut has_neg128 = false;
        for p in 0..panels {
            let j0 = p * nr;
            let jw = nr.min(n - j0);
            let dst = &mut data[p * k_pad * nr..(p + 1) * k_pad * nr];
            for kk in 0..k {
                let src = &b.data[kk * n + j0..kk * n + j0 + jw];
                dst[kk * nr..kk * nr + jw].copy_from_slice(src);
                has_neg128 |= src.contains(&i8::MIN);
            }
        }
        PackedMatI8 { rows: k, cols: n, nr, k_pad, has_neg128, data }
    }

    /// Panel width this matrix was packed with.
    pub fn nr(&self) -> usize {
        self.nr
    }

    /// Whether any packed value is -128 (forces the [`Kernel::WideI32`]
    /// path under [`Kernel::Auto`] — see the module-level overflow
    /// proof). Never true for symmetric-quantized weights.
    pub fn has_neg128(&self) -> bool {
        self.has_neg128
    }

    /// Number of column panels.
    pub fn panels(&self) -> usize {
        self.cols.div_ceil(self.nr)
    }

    /// Actual storage bytes, *including* panel and K-pair padding — what
    /// the packed layout really occupies in memory (the honest number
    /// for the memory-saving claim).
    pub fn padded_bytes(&self) -> usize {
        self.data.len()
    }

    /// Logical (unpadded) element count of the original matrix.
    pub fn logical_len(&self) -> usize {
        self.rows * self.cols
    }

    #[inline(always)]
    fn panel(&self, p: usize) -> &[i8] {
        &self.data[p * self.k_pad * self.nr..(p + 1) * self.k_pad * self.nr]
    }
}

/// Clamp an i8 value into the signed 4-bit range, recording saturation.
#[inline(always)]
fn clamp_i4(v: i8, saturated: &mut bool) -> i8 {
    if v < -8 {
        *saturated = true;
        -8
    } else if v > 7 {
        *saturated = true;
        7
    } else {
        v
    }
}

/// Weight matrix pre-packed into K-major NIBBLE panels: two signed
/// 4-bit weights per byte, half the bytes of [`PackedMatI8`] — the
/// weight-traffic lever for the bytes-dominated decode path
/// (DESIGN.md §4a).
///
/// Layout: `ceil(cols / nr)` panels of `(k_pad / 2) · nr` bytes each,
/// `k_pad` rounding K up to even exactly like the i8 layout — the
/// k-pair alignment the pair microkernels already need IS the nibble
/// alignment. Byte `t·nr + j` of a panel holds the k-pair
/// `(2t, 2t+1)` of the panel's column `j`: the EVEN k row in the low
/// nibble, the ODD k row in the high nibble, both two's-complement in
/// [-8, 7]. Odd K leaves the high nibble of the last byte row zero
/// (the same zero pad the i8 layout gives a full row), so no K-tail
/// branch is needed when streaming whole pairs.
///
/// Packing clamps out-of-range source values (saturating to [-8, 7])
/// and records the event in [`PackedMatI4::saturated`] — symmetric
/// 4-bit quantization emits [-7, 7] and never trips it; the scan is a
/// deployment-time sanity signal, NOT a kernel-correctness gate (the
/// W4 kernels are exact for the full [-8, 7] range including -8).
#[derive(Debug, Clone)]
pub struct PackedMatI4 {
    /// K — the inner (contraction) dimension (logical, unpadded).
    pub rows: usize,
    /// N — the output dimension (logical, unpadded).
    pub cols: usize,
    nr: usize,
    k_pad: usize,
    saturated: bool,
    data: Vec<u8>,
}

impl PackedMatI4 {
    /// One-time nibble packing with the tile-selected panel width. The
    /// source matrix carries i4-range values widened to i8 (what the
    /// 4-bit quantizer emits); anything outside [-8, 7] saturates.
    pub fn pack(b: &MatI8) -> PackedMatI4 {
        Self::pack_with(b, TileConfig::nr_for(b.rows, b.cols))
    }

    /// Pack with an explicit panel width (bench/test hook; `nr` must be
    /// 4 or 8).
    pub fn pack_with(b: &MatI8, nr: usize) -> PackedMatI4 {
        assert!(nr == 4 || nr == 8, "unsupported panel width {nr}");
        PACK_COUNT.with(|c| c.set(c.get() + 1));
        let (k, n) = (b.rows, b.cols);
        let k_pad = k + (k & 1);
        let panels = n.div_ceil(nr);
        let mut data = vec![0u8; panels * (k_pad / 2) * nr];
        let mut saturated = false;
        for p in 0..panels {
            let j0 = p * nr;
            let jw = nr.min(n - j0);
            let base = p * (k_pad / 2) * nr;
            for t in 0..k_pad / 2 {
                for j in 0..jw {
                    let lo = clamp_i4(b.data[2 * t * n + j0 + j], &mut saturated);
                    let hi = if 2 * t + 1 < k {
                        clamp_i4(b.data[(2 * t + 1) * n + j0 + j], &mut saturated)
                    } else {
                        0
                    };
                    data[base + t * nr + j] = (lo as u8 & 0x0f) | ((hi as u8) << 4);
                }
            }
        }
        PackedMatI4 { rows: k, cols: n, nr, k_pad, saturated, data }
    }

    /// Panel width this matrix was packed with.
    pub fn nr(&self) -> usize {
        self.nr
    }

    /// Whether any source value fell outside [-8, 7] and was clamped.
    pub fn saturated(&self) -> bool {
        self.saturated
    }

    /// Number of column panels.
    pub fn panels(&self) -> usize {
        self.cols.div_ceil(self.nr)
    }

    /// Actual storage bytes including panel and K-pair padding — the
    /// honest number for the ~2× weight-traffic claim (compare with
    /// [`PackedMatI8::padded_bytes`] of the same logical matrix).
    pub fn padded_bytes(&self) -> usize {
        self.data.len()
    }

    /// Logical (unpadded) element count of the original matrix.
    pub fn logical_len(&self) -> usize {
        self.rows * self.cols
    }

    /// Decode one logical element (test/oracle hook; the kernels unpack
    /// nibbles in-register, never through this).
    pub fn get(&self, k: usize, j: usize) -> i8 {
        debug_assert!(k < self.rows && j < self.cols);
        let p = j / self.nr;
        let b = self.data
            [p * (self.k_pad / 2) * self.nr + (k / 2) * self.nr + (j % self.nr)];
        nib(b, k & 1 == 1)
    }

    #[inline(always)]
    fn panel(&self, p: usize) -> &[u8] {
        let stride = (self.k_pad / 2) * self.nr;
        &self.data[p * stride..(p + 1) * stride]
    }
}

/// Row-panel parallelism config. `threads == 1` (or a shape below
/// `min_parallel_macs`) takes the sequential path — spawning scoped
/// threads costs more than a small GEMM.
#[derive(Debug, Clone, Copy)]
pub struct ParallelGemm {
    /// Worker count. [`ParallelGemm::global`] resolves this from
    /// `MUXQ_GEMM_THREADS` or the host's available parallelism;
    /// `Default`/[`ParallelGemm::sequential`] stay at 1.
    pub threads: usize,
    /// Sequential below this many MACs (m·k·n).
    pub min_parallel_macs: usize,
}

impl Default for ParallelGemm {
    fn default() -> Self {
        ParallelGemm { threads: 1, min_parallel_macs: 1 << 21 }
    }
}

impl ParallelGemm {
    /// Explicitly sequential (reference/fallback path).
    pub fn sequential() -> ParallelGemm {
        ParallelGemm::default()
    }

    /// The process-wide config, resolved once from the environment.
    pub fn global() -> ParallelGemm {
        static GLOBAL: OnceLock<ParallelGemm> = OnceLock::new();
        *GLOBAL.get_or_init(ParallelGemm::from_env)
    }

    fn from_env() -> ParallelGemm {
        let threads = std::env::var("MUXQ_GEMM_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
            .max(1);
        ParallelGemm { threads, min_parallel_macs: 1 << 21 }
    }
}

/// C = A_i8 @ B_packed with i32 accumulation, fresh output matrix.
pub fn matmul_i8_packed(a: &MatI8, bp: &PackedMatI8) -> MatI32 {
    matmul_i8_packed_with(a, bp, ParallelGemm::global())
}

/// Same, with an explicit parallelism config (bench/test hook).
pub fn matmul_i8_packed_with(a: &MatI8, bp: &PackedMatI8, cfg: ParallelGemm) -> MatI32 {
    let mut c = MatI32::zeros(a.rows, bp.cols);
    matmul_i8_packed_into(a, bp, &mut c, cfg);
    c
}

/// C = A_i8 @ B_packed written into a reusable accumulator (resized in
/// place; every element is overwritten, so no zeroing pass is needed).
/// Kernel and register tile are auto-selected ([`Kernel::Auto`],
/// [`TileConfig::mr_for`]); skinny shapes (M ≤
/// [`TileConfig::gemv_max_m`], the decode regime) skip the tile cascade
/// and take the GEMV path.
pub fn matmul_i8_packed_into(a: &MatI8, bp: &PackedMatI8, c: &mut MatI32, cfg: ParallelGemm) {
    if TileConfig::use_gemv(a.rows) {
        matmul_i8_gemv_into(a, bp, c, Kernel::Auto);
        return;
    }
    matmul_i8_packed_kernel_into(a, bp, c, cfg, Kernel::Auto, TileConfig::mr_for(a.rows));
}

/// Full-control variant: explicit accumulation [`Kernel`] and register
/// tile rows `mr` ∈ {4, 8} (the tile-grid bench and the bit-exactness
/// proptests drive every combination through this).
pub fn matmul_i8_packed_kernel_into(
    a: &MatI8,
    bp: &PackedMatI8,
    c: &mut MatI32,
    cfg: ParallelGemm,
    kernel: Kernel,
    mr: usize,
) {
    assert_eq!(a.cols, bp.rows, "inner dims {}x{}", a.cols, bp.rows);
    assert!(mr == 4 || mr == 8, "unsupported register tile rows {mr}");
    let (m, n) = (a.rows, bp.cols);
    let route = kernel.route(bp);
    c.rows = m;
    c.cols = n;
    c.data.resize(m * n, 0);
    run_row_parallel(m, n, a.cols, cfg, &mut c.data, &|row0, row1, chunk| {
        gemm_rows(a, bp, None, route, mr, row0, row1, chunk);
    });
}

/// Skinny GEMM against a *row subset* of the packed weights:
/// `C = A_compact @ B[idx, :]` where A_compact is `[m, r]` and `idx[t]`
/// names the B row matched to A's column `t`. This is MUXQ's Aux GEMM
/// (eq. 7): the outlier weight rows are read straight out of the full
/// packed layout — zero-copy, no per-call gather/re-pack. The index list
/// is walked in pairs, so this path pair-accumulates too.
pub fn matmul_i8_rows_subset_into(
    a: &MatI8,
    bp: &PackedMatI8,
    idx: &[usize],
    c: &mut MatI32,
    cfg: ParallelGemm,
) {
    assert_eq!(a.cols, idx.len(), "compact A width vs index list");
    debug_assert!(idx.iter().all(|&k| k < bp.rows));
    let (m, n) = (a.rows, bp.cols);
    let route = Kernel::Auto.route(bp);
    c.rows = m;
    c.cols = n;
    c.data.resize(m * n, 0);
    if TileConfig::use_gemv(m) {
        // skinny Aux route (single decode rows): walk the index list
        // straight off the A row, no interleave, no threads
        gemv_dispatch(a, bp, Some(idx), route, &mut c.data);
        return;
    }
    let mr = TileConfig::mr_for(m);
    run_row_parallel(m, n, idx.len(), cfg, &mut c.data, &|row0, row1, chunk| {
        gemm_rows(a, bp, Some(idx), route, mr, row0, row1, chunk);
    });
}

/// Skinny-M GEMV against the packed panels: `C = A @ B_packed` with the
/// A rows streamed in place — no A-tile interleave buffer, no tile
/// cascade, no thread setup. The per-call overheads the register-tiled
/// path amortizes over many output rows are exactly what an M=1 decode
/// projection cannot amortize. Pair accumulation (and the -128 fallback
/// dispatch) match the batch path, so results are bit-identical to it.
/// `a.rows` may be anything, but the route is intended for (and
/// auto-selected at) M ≤ [`TileConfig::gemv_max_m`].
pub fn matmul_i8_gemv_into(a: &MatI8, bp: &PackedMatI8, c: &mut MatI32, kernel: Kernel) {
    assert_eq!(a.cols, bp.rows, "inner dims {}x{}", a.cols, bp.rows);
    let (m, n) = (a.rows, bp.cols);
    let route = kernel.route(bp);
    c.rows = m;
    c.cols = n;
    c.data.resize(m * n, 0);
    gemv_dispatch(a, bp, None, route, &mut c.data);
}

/// C = A_i8 @ B4_packed against the nibble panels — the W4A8 twin of
/// [`matmul_i8_packed_into`]: auto kernel/tile selection, skinny shapes
/// (M ≤ [`TileConfig::gemv_max_m`], the decode regime) take the GEMV
/// route. Bit-exact vs widening the i4 weights to i8 and running the
/// i8 engine, at half the weight bytes streamed.
pub fn matmul_i8w4_packed_into(a: &MatI8, bp: &PackedMatI4, c: &mut MatI32, cfg: ParallelGemm) {
    if TileConfig::use_gemv(a.rows) {
        matmul_i8w4_gemv_into(a, bp, c, Kernel::Auto);
        return;
    }
    matmul_i8w4_packed_kernel_into(a, bp, c, cfg, Kernel::Auto, TileConfig::mr_for(a.rows));
}

/// Full-control W4 variant: explicit [`Kernel`] and register tile rows
/// `mr` ∈ {4, 8} (the bit-exactness proptests drive every combination
/// through this; `PairI16`/`WideI32` both mean "the scalar W4 kernel",
/// which needs no wide fallback — the pair sum is bounded by 2048).
pub fn matmul_i8w4_packed_kernel_into(
    a: &MatI8,
    bp: &PackedMatI4,
    c: &mut MatI32,
    cfg: ParallelGemm,
    kernel: Kernel,
    mr: usize,
) {
    assert_eq!(a.cols, bp.rows, "inner dims {}x{}", a.cols, bp.rows);
    assert!(mr == 4 || mr == 8, "unsupported register tile rows {mr}");
    let (m, n) = (a.rows, bp.cols);
    let route = kernel.route_w4();
    c.rows = m;
    c.cols = n;
    c.data.resize(m * n, 0);
    run_row_parallel(m, n, a.cols, cfg, &mut c.data, &|row0, row1, chunk| {
        gemm_rows_w4(a, bp, None, route, mr, row0, row1, chunk);
    });
}

/// W4 rows-subset GEMM: `C = A_compact @ B4[idx, :]` read straight out
/// of the nibble panels — MUXQ's Aux GEMM against a W4 body, so the
/// muxq-w4a8 operator runs body and aux legs off ONE packed weight.
/// Each indexed k row is one nibble of byte row `idx[t] / 2` (parity
/// selects the half); the index list is walked in pairs for the i16
/// pair math exactly like the i8 path.
pub fn matmul_i8w4_rows_subset_into(
    a: &MatI8,
    bp: &PackedMatI4,
    idx: &[usize],
    c: &mut MatI32,
    cfg: ParallelGemm,
) {
    assert_eq!(a.cols, idx.len(), "compact A width vs index list");
    debug_assert!(idx.iter().all(|&k| k < bp.rows));
    let (m, n) = (a.rows, bp.cols);
    let route = Kernel::Auto.route_w4();
    c.rows = m;
    c.cols = n;
    c.data.resize(m * n, 0);
    if TileConfig::use_gemv(m) {
        gemv_dispatch_w4(a, bp, Some(idx), route, &mut c.data);
        return;
    }
    let mr = TileConfig::mr_for(m);
    run_row_parallel(m, n, idx.len(), cfg, &mut c.data, &|row0, row1, chunk| {
        gemm_rows_w4(a, bp, Some(idx), route, mr, row0, row1, chunk);
    });
}

/// Skinny-M W4 GEMV: the decode projection against nibble panels — the
/// call where the 2× byte cut matters most, since an M=1 token streams
/// the entire weight once and npusim prices decode as bytes-bound. A
/// rows stream in place, no interleave buffer, no threads, same as the
/// i8 GEMV.
pub fn matmul_i8w4_gemv_into(a: &MatI8, bp: &PackedMatI4, c: &mut MatI32, kernel: Kernel) {
    assert_eq!(a.cols, bp.rows, "inner dims {}x{}", a.cols, bp.rows);
    let (m, n) = (a.rows, bp.cols);
    let route = kernel.route_w4();
    c.rows = m;
    c.cols = n;
    c.data.resize(m * n, 0);
    gemv_dispatch_w4(a, bp, None, route, &mut c.data);
}

/// Split output rows into near-equal chunks and run `body(row0, row1,
/// chunk)` on scoped threads; sequential when the shape is small.
fn run_row_parallel(
    m: usize,
    n: usize,
    k: usize,
    cfg: ParallelGemm,
    data: &mut [i32],
    body: &(dyn Fn(usize, usize, &mut [i32]) + Sync),
) {
    let threads = cfg.threads.min(m).max(1);
    if threads == 1 || n == 0 || m * k * n < cfg.min_parallel_macs {
        body(0, m, data);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in data.chunks_mut(rows_per * n).enumerate() {
            let row0 = t * rows_per;
            let row1 = (row0 + rows_per).min(m);
            s.spawn(move || body(row0, row1, chunk));
        }
    });
}

/// Compute output rows `[row0, row1)` into `c_rows` (len `(row1-row0)*n`).
/// One driver for both the dense GEMM (`idx == None`, contraction over
/// `0..k`) and the Aux rows-subset GEMM (`idx == Some`, contraction
/// walking the index list). Register tiles cascade 8 → 4 → 1 rows (the
/// 8-row tier only when `mr == 8`), all through the same const-generic
/// microkernels (a 1-row tile is just `M = 1`) — so a parallel chunk or
/// tail shorter than `mr` still gets the widest tile that fits instead
/// of falling straight to the scalar row path. Each (row-tile, panel)
/// pair streams the FULL contraction once, so every output element is
/// written exactly once (store, not accumulate).
#[allow(clippy::too_many_arguments)]
fn gemm_rows(
    a: &MatI8,
    bp: &PackedMatI8,
    idx: Option<&[usize]>,
    route: Route,
    mr: usize,
    row0: usize,
    row1: usize,
    c_rows: &mut [i32],
) {
    debug_assert_eq!(c_rows.len(), (row1 - row0) * bp.cols);
    let mut abuf = Vec::new();
    let mut i = row0;
    if mr == 8 {
        i = if bp.nr == 8 {
            tiles::<8, 8>(a, bp, idx, route, i, row1, row0, c_rows, &mut abuf)
        } else {
            tiles::<8, 4>(a, bp, idx, route, i, row1, row0, c_rows, &mut abuf)
        };
    }
    i = if bp.nr == 8 {
        tiles::<4, 8>(a, bp, idx, route, i, row1, row0, c_rows, &mut abuf)
    } else {
        tiles::<4, 4>(a, bp, idx, route, i, row1, row0, c_rows, &mut abuf)
    };
    if bp.nr == 8 {
        tiles::<1, 8>(a, bp, idx, route, i, row1, row0, c_rows, &mut abuf);
    } else {
        tiles::<1, 4>(a, bp, idx, route, i, row1, row0, c_rows, &mut abuf);
    }
}

/// Process full `M`-row tiles from `start` while they fit below `row1`;
/// returns the first unprocessed row. The pair path re-packs the A tile
/// into a K-major interleaved panel (`abuf[kk*M + i] = a[i][kk]`) so
/// both operands stream pair blocks with unit stride; dense contractions
/// pad it to `k_pad` (the zero pad row absorbs odd K), subset
/// contractions are exactly `idx.len()` wide (odd lists take a scalar
/// tail step inside the microkernel instead). The wide path reads A rows
/// directly (the PR-1 scheme), and so does the SIMD path — its A pairs /
/// quads are adjacent in the row itself and broadcast into registers, so
/// the interleave copy is skipped entirely (odd tails are scalar steps
/// inside the SIMD kernels; the packed zero-pad row is never read).
#[allow(clippy::too_many_arguments)]
fn tiles<const M: usize, const N: usize>(
    a: &MatI8,
    bp: &PackedMatI8,
    idx: Option<&[usize]>,
    route: Route,
    start: usize,
    row1: usize,
    row0: usize,
    c_rows: &mut [i32],
    abuf: &mut Vec<i8>,
) -> usize {
    debug_assert_eq!(N, bp.nr);
    let (k, n) = (a.cols, bp.cols);
    if route == Route::Pair {
        // zero-filled; the dense K-pad row (odd k) is never rewritten
        let awidth = if idx.is_some() { k } else { bp.k_pad };
        abuf.clear();
        abuf.resize(awidth * M, 0);
    }
    let mut i = start;
    while i + M <= row1 {
        if route == Route::Pair {
            // interleave: abuf[kk*M + di] = a[i+di][kk]
            for di in 0..M {
                let ar = a.row(i + di);
                for (kk, &v) in ar.iter().enumerate() {
                    abuf[kk * M + di] = v;
                }
            }
        }
        for p in 0..bp.panels() {
            let j0 = p * N;
            let jw = N.min(n - j0);
            let panel = bp.panel(p);
            let mut acc = [[0i32; N]; M];
            match (idx, route) {
                (None, Route::Pair) => micro_pair::<M, N>(bp.k_pad / 2, abuf, panel, &mut acc),
                (Some(ix), Route::Pair) => micro_pair_idx::<M, N>(ix, abuf, panel, &mut acc),
                (None, Route::Wide) => {
                    let rows: [&[i8]; M] = std::array::from_fn(|di| a.row(i + di));
                    micro_wide::<M, N>(k, &rows, panel, &mut acc);
                }
                (Some(ix), Route::Wide) => {
                    let rows: [&[i8]; M] = std::array::from_fn(|di| a.row(i + di));
                    micro_wide_idx::<M, N>(ix, &rows, panel, &mut acc);
                }
                (None, Route::Simd) => {
                    let rows: [&[i8]; M] = std::array::from_fn(|di| a.row(i + di));
                    simd::micro_dense::<M, N>(k, &rows, panel, &mut acc);
                }
                (Some(ix), Route::Simd) => {
                    let rows: [&[i8]; M] = std::array::from_fn(|di| a.row(i + di));
                    simd::micro_idx::<M, N>(ix, &rows, panel, &mut acc);
                }
            }
            for (di, accr) in acc.iter().enumerate() {
                c_rows[(i - row0 + di) * n + j0..][..jw].copy_from_slice(&accr[..jw]);
            }
        }
        i += M;
    }
    i
}

/// W4 twin of [`gemm_rows`]: same 8 → 4 → 1 register-tile cascade, one
/// driver for dense and rows-subset contractions. No A-interleave
/// buffer on any route — the packed byte already holds the whole
/// k-pair, so the scalar W4 kernel reads A rows in place just like the
/// SIMD kernels do.
#[allow(clippy::too_many_arguments)]
fn gemm_rows_w4(
    a: &MatI8,
    bp: &PackedMatI4,
    idx: Option<&[usize]>,
    route: Route,
    mr: usize,
    row0: usize,
    row1: usize,
    c_rows: &mut [i32],
) {
    debug_assert_eq!(c_rows.len(), (row1 - row0) * bp.cols);
    let mut i = row0;
    if mr == 8 {
        i = if bp.nr == 8 {
            tiles_w4::<8, 8>(a, bp, idx, route, i, row1, row0, c_rows)
        } else {
            tiles_w4::<8, 4>(a, bp, idx, route, i, row1, row0, c_rows)
        };
    }
    i = if bp.nr == 8 {
        tiles_w4::<4, 8>(a, bp, idx, route, i, row1, row0, c_rows)
    } else {
        tiles_w4::<4, 4>(a, bp, idx, route, i, row1, row0, c_rows)
    };
    if bp.nr == 8 {
        tiles_w4::<1, 8>(a, bp, idx, route, i, row1, row0, c_rows);
    } else {
        tiles_w4::<1, 4>(a, bp, idx, route, i, row1, row0, c_rows);
    }
}

/// Process full `M`-row W4 tiles from `start`; returns the first
/// unprocessed row. `Route::Simd` runs the host's nibble-expand SIMD
/// kernels; every other route runs the scalar W4 pair kernel (exact for
/// all inputs, so `Route::Wide` never exists for W4).
#[allow(clippy::too_many_arguments)]
fn tiles_w4<const M: usize, const N: usize>(
    a: &MatI8,
    bp: &PackedMatI4,
    idx: Option<&[usize]>,
    route: Route,
    start: usize,
    row1: usize,
    row0: usize,
    c_rows: &mut [i32],
) -> usize {
    debug_assert_eq!(N, bp.nr);
    let (k, n) = (a.cols, bp.cols);
    let mut i = start;
    while i + M <= row1 {
        for p in 0..bp.panels() {
            let j0 = p * N;
            let jw = N.min(n - j0);
            let panel = bp.panel(p);
            let mut acc = [[0i32; N]; M];
            let rows: [&[i8]; M] = std::array::from_fn(|di| a.row(i + di));
            match (idx, route) {
                (None, Route::Simd) => simd::micro_dense_w4::<M, N>(k, &rows, panel, &mut acc),
                (Some(ix), Route::Simd) => simd::micro_idx_w4::<M, N>(ix, &rows, panel, &mut acc),
                (None, _) => micro_pair_w4::<M, N>(k, &rows, panel, &mut acc),
                (Some(ix), _) => micro_idx_w4::<M, N>(ix, &rows, panel, &mut acc),
            }
            for (di, accr) in acc.iter().enumerate() {
                c_rows[(i - row0 + di) * n + j0..][..jw].copy_from_slice(&accr[..jw]);
            }
        }
        i += M;
    }
    i
}

/// GEMV driver: panel-outer / row-inner, so one B panel stays hot in L1
/// across the (few) A rows; each output element is written exactly once.
/// Monomorphizes on the packed panel width.
fn gemv_dispatch(a: &MatI8, bp: &PackedMatI8, idx: Option<&[usize]>, route: Route, c: &mut [i32]) {
    if bp.nr == 8 {
        gemv_panels::<8>(a, bp, idx, route, c);
    } else {
        gemv_panels::<4>(a, bp, idx, route, c);
    }
}

fn gemv_panels<const N: usize>(
    a: &MatI8,
    bp: &PackedMatI8,
    idx: Option<&[usize]>,
    route: Route,
    c: &mut [i32],
) {
    debug_assert_eq!(N, bp.nr);
    let n = bp.cols;
    for p in 0..bp.panels() {
        let j0 = p * N;
        let jw = N.min(n - j0);
        let panel = bp.panel(p);
        for i in 0..a.rows {
            let arow = a.row(i);
            let mut acc = [[0i32; N]; 1];
            match (idx, route) {
                (None, Route::Pair) => gemv_pair::<N>(arow, panel, &mut acc[0]),
                (Some(ix), Route::Pair) => gemv_pair_idx::<N>(arow, ix, panel, &mut acc[0]),
                // the wide fallback is the existing 1-row microkernels
                (None, Route::Wide) => micro_wide::<1, N>(arow.len(), &[arow], panel, &mut acc),
                (Some(ix), Route::Wide) => micro_wide_idx::<1, N>(ix, &[arow], panel, &mut acc),
                // SIMD GEMV = the 1-row instances of the SIMD kernels:
                // the A row streams in place, same as the scalar twins
                (None, Route::Simd) => {
                    simd::micro_dense::<1, N>(arow.len(), &[arow], panel, &mut acc)
                }
                (Some(ix), Route::Simd) => simd::micro_idx::<1, N>(ix, &[arow], panel, &mut acc),
            }
            c[i * n + j0..][..jw].copy_from_slice(&acc[0][..jw]);
        }
    }
}

/// Dense GEMV pair step: A row read in place, two k's per i32 widening.
/// Odd K takes one scalar tail step against the last real B row (the
/// packed zero-pad row is never touched, so the A row needs no padding).
#[inline(always)]
fn gemv_pair<const N: usize>(arow: &[i8], panel: &[i8], acc: &mut [i32; N]) {
    let k = arow.len();
    for t in 0..k / 2 {
        let a_lo = arow[2 * t] as i16;
        let a_hi = arow[2 * t + 1] as i16;
        let bb = &panel[2 * t * N..2 * t * N + 2 * N];
        for j in 0..N {
            let p = a_lo * bb[j] as i16;
            let q = a_hi * bb[N + j] as i16;
            acc[j] += (p + q) as i32;
        }
    }
    if k % 2 == 1 {
        let av = arow[k - 1] as i32;
        let b = &panel[(k - 1) * N..(k - 1) * N + N];
        for j in 0..N {
            acc[j] += av * b[j] as i32;
        }
    }
}

/// Rows-subset GEMV pair step (Aux GEMM at decode): the contraction
/// walks `idx` two entries at a time, B rows from arbitrary panel
/// offsets, the A pair contiguous in the row itself.
#[inline(always)]
fn gemv_pair_idx<const N: usize>(arow: &[i8], idx: &[usize], panel: &[i8], acc: &mut [i32; N]) {
    let pairs = idx.len() / 2;
    for t in 0..pairs {
        let a_lo = arow[2 * t] as i16;
        let a_hi = arow[2 * t + 1] as i16;
        let b0 = &panel[idx[2 * t] * N..idx[2 * t] * N + N];
        let b1 = &panel[idx[2 * t + 1] * N..idx[2 * t + 1] * N + N];
        for j in 0..N {
            let p = a_lo * b0[j] as i16;
            let q = a_hi * b1[j] as i16;
            acc[j] += (p + q) as i32;
        }
    }
    if idx.len() % 2 == 1 {
        let t = idx.len() - 1;
        let av = arow[t] as i32;
        let b = &panel[idx[t] * N..idx[t] * N + N];
        for j in 0..N {
            acc[j] += av * b[j] as i32;
        }
    }
}

/// W4 GEMV driver: same panel-outer / row-inner walk as
/// [`gemv_dispatch`], against nibble panels. The GEMV kernels ARE the
/// M=1 instances of the W4 microkernels — the A row streams in place on
/// every route, so no separate pair/idx GEMV bodies are needed.
fn gemv_dispatch_w4(
    a: &MatI8,
    bp: &PackedMatI4,
    idx: Option<&[usize]>,
    route: Route,
    c: &mut [i32],
) {
    if bp.nr == 8 {
        gemv_panels_w4::<8>(a, bp, idx, route, c);
    } else {
        gemv_panels_w4::<4>(a, bp, idx, route, c);
    }
}

fn gemv_panels_w4<const N: usize>(
    a: &MatI8,
    bp: &PackedMatI4,
    idx: Option<&[usize]>,
    route: Route,
    c: &mut [i32],
) {
    debug_assert_eq!(N, bp.nr);
    let n = bp.cols;
    for p in 0..bp.panels() {
        let j0 = p * N;
        let jw = N.min(n - j0);
        let panel = bp.panel(p);
        for i in 0..a.rows {
            let arow = a.row(i);
            let mut acc = [[0i32; N]; 1];
            match (idx, route) {
                (None, Route::Simd) => {
                    simd::micro_dense_w4::<1, N>(arow.len(), &[arow], panel, &mut acc)
                }
                (Some(ix), Route::Simd) => {
                    simd::micro_idx_w4::<1, N>(ix, &[arow], panel, &mut acc)
                }
                (None, _) => micro_pair_w4::<1, N>(arow.len(), &[arow], panel, &mut acc),
                (Some(ix), _) => micro_idx_w4::<1, N>(ix, &[arow], panel, &mut acc),
            }
            c[i * n + j0..][..jw].copy_from_slice(&acc[0][..jw]);
        }
    }
}

/// i16 pair-accumulation microkernel: `kp` K-pairs, both operands
/// pair-interleaved (A: `2·M` block per pair, B panel: `2·N` block per
/// pair). Each i8×i8 product widens to i16 (|p| ≤ 16384 always fits);
/// the pair adds in i16 — bounded by 32512 < `i16::MAX` because the
/// dispatcher guarantees B holds no -128 — and widens into i32 once per
/// pair: two MACs per lane per widening step.
#[inline(always)]
fn micro_pair<const M: usize, const N: usize>(
    kp: usize,
    apanel: &[i8],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    for t in 0..kp {
        let ab = &apanel[2 * t * M..2 * t * M + 2 * M];
        let bb = &panel[2 * t * N..2 * t * N + 2 * N];
        for i in 0..M {
            let a_lo = ab[i] as i16;
            let a_hi = ab[M + i] as i16;
            for j in 0..N {
                let p = a_lo * bb[j] as i16;
                let q = a_hi * bb[N + j] as i16;
                acc[i][j] += (p + q) as i32;
            }
        }
    }
}

/// One contraction step of the M×N tile at position `kk` (wide-i32).
#[inline(always)]
fn wide_step<const M: usize, const N: usize>(
    kk: usize,
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    let b = &panel[kk * N..kk * N + N];
    for i in 0..M {
        let av = a[i][kk] as i32;
        for j in 0..N {
            acc[i][j] += av * b[j] as i32;
        }
    }
}

/// Wide-i32 microkernel (the PR-1 scheme): M×N i32 accumulators live
/// across the whole K loop, K unrolled by 4, branch-free dense MACs, one
/// MAC per lane per step. Exact for every i8 input (kept as the -128
/// fallback and the pair-kernel comparator; also the portable fallback
/// behind `super::simd`'s wrappers on arches with no SIMD kernel).
#[inline(always)]
pub(crate) fn micro_wide<const M: usize, const N: usize>(
    k: usize,
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    let mut kk = 0;
    while kk + 4 <= k {
        wide_step::<M, N>(kk, a, panel, acc);
        wide_step::<M, N>(kk + 1, a, panel, acc);
        wide_step::<M, N>(kk + 2, a, panel, acc);
        wide_step::<M, N>(kk + 3, a, panel, acc);
        kk += 4;
    }
    while kk < k {
        wide_step::<M, N>(kk, a, panel, acc);
        kk += 1;
    }
}

/// Index-mapped pair microkernel (Aux GEMM): the contraction walks `idx`
/// two entries at a time — the pair's B rows come from arbitrary panel
/// offsets, the A pair stays contiguous in the interleaved tile. An
/// odd-length list takes one scalar (wide-i32) tail step.
#[inline(always)]
fn micro_pair_idx<const M: usize, const N: usize>(
    idx: &[usize],
    apanel: &[i8],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    let pairs = idx.len() / 2;
    for t in 0..pairs {
        let b0 = &panel[idx[2 * t] * N..idx[2 * t] * N + N];
        let b1 = &panel[idx[2 * t + 1] * N..idx[2 * t + 1] * N + N];
        let ab = &apanel[2 * t * M..2 * t * M + 2 * M];
        for i in 0..M {
            let a_lo = ab[i] as i16;
            let a_hi = ab[M + i] as i16;
            for j in 0..N {
                let p = a_lo * b0[j] as i16;
                let q = a_hi * b1[j] as i16;
                acc[i][j] += (p + q) as i32;
            }
        }
    }
    if idx.len() % 2 == 1 {
        let t = idx.len() - 1;
        let b = &panel[idx[t] * N..idx[t] * N + N];
        let ab = &apanel[t * M..t * M + M];
        for i in 0..M {
            let av = ab[i] as i32;
            for j in 0..N {
                acc[i][j] += av * b[j] as i32;
            }
        }
    }
}

/// Index-mapped wide-i32 microkernel (Aux GEMM fallback path).
#[inline(always)]
pub(crate) fn micro_wide_idx<const M: usize, const N: usize>(
    idx: &[usize],
    a: &[&[i8]; M],
    panel: &[i8],
    acc: &mut [[i32; N]; M],
) {
    for (t, &krow) in idx.iter().enumerate() {
        let b = &panel[krow * N..krow * N + N];
        for i in 0..M {
            let av = a[i][t] as i32;
            for j in 0..N {
                acc[i][j] += av * b[j] as i32;
            }
        }
    }
}

/// Sign-extend the LOW nibble of a packed W4 byte (the even-k weight):
/// shift the nibble to the top of the byte, then arithmetic-shift back.
#[inline(always)]
pub(crate) fn nib_lo(b: u8) -> i8 {
    ((b << 4) as i8) >> 4
}

/// Sign-extend the HIGH nibble of a packed W4 byte (the odd-k weight).
#[inline(always)]
pub(crate) fn nib_hi(b: u8) -> i8 {
    (b as i8) >> 4
}

/// Nibble of packed byte `b` for k-row parity `odd`.
#[inline(always)]
pub(crate) fn nib(b: u8, odd: bool) -> i8 {
    if odd {
        nib_hi(b)
    } else {
        nib_lo(b)
    }
}

/// Scalar W4 dense microkernel: one packed byte per (k-pair, column),
/// both nibbles unpacked in-register and retired against the adjacent
/// A pair in one i16 pair sum.
///
/// No-overflow proof (stronger than the i8 kernel's): |w| ≤ 8 and
/// |a| ≤ 128 bound each i16 product by 1024 and the pair sum by 2048 ≪
/// `i16::MAX` — exact for EVERY input including the -8 nibble corner
/// and a -128 activation, so W4 needs no pack-time -128 scan and no
/// wide-i32 fallback route. A rows are read in place (the byte already
/// holds the whole k-pair, so there is nothing to interleave); odd K
/// takes the low nibble of the final byte row (its high nibble is the
/// zero pad).
#[inline(always)]
pub(crate) fn micro_pair_w4<const M: usize, const N: usize>(
    k: usize,
    a: &[&[i8]; M],
    panel: &[u8],
    acc: &mut [[i32; N]; M],
) {
    debug_assert!(panel.len() >= k.div_ceil(2) * N);
    for t in 0..k / 2 {
        let bb = &panel[t * N..t * N + N];
        for i in 0..M {
            let a_lo = a[i][2 * t] as i16;
            let a_hi = a[i][2 * t + 1] as i16;
            for j in 0..N {
                let p = a_lo * nib_lo(bb[j]) as i16;
                let q = a_hi * nib_hi(bb[j]) as i16;
                acc[i][j] += (p + q) as i32;
            }
        }
    }
    if k % 2 == 1 {
        let bb = &panel[(k / 2) * N..(k / 2) * N + N];
        for i in 0..M {
            let av = a[i][k - 1] as i32;
            for j in 0..N {
                acc[i][j] += av * nib_lo(bb[j]) as i32;
            }
        }
    }
}

/// Index-mapped W4 microkernel (Aux GEMM against a nibble body): walks
/// `idx` in pairs — each indexed k row is the `idx[t] & 1` nibble of
/// byte row `idx[t] / 2`, read from arbitrary panel offsets. The i16
/// pair sum stays bounded by 2048, so odd-length lists just take a
/// single-nibble tail step (no widening needed).
#[inline(always)]
pub(crate) fn micro_idx_w4<const M: usize, const N: usize>(
    idx: &[usize],
    a: &[&[i8]; M],
    panel: &[u8],
    acc: &mut [[i32; N]; M],
) {
    let pairs = idx.len() / 2;
    for t in 0..pairs {
        let (k0, k1) = (idx[2 * t], idx[2 * t + 1]);
        let b0 = &panel[(k0 >> 1) * N..(k0 >> 1) * N + N];
        let b1 = &panel[(k1 >> 1) * N..(k1 >> 1) * N + N];
        let (o0, o1) = (k0 & 1 == 1, k1 & 1 == 1);
        for i in 0..M {
            let a_lo = a[i][2 * t] as i16;
            let a_hi = a[i][2 * t + 1] as i16;
            for j in 0..N {
                let p = a_lo * nib(b0[j], o0) as i16;
                let q = a_hi * nib(b1[j], o1) as i16;
                acc[i][j] += (p + q) as i32;
            }
        }
    }
    if idx.len() % 2 == 1 {
        let t = idx.len() - 1;
        let krow = idx[t];
        let b = &panel[(krow >> 1) * N..(krow >> 1) * N + N];
        let odd = krow & 1 == 1;
        for i in 0..M {
            let av = a[i][t] as i32;
            for j in 0..N {
                acc[i][j] += av * nib(b[j], odd) as i32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;

    fn rand_i8(rows: usize, cols: usize, seed: u64) -> MatI8 {
        let mut rng = SplitMix64::new(seed);
        let mut m = MatI8::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = (rng.next_below(255) as i32 - 127) as i8;
        }
        m
    }

    fn matmul_naive(a: &MatI8, b: &MatI8) -> MatI32 {
        let mut c = MatI32::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0i32;
                for k in 0..a.cols {
                    acc += a.row(i)[k] as i32 * b.data[k * b.cols + j] as i32;
                }
                c.data[i * b.cols + j] = acc;
            }
        }
        c
    }

    #[test]
    fn pack_layout_golden() {
        // 2x3 (one padded panel, even K): [b00 b01 b02 0 | b10 b11 b12 0]
        let mut b = MatI8::zeros(2, 3);
        b.data.copy_from_slice(&[1, 2, 3, 4, 5, 6]);
        let p = PackedMatI8::pack_with(&b, 4);
        assert_eq!(p.panels(), 1);
        assert_eq!(p.padded_bytes(), 2 * 4);
        assert_eq!(p.logical_len(), 6);
        assert_eq!(p.panel(0), &[1, 2, 3, 0, 4, 5, 6, 0]);
        assert!(!p.has_neg128());
    }

    #[test]
    fn pack_layout_odd_k_pair_padded() {
        // 3x3: odd K rounds up to k_pad = 4 with one zero row per panel,
        // so the pair kernel needs no K-tail branch
        let mut b = MatI8::zeros(3, 3);
        b.data.copy_from_slice(&[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        let p = PackedMatI8::pack_with(&b, 4);
        assert_eq!(p.panels(), 1);
        assert_eq!(p.padded_bytes(), 4 * 4);
        assert_eq!(p.panel(0), &[1, 2, 3, 0, 4, 5, 6, 0, 7, 8, 9, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn tile_parse_and_heuristics() {
        assert_eq!(TileConfig::parse("8x4"), Some(TileConfig { mr: 8, nr: 4 }));
        assert_eq!(TileConfig::parse(" 4X8 "), Some(TileConfig { mr: 4, nr: 8 }));
        assert_eq!(TileConfig::parse("6x4"), None);
        assert_eq!(TileConfig::parse("8"), None);
        assert_eq!(TileConfig::parse("8x16"), None);
        // per-arch tables (no MUXQ_TILE override in the test env):
        // narrow outputs stay at the portable width on every arch and
        // wide outputs widen; at L1-blowing K the scalar rows narrow
        // back to 4 while the SIMD rows keep full-width panels (the A
        // side is register broadcasts, not an interleaved L1 tile)
        assert_eq!(TileConfig::nr_for(768, 4), 4);
        assert_eq!(TileConfig::nr_for(768, 768), 8);
        let deep = TileConfig::nr_for(1 << 20, 768);
        if simd::dispatch().is_simd() {
            assert_eq!(deep, 8, "SIMD table keeps wide panels at deep K");
        } else {
            assert_eq!(deep, 4, "scalar table narrows at deep K");
        }
        assert_eq!(TileConfig::mr_for(4), 4);
        assert_eq!(TileConfig::mr_for(512), 8);
    }

    #[test]
    fn packed_matches_naive_ragged_shapes() {
        // 1x1x1, primes, odd K, and dims straddling MR/NR panel
        // boundaries — via the auto-selected (pair) kernel and tile
        for &(m, k, n) in &[
            (1, 1, 1),
            (2, 3, 5),
            (7, 11, 13),
            (4, 4, 4),
            (5, 4, 9),
            (6, 65, 7),
            (33, 17, 12),
            (8, 8, 3),
            (9, 7, 10),
        ] {
            let a = rand_i8(m, k, m as u64 * 31 + n as u64);
            let b = rand_i8(k, n, k as u64 * 37 + 1);
            let bp = PackedMatI8::pack(&b);
            let got = matmul_i8_packed_with(&a, &bp, ParallelGemm::sequential());
            let want = matmul_naive(&a, &b);
            assert_eq!(got.data, want.data, "{m}x{k}x{n}");
        }
    }

    /// Every explicitly selectable kernel on this host (Simd only where
    /// the host has one — `Kernel::Simd` is a clean panic elsewhere).
    fn selectable_kernels() -> Vec<Kernel> {
        let mut ks = vec![Kernel::PairI16, Kernel::WideI32, Kernel::Auto];
        if simd::host_simd().is_some() {
            ks.push(Kernel::Simd);
        }
        ks
    }

    #[test]
    fn pair_wide_and_simd_kernels_bit_exact_across_tile_grid() {
        // every (kernel, mr, nr) combination against the naive loop,
        // on shapes with odd K and ragged M/N tails
        for &(m, k, n) in &[(5, 9, 11), (8, 16, 8), (13, 31, 17), (1, 3, 1)] {
            let a = rand_i8(m, k, 100 + m as u64);
            let b = rand_i8(k, n, 200 + n as u64);
            let want = matmul_naive(&a, &b);
            for nr in [4usize, 8] {
                let bp = PackedMatI8::pack_with(&b, nr);
                for mr in [4usize, 8] {
                    for kernel in selectable_kernels() {
                        let mut c = MatI32::zeros(0, 0);
                        matmul_i8_packed_kernel_into(
                            &a,
                            &bp,
                            &mut c,
                            ParallelGemm::sequential(),
                            kernel,
                            mr,
                        );
                        assert_eq!(
                            c.data, want.data,
                            "{m}x{k}x{n} {kernel:?} tile {mr}x{nr}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn simd_kernel_exact_even_with_neg128_weights() {
        // the SIMD kernels form pair/quad sums in i32, so unlike the
        // scalar pair kernel they need no −128 fallback: the all-(−128)
        // corner must be bit-exact through the explicit Simd selection,
        // dense AND rows-subset, GEMV and tiled
        if simd::host_simd().is_none() {
            return; // no SIMD on this host; routing covered elsewhere
        }
        let mut a = MatI8::zeros(5, 7);
        let mut b = MatI8::zeros(7, 9);
        a.data.iter_mut().for_each(|v| *v = i8::MIN);
        b.data.iter_mut().for_each(|v| *v = i8::MIN);
        let want = matmul_naive(&a, &b);
        for nr in [4usize, 8] {
            let bp = PackedMatI8::pack_with(&b, nr);
            assert!(bp.has_neg128());
            for mr in [4usize, 8] {
                let mut c = MatI32::zeros(0, 0);
                matmul_i8_packed_kernel_into(
                    &a,
                    &bp,
                    &mut c,
                    ParallelGemm::sequential(),
                    Kernel::Simd,
                    mr,
                );
                assert_eq!(c.data, want.data, "tile {mr}x{nr}");
            }
            let mut g = MatI32::zeros(0, 0);
            matmul_i8_gemv_into(&a, &bp, &mut g, Kernel::Simd);
            assert_eq!(g.data, want.data, "gemv nr {nr}");
        }
    }

    #[test]
    fn auto_route_honors_dispatch() {
        // whatever MUXQ_FORCE_KERNEL this suite runs under, Auto must
        // resolve consistently with the process-wide dispatch — and a
        // −128-laden B may only downgrade the scalar pair route
        let clean = PackedMatI8::pack(&rand_i8(6, 5, 77));
        assert!(!clean.has_neg128());
        let mut hot = MatI8::zeros(6, 5);
        hot.data[3] = i8::MIN;
        let hotp = PackedMatI8::pack(&hot);
        assert!(hotp.has_neg128());
        match simd::dispatch() {
            DispatchKernel::Avx2 | DispatchKernel::Neon => {
                assert_eq!(Kernel::Auto.route(&clean), Route::Simd);
                assert_eq!(Kernel::Auto.route(&hotp), Route::Simd);
            }
            DispatchKernel::Pair => {
                assert_eq!(Kernel::Auto.route(&clean), Route::Pair);
                assert_eq!(Kernel::Auto.route(&hotp), Route::Wide);
            }
            DispatchKernel::Scalar => {
                assert_eq!(Kernel::Auto.route(&clean), Route::Wide);
                assert_eq!(Kernel::Auto.route(&hotp), Route::Wide);
            }
        }
        // explicit selections ignore the env
        assert_eq!(Kernel::WideI32.route(&clean), Route::Wide);
        assert_eq!(Kernel::PairI16.route(&clean), Route::Pair);
    }

    #[test]
    fn neg128_weights_fall_back_to_wide_and_stay_exact() {
        // all-(-128) operands: the i16 pair sum would wrap at +32768, so
        // Auto must route to the wide kernel and match the naive loop
        let mut a = MatI8::zeros(4, 6);
        let mut b = MatI8::zeros(6, 5);
        a.data.iter_mut().for_each(|v| *v = i8::MIN);
        b.data.iter_mut().for_each(|v| *v = i8::MIN);
        let bp = PackedMatI8::pack(&b);
        assert!(bp.has_neg128());
        let got = matmul_i8_packed_with(&a, &bp, ParallelGemm::sequential());
        assert_eq!(got.data, matmul_naive(&a, &b).data);
        // and -128 on the A side alone is safe for the pair kernel:
        // |(-128)·b| ≤ 128·127, pair sum ≤ 32512 < i16::MAX
        let b7 = rand_i8(6, 5, 7);
        let bp7 = PackedMatI8::pack(&b7);
        assert!(!bp7.has_neg128());
        let got7 = matmul_i8_packed_with(&a, &bp7, ParallelGemm::sequential());
        assert_eq!(got7.data, matmul_naive(&a, &b7).data);
    }

    #[test]
    fn parallel_bit_exact_vs_sequential() {
        let a = rand_i8(37, 29, 1);
        let b = rand_i8(29, 23, 2);
        let bp = PackedMatI8::pack(&b);
        let seq = matmul_i8_packed_with(&a, &bp, ParallelGemm::sequential());
        for threads in [2usize, 3, 4, 8] {
            let cfg = ParallelGemm { threads, min_parallel_macs: 0 };
            let par = matmul_i8_packed_with(&a, &bp, cfg);
            assert_eq!(par.data, seq.data, "{threads} threads");
            assert_eq!((par.rows, par.cols), (37, 23));
        }
    }

    #[test]
    fn rows_subset_equals_explicit_gather() {
        let b = rand_i8(15, 10, 4);
        for idx in [&[2usize, 7, 14][..], &[0, 3, 6, 11][..], &[5][..]] {
            let a = rand_i8(9, idx.len(), 3); // compact [m, r]
            for nr in [4usize, 8] {
                let bp = PackedMatI8::pack_with(&b, nr);
                let mut got = MatI32::zeros(0, 0);
                matmul_i8_rows_subset_into(&a, &bp, idx, &mut got, ParallelGemm::sequential());
                // reference: gather the rows, then dense naive
                let mut gathered = MatI8::zeros(idx.len(), 10);
                for (t, &r) in idx.iter().enumerate() {
                    gathered.data[t * 10..(t + 1) * 10].copy_from_slice(b.row(r));
                }
                let want = matmul_naive(&a, &gathered);
                assert_eq!(got.data, want.data, "idx {idx:?} nr {nr}");
                // and in parallel
                let mut par = MatI32::zeros(0, 0);
                let cfg = ParallelGemm { threads: 3, min_parallel_macs: 0 };
                matmul_i8_rows_subset_into(&a, &bp, idx, &mut par, cfg);
                assert_eq!(par.data, want.data, "parallel idx {idx:?} nr {nr}");
            }
        }
    }

    #[test]
    fn gemv_matches_naive_skinny_shapes() {
        // the decode regime: M in 1..=4, odd/even K, ragged N tails,
        // both panel widths, explicit pair and wide kernels
        for &(m, k, n) in &[(1, 1, 1), (1, 7, 5), (1, 64, 48), (2, 9, 11), (3, 16, 4), (4, 33, 13)]
        {
            let a = rand_i8(m, k, 300 + m as u64 * 7 + k as u64);
            let b = rand_i8(k, n, 400 + n as u64);
            let want = matmul_naive(&a, &b);
            for nr in [4usize, 8] {
                let bp = PackedMatI8::pack_with(&b, nr);
                for kernel in selectable_kernels() {
                    let mut c = MatI32::zeros(0, 0);
                    matmul_i8_gemv_into(&a, &bp, &mut c, kernel);
                    assert_eq!(c.data, want.data, "{m}x{k}x{n} {kernel:?} nr {nr}");
                    assert_eq!((c.rows, c.cols), (m, n));
                }
            }
        }
    }

    #[test]
    fn gemv_neg128_weights_fall_back_to_wide() {
        let a = rand_i8(1, 10, 1);
        let mut b = MatI8::zeros(10, 6);
        b.data.iter_mut().for_each(|v| *v = i8::MIN);
        let bp = PackedMatI8::pack(&b);
        assert!(bp.has_neg128());
        let mut c = MatI32::zeros(0, 0);
        matmul_i8_gemv_into(&a, &bp, &mut c, Kernel::Auto);
        assert_eq!(c.data, matmul_naive(&a, &b).data);
    }

    #[test]
    fn skinny_auto_route_matches_tile_cascade() {
        // matmul_i8_packed_into routes M <= gemv_max_m through the GEMV
        // path; results must be bit-identical to the explicit-tile path
        assert_eq!(TileConfig::gemv_max_m(), 4);
        assert!(TileConfig::use_gemv(1) && TileConfig::use_gemv(4));
        assert!(!TileConfig::use_gemv(5));
        for m in 1..=4usize {
            let a = rand_i8(m, 31, 500 + m as u64);
            let b = rand_i8(31, 17, 600);
            let bp = PackedMatI8::pack(&b);
            let mut via_auto = MatI32::zeros(0, 0);
            matmul_i8_packed_into(&a, &bp, &mut via_auto, ParallelGemm::sequential());
            let mut via_tiles = MatI32::zeros(0, 0);
            matmul_i8_packed_kernel_into(
                &a,
                &bp,
                &mut via_tiles,
                ParallelGemm::sequential(),
                Kernel::Auto,
                4,
            );
            assert_eq!(via_auto.data, via_tiles.data, "m = {m}");
        }
    }

    #[test]
    fn gemv_rows_subset_matches_gather() {
        // Aux-GEMM decode shape: single row against scattered weight rows
        let b = rand_i8(21, 9, 8);
        for idx in [&[0usize][..], &[3, 7][..], &[1, 4, 9, 16, 20][..]] {
            for m in 1..=4usize {
                let a = rand_i8(m, idx.len(), 9 + m as u64);
                for nr in [4usize, 8] {
                    let bp = PackedMatI8::pack_with(&b, nr);
                    let mut got = MatI32::zeros(0, 0);
                    matmul_i8_rows_subset_into(&a, &bp, idx, &mut got, ParallelGemm::sequential());
                    let mut gathered = MatI8::zeros(idx.len(), 9);
                    for (t, &r) in idx.iter().enumerate() {
                        gathered.data[t * 9..(t + 1) * 9].copy_from_slice(b.row(r));
                    }
                    assert_eq!(
                        got.data,
                        matmul_naive(&a, &gathered).data,
                        "m {m} idx {idx:?} nr {nr}"
                    );
                }
            }
        }
    }

    #[test]
    fn into_reuses_and_resizes_scratch() {
        let mut c = MatI32::zeros(64, 64); // oversized scratch
        let a = rand_i8(3, 5, 5);
        let b = rand_i8(5, 6, 6);
        let bp = PackedMatI8::pack(&b);
        matmul_i8_packed_into(&a, &bp, &mut c, ParallelGemm::sequential());
        assert_eq!((c.rows, c.cols, c.data.len()), (3, 6, 18));
        assert_eq!(c.data, matmul_naive(&a, &b).data);
    }

    #[test]
    fn pack_count_is_per_thread() {
        let before = pack_count();
        let _ = PackedMatI8::pack(&rand_i8(2, 2, 7));
        let _ = PackedMatI8::pack(&rand_i8(2, 2, 8));
        assert_eq!(pack_count(), before + 2);
    }

    // ------------------------------------------------------- W4 (nibble)

    /// Random i4-range weights, full signed span [-8, 7] incl. -8.
    fn rand_i4(rows: usize, cols: usize, seed: u64) -> MatI8 {
        let mut rng = SplitMix64::new(seed);
        let mut m = MatI8::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = (rng.next_below(16) as i32 - 8) as i8;
        }
        m
    }

    #[test]
    fn pack4_layout_golden() {
        // 4x3, nr 4: two byte rows per panel, each byte = (even k lo
        // nibble, odd k hi nibble); col pad bytes zero
        let mut b = MatI8::zeros(4, 3);
        b.data.copy_from_slice(&[1, -2, 3, -8, 5, -6, 7, 0, -1, 2, -3, 4]);
        let p = PackedMatI4::pack_with(&b, 4);
        assert_eq!(p.panels(), 1);
        assert_eq!(p.padded_bytes(), 2 * 4); // (k_pad/2)·nr = 2·4
        assert_eq!(p.logical_len(), 12);
        assert!(!p.saturated());
        // byte row 0 pairs rows 0/1: (1,-8) (−2,5) (3,−6) (pad 0,0)
        // byte row 1 pairs rows 2/3: (7,2) (0,−3) (−1,4)
        let lo = |v: i8| (v as u8) & 0x0f;
        let hi = |v: i8| ((v as u8) & 0x0f) << 4;
        assert_eq!(
            p.panel(0),
            &[
                lo(1) | hi(-8),
                lo(-2) | hi(5),
                lo(3) | hi(-6),
                0,
                lo(7) | hi(2),
                lo(0) | hi(-3),
                lo(-1) | hi(4),
                0
            ]
        );
        // every logical element round-trips through get(), -8 included
        for k in 0..4 {
            for j in 0..3 {
                assert_eq!(p.get(k, j), b.data[k * 3 + j], "({k},{j})");
            }
        }
    }

    #[test]
    fn pack4_odd_k_zero_pads_high_nibble() {
        let mut b = MatI8::zeros(3, 2);
        b.data.copy_from_slice(&[-8, 7, 1, -1, 5, -5]);
        let p = PackedMatI4::pack_with(&b, 4);
        assert_eq!(p.padded_bytes(), 2 * 4);
        // last byte row pairs row 2 with the zero pad row
        assert_eq!(nib_lo(p.panel(0)[4]), 5);
        assert_eq!(nib_hi(p.panel(0)[4]), 0);
        assert_eq!(p.get(2, 1), -5);
    }

    #[test]
    fn pack4_saturates_out_of_range_and_records_it() {
        let mut b = MatI8::zeros(2, 2);
        b.data.copy_from_slice(&[127, -128, 8, -9]);
        let p = PackedMatI4::pack(&b);
        assert!(p.saturated());
        assert_eq!(p.get(0, 0), 7);
        assert_eq!(p.get(0, 1), -8);
        assert_eq!(p.get(1, 0), 7);
        assert_eq!(p.get(1, 1), -8);
        // in-range packs never trip the flag
        assert!(!PackedMatI4::pack(&rand_i4(5, 5, 1)).saturated());
    }

    #[test]
    fn w4_matches_widened_oracle_ragged_shapes() {
        // the i8-widened oracle: the SAME i4-range weight matrix through
        // the proven i8 engine — W4 must be bit-identical at half the
        // panel bytes
        for &(m, k, n) in &[
            (1, 1, 1),
            (2, 3, 5),
            (7, 11, 13),
            (5, 4, 9),
            (6, 65, 7),
            (33, 17, 12),
            (8, 8, 3),
            (9, 7, 10),
        ] {
            let a = rand_i8(m, k, 700 + m as u64 * 31 + n as u64);
            let w = rand_i4(k, n, 800 + k as u64 * 37);
            let bp8 = PackedMatI8::pack(&w);
            let bp4 = PackedMatI4::pack(&w);
            assert_eq!(bp4.padded_bytes() * 2, bp8.padded_bytes(), "{m}x{k}x{n}");
            let want = matmul_i8_packed_with(&a, &bp8, ParallelGemm::sequential());
            let mut got = MatI32::zeros(0, 0);
            matmul_i8w4_packed_into(&a, &bp4, &mut got, ParallelGemm::sequential());
            assert_eq!(got.data, want.data, "{m}x{k}x{n}");
            assert_eq!((got.rows, got.cols), (m, n));
        }
    }

    #[test]
    fn w4_kernels_bit_exact_across_tile_grid() {
        // every (kernel, mr, nr) combination, odd K, ragged M/N tails
        for &(m, k, n) in &[(5, 9, 11), (8, 16, 8), (13, 31, 17), (1, 3, 1)] {
            let a = rand_i8(m, k, 900 + m as u64);
            let w = rand_i4(k, n, 1000 + n as u64);
            let want = matmul_naive(&a, &w);
            for nr in [4usize, 8] {
                let bp4 = PackedMatI4::pack_with(&w, nr);
                for mr in [4usize, 8] {
                    for kernel in selectable_kernels() {
                        let mut c = MatI32::zeros(0, 0);
                        matmul_i8w4_packed_kernel_into(
                            &a,
                            &bp4,
                            &mut c,
                            ParallelGemm::sequential(),
                            kernel,
                            mr,
                        );
                        assert_eq!(c.data, want.data, "{m}x{k}x{n} {kernel:?} tile {mr}x{nr}");
                    }
                }
            }
        }
    }

    #[test]
    fn w4_neg8_corner_exact_on_every_route() {
        // all-(-8) weights against all-(-128) activations: the W4 pair
        // sum peaks at 2·128·8 = 2048 — exact on every kernel with no
        // fallback (contrast the i8 engine's -128 wide fallback)
        let mut a = MatI8::zeros(5, 7);
        let mut w = MatI8::zeros(7, 9);
        a.data.iter_mut().for_each(|v| *v = i8::MIN);
        w.data.iter_mut().for_each(|v| *v = -8);
        let want = matmul_naive(&a, &w);
        for nr in [4usize, 8] {
            let bp4 = PackedMatI4::pack_with(&w, nr);
            assert!(!bp4.saturated());
            for kernel in selectable_kernels() {
                for mr in [4usize, 8] {
                    let mut c = MatI32::zeros(0, 0);
                    matmul_i8w4_packed_kernel_into(
                        &a,
                        &bp4,
                        &mut c,
                        ParallelGemm::sequential(),
                        kernel,
                        mr,
                    );
                    assert_eq!(c.data, want.data, "{kernel:?} tile {mr}x{nr}");
                }
                let mut g = MatI32::zeros(0, 0);
                matmul_i8w4_gemv_into(&a, &bp4, &mut g, kernel);
                assert_eq!(g.data, want.data, "gemv {kernel:?} nr {nr}");
            }
        }
    }

    #[test]
    fn w4_gemv_matches_naive_skinny_shapes() {
        for &(m, k, n) in &[(1, 1, 1), (1, 7, 5), (1, 64, 48), (2, 9, 11), (3, 16, 4), (4, 33, 13)]
        {
            let a = rand_i8(m, k, 1100 + m as u64 * 7 + k as u64);
            let w = rand_i4(k, n, 1200 + n as u64);
            let want = matmul_naive(&a, &w);
            for nr in [4usize, 8] {
                let bp4 = PackedMatI4::pack_with(&w, nr);
                for kernel in selectable_kernels() {
                    let mut c = MatI32::zeros(0, 0);
                    matmul_i8w4_gemv_into(&a, &bp4, &mut c, kernel);
                    assert_eq!(c.data, want.data, "{m}x{k}x{n} {kernel:?} nr {nr}");
                }
            }
        }
    }

    #[test]
    fn w4_rows_subset_equals_explicit_gather() {
        // odd/even indices exercise both nibble parities at arbitrary
        // panel offsets; m spans the GEMV and tiled routes
        let w = rand_i4(21, 9, 1300);
        for idx in [&[0usize][..], &[3, 7][..], &[1, 4, 9, 16, 20][..], &[2, 5, 11][..]] {
            for m in [1usize, 3, 6, 9] {
                let a = rand_i8(m, idx.len(), 1400 + m as u64);
                for nr in [4usize, 8] {
                    let bp4 = PackedMatI4::pack_with(&w, nr);
                    let mut got = MatI32::zeros(0, 0);
                    matmul_i8w4_rows_subset_into(
                        &a,
                        &bp4,
                        idx,
                        &mut got,
                        ParallelGemm::sequential(),
                    );
                    let mut gathered = MatI8::zeros(idx.len(), 9);
                    for (t, &r) in idx.iter().enumerate() {
                        gathered.data[t * 9..(t + 1) * 9].copy_from_slice(w.row(r));
                    }
                    assert_eq!(
                        got.data,
                        matmul_naive(&a, &gathered).data,
                        "m {m} idx {idx:?} nr {nr}"
                    );
                }
            }
        }
    }

    #[test]
    fn w4_parallel_bit_exact_vs_sequential() {
        let a = rand_i8(37, 29, 1500);
        let w = rand_i4(29, 23, 1600);
        let bp4 = PackedMatI4::pack(&w);
        let mut seq = MatI32::zeros(0, 0);
        matmul_i8w4_packed_into(&a, &bp4, &mut seq, ParallelGemm::sequential());
        for threads in [2usize, 3, 4, 8] {
            let cfg = ParallelGemm { threads, min_parallel_macs: 0 };
            let mut par = MatI32::zeros(0, 0);
            matmul_i8w4_packed_into(&a, &bp4, &mut par, cfg);
            assert_eq!(par.data, seq.data, "{threads} threads");
        }
    }

    #[test]
    fn w4_skinny_auto_route_matches_tile_cascade() {
        for m in 1..=4usize {
            let a = rand_i8(m, 31, 1700 + m as u64);
            let w = rand_i4(31, 17, 1800);
            let bp4 = PackedMatI4::pack(&w);
            let mut via_auto = MatI32::zeros(0, 0);
            matmul_i8w4_packed_into(&a, &bp4, &mut via_auto, ParallelGemm::sequential());
            let mut via_tiles = MatI32::zeros(0, 0);
            matmul_i8w4_packed_kernel_into(
                &a,
                &bp4,
                &mut via_tiles,
                ParallelGemm::sequential(),
                Kernel::Auto,
                4,
            );
            assert_eq!(via_auto.data, via_tiles.data, "m = {m}");
        }
    }

    #[test]
    fn pack4_counts_toward_pack_count() {
        let before = pack_count();
        let _ = PackedMatI4::pack(&rand_i4(4, 4, 2000));
        assert_eq!(pack_count(), before + 1);
    }
}
