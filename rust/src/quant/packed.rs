//! Packed, parallel INT8 GEMM engine — the hot path of the uniform-INT
//! pipeline MUXQ argues for (paper §3, eq. 7).
//!
//! Production INT-GEMM stacks (GPTQ/mistralrs-style packed-weight
//! kernels) pre-pack the weight operand ONCE into a layout the
//! microkernel can stream, then tile the output over registers. The
//! rust-native equivalent implemented here:
//!
//! * [`PackedMatI8`] — K-major column panels of width [`NR`], zero-padded
//!   to the panel width, built by a one-time `pack()` (at model load for
//!   the deployment pipeline; amortized against O(M·K·N) compute when
//!   packing on the fly).
//! * A register-tiled [`MR`]×[`NR`] microkernel holding a 4×4 block of
//!   i32 accumulators, K unrolled by 4, **no zero-skip branch**: dense
//!   i8 activations are essentially never exactly zero, and a
//!   branch-per-element defeats autovectorization.
//! * [`matmul_i8_rows_subset_into`] — the MUXQ Aux GEMM reads its
//!   outlier weight rows *directly out of the full packed layout* via an
//!   index list, so the skinny second GEMM of eq. 7 needs no per-call
//!   weight gather or re-pack.
//! * [`ParallelGemm`] — row-panel parallelism over scoped threads with a
//!   sequential fallback for small shapes (thread spawn costs more than
//!   the GEMM below ~2M MACs).
//!
//! Perf numbers live in EXPERIMENTS.md §Perf; `bench_gemm` regenerates
//! them (BENCH_gemm.json, gated by rust/scripts/bench_check.sh).

use super::matrix::{MatI32, MatI8};
use std::cell::Cell;
use std::sync::OnceLock;

/// Microkernel register tile: MR rows of A × NR columns of B.
pub const MR: usize = 4;
/// Panel width — one packed panel holds NR output columns, K-major.
pub const NR: usize = 4;

thread_local! {
    static PACK_COUNT: Cell<usize> = const { Cell::new(0) };
}

/// Number of [`PackedMatI8::pack`] calls made *by this thread*. Test
/// hook: asserts weights are packed once at construction and never on
/// the per-call projection path. Thread-local so concurrently running
/// tests cannot perturb each other's counts.
pub fn pack_count() -> usize {
    PACK_COUNT.with(|c| c.get())
}

/// Weight matrix pre-packed into K-major column panels.
///
/// Layout: `ceil(cols / NR)` panels, each `rows * NR` bytes. Panel `p`
/// stores columns `p*NR .. p*NR+NR` of B; within a panel the NR column
/// values for each k are contiguous (`panel[k*NR + j]`), so the
/// microkernel streams the panel front-to-back with unit stride. The
/// last panel is zero-padded to full width — padding contributes zero to
/// every accumulator, so no column-tail branch is needed in the kernel.
#[derive(Debug, Clone)]
pub struct PackedMatI8 {
    /// K — the inner (contraction) dimension.
    pub rows: usize,
    /// N — the output dimension (logical, unpadded).
    pub cols: usize,
    data: Vec<i8>,
}

impl PackedMatI8 {
    /// One-time packing pass: O(K·N), done at weight-load time in the
    /// deployment pipeline.
    pub fn pack(b: &MatI8) -> PackedMatI8 {
        PACK_COUNT.with(|c| c.set(c.get() + 1));
        let (k, n) = (b.rows, b.cols);
        let panels = n.div_ceil(NR);
        let mut data = vec![0i8; panels * k * NR];
        for p in 0..panels {
            let j0 = p * NR;
            let jw = NR.min(n - j0);
            let dst = &mut data[p * k * NR..(p + 1) * k * NR];
            for kk in 0..k {
                dst[kk * NR..kk * NR + jw]
                    .copy_from_slice(&b.data[kk * n + j0..kk * n + j0 + jw]);
            }
        }
        PackedMatI8 { rows: k, cols: n, data }
    }

    /// Number of column panels.
    pub fn panels(&self) -> usize {
        self.cols.div_ceil(NR)
    }

    /// Actual storage bytes, *including* panel padding — what the packed
    /// layout really occupies in memory (the honest number for the
    /// memory-saving claim).
    pub fn padded_bytes(&self) -> usize {
        self.data.len()
    }

    /// Logical (unpadded) element count of the original matrix.
    pub fn logical_len(&self) -> usize {
        self.rows * self.cols
    }

    #[inline(always)]
    fn panel(&self, p: usize) -> &[i8] {
        &self.data[p * self.rows * NR..(p + 1) * self.rows * NR]
    }
}

/// Row-panel parallelism config. `threads == 1` (or a shape below
/// `min_parallel_macs`) takes the sequential path — spawning scoped
/// threads costs more than a small GEMM.
#[derive(Debug, Clone, Copy)]
pub struct ParallelGemm {
    /// Worker count. [`ParallelGemm::global`] resolves this from
    /// `MUXQ_GEMM_THREADS` or the host's available parallelism;
    /// `Default`/[`ParallelGemm::sequential`] stay at 1.
    pub threads: usize,
    /// Sequential below this many MACs (m·k·n).
    pub min_parallel_macs: usize,
}

impl Default for ParallelGemm {
    fn default() -> Self {
        ParallelGemm { threads: 1, min_parallel_macs: 1 << 21 }
    }
}

impl ParallelGemm {
    /// Explicitly sequential (reference/fallback path).
    pub fn sequential() -> ParallelGemm {
        ParallelGemm::default()
    }

    /// The process-wide config, resolved once from the environment.
    pub fn global() -> ParallelGemm {
        static GLOBAL: OnceLock<ParallelGemm> = OnceLock::new();
        *GLOBAL.get_or_init(ParallelGemm::from_env)
    }

    fn from_env() -> ParallelGemm {
        let threads = std::env::var("MUXQ_GEMM_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
            .max(1);
        ParallelGemm { threads, min_parallel_macs: 1 << 21 }
    }
}

/// C = A_i8 @ B_packed with i32 accumulation, fresh output matrix.
pub fn matmul_i8_packed(a: &MatI8, bp: &PackedMatI8) -> MatI32 {
    matmul_i8_packed_with(a, bp, ParallelGemm::global())
}

/// Same, with an explicit parallelism config (bench/test hook).
pub fn matmul_i8_packed_with(a: &MatI8, bp: &PackedMatI8, cfg: ParallelGemm) -> MatI32 {
    let mut c = MatI32::zeros(a.rows, bp.cols);
    matmul_i8_packed_into(a, bp, &mut c, cfg);
    c
}

/// C = A_i8 @ B_packed written into a reusable accumulator (resized in
/// place; every element is overwritten, so no zeroing pass is needed).
pub fn matmul_i8_packed_into(a: &MatI8, bp: &PackedMatI8, c: &mut MatI32, cfg: ParallelGemm) {
    assert_eq!(a.cols, bp.rows, "inner dims {}x{}", a.cols, bp.rows);
    let (m, n) = (a.rows, bp.cols);
    c.rows = m;
    c.cols = n;
    c.data.resize(m * n, 0);
    run_row_parallel(m, n, a.cols, cfg, &mut c.data, &|row0, row1, chunk| {
        gemm_rows(a, bp, row0, row1, chunk);
    });
}

/// Skinny GEMM against a *row subset* of the packed weights:
/// `C = A_compact @ B[idx, :]` where A_compact is `[m, r]` and `idx[t]`
/// names the B row matched to A's column `t`. This is MUXQ's Aux GEMM
/// (eq. 7): the outlier weight rows are read straight out of the full
/// packed layout — zero-copy, no per-call gather/re-pack.
pub fn matmul_i8_rows_subset_into(
    a: &MatI8,
    bp: &PackedMatI8,
    idx: &[usize],
    c: &mut MatI32,
    cfg: ParallelGemm,
) {
    assert_eq!(a.cols, idx.len(), "compact A width vs index list");
    debug_assert!(idx.iter().all(|&k| k < bp.rows));
    let (m, n) = (a.rows, bp.cols);
    c.rows = m;
    c.cols = n;
    c.data.resize(m * n, 0);
    run_row_parallel(m, n, idx.len(), cfg, &mut c.data, &|row0, row1, chunk| {
        gemm_rows_subset(a, bp, idx, row0, row1, chunk);
    });
}

/// Split output rows into near-equal chunks and run `body(row0, row1,
/// chunk)` on scoped threads; sequential when the shape is small.
fn run_row_parallel(
    m: usize,
    n: usize,
    k: usize,
    cfg: ParallelGemm,
    data: &mut [i32],
    body: &(dyn Fn(usize, usize, &mut [i32]) + Sync),
) {
    let threads = cfg.threads.min(m).max(1);
    if threads == 1 || n == 0 || m * k * n < cfg.min_parallel_macs {
        body(0, m, data);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in data.chunks_mut(rows_per * n).enumerate() {
            let row0 = t * rows_per;
            let row1 = (row0 + rows_per).min(m);
            s.spawn(move || body(row0, row1, chunk));
        }
    });
}

/// Compute output rows `[row0, row1)` into `c_rows` (len `(row1-row0)*n`).
/// Each (row-tile, panel) pair streams the FULL K dimension once, so
/// every output element is written exactly once (store, not accumulate).
fn gemm_rows(a: &MatI8, bp: &PackedMatI8, row0: usize, row1: usize, c_rows: &mut [i32]) {
    let k = a.cols;
    let n = bp.cols;
    debug_assert_eq!(c_rows.len(), (row1 - row0) * n);
    for p in 0..bp.panels() {
        let j0 = p * NR;
        let jw = NR.min(n - j0);
        let panel = &bp.panel(p)[..k * NR];
        let mut i = row0;
        while i + MR <= row1 {
            let rows = [a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3)];
            let mut acc = [[0i32; NR]; MR];
            micro_mr(k, rows, panel, &mut acc);
            for (di, accr) in acc.iter().enumerate() {
                c_rows[(i - row0 + di) * n + j0..][..jw].copy_from_slice(&accr[..jw]);
            }
            i += MR;
        }
        while i < row1 {
            let mut acc = [0i32; NR];
            micro_1(k, a.row(i), panel, &mut acc);
            c_rows[(i - row0) * n + j0..][..jw].copy_from_slice(&acc[..jw]);
            i += 1;
        }
    }
}

/// Row-subset twin of [`gemm_rows`]: the contraction walks `idx` instead
/// of `0..k`, jumping to `panel[idx[t]*NR]` for the weight values.
fn gemm_rows_subset(
    a: &MatI8,
    bp: &PackedMatI8,
    idx: &[usize],
    row0: usize,
    row1: usize,
    c_rows: &mut [i32],
) {
    let n = bp.cols;
    debug_assert_eq!(c_rows.len(), (row1 - row0) * n);
    for p in 0..bp.panels() {
        let j0 = p * NR;
        let jw = NR.min(n - j0);
        let panel = bp.panel(p);
        let mut i = row0;
        while i + MR <= row1 {
            let rows = [a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3)];
            let mut acc = [[0i32; NR]; MR];
            micro_mr_idx(idx, rows, panel, &mut acc);
            for (di, accr) in acc.iter().enumerate() {
                c_rows[(i - row0 + di) * n + j0..][..jw].copy_from_slice(&accr[..jw]);
            }
            i += MR;
        }
        while i < row1 {
            let mut acc = [0i32; NR];
            micro_1_idx(idx, a.row(i), panel, &mut acc);
            c_rows[(i - row0) * n + j0..][..jw].copy_from_slice(&acc[..jw]);
            i += 1;
        }
    }
}

/// One contraction step of the MR×NR tile at position `kk`.
#[inline(always)]
fn micro_step(kk: usize, a: [&[i8]; MR], panel: &[i8], acc: &mut [[i32; NR]; MR]) {
    let b = &panel[kk * NR..kk * NR + NR];
    for i in 0..MR {
        let av = a[i][kk] as i32;
        for j in 0..NR {
            acc[i][j] += av * b[j] as i32;
        }
    }
}

/// MR×NR register-tiled microkernel: 16 i32 accumulators live across the
/// whole K loop, K unrolled by 4, branch-free dense MACs.
#[inline(always)]
fn micro_mr(k: usize, a: [&[i8]; MR], panel: &[i8], acc: &mut [[i32; NR]; MR]) {
    let mut kk = 0;
    while kk + 4 <= k {
        micro_step(kk, a, panel, acc);
        micro_step(kk + 1, a, panel, acc);
        micro_step(kk + 2, a, panel, acc);
        micro_step(kk + 3, a, panel, acc);
        kk += 4;
    }
    while kk < k {
        micro_step(kk, a, panel, acc);
        kk += 1;
    }
}

/// 1×NR tail microkernel for the M remainder rows.
#[inline(always)]
fn micro_1(k: usize, a: &[i8], panel: &[i8], acc: &mut [i32; NR]) {
    for kk in 0..k {
        let av = a[kk] as i32;
        let b = &panel[kk * NR..kk * NR + NR];
        for j in 0..NR {
            acc[j] += av * b[j] as i32;
        }
    }
}

/// MR×NR microkernel over an index-mapped contraction (Aux GEMM).
#[inline(always)]
fn micro_mr_idx(idx: &[usize], a: [&[i8]; MR], panel: &[i8], acc: &mut [[i32; NR]; MR]) {
    for (t, &krow) in idx.iter().enumerate() {
        let b = &panel[krow * NR..krow * NR + NR];
        for i in 0..MR {
            let av = a[i][t] as i32;
            for j in 0..NR {
                acc[i][j] += av * b[j] as i32;
            }
        }
    }
}

/// 1×NR index-mapped tail microkernel.
#[inline(always)]
fn micro_1_idx(idx: &[usize], a: &[i8], panel: &[i8], acc: &mut [i32; NR]) {
    for (t, &krow) in idx.iter().enumerate() {
        let av = a[t] as i32;
        let b = &panel[krow * NR..krow * NR + NR];
        for j in 0..NR {
            acc[j] += av * b[j] as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;

    fn rand_i8(rows: usize, cols: usize, seed: u64) -> MatI8 {
        let mut rng = SplitMix64::new(seed);
        let mut m = MatI8::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = (rng.next_below(255) as i32 - 127) as i8;
        }
        m
    }

    fn matmul_naive(a: &MatI8, b: &MatI8) -> MatI32 {
        let mut c = MatI32::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0i32;
                for k in 0..a.cols {
                    acc += a.row(i)[k] as i32 * b.data[k * b.cols + j] as i32;
                }
                c.data[i * b.cols + j] = acc;
            }
        }
        c
    }

    #[test]
    fn pack_layout_golden() {
        // 2x3 (one padded panel): [b00 b01 b02 0 | b10 b11 b12 0]
        let mut b = MatI8::zeros(2, 3);
        b.data.copy_from_slice(&[1, 2, 3, 4, 5, 6]);
        let p = PackedMatI8::pack(&b);
        assert_eq!(p.panels(), 1);
        assert_eq!(p.padded_bytes(), 2 * NR);
        assert_eq!(p.logical_len(), 6);
        assert_eq!(p.panel(0), &[1, 2, 3, 0, 4, 5, 6, 0]);
    }

    #[test]
    fn packed_matches_naive_ragged_shapes() {
        // 1x1x1, primes, and dims straddling MR/NR panel boundaries
        for &(m, k, n) in &[
            (1, 1, 1),
            (2, 3, 5),
            (7, 11, 13),
            (4, 4, 4),
            (5, 4, 9),
            (6, 65, 7),
            (33, 17, 12),
            (8, 8, 3),
        ] {
            let a = rand_i8(m, k, m as u64 * 31 + n as u64);
            let b = rand_i8(k, n, k as u64 * 37 + 1);
            let bp = PackedMatI8::pack(&b);
            let got = matmul_i8_packed_with(&a, &bp, ParallelGemm::sequential());
            let want = matmul_naive(&a, &b);
            assert_eq!(got.data, want.data, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn parallel_bit_exact_vs_sequential() {
        let a = rand_i8(37, 29, 1);
        let b = rand_i8(29, 23, 2);
        let bp = PackedMatI8::pack(&b);
        let seq = matmul_i8_packed_with(&a, &bp, ParallelGemm::sequential());
        for threads in [2usize, 3, 4, 8] {
            let cfg = ParallelGemm { threads, min_parallel_macs: 0 };
            let par = matmul_i8_packed_with(&a, &bp, cfg);
            assert_eq!(par.data, seq.data, "{threads} threads");
            assert_eq!((par.rows, par.cols), (37, 23));
        }
    }

    #[test]
    fn rows_subset_equals_explicit_gather() {
        let a = rand_i8(9, 3, 3); // compact [m, r] with r = 3
        let b = rand_i8(15, 10, 4);
        let idx = [2usize, 7, 14];
        let bp = PackedMatI8::pack(&b);
        let mut got = MatI32::zeros(0, 0);
        matmul_i8_rows_subset_into(&a, &bp, &idx, &mut got, ParallelGemm::sequential());
        // reference: gather the rows, then dense naive
        let mut gathered = MatI8::zeros(3, 10);
        for (t, &r) in idx.iter().enumerate() {
            gathered.data[t * 10..(t + 1) * 10].copy_from_slice(b.row(r));
        }
        let want = matmul_naive(&a, &gathered);
        assert_eq!(got.data, want.data);
        // and in parallel
        let mut par = MatI32::zeros(0, 0);
        let cfg = ParallelGemm { threads: 3, min_parallel_macs: 0 };
        matmul_i8_rows_subset_into(&a, &bp, &idx, &mut par, cfg);
        assert_eq!(par.data, want.data);
    }

    #[test]
    fn into_reuses_and_resizes_scratch() {
        let mut c = MatI32::zeros(64, 64); // oversized scratch
        let a = rand_i8(3, 5, 5);
        let b = rand_i8(5, 6, 6);
        let bp = PackedMatI8::pack(&b);
        matmul_i8_packed_into(&a, &bp, &mut c, ParallelGemm::sequential());
        assert_eq!((c.rows, c.cols, c.data.len()), (3, 6, 18));
        assert_eq!(c.data, matmul_naive(&a, &b).data);
    }

    #[test]
    fn pack_count_is_per_thread() {
        let before = pack_count();
        let _ = PackedMatI8::pack(&rand_i8(2, 2, 7));
        let _ = PackedMatI8::pack(&rand_i8(2, 2, 8));
        assert_eq!(pack_count(), before + 2);
    }
}
