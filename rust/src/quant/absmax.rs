//! Symmetric abs-max quantization — the rust twin of
//! `python/compile/kernels/ref.py` (cross-validated against
//! `artifacts/goldens/quant.bin` in `tests/golden_quant.rs`).

use super::matrix::{rint, MatF32, MatI8};

/// Matches ref.py EPS: scales are floored so all-zero slices stay finite.
pub const EPS: f32 = 1e-8;

/// Quantization granularity (paper Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Granularity {
    /// one scale for the whole tensor
    PerTensor,
    /// one scale per row (per-token for activations)
    PerRow,
    /// one scale per column (per-output-channel for weights)
    PerCol,
}

impl Granularity {
    /// Parse the manifest/CLI spelling.
    pub fn parse(s: &str) -> Option<(Granularity, Granularity)> {
        // returns (activation, weight) granularities for a variant tag
        match s {
            "per-tensor" | "pt" => Some((Granularity::PerTensor, Granularity::PerTensor)),
            "per-vector" | "pv" => Some((Granularity::PerRow, Granularity::PerCol)),
            _ => None,
        }
    }
}

/// qmax = 2^(bits-1) - 1 (symmetric signed grid).
#[inline]
pub fn qmax_from_bits(bits: u32) -> f32 {
    (1u32 << (bits - 1)) as f32 - 1.0
}

/// Per-slice scales for a matrix at the given granularity.
#[derive(Debug, Clone)]
pub enum Scales {
    Tensor(f32),
    Rows(Vec<f32>),
    Cols(Vec<f32>),
}

impl Scales {
    pub fn compute(x: &MatF32, qmax: f32, gran: Granularity) -> Scales {
        let f = |m: f32| m.max(EPS) / qmax;
        match gran {
            Granularity::PerTensor => Scales::Tensor(f(x.absmax())),
            Granularity::PerRow => Scales::Rows(x.absmax_rows().into_iter().map(f).collect()),
            Granularity::PerCol => Scales::Cols(x.absmax_cols().into_iter().map(f).collect()),
        }
    }

    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        match self {
            Scales::Tensor(s) => *s,
            Scales::Rows(v) => v[r],
            Scales::Cols(v) => v[c],
        }
    }
}

/// quantize -> dequantize in place semantics (returns a new matrix).
pub fn fake_quant(x: &MatF32, scales: &Scales, qmax: f32) -> MatF32 {
    let mut out = MatF32::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        for c in 0..x.cols {
            let s = scales.at(r, c);
            let q = rint(x.at(r, c) / s).clamp(-qmax, qmax);
            *out.at_mut(r, c) = q * s;
        }
    }
    out
}

/// One-call naive fake quant (compute scales + apply).
pub fn fq_naive(x: &MatF32, qmax: f32, gran: Granularity) -> MatF32 {
    let s = Scales::compute(x, qmax, gran);
    fake_quant(x, &s, qmax)
}

/// Quantize to an i8 grid (true INT pipeline operand). qmax must be <= 127.
pub fn quantize_i8(x: &MatF32, scales: &Scales, qmax: f32) -> MatI8 {
    debug_assert!(qmax <= 127.0);
    let mut out = MatI8::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let base = r * x.cols;
        for c in 0..x.cols {
            let s = scales.at(r, c);
            let q = rint(x.data[base + c] / s).clamp(-qmax, qmax);
            out.data[base + c] = q as i8;
        }
    }
    out
}

/// Mean absolute quantization error of naive fake quant (Fig. 3 metric).
pub fn quant_error(x: &MatF32, qmax: f32, gran: Granularity) -> f32 {
    fq_naive(x, qmax, gran).mean_abs_diff(x)
}

/// Signal-to-quantization-noise ratio in dB (10 log10 P_sig/P_noise).
pub fn sqnr_db(x: &MatF32, y: &MatF32) -> f32 {
    let sig: f64 = x.data.iter().map(|v| (*v as f64).powi(2)).sum();
    let noise: f64 = x
        .data
        .iter()
        .zip(&y.data)
        .map(|(a, b)| ((*a - *b) as f64).powi(2))
        .sum();
    if noise == 0.0 {
        return f32::INFINITY;
    }
    (10.0 * (sig / noise).log10()) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, seed: u64) -> MatF32 {
        // simple deterministic pseudo-values
        let mut rng = crate::data::prng::SplitMix64::new(seed);
        let data = (0..rows * cols)
            .map(|_| (rng.next_f64() as f32 - 0.5) * 4.0)
            .collect();
        MatF32::from_vec(rows, cols, data).unwrap()
    }

    #[test]
    fn qmax_values() {
        assert_eq!(qmax_from_bits(8), 127.0);
        assert_eq!(qmax_from_bits(4), 7.0);
        assert_eq!(qmax_from_bits(2), 1.0);
    }

    #[test]
    fn fake_quant_bounded_error() {
        let x = mat(16, 16, 1);
        let y = fq_naive(&x, 127.0, Granularity::PerTensor);
        // max error is half a quantization step
        let step = x.absmax() / 127.0;
        assert!(x.max_abs_diff(&y) <= step / 2.0 + 1e-6);
    }

    #[test]
    fn per_row_beats_per_tensor_with_row_outlier() {
        let mut x = mat(8, 8, 2);
        for c in 0..8 {
            *x.at_mut(0, c) *= 50.0; // one hot row
        }
        let e_pt = quant_error(&x, 127.0, Granularity::PerTensor);
        let e_pr = quant_error(&x, 127.0, Granularity::PerRow);
        assert!(e_pr < e_pt);
    }

    #[test]
    fn error_monotone_in_bits() {
        let x = mat(32, 32, 3);
        let mut prev = f32::INFINITY;
        for bits in [4u32, 6, 8] {
            let e = quant_error(&x, qmax_from_bits(bits), Granularity::PerTensor);
            assert!(e < prev);
            prev = e;
        }
    }

    #[test]
    fn quantize_i8_in_range() {
        let x = mat(8, 8, 4);
        let s = Scales::compute(&x, 127.0, Granularity::PerTensor);
        let q = quantize_i8(&x, &s, 127.0);
        assert!(q.data.iter().all(|&v| (-127..=127).contains(&(v as i32))));
    }

    #[test]
    fn zero_matrix_safe() {
        let x = MatF32::zeros(4, 4);
        let y = fq_naive(&x, 127.0, Granularity::PerRow);
        assert!(y.data.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn sqnr_improves_with_bits() {
        let x = mat(32, 32, 5);
        let a = sqnr_db(&x, &fq_naive(&x, qmax_from_bits(4), Granularity::PerTensor));
        let b = sqnr_db(&x, &fq_naive(&x, qmax_from_bits(8), Granularity::PerTensor));
        assert!(b > a + 15.0, "expected ~24dB gain, got {a} -> {b}");
    }
}
