//! SmoothQuant (Xiao et al., 2023) difficulty migration — the technique
//! the paper names as *composable* with MUXQ (contribution #2).
//!
//! s_j = max|X_j|^alpha / max|W_j|^(1-alpha);  X' = X / s, W' = s ⊙ W.
//! Function-preserving in FP, shifts quantization difficulty from
//! activations into weights.

use super::matrix::MatF32;

pub const EPS: f32 = 1e-8;

/// Migration scales from calibration activation abs-max (per input
/// channel) and the weight matrix [K, N].
pub fn smooth_scales(act_absmax: &[f32], w: &MatF32, alpha: f32) -> Vec<f32> {
    assert_eq!(act_absmax.len(), w.rows);
    let wmax: Vec<f32> = (0..w.rows)
        .map(|r| w.row(r).iter().fold(0.0f32, |m, v| m.max(v.abs())))
        .collect();
    act_absmax
        .iter()
        .zip(&wmax)
        .map(|(a, b)| {
            let num = a.max(EPS).powf(alpha);
            let den = b.max(EPS).powf(1.0 - alpha);
            (num / den).max(EPS)
        })
        .collect()
}

/// Apply the migration: returns (X / s, s ⊙ W rows).
pub fn migrate(x: &MatF32, w: &MatF32, s: &[f32]) -> (MatF32, MatF32) {
    assert_eq!(s.len(), x.cols);
    assert_eq!(s.len(), w.rows);
    let mut xs = x.clone();
    for r in 0..x.rows {
        for (v, sc) in xs.row_mut(r).iter_mut().zip(s) {
            *v /= sc;
        }
    }
    let mut ws = w.clone();
    for (r, sc) in s.iter().enumerate() {
        for v in ws.row_mut(r) {
            *v *= sc;
        }
    }
    (xs, ws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;
    use crate::quant::gemm::matmul_f32;

    fn mat(rows: usize, cols: usize, seed: u64) -> MatF32 {
        let mut rng = SplitMix64::new(seed);
        MatF32::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
        )
        .unwrap()
    }

    #[test]
    fn function_preserving() {
        let mut x = mat(16, 24, 1);
        for r in 0..16 {
            *x.at_mut(r, 5) *= 30.0;
        }
        let w = mat(24, 8, 2);
        let s = smooth_scales(&x.absmax_cols(), &w, 0.5);
        let (xs, ws) = migrate(&x, &w, &s);
        let y0 = matmul_f32(&x, &w);
        let y1 = matmul_f32(&xs, &ws);
        assert!(y0.mean_abs_diff(&y1) < 1e-4);
    }

    #[test]
    fn reduces_activation_range() {
        let mut x = mat(16, 24, 3);
        for r in 0..16 {
            *x.at_mut(r, 2) *= 40.0;
        }
        let w = mat(24, 8, 4);
        let s = smooth_scales(&x.absmax_cols(), &w, 0.5);
        let (xs, _) = migrate(&x, &w, &s);
        assert!(xs.absmax() < x.absmax());
    }

    #[test]
    fn composes_with_muxq() {
        // smoothed activations quantize better; muxq on top handles the
        // residual outliers (the paper's composability claim)
        use crate::quant::absmax::{fq_naive, Granularity};
        use crate::quant::muxq::{fq_muxq, MuxqParams};
        let mut x = mat(32, 32, 5);
        for r in 0..32 {
            *x.at_mut(r, 7) *= 50.0;
            *x.at_mut(r, 19) *= 20.0;
        }
        let w = mat(32, 16, 6);
        let s = smooth_scales(&x.absmax_cols(), &w, 0.5);
        let (xs, _) = migrate(&x, &w, &s);
        let qmax = 31.0;
        let e_plain = fq_naive(&x, qmax, Granularity::PerTensor).mean_abs_diff(&x);
        let rel = |e: f32, m: &MatF32| e / m.absmax();
        let e_smooth_muxq =
            fq_muxq(&xs, qmax, Granularity::PerTensor, &MuxqParams::default()).mean_abs_diff(&xs);
        // compare *relative* errors since ranges differ after migration
        assert!(rel(e_smooth_muxq, &xs) < rel(e_plain, &x));
    }
}
