//! Blocked GEMM kernels: f32 reference and the i8xi8 -> i32 integer
//! pipeline (the operation MUXQ keeps *uniform* on INT hardware).
//!
//! The i8 kernel is the rust hot path for the native engine benches; it is
//! cache-blocked and accumulates in i32 exactly like an NPU MAC array
//! would. Perf notes live in EXPERIMENTS.md §Perf.

use super::absmax::{Granularity, Scales};
use super::matrix::{MatF32, MatI32, MatI8};

/// Cache block sizes for the f32 kernel (L1-friendly on typical x86).
const BM: usize = 32;
const BN: usize = 64;
const BK: usize = 64;

/// Reference f32 GEMM: C = A @ B. Blocked i-k-j loop order (row-major
/// streaming on both operands).
pub fn matmul_f32(a: &MatF32, b: &MatF32) -> MatF32 {
    assert_eq!(a.cols, b.rows, "inner dims {}x{}", a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = MatF32::zeros(m, n);
    for i0 in (0..m).step_by(BM) {
        let i1 = (i0 + BM).min(m);
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for j0 in (0..n).step_by(BN) {
                let j1 = (j0 + BN).min(n);
                for i in i0..i1 {
                    let arow = a.row(i);
                    let crow = &mut c.data[i * n..(i + 1) * n];
                    for kk in k0..k1 {
                        let av = arow[kk];
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b.data[kk * n..(kk + 1) * n];
                        for j in j0..j1 {
                            crow[j] += av * brow[j];
                        }
                    }
                }
            }
        }
    }
    c
}

/// Integer GEMM: C_i32 = A_i8 @ B_i8 with i32 accumulation.
pub fn matmul_i8(a: &MatI8, b: &MatI8) -> MatI32 {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = MatI32::zeros(m, n);
    for i0 in (0..m).step_by(BM) {
        let i1 = (i0 + BM).min(m);
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for i in i0..i1 {
                let arow = a.row(i);
                let crow = &mut c.data[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let av = arow[kk] as i32;
                    if av == 0 {
                        continue;
                    }
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * *bv as i32;
                    }
                }
            }
        }
    }
    c
}

/// Dequantize an integer GEMM result: C_f32[i,j] = acc[i,j] * sx(i) * sw(j).
pub fn dequant(acc: &MatI32, sx: &Scales, sw: &Scales) -> MatF32 {
    let mut out = MatF32::zeros(acc.rows, acc.cols);
    for r in 0..acc.rows {
        for c in 0..acc.cols {
            let s = sx.at(r, 0) * sw.at(0, c);
            *out.at_mut(r, c) = acc.data[r * acc.cols + c] as f32 * s;
        }
    }
    out
}

/// Full quantize -> int matmul -> dequant pipeline (the rust twin of
/// `quant_matmul_pallas`). Granularities: activation PerRow|PerTensor,
/// weight PerCol|PerTensor.
pub fn quant_matmul(
    x: &MatF32,
    w: &MatF32,
    qmax: f32,
    gx: Granularity,
    gw: Granularity,
) -> MatF32 {
    let sx = Scales::compute(x, qmax, gx);
    let sw = Scales::compute(w, qmax, gw);
    let xq = super::absmax::quantize_i8(x, &sx, qmax);
    let wq = super::absmax::quantize_i8(w, &sw, qmax);
    let acc = matmul_i8(&xq, &wq);
    dequant(&acc, &sx, &sw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;

    fn mat(rows: usize, cols: usize, seed: u64) -> MatF32 {
        let mut rng = SplitMix64::new(seed);
        MatF32::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
        )
        .unwrap()
    }

    fn matmul_naive(a: &MatF32, b: &MatF32) -> MatF32 {
        let mut c = MatF32::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for k in 0..a.cols {
                    acc += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = acc as f32;
            }
        }
        c
    }

    #[test]
    fn blocked_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (33, 65, 17), (64, 64, 64)] {
            let a = mat(m, k, 1);
            let b = mat(k, n, 2);
            let c = matmul_f32(&a, &b);
            let r = matmul_naive(&a, &b);
            assert!(c.max_abs_diff(&r) < 1e-4, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn i8_matmul_exact() {
        // small integer values: blocked i8 path must be exact vs f64
        let mut a8 = MatI8::zeros(5, 9);
        let mut b8 = MatI8::zeros(9, 4);
        let mut rng = SplitMix64::new(3);
        for v in a8.data.iter_mut() {
            *v = (rng.next_below(255) as i32 - 127) as i8;
        }
        for v in b8.data.iter_mut() {
            *v = (rng.next_below(255) as i32 - 127) as i8;
        }
        let c = matmul_i8(&a8, &b8);
        for i in 0..5 {
            for j in 0..4 {
                let want: i32 = (0..9).map(|k| a8.row(i)[k] as i32 * b8.data[k * 4 + j] as i32).sum();
                assert_eq!(c.data[i * 4 + j], want);
            }
        }
    }

    #[test]
    fn quant_matmul_close_to_fp() {
        let x = mat(16, 32, 4);
        let w = mat(32, 8, 5);
        let exact = matmul_f32(&x, &w);
        let q = quant_matmul(&x, &w, 127.0, Granularity::PerRow, Granularity::PerCol);
        // int8 per-vector error on unit-scale data is small
        assert!(q.mean_abs_diff(&exact) < 0.05, "mae {}", q.mean_abs_diff(&exact));
    }

    #[test]
    fn quant_matmul_error_shrinks_with_bits() {
        let x = mat(16, 32, 6);
        let w = mat(32, 8, 7);
        let exact = matmul_f32(&x, &w);
        let e4 = quant_matmul(&x, &w, 7.0, Granularity::PerTensor, Granularity::PerTensor)
            .mean_abs_diff(&exact);
        let e8 = quant_matmul(&x, &w, 127.0, Granularity::PerTensor, Granularity::PerTensor)
            .mean_abs_diff(&exact);
        assert!(e8 < e4);
    }
}
