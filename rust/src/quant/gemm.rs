//! Blocked GEMM kernels: f32 reference and the i8xi8 -> i32 integer
//! pipeline (the operation MUXQ keeps *uniform* on INT hardware).
//!
//! The i8 hot path lives in [`super::packed`] (packed weight panels +
//! register-tiled microkernel, row-panel parallel); [`matmul_i8`] routes
//! there for any shape big enough to amortize the O(K·N) pack, keeping a
//! cache-blocked dense fallback for tiny operands. The f32 kernel is the
//! accuracy reference and parallelizes over row panels behind the same
//! [`super::packed::ParallelGemm`] config. Perf notes live in
//! EXPERIMENTS.md §Perf.

use super::absmax::{Granularity, Scales};
use super::matrix::{MatF32, MatI32, MatI8};
use super::packed::{self, PackedMatI8, ParallelGemm};

/// Cache block sizes for the blocked kernels (L1-friendly on typical x86).
pub(crate) const BM: usize = 32;
pub(crate) const BN: usize = 64;
pub(crate) const BK: usize = 64;

/// [`matmul_i8`] packs B on the fly and takes the packed engine only
/// when BOTH hold: total work is above this many MACs (m·k·n), and m is
/// at least [`PACK_ON_THE_FLY_MIN_M`]. The O(K·N) pack is amortized m
/// times, so skinny (small-m) GEMMs would pay ~2x the memory traffic of
/// the blocked fallback for no compute win.
pub(crate) const PACK_ON_THE_FLY_MACS: usize = 1 << 17;
pub(crate) const PACK_ON_THE_FLY_MIN_M: usize = 16;

/// Reference f32 GEMM: C = A @ B. Blocked i-k-j loop order (row-major
/// streaming on both operands), row-panel parallel for large shapes.
pub fn matmul_f32(a: &MatF32, b: &MatF32) -> MatF32 {
    assert_eq!(a.cols, b.rows, "inner dims {}x{}", a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = MatF32::zeros(m, n);
    let cfg = ParallelGemm::global();
    let threads = cfg.threads.min(m).max(1);
    if threads == 1 || n == 0 || m * k * n < cfg.min_parallel_macs {
        matmul_f32_rows(a, b, 0, m, &mut c.data);
        return c;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in c.data.chunks_mut(rows_per * n).enumerate() {
            let row0 = t * rows_per;
            let row1 = (row0 + rows_per).min(m);
            s.spawn(move || matmul_f32_rows(a, b, row0, row1, chunk));
        }
    });
    c
}

/// Blocked f32 kernel over output rows `[row0, row1)`. Keeps the
/// zero-skip branch: f32 activations (embeddings, padded batches) carry
/// real sparsity, unlike the dense i8 grid.
fn matmul_f32_rows(a: &MatF32, b: &MatF32, row0: usize, row1: usize, c_rows: &mut [f32]) {
    let (k, n) = (a.cols, b.cols);
    debug_assert_eq!(c_rows.len(), (row1 - row0) * n);
    for i0 in (row0..row1).step_by(BM) {
        let i1 = (i0 + BM).min(row1);
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for j0 in (0..n).step_by(BN) {
                let j1 = (j0 + BN).min(n);
                for i in i0..i1 {
                    let arow = a.row(i);
                    let crow = &mut c_rows[(i - row0) * n..(i - row0 + 1) * n];
                    for kk in k0..k1 {
                        let av = arow[kk];
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b.data[kk * n..(kk + 1) * n];
                        for j in j0..j1 {
                            crow[j] += av * brow[j];
                        }
                    }
                }
            }
        }
    }
}

/// Integer GEMM: C_i32 = A_i8 @ B_i8 with i32 accumulation. Large shapes
/// pack B on the fly and run the packed parallel engine; tiny shapes use
/// the dense blocked fallback below.
pub fn matmul_i8(a: &MatI8, b: &MatI8) -> MatI32 {
    assert_eq!(a.cols, b.rows);
    if a.rows >= PACK_ON_THE_FLY_MIN_M && a.rows * a.cols * b.cols >= PACK_ON_THE_FLY_MACS {
        let bp = PackedMatI8::pack(b);
        return packed::matmul_i8_packed(a, &bp);
    }
    matmul_i8_blocked(a, b)
}

/// Dense cache-blocked fallback kernel (small shapes; also the
/// cross-check reference for the packed engine). The inner loop is
/// branch-free: i8 activations are essentially never exactly zero, and a
/// zero-skip branch per element defeats vectorization.
pub fn matmul_i8_blocked(a: &MatI8, b: &MatI8) -> MatI32 {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = MatI32::zeros(m, n);
    for i0 in (0..m).step_by(BM) {
        let i1 = (i0 + BM).min(m);
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for i in i0..i1 {
                let arow = a.row(i);
                let crow = &mut c.data[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let av = arow[kk] as i32;
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * *bv as i32;
                    }
                }
            }
        }
    }
    c
}

/// Gathered-rows f32 accumulation: `y[i][j] += Σ_t x[i][idx[t]] · w[idx[t]][j]`
/// — the FP outlier leg of deployed LLM.int8() (`quant::linear::LlmInt8Linear`),
/// where `idx` names the outlier channels and `w` is the operator's
/// resident FP copy. Blocked over the index list (four gathered weight
/// rows per step, so the j-loop carries four independent FMAs and
/// vectorizes) instead of the one-row-at-a-time scalar loop it replaces;
/// `y` is `m·n` and accumulated in place on top of the INT leg.
///
/// Each output row's accumulation order depends only on `idx`, never on
/// the batch size — the row path and a coalesced batch stay equal, the
/// seam the decode oracles stand on.
pub fn matmul_f32_rows_gathered_acc(x: &MatF32, idx: &[usize], w: &MatF32, y: &mut [f32]) {
    let n = w.cols;
    debug_assert_eq!(y.len(), x.rows * n);
    debug_assert!(idx.iter().all(|&c| c < w.rows && c < x.cols));
    for i in 0..x.rows {
        let xr = x.row(i);
        let yrow = &mut y[i * n..(i + 1) * n];
        let mut t = 0;
        while t + 4 <= idx.len() {
            let (c0, c1, c2, c3) = (idx[t], idx[t + 1], idx[t + 2], idx[t + 3]);
            let (x0, x1, x2, x3) = (xr[c0], xr[c1], xr[c2], xr[c3]);
            let (w0, w1, w2, w3) = (w.row(c0), w.row(c1), w.row(c2), w.row(c3));
            for j in 0..n {
                yrow[j] += x0 * w0[j] + x1 * w1[j] + x2 * w2[j] + x3 * w3[j];
            }
            t += 4;
        }
        while t < idx.len() {
            let c = idx[t];
            let xv = xr[c];
            for (yv, wv) in yrow.iter_mut().zip(w.row(c)) {
                *yv += xv * wv;
            }
            t += 1;
        }
    }
}

/// Dequantize an integer GEMM result: C_f32[i,j] = acc[i,j] * sx(i) * sw(j).
pub fn dequant(acc: &MatI32, sx: &Scales, sw: &Scales) -> MatF32 {
    let mut out = MatF32::zeros(acc.rows, acc.cols);
    for r in 0..acc.rows {
        for c in 0..acc.cols {
            let s = sx.at(r, 0) * sw.at(0, c);
            *out.at_mut(r, c) = acc.data[r * acc.cols + c] as f32 * s;
        }
    }
    out
}

/// Full quantize -> int matmul -> dequant pipeline (the rust twin of
/// `quant_matmul_pallas`). Granularities: activation PerRow|PerTensor,
/// weight PerCol|PerTensor.
pub fn quant_matmul(
    x: &MatF32,
    w: &MatF32,
    qmax: f32,
    gx: Granularity,
    gw: Granularity,
) -> MatF32 {
    let sx = Scales::compute(x, qmax, gx);
    let sw = Scales::compute(w, qmax, gw);
    let xq = super::absmax::quantize_i8(x, &sx, qmax);
    let wq = super::absmax::quantize_i8(w, &sw, qmax);
    let acc = matmul_i8(&xq, &wq);
    dequant(&acc, &sx, &sw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;

    fn mat(rows: usize, cols: usize, seed: u64) -> MatF32 {
        let mut rng = SplitMix64::new(seed);
        MatF32::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
        )
        .unwrap()
    }

    fn matmul_naive(a: &MatF32, b: &MatF32) -> MatF32 {
        let mut c = MatF32::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for k in 0..a.cols {
                    acc += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = acc as f32;
            }
        }
        c
    }

    #[test]
    fn blocked_matches_naive() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (33, 65, 17), (64, 64, 64)] {
            let a = mat(m, k, 1);
            let b = mat(k, n, 2);
            let c = matmul_f32(&a, &b);
            let r = matmul_naive(&a, &b);
            assert!(c.max_abs_diff(&r) < 1e-4, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn i8_matmul_exact() {
        // small integer values: blocked i8 path must be exact vs f64
        let mut a8 = MatI8::zeros(5, 9);
        let mut b8 = MatI8::zeros(9, 4);
        let mut rng = SplitMix64::new(3);
        for v in a8.data.iter_mut() {
            *v = (rng.next_below(255) as i32 - 127) as i8;
        }
        for v in b8.data.iter_mut() {
            *v = (rng.next_below(255) as i32 - 127) as i8;
        }
        let c = matmul_i8(&a8, &b8);
        for i in 0..5 {
            for j in 0..4 {
                let want: i32 =
                    (0..9).map(|k| a8.row(i)[k] as i32 * b8.data[k * 4 + j] as i32).sum();
                assert_eq!(c.data[i * 4 + j], want);
            }
        }
    }

    #[test]
    fn routed_packed_path_matches_blocked() {
        // big enough to take the pack-on-the-fly route; cross-check
        // against the dense blocked fallback
        let mut rng = SplitMix64::new(9);
        let mut a8 = MatI8::zeros(64, 80);
        let mut b8 = MatI8::zeros(80, 48);
        for v in a8.data.iter_mut().chain(b8.data.iter_mut()) {
            *v = (rng.next_below(255) as i32 - 127) as i8;
        }
        assert!(64 >= super::PACK_ON_THE_FLY_MIN_M);
        assert!(64 * 80 * 48 >= super::PACK_ON_THE_FLY_MACS);
        let routed = matmul_i8(&a8, &b8);
        let blocked = matmul_i8_blocked(&a8, &b8);
        assert_eq!(routed.data, blocked.data);
    }

    #[test]
    fn gathered_rows_acc_exact_on_integer_valued_data() {
        // small-integer f32 values make every partial sum exact, so the
        // blocked (4-rows-per-step) accumulation must equal the naive
        // gather bit for bit regardless of summation order — across
        // index lists hitting every tail length (0..4)
        let mut rng = SplitMix64::new(11);
        let x = MatF32::from_vec(
            3,
            12,
            (0..36).map(|_| (rng.next_below(17) as f32) - 8.0).collect(),
        )
        .unwrap();
        let w = MatF32::from_vec(
            12,
            7,
            (0..84).map(|_| (rng.next_below(17) as f32) - 8.0).collect(),
        )
        .unwrap();
        for idx in [
            &[][..],
            &[5][..],
            &[0, 11][..],
            &[2, 4, 6][..],
            &[1, 3, 5, 7][..],
            &[0, 2, 4, 6, 8, 10, 11][..],
        ] {
            let mut y = vec![1.0f32; 3 * 7]; // nonzero: the leg ACCUMULATES
            matmul_f32_rows_gathered_acc(&x, idx, &w, &mut y);
            for i in 0..3 {
                for j in 0..7 {
                    let want: f32 =
                        1.0 + idx.iter().map(|&c| x.at(i, c) * w.at(c, j)).sum::<f32>();
                    assert_eq!(y[i * 7 + j], want, "i {i} j {j} idx {idx:?}");
                }
            }
        }
    }

    #[test]
    fn gathered_rows_acc_row_order_is_batch_invariant() {
        // per-row results must not depend on how many rows share the
        // call — the llm.int8() batch path and the decode row path run
        // the same kernel and must agree bit for bit
        let x = mat(5, 16, 21);
        let w = mat(16, 9, 22);
        let idx = [3usize, 7, 9, 12, 15];
        let mut batch = vec![0.0f32; 5 * 9];
        matmul_f32_rows_gathered_acc(&x, &idx, &w, &mut batch);
        for r in 0..5 {
            let row = MatF32::from_vec(1, 16, x.row(r).to_vec()).unwrap();
            let mut solo = vec![0.0f32; 9];
            matmul_f32_rows_gathered_acc(&row, &idx, &w, &mut solo);
            assert_eq!(&batch[r * 9..(r + 1) * 9], solo.as_slice(), "row {r}");
        }
    }

    #[test]
    fn quant_matmul_close_to_fp() {
        let x = mat(16, 32, 4);
        let w = mat(32, 8, 5);
        let exact = matmul_f32(&x, &w);
        let q = quant_matmul(&x, &w, 127.0, Granularity::PerRow, Granularity::PerCol);
        // int8 per-vector error on unit-scale data is small
        assert!(q.mean_abs_diff(&exact) < 0.05, "mae {}", q.mean_abs_diff(&exact));
    }

    #[test]
    fn quant_matmul_error_shrinks_with_bits() {
        let x = mat(16, 32, 6);
        let w = mat(32, 8, 7);
        let exact = matmul_f32(&x, &w);
        let e4 = quant_matmul(&x, &w, 7.0, Granularity::PerTensor, Granularity::PerTensor)
            .mean_abs_diff(&exact);
        let e8 = quant_matmul(&x, &w, 127.0, Granularity::PerTensor, Granularity::PerTensor)
            .mean_abs_diff(&exact);
        assert!(e8 < e4);
    }
}
