//! Method naming + the fake-quant evaluation spec.
//!
//! [`Method`] is the paper's column axis (Table 1); [`QuantSpec`] carries
//! the fake-quantization evaluation parameters (the paper's §4.3
//! pipeline). Projection DISPATCH no longer lives here: the one pluggable
//! route from a (method, bits, granularity) point to kernels is
//! [`super::linear::EngineSpec`] → [`super::linear::QuantLinear`] — the
//! `QuantSpec::matmul` match this module used to own is
//! `EngineSpec::matmul` now, and `QuantSpec::engine()` is the bridge the
//! model's fake-quant forward path crosses.

use super::absmax::{fq_naive, Granularity};
use super::linear::EngineSpec;
use super::llmint8::fq_llmint8_act;
use super::matrix::MatF32;
use super::muxq::{fq_muxq, MuxqParams};
use anyhow::{bail, Result};

/// Quantization method (Table 1 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Fp16,
    Naive,
    Muxq,
    LlmInt8,
    /// ResQ-style W4 + rank-r FP residual (arXiv:2412.14363): the weight
    /// body is nibble-packed INT4, accuracy is recovered by a low-rank
    /// FP correction on the rows where the quantization error
    /// concentrates. Activations quantize exactly like Naive (plain
    /// per-row INT8) — the residual is a *weight*-side leg.
    Resq,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "fp16" => Method::Fp16,
            "naive" => Method::Naive,
            "muxq" => Method::Muxq,
            "llmint8" | "llm.int8" | "llm.int8()" => Method::LlmInt8,
            "resq" => Method::Resq,
            _ => bail!("unknown method {s:?}"),
        })
    }

    /// Human-facing name (tables, reports).
    pub fn name(&self) -> &'static str {
        match self {
            Method::Fp16 => "fp16",
            Method::Naive => "naive",
            Method::Muxq => "muxq",
            Method::LlmInt8 => "llm.int8()",
            Method::Resq => "resq",
        }
    }

    /// The spelling used inside variant tags and the build manifest
    /// (`python/compile/config.py` uses the same strings) — parseable by
    /// [`Method::parse`], unlike the display name `"llm.int8()"`.
    pub fn tag_name(&self) -> &'static str {
        match self {
            Method::Fp16 => "fp16",
            Method::Naive => "naive",
            Method::Muxq => "muxq",
            Method::LlmInt8 => "llmint8",
            Method::Resq => "resq",
        }
    }
}

/// A full fake-quantization specification (method + granularity + bits +
/// MUXQ hyper-parameters) — the paper's evaluation pipeline. For the
/// deployed (true-INT, pack-once) pipeline use
/// [`EngineSpec`](super::linear::EngineSpec) directly.
#[derive(Debug, Clone, Copy)]
pub struct QuantSpec {
    pub method: Method,
    pub act_gran: Granularity,
    pub w_gran: Granularity,
    pub ia_bits: u32,
    pub w_bits: u32,
    pub muxq: MuxqParams,
}

impl QuantSpec {
    pub fn new(method: Method, granularity: &str, ia_bits: u32, w_bits: u32) -> Result<Self> {
        let Some((act_gran, w_gran)) = Granularity::parse(granularity) else {
            bail!("unknown granularity {granularity:?}");
        };
        Ok(QuantSpec { method, act_gran, w_gran, ia_bits, w_bits, muxq: MuxqParams::default() })
    }

    pub fn ia_qmax(&self) -> f32 {
        super::absmax::qmax_from_bits(self.ia_bits)
    }

    pub fn w_qmax(&self) -> f32 {
        super::absmax::qmax_from_bits(self.w_bits)
    }

    /// The deployable engine spec at this evaluation point — the bridge
    /// from the fake-quant eval world into the one projection trait
    /// (`Gpt2Model::forward`'s quantized path projects through this).
    pub fn engine(&self) -> EngineSpec {
        EngineSpec::new(self.method)
            .with_granularity(self.act_gran, self.w_gran)
            .with_bits(self.ia_bits, self.w_bits)
            .with_muxq(self.muxq)
    }

    /// Fake-quantize activations (paper's evaluation pipeline).
    pub fn fq_act(&self, x: &MatF32) -> MatF32 {
        match self.method {
            Method::Fp16 => x.clone(),
            Method::Naive => fq_naive(x, self.ia_qmax(), self.act_gran),
            Method::Muxq => fq_muxq(x, self.ia_qmax(), self.act_gran, &self.muxq),
            Method::LlmInt8 => fq_llmint8_act(x, self.ia_qmax(), self.act_gran, self.muxq.theta),
            // ResQ activations are plain INT8 — the method's novelty is
            // entirely on the weight side (W4 body + FP residual)
            Method::Resq => fq_naive(x, self.ia_qmax(), self.act_gran),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;
    use crate::quant::gemm::matmul_f32;

    fn outlier_mat(rows: usize, cols: usize, seed: u64) -> MatF32 {
        let mut rng = SplitMix64::new(seed);
        let mut m = MatF32::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
        )
        .unwrap();
        for r in 0..rows {
            *m.at_mut(r, 3) *= 25.0;
        }
        m
    }

    #[test]
    fn parse_methods() {
        assert_eq!(Method::parse("muxq").unwrap(), Method::Muxq);
        assert_eq!(Method::parse("llm.int8()").unwrap(), Method::LlmInt8);
        assert_eq!(Method::parse("llmint8").unwrap(), Method::LlmInt8);
        assert!(Method::parse("nope").is_err());
        assert_eq!(Method::parse("resq").unwrap(), Method::Resq);
        // the tag spelling always round-trips through parse
        for m in [Method::Fp16, Method::Naive, Method::Muxq, Method::LlmInt8, Method::Resq] {
            assert_eq!(Method::parse(m.tag_name()).unwrap(), m);
        }
    }

    #[test]
    fn spec_qmax() {
        let s = QuantSpec::new(Method::Naive, "per-tensor", 8, 4).unwrap();
        assert_eq!(s.ia_qmax(), 127.0);
        assert_eq!(s.w_qmax(), 7.0);
    }

    #[test]
    fn table1_error_ordering_all_methods() {
        let x = outlier_mat(64, 64, 1);
        let mk = |m| QuantSpec::new(m, "per-tensor", 6, 8).unwrap();
        let e = |m: Method| mk(m).fq_act(&x).mean_abs_diff(&x);
        assert_eq!(e(Method::Fp16), 0.0);
        assert!(e(Method::LlmInt8) <= e(Method::Muxq));
        assert!(e(Method::Muxq) < e(Method::Naive));
    }

    #[test]
    fn engine_bridge_carries_the_eval_point() {
        let s = QuantSpec::new(Method::Muxq, "per-vector", 6, 8).unwrap();
        let e = s.engine();
        assert_eq!(e.method, Method::Muxq);
        assert_eq!((e.ia_bits, e.w_bits), (6, 8));
        assert_eq!(e.act_gran, Granularity::PerRow);
        assert_eq!(e.w_gran, Granularity::PerCol);
        assert_eq!(e.tag(), "muxq-pv");
    }

    #[test]
    fn matmul_dispatch_all_through_engine() {
        // the one dispatch: every method's projection runs through the
        // QuantLinear trait and lands near FP at 8 bits
        let x = outlier_mat(16, 32, 2);
        let mut rng = SplitMix64::new(3);
        let w = MatF32::from_vec(
            32,
            8,
            (0..32 * 8).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
        )
        .unwrap();
        let exact = matmul_f32(&x, &w);
        for method in
            [Method::Fp16, Method::Naive, Method::Muxq, Method::LlmInt8, Method::Resq]
        {
            let y = QuantSpec::new(method, "per-vector", 8, 8).unwrap().engine().matmul(&x, &w);
            assert_eq!((y.rows, y.cols), (16, 8));
            assert!(y.mean_abs_diff(&exact) < 0.2, "{method:?}");
        }
    }
}
