//! Unified method dispatch: one enum covering every scheme in the paper's
//! evaluation, used by examples, benches and the coordinator's variant
//! registry.

use super::absmax::{fq_naive, Granularity};
use super::gemm::{matmul_f32, quant_matmul};
use super::llmint8::{fq_llmint8_act, llmint8_matmul};
use super::matrix::MatF32;
use super::muxq::{fq_muxq, muxq_matmul_int, MuxqParams};
use anyhow::{bail, Result};

/// Quantization method (Table 1 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Fp16,
    Naive,
    Muxq,
    LlmInt8,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "fp16" => Method::Fp16,
            "naive" => Method::Naive,
            "muxq" => Method::Muxq,
            "llmint8" | "llm.int8" | "llm.int8()" => Method::LlmInt8,
            _ => bail!("unknown method {s:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Fp16 => "fp16",
            Method::Naive => "naive",
            Method::Muxq => "muxq",
            Method::LlmInt8 => "llm.int8()",
        }
    }
}

/// A full quantization specification (method + granularity + bits + MUXQ
/// hyper-parameters).
#[derive(Debug, Clone, Copy)]
pub struct QuantSpec {
    pub method: Method,
    pub act_gran: Granularity,
    pub w_gran: Granularity,
    pub ia_bits: u32,
    pub w_bits: u32,
    pub muxq: MuxqParams,
}

impl QuantSpec {
    pub fn new(method: Method, granularity: &str, ia_bits: u32, w_bits: u32) -> Result<Self> {
        let Some((act_gran, w_gran)) = Granularity::parse(granularity) else {
            bail!("unknown granularity {granularity:?}");
        };
        Ok(QuantSpec { method, act_gran, w_gran, ia_bits, w_bits, muxq: MuxqParams::default() })
    }

    pub fn ia_qmax(&self) -> f32 {
        super::absmax::qmax_from_bits(self.ia_bits)
    }

    pub fn w_qmax(&self) -> f32 {
        super::absmax::qmax_from_bits(self.w_bits)
    }

    /// Fake-quantize activations (paper's evaluation pipeline).
    pub fn fq_act(&self, x: &MatF32) -> MatF32 {
        match self.method {
            Method::Fp16 => x.clone(),
            Method::Naive => fq_naive(x, self.ia_qmax(), self.act_gran),
            Method::Muxq => fq_muxq(x, self.ia_qmax(), self.act_gran, &self.muxq),
            Method::LlmInt8 => fq_llmint8_act(x, self.ia_qmax(), self.act_gran, self.muxq.theta),
        }
    }

    /// Quantized matmul on the *true INT* path where the method allows it
    /// (the paper's deployment story), FP/mixed elsewhere.
    pub fn matmul(&self, x: &MatF32, w: &MatF32) -> MatF32 {
        match self.method {
            Method::Fp16 => matmul_f32(x, w),
            Method::Naive => quant_matmul(x, w, self.ia_qmax(), self.act_gran, self.w_gran),
            Method::Muxq => {
                muxq_matmul_int(x, w, self.ia_qmax(), self.act_gran, self.w_gran, &self.muxq)
            }
            Method::LlmInt8 => llmint8_matmul(
                x,
                w,
                self.ia_qmax(),
                self.act_gran,
                self.w_gran,
                self.muxq.theta,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::prng::SplitMix64;

    fn outlier_mat(rows: usize, cols: usize, seed: u64) -> MatF32 {
        let mut rng = SplitMix64::new(seed);
        let mut m = MatF32::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
        )
        .unwrap();
        for r in 0..rows {
            *m.at_mut(r, 3) *= 25.0;
        }
        m
    }

    #[test]
    fn parse_methods() {
        assert_eq!(Method::parse("muxq").unwrap(), Method::Muxq);
        assert_eq!(Method::parse("llm.int8()").unwrap(), Method::LlmInt8);
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn spec_qmax() {
        let s = QuantSpec::new(Method::Naive, "per-tensor", 8, 4).unwrap();
        assert_eq!(s.ia_qmax(), 127.0);
        assert_eq!(s.w_qmax(), 7.0);
    }

    #[test]
    fn table1_error_ordering_all_methods() {
        let x = outlier_mat(64, 64, 1);
        let mk = |m| QuantSpec::new(m, "per-tensor", 6, 8).unwrap();
        let e = |m: Method| mk(m).fq_act(&x).mean_abs_diff(&x);
        assert_eq!(e(Method::Fp16), 0.0);
        assert!(e(Method::LlmInt8) <= e(Method::Muxq));
        assert!(e(Method::Muxq) < e(Method::Naive));
    }

    #[test]
    fn matmul_dispatch_all() {
        let x = outlier_mat(16, 32, 2);
        let mut rng = SplitMix64::new(3);
        let w = MatF32::from_vec(
            32,
            8,
            (0..32 * 8).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
        )
        .unwrap();
        let exact = matmul_f32(&x, &w);
        for method in [Method::Fp16, Method::Naive, Method::Muxq, Method::LlmInt8] {
            let y = QuantSpec::new(method, "per-vector", 8, 8).unwrap().matmul(&x, &w);
            assert_eq!((y.rows, y.cols), (16, 8));
            assert!(y.mean_abs_diff(&exact) < 0.2, "{method:?}");
        }
    }
}
