//! Draft-and-verify speculative decoding over the session oracle
//! (DESIGN.md §5b). A cheap DRAFT session proposes `k` tokens; the
//! TARGET scores all `k + 1` positions in ONE skinny-M batched forward
//! ([`SessionState::extend_scored`] — the same M ≤ 4 GEMV regime the
//! decode path already routes through); an acceptance rule keeps the
//! emitted distribution identical to plain decode and a KV rollback
//! ([`SessionState::truncate_to`]) erases rejected draft rows.
//!
//! Why it wins: npusim pins decode as memory-bound for every INT
//! operator, so a (k+1)-row verify costs roughly the same weight
//! traffic as ONE sequential step. Each round emits `a + 1` tokens
//! (`a` = accepted drafts) for one target pass plus `k` cheap draft
//! steps — see `npusim::gemm_plan::SpecRoundPlan` for the pricing.
//!
//! # Acceptance rules
//!
//! * **Greedy** (`sampler.is_greedy()`): accept draft `d_i` iff it
//!   equals the target argmax at that position; on mismatch emit the
//!   target's choice and stop. Consumes NO randomness — by induction
//!   every emitted token equals plain greedy decode (`tests/
//!   speculative.rs` pins this token-for-token), because verify row `i`
//!   is bit-exact against the plain decode step at the same prefix.
//! * **Stochastic**: standard rejection sampling (Leviathan et al.).
//!   Draft token `d ~ q`; accept iff `u · q(d) < p(d)`; on rejection
//!   draw the correction from `norm(max(0, p − q))`. The marginal of
//!   every emitted token is exactly `p` — distribution-identical to
//!   plain sampled decode, though not stream-identical (the RNG is
//!   consumed in a different order).
//!
//! # Sessions, rollback, catch-up
//!
//! Target and draft each own a full [`SessionState`]. After a round
//! with `a` accepted drafts the target holds `a + 1` new rows (`next`
//! plus the accepted drafts) — `truncate_to` drops the rejected tail.
//! The draft cached `d_1 .. d_{k-1}` while proposing; on rejection it
//! rolls back to the accepted prefix, on full acceptance `d_k` (chosen
//! but never stepped) goes into `pending` and is replayed at the next
//! round's catch-up extend. Both sessions require the exact
//! [`WrapPolicy::Reprefill`] policy: rollback needs window ↔ ring
//! agreement, which Slide's in-place overwrite breaks.

use super::kvpool::KvPool;
use super::model::{Gpt2Config, Gpt2Model};
use super::quantized::QuantizedGpt2;
use super::session::{Sampler, SessionModel, SessionState, WrapPolicy};
use anyhow::{bail, Result};

/// Salt for deriving the draft's RNG stream from the request sampler
/// ([`Sampler::fork`]) — one fixed constant so (seed, prompt, model)
/// still reproduces a speculative generation exactly.
pub const DRAFT_SEED_SALT: u64 = 0xd12a_f75a;

/// Which cheap model proposes the draft tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DraftKind {
    /// The full-depth model through the naive-INT8 operator — same
    /// architecture, cheapest uniform quantization (high acceptance,
    /// draft cost ≈ target's INT cost).
    NaiveInt8,
    /// The full-depth model through the naive-W4A8 operator — the
    /// nibble-packed weight engine makes the natural cheap draft: same
    /// architecture (high acceptance) at half the INT8 draft's
    /// bytes-dominated decode cost.
    NaiveInt4,
    /// The first `n` transformer blocks of the target at f32
    /// ([`Gpt2Model::truncated`]) — depth-scaled cost, lower acceptance.
    TruncateLayers(usize),
}

impl DraftKind {
    /// Parse the CLI / request tag: `naive-int8`, `naive-int4` or
    /// `trunc<N>`.
    pub fn parse(tag: &str) -> Result<DraftKind> {
        if tag == "naive-int8" {
            return Ok(DraftKind::NaiveInt8);
        }
        if tag == "naive-int4" {
            return Ok(DraftKind::NaiveInt4);
        }
        if let Some(n) = tag.strip_prefix("trunc") {
            if let Ok(n) = n.parse::<usize>() {
                return Ok(DraftKind::TruncateLayers(n));
            }
        }
        bail!("unknown draft kind {tag:?} (naive-int8 | naive-int4 | trunc<N>)")
    }

    pub fn tag(&self) -> String {
        match self {
            DraftKind::NaiveInt8 => "naive-int8".into(),
            DraftKind::NaiveInt4 => "naive-int4".into(),
            DraftKind::TruncateLayers(n) => format!("trunc{n}"),
        }
    }
}

/// An owned draft deployment built from the target model — owning (not
/// borrowing) lets the serving loop cache drafts next to its backend.
pub enum DraftModel {
    Fp(Gpt2Model),
    Int(QuantizedGpt2),
}

impl DraftModel {
    /// Build the draft for `kind` from the target's weights.
    pub fn build(target: &Gpt2Model, kind: DraftKind) -> Result<DraftModel> {
        use crate::quant::EngineSpec;
        Ok(match kind {
            DraftKind::NaiveInt8 => {
                DraftModel::Int(QuantizedGpt2::new(target.clone(), EngineSpec::naive()))
            }
            DraftKind::NaiveInt4 => DraftModel::Int(QuantizedGpt2::new(
                target.clone(),
                EngineSpec::naive().with_bits(8, 4),
            )),
            DraftKind::TruncateLayers(n) => DraftModel::Fp(target.truncated(n)?),
        })
    }

    pub fn cfg(&self) -> &Gpt2Config {
        match self {
            DraftModel::Fp(m) => &m.cfg,
            DraftModel::Int(q) => &q.fp.cfg,
        }
    }

    /// The session-facing view (same enum every decode path consumes).
    pub fn session_model(&self) -> SessionModel<'_> {
        match self {
            DraftModel::Fp(m) => SessionModel::Fp(m),
            DraftModel::Int(q) => SessionModel::Int(q),
        }
    }
}

/// Model-borrowing-free speculative pair state — the serving loop owns
/// many of these alongside its backend and draft cache, mirroring how
/// [`SessionState`] relates to [`super::session::DecodeSession`].
pub struct SpeculativeState {
    /// drafts proposed per round
    pub k: usize,
    t: SessionState,
    d: SessionState,
    /// tokens already in the target window that the draft has not yet
    /// cached (at most one: the last draft of a fully-accepted round)
    pending: Vec<u32>,
    rounds: u64,
    drafted: u64,
    accepted: u64,
    /// reusable q / p / residual rows for the stochastic rule
    qrows: Vec<Vec<f32>>,
    pbuf: Vec<f32>,
}

impl SpeculativeState {
    /// `k` drafts per round over a target/draft config pair. Speculation
    /// requires the exact wrap policy (see module docs).
    pub fn new(
        target_cfg: &Gpt2Config,
        draft_cfg: &Gpt2Config,
        k: usize,
        wrap: WrapPolicy,
    ) -> Result<SpeculativeState> {
        Self::validate(target_cfg, k, wrap)?;
        Ok(Self::from_sessions(
            k,
            SessionState::new(target_cfg, wrap),
            SessionState::new(draft_cfg, wrap),
        ))
    }

    /// [`SpeculativeState::new`] with BOTH sessions drawing KV pages
    /// from a shared [`KvPool`] — target and draft preserve `d_model`
    /// (the NaiveInt* drafts are the same architecture; TruncateLayers
    /// shrinks depth only), so one pool serves both block tables. Rollback
    /// (`truncate_to`) releases dead pages instead of merely shrinking
    /// `len`, which the differential proptests pin bit-exact against the
    /// ring pair.
    pub fn new_paged(
        target_cfg: &Gpt2Config,
        draft_cfg: &Gpt2Config,
        k: usize,
        wrap: WrapPolicy,
        pool: &KvPool,
    ) -> Result<SpeculativeState> {
        Self::validate(target_cfg, k, wrap)?;
        Ok(Self::from_sessions(
            k,
            SessionState::new_paged(target_cfg, wrap, pool),
            SessionState::new_paged(draft_cfg, wrap, pool),
        ))
    }

    /// Shared admission checks for both constructors. Speculation
    /// requires the exact wrap policy (see module docs).
    fn validate(target_cfg: &Gpt2Config, k: usize, wrap: WrapPolicy) -> Result<()> {
        if k == 0 {
            bail!("speculative k must be >= 1");
        }
        if !matches!(wrap, WrapPolicy::Reprefill { .. }) {
            bail!("speculative decoding requires WrapPolicy::Reprefill (rollback needs exact ring state)");
        }
        if k + 1 >= target_cfg.n_ctx {
            bail!("k {k} leaves no room for verify in n_ctx {}", target_cfg.n_ctx);
        }
        Ok(())
    }

    fn from_sessions(k: usize, t: SessionState, d: SessionState) -> SpeculativeState {
        SpeculativeState {
            k,
            t,
            d,
            pending: Vec::new(),
            rounds: 0,
            drafted: 0,
            accepted: 0,
            qrows: Vec::new(),
            pbuf: Vec::new(),
        }
    }

    /// Prefill BOTH sessions with the prompt; returns the target's
    /// next-token logits (the caller samples the first token from them,
    /// exactly like plain decode).
    pub fn prefill(
        &mut self,
        target: SessionModel<'_>,
        draft: SessionModel<'_>,
        prompt: &[u32],
    ) -> Result<Vec<f32>> {
        self.pending.clear();
        self.d.prefill(draft, prompt)?;
        self.t.prefill(target, prompt)
    }

    /// One draft-and-verify round. `next` is the most recently emitted
    /// token (sampled by the caller, not yet in either cache). Returns
    /// the `a + 1` tokens this round emits — `a` accepted drafts plus
    /// one correction (on rejection) or bonus (all accepted); the LAST
    /// returned token is the next round's `next`.
    pub fn round(
        &mut self,
        target: SessionModel<'_>,
        draft: SessionModel<'_>,
        next: u32,
        sampler: &mut Sampler,
        draft_sampler: &mut Sampler,
    ) -> Result<Vec<u32>> {
        let k = self.k;
        let greedy = sampler.is_greedy();
        self.t.ensure_room_for(target, k + 1)?;
        self.d.ensure_room_for(draft, self.pending.len() + k)?;

        // ---- draft: catch up on accepted tokens, then propose k more
        let mut catchup = std::mem::take(&mut self.pending);
        catchup.push(next);
        let mut dlogits = self.d.extend_last(draft, &catchup)?;
        catchup.clear();
        self.pending = catchup;
        let d_base = self.d.context_len(); // draft rollback point
        self.qrows.resize_with(k, Vec::new);
        let mut drafts = Vec::with_capacity(k);
        for i in 0..k {
            let di = if greedy {
                // exact-match acceptance never reads q — let the draft
                // pick however its sampler likes (no RNG when greedy)
                draft_sampler.sample_in_context(&dlogits, self.d.window())
            } else {
                // stochastic: remember q_i, then draw from it so the
                // proposal and the recorded distribution agree exactly
                let q = &mut self.qrows[i];
                draft_sampler.probs_in_context(&dlogits, self.d.window(), q);
                draft_sampler.draw_from(q)
            };
            drafts.push(di);
            if i + 1 < k {
                dlogits = self.d.decode_step(draft, di)?;
            }
            // d_k is proposed but never stepped — the verify outcome
            // decides whether it enters any cache
        }

        // ---- verify: one (k+1)-row scored extend on the target
        let base = self.t.context_len();
        let mut block = Vec::with_capacity(k + 1);
        block.push(next);
        block.extend_from_slice(&drafts);
        let ver = self.t.extend_scored(target, &block)?;

        // ---- accept
        let mut emitted = Vec::with_capacity(k + 1);
        let mut a = 0usize; // accepted drafts
        for (i, &di) in drafts.iter().enumerate() {
            // the context verify row i was computed over
            let hist_len = base + 1 + i;
            if greedy {
                let choice = {
                    let hist = &self.t.window()[..hist_len];
                    sampler.sample_in_context(ver.row(i), hist)
                };
                if choice == di {
                    a += 1;
                    emitted.push(di);
                } else {
                    emitted.push(choice);
                    break;
                }
            } else {
                let mut p = std::mem::take(&mut self.pbuf);
                {
                    let hist = &self.t.window()[..hist_len];
                    sampler.probs_in_context(ver.row(i), hist, &mut p);
                }
                let q = &self.qrows[i];
                let (pd, qd) = (p[di as usize], q[di as usize]);
                let accept = (sampler.next_uniform() as f32) * qd < pd;
                if accept {
                    a += 1;
                    emitted.push(di);
                    self.pbuf = p;
                } else {
                    // correction ~ norm(max(0, p - q)); the residual is
                    // all-zero only when p == q up to float dust, where
                    // drawing from p itself is the same distribution
                    let mut total = 0.0f32;
                    for (pv, &qv) in p.iter_mut().zip(q) {
                        *pv = (*pv - qv).max(0.0);
                        total += *pv;
                    }
                    if total > 0.0 {
                        for pv in p.iter_mut() {
                            *pv /= total;
                        }
                        emitted.push(sampler.draw_from(&p));
                    } else {
                        let hist = &self.t.window()[..hist_len];
                        sampler.probs_in_context(ver.row(i), hist, &mut p);
                        emitted.push(sampler.draw_from(&p));
                    }
                    self.pbuf = p;
                    break;
                }
            }
        }
        if a == k {
            // everything accepted: the bonus token comes free from the
            // last verify row (full-window context)
            let bonus = sampler.sample_in_context(ver.row(k), self.t.window());
            emitted.push(bonus);
        }

        // ---- rollback to the accepted prefix
        // target gained k+1 rows; keep `next` + the a accepted drafts
        // (the final emitted token is NEXT round's input, not cached yet)
        self.t.truncate_to(base + 1 + a);
        if a == k {
            // draft cached d_1..d_{k-1}; d_k rides along to the catch-up
            self.pending.push(drafts[k - 1]);
        } else {
            self.d.truncate_to(d_base + a);
        }

        self.rounds += 1;
        self.drafted += k as u64;
        self.accepted += a as u64;
        Ok(emitted)
    }

    /// The target-side session (its `window()` is the emitted context).
    pub fn target_state(&self) -> &SessionState {
        &self.t
    }

    pub fn draft_state(&self) -> &SessionState {
        &self.d
    }

    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    pub fn drafted(&self) -> u64 {
        self.drafted
    }

    pub fn accepted(&self) -> u64 {
        self.accepted
    }

    /// Fraction of proposed drafts accepted (0 when no rounds ran).
    pub fn accept_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Mean tokens emitted per round (each round emits `a + 1`).
    pub fn tokens_per_round(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            (self.accepted + self.rounds) as f64 / self.rounds as f64
        }
    }
}

/// Ergonomic owned-draft wrapper binding a [`SpeculativeState`] to its
/// target model — the API `examples/generate.rs --spec` uses.
pub struct SpeculativeSession<'m> {
    target: SessionModel<'m>,
    draft: DraftModel,
    pub state: SpeculativeState,
}

impl<'m> SpeculativeSession<'m> {
    pub fn new(
        target: SessionModel<'m>,
        kind: DraftKind,
        k: usize,
        wrap: WrapPolicy,
    ) -> Result<SpeculativeSession<'m>> {
        let draft = DraftModel::build(target.gpt(), kind)?;
        let state = SpeculativeState::new(&target.gpt().cfg, draft.cfg(), k, wrap)?;
        Ok(SpeculativeSession { target, draft, state })
    }

    /// Prefill + decode `steps` tokens speculatively. With a greedy
    /// sampler the result equals [`super::session::DecodeSession::
    /// generate_greedy`] token-for-token (while the context stays inside
    /// `n_ctx` — wrap points differ between the two schedules).
    pub fn generate(
        &mut self,
        prompt: &[u32],
        steps: usize,
        sampler: &mut Sampler,
    ) -> Result<Vec<u32>> {
        let mut draft_sampler = sampler.fork(DRAFT_SEED_SALT);
        let logits = self.state.prefill(self.target, self.draft.session_model(), prompt)?;
        if steps == 0 {
            return Ok(Vec::new());
        }
        let mut next = sampler.sample_in_context(&logits, self.state.target_state().window());
        let mut out = vec![next];
        while out.len() < steps {
            let emitted = self.state.round(
                self.target,
                self.draft.session_model(),
                next,
                sampler,
                &mut draft_sampler,
            )?;
            next = *emitted.last().expect("round emits at least one token");
            out.extend_from_slice(&emitted);
        }
        out.truncate(steps);
        Ok(out)
    }

    pub fn generate_greedy(&mut self, prompt: &[u32], steps: usize) -> Result<Vec<u32>> {
        self.generate(prompt, steps, &mut Sampler::greedy())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::EngineSpec;

    fn tiny() -> Gpt2Model {
        Gpt2Model::test_model(2, 16, 2, 16, 32, 7)
    }

    fn toks(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = crate::data::prng::SplitMix64::new(seed);
        (0..n).map(|_| rng.next_below(32) as u32).collect()
    }

    #[test]
    fn greedy_spec_equals_plain_greedy_both_drafts() {
        let m = tiny();
        let prompt = toks(4, 41);
        // n_ctx 16: 4 prompt + 8 steps + k+1 <= 16 stays wrap-free
        let steps = 8;
        let mut plain = m.session(WrapPolicy::default());
        let want = plain.generate_greedy(&prompt, steps).unwrap();
        for kind in
            [DraftKind::TruncateLayers(1), DraftKind::NaiveInt8, DraftKind::NaiveInt4]
        {
            for k in 1..=3usize {
                let mut s =
                    SpeculativeSession::new(SessionModel::Fp(&m), kind, k, WrapPolicy::default())
                        .unwrap();
                let got = s.generate_greedy(&prompt, steps).unwrap();
                assert_eq!(got, want, "kind {kind:?} k {k}");
                assert!(s.state.rounds() > 0);
            }
        }
    }

    #[test]
    fn greedy_spec_on_int_target_matches_int_plain() {
        // the target itself can be a deployed INT operator stack
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let prompt = toks(5, 43);
        let mut plain = q.session(WrapPolicy::default());
        let want = plain.generate_greedy(&prompt, 7).unwrap();
        let mut s = SpeculativeSession::new(
            SessionModel::Int(&q),
            DraftKind::TruncateLayers(1),
            2,
            WrapPolicy::default(),
        )
        .unwrap();
        assert_eq!(s.generate_greedy(&prompt, 7).unwrap(), want);
    }

    #[test]
    fn self_draft_accepts_everything() {
        // draft == target (full-depth truncation): greedy acceptance is
        // total, every round emits k+1 tokens
        let m = tiny();
        let mut s = SpeculativeSession::new(
            SessionModel::Fp(&m),
            DraftKind::TruncateLayers(m.cfg.n_layer),
            3,
            WrapPolicy::default(),
        )
        .unwrap();
        let out = s.generate_greedy(&toks(4, 44), 9).unwrap();
        assert_eq!(out.len(), 9);
        assert_eq!(s.state.accept_rate(), 1.0);
        assert_eq!(s.state.tokens_per_round(), 4.0);
    }

    #[test]
    fn stochastic_spec_is_seed_reproducible_and_valid() {
        let m = tiny();
        let prompt = toks(4, 45);
        let run = |seed: u64| {
            let mut s = SpeculativeSession::new(
                SessionModel::Fp(&m),
                DraftKind::TruncateLayers(1),
                2,
                WrapPolicy::default(),
            )
            .unwrap();
            s.generate(&prompt, 8, &mut Sampler::new(0.9, 8, seed).with_top_p(0.95))
                .unwrap()
        };
        assert_eq!(run(5), run(5), "same seed, same speculative stream");
        for &t in &run(5) {
            assert!((t as usize) < 32, "token {t} outside vocab");
        }
    }

    #[test]
    fn misconfigurations_are_rejected() {
        let m = tiny();
        assert!(
            SpeculativeSession::new(SessionModel::Fp(&m), DraftKind::NaiveInt8, 0, WrapPolicy::default())
                .is_err(),
            "k = 0"
        );
        assert!(
            SpeculativeSession::new(SessionModel::Fp(&m), DraftKind::NaiveInt8, 2, WrapPolicy::Slide)
                .is_err(),
            "slide wrap"
        );
        assert!(
            SpeculativeSession::new(
                SessionModel::Fp(&m),
                DraftKind::TruncateLayers(99),
                2,
                WrapPolicy::default()
            )
            .is_err(),
            "draft deeper than target"
        );
    }

    #[test]
    fn draft_kind_tags_round_trip() {
        for kind in [DraftKind::NaiveInt8, DraftKind::NaiveInt4, DraftKind::TruncateLayers(3)] {
            assert_eq!(DraftKind::parse(&kind.tag()).unwrap(), kind);
        }
        assert!(DraftKind::parse("bogus").is_err());
        assert!(DraftKind::parse("truncX").is_err());
    }
}
