//! Decode sessions: the stateful layer between the incremental model
//! forward (`model.rs`: [`KvCache`], `forward_session`,
//! `decode_step_sessions`) and the serving coordinator
//! (`coordinator::generation`). One [`DecodeSession`] owns one
//! sequence's per-layer caches, its live token window and its position
//! counter; [`decode_step_batch`] advances many sessions in one fused
//! skinny GEMM step (continuous batching) with per-session results
//! bit-identical to stepping each alone.
//!
//! # Context-overflow (wrap) policies
//!
//! GPT-2's absolute position embeddings mean a ring cache cannot keep
//! attending exactly once generation passes `n_ctx` — cached K/V were
//! computed under their admission positions. Two policies:
//!
//! * [`WrapPolicy::Reprefill`] (default): when the cache fills, drop the
//!   oldest tokens and re-prefill the kept window with fresh positions.
//!   Logits stay **bit-exact** against a full forward over the session's
//!   live window at every step — the oracle property the proptests pin —
//!   at the amortized cost of one O(keep²) prefill per `n_ctx - keep`
//!   generated tokens (still O(context) per token).
//! * [`WrapPolicy::Slide`]: StreamingLLM-style infinite generation — the
//!   ring overwrites the oldest entry in place and new tokens clamp to
//!   the last position index. O(1) per step forever, but approximate:
//!   kept K/V retain their admission-time positions (and were computed
//!   attending over context that has since been evicted), so there is no
//!   full-forward oracle past the wrap; the ring mechanics themselves
//!   are pinned against a deque reference in `tests/decode_session.rs`.

use super::model::{Gpt2Config, Gpt2Model, KvCache};
use super::quantized::QuantizedGpt2;
use crate::quant::MatF32;
use anyhow::{bail, Result};

/// What to do when a session's context window is full (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WrapPolicy {
    /// Drop the oldest tokens and re-prefill the last `keep` with fresh
    /// positions (exact; `keep == 0` means 3/4 of `n_ctx`).
    Reprefill { keep: usize },
    /// Ring-overwrite the oldest entry, clamp positions at `n_ctx - 1`
    /// (approximate, O(1) per step).
    Slide,
}

impl Default for WrapPolicy {
    fn default() -> Self {
        WrapPolicy::Reprefill { keep: 0 }
    }
}

impl WrapPolicy {
    fn keep_for(self, n_ctx: usize) -> usize {
        match self {
            WrapPolicy::Reprefill { keep: 0 } => (n_ctx * 3 / 4).max(1),
            WrapPolicy::Reprefill { keep } => keep.min(n_ctx - 1).max(1),
            WrapPolicy::Slide => n_ctx,
        }
    }
}

/// The model a session runs against: plain f32, or the true-INT pipeline
/// through its row-independent session projection.
#[derive(Clone, Copy)]
pub enum SessionModel<'m> {
    Fp(&'m Gpt2Model),
    Int(&'m QuantizedGpt2),
}

impl<'m> SessionModel<'m> {
    pub fn gpt(&self) -> &'m Gpt2Model {
        match *self {
            SessionModel::Fp(m) => m,
            SessionModel::Int(q) => &q.fp,
        }
    }

    fn extend(&self, tokens: &[u32], pos0: usize, caches: &mut [KvCache]) -> Result<MatF32> {
        match self {
            SessionModel::Fp(m) => m.forward_session(tokens, pos0, caches, None),
            SessionModel::Int(q) => {
                let mut f = |x: &MatF32, site: &'static str, li: usize| q.proj_session(x, site, li);
                q.fp.forward_session(tokens, pos0, caches, Some(&mut f))
            }
        }
    }

    /// `extend` without computing logits — the wrap re-prefill discards
    /// them, and the tied-head GEMM they cost is the biggest in the pass.
    fn extend_quiet(&self, tokens: &[u32], pos0: usize, caches: &mut [KvCache]) -> Result<()> {
        match self {
            SessionModel::Fp(m) => m.forward_session_no_logits(tokens, pos0, caches, None),
            SessionModel::Int(q) => {
                let mut f = |x: &MatF32, site: &'static str, li: usize| q.proj_session(x, site, li);
                q.fp.forward_session_no_logits(tokens, pos0, caches, Some(&mut f))
            }
        }
    }

    fn step(
        &self,
        tokens: &[u32],
        positions: &[usize],
        caches: &mut [&mut [KvCache]],
    ) -> Result<MatF32> {
        match self {
            SessionModel::Fp(m) => m.decode_step_sessions(tokens, positions, caches, None),
            SessionModel::Int(q) => {
                let mut f = |x: &MatF32, site: &'static str, li: usize| q.proj_session(x, site, li);
                q.fp.decode_step_sessions(tokens, positions, caches, Some(&mut f))
            }
        }
    }
}

/// Per-sequence decode state, model-borrowing-free so a serving loop can
/// own many of these alongside the model (see [`DecodeSession`] for the
/// ergonomic borrowed wrapper).
pub struct SessionState {
    caches: Vec<KvCache>,
    /// tokens whose K/V are live, oldest first (== the effective context)
    window: Vec<u32>,
    wrap: WrapPolicy,
    /// prefill passes run (1 after `prefill`, +1 per Reprefill wrap)
    prefills: u64,
}

impl SessionState {
    pub fn new(cfg: &Gpt2Config, wrap: WrapPolicy) -> SessionState {
        SessionState {
            caches: (0..cfg.n_layer).map(|_| KvCache::new(cfg.n_ctx, cfg.d_model)).collect(),
            window: Vec::new(),
            wrap,
            prefills: 0,
        }
    }

    /// The live context: every token whose K/V the next step attends to.
    /// After a `decode_step` the stepped token is included, so under the
    /// (default, exact) Reprefill policy the returned logits are always a
    /// full forward of exactly `window()`.
    pub fn window(&self) -> &[u32] {
        &self.window
    }

    pub fn context_len(&self) -> usize {
        self.window.len()
    }

    pub fn prefills(&self) -> u64 {
        self.prefills
    }

    /// Process the prompt at its TRUE length (no padding rows — the old
    /// fixed-shape generate path left-padded with token 0 and attended
    /// over the pads, skewing short-prompt logits). Prompts longer than
    /// `n_ctx` keep their last `n_ctx` tokens. Returns the last row's
    /// logits (the next-token distribution).
    pub fn prefill(&mut self, m: SessionModel<'_>, prompt: &[u32]) -> Result<Vec<f32>> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        let n_ctx = m.gpt().cfg.n_ctx;
        let used = &prompt[prompt.len().saturating_sub(n_ctx)..];
        for c in &mut self.caches {
            c.clear();
        }
        self.window.clear();
        let logits = m.extend(used, 0, &mut self.caches)?;
        self.window.extend_from_slice(used);
        self.prefills += 1;
        Ok(logits.row(logits.rows - 1).to_vec())
    }

    /// Append one token and return its next-token logits — O(context)
    /// work, unlike re-running the full forward. Must follow `prefill`.
    pub fn decode_step(&mut self, m: SessionModel<'_>, token: u32) -> Result<Vec<f32>> {
        if self.window.is_empty() {
            bail!("decode_step before prefill");
        }
        self.ensure_room(m)?;
        let pos = self.next_pos(m.gpt().cfg.n_ctx);
        let logits = m.step(&[token], &[pos], &mut [self.caches.as_mut_slice()])?;
        self.note(m.gpt().cfg.n_ctx, token);
        Ok(logits.data)
    }

    fn next_pos(&self, n_ctx: usize) -> usize {
        self.window.len().min(n_ctx - 1)
    }

    fn note(&mut self, n_ctx: usize, token: u32) {
        self.window.push(token);
        if self.window.len() > n_ctx {
            // Slide evicted the oldest K/V in the ring; mirror it here
            self.window.remove(0);
        }
    }

    /// Apply the wrap policy if the cache is full (called before a step).
    fn ensure_room(&mut self, m: SessionModel<'_>) -> Result<()> {
        let n_ctx = m.gpt().cfg.n_ctx;
        if self.window.len() < n_ctx {
            return Ok(());
        }
        match self.wrap {
            WrapPolicy::Slide => Ok(()), // the ring overwrites in place
            WrapPolicy::Reprefill { .. } => {
                let keep = self.wrap.keep_for(n_ctx);
                self.window.drain(..self.window.len() - keep);
                for c in &mut self.caches {
                    c.clear();
                }
                // logits of the kept window are not needed — the caller
                // is about to decode the NEXT token
                m.extend_quiet(&self.window, 0, &mut self.caches)?;
                self.prefills += 1;
                Ok(())
            }
        }
    }
}

/// One decode step for many live sessions, coalesced into a single
/// skinny-GEMM batch (`tokens[i]` feeds `sessions[i]`). Wrap policies
/// are applied per session first, then all projections run as `[G, ·]`
/// GEMMs. Returns logits `[G, vocab]`; each row is bit-identical to
/// `sessions[i].decode_step(m, tokens[i])` run alone.
pub fn decode_step_batch(
    m: SessionModel<'_>,
    sessions: &mut [&mut SessionState],
    tokens: &[u32],
) -> Result<MatF32> {
    if sessions.is_empty() || sessions.len() != tokens.len() {
        bail!("{} sessions vs {} tokens", sessions.len(), tokens.len());
    }
    if sessions.iter().any(|s| s.window.is_empty()) {
        bail!("decode_step_batch before prefill");
    }
    for s in sessions.iter_mut() {
        s.ensure_room(m)?;
    }
    let n_ctx = m.gpt().cfg.n_ctx;
    let positions: Vec<usize> = sessions.iter().map(|s| s.next_pos(n_ctx)).collect();
    let mut cache_refs: Vec<&mut [KvCache]> =
        sessions.iter_mut().map(|s| s.caches.as_mut_slice()).collect();
    let logits = m.step(tokens, &positions, &mut cache_refs)?;
    drop(cache_refs);
    for (s, &t) in sessions.iter_mut().zip(tokens) {
        s.note(n_ctx, t);
    }
    Ok(logits)
}

/// Ergonomic single-session wrapper binding a [`SessionState`] to its
/// model — the API `examples/generate.rs` uses.
pub struct DecodeSession<'m> {
    model: SessionModel<'m>,
    pub state: SessionState,
}

impl<'m> DecodeSession<'m> {
    pub fn new(model: SessionModel<'m>, wrap: WrapPolicy) -> DecodeSession<'m> {
        DecodeSession { state: SessionState::new(&model.gpt().cfg, wrap), model }
    }

    pub fn prefill(&mut self, prompt: &[u32]) -> Result<Vec<f32>> {
        self.state.prefill(self.model, prompt)
    }

    pub fn decode_step(&mut self, token: u32) -> Result<Vec<f32>> {
        self.state.decode_step(self.model, token)
    }

    /// Prefill + greedy-decode `steps` tokens; returns the generated ids.
    pub fn generate_greedy(&mut self, prompt: &[u32], steps: usize) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(steps);
        if steps == 0 {
            self.prefill(prompt)?;
            return Ok(out);
        }
        let mut next = argmax(&self.prefill(prompt)?);
        for i in 0..steps {
            out.push(next);
            if i + 1 < steps {
                next = argmax(&self.decode_step(next)?);
            }
        }
        Ok(out)
    }
}

impl Gpt2Model {
    /// Open an incremental-decode session over this model.
    pub fn session(&self, wrap: WrapPolicy) -> DecodeSession<'_> {
        DecodeSession::new(SessionModel::Fp(self), wrap)
    }
}

impl QuantizedGpt2 {
    /// Open an incremental-decode session through the true-INT pipeline
    /// (row-independent session projection — see `quantized.rs` docs).
    pub fn session(&self, wrap: WrapPolicy) -> DecodeSession<'_> {
        DecodeSession::new(SessionModel::Int(self), wrap)
    }
}

/// Greedy sampling: index of the maximum logit (ties resolve to the
/// highest index — the `max_by`/`total_cmp` convention every caller in
/// this repo shares, so identical logits always yield identical tokens).
pub fn argmax(logits: &[f32]) -> u32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as u32)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpt2::IntMethod;

    fn tiny() -> Gpt2Model {
        Gpt2Model::test_model(2, 16, 2, 12, 32, 7)
    }

    fn toks(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = crate::data::prng::SplitMix64::new(seed);
        (0..n).map(|_| rng.next_below(32) as u32).collect()
    }

    #[test]
    fn session_matches_full_forward_fp() {
        let m = tiny();
        let prompt = toks(5, 1);
        let mut s = m.session(WrapPolicy::default());
        let mut logits = s.prefill(&prompt).unwrap();
        let mut ctx = prompt.clone();
        for step in 0..4u32 {
            let full = m.forward(&[ctx.clone()], None, None).unwrap();
            assert_eq!(logits, full.row(ctx.len() - 1).to_vec(), "step {step}");
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            ctx.push(next);
        }
    }

    #[test]
    fn session_matches_oracle_int_muxq() {
        let q = QuantizedGpt2::new(tiny(), IntMethod::Muxq, 8, 8);
        let prompt = toks(6, 2);
        let mut s = q.session(WrapPolicy::default());
        let mut logits = s.prefill(&prompt).unwrap();
        let mut ctx = prompt.clone();
        for _ in 0..3 {
            let oracle = q.forward_logits_session(&[ctx.clone()]).unwrap();
            assert_eq!(logits, oracle.row(ctx.len() - 1).to_vec());
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            ctx.push(next);
        }
    }

    #[test]
    fn reprefill_wrap_stays_exact_past_n_ctx() {
        // n_ctx = 12; generate far past it — every step's logits must be
        // a full forward of the session's live window
        let m = tiny();
        let mut s = m.session(WrapPolicy::default());
        let mut logits = s.prefill(&toks(8, 3)).unwrap();
        for _ in 0..20 {
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            let win = s.state.window().to_vec();
            assert!(win.len() <= 12);
            let full = m.forward(&[win.clone()], None, None).unwrap();
            assert_eq!(logits, full.row(win.len() - 1).to_vec());
        }
        assert!(s.state.prefills() > 1, "wrap must have re-prefilled");
    }

    #[test]
    fn slide_wrap_keeps_ring_at_n_ctx() {
        let m = tiny();
        let mut s = m.session(WrapPolicy::Slide);
        let mut logits = s.prefill(&toks(12, 4)).unwrap(); // full from the start
        for _ in 0..10 {
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            assert_eq!(s.state.context_len(), 12);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        assert_eq!(s.state.prefills(), 1, "slide never re-prefills");
    }

    #[test]
    fn batched_decode_bit_exact_vs_solo() {
        let q = QuantizedGpt2::new(tiny(), IntMethod::Muxq, 8, 8);
        let m = SessionModel::Int(&q);
        let prompts = [toks(3, 5), toks(7, 6), toks(5, 7)];
        // solo runs
        let mut solo_logits = Vec::new();
        for p in &prompts {
            let mut s = SessionState::new(&q.fp.cfg, WrapPolicy::default());
            let first = argmax(&s.prefill(m, p).unwrap());
            solo_logits.push(s.decode_step(m, first).unwrap());
        }
        // batched run over the same three sessions
        let mut states: Vec<SessionState> =
            prompts.iter().map(|_| SessionState::new(&q.fp.cfg, WrapPolicy::default())).collect();
        let mut tokens = Vec::new();
        for (st, p) in states.iter_mut().zip(&prompts) {
            tokens.push(argmax(&st.prefill(m, p).unwrap()));
        }
        let mut refs: Vec<&mut SessionState> = states.iter_mut().collect();
        let batch = decode_step_batch(m, &mut refs, &tokens).unwrap();
        for (i, solo) in solo_logits.iter().enumerate() {
            assert_eq!(batch.row(i), &solo[..], "session {i}");
        }
    }

    #[test]
    fn long_prompt_truncates_to_n_ctx() {
        let m = tiny();
        let mut s = m.session(WrapPolicy::default());
        let long = toks(30, 8);
        s.prefill(&long).unwrap();
        assert_eq!(s.state.context_len(), 12);
        assert_eq!(s.state.window(), &long[18..]);
    }

    #[test]
    fn misuse_is_rejected() {
        let m = tiny();
        let mut s = m.session(WrapPolicy::default());
        assert!(s.decode_step(0).is_err(), "step before prefill");
        assert!(s.prefill(&[]).is_err(), "empty prompt");
        let mut a = SessionState::new(&m.cfg, WrapPolicy::default());
        a.prefill(SessionModel::Fp(&m), &[1, 2]).unwrap();
        let mut refs = [&mut a];
        assert!(decode_step_batch(SessionModel::Fp(&m), &mut refs, &[1, 2]).is_err());
    }

    #[test]
    fn argmax_last_max_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 2);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }
}
