//! Decode sessions: the stateful layer between the incremental model
//! forward (`model.rs`: [`KvCache`], `forward_session`,
//! `decode_step_sessions`) and the serving coordinator
//! (`coordinator::generation`). One [`DecodeSession`] owns one
//! sequence's per-layer caches, its live token window and its position
//! counter; [`decode_step_batch`] advances many sessions in one fused
//! skinny GEMM step (continuous batching) with per-session results
//! bit-identical to stepping each alone.
//!
//! The model behind a session is either the plain f32 forward or ANY
//! deployed [`QuantizedGpt2`] — the operator API (`quant::linear`) means
//! naive, MUXQ, LLM.int8() and their SmoothQuant compositions all decode
//! through the same code path here.
//!
//! Token selection is a [`Sampler`]: greedy argmax by default, or
//! seeded temperature / top-k sampling (`SplitMix64`-driven, so a (seed,
//! prompt, model) triple reproduces its stream exactly).
//!
//! # Context-overflow (wrap) policies
//!
//! GPT-2's absolute position embeddings mean a ring cache cannot keep
//! attending exactly once generation passes `n_ctx` — cached K/V were
//! computed under their admission positions. Two policies:
//!
//! * [`WrapPolicy::Reprefill`] (default): when the cache fills, drop the
//!   oldest tokens and re-prefill the kept window with fresh positions.
//!   Logits stay **bit-exact** against a full forward over the session's
//!   live window at every step — the oracle property the proptests pin —
//!   at the amortized cost of one O(keep²) prefill per `n_ctx - keep`
//!   generated tokens (still O(context) per token).
//! * [`WrapPolicy::Slide`]: StreamingLLM-style infinite generation — the
//!   ring overwrites the oldest entry in place and new tokens clamp to
//!   the last position index. O(1) per step forever, but approximate:
//!   kept K/V retain their admission-time positions (and were computed
//!   attending over context that has since been evicted), so there is no
//!   full-forward oracle past the wrap; the ring mechanics themselves
//!   are pinned against a deque reference in `tests/decode_session.rs`.

use super::model::{Gpt2Config, Gpt2Model, KvCache};
use super::quantized::QuantizedGpt2;
use crate::data::prng::SplitMix64;
use crate::quant::MatF32;
use anyhow::{bail, Result};

/// What to do when a session's context window is full (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WrapPolicy {
    /// Drop the oldest tokens and re-prefill the last `keep` with fresh
    /// positions (exact; `keep == 0` means 3/4 of `n_ctx`).
    Reprefill { keep: usize },
    /// Ring-overwrite the oldest entry, clamp positions at `n_ctx - 1`
    /// (approximate, O(1) per step).
    Slide,
}

impl Default for WrapPolicy {
    fn default() -> Self {
        WrapPolicy::Reprefill { keep: 0 }
    }
}

impl WrapPolicy {
    fn keep_for(self, n_ctx: usize) -> usize {
        match self {
            WrapPolicy::Reprefill { keep: 0 } => (n_ctx * 3 / 4).max(1),
            WrapPolicy::Reprefill { keep } => keep.min(n_ctx - 1).max(1),
            WrapPolicy::Slide => n_ctx,
        }
    }
}

/// The model a session runs against: plain f32, or a deployed
/// [`QuantizedGpt2`] (any method) through its row-independent session
/// projection.
#[derive(Clone, Copy)]
pub enum SessionModel<'m> {
    Fp(&'m Gpt2Model),
    Int(&'m QuantizedGpt2),
}

impl<'m> SessionModel<'m> {
    pub fn gpt(&self) -> &'m Gpt2Model {
        match *self {
            SessionModel::Fp(m) => m,
            SessionModel::Int(q) => &q.fp,
        }
    }

    /// Prefill-shaped extend: all rows land in the caches, only the LAST
    /// row's logits are computed (the next-token distribution — the only
    /// row a prefill ever reads; the all-rows head GEMM the old path
    /// paid grows with prompt length for no benefit).
    fn extend_last(&self, tokens: &[u32], pos0: usize, caches: &mut [KvCache]) -> Result<Vec<f32>> {
        match self {
            SessionModel::Fp(m) => m.forward_session_last_logits(tokens, pos0, caches, None),
            SessionModel::Int(q) => {
                let mut f = |x: &MatF32, site: &'static str, li: usize| q.proj_session(x, site, li);
                q.fp.forward_session_last_logits(tokens, pos0, caches, Some(&mut f))
            }
        }
    }

    /// `extend` without computing logits — the wrap re-prefill discards
    /// them, and the tied-head GEMM they cost is the biggest in the pass.
    fn extend_quiet(&self, tokens: &[u32], pos0: usize, caches: &mut [KvCache]) -> Result<()> {
        match self {
            SessionModel::Fp(m) => m.forward_session_no_logits(tokens, pos0, caches, None),
            SessionModel::Int(q) => {
                let mut f = |x: &MatF32, site: &'static str, li: usize| q.proj_session(x, site, li);
                q.fp.forward_session_no_logits(tokens, pos0, caches, Some(&mut f))
            }
        }
    }

    fn step(
        &self,
        tokens: &[u32],
        positions: &[usize],
        caches: &mut [&mut [KvCache]],
    ) -> Result<MatF32> {
        match self {
            SessionModel::Fp(m) => m.decode_step_sessions(tokens, positions, caches, None),
            SessionModel::Int(q) => {
                let mut f = |x: &MatF32, site: &'static str, li: usize| q.proj_session(x, site, li);
                q.fp.decode_step_sessions(tokens, positions, caches, Some(&mut f))
            }
        }
    }
}

// --------------------------------------------------------------- sampling

/// Token selection over a logits row: greedy argmax, or seeded
/// temperature / top-k sampling. Deterministic — the internal
/// `SplitMix64` stream makes (seed, logits sequence) → tokens a pure
/// function, so sampled generations are replayable and the server can be
/// tested bit-for-bit against solo sessions.
#[derive(Debug, Clone)]
pub struct Sampler {
    /// softmax temperature; `<= 0` means greedy argmax
    pub temperature: f32,
    /// keep only the k highest logits before sampling; `0` = all
    pub top_k: usize,
    rng: SplitMix64,
    /// reusable candidate-index / weight buffers — this runs once per
    /// decoded token on the serving hot path, so no per-call allocation
    /// and no full-vocab sort (top-k is a partial selection)
    order: Vec<usize>,
    weights: Vec<f32>,
}

impl Sampler {
    /// Greedy argmax (the default serving mode; no randomness consumed).
    pub fn greedy() -> Sampler {
        Sampler::new(0.0, 0, 0)
    }

    /// Seeded temperature / top-k sampler.
    pub fn new(temperature: f32, top_k: usize, seed: u64) -> Sampler {
        Sampler {
            temperature,
            top_k,
            rng: SplitMix64::new(seed),
            order: Vec::new(),
            weights: Vec::new(),
        }
    }

    /// Greedy when the parameters make sampling degenerate: zero
    /// temperature, or a top-k of exactly one.
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0 || self.top_k == 1
    }

    /// Pick the next token for one logits row. Greedy consumes no
    /// randomness (ties resolve like [`argmax`]); otherwise one uniform
    /// draw over the temperature-softmaxed top-k candidates. O(V) per
    /// call (`select_nth` for the top-k cut, no sort), zero steady-state
    /// allocation.
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        if self.is_greedy() {
            return argmax(logits);
        }
        let v = logits.len();
        let k = if self.top_k == 0 { v } else { self.top_k.min(v) };
        self.order.clear();
        self.order.extend(0..v);
        if k < v {
            // partial selection: top-k candidates land (unordered) in
            // the first k slots
            let _ = self
                .order
                .select_nth_unstable_by(k - 1, |&a, &b| logits[b].total_cmp(&logits[a]));
            self.order.truncate(k);
        }
        // temperature softmax with max-subtraction for stability (the
        // global max is always among the candidates)
        let max =
            self.order.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
        let inv_t = 1.0 / self.temperature;
        self.weights.clear();
        self.weights.extend(self.order.iter().map(|&i| ((logits[i] - max) * inv_t).exp()));
        let total: f32 = self.weights.iter().sum();
        let mut u = self.rng.next_f64() as f32 * total;
        for (w, &i) in self.weights.iter().zip(&self.order) {
            u -= w;
            if u <= 0.0 {
                return i as u32;
            }
        }
        // numerical tail: fall back to the last candidate
        self.order[k - 1] as u32
    }
}

impl Default for Sampler {
    fn default() -> Self {
        Sampler::greedy()
    }
}

/// Per-sequence decode state, model-borrowing-free so a serving loop can
/// own many of these alongside the model (see [`DecodeSession`] for the
/// ergonomic borrowed wrapper).
pub struct SessionState {
    caches: Vec<KvCache>,
    /// tokens whose K/V are live, oldest first (== the effective context)
    window: Vec<u32>,
    wrap: WrapPolicy,
    /// prefill passes run (1 after `prefill`, +1 per Reprefill wrap)
    prefills: u64,
}

impl SessionState {
    pub fn new(cfg: &Gpt2Config, wrap: WrapPolicy) -> SessionState {
        SessionState {
            caches: (0..cfg.n_layer).map(|_| KvCache::new(cfg.n_ctx, cfg.d_model)).collect(),
            window: Vec::new(),
            wrap,
            prefills: 0,
        }
    }

    /// The live context: every token whose K/V the next step attends to.
    /// After a `decode_step` the stepped token is included, so under the
    /// (default, exact) Reprefill policy the returned logits are always a
    /// full forward of exactly `window()`.
    pub fn window(&self) -> &[u32] {
        &self.window
    }

    pub fn context_len(&self) -> usize {
        self.window.len()
    }

    pub fn prefills(&self) -> u64 {
        self.prefills
    }

    /// Process the prompt at its TRUE length (no padding rows — the old
    /// fixed-shape generate path left-padded with token 0 and attended
    /// over the pads, skewing short-prompt logits). Prompts longer than
    /// `n_ctx` keep their last `n_ctx` tokens. Returns the last row's
    /// logits (the next-token distribution) — the head GEMM runs for
    /// that row ONLY (`forward_session_last_logits`), cutting prefill
    /// cost by the prompt length at real vocab sizes.
    pub fn prefill(&mut self, m: SessionModel<'_>, prompt: &[u32]) -> Result<Vec<f32>> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        let n_ctx = m.gpt().cfg.n_ctx;
        let used = &prompt[prompt.len().saturating_sub(n_ctx)..];
        for c in &mut self.caches {
            c.clear();
        }
        self.window.clear();
        let logits = m.extend_last(used, 0, &mut self.caches)?;
        self.window.extend_from_slice(used);
        self.prefills += 1;
        Ok(logits)
    }

    /// Append one token and return its next-token logits — O(context)
    /// work, unlike re-running the full forward. Must follow `prefill`.
    pub fn decode_step(&mut self, m: SessionModel<'_>, token: u32) -> Result<Vec<f32>> {
        if self.window.is_empty() {
            bail!("decode_step before prefill");
        }
        self.ensure_room(m)?;
        let pos = self.next_pos(m.gpt().cfg.n_ctx);
        let logits = m.step(&[token], &[pos], &mut [self.caches.as_mut_slice()])?;
        self.note(m.gpt().cfg.n_ctx, token);
        Ok(logits.data)
    }

    fn next_pos(&self, n_ctx: usize) -> usize {
        self.window.len().min(n_ctx - 1)
    }

    fn note(&mut self, n_ctx: usize, token: u32) {
        self.window.push(token);
        if self.window.len() > n_ctx {
            // Slide evicted the oldest K/V in the ring; mirror it here
            self.window.remove(0);
        }
    }

    /// Apply the wrap policy if the cache is full (called before a step).
    fn ensure_room(&mut self, m: SessionModel<'_>) -> Result<()> {
        let n_ctx = m.gpt().cfg.n_ctx;
        if self.window.len() < n_ctx {
            return Ok(());
        }
        match self.wrap {
            WrapPolicy::Slide => Ok(()), // the ring overwrites in place
            WrapPolicy::Reprefill { .. } => {
                let keep = self.wrap.keep_for(n_ctx);
                self.window.drain(..self.window.len() - keep);
                for c in &mut self.caches {
                    c.clear();
                }
                // logits of the kept window are not needed — the caller
                // is about to decode the NEXT token
                m.extend_quiet(&self.window, 0, &mut self.caches)?;
                self.prefills += 1;
                Ok(())
            }
        }
    }
}

/// One decode step for many live sessions, coalesced into a single
/// skinny-GEMM batch (`tokens[i]` feeds `sessions[i]`). Wrap policies
/// are applied per session first, then all projections run as `[G, ·]`
/// GEMMs. Returns logits `[G, vocab]`; each row is bit-identical to
/// `sessions[i].decode_step(m, tokens[i])` run alone.
pub fn decode_step_batch(
    m: SessionModel<'_>,
    sessions: &mut [&mut SessionState],
    tokens: &[u32],
) -> Result<MatF32> {
    if sessions.is_empty() || sessions.len() != tokens.len() {
        bail!("{} sessions vs {} tokens", sessions.len(), tokens.len());
    }
    if sessions.iter().any(|s| s.window.is_empty()) {
        bail!("decode_step_batch before prefill");
    }
    for s in sessions.iter_mut() {
        s.ensure_room(m)?;
    }
    let n_ctx = m.gpt().cfg.n_ctx;
    let positions: Vec<usize> = sessions.iter().map(|s| s.next_pos(n_ctx)).collect();
    let mut cache_refs: Vec<&mut [KvCache]> =
        sessions.iter_mut().map(|s| s.caches.as_mut_slice()).collect();
    let logits = m.step(tokens, &positions, &mut cache_refs)?;
    drop(cache_refs);
    for (s, &t) in sessions.iter_mut().zip(tokens) {
        s.note(n_ctx, t);
    }
    Ok(logits)
}

/// Ergonomic single-session wrapper binding a [`SessionState`] to its
/// model — the API `examples/generate.rs` uses.
pub struct DecodeSession<'m> {
    model: SessionModel<'m>,
    pub state: SessionState,
}

impl<'m> DecodeSession<'m> {
    pub fn new(model: SessionModel<'m>, wrap: WrapPolicy) -> DecodeSession<'m> {
        DecodeSession { state: SessionState::new(&model.gpt().cfg, wrap), model }
    }

    pub fn prefill(&mut self, prompt: &[u32]) -> Result<Vec<f32>> {
        self.state.prefill(self.model, prompt)
    }

    pub fn decode_step(&mut self, token: u32) -> Result<Vec<f32>> {
        self.state.decode_step(self.model, token)
    }

    /// Prefill + decode `steps` tokens, selecting each with `sampler`;
    /// returns the generated ids. With a greedy sampler this IS
    /// [`DecodeSession::generate_greedy`].
    pub fn generate(
        &mut self,
        prompt: &[u32],
        steps: usize,
        sampler: &mut Sampler,
    ) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(steps);
        if steps == 0 {
            self.prefill(prompt)?;
            return Ok(out);
        }
        let mut next = sampler.sample(&self.prefill(prompt)?);
        for i in 0..steps {
            out.push(next);
            if i + 1 < steps {
                next = sampler.sample(&self.decode_step(next)?);
            }
        }
        Ok(out)
    }

    /// Prefill + greedy-decode `steps` tokens; returns the generated ids.
    pub fn generate_greedy(&mut self, prompt: &[u32], steps: usize) -> Result<Vec<u32>> {
        self.generate(prompt, steps, &mut Sampler::greedy())
    }
}

impl Gpt2Model {
    /// Open an incremental-decode session over this model.
    pub fn session(&self, wrap: WrapPolicy) -> DecodeSession<'_> {
        DecodeSession::new(SessionModel::Fp(self), wrap)
    }
}

impl QuantizedGpt2 {
    /// Open an incremental-decode session through the true-INT pipeline
    /// (row-independent session projection — see `quantized.rs` docs).
    pub fn session(&self, wrap: WrapPolicy) -> DecodeSession<'_> {
        DecodeSession::new(SessionModel::Int(self), wrap)
    }
}

/// Greedy sampling: index of the maximum logit (ties resolve to the
/// highest index — the `max_by`/`total_cmp` convention every caller in
/// this repo shares, so identical logits always yield identical tokens).
pub fn argmax(logits: &[f32]) -> u32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as u32)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::EngineSpec;

    fn tiny() -> Gpt2Model {
        Gpt2Model::test_model(2, 16, 2, 12, 32, 7)
    }

    fn toks(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = crate::data::prng::SplitMix64::new(seed);
        (0..n).map(|_| rng.next_below(32) as u32).collect()
    }

    #[test]
    fn session_matches_full_forward_fp() {
        let m = tiny();
        let prompt = toks(5, 1);
        let mut s = m.session(WrapPolicy::default());
        let mut logits = s.prefill(&prompt).unwrap();
        let mut ctx = prompt.clone();
        for step in 0..4u32 {
            let full = m.forward(&[ctx.clone()], None, None).unwrap();
            assert_eq!(logits, full.row(ctx.len() - 1).to_vec(), "step {step}");
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            ctx.push(next);
        }
    }

    #[test]
    fn session_matches_oracle_int_muxq() {
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let prompt = toks(6, 2);
        let mut s = q.session(WrapPolicy::default());
        let mut logits = s.prefill(&prompt).unwrap();
        let mut ctx = prompt.clone();
        for _ in 0..3 {
            let oracle = q.forward_logits_session(&[ctx.clone()]).unwrap();
            assert_eq!(logits, oracle.row(ctx.len() - 1).to_vec());
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            ctx.push(next);
        }
    }

    #[test]
    fn session_matches_oracle_int_llmint8() {
        // the new deployed operator reaches the session layer unchanged:
        // incremental decode must equal the row-independent full-forward
        // oracle bit for bit
        let q = QuantizedGpt2::new(tiny(), EngineSpec::llmint8());
        let prompt = toks(6, 12);
        let mut s = q.session(WrapPolicy::default());
        let mut logits = s.prefill(&prompt).unwrap();
        let mut ctx = prompt.clone();
        for _ in 0..3 {
            let oracle = q.forward_logits_session(&[ctx.clone()]).unwrap();
            assert_eq!(logits, oracle.row(ctx.len() - 1).to_vec());
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            ctx.push(next);
        }
    }

    #[test]
    fn reprefill_wrap_stays_exact_past_n_ctx() {
        // n_ctx = 12; generate far past it — every step's logits must be
        // a full forward of the session's live window
        let m = tiny();
        let mut s = m.session(WrapPolicy::default());
        let mut logits = s.prefill(&toks(8, 3)).unwrap();
        for _ in 0..20 {
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            let win = s.state.window().to_vec();
            assert!(win.len() <= 12);
            let full = m.forward(&[win.clone()], None, None).unwrap();
            assert_eq!(logits, full.row(win.len() - 1).to_vec());
        }
        assert!(s.state.prefills() > 1, "wrap must have re-prefilled");
    }

    #[test]
    fn slide_wrap_keeps_ring_at_n_ctx() {
        let m = tiny();
        let mut s = m.session(WrapPolicy::Slide);
        let mut logits = s.prefill(&toks(12, 4)).unwrap(); // full from the start
        for _ in 0..10 {
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            assert_eq!(s.state.context_len(), 12);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        assert_eq!(s.state.prefills(), 1, "slide never re-prefills");
    }

    #[test]
    fn batched_decode_bit_exact_vs_solo() {
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let m = SessionModel::Int(&q);
        let prompts = [toks(3, 5), toks(7, 6), toks(5, 7)];
        // solo runs
        let mut solo_logits = Vec::new();
        for p in &prompts {
            let mut s = SessionState::new(&q.fp.cfg, WrapPolicy::default());
            let first = argmax(&s.prefill(m, p).unwrap());
            solo_logits.push(s.decode_step(m, first).unwrap());
        }
        // batched run over the same three sessions
        let mut states: Vec<SessionState> =
            prompts.iter().map(|_| SessionState::new(&q.fp.cfg, WrapPolicy::default())).collect();
        let mut tokens = Vec::new();
        for (st, p) in states.iter_mut().zip(&prompts) {
            tokens.push(argmax(&st.prefill(m, p).unwrap()));
        }
        let mut refs: Vec<&mut SessionState> = states.iter_mut().collect();
        let batch = decode_step_batch(m, &mut refs, &tokens).unwrap();
        for (i, solo) in solo_logits.iter().enumerate() {
            assert_eq!(batch.row(i), &solo[..], "session {i}");
        }
    }

    #[test]
    fn long_prompt_truncates_to_n_ctx() {
        let m = tiny();
        let mut s = m.session(WrapPolicy::default());
        let long = toks(30, 8);
        s.prefill(&long).unwrap();
        assert_eq!(s.state.context_len(), 12);
        assert_eq!(s.state.window(), &long[18..]);
    }

    #[test]
    fn misuse_is_rejected() {
        let m = tiny();
        let mut s = m.session(WrapPolicy::default());
        assert!(s.decode_step(0).is_err(), "step before prefill");
        assert!(s.prefill(&[]).is_err(), "empty prompt");
        let mut a = SessionState::new(&m.cfg, WrapPolicy::default());
        a.prefill(SessionModel::Fp(&m), &[1, 2]).unwrap();
        let mut refs = [&mut a];
        assert!(decode_step_batch(SessionModel::Fp(&m), &mut refs, &[1, 2]).is_err());
    }

    #[test]
    fn argmax_last_max_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 2);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn greedy_sampler_is_argmax_and_consumes_no_rng() {
        let logits = [0.1f32, 2.5, -1.0, 2.5];
        let mut s = Sampler::greedy();
        assert!(s.is_greedy());
        for _ in 0..3 {
            assert_eq!(s.sample(&logits), argmax(&logits));
        }
        // top_k == 1 degenerates to greedy too
        let mut s1 = Sampler::new(1.0, 1, 42);
        assert!(s1.is_greedy());
        assert_eq!(s1.sample(&logits), argmax(&logits));
    }

    #[test]
    fn sampler_is_seed_deterministic_and_in_top_k() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let draw = |seed: u64| -> Vec<u32> {
            let mut s = Sampler::new(0.8, 4, seed);
            (0..20).map(|_| s.sample(&logits)).collect()
        };
        assert_eq!(draw(7), draw(7), "same seed, same stream");
        assert_ne!(draw(7), draw(8), "different seed, different stream");
        // every draw lands in the true top-4
        let mut order: Vec<usize> = (0..logits.len()).collect();
        order.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        let top4: Vec<u32> = order[..4].iter().map(|&i| i as u32).collect();
        for t in draw(7) {
            assert!(top4.contains(&t), "{t} outside top-k");
        }
    }

    #[test]
    fn sampler_temperature_sharpens_toward_argmax() {
        // at tiny temperature the softmax collapses onto the max logit
        let logits = [0.0f32, 1.0, 5.0, 2.0];
        let mut s = Sampler::new(0.05, 0, 11);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 2);
        }
        // at high temperature other tokens appear
        let mut hot = Sampler::new(50.0, 0, 13);
        let draws: Vec<u32> = (0..200).map(|_| hot.sample(&logits)).collect();
        assert!(draws.iter().any(|&t| t != 2), "high T must diversify");
    }

    #[test]
    fn sampled_generation_reproducible_and_session_exact() {
        // a sampled generation replays exactly given the same seed, and
        // its tokens stay a valid decode (session == oracle property is
        // decoupled from HOW the next token is chosen)
        let m = tiny();
        let prompt = toks(5, 21);
        let gen = |seed: u64| {
            let mut s = m.session(WrapPolicy::default());
            s.generate(&prompt, 8, &mut Sampler::new(0.9, 5, seed)).unwrap()
        };
        assert_eq!(gen(3), gen(3));
        // greedy generate == generate_greedy
        let mut s1 = m.session(WrapPolicy::default());
        let mut s2 = m.session(WrapPolicy::default());
        let a = s1.generate(&prompt, 6, &mut Sampler::greedy()).unwrap();
        let b = s2.generate_greedy(&prompt, 6).unwrap();
        assert_eq!(a, b);
    }
}
