//! Decode sessions: the stateful layer between the incremental model
//! forward (`model.rs`: [`KvCache`], `forward_session`,
//! `decode_step_sessions`) and the serving coordinator
//! (`coordinator::generation`). One [`DecodeSession`] owns one
//! sequence's per-layer caches, its live token window and its position
//! counter; [`decode_step_batch`] advances many sessions in one fused
//! skinny GEMM step (continuous batching) with per-session results
//! bit-identical to stepping each alone.
//!
//! The model behind a session is either the plain f32 forward or ANY
//! deployed [`QuantizedGpt2`] — the operator API (`quant::linear`) means
//! naive, MUXQ, LLM.int8() and their SmoothQuant compositions all decode
//! through the same code path here.
//!
//! Token selection is a [`Sampler`]: greedy argmax by default, or
//! seeded temperature / top-k sampling (`SplitMix64`-driven, so a (seed,
//! prompt, model) triple reproduces its stream exactly).
//!
//! # Context-overflow (wrap) policies
//!
//! GPT-2's absolute position embeddings mean a ring cache cannot keep
//! attending exactly once generation passes `n_ctx` — cached K/V were
//! computed under their admission positions. Two policies:
//!
//! * [`WrapPolicy::Reprefill`] (default): when the cache fills, drop the
//!   oldest tokens and re-prefill the kept window with fresh positions.
//!   Logits stay **bit-exact** against a full forward over the session's
//!   live window at every step — the oracle property the proptests pin —
//!   at the amortized cost of one O(keep²) prefill per `n_ctx - keep`
//!   generated tokens (still O(context) per token).
//! * [`WrapPolicy::Slide`]: StreamingLLM-style infinite generation — the
//!   ring overwrites the oldest entry in place and new tokens clamp to
//!   the last position index. O(1) per step forever, but approximate:
//!   kept K/V retain their admission-time positions (and were computed
//!   attending over context that has since been evicted), so there is no
//!   full-forward oracle past the wrap; the ring mechanics themselves
//!   are pinned against a deque reference in `tests/decode_session.rs`.

use super::kvpool::{KvPool, PrefixCache};
use super::model::{Gpt2Config, Gpt2Model, KvCache};
use super::quantized::QuantizedGpt2;
use crate::data::prng::SplitMix64;
use crate::quant::MatF32;
use anyhow::{bail, Result};

/// What to do when a session's context window is full (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WrapPolicy {
    /// Drop the oldest tokens and re-prefill the last `keep` with fresh
    /// positions (exact). `keep == 0` selects the default window,
    /// `3/4 · n_ctx` rounded down but never below 1; an explicit `keep`
    /// is clamped into `[1, n_ctx - 1]` — silently, so a `keep >= n_ctx`
    /// retains `n_ctx - 1` tokens rather than failing. See
    /// [`WrapPolicy::keep_for`] for the exact rule (including the
    /// degenerate `n_ctx <= 1` edge).
    Reprefill { keep: usize },
    /// Ring-overwrite the oldest entry, clamp positions at `n_ctx - 1`
    /// (approximate, O(1) per step).
    Slide,
}

impl Default for WrapPolicy {
    fn default() -> Self {
        WrapPolicy::Reprefill { keep: 0 }
    }
}

impl WrapPolicy {
    /// Tokens retained across a wrap of an `n_ctx`-sized window.
    ///
    /// * `Reprefill { keep: 0 }` → `max(n_ctx * 3 / 4, 1)` (the default
    ///   window; the `max` matters only for `n_ctx <= 1`).
    /// * `Reprefill { keep }` → `keep` clamped into `[1, n_ctx - 1]`.
    ///   The clamp is silent — this is a best-effort policy knob, not a
    ///   validated config.
    /// * `Slide` → `n_ctx` (nothing is dropped; the ring overwrites).
    ///
    /// Degenerate edge: at `n_ctx <= 1` both Reprefill arms resolve to
    /// 1, which *exceeds* `n_ctx - 1` (saturating to 0 for `n_ctx == 0`)
    /// — there is no way to keep a nonempty strict prefix of a ≤1-token
    /// window. Callers that must leave room for new tokens apply their
    /// own cap (`SessionState::ensure_room_for` takes
    /// `min(keep_for(n_ctx), n_ctx - need)`), which is also what makes
    /// the value usable at all in that edge.
    pub fn keep_for(self, n_ctx: usize) -> usize {
        match self {
            WrapPolicy::Reprefill { keep: 0 } => (n_ctx * 3 / 4).max(1),
            WrapPolicy::Reprefill { keep } => keep.min(n_ctx.saturating_sub(1)).max(1),
            WrapPolicy::Slide => n_ctx,
        }
    }
}

/// The model a session runs against: plain f32, or a deployed
/// [`QuantizedGpt2`] (any method) through its row-independent session
/// projection.
#[derive(Clone, Copy)]
pub enum SessionModel<'m> {
    Fp(&'m Gpt2Model),
    Int(&'m QuantizedGpt2),
}

impl<'m> SessionModel<'m> {
    pub fn gpt(&self) -> &'m Gpt2Model {
        match *self {
            SessionModel::Fp(m) => m,
            SessionModel::Int(q) => &q.fp,
        }
    }

    /// Prefill-shaped extend: all rows land in the caches, only the LAST
    /// row's logits are computed (the next-token distribution — the only
    /// row a prefill ever reads; the all-rows head GEMM the old path
    /// paid grows with prompt length for no benefit).
    fn extend_last(&self, tokens: &[u32], pos0: usize, caches: &mut [KvCache]) -> Result<Vec<f32>> {
        match self {
            SessionModel::Fp(m) => m.forward_session_last_logits(tokens, pos0, caches, None),
            SessionModel::Int(q) => {
                let mut f = |x: &MatF32, site: &'static str, li: usize| q.proj_session(x, site, li);
                q.fp.forward_session_last_logits(tokens, pos0, caches, Some(&mut f))
            }
        }
    }

    /// Verify-shaped extend: all rows land in the caches AND every row's
    /// logits come back — the speculative k+1-row scoring pass. Refuses
    /// to overflow the ring (`forward_session` bails), so callers make
    /// room first ([`SessionState::ensure_room_for`]).
    fn extend_scored(
        &self,
        tokens: &[u32],
        pos0: usize,
        caches: &mut [KvCache],
    ) -> Result<MatF32> {
        match self {
            SessionModel::Fp(m) => m.forward_session(tokens, pos0, caches, None),
            SessionModel::Int(q) => {
                let mut f = |x: &MatF32, site: &'static str, li: usize| q.proj_session(x, site, li);
                q.fp.forward_session(tokens, pos0, caches, Some(&mut f))
            }
        }
    }

    /// `extend` without computing logits — the wrap re-prefill discards
    /// them, and the tied-head GEMM they cost is the biggest in the pass.
    fn extend_quiet(&self, tokens: &[u32], pos0: usize, caches: &mut [KvCache]) -> Result<()> {
        match self {
            SessionModel::Fp(m) => m.forward_session_no_logits(tokens, pos0, caches, None),
            SessionModel::Int(q) => {
                let mut f = |x: &MatF32, site: &'static str, li: usize| q.proj_session(x, site, li);
                q.fp.forward_session_no_logits(tokens, pos0, caches, Some(&mut f))
            }
        }
    }

    fn step(
        &self,
        tokens: &[u32],
        positions: &[usize],
        caches: &mut [&mut [KvCache]],
    ) -> Result<MatF32> {
        match self {
            SessionModel::Fp(m) => m.decode_step_sessions(tokens, positions, caches, None),
            SessionModel::Int(q) => {
                let mut f = |x: &MatF32, site: &'static str, li: usize| q.proj_session(x, site, li);
                q.fp.decode_step_sessions(tokens, positions, caches, Some(&mut f))
            }
        }
    }
}

// --------------------------------------------------------------- sampling

/// Token selection over a logits row: greedy argmax, or seeded
/// temperature / top-k / top-p sampling with optional repetition
/// penalty. Deterministic — the internal `SplitMix64` stream makes
/// (seed, logits sequence) → tokens a pure function, so sampled
/// generations are replayable and the server can be tested bit-for-bit
/// against solo sessions.
///
/// Speculative decoding needs the sampler split into its two halves:
/// [`Sampler::probs_in_context`] exposes the exact distribution a
/// [`Sampler::sample_in_context`] call would draw from (consuming no
/// randomness), and [`Sampler::draw_from`] / [`Sampler::next_uniform`]
/// consume the stream — so the rejection rule can compare target p
/// against draft q and still draw from the identical RNG sequence.
#[derive(Debug, Clone)]
pub struct Sampler {
    /// softmax temperature; `<= 0` means greedy argmax
    pub temperature: f32,
    /// keep only the k highest logits before sampling; `0` = all
    pub top_k: usize,
    /// nucleus cut: keep the smallest prefix of the probability-sorted
    /// candidates whose mass reaches `top_p`; `>= 1.0` = off
    pub top_p: f32,
    /// divide positive / multiply negative logits of tokens already in
    /// the context by this factor (the CTRL / HF convention); `1.0` = off
    pub repetition_penalty: f32,
    /// the seed this sampler was built from — kept so [`Sampler::fork`]
    /// can derive decorrelated child streams
    seed: u64,
    rng: SplitMix64,
    /// reusable candidate-index / weight buffers — this runs once per
    /// decoded token on the serving hot path, so no per-call allocation
    /// and no full-vocab sort unless top-p asks for one
    order: Vec<usize>,
    weights: Vec<f32>,
    /// scratch row for repetition-penalized logits
    penalized: Vec<f32>,
}

impl Sampler {
    /// Greedy argmax (the default serving mode; no randomness consumed).
    pub fn greedy() -> Sampler {
        Sampler::new(0.0, 0, 0)
    }

    /// Seeded temperature / top-k sampler (top-p off, no penalty).
    pub fn new(temperature: f32, top_k: usize, seed: u64) -> Sampler {
        Sampler {
            temperature,
            top_k,
            top_p: 1.0,
            repetition_penalty: 1.0,
            seed,
            rng: SplitMix64::new(seed),
            order: Vec::new(),
            weights: Vec::new(),
            penalized: Vec::new(),
        }
    }

    /// Builder: nucleus (top-p) cut. Values `>= 1.0` disable it.
    pub fn with_top_p(mut self, top_p: f32) -> Sampler {
        self.top_p = top_p;
        self
    }

    /// Builder: repetition penalty. `1.0` disables it.
    pub fn with_repetition_penalty(mut self, penalty: f32) -> Sampler {
        self.repetition_penalty = penalty;
        self
    }

    /// A sampler with the same parameters but an independent stream
    /// derived from (this seed, `salt`) — how a speculative session gives
    /// its draft a decorrelated-but-reproducible RNG.
    pub fn fork(&self, salt: u64) -> Sampler {
        let mut s = Sampler::new(self.temperature, self.top_k, crate::data::prng::mix(&[self.seed, salt]));
        s.top_p = self.top_p;
        s.repetition_penalty = self.repetition_penalty;
        s
    }

    /// Greedy when the parameters make sampling degenerate: zero
    /// temperature, or a top-k of exactly one.
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0 || self.top_k == 1
    }

    /// One raw uniform from the sampler's stream — the rejection-sampling
    /// accept/reject coin for speculative decoding.
    pub fn next_uniform(&mut self) -> f64 {
        self.rng.next_f64()
    }

    /// [`Sampler::sample_in_context`] with no context (no penalty applied).
    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        self.sample_in_context(logits, &[])
    }

    /// Pick the next token for one logits row, `history` being the live
    /// context the repetition penalty reads. Greedy consumes no
    /// randomness (ties resolve like [`argmax`]); otherwise one uniform
    /// draw over the temperature-softmaxed top-k/top-p candidates. O(V)
    /// per call (`select_nth` for the top-k cut; a candidate sort only
    /// when top-p is on), zero steady-state allocation.
    pub fn sample_in_context(&mut self, logits: &[f32], history: &[u32]) -> u32 {
        let buf = std::mem::take(&mut self.penalized);
        let buf = self.penalize(logits, history, buf);
        let row: &[f32] = if buf.is_empty() { logits } else { &buf };
        let tok = if self.is_greedy() {
            argmax(row)
        } else {
            self.dist(row);
            let total: f32 = self.weights.iter().sum();
            let u = self.next_uniform() as f32 * total;
            self.pick(u)
        };
        self.penalized = buf;
        tok
    }

    /// The FULL-VOCAB probability vector `sample_in_context` would draw
    /// from, written into `out` (zeros outside the candidate set; a point
    /// mass at the argmax when greedy). Consumes no randomness — this is
    /// the p / q the speculative acceptance rule compares.
    pub fn probs_in_context(&mut self, logits: &[f32], history: &[u32], out: &mut Vec<f32>) {
        let buf = std::mem::take(&mut self.penalized);
        let buf = self.penalize(logits, history, buf);
        let row: &[f32] = if buf.is_empty() { logits } else { &buf };
        out.clear();
        out.resize(logits.len(), 0.0);
        if self.is_greedy() {
            out[argmax(row) as usize] = 1.0;
        } else {
            self.dist(row);
            let total: f32 = self.weights.iter().sum();
            for (&i, &w) in self.order.iter().zip(&self.weights) {
                out[i] = w / total;
            }
        }
        self.penalized = buf;
    }

    /// One seeded draw from an explicit (normalized) probability vector —
    /// the speculative correction draw from `max(0, p - q)`. Consumes one
    /// uniform. Falls back to the vector's argmax on numerical tails.
    pub fn draw_from(&mut self, probs: &[f32]) -> u32 {
        let mut u = self.next_uniform();
        let mut last_live = None;
        for (i, &p) in probs.iter().enumerate() {
            if p <= 0.0 {
                continue;
            }
            last_live = Some(i as u32);
            u -= p as f64;
            if u <= 0.0 {
                return i as u32;
            }
        }
        last_live.unwrap_or_else(|| argmax(probs))
    }

    /// Repetition penalty into the scratch `buf` (CTRL convention:
    /// positive logits divided, negative multiplied — both push the
    /// token down). Returns `buf` empty when the penalty is off so
    /// callers can use the raw row without a copy.
    fn penalize(&self, logits: &[f32], history: &[u32], mut buf: Vec<f32>) -> Vec<f32> {
        buf.clear();
        if self.repetition_penalty == 1.0 || history.is_empty() {
            return buf;
        }
        buf.extend_from_slice(logits);
        let rp = self.repetition_penalty;
        for &t in history {
            if let Some(l) = buf.get_mut(t as usize) {
                *l = if *l > 0.0 { *l / rp } else { *l * rp };
            }
        }
        buf
    }

    /// Fill `order` / `weights` with the candidate set and its
    /// (unnormalized) softmax weights: top-k partial selection, then the
    /// nucleus cut if top-p is on. Both `sample_in_context` and
    /// `probs_in_context` route through this, so the drawn and the
    /// reported distributions agree bit-for-bit.
    fn dist(&mut self, logits: &[f32]) {
        let v = logits.len();
        let k = if self.top_k == 0 { v } else { self.top_k.min(v) };
        self.order.clear();
        self.order.extend(0..v);
        if k < v {
            // partial selection: top-k candidates land (unordered) in
            // the first k slots
            let _ = self
                .order
                .select_nth_unstable_by(k - 1, |&a, &b| logits[b].total_cmp(&logits[a]));
            self.order.truncate(k);
        }
        if self.top_p < 1.0 {
            // nucleus needs the candidates probability-sorted; ties
            // break on index so the cut is deterministic
            self.order.sort_unstable_by(|&a, &b| {
                logits[b].total_cmp(&logits[a]).then(a.cmp(&b))
            });
        }
        // temperature softmax with max-subtraction for stability (the
        // global max is always among the candidates)
        let max =
            self.order.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
        let inv_t = 1.0 / self.temperature;
        self.weights.clear();
        self.weights.extend(self.order.iter().map(|&i| ((logits[i] - max) * inv_t).exp()));
        if self.top_p < 1.0 {
            let total: f32 = self.weights.iter().sum();
            let target = self.top_p * total;
            let mut cum = 0.0f32;
            let mut keep = self.weights.len();
            for (n, &w) in self.weights.iter().enumerate() {
                cum += w;
                if cum >= target {
                    keep = n + 1;
                    break;
                }
            }
            self.order.truncate(keep);
            self.weights.truncate(keep);
        }
    }

    /// Walk `weights` with a pre-scaled uniform; numerical tail falls
    /// back to the last candidate.
    fn pick(&self, mut u: f32) -> u32 {
        for (w, &i) in self.weights.iter().zip(&self.order) {
            u -= w;
            if u <= 0.0 {
                return i as u32;
            }
        }
        self.order[self.order.len() - 1] as u32
    }
}

impl Default for Sampler {
    fn default() -> Self {
        Sampler::greedy()
    }
}

/// Per-sequence decode state, model-borrowing-free so a serving loop can
/// own many of these alongside the model (see [`DecodeSession`] for the
/// ergonomic borrowed wrapper).
pub struct SessionState {
    caches: Vec<KvCache>,
    /// tokens whose K/V are live, oldest first (== the effective context)
    window: Vec<u32>,
    wrap: WrapPolicy,
    /// prefill passes run (1 after `prefill`, +1 per Reprefill wrap)
    prefills: u64,
}

impl SessionState {
    pub fn new(cfg: &Gpt2Config, wrap: WrapPolicy) -> SessionState {
        SessionState {
            caches: (0..cfg.n_layer).map(|_| KvCache::new(cfg.n_ctx, cfg.d_model)).collect(),
            window: Vec::new(),
            wrap,
            prefills: 0,
        }
    }

    /// A session whose per-layer caches draw pages from a shared
    /// [`KvPool`] instead of owning `[n_ctx, d_model]` rings — same
    /// decode semantics (the proptests pin bit-exactness), but storage
    /// is priced per page and common prefixes can be shared
    /// copy-on-write across sessions.
    pub fn new_paged(cfg: &Gpt2Config, wrap: WrapPolicy, pool: &KvPool) -> SessionState {
        assert_eq!(pool.d_model(), cfg.d_model, "kv pool row width does not match the model");
        SessionState {
            caches: (0..cfg.n_layer).map(|_| KvCache::paged(pool, cfg.n_ctx)).collect(),
            window: Vec::new(),
            wrap,
            prefills: 0,
        }
    }

    /// Whether this session's caches are pool-backed.
    pub fn is_paged(&self) -> bool {
        self.caches.first().map(|c| c.is_paged()).unwrap_or(false)
    }

    /// The live context: every token whose K/V the next step attends to.
    /// After a `decode_step` the stepped token is included, so under the
    /// (default, exact) Reprefill policy the returned logits are always a
    /// full forward of exactly `window()`.
    pub fn window(&self) -> &[u32] {
        &self.window
    }

    pub fn context_len(&self) -> usize {
        self.window.len()
    }

    pub fn prefills(&self) -> u64 {
        self.prefills
    }

    /// Process the prompt at its TRUE length (no padding rows — the old
    /// fixed-shape generate path left-padded with token 0 and attended
    /// over the pads, skewing short-prompt logits). Prompts longer than
    /// `n_ctx` keep their last `n_ctx` tokens. Returns the last row's
    /// logits (the next-token distribution) — the head GEMM runs for
    /// that row ONLY (`forward_session_last_logits`), cutting prefill
    /// cost by the prompt length at real vocab sizes.
    pub fn prefill(&mut self, m: SessionModel<'_>, prompt: &[u32]) -> Result<Vec<f32>> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        let n_ctx = m.gpt().cfg.n_ctx;
        let used = &prompt[prompt.len().saturating_sub(n_ctx)..];
        for c in &mut self.caches {
            c.clear();
        }
        self.window.clear();
        let logits = m.extend_last(used, 0, &mut self.caches)?;
        self.window.extend_from_slice(used);
        self.prefills += 1;
        Ok(logits)
    }

    /// Prefill through a shared [`PrefixCache`]: if a registered prefix
    /// matches this prompt, seed its pages into the caches (zero copies,
    /// copy-on-write from here on) and run the forward only over the
    /// uncached tail; afterwards, register this prompt's own page-aligned
    /// prefix for future sessions. Falls back to a plain
    /// [`SessionState::prefill`] on ring-backed caches. Bit-exact either
    /// way: K/V rows are deterministic functions of the causal token
    /// prefix from position 0, so a seeded page equals recomputation.
    pub fn prefill_cached(
        &mut self,
        m: SessionModel<'_>,
        prompt: &[u32],
        pc: &mut PrefixCache,
    ) -> Result<Vec<f32>> {
        if !self.is_paged() {
            return self.prefill(m, prompt);
        }
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        let n_ctx = m.gpt().cfg.n_ctx;
        let used = &prompt[prompt.len().saturating_sub(n_ctx)..];
        for c in &mut self.caches {
            c.clear();
        }
        self.window.clear();
        let hit = pc.lookup(used);
        let logits = match hit {
            Some(h) => {
                debug_assert!(h.rows < used.len(), "lookup must leave a tail to prefill");
                for (c, pages) in self.caches.iter_mut().zip(&h.pages) {
                    c.seed_prefix(pages, h.rows)?;
                }
                m.extend_last(&used[h.rows..], h.rows, &mut self.caches)?
            }
            None => m.extend_last(used, 0, &mut self.caches)?,
        };
        self.window.extend_from_slice(used);
        self.prefills += 1;
        // offer this prompt's page-aligned prefix to future sessions
        // (register() drops duplicates and releases their references)
        let r = pc.page_rows();
        let t = used.len() / r * r;
        if t > 0 {
            if let Some(pages) =
                self.caches.iter().map(|c| c.prefix_pages(t)).collect::<Option<Vec<_>>>()
            {
                pc.register(used[..t].to_vec(), pages);
            }
        }
        Ok(logits)
    }

    /// Pages this session's next `need`-token extend will demand from
    /// the pool, worst case (0 for ring sessions) — the scheduler's
    /// pressure input. If the extend will trigger a Reprefill wrap, the
    /// wrap's full re-prefill footprint is priced (conservatively
    /// ignoring the pages the preceding clear frees).
    pub fn page_demand(&self, n_ctx: usize, need: usize) -> usize {
        if !self.is_paged() {
            return 0;
        }
        let wraps = self.window.len() + need > n_ctx
            && matches!(self.wrap, WrapPolicy::Reprefill { .. })
            && need < n_ctx;
        if wraps {
            let keep = self.wrap.keep_for(n_ctx).min(n_ctx - need);
            self.caches.iter().map(|c| c.pages_for(keep + need)).sum()
        } else {
            self.caches.iter().map(|c| c.pages_needed(need)).sum()
        }
    }

    /// Pages this session holds that are shared with another owner
    /// (summed over layers; 0 for ring sessions).
    pub fn shared_pages(&self) -> usize {
        self.caches.iter().map(|c| c.shared_pages()).sum()
    }

    /// Append one token and return its next-token logits — O(context)
    /// work, unlike re-running the full forward. Must follow `prefill`.
    pub fn decode_step(&mut self, m: SessionModel<'_>, token: u32) -> Result<Vec<f32>> {
        if self.window.is_empty() {
            bail!("decode_step before prefill");
        }
        self.ensure_room(m)?;
        let pos = self.next_pos(m.gpt().cfg.n_ctx);
        let logits = m.step(&[token], &[pos], &mut [self.caches.as_mut_slice()])?;
        self.note(m.gpt().cfg.n_ctx, token);
        Ok(logits.data)
    }

    /// Append `tokens` in one pass and return ALL their next-token
    /// logits (`[len, vocab]`, row i scoring the context up to and
    /// including `tokens[i]`) — the speculative verify step: the target
    /// scores the drafted continuation in one skinny-M batched forward
    /// instead of `len` sequential steps. No implicit wrap: callers run
    /// [`SessionState::ensure_room_for`] first; overflowing extends bail.
    pub fn extend_scored(&mut self, m: SessionModel<'_>, tokens: &[u32]) -> Result<MatF32> {
        if self.window.is_empty() {
            bail!("extend_scored before prefill");
        }
        let logits = m.extend_scored(tokens, self.window.len(), &mut self.caches)?;
        self.window.extend_from_slice(tokens);
        Ok(logits)
    }

    /// Append `tokens` in one pass and return the LAST row's logits —
    /// the draft session's catch-up extend (tokens the target accepted
    /// that the draft has not yet cached). Same no-implicit-wrap
    /// contract as [`SessionState::extend_scored`].
    pub fn extend_last(&mut self, m: SessionModel<'_>, tokens: &[u32]) -> Result<Vec<f32>> {
        if self.window.is_empty() {
            bail!("extend_last before prefill");
        }
        let logits = m.extend_last(tokens, self.window.len(), &mut self.caches)?;
        self.window.extend_from_slice(tokens);
        Ok(logits)
    }

    /// Roll the session back to its first `len` tokens: the speculative
    /// rejection path. Drops the NEWEST window entries and K/V rows
    /// ([`KvCache::truncate`]); the retained prefix reads back
    /// bit-identical, as if the rolled-back tokens were never decoded.
    pub fn truncate_to(&mut self, len: usize) {
        self.window.truncate(len);
        for c in &mut self.caches {
            c.truncate(len);
        }
    }

    /// The per-layer K/V caches — read-only, for state-equivalence tests
    /// (rollback must leave ring contents equal to a never-extended
    /// oracle's).
    pub fn caches(&self) -> &[KvCache] {
        &self.caches
    }

    /// This session's wrap policy (the server validates speculative
    /// requests against it — spec rollback needs the exact policy).
    pub fn wrap_policy(&self) -> WrapPolicy {
        self.wrap
    }

    fn next_pos(&self, n_ctx: usize) -> usize {
        self.window.len().min(n_ctx - 1)
    }

    fn note(&mut self, n_ctx: usize, token: u32) {
        self.window.push(token);
        if self.window.len() > n_ctx {
            // Slide evicted the oldest K/V in the ring; mirror it here
            self.window.remove(0);
        }
    }

    /// Apply the wrap policy if the cache is full (called before a step).
    fn ensure_room(&mut self, m: SessionModel<'_>) -> Result<()> {
        self.ensure_room_for(m, 1)
    }

    /// Make room for a `need`-token extend, applying the wrap policy
    /// early if the window plus `need` would overflow the ring. A
    /// speculative round calls this with `k + 1` before the verify
    /// extend; `need == 1` is the plain decode-step path. Reprefill's
    /// kept window shrinks below its configured `keep` when necessary so
    /// the extend always fits; Slide can only absorb one token per step
    /// (ring overwrite), so multi-token needs are rejected there.
    pub fn ensure_room_for(&mut self, m: SessionModel<'_>, need: usize) -> Result<()> {
        let n_ctx = m.gpt().cfg.n_ctx;
        if need >= n_ctx {
            bail!("{need}-token extend cannot fit n_ctx {n_ctx}");
        }
        if self.window.len() + need <= n_ctx {
            return Ok(());
        }
        match self.wrap {
            WrapPolicy::Slide => {
                if need > 1 {
                    bail!("Slide wrap cannot make room for a {need}-token extend");
                }
                Ok(()) // the ring overwrites in place
            }
            WrapPolicy::Reprefill { .. } => {
                let keep = self.wrap.keep_for(n_ctx).min(n_ctx - need);
                self.window.drain(..self.window.len() - keep);
                for c in &mut self.caches {
                    c.clear();
                }
                // logits of the kept window are not needed — the caller
                // is about to decode the NEXT token(s)
                m.extend_quiet(&self.window, 0, &mut self.caches)?;
                self.prefills += 1;
                Ok(())
            }
        }
    }
}

/// One decode step for many live sessions, coalesced into a single
/// skinny-GEMM batch (`tokens[i]` feeds `sessions[i]`). Wrap policies
/// are applied per session first, then all projections run as `[G, ·]`
/// GEMMs. Returns logits `[G, vocab]`; each row is bit-identical to
/// `sessions[i].decode_step(m, tokens[i])` run alone.
pub fn decode_step_batch(
    m: SessionModel<'_>,
    sessions: &mut [&mut SessionState],
    tokens: &[u32],
) -> Result<MatF32> {
    if sessions.is_empty() || sessions.len() != tokens.len() {
        bail!("{} sessions vs {} tokens", sessions.len(), tokens.len());
    }
    if sessions.iter().any(|s| s.window.is_empty()) {
        bail!("decode_step_batch before prefill");
    }
    for s in sessions.iter_mut() {
        s.ensure_room(m)?;
    }
    let n_ctx = m.gpt().cfg.n_ctx;
    let positions: Vec<usize> = sessions.iter().map(|s| s.next_pos(n_ctx)).collect();
    let mut cache_refs: Vec<&mut [KvCache]> =
        sessions.iter_mut().map(|s| s.caches.as_mut_slice()).collect();
    let logits = m.step(tokens, &positions, &mut cache_refs)?;
    drop(cache_refs);
    for (s, &t) in sessions.iter_mut().zip(tokens) {
        s.note(n_ctx, t);
    }
    Ok(logits)
}

/// Ergonomic single-session wrapper binding a [`SessionState`] to its
/// model — the API `examples/generate.rs` uses.
pub struct DecodeSession<'m> {
    model: SessionModel<'m>,
    pub state: SessionState,
}

impl<'m> DecodeSession<'m> {
    pub fn new(model: SessionModel<'m>, wrap: WrapPolicy) -> DecodeSession<'m> {
        DecodeSession { state: SessionState::new(&model.gpt().cfg, wrap), model }
    }

    /// A session with pool-backed (paged) KV caches.
    pub fn new_paged(model: SessionModel<'m>, wrap: WrapPolicy, pool: &KvPool) -> DecodeSession<'m> {
        DecodeSession { state: SessionState::new_paged(&model.gpt().cfg, wrap, pool), model }
    }

    pub fn prefill(&mut self, prompt: &[u32]) -> Result<Vec<f32>> {
        self.state.prefill(self.model, prompt)
    }

    pub fn decode_step(&mut self, token: u32) -> Result<Vec<f32>> {
        self.state.decode_step(self.model, token)
    }

    /// Prefill + decode `steps` tokens, selecting each with `sampler`;
    /// returns the generated ids. With a greedy sampler this IS
    /// [`DecodeSession::generate_greedy`].
    pub fn generate(
        &mut self,
        prompt: &[u32],
        steps: usize,
        sampler: &mut Sampler,
    ) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(steps);
        if steps == 0 {
            self.prefill(prompt)?;
            return Ok(out);
        }
        // selection reads the live window so the repetition penalty sees
        // exactly the context the logits were computed over
        let logits = self.prefill(prompt)?;
        let mut next = sampler.sample_in_context(&logits, self.state.window());
        for i in 0..steps {
            out.push(next);
            if i + 1 < steps {
                let logits = self.decode_step(next)?;
                next = sampler.sample_in_context(&logits, self.state.window());
            }
        }
        Ok(out)
    }

    /// Prefill + greedy-decode `steps` tokens; returns the generated ids.
    pub fn generate_greedy(&mut self, prompt: &[u32], steps: usize) -> Result<Vec<u32>> {
        self.generate(prompt, steps, &mut Sampler::greedy())
    }
}

impl Gpt2Model {
    /// Open an incremental-decode session over this model.
    pub fn session(&self, wrap: WrapPolicy) -> DecodeSession<'_> {
        DecodeSession::new(SessionModel::Fp(self), wrap)
    }

    /// Open a session whose KV caches draw pages from `pool`.
    pub fn session_paged(&self, wrap: WrapPolicy, pool: &KvPool) -> DecodeSession<'_> {
        DecodeSession::new_paged(SessionModel::Fp(self), wrap, pool)
    }
}

impl QuantizedGpt2 {
    /// Open an incremental-decode session through the true-INT pipeline
    /// (row-independent session projection — see `quantized.rs` docs).
    pub fn session(&self, wrap: WrapPolicy) -> DecodeSession<'_> {
        DecodeSession::new(SessionModel::Int(self), wrap)
    }

    /// Open a true-INT session whose KV caches draw pages from `pool`.
    pub fn session_paged(&self, wrap: WrapPolicy, pool: &KvPool) -> DecodeSession<'_> {
        DecodeSession::new_paged(SessionModel::Int(self), wrap, pool)
    }
}

/// Greedy sampling: index of the maximum logit (ties resolve to the
/// highest index — the `max_by`/`total_cmp` convention every caller in
/// this repo shares, so identical logits always yield identical tokens).
pub fn argmax(logits: &[f32]) -> u32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as u32)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::EngineSpec;

    fn tiny() -> Gpt2Model {
        Gpt2Model::test_model(2, 16, 2, 12, 32, 7)
    }

    fn toks(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = crate::data::prng::SplitMix64::new(seed);
        (0..n).map(|_| rng.next_below(32) as u32).collect()
    }

    #[test]
    fn keep_for_default_is_three_quarters_floor_one() {
        assert_eq!(WrapPolicy::Reprefill { keep: 0 }.keep_for(16), 12);
        assert_eq!(WrapPolicy::Reprefill { keep: 0 }.keep_for(5), 3);
        // the max(1) floor only matters at degenerate contexts
        assert_eq!(WrapPolicy::Reprefill { keep: 0 }.keep_for(1), 1);
    }

    #[test]
    fn keep_for_clamps_explicit_keep_silently() {
        // in range: passes through
        assert_eq!(WrapPolicy::Reprefill { keep: 5 }.keep_for(16), 5);
        // too big: clamped to n_ctx - 1, not an error
        assert_eq!(WrapPolicy::Reprefill { keep: 99 }.keep_for(16), 15);
        assert_eq!(WrapPolicy::Reprefill { keep: 16 }.keep_for(16), 15);
        // Slide keeps everything (the ring overwrites)
        assert_eq!(WrapPolicy::Slide.keep_for(16), 16);
    }

    #[test]
    fn keep_for_n_ctx_at_most_one_resolves_to_one() {
        // the documented degenerate edge: at n_ctx <= 1 there is no
        // nonempty strict prefix to keep, so BOTH Reprefill arms return
        // 1 — which exceeds n_ctx - 1. Callers needing room apply their
        // own min(.., n_ctx - need) cap (ensure_room_for does).
        for n_ctx in [0usize, 1] {
            assert_eq!(WrapPolicy::Reprefill { keep: 0 }.keep_for(n_ctx), 1);
            assert_eq!(WrapPolicy::Reprefill { keep: 7 }.keep_for(n_ctx), 1);
        }
        // and the cap callers apply does saturate sanely
        assert_eq!(WrapPolicy::Reprefill { keep: 7 }.keep_for(1).min(1usize.saturating_sub(1)), 0);
    }

    #[test]
    fn session_matches_full_forward_fp() {
        let m = tiny();
        let prompt = toks(5, 1);
        let mut s = m.session(WrapPolicy::default());
        let mut logits = s.prefill(&prompt).unwrap();
        let mut ctx = prompt.clone();
        for step in 0..4u32 {
            let full = m.forward(&[ctx.clone()], None, None).unwrap();
            assert_eq!(logits, full.row(ctx.len() - 1).to_vec(), "step {step}");
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            ctx.push(next);
        }
    }

    #[test]
    fn session_matches_oracle_int_muxq() {
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let prompt = toks(6, 2);
        let mut s = q.session(WrapPolicy::default());
        let mut logits = s.prefill(&prompt).unwrap();
        let mut ctx = prompt.clone();
        for _ in 0..3 {
            let oracle = q.forward_logits_session(&[ctx.clone()]).unwrap();
            assert_eq!(logits, oracle.row(ctx.len() - 1).to_vec());
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            ctx.push(next);
        }
    }

    #[test]
    fn session_matches_oracle_int_llmint8() {
        // the new deployed operator reaches the session layer unchanged:
        // incremental decode must equal the row-independent full-forward
        // oracle bit for bit
        let q = QuantizedGpt2::new(tiny(), EngineSpec::llmint8());
        let prompt = toks(6, 12);
        let mut s = q.session(WrapPolicy::default());
        let mut logits = s.prefill(&prompt).unwrap();
        let mut ctx = prompt.clone();
        for _ in 0..3 {
            let oracle = q.forward_logits_session(&[ctx.clone()]).unwrap();
            assert_eq!(logits, oracle.row(ctx.len() - 1).to_vec());
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            ctx.push(next);
        }
    }

    #[test]
    fn reprefill_wrap_stays_exact_past_n_ctx() {
        // n_ctx = 12; generate far past it — every step's logits must be
        // a full forward of the session's live window
        let m = tiny();
        let mut s = m.session(WrapPolicy::default());
        let mut logits = s.prefill(&toks(8, 3)).unwrap();
        for _ in 0..20 {
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            let win = s.state.window().to_vec();
            assert!(win.len() <= 12);
            let full = m.forward(&[win.clone()], None, None).unwrap();
            assert_eq!(logits, full.row(win.len() - 1).to_vec());
        }
        assert!(s.state.prefills() > 1, "wrap must have re-prefilled");
    }

    #[test]
    fn slide_wrap_keeps_ring_at_n_ctx() {
        let m = tiny();
        let mut s = m.session(WrapPolicy::Slide);
        let mut logits = s.prefill(&toks(12, 4)).unwrap(); // full from the start
        for _ in 0..10 {
            let next = argmax(&logits);
            logits = s.decode_step(next).unwrap();
            assert_eq!(s.state.context_len(), 12);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        assert_eq!(s.state.prefills(), 1, "slide never re-prefills");
    }

    #[test]
    fn batched_decode_bit_exact_vs_solo() {
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let m = SessionModel::Int(&q);
        let prompts = [toks(3, 5), toks(7, 6), toks(5, 7)];
        // solo runs
        let mut solo_logits = Vec::new();
        for p in &prompts {
            let mut s = SessionState::new(&q.fp.cfg, WrapPolicy::default());
            let first = argmax(&s.prefill(m, p).unwrap());
            solo_logits.push(s.decode_step(m, first).unwrap());
        }
        // batched run over the same three sessions
        let mut states: Vec<SessionState> =
            prompts.iter().map(|_| SessionState::new(&q.fp.cfg, WrapPolicy::default())).collect();
        let mut tokens = Vec::new();
        for (st, p) in states.iter_mut().zip(&prompts) {
            tokens.push(argmax(&st.prefill(m, p).unwrap()));
        }
        let mut refs: Vec<&mut SessionState> = states.iter_mut().collect();
        let batch = decode_step_batch(m, &mut refs, &tokens).unwrap();
        for (i, solo) in solo_logits.iter().enumerate() {
            assert_eq!(batch.row(i), &solo[..], "session {i}");
        }
    }

    #[test]
    fn long_prompt_truncates_to_n_ctx() {
        let m = tiny();
        let mut s = m.session(WrapPolicy::default());
        let long = toks(30, 8);
        s.prefill(&long).unwrap();
        assert_eq!(s.state.context_len(), 12);
        assert_eq!(s.state.window(), &long[18..]);
    }

    #[test]
    fn misuse_is_rejected() {
        let m = tiny();
        let mut s = m.session(WrapPolicy::default());
        assert!(s.decode_step(0).is_err(), "step before prefill");
        assert!(s.prefill(&[]).is_err(), "empty prompt");
        let mut a = SessionState::new(&m.cfg, WrapPolicy::default());
        a.prefill(SessionModel::Fp(&m), &[1, 2]).unwrap();
        let mut refs = [&mut a];
        assert!(decode_step_batch(SessionModel::Fp(&m), &mut refs, &[1, 2]).is_err());
    }

    #[test]
    fn argmax_last_max_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 2);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn greedy_sampler_is_argmax_and_consumes_no_rng() {
        let logits = [0.1f32, 2.5, -1.0, 2.5];
        let mut s = Sampler::greedy();
        assert!(s.is_greedy());
        for _ in 0..3 {
            assert_eq!(s.sample(&logits), argmax(&logits));
        }
        // top_k == 1 degenerates to greedy too
        let mut s1 = Sampler::new(1.0, 1, 42);
        assert!(s1.is_greedy());
        assert_eq!(s1.sample(&logits), argmax(&logits));
    }

    #[test]
    fn sampler_is_seed_deterministic_and_in_top_k() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let draw = |seed: u64| -> Vec<u32> {
            let mut s = Sampler::new(0.8, 4, seed);
            (0..20).map(|_| s.sample(&logits)).collect()
        };
        assert_eq!(draw(7), draw(7), "same seed, same stream");
        assert_ne!(draw(7), draw(8), "different seed, different stream");
        // every draw lands in the true top-4
        let mut order: Vec<usize> = (0..logits.len()).collect();
        order.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        let top4: Vec<u32> = order[..4].iter().map(|&i| i as u32).collect();
        for t in draw(7) {
            assert!(top4.contains(&t), "{t} outside top-k");
        }
    }

    #[test]
    fn sampler_temperature_sharpens_toward_argmax() {
        // at tiny temperature the softmax collapses onto the max logit
        let logits = [0.0f32, 1.0, 5.0, 2.0];
        let mut s = Sampler::new(0.05, 0, 11);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 2);
        }
        // at high temperature other tokens appear
        let mut hot = Sampler::new(50.0, 0, 13);
        let draws: Vec<u32> = (0..200).map(|_| hot.sample(&logits)).collect();
        assert!(draws.iter().any(|&t| t != 2), "high T must diversify");
    }

    #[test]
    fn top_p_keeps_only_the_nucleus() {
        // one dominant logit: a tight nucleus must collapse onto it
        let mut logits = vec![0.0f32; 16];
        logits[5] = 8.0;
        logits[9] = 7.0;
        let mut s = Sampler::new(1.0, 0, 3).with_top_p(0.5);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 5);
        }
        // p ~ 1-eps keeps (almost) everything: other tokens appear
        let flat = vec![0.0f32; 16];
        let mut wide = Sampler::new(1.0, 0, 4).with_top_p(0.99);
        let draws: Vec<u32> = (0..100).map(|_| wide.sample(&flat)).collect();
        assert!(draws.iter().any(|&t| t != draws[0]), "near-1 top-p must diversify");
        // and stays seed-deterministic
        let run = |seed: u64| -> Vec<u32> {
            let mut s = Sampler::new(0.9, 6, seed).with_top_p(0.8);
            let l: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
            (0..20).map(|_| s.sample(&l)).collect()
        };
        assert_eq!(run(7), run(7));
    }

    #[test]
    fn repetition_penalty_pushes_history_down() {
        // greedy + penalty: once 2 is in the history a strong penalty
        // hands the argmax to the runner-up
        let logits = [0.0f32, 1.0, 5.0, 4.0];
        let mut s = Sampler::greedy().with_repetition_penalty(10.0);
        assert_eq!(s.sample_in_context(&logits, &[]), 2);
        assert_eq!(s.sample_in_context(&logits, &[2]), 3);
        // negative logits are multiplied (pushed further down)
        let neg = [-0.1f32, -5.0];
        let mut s2 = Sampler::greedy().with_repetition_penalty(100.0);
        assert_eq!(s2.sample_in_context(&neg, &[0]), 1);
        // history ids past the vocab edge are ignored, not a panic
        assert_eq!(s2.sample_in_context(&neg, &[999]), 0);
    }

    #[test]
    fn probs_match_the_drawn_distribution() {
        // probs_in_context must describe exactly what sample_in_context
        // draws: support == candidate set, sums to 1, greedy = point mass
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.51).cos() * 2.0).collect();
        let mut s = Sampler::new(0.8, 4, 9).with_top_p(0.9);
        let mut p = Vec::new();
        s.probs_in_context(&logits, &[], &mut p);
        let total: f32 = p.iter().sum();
        assert!((total - 1.0).abs() < 1e-5, "sums to {total}");
        let support: Vec<usize> = (0..16).filter(|&i| p[i] > 0.0).collect();
        assert!(support.len() <= 4, "top-k bound");
        // every later draw lands inside the reported support
        for _ in 0..50 {
            let t = s.sample(&logits) as usize;
            assert!(p[t] > 0.0, "draw {t} outside reported support");
        }
        // greedy: point mass, no RNG consumed
        let mut g = Sampler::greedy();
        g.probs_in_context(&logits, &[], &mut p);
        assert_eq!(p.iter().filter(|&&x| x > 0.0).count(), 1);
        assert_eq!(p[argmax(&logits) as usize], 1.0);
    }

    #[test]
    fn draw_from_is_seeded_and_respects_support() {
        let probs = [0.0f32, 0.5, 0.0, 0.5];
        let run = |seed: u64| -> Vec<u32> {
            let mut s = Sampler::new(1.0, 0, seed);
            (0..30).map(|_| s.draw_from(&probs)).collect()
        };
        assert_eq!(run(5), run(5));
        for t in run(5) {
            assert!(t == 1 || t == 3, "draw {t} has zero probability");
        }
        // degenerate all-zero vector falls back without panicking
        let mut s = Sampler::new(1.0, 0, 1);
        let _ = s.draw_from(&[0.0, 0.0]);
    }

    #[test]
    fn fork_is_reproducible_and_decorrelated() {
        let base = Sampler::new(0.9, 5, 77).with_top_p(0.8).with_repetition_penalty(1.3);
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.29).sin() * 3.0).collect();
        let draw = |mut s: Sampler| -> Vec<u32> { (0..20).map(|_| s.sample(&logits)).collect() };
        let a = base.fork(1);
        assert_eq!(a.temperature, 0.9);
        assert_eq!(a.top_p, 0.8);
        assert_eq!(a.repetition_penalty, 1.3);
        assert_eq!(draw(base.fork(1)), draw(base.fork(1)), "same salt, same stream");
        assert_ne!(draw(base.fork(1)), draw(base.fork(2)), "different salt, different stream");
    }

    #[test]
    fn extend_scored_rows_match_sequential_decode() {
        // the verify primitive: one k+1-row scored extend == stepping the
        // same tokens one at a time, row for row, bit for bit
        let m = tiny();
        let prompt = toks(4, 31);
        let ext = [1u32, 9, 17];
        let mut a = SessionState::new(&m.cfg, WrapPolicy::default());
        let mut b = SessionState::new(&m.cfg, WrapPolicy::default());
        let sm = SessionModel::Fp(&m);
        a.prefill(sm, &prompt).unwrap();
        b.prefill(sm, &prompt).unwrap();
        let scored = a.extend_scored(sm, &ext).unwrap();
        assert_eq!((scored.rows, scored.cols), (3, m.cfg.vocab_size));
        for (i, &t) in ext.iter().enumerate() {
            let solo = b.decode_step(sm, t).unwrap();
            assert_eq!(scored.row(i), &solo[..], "row {i}");
        }
        assert_eq!(a.window(), b.window());
    }

    #[test]
    fn truncate_to_restores_the_rolled_back_state() {
        // extend 3 tokens, roll them back, decode: logits and ring
        // contents equal a session that never saw them
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let sm = SessionModel::Int(&q);
        let prompt = toks(5, 33);
        let mut a = SessionState::new(&q.fp.cfg, WrapPolicy::default());
        let mut b = SessionState::new(&q.fp.cfg, WrapPolicy::default());
        a.prefill(sm, &prompt).unwrap();
        b.prefill(sm, &prompt).unwrap();
        a.extend_scored(sm, &[3, 1, 4]).unwrap();
        a.truncate_to(prompt.len());
        assert_eq!(a.window(), &prompt[..]);
        for (ca, cb) in a.caches().iter().zip(b.caches()) {
            assert_eq!(ca.len(), cb.len());
            for i in 0..ca.len() {
                assert_eq!(ca.k_row(i), cb.k_row(i));
                assert_eq!(ca.v_row(i), cb.v_row(i));
            }
        }
        let la = a.decode_step(sm, 7).unwrap();
        let lb = b.decode_step(sm, 7).unwrap();
        assert_eq!(la, lb);
    }

    #[test]
    fn ensure_room_for_multi_token_extends() {
        // n_ctx = 12: an 8-token window + need 5 forces an early wrap
        // that still leaves the extend fitting exactly
        let m = tiny();
        let sm = SessionModel::Fp(&m);
        let mut s = SessionState::new(&m.cfg, WrapPolicy::default());
        s.prefill(sm, &toks(8, 35)).unwrap();
        s.ensure_room_for(sm, 5).unwrap();
        assert!(s.context_len() + 5 <= 12, "window {} too big", s.context_len());
        assert_eq!(s.prefills(), 2, "wrap must have re-prefilled");
        s.extend_scored(sm, &[1, 2, 3, 4, 5]).unwrap();
        // Slide cannot absorb multi-token extends
        let mut sl = SessionState::new(&m.cfg, WrapPolicy::Slide);
        sl.prefill(sm, &toks(12, 36)).unwrap();
        assert!(sl.ensure_room_for(sm, 2).is_err());
        // need >= n_ctx is rejected outright
        assert!(s.ensure_room_for(sm, 12).is_err());
    }

    #[test]
    fn sampled_generation_reproducible_and_session_exact() {
        // a sampled generation replays exactly given the same seed, and
        // its tokens stay a valid decode (session == oracle property is
        // decoupled from HOW the next token is chosen)
        let m = tiny();
        let prompt = toks(5, 21);
        let gen = |seed: u64| {
            let mut s = m.session(WrapPolicy::default());
            s.generate(&prompt, 8, &mut Sampler::new(0.9, 5, seed)).unwrap()
        };
        assert_eq!(gen(3), gen(3));
        // greedy generate == generate_greedy
        let mut s1 = m.session(WrapPolicy::default());
        let mut s2 = m.session(WrapPolicy::default());
        let a = s1.generate(&prompt, 6, &mut Sampler::greedy()).unwrap();
        let b = s2.generate_greedy(&prompt, 6).unwrap();
        assert_eq!(a, b);
    }
}
