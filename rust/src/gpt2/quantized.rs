//! True-INT deployment pipeline: weights quantized AND packed once at
//! load time (per-out-channel scales, K-major panel layout), activations
//! quantized per batch, all projections running as i8 x i8 -> i32 GEMMs
//! on the packed parallel engine.
//!
//! This is the pipeline the paper *argues for* but does not implement
//! (§4.3 uses fake quantization; §4.5 leaves the INT pipeline to future
//! work). Here it is, end to end, with MUXQ's two-GEMM outlier handling
//! in real integer arithmetic — plus the memory accounting that
//! motivates INT deployment in the first place.
//!
//! Zero-copy projection path: `proj_int` performs no weight gathering or
//! re-packing per call (weights are packed once in [`QuantizedGpt2::new`]
//! with the tile-selected panel width; the MUXQ Aux GEMM reads its
//! outlier rows straight out of the full packed layout via an index
//! list), and the Body/Aux operands are quantized in a single fused pass
//! over X into reusable scratch buffers — no intermediate f32 Body/Aux
//! matrices are ever materialized. Both GEMMs run the i16
//! pair-accumulation microkernel (quantized operands never contain -128,
//! so the pair path is always taken — see `quant::packed`).
//!
//! Session (incremental-decode) projection: the batch MUXQ path computes
//! ONE outlier mask over all rows of a projection call — a batching
//! artifact that makes results depend on which rows happen to share a
//! call. Decode sessions need *row independence* (a decode step must
//! match the same token scored inside a prefill, and a coalesced
//! multi-session step must match stepping each session alone), so
//! `proj_session` gives every row its own mask via the single-row fused
//! decompose+quantize (`proj_int_rowwise`): mask, Body/Aux scales and
//! both GEMVs all come from that row only. This is also the natural M=1
//! semantics of the paper's decomposition — at decode there IS only one
//! row. [`QuantizedGpt2::forward_logits_session`] is the full-forward
//! oracle with identical semantics, which `tests/decode_session.rs`
//! pins bit-exact against the incremental path. Naive per-row abs-max is
//! row-independent already, so its session path IS the batch path.

use super::model::Gpt2Model;
use crate::quant::absmax::{Granularity, Scales, EPS};
use crate::quant::matrix::{rint, MatF32, MatI32, MatI8};
use crate::quant::muxq::{outlier_mask_into, MuxqParams};
use crate::quant::packed::{self, PackedMatI8, ParallelGemm};
use anyhow::Result;
use std::sync::Mutex;

/// One weight matrix, pre-quantized and pre-packed.
pub struct QuantWeight {
    /// K-major packed panels — the layout the microkernel streams.
    pub packed: PackedMatI8,
    pub scales: Scales, // PerCol
    pub bias: Vec<f32>,
}

impl QuantWeight {
    pub fn from_f32(w: &MatF32, bias: &[f32], w_bits: u32) -> QuantWeight {
        let qmax = crate::quant::qmax_from_bits(w_bits);
        let scales = Scales::compute(w, qmax, Granularity::PerCol);
        let q = crate::quant::absmax::quantize_i8(w, &scales, qmax);
        QuantWeight { packed: PackedMatI8::pack(&q), scales, bias: bias.to_vec() }
    }

    /// Deployed INT bytes. Counts the *padded* panel storage — the packed
    /// layout rounds the output dim up to the panel width, and the
    /// memory-saving claim must stay honest about that.
    pub fn bytes(&self) -> usize {
        self.packed.padded_bytes()
            + match &self.scales {
                Scales::Tensor(_) => 4,
                Scales::Rows(v) | Scales::Cols(v) => v.len() * 4,
            }
            + self.bias.len() * 4
    }
}

/// MUXQ execution mode for the INT pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntMethod {
    Naive,
    Muxq,
}

/// Reusable per-projection buffers: on the steady-state path `proj_int`
/// allocates only its output matrix — quantized operands, i32
/// accumulators, scale vectors and the outlier mask/index lists are all
/// resized in place.
struct Scratch {
    /// quantized Body (MUXQ) or plain activations (Naive)
    xq: MatI8,
    /// compact quantized Aux — outlier columns only, [m, r]
    aux_q: MatI8,
    /// body / aux GEMM accumulators
    acc: MatI32,
    acc_aux: MatI32,
    /// per-row activation scales (body, aux)
    sx: Vec<f32>,
    sa: Vec<f32>,
    mask: Vec<bool>,
    idx: Vec<usize>,
    /// single-row f32 view for the row-wise session projection
    xrow: MatF32,
}

impl Scratch {
    fn new() -> Scratch {
        Scratch {
            xq: MatI8::zeros(0, 0),
            aux_q: MatI8::zeros(0, 0),
            acc: MatI32::zeros(0, 0),
            acc_aux: MatI32::zeros(0, 0),
            sx: Vec::new(),
            sa: Vec::new(),
            mask: Vec::new(),
            idx: Vec::new(),
            xrow: MatF32::zeros(0, 0),
        }
    }
}

/// A GPT-2 whose four projection sites hold packed i8 weights. Built from
/// (and borrowing the FP parts of) a loaded [`Gpt2Model`].
pub struct QuantizedGpt2 {
    pub fp: Gpt2Model,
    pub method: IntMethod,
    pub ia_bits: u32,
    pub muxq: MuxqParams,
    /// row-panel parallel GEMM config (sequential fallback for small shapes)
    pub gemm: ParallelGemm,
    /// per block: [c_attn, attn_proj, c_fc, mlp_proj]
    weights: Vec<[QuantWeight; 4]>,
    scratch: Mutex<Scratch>,
}

impl QuantizedGpt2 {
    pub fn new(fp: Gpt2Model, method: IntMethod, ia_bits: u32, w_bits: u32) -> QuantizedGpt2 {
        let weights = fp
            .blocks_raw()
            .iter()
            .map(|b| {
                [
                    QuantWeight::from_f32(&b.0, &b.1, w_bits),
                    QuantWeight::from_f32(&b.2, &b.3, w_bits),
                    QuantWeight::from_f32(&b.4, &b.5, w_bits),
                    QuantWeight::from_f32(&b.6, &b.7, w_bits),
                ]
            })
            .collect();
        QuantizedGpt2 {
            fp,
            method,
            ia_bits,
            muxq: MuxqParams::default(),
            gemm: ParallelGemm::global(),
            weights,
            scratch: Mutex::new(Scratch::new()),
        }
    }

    /// INT weight bytes vs the FP32 original (the memory-saving claim).
    pub fn weight_bytes(&self) -> (usize, usize) {
        let int: usize = self.weights.iter().flatten().map(|w| w.bytes()).sum();
        let fp: usize = self
            .weights
            .iter()
            .flatten()
            .map(|w| w.packed.logical_len() * 4 + w.bias.len() * 4)
            .sum();
        (int, fp)
    }

    /// One projection through the INT pipeline. Weights were packed at
    /// construction; the only per-call allocation is the output matrix.
    fn proj_int(&self, x: &MatF32, qw: &QuantWeight) -> MatF32 {
        let qmax = crate::quant::qmax_from_bits(self.ia_bits);
        let mut guard = self.scratch.lock().unwrap();
        let sc = &mut *guard;
        match self.method {
            IntMethod::Naive => {
                quantize_rows_into(x, qmax, &mut sc.xq, &mut sc.sx);
                packed::matmul_i8_packed_into(&sc.xq, &qw.packed, &mut sc.acc, self.gemm);
                dequant_bias(&sc.acc, &sc.sx, &qw.scales, None, &qw.bias)
            }
            IntMethod::Muxq => {
                outlier_mask_into(x, self.muxq.theta, &mut sc.mask);
                sc.idx.clear();
                sc.idx.extend(
                    sc.mask.iter().enumerate().filter(|(_, m)| **m).map(|(i, _)| i),
                );
                fused_decompose_quantize(
                    x,
                    &sc.mask,
                    &sc.idx,
                    self.muxq.inv_shift(),
                    qmax,
                    &mut sc.xq,
                    &mut sc.sx,
                    &mut sc.aux_q,
                    &mut sc.sa,
                );
                // Body GEMM over the full (shifted-outlier) activations
                packed::matmul_i8_packed_into(&sc.xq, &qw.packed, &mut sc.acc, self.gemm);
                if sc.idx.is_empty() {
                    dequant_bias(&sc.acc, &sc.sx, &qw.scales, None, &qw.bias)
                } else {
                    // skinny Aux GEMM straight against the packed full W,
                    // contraction walking the outlier row indices
                    packed::matmul_i8_rows_subset_into(
                        &sc.aux_q,
                        &qw.packed,
                        &sc.idx,
                        &mut sc.acc_aux,
                        self.gemm,
                    );
                    dequant_bias(
                        &sc.acc,
                        &sc.sx,
                        &qw.scales,
                        Some((&sc.acc_aux, &sc.sa, self.muxq.aux_weight())),
                        &qw.bias,
                    )
                }
            }
        }
    }

    /// One projection with *row-independent* semantics — the session
    /// (incremental decode) path, also the semantics of the oracle
    /// [`QuantizedGpt2::forward_logits_session`]. Naive per-row abs-max
    /// is row-independent already; MUXQ switches to per-row outlier
    /// masks (see the module docs).
    pub(crate) fn proj_session(&self, x: &MatF32, site: &str, li: usize) -> MatF32 {
        let qw = &self.weights[li][Self::site_index(site)];
        match self.method {
            IntMethod::Naive => self.proj_int(x, qw),
            IntMethod::Muxq => self.proj_int_rowwise(x, qw),
        }
    }

    /// Row-wise MUXQ projection: every row of X gets its own outlier
    /// mask, its own fused decompose+quantize pass, and its own Body GEMV
    /// + Aux rows-subset GEMV against the (shared, load-time-packed)
    /// weights. M=1 operands route through the packed engine's GEMV path
    /// — no tile-cascade overhead on the decode hot loop.
    fn proj_int_rowwise(&self, x: &MatF32, qw: &QuantWeight) -> MatF32 {
        let qmax = crate::quant::qmax_from_bits(self.ia_bits);
        let (m, k) = (x.rows, x.cols);
        let n = qw.packed.cols;
        let mut y = MatF32::zeros(m, n);
        let mut guard = self.scratch.lock().unwrap();
        let sc = &mut *guard;
        sc.xrow.rows = 1;
        sc.xrow.cols = k;
        sc.xrow.data.resize(k, 0.0);
        for r in 0..m {
            sc.xrow.data.copy_from_slice(x.row(r));
            outlier_mask_into(&sc.xrow, self.muxq.theta, &mut sc.mask);
            sc.idx.clear();
            sc.idx
                .extend(sc.mask.iter().enumerate().filter(|(_, m)| **m).map(|(i, _)| i));
            fused_decompose_quantize(
                &sc.xrow,
                &sc.mask,
                &sc.idx,
                self.muxq.inv_shift(),
                qmax,
                &mut sc.xq,
                &mut sc.sx,
                &mut sc.aux_q,
                &mut sc.sa,
            );
            packed::matmul_i8_packed_into(&sc.xq, &qw.packed, &mut sc.acc, self.gemm);
            let aux = if sc.idx.is_empty() {
                None
            } else {
                packed::matmul_i8_rows_subset_into(
                    &sc.aux_q,
                    &qw.packed,
                    &sc.idx,
                    &mut sc.acc_aux,
                    self.gemm,
                );
                Some((&sc.acc_aux.data[..n], sc.sa[0], self.muxq.aux_weight()))
            };
            dequant_bias_row(&sc.acc.data[..n], sc.sx[0], &qw.scales, aux, &qw.bias, y.row_mut(r));
        }
        y
    }

    /// Full-forward logits under the *session* projection semantics —
    /// the bit-exactness oracle for incremental decode (see module docs).
    pub fn forward_logits_session(&self, tokens: &[Vec<u32>]) -> Result<MatF32> {
        self.fp
            .forward_with_proj(tokens, &mut |x, site, li| self.proj_session(x, site, li))
    }

    fn site_index(site: &str) -> usize {
        match site {
            "c_attn" => 0,
            "attn_proj" => 1,
            "c_fc" => 2,
            _ => 3,
        }
    }

    /// Per-sequence NLL through the full INT pipeline.
    pub fn nll_per_seq(&self, tokens: &[Vec<u32>]) -> Result<(Vec<f32>, Vec<f32>)> {
        self.fp.nll_per_seq_with_proj(tokens, &mut |x, site, li| {
            self.proj_int(x, &self.weights[li][Self::site_index(site)])
        })
    }
}

/// Per-row abs-max quantization straight into reusable scratch — the twin
/// of `Scales::compute(PerRow)` + `quantize_i8`, fused into one pass.
fn quantize_rows_into(x: &MatF32, qmax: f32, xq: &mut MatI8, sx: &mut Vec<f32>) {
    let (m, k) = (x.rows, x.cols);
    xq.rows = m;
    xq.cols = k;
    xq.data.resize(m * k, 0);
    sx.clear();
    sx.resize(m, 0.0);
    for r in 0..m {
        let xr = x.row(r);
        let amax = xr.iter().fold(0.0f32, |mx, v| mx.max(v.abs()));
        let s = amax.max(EPS) / qmax;
        sx[r] = s;
        for (qv, v) in xq.data[r * k..(r + 1) * k].iter_mut().zip(xr) {
            *qv = rint(v / s).clamp(-qmax, qmax) as i8;
        }
    }
}

/// Fused MUXQ decompose + quantize: ONE pass over each row of X computes
/// the Body and compact-Aux row abs-maxes, a second writes the quantized
/// values straight into the i8 scratch. No f32 Body/Aux matrices exist.
/// Bit-identical to decompose -> Scales::compute(PerRow) -> quantize_i8
/// (|x·2^-e| == |x|·2^-e exactly: the shift is a power of two).
#[allow(clippy::too_many_arguments)]
fn fused_decompose_quantize(
    x: &MatF32,
    mask: &[bool],
    idx: &[usize],
    inv: f32,
    qmax: f32,
    body_q: &mut MatI8,
    sb: &mut Vec<f32>,
    aux_q: &mut MatI8,
    sa: &mut Vec<f32>,
) {
    let (m, k, r) = (x.rows, x.cols, idx.len());
    debug_assert_eq!(mask.len(), k);
    body_q.rows = m;
    body_q.cols = k;
    body_q.data.resize(m * k, 0);
    aux_q.rows = m;
    aux_q.cols = r;
    aux_q.data.resize(m * r, 0);
    sb.clear();
    sb.resize(m, 0.0);
    sa.clear();
    sa.resize(m, 0.0);
    for row in 0..m {
        let xr = x.row(row);
        let mut bmax = 0.0f32;
        let mut amax = 0.0f32;
        for c in 0..k {
            let v = xr[c].abs();
            if mask[c] {
                let shifted = v * inv;
                bmax = bmax.max(shifted);
                amax = amax.max(shifted);
            } else {
                bmax = bmax.max(v);
            }
        }
        let sbv = bmax.max(EPS) / qmax;
        let sav = amax.max(EPS) / qmax;
        sb[row] = sbv;
        sa[row] = sav;
        for (c, bq) in body_q.data[row * k..(row + 1) * k].iter_mut().enumerate() {
            let v = if mask[c] { xr[c] * inv } else { xr[c] };
            *bq = rint(v / sbv).clamp(-qmax, qmax) as i8;
        }
        for (t, aq) in aux_q.data[row * r..(row + 1) * r].iter_mut().enumerate() {
            *aq = rint(xr[idx[t]] * inv / sav).clamp(-qmax, qmax) as i8;
        }
    }
}

/// Dequantize the body accumulator — plus, for MUXQ, the recombination
/// `f · Aux` term — and add the bias, all in one pass over the output.
fn dequant_bias(
    acc: &MatI32,
    sx: &[f32],
    sw: &Scales,
    aux: Option<(&MatI32, &[f32], f32)>,
    bias: &[f32],
) -> MatF32 {
    let (m, n) = (acc.rows, acc.cols);
    let mut y = MatF32::zeros(m, n);
    for r in 0..m {
        let yrow = &mut y.data[r * n..(r + 1) * n];
        let arow = &acc.data[r * n..(r + 1) * n];
        let aux_row =
            aux.map(|(acc2, sa, f)| (&acc2.data[r * n..(r + 1) * n], sa[r], f));
        dequant_bias_row(arow, sx[r], sw, aux_row, bias, yrow);
    }
    y
}

/// One output row of [`dequant_bias`] — shared by the batch path and the
/// row-wise session path, so the two are arithmetic-for-arithmetic
/// identical (the decode bit-exactness oracle depends on this).
fn dequant_bias_row(
    arow: &[i32],
    sxr: f32,
    sw: &Scales,
    aux: Option<(&[i32], f32, f32)>,
    bias: &[f32],
    yrow: &mut [f32],
) {
    let n = arow.len();
    match aux {
        None => {
            for j in 0..n {
                yrow[j] = arow[j] as f32 * (sxr * sw.at(0, j)) + bias[j];
            }
        }
        Some((a2, sar, f)) => {
            for j in 0..n {
                let swj = sw.at(0, j);
                yrow[j] =
                    arow[j] as f32 * (sxr * swj) + f * (a2[j] as f32 * (sar * swj)) + bias[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Gpt2Model {
        Gpt2Model::test_model(2, 16, 2, 12, 32, 7)
    }

    fn toks(b: usize, s: usize, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = crate::data::prng::SplitMix64::new(seed);
        (0..b).map(|_| (0..s).map(|_| rng.next_below(32) as u32).collect()).collect()
    }

    #[test]
    fn int_pipeline_close_to_fp_at_8bit() {
        let fp = tiny();
        let t = toks(2, 8, 1);
        let (fp_nll, _) = fp.nll_per_seq(&t, None).unwrap();
        for method in [IntMethod::Naive, IntMethod::Muxq] {
            let q = QuantizedGpt2::new(tiny(), method, 8, 8);
            let (q_nll, counts) = q.nll_per_seq(&t).unwrap();
            assert_eq!(counts[0], 7.0);
            for (a, b) in fp_nll.iter().zip(&q_nll) {
                let rel = (a - b).abs() / a.abs().max(1.0);
                assert!(rel < 0.05, "{method:?}: fp {a} int {b}");
            }
        }
    }

    #[test]
    fn weights_packed_once_at_construction() {
        // pack_count is thread-local, so concurrent tests can't perturb it
        let before = packed::pack_count();
        let q = QuantizedGpt2::new(tiny(), IntMethod::Muxq, 8, 8);
        let after_new = packed::pack_count();
        assert_eq!(after_new - before, 2 * 4, "one pack per projection site");
        let t = toks(2, 8, 1);
        q.nll_per_seq(&t).unwrap();
        assert_eq!(
            packed::pack_count(),
            after_new,
            "proj_int must never gather or re-pack weights per call"
        );
    }

    #[test]
    fn weight_bytes_count_panel_padding() {
        // 8x6 weight: 6 cols round up to 2 panels of 4 -> 64 padded bytes
        let w = MatF32::from_vec(8, 6, (0..48).map(|v| v as f32 / 48.0).collect()).unwrap();
        let qw = QuantWeight::from_f32(&w, &[0.0; 6], 8);
        assert_eq!(qw.packed.padded_bytes(), 64);
        assert_eq!(qw.packed.logical_len(), 48);
        // padded panels + 6 per-col scales + 6 biases
        assert_eq!(qw.bytes(), 64 + 6 * 4 + 6 * 4);
    }

    #[test]
    fn weight_memory_saving_approaches_4x() {
        // per-out-channel scales + f32 biases dilute the 4x ideal; the
        // dilution shrinks as d grows
        let small = QuantizedGpt2::new(tiny(), IntMethod::Naive, 8, 8);
        let (int_s, fp_s) = small.weight_bytes();
        let ratio_small = fp_s as f64 / int_s as f64;
        let big = QuantizedGpt2::new(
            Gpt2Model::test_model(2, 128, 2, 12, 32, 7),
            IntMethod::Naive,
            8,
            8,
        );
        let (int_b, fp_b) = big.weight_bytes();
        let ratio_big = fp_b as f64 / int_b as f64;
        assert!(ratio_small > 2.5, "ratio {ratio_small}");
        assert!(ratio_big > ratio_small, "dilution should shrink with d");
        assert!(ratio_big > 3.7 && ratio_big <= 4.0, "ratio {ratio_big}");
    }

    #[test]
    fn rowwise_muxq_equals_batch_on_single_row() {
        // for a 1-row input the batch mask IS the row mask, so the batch
        // and row-wise projections must agree bit-for-bit
        let q = QuantizedGpt2::new(tiny(), IntMethod::Muxq, 8, 8);
        let d = q.fp.cfg.d_model;
        let mut rng = crate::data::prng::SplitMix64::new(31);
        let mut x = MatF32::from_vec(
            1,
            d,
            (0..d).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
        )
        .unwrap();
        *x.at_mut(0, 3) = 21.0; // force an outlier channel
        let qw = &q.weights[0][0];
        let batch = q.proj_int(&x, qw);
        let rowwise = q.proj_int_rowwise(&x, qw);
        assert_eq!(batch.data, rowwise.data);
    }

    #[test]
    fn rowwise_muxq_masks_rows_independently() {
        // two rows, only one carrying an outlier: the row-wise path must
        // differ from the batch path (whose shared mask leaks the outlier
        // channel into the clean row) yet stay close to it in value
        let q = QuantizedGpt2::new(tiny(), IntMethod::Muxq, 8, 8);
        let d = q.fp.cfg.d_model;
        let mut rng = crate::data::prng::SplitMix64::new(33);
        let mut x = MatF32::from_vec(
            2,
            d,
            (0..2 * d).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
        )
        .unwrap();
        *x.at_mut(0, 5) = 30.0;
        let qw = &q.weights[0][0];
        let batch = q.proj_int(&x, qw);
        let rowwise = q.proj_int_rowwise(&x, qw);
        assert!(batch.mean_abs_diff(&rowwise) < 0.1, "paths diverged wildly");
        // row 0 (the outlier row) has the same mask either way
        assert_eq!(&batch.data[..batch.cols], &rowwise.data[..rowwise.cols]);
    }

    #[test]
    fn session_oracle_close_to_fp_at_8bit() {
        let fp = tiny();
        let t = toks(2, 8, 5);
        let fp_logits = fp.forward(&t, None, None).unwrap();
        for method in [IntMethod::Naive, IntMethod::Muxq] {
            let q = QuantizedGpt2::new(tiny(), method, 8, 8);
            let s_logits = q.forward_logits_session(&t).unwrap();
            assert_eq!((s_logits.rows, s_logits.cols), (fp_logits.rows, fp_logits.cols));
            assert!(
                fp_logits.mean_abs_diff(&s_logits) < 0.25,
                "{method:?} mae {}",
                fp_logits.mean_abs_diff(&s_logits)
            );
        }
    }

    #[test]
    fn muxq_int_matches_fp_better_than_naive_with_outliers() {
        // inject an outlier channel into the fp model's ln gains to make
        // the activations hostile, then compare INT pipelines
        let mut fp_a = tiny();
        let mut fp_b = tiny();
        fp_a.scale_ln1_channel(0, 3, 14.0);
        fp_b.scale_ln1_channel(0, 3, 14.0);
        let mut fp_ref = tiny();
        fp_ref.scale_ln1_channel(0, 3, 14.0);
        let t = toks(2, 10, 2);
        let (ref_nll, _) = fp_ref.nll_per_seq(&t, None).unwrap();
        let naive = QuantizedGpt2::new(fp_a, IntMethod::Naive, 5, 8);
        let muxq = QuantizedGpt2::new(fp_b, IntMethod::Muxq, 5, 8);
        let (n_nll, _) = naive.nll_per_seq(&t).unwrap();
        let (m_nll, _) = muxq.nll_per_seq(&t).unwrap();
        let err = |v: &[f32]| -> f32 {
            v.iter().zip(&ref_nll).map(|(a, b)| (a - b).abs()).sum()
        };
        // per-row activation scales absorb much of it, so allow equality
        assert!(
            err(&m_nll) <= err(&n_nll) * 1.2 + 0.05,
            "muxq {} naive {}",
            err(&m_nll),
            err(&n_nll)
        );
    }
}
