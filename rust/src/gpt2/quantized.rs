//! True-INT deployment pipeline: weights quantized ONCE to i8 at load
//! time (per-out-channel scales), activations quantized per batch, all
//! projections running as i8 x i8 -> i32 GEMMs.
//!
//! This is the pipeline the paper *argues for* but does not implement
//! (§4.3 uses fake quantization; §4.5 leaves the INT pipeline to future
//! work). Here it is, end to end, with MUXQ's two-GEMM outlier handling
//! in real integer arithmetic — plus the memory accounting that
//! motivates INT deployment in the first place.

use super::model::Gpt2Model;
use crate::quant::absmax::{quantize_i8, Granularity, Scales};
use crate::quant::gemm::{dequant, matmul_i8};
use crate::quant::matrix::{MatF32, MatI8};
use crate::quant::muxq::{gather_outlier_cols, outlier_mask, MuxqParams};
use anyhow::Result;

/// One weight matrix, pre-quantized.
pub struct QuantWeight {
    pub q: MatI8,
    pub scales: Scales, // PerCol
    pub bias: Vec<f32>,
}

impl QuantWeight {
    pub fn from_f32(w: &MatF32, bias: &[f32], w_bits: u32) -> QuantWeight {
        let qmax = crate::quant::qmax_from_bits(w_bits);
        let scales = Scales::compute(w, qmax, Granularity::PerCol);
        QuantWeight { q: quantize_i8(w, &scales, qmax), scales, bias: bias.to_vec() }
    }

    pub fn bytes(&self) -> usize {
        self.q.data.len() + match &self.scales {
            Scales::Tensor(_) => 4,
            Scales::Rows(v) | Scales::Cols(v) => v.len() * 4,
        } + self.bias.len() * 4
    }
}

/// MUXQ execution mode for the INT pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntMethod {
    Naive,
    Muxq,
}

/// A GPT-2 whose four projection sites hold i8 weights. Built from (and
/// borrowing the FP parts of) a loaded [`Gpt2Model`].
pub struct QuantizedGpt2 {
    pub fp: Gpt2Model,
    pub method: IntMethod,
    pub ia_bits: u32,
    pub muxq: MuxqParams,
    /// per block: [c_attn, attn_proj, c_fc, mlp_proj]
    weights: Vec<[QuantWeight; 4]>,
}

impl QuantizedGpt2 {
    pub fn new(fp: Gpt2Model, method: IntMethod, ia_bits: u32, w_bits: u32) -> QuantizedGpt2 {
        let weights = fp
            .blocks_raw()
            .iter()
            .map(|b| {
                [
                    QuantWeight::from_f32(&b.0, &b.1, w_bits),
                    QuantWeight::from_f32(&b.2, &b.3, w_bits),
                    QuantWeight::from_f32(&b.4, &b.5, w_bits),
                    QuantWeight::from_f32(&b.6, &b.7, w_bits),
                ]
            })
            .collect();
        QuantizedGpt2 { fp, method, ia_bits, muxq: MuxqParams::default(), weights }
    }

    /// INT weight bytes vs the FP32 original (the memory-saving claim).
    pub fn weight_bytes(&self) -> (usize, usize) {
        let int: usize = self.weights.iter().flatten().map(|w| w.bytes()).sum();
        let fp: usize = self
            .weights
            .iter()
            .flatten()
            .map(|w| w.q.data.len() * 4 + w.bias.len() * 4)
            .sum();
        (int, fp)
    }

    /// One projection through the INT pipeline.
    fn proj_int(&self, x: &MatF32, qw: &QuantWeight) -> MatF32 {
        let qmax = crate::quant::qmax_from_bits(self.ia_bits);
        let mut y = match self.method {
            IntMethod::Naive => {
                let sx = Scales::compute(x, qmax, Granularity::PerRow);
                let xq = quantize_i8(x, &sx, qmax);
                dequant(&matmul_i8(&xq, &qw.q), &sx, &qw.scales)
            }
            IntMethod::Muxq => {
                let mask = outlier_mask(x, self.muxq.theta);
                let r = mask.iter().filter(|m| **m).count();
                // Body GEMM (shifted outlier cols)
                let (body, _) = crate::quant::muxq::decompose(x, &mask, &self.muxq);
                let sb = Scales::compute(&body, qmax, Granularity::PerRow);
                let bq = quantize_i8(&body, &sb, qmax);
                let mut y = dequant(&matmul_i8(&bq, &qw.q), &sb, &qw.scales);
                if r > 0 {
                    // skinny Aux GEMM against the gathered i8 weight rows
                    let aux = gather_outlier_cols(x, &mask, self.muxq.inv_shift());
                    let w_rows_i8 = gather_i8_rows(&qw.q, &mask);
                    let sa = Scales::compute(&aux, qmax, Granularity::PerRow);
                    let aq = quantize_i8(&aux, &sa, qmax);
                    let ya = dequant(&matmul_i8(&aq, &w_rows_i8), &sa, &qw.scales);
                    let f = self.muxq.aux_weight();
                    for (yv, av) in y.data.iter_mut().zip(&ya.data) {
                        *yv += f * av;
                    }
                }
                y
            }
        };
        for r in 0..y.rows {
            for (v, b) in y.row_mut(r).iter_mut().zip(&qw.bias) {
                *v += b;
            }
        }
        y
    }

    /// Per-sequence NLL through the full INT pipeline.
    pub fn nll_per_seq(&self, tokens: &[Vec<u32>]) -> Result<(Vec<f32>, Vec<f32>)> {
        self.fp.nll_per_seq_with_proj(tokens, &mut |x, site, li| {
            let idx = match site {
                "c_attn" => 0,
                "attn_proj" => 1,
                "c_fc" => 2,
                _ => 3,
            };
            self.proj_int(x, &self.weights[li][idx])
        })
    }
}

fn gather_i8_rows(w: &MatI8, mask: &[bool]) -> MatI8 {
    let idx: Vec<usize> =
        mask.iter().enumerate().filter(|(_, m)| **m).map(|(i, _)| i).collect();
    let mut out = MatI8::zeros(idx.len(), w.cols);
    for (j, &r) in idx.iter().enumerate() {
        out.data[j * w.cols..(j + 1) * w.cols].copy_from_slice(w.row(r));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Gpt2Model {
        Gpt2Model::test_model(2, 16, 2, 12, 32, 7)
    }

    fn toks(b: usize, s: usize, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = crate::data::prng::SplitMix64::new(seed);
        (0..b).map(|_| (0..s).map(|_| rng.next_below(32) as u32).collect()).collect()
    }

    #[test]
    fn int_pipeline_close_to_fp_at_8bit() {
        let fp = tiny();
        let t = toks(2, 8, 1);
        let (fp_nll, _) = fp.nll_per_seq(&t, None).unwrap();
        for method in [IntMethod::Naive, IntMethod::Muxq] {
            let q = QuantizedGpt2::new(tiny(), method, 8, 8);
            let (q_nll, counts) = q.nll_per_seq(&t).unwrap();
            assert_eq!(counts[0], 7.0);
            for (a, b) in fp_nll.iter().zip(&q_nll) {
                let rel = (a - b).abs() / a.abs().max(1.0);
                assert!(rel < 0.05, "{method:?}: fp {a} int {b}");
            }
        }
    }

    #[test]
    fn weight_memory_saving_approaches_4x() {
        // per-out-channel scales + f32 biases dilute the 4x ideal; the
        // dilution shrinks as d grows
        let small = QuantizedGpt2::new(tiny(), IntMethod::Naive, 8, 8);
        let (int_s, fp_s) = small.weight_bytes();
        let ratio_small = fp_s as f64 / int_s as f64;
        let big = QuantizedGpt2::new(
            Gpt2Model::test_model(2, 128, 2, 12, 32, 7),
            IntMethod::Naive,
            8,
            8,
        );
        let (int_b, fp_b) = big.weight_bytes();
        let ratio_big = fp_b as f64 / int_b as f64;
        assert!(ratio_small > 2.5, "ratio {ratio_small}");
        assert!(ratio_big > ratio_small, "dilution should shrink with d");
        assert!(ratio_big > 3.7 && ratio_big <= 4.0, "ratio {ratio_big}");
    }

    #[test]
    fn muxq_int_matches_fp_better_than_naive_with_outliers() {
        // inject an outlier channel into the fp model's ln gains to make
        // the activations hostile, then compare INT pipelines
        let mut fp_a = tiny();
        let mut fp_b = tiny();
        fp_a.scale_ln1_channel(0, 3, 14.0);
        fp_b.scale_ln1_channel(0, 3, 14.0);
        let mut fp_ref = tiny();
        fp_ref.scale_ln1_channel(0, 3, 14.0);
        let t = toks(2, 10, 2);
        let (ref_nll, _) = fp_ref.nll_per_seq(&t, None).unwrap();
        let naive = QuantizedGpt2::new(fp_a, IntMethod::Naive, 5, 8);
        let muxq = QuantizedGpt2::new(fp_b, IntMethod::Muxq, 5, 8);
        let (n_nll, _) = naive.nll_per_seq(&t).unwrap();
        let (m_nll, _) = muxq.nll_per_seq(&t).unwrap();
        let err = |v: &[f32]| -> f32 {
            v.iter().zip(&ref_nll).map(|(a, b)| (a - b).abs()).sum()
        };
        // per-row activation scales absorb much of it, so allow equality
        assert!(
            err(&m_nll) <= err(&n_nll) * 1.2 + 0.05,
            "muxq {} naive {}",
            err(&m_nll),
            err(&n_nll)
        );
    }
}
