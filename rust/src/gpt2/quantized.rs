//! True-INT deployment pipeline over the unified operator API: each of
//! the four projection sites per block holds ONE boxed
//! [`QuantLinear`](crate::quant::QuantLinear) — weights quantized AND
//! packed once at load time by [`EngineSpec::pack`], activations handled
//! per call behind the operator (reusable scratch; the only steady-state
//! per-call allocation is the output matrix).
//!
//! This is the pipeline the paper *argues for* but does not implement
//! (§4.3 uses fake quantization; §4.5 leaves the INT pipeline to future
//! work). Because the projection is a trait object, every method the
//! paper evaluates deploys end to end — naive, MUXQ, LLM.int8() (with
//! its resident-FP outlier leg and the memory bill that comes with it),
//! each optionally composed with SmoothQuant — and all of them reach the
//! KV-cache sessions and the `GenerationServer` unchanged.
//!
//! Session (incremental-decode) projection: batch-masked methods (MUXQ,
//! LLM.int8()) compute ONE outlier mask over all rows of a projection
//! call — a batching artifact that makes results depend on which rows
//! happen to share a call. Decode sessions need *row independence* (a
//! decode step must match the same token scored inside a prefill, and a
//! coalesced multi-session step must match stepping each session alone),
//! so [`QuantizedGpt2::proj_session`] gives every row its own mask via
//! the operators' `forward_rows_into` (per-row fused quantize against
//! the shared load-time-packed weights; MUXQ coalesces mask-sharing
//! runs of rows into one Body+Aux GEMM pair, bit-identical to the
//! per-row loop). Methods whose batch path is already row-independent
//! (`row_independent()` — naive per-row, fp) keep the coalesced batch
//! GEMM.
//! [`QuantizedGpt2::forward_logits_session`] is the full-forward oracle
//! with identical semantics, which `tests/decode_session.rs` pins
//! bit-exact against the incremental path.

use super::model::{Gpt2Model, SiteCapture, PROJ_SITES};
use crate::npusim::gemm_plan::Plan;
use crate::npusim::{Cost, NpuConfig};
use crate::quant::linear::{EngineSpec, QuantLinear};
use crate::quant::matrix::MatF32;
use anyhow::Result;

/// A GPT-2 whose four projection sites per block hold deployed
/// [`QuantLinear`] operators. Built from (and owning the FP parts of) a
/// loaded [`Gpt2Model`].
pub struct QuantizedGpt2 {
    pub fp: Gpt2Model,
    pub spec: EngineSpec,
    /// per block: [c_attn, attn_proj, c_fc, mlp_proj]
    weights: Vec<[Box<dyn QuantLinear>; 4]>,
}

fn pack_site(
    spec: &EngineSpec,
    cap: Option<&SiteCapture>,
    li: usize,
    si: usize,
    w: &MatF32,
    bias: &[f32],
) -> Box<dyn QuantLinear> {
    let amax = cap
        .and_then(|c| c.get(&(li, PROJ_SITES[si])))
        .map(|v| v.as_slice());
    spec.pack_calibrated(w, bias, amax)
}

impl QuantizedGpt2 {
    /// Deploy `fp` under `spec`, packing every projection weight once.
    /// Smoothed specs fall back to weight-only equalization here; use
    /// [`QuantizedGpt2::new_calibrated`] to feed measured activation
    /// ranges into the migration.
    pub fn new(fp: Gpt2Model, spec: EngineSpec) -> QuantizedGpt2 {
        Self::build(fp, spec, None)
    }

    /// Deploy with SmoothQuant calibration: one FP forward over
    /// `calib_tokens` captures each site's per-channel activation
    /// abs-max, which feeds the migration scales at pack time.
    pub fn new_calibrated(
        fp: Gpt2Model,
        spec: EngineSpec,
        calib_tokens: &[Vec<u32>],
    ) -> Result<QuantizedGpt2> {
        let mut cap = SiteCapture::new();
        fp.forward(calib_tokens, None, Some(&mut cap))?;
        Ok(Self::build(fp, spec, Some(cap)))
    }

    fn build(fp: Gpt2Model, spec: EngineSpec, cap: Option<SiteCapture>) -> QuantizedGpt2 {
        let cap = cap.as_ref();
        let weights = fp
            .blocks_raw()
            .iter()
            .enumerate()
            .map(|(li, b)| {
                [
                    pack_site(&spec, cap, li, 0, b.0, b.1),
                    pack_site(&spec, cap, li, 1, b.2, b.3),
                    pack_site(&spec, cap, li, 2, b.4, b.5),
                    pack_site(&spec, cap, li, 3, b.6, b.7),
                ]
            })
            .collect();
        QuantizedGpt2 { fp, spec, weights }
    }

    /// The deployed operator at one projection site.
    pub fn op(&self, site: &str, li: usize) -> &dyn QuantLinear {
        &*self.weights[li][Self::site_index(site)]
    }

    /// INT weight bytes vs the FP32 original (the memory-saving claim —
    /// LLM.int8()'s resident FP copy is charged by its operator).
    pub fn weight_bytes(&self) -> (usize, usize) {
        let int: usize = self.weights.iter().flatten().map(|w| w.bytes()).sum();
        let fp: usize = self
            .weights
            .iter()
            .flatten()
            .map(|w| {
                let (k, n) = w.shape();
                k * n * 4 + n * 4
            })
            .sum();
        (int, fp)
    }

    /// One projection with *row-independent* semantics — the session
    /// (incremental decode) path, also the semantics of the oracle
    /// [`QuantizedGpt2::forward_logits_session`]. Operators whose batch
    /// path is row-independent keep the coalesced GEMM; batch-masked
    /// operators route through `forward_rows_into` (per-row masks, with
    /// the operator free to coalesce mask-sharing runs into one GEMM —
    /// MUXQ does; results stay bit-identical to the per-row loop).
    pub(crate) fn proj_session(&self, x: &MatF32, site: &str, li: usize) -> MatF32 {
        let op = self.op(site, li);
        if op.row_independent() {
            op.forward(x)
        } else {
            let mut y = MatF32::zeros(0, 0);
            op.forward_rows_into(x, &mut y);
            y
        }
    }

    /// Full-forward logits under the *session* projection semantics —
    /// the bit-exactness oracle for incremental decode (see module docs).
    pub fn forward_logits_session(&self, tokens: &[Vec<u32>]) -> Result<MatF32> {
        self.fp
            .forward_with_proj(tokens, &mut |x, site, li| self.proj_session(x, site, li))
    }

    fn site_index(site: &str) -> usize {
        match site {
            "c_attn" => 0,
            "attn_proj" => 1,
            "c_fc" => 2,
            _ => 3,
        }
    }

    /// Per-sequence NLL through the full INT pipeline (batch semantics).
    pub fn nll_per_seq(&self, tokens: &[Vec<u32>]) -> Result<(Vec<f32>, Vec<f32>)> {
        self.fp
            .nll_per_seq_with_proj(tokens, &mut |x, site, li| self.op(site, li).forward(x))
    }

    /// Per-site npusim decode plans (M = 1) across every block, with `r`
    /// live outlier channels at the two post-LN sites (c_attn, c_fc) and
    /// none at the residual projections — the same site split
    /// `npusim::model_cost` prices. Simulated-hardware pricing now flows
    /// from the very operators that serve traffic.
    pub fn decode_plans(&self, cfg: &NpuConfig, r: usize) -> Vec<Plan> {
        let mut plans = Vec::with_capacity(self.weights.len() * 4);
        for site_ops in &self.weights {
            for (si, ri) in [(0usize, r), (1, 0), (2, r), (3, 0)] {
                plans.push(site_ops[si].plan(cfg, 1, ri));
            }
        }
        plans
    }

    /// Simulated cost of ONE autoregressive decode step through every
    /// projection of the deployed model (sequential composition).
    pub fn decode_cost_sim(&self, cfg: &NpuConfig, r: usize) -> Cost {
        let mut total = Cost::default();
        for p in self.decode_plans(cfg, r) {
            total.add(p.cost(cfg));
        }
        total
    }

    /// [`QuantizedGpt2::decode_plans`] with paged-KV attention traffic
    /// priced in: each block's attention step streams `ctx_rows` K/V
    /// rows gathered from non-contiguous `page_rows`-sized pages, so the
    /// c_attn plan carries the page-gather DMA overhead
    /// ([`Plan::with_paged_kv_gather`]) on top of its GEMM cost. The
    /// residual and MLP sites are KV-free and price unchanged.
    pub fn decode_plans_paged(
        &self,
        cfg: &NpuConfig,
        r: usize,
        ctx_rows: usize,
        page_rows: usize,
    ) -> Vec<Plan> {
        let d_model = self.fp.cfg.d_model;
        let mut plans = Vec::with_capacity(self.weights.len() * 4);
        for site_ops in &self.weights {
            for (si, ri) in [(0usize, r), (1, 0), (2, r), (3, 0)] {
                let p = site_ops[si].plan(cfg, 1, ri);
                plans.push(if si == 0 {
                    p.with_paged_kv_gather(cfg, ctx_rows, d_model, page_rows)
                } else {
                    p
                });
            }
        }
        plans
    }

    /// Simulated cost of one decode step over a paged KV cache holding
    /// `ctx_rows` live rows in `page_rows`-sized pages.
    pub fn decode_cost_sim_paged(
        &self,
        cfg: &NpuConfig,
        r: usize,
        ctx_rows: usize,
        page_rows: usize,
    ) -> Cost {
        let mut total = Cost::default();
        for p in self.decode_plans_paged(cfg, r, ctx_rows, page_rows) {
            total.add(p.cost(cfg));
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::packed;

    fn tiny() -> Gpt2Model {
        Gpt2Model::test_model(2, 16, 2, 12, 32, 7)
    }

    fn toks(b: usize, s: usize, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = crate::data::prng::SplitMix64::new(seed);
        (0..b).map(|_| (0..s).map(|_| rng.next_below(32) as u32).collect()).collect()
    }

    #[test]
    fn int_pipeline_close_to_fp_at_8bit_all_methods() {
        let fp = tiny();
        let t = toks(2, 8, 1);
        let (fp_nll, _) = fp.nll_per_seq(&t, None).unwrap();
        for spec in [EngineSpec::naive(), EngineSpec::muxq(), EngineSpec::llmint8()] {
            let q = QuantizedGpt2::new(tiny(), spec.clone());
            let (q_nll, counts) = q.nll_per_seq(&t).unwrap();
            assert_eq!(counts[0], 7.0);
            for (a, b) in fp_nll.iter().zip(&q_nll) {
                let rel = (a - b).abs() / a.abs().max(1.0);
                assert!(rel < 0.05, "{}: fp {a} int {b}", spec.tag());
            }
        }
    }

    #[test]
    fn fp16_operator_deployment_is_bit_exact_vs_fp_forward() {
        // the Fp32Linear operator runs the same GEMM + bias arithmetic
        // as the model's own projection — deploying under fp16-pv must
        // change nothing at all
        let fp = tiny();
        let t = toks(2, 8, 3);
        let (want, _) = fp.nll_per_seq(&t, None).unwrap();
        let q = QuantizedGpt2::new(tiny(), EngineSpec::fp16());
        let (got, _) = q.nll_per_seq(&t).unwrap();
        assert_eq!(want, got);
    }

    #[test]
    fn weights_packed_once_at_construction() {
        // pack_count is thread-local, so concurrent tests can't perturb it
        let before = packed::pack_count();
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let after_new = packed::pack_count();
        assert_eq!(after_new - before, 2 * 4, "one pack per projection site");
        let t = toks(2, 8, 1);
        q.nll_per_seq(&t).unwrap();
        assert_eq!(
            packed::pack_count(),
            after_new,
            "projections must never gather or re-pack weights per call"
        );
    }

    #[test]
    fn weight_memory_saving_approaches_4x() {
        // per-out-channel scales + f32 biases dilute the 4x ideal; the
        // dilution shrinks as d grows
        let small = QuantizedGpt2::new(tiny(), EngineSpec::naive());
        let (int_s, fp_s) = small.weight_bytes();
        let ratio_small = fp_s as f64 / int_s as f64;
        let big = QuantizedGpt2::new(
            Gpt2Model::test_model(2, 128, 2, 12, 32, 7),
            EngineSpec::naive(),
        );
        let (int_b, fp_b) = big.weight_bytes();
        let ratio_big = fp_b as f64 / int_b as f64;
        assert!(ratio_small > 2.5, "ratio {ratio_small}");
        assert!(ratio_big > ratio_small, "dilution should shrink with d");
        assert!(ratio_big > 3.7 && ratio_big <= 4.0, "ratio {ratio_big}");
    }

    #[test]
    fn llmint8_deployment_pays_for_its_fp_copy() {
        let muxq = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let mixed = QuantizedGpt2::new(tiny(), EngineSpec::llmint8());
        let (muxq_bytes, fp_bytes) = muxq.weight_bytes();
        let (mixed_bytes, _) = mixed.weight_bytes();
        assert!(mixed_bytes > muxq_bytes, "resident FP copy must be charged");
        assert!(mixed_bytes < fp_bytes, "int8 + fp16 copy still beats pure f32");
        let ratio = fp_bytes as f64 / mixed_bytes as f64;
        assert!(ratio < 2.0, "llm.int8() cannot approach the 4x saving: {ratio}");
    }

    #[test]
    fn rowwise_muxq_equals_batch_on_single_row() {
        // for a 1-row input the batch mask IS the row mask, so the batch
        // and row-wise projections must agree bit-for-bit
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let d = q.fp.cfg.d_model;
        let mut rng = crate::data::prng::SplitMix64::new(31);
        let mut x = MatF32::from_vec(
            1,
            d,
            (0..d).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
        )
        .unwrap();
        *x.at_mut(0, 3) = 21.0; // force an outlier channel
        let op = q.op("c_attn", 0);
        let batch = op.forward(&x);
        let rowwise = q.proj_session(&x, "c_attn", 0);
        assert_eq!(batch.data, rowwise.data);
    }

    #[test]
    fn rowwise_muxq_masks_rows_independently() {
        // two rows, only one carrying an outlier: the row-wise path must
        // differ from the batch path (whose shared mask leaks the outlier
        // channel into the clean row) yet stay close to it in value
        let q = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let d = q.fp.cfg.d_model;
        let mut rng = crate::data::prng::SplitMix64::new(33);
        let mut x = MatF32::from_vec(
            2,
            d,
            (0..2 * d).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
        )
        .unwrap();
        *x.at_mut(0, 5) = 30.0;
        let op = q.op("c_attn", 0);
        let batch = op.forward(&x);
        let rowwise = q.proj_session(&x, "c_attn", 0);
        assert!(batch.mean_abs_diff(&rowwise) < 0.1, "paths diverged wildly");
        // row 0 (the outlier row) has the same mask either way
        assert_eq!(&batch.data[..batch.cols], &rowwise.data[..rowwise.cols]);
    }

    #[test]
    fn session_oracle_close_to_fp_at_8bit() {
        let fp = tiny();
        let t = toks(2, 8, 5);
        let fp_logits = fp.forward(&t, None, None).unwrap();
        for spec in [EngineSpec::naive(), EngineSpec::muxq(), EngineSpec::llmint8()] {
            let q = QuantizedGpt2::new(tiny(), spec.clone());
            let s_logits = q.forward_logits_session(&t).unwrap();
            assert_eq!((s_logits.rows, s_logits.cols), (fp_logits.rows, fp_logits.cols));
            assert!(
                fp_logits.mean_abs_diff(&s_logits) < 0.25,
                "{} mae {}",
                spec.tag(),
                fp_logits.mean_abs_diff(&s_logits)
            );
        }
    }

    #[test]
    fn muxq_int_matches_fp_better_than_naive_with_outliers() {
        // inject an outlier channel into the fp model's ln gains to make
        // the activations hostile, then compare INT pipelines
        let mut fp_a = tiny();
        let mut fp_b = tiny();
        fp_a.scale_ln1_channel(0, 3, 14.0);
        fp_b.scale_ln1_channel(0, 3, 14.0);
        let mut fp_ref = tiny();
        fp_ref.scale_ln1_channel(0, 3, 14.0);
        let t = toks(2, 10, 2);
        let (ref_nll, _) = fp_ref.nll_per_seq(&t, None).unwrap();
        let naive = QuantizedGpt2::new(fp_a, EngineSpec::naive().with_bits(5, 8));
        let muxq = QuantizedGpt2::new(fp_b, EngineSpec::muxq().with_bits(5, 8));
        let (n_nll, _) = naive.nll_per_seq(&t).unwrap();
        let (m_nll, _) = muxq.nll_per_seq(&t).unwrap();
        let err = |v: &[f32]| -> f32 {
            v.iter().zip(&ref_nll).map(|(a, b)| (a - b).abs()).sum()
        };
        // per-row activation scales absorb much of it, so allow equality
        assert!(
            err(&m_nll) <= err(&n_nll) * 1.2 + 0.05,
            "muxq {} naive {}",
            err(&m_nll),
            err(&n_nll)
        );
    }

    #[test]
    fn smooth_calibrated_deployment_runs_and_stays_close() {
        let fp = tiny();
        let calib = toks(2, 8, 9);
        let t = toks(2, 8, 10);
        let (fp_nll, _) = fp.nll_per_seq(&t, None).unwrap();
        let q = QuantizedGpt2::new_calibrated(tiny(), EngineSpec::muxq().with_smooth(0.5), &calib)
            .unwrap();
        assert_eq!(q.spec.tag(), "muxq-pv-sq");
        let (q_nll, _) = q.nll_per_seq(&t).unwrap();
        for (a, b) in fp_nll.iter().zip(&q_nll) {
            let rel = (a - b).abs() / a.abs().max(1.0);
            assert!(rel < 0.05, "fp {a} smooth-int {b}");
        }
    }

    #[test]
    fn rotated_permuted_calibrated_deployment_runs_and_stays_close() {
        // the full pipeline surface — rotation + permutation folded into
        // the packed weights at load time, inverses applied per call —
        // deploys through the same calibrated path SmoothQuant uses and
        // keeps 8-bit NLL within the usual envelope of the fp model
        let fp = tiny();
        let calib = toks(2, 8, 9);
        let t = toks(2, 8, 10);
        let (fp_nll, _) = fp.nll_per_seq(&t, None).unwrap();
        let spec = EngineSpec::muxq().with_rotate().with_permute();
        let q = QuantizedGpt2::new_calibrated(tiny(), spec, &calib).unwrap();
        assert_eq!(q.spec.tag(), "muxq-pv-rot-perm");
        let (q_nll, _) = q.nll_per_seq(&t).unwrap();
        for (a, b) in fp_nll.iter().zip(&q_nll) {
            let rel = (a - b).abs() / a.abs().max(1.0);
            assert!(rel < 0.05, "fp {a} rot-perm-int {b}");
        }
        // the uncalibrated constructor serves the same pipeline (pack-time
        // fallback ranges), including composed with a W4 weight stream
        let q2 = QuantizedGpt2::new(tiny(), EngineSpec::naive().with_bits(8, 4).with_rotate());
        assert_eq!(q2.spec.tag(), "naive-pv-rot-w4a8");
        let (nll2, _) = q2.nll_per_seq(&t).unwrap();
        assert!(nll2.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn w4_deployments_serve_with_halved_weight_bytes() {
        // nibble-packed W4 is a drop-in deployment: same sites, same
        // pack-once discipline, roughly half the body bytes
        let before = packed::pack_count();
        let w4 = QuantizedGpt2::new(tiny(), EngineSpec::naive().with_bits(8, 4));
        assert_eq!(packed::pack_count() - before, 2 * 4, "W4 packs once per site too");
        let w8 = QuantizedGpt2::new(tiny(), EngineSpec::naive());
        let (b4, _) = w4.weight_bytes();
        let (b8, _) = w8.weight_bytes();
        assert!(b4 < b8, "nibble panels must shrink the deployed model");
        // on a wider model the f32-vs-deployed ratio clears W8's 4x cap
        let big4 = QuantizedGpt2::new(
            Gpt2Model::test_model(2, 128, 2, 12, 32, 7),
            EngineSpec::naive().with_bits(8, 4),
        );
        let (int_b, fp_b) = big4.weight_bytes();
        let ratio = fp_b as f64 / int_b as f64;
        assert!(ratio > 6.0 && ratio <= 8.0, "ratio {ratio}");
        // and serving never re-packs
        let t = toks(2, 8, 1);
        let after = packed::pack_count();
        w4.nll_per_seq(&t).unwrap();
        assert_eq!(packed::pack_count(), after, "no per-call repacking");
    }

    #[test]
    fn w4_session_oracle_stays_sane_and_resq_recovers() {
        let fp = tiny();
        let t = toks(2, 8, 5);
        let fp_logits = fp.forward(&t, None, None).unwrap();
        let mae = |spec: EngineSpec| {
            let q = QuantizedGpt2::new(tiny(), spec);
            let s = q.forward_logits_session(&t).unwrap();
            assert_eq!((s.rows, s.cols), (fp_logits.rows, fp_logits.cols));
            fp_logits.mean_abs_diff(&s)
        };
        let naive8 = mae(EngineSpec::naive());
        let naive4 = mae(EngineSpec::naive().with_bits(8, 4));
        let muxq4 = mae(EngineSpec::muxq().with_bits(8, 4));
        let resq = mae(EngineSpec::resq());
        assert!(naive4.is_finite() && muxq4.is_finite() && resq.is_finite());
        // W4 weights cost accuracy vs W8...
        assert!(naive4 > naive8, "naive-w4 {naive4} vs naive-w8 {naive8}");
        // ...and the rank-r residual claws it back (never makes it worse)
        assert!(resq < naive4 * 1.05, "resq {resq} vs naive-w4 {naive4}");
    }

    #[test]
    fn decode_plans_price_the_deployed_model() {
        let cfg = NpuConfig::default();
        let muxq = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let mixed = QuantizedGpt2::new(tiny(), EngineSpec::llmint8());
        let plans = muxq.decode_plans(&cfg, 4);
        assert_eq!(plans.len(), 2 * 4, "one plan per site per block");
        assert!(plans.iter().all(|p| p.gemms.iter().all(|g| g.m == 1)), "decode is M=1");
        // uniform INT decode beats the mixed-precision pipeline on the
        // simulated NPU — the paper's §4.5 argument, priced through the
        // SAME operators that serve tokens
        let cm = muxq.decode_cost_sim(&cfg, 4).cycles();
        let cx = mixed.decode_cost_sim(&cfg, 4).cycles();
        assert!(cm < cx, "muxq {cm} vs llm.int8() {cx}");
        // and the W4 deployment decodes cheaper than its W8 twin — the
        // halved weight stream priced through the served operators
        let w8 = QuantizedGpt2::new(tiny(), EngineSpec::naive());
        let w4 = QuantizedGpt2::new(tiny(), EngineSpec::naive().with_bits(8, 4));
        let c8 = w8.decode_cost_sim(&cfg, 0).cycles();
        let c4 = w4.decode_cost_sim(&cfg, 0).cycles();
        assert!(c4 < c8, "w4 decode {c4} vs w8 {c8}");
    }

    #[test]
    fn paged_decode_plans_price_the_kv_gather() {
        let cfg = NpuConfig::default();
        let muxq = QuantizedGpt2::new(tiny(), EngineSpec::muxq());
        let flat = muxq.decode_plans(&cfg, 4);
        let paged = muxq.decode_plans_paged(&cfg, 4, 96, 16);
        assert_eq!(flat.len(), paged.len());
        // only the attention site (every 4th plan, si == 0) pays gather
        for (i, (f, p)) in flat.iter().zip(&paged).enumerate() {
            if i % 4 == 0 {
                assert!(
                    p.overhead_cycles > f.overhead_cycles,
                    "c_attn plan {i} must carry page-gather overhead"
                );
            } else {
                assert_eq!(p.overhead_cycles, f.overhead_cycles, "KV-free site {i} changed");
            }
        }
        // gather overhead grows with context and shrinks with page size
        let short = muxq.decode_cost_sim_paged(&cfg, 4, 16, 16).cycles();
        let long = muxq.decode_cost_sim_paged(&cfg, 4, 96, 16).cycles();
        assert!(long > short, "more live KV rows must cost more ({long} vs {short})");
        let coarse = muxq.decode_cost_sim_paged(&cfg, 4, 96, 32).cycles();
        assert!(coarse < long, "bigger pages mean fewer gather bursts ({coarse} vs {long})");
    }
}
