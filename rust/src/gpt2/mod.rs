//! Native f32 GPT-2 forward pass — the rust twin of
//! `python/compile/model.py`.
//!
//! Loads `artifacts/weights/<model>.bin` and runs the same architecture
//! (pre-LN blocks, Conv1D [in,out] projections, tanh-GELU, tied head),
//! with each of the four projection sites optionally routed through a
//! [`crate::quant::QuantSpec`] from the rust quantization engine.
//!
//! Roles: (a) baseline comparator + cross-check against the PJRT path
//! (`tests/native_vs_runtime.rs`); (b) activation capture for Fig. 1;
//! (c) workload for the native-engine benches where PJRT would hide the
//! quantization cost being measured; (d) the incremental-decode engine
//! behind token-level generation serving ([`session`],
//! `coordinator::generation`): per-layer [`KvCache`]s split the forward
//! into prefill + decode steps, with skinny per-token projections routed
//! through the packed engine's GEMV path. [`speculative`] stacks
//! draft-and-verify decoding on top: a cheap draft session proposes k
//! tokens, the target scores k+1 positions in one skinny batched
//! forward, and greedy acceptance is provably lossless. [`kvpool`]
//! lifts KV storage off private rings onto a shared block pool
//! (fixed-size pages, per-session block tables, copy-on-write prefix
//! sharing) so resident sessions are priced by pages, not worst-case
//! `n_ctx` buffers.
//!
//! The deployed (true-INT) pipeline is [`QuantizedGpt2`]: one
//! [`crate::quant::QuantLinear`] operator per projection site, built by
//! an [`crate::quant::EngineSpec`] — every method the paper evaluates
//! (naive, MUXQ, LLM.int8(), SmoothQuant compositions) deploys through
//! the same object shape, end to end into the generation server.

pub mod kvpool;
mod model;
mod quantized;
pub mod session;
pub mod speculative;

pub use kvpool::{KvPool, LayerPages, Page, PagedKv, PrefixCache, PrefixHit};
pub use model::{Gpt2Config, Gpt2Model, KvCache, ProjFn, SiteCapture, PROJ_SITES};
pub use quantized::QuantizedGpt2;
pub use session::{
    argmax, decode_step_batch, DecodeSession, Sampler, SessionModel, SessionState, WrapPolicy,
};
pub use speculative::{DraftKind, DraftModel, SpeculativeSession, SpeculativeState};
