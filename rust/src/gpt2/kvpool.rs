//! Paged KV storage: a block-pool allocator ([`KvPool`]), per-session
//! block tables ([`PagedKv`]), and copy-on-write prefix sharing
//! ([`PrefixCache`]).
//!
//! The ring-per-session layout (`KvCache` backed by one `[n_ctx,
//! d_model]` matrix per layer) caps resident sessions by memory long
//! before the kernels saturate. This module replaces the backing store
//! with fixed-size **pages** of K/V rows drawn from a shared pool:
//!
//! ```text
//!   KvPool (one per server)                 PagedKv (one per layer per session)
//!   ┌────────────────────────┐              ┌──────────────────────────────┐
//!   │ free list: [P7, P3]    │              │ block table: [P0, P5, None]  │
//!   │ created:   6 / max 64  │              │ start=0 len=34 cap_rows=48   │
//!   └────────────────────────┘              └──────────────────────────────┘
//!                                   page_rows = 16 → logical row 17 lives in
//!                                   table[1] (= P5), in-page row 1
//! ```
//!
//! Pages are handed out as `Arc<Page>`: the Arc strong count **is** the
//! refcount. A page referenced by several block tables (a shared system
//! prompt seeded through [`PrefixCache`]) is written through
//! `Arc::get_mut`, which only succeeds for a unique owner — a shared
//! page is forked (copied into a fresh page) before the first write
//! touches it, so aliasing after a fork is structurally impossible.
//!
//! Every long-lived page owner (a [`PagedKv`] table, a [`PrefixCache`]
//! entry) must return pages through [`KvPool::release`] so the buffer
//! lands back on the free list; transient Arc clones (a [`PrefixHit`]
//! in flight to a session) may simply drop, because the owning entry
//! outlives them and its eventual release recycles the buffer.

use crate::quant::MatF32;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One fixed-size block of K and V rows (`page_rows × d_model` each).
/// Fields are private: rows are read through [`Page::k_row`] /
/// [`Page::v_row`] and written only by [`PagedKv`] through
/// `Arc::get_mut` (the copy-on-write choke point).
pub struct Page {
    k: MatF32,
    v: MatF32,
}

impl Page {
    fn zeroed(rows: usize, d_model: usize) -> Page {
        Page { k: MatF32::zeros(rows, d_model), v: MatF32::zeros(rows, d_model) }
    }

    /// K row `r` of this page (`r < page_rows`).
    pub fn k_row(&self, r: usize) -> &[f32] {
        self.k.row(r)
    }

    /// V row `r` of this page (`r < page_rows`).
    pub fn v_row(&self, r: usize) -> &[f32] {
        self.v.row(r)
    }
}

struct PoolInner {
    /// recycled page buffers awaiting reuse
    free: Vec<Page>,
    /// pages ever created; never exceeds `max_pages`
    created: usize,
}

struct PoolShared {
    page_rows: usize,
    d_model: usize,
    max_pages: usize,
    inner: Mutex<PoolInner>,
    /// copy-on-write forks performed (a shared page copied before a write)
    cow_forks: AtomicU64,
    /// peak shared-page count noted by the server (fetch_max gauge)
    shared_note: AtomicU64,
}

/// Shared handle to the block pool. Cloning is cheap (one `Arc`); every
/// clone sees the same free list, counters, and capacity. The mutex is
/// touched only on alloc/release — row reads inside the decode hot loop
/// go straight through `Arc<Page>` without locking.
#[derive(Clone)]
pub struct KvPool {
    shared: Arc<PoolShared>,
}

impl KvPool {
    /// A pool of at most `max_pages` pages, each holding `page_rows`
    /// K/V rows of width `d_model`. Pages are created lazily and
    /// recycled through a free list, so a cold pool costs nothing.
    pub fn new(max_pages: usize, page_rows: usize, d_model: usize) -> KvPool {
        assert!(max_pages > 0, "kv pool needs at least one page");
        assert!(page_rows > 0, "kv pages need at least one row");
        assert!(d_model > 0, "kv rows need at least one column");
        KvPool {
            shared: Arc::new(PoolShared {
                page_rows,
                d_model,
                max_pages,
                inner: Mutex::new(PoolInner { free: Vec::new(), created: 0 }),
                cow_forks: AtomicU64::new(0),
                shared_note: AtomicU64::new(0),
            }),
        }
    }

    /// Allocate one page: reuse a free buffer if any, otherwise create
    /// one if the pool is under capacity. `None` means exhausted — the
    /// caller decides whether that is an admission refusal or a bug.
    pub fn alloc(&self) -> Option<Arc<Page>> {
        let mut inner = self.shared.inner.lock().unwrap();
        if let Some(p) = inner.free.pop() {
            return Some(Arc::new(p));
        }
        if inner.created < self.shared.max_pages {
            inner.created += 1;
            return Some(Arc::new(Page::zeroed(self.shared.page_rows, self.shared.d_model)));
        }
        None
    }

    /// Return a page reference to the pool. If this was the last strong
    /// reference the buffer goes back on the free list; otherwise the
    /// clone is dropped and the page stays alive with its remaining
    /// owners (whichever of them releases last recycles it).
    pub fn release(&self, page: Arc<Page>) {
        let mut inner = self.shared.inner.lock().unwrap();
        if let Ok(buf) = Arc::try_unwrap(page) {
            inner.free.push(buf);
        }
    }

    /// Pages currently held by live owners (created minus free).
    pub fn pages_in_use(&self) -> usize {
        let inner = self.shared.inner.lock().unwrap();
        inner.created - inner.free.len()
    }

    /// Pages still allocatable right now (free-list + never-created).
    pub fn free_pages(&self) -> usize {
        self.shared.max_pages - self.pages_in_use()
    }

    /// Hard capacity in pages.
    pub fn capacity(&self) -> usize {
        self.shared.max_pages
    }

    /// Pages ever created (high-water mark of physical buffers; a
    /// stable value under churn proves free-list reuse).
    pub fn pages_created(&self) -> usize {
        self.shared.inner.lock().unwrap().created
    }

    /// Rows per page.
    pub fn page_rows(&self) -> usize {
        self.shared.page_rows
    }

    /// Row width every page in this pool was created with.
    pub fn d_model(&self) -> usize {
        self.shared.d_model
    }

    /// Copy-on-write forks performed so far (monotonic).
    pub fn cow_forks(&self) -> u64 {
        self.shared.cow_forks.load(Ordering::Relaxed)
    }

    fn note_fork(&self) {
        self.shared.cow_forks.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an observed shared-page count. The note keeps the PEAK
    /// (`fetch_max`), not the latest sample: sessions retire between
    /// scheduler ticks and a last-written gauge would usually read 0 by
    /// the time stats are collected.
    pub fn note_shared(&self, shared_pages: usize) {
        self.shared.shared_note.fetch_max(shared_pages as u64, Ordering::Relaxed);
    }

    /// Peak shared-page count ever noted via [`KvPool::note_shared`].
    pub fn shared_pages_note(&self) -> u64 {
        self.shared.shared_note.load(Ordering::Relaxed)
    }
}

/// Per-layer paged KV storage for one session: a block table over pool
/// pages presenting the exact ring semantics of the old contiguous
/// `KvCache` (logical row `i` lives at slot `(start + i) % cap_rows`).
/// Unmapped table entries are `None` until the first write reaches
/// their slot range, so a short session in a big context maps only the
/// pages it touches.
pub struct PagedKv {
    pool: KvPool,
    table: Vec<Option<Arc<Page>>>,
    cap_rows: usize,
    start: usize,
    len: usize,
}

impl PagedKv {
    /// An empty paged cache of `cap_rows` logical rows drawn from
    /// `pool`. No pages are allocated until rows are written.
    pub fn new(pool: &KvPool, cap_rows: usize) -> PagedKv {
        assert!(cap_rows > 0, "kv cache capacity must be positive");
        let r = pool.page_rows();
        PagedKv {
            pool: pool.clone(),
            table: (0..cap_rows.div_ceil(r)).map(|_| None).collect(),
            cap_rows,
            start: 0,
            len: 0,
        }
    }

    /// Logical capacity in rows.
    pub fn cap(&self) -> usize {
        self.cap_rows
    }

    /// Rows currently stored.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no rows are stored.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn page_rows(&self) -> usize {
        self.pool.page_rows()
    }

    /// Rows per page of the backing pool.
    pub fn page_size(&self) -> usize {
        self.pool.page_rows()
    }

    /// Physical slot of logical row `i` (ring addressing).
    fn slot(&self, logical: usize) -> usize {
        debug_assert!(logical < self.len, "kv read past cache length");
        (self.start + logical) % self.cap_rows
    }

    /// Physical slot the `i`-th upcoming push will write. Covers both
    /// the append case and the full-ring overwrite case: with
    /// `len == cap_rows` this reduces to `(start + i) % cap_rows`,
    /// exactly the oldest rows a sliding overwrite replaces.
    fn write_slot(&self, i: usize) -> usize {
        (self.start + self.len + i) % self.cap_rows
    }

    /// K row for logical position `logical`.
    pub fn k_row(&self, logical: usize) -> &[f32] {
        let s = self.slot(logical);
        let page = self.table[s / self.page_rows()].as_ref().expect("read of an unmapped kv page");
        page.k_row(s % self.page_rows())
    }

    /// V row for logical position `logical`.
    pub fn v_row(&self, logical: usize) -> &[f32] {
        let s = self.slot(logical);
        let page = self.table[s / self.page_rows()].as_ref().expect("read of an unmapped kv page");
        page.v_row(s % self.page_rows())
    }

    /// Pages this cache would need to allocate (or fork) before it can
    /// absorb `rows` more pushes. Counts distinct target pages that are
    /// either unmapped or currently shared (a shared page must be
    /// forked into a fresh one before the write).
    pub fn pages_needed(&self, rows: usize) -> usize {
        let r = self.page_rows();
        let mut need = 0usize;
        let mut last_pi = usize::MAX;
        for i in 0..rows.min(self.cap_rows) {
            let pi = self.write_slot(i) / r;
            if pi == last_pi {
                continue;
            }
            last_pi = pi;
            match &self.table[pi] {
                None => need += 1,
                Some(p) if Arc::strong_count(p) > 1 => need += 1,
                Some(_) => {}
            }
        }
        need
    }

    /// Reserve (allocate or COW-fork) every page the next `rows` pushes
    /// will touch. Errors — without partial-write side effects visible
    /// to readers — when the pool is exhausted, which the admission
    /// layer converts into a refusal instead of a panic.
    pub fn ensure_capacity(&mut self, rows: usize) -> Result<()> {
        let r = self.page_rows();
        let mut last_pi = usize::MAX;
        for i in 0..rows.min(self.cap_rows) {
            let pi = self.write_slot(i) / r;
            if pi == last_pi {
                continue;
            }
            last_pi = pi;
            self.ensure_page(pi)?;
        }
        Ok(())
    }

    /// Make `table[pi]` present and uniquely owned: allocate a fresh
    /// page if unmapped, or fork (copy) it if shared. The fork is the
    /// copy-on-write choke point — the old page is released back to its
    /// remaining owners untouched.
    fn ensure_page(&mut self, pi: usize) -> Result<()> {
        if self.table[pi].is_none() {
            match self.pool.alloc() {
                Some(p) => self.table[pi] = Some(p),
                None => bail!(
                    "kv pool exhausted ({} of {} pages in use)",
                    self.pool.pages_in_use(),
                    self.pool.capacity()
                ),
            }
            return Ok(());
        }
        if Arc::strong_count(self.table[pi].as_ref().unwrap()) > 1 {
            let mut fresh = match self.pool.alloc() {
                Some(p) => p,
                None => bail!(
                    "kv pool exhausted ({} of {} pages in use)",
                    self.pool.pages_in_use(),
                    self.pool.capacity()
                ),
            };
            let old = self.table[pi].take().unwrap();
            {
                let dst = Arc::get_mut(&mut fresh).expect("freshly allocated page is unique");
                dst.k.data.copy_from_slice(&old.k.data);
                dst.v.data.copy_from_slice(&old.v.data);
            }
            self.table[pi] = Some(fresh);
            self.pool.release(old);
            self.pool.note_fork();
        }
        Ok(())
    }

    /// Append one K/V row pair, overwriting the oldest row once full
    /// (identical return contract to the ring `KvCache::push`: `true`
    /// iff an old row was overwritten). The target page is self-healed
    /// via [`PagedKv::ensure_capacity`] if the caller skipped the
    /// reservation; that path panics on pool exhaustion, so reserve
    /// first whenever refusal (not panic) is the desired failure mode.
    pub fn push(&mut self, k: &[f32], v: &[f32]) -> bool {
        self.ensure_capacity(1)
            .expect("kv pool exhausted (reserve with ensure_capacity before push)");
        let s = self.write_slot(0);
        let r = self.page_rows();
        let page = self.table[s / r].as_mut().unwrap();
        let page = Arc::get_mut(page).expect("write page is uniquely owned after ensure_capacity");
        page.k.row_mut(s % r).copy_from_slice(k);
        page.v.row_mut(s % r).copy_from_slice(v);
        if self.len == self.cap_rows {
            self.start = (self.start + 1) % self.cap_rows;
            true
        } else {
            self.len += 1;
            false
        }
    }

    /// Shrink to at most `len` rows (newest rows are discarded — this
    /// backs speculative rollback) and release any page that no longer
    /// covers a live slot.
    pub fn truncate(&mut self, len: usize) {
        self.len = self.len.min(len);
        self.gc_dead_pages();
    }

    /// Drop all rows and return every mapped page to the pool.
    pub fn clear(&mut self) {
        self.start = 0;
        self.len = 0;
        for entry in self.table.iter_mut() {
            if let Some(p) = entry.take() {
                self.pool.release(p);
            }
        }
    }

    /// Release mapped pages covering no live slot. Liveness of physical
    /// slot `s` under ring addressing: `(s + cap - start) % cap < len`.
    fn gc_dead_pages(&mut self) {
        let r = self.page_rows();
        for pi in 0..self.table.len() {
            if self.table[pi].is_none() {
                continue;
            }
            let lo = pi * r;
            let hi = (lo + r).min(self.cap_rows);
            let any_live = (lo..hi)
                .any(|s| (s + self.cap_rows - self.start) % self.cap_rows < self.len);
            if !any_live {
                let p = self.table[pi].take().unwrap();
                self.pool.release(p);
            }
        }
    }

    /// Adopt `rows` rows of prefix content by sharing `pages` (cloned
    /// Arcs — zero copies). Only legal on an empty, unwrapped cache;
    /// the shared pages are forked lazily if this session ever writes
    /// into them.
    pub fn seed_prefix(&mut self, pages: &[Arc<Page>], rows: usize) -> Result<()> {
        if self.len != 0 || self.start != 0 {
            bail!("seed_prefix requires an empty cache");
        }
        let r = self.page_rows();
        let need = rows.div_ceil(r);
        if rows == 0 || rows > self.cap_rows || pages.len() != need {
            bail!(
                "seed_prefix shape mismatch: {} rows need {} pages, got {}",
                rows,
                need,
                pages.len()
            );
        }
        for (i, p) in pages.iter().enumerate() {
            self.table[i] = Some(Arc::clone(p));
        }
        self.len = rows;
        Ok(())
    }

    /// Clone out the first `rows` rows as shareable pages, for
    /// registration in a [`PrefixCache`]. `None` unless the cache is
    /// unwrapped (`start == 0`), holds at least `rows`, and `rows` is
    /// page-aligned — sharing a partially written page would let this
    /// session's next push mutate rows another session reads.
    pub fn prefix_pages(&self, rows: usize) -> Option<Vec<Arc<Page>>> {
        let r = self.page_rows();
        if self.start != 0 || rows == 0 || rows > self.len || rows % r != 0 {
            return None;
        }
        Some(self.table[..rows / r].iter().map(|p| Arc::clone(p.as_ref().unwrap())).collect())
    }

    /// Mapped pages currently held by this cache's block table.
    pub fn pages_held(&self) -> usize {
        self.table.iter().filter(|p| p.is_some()).count()
    }

    /// Held pages that are shared with at least one other owner.
    pub fn shared_pages(&self) -> usize {
        self.table
            .iter()
            .filter(|p| p.as_ref().map(|p| Arc::strong_count(p) > 1).unwrap_or(false))
            .count()
    }
}

impl Drop for PagedKv {
    fn drop(&mut self) {
        self.clear();
    }
}

/// Block tables for all layers of one registered prefix:
/// `pages[layer][page_index]`.
pub type LayerPages = Vec<Vec<Arc<Page>>>;

/// A successful prefix-cache lookup: `rows` token positions whose K/V
/// content is already materialized in `pages` (one block table per
/// layer). The Arcs are transient clones — the owning cache entry
/// outlives them, so dropping a hit leaks nothing.
pub struct PrefixHit {
    pub rows: usize,
    pub pages: LayerPages,
}

struct PrefixEntry {
    tokens: Vec<u32>,
    pages: LayerPages,
    last_used: u64,
}

impl PrefixEntry {
    fn rows(&self) -> usize {
        self.tokens.len()
    }
}

/// Token-prefix → shared-page cache: sessions admitted with a common
/// system prompt seed their block tables from here instead of
/// recomputing (and re-storing) the same K/V rows. Sharing is safe and
/// bit-exact because K/V rows are deterministic functions of the causal
/// token prefix from position 0, and a shared page is COW-forked before
/// any session writes into it. Entries hold real page references and
/// are LRU-evicted (returning their pages) under pool pressure.
pub struct PrefixCache {
    pool: KvPool,
    entries: Vec<PrefixEntry>,
    max_entries: usize,
    tick: u64,
    hits: u64,
    misses: u64,
}

impl PrefixCache {
    /// A cache holding at most `max_entries` registered prefixes.
    pub fn new(pool: KvPool, max_entries: usize) -> PrefixCache {
        PrefixCache { pool, entries: Vec::new(), max_entries: max_entries.max(1), tick: 0, hits: 0, misses: 0 }
    }

    /// Rows per page of the backing pool.
    pub fn page_rows(&self) -> usize {
        self.pool.page_rows()
    }

    /// Lookup hits so far.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookup misses so far.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Registered prefixes.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no prefixes are registered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Longest usable page-aligned shared prefix of `tokens`, if any.
    /// The match is capped at `tokens.len() - 1` so a hit always leaves
    /// at least one token for the session to prefill into a fresh row
    /// (prefill needs a final row to produce logits from).
    pub fn lookup(&mut self, tokens: &[u32]) -> Option<PrefixHit> {
        let (bi, rows) = match self.best_match(tokens) {
            Some(m) => m,
            None => {
                self.misses += 1;
                return None;
            }
        };
        self.tick += 1;
        self.entries[bi].last_used = self.tick;
        self.hits += 1;
        let r = self.page_rows();
        let pages = self.entries[bi]
            .pages
            .iter()
            .map(|lp| lp[..rows / r].iter().map(Arc::clone).collect())
            .collect();
        Some(PrefixHit { rows, pages })
    }

    /// The rows a [`PrefixCache::lookup`] for `tokens` would return,
    /// without touching hit/miss stats or LRU order — for admission
    /// pricing (how many pages would this prompt actually need?).
    pub fn probe_rows(&self, tokens: &[u32]) -> usize {
        self.best_match(tokens).map(|(_, rows)| rows).unwrap_or(0)
    }

    fn best_match(&self, tokens: &[u32]) -> Option<(usize, usize)> {
        if tokens.len() < 2 {
            return None;
        }
        let r = self.page_rows();
        let mut best: Option<(usize, usize)> = None;
        for (i, e) in self.entries.iter().enumerate() {
            let cap = e.rows().min(tokens.len() - 1);
            let common =
                tokens[..cap].iter().zip(&e.tokens[..cap]).take_while(|(a, b)| a == b).count();
            let aligned = common / r * r;
            if aligned > 0 && best.map(|(_, b)| aligned > b).unwrap_or(true) {
                best = Some((i, aligned));
            }
        }
        best
    }

    /// Register a computed prefix: `pages[layer]` must each cover
    /// exactly `tokens.len()` rows (page-aligned). Malformed or
    /// duplicate registrations are dropped — their page references are
    /// released, not leaked.
    pub fn register(&mut self, tokens: Vec<u32>, pages: LayerPages) {
        let rows = tokens.len();
        let r = self.page_rows();
        let well_formed = rows > 0
            && rows % r == 0
            && !pages.is_empty()
            && pages.iter().all(|lp| lp.len() == rows / r);
        let duplicate = self
            .entries
            .iter()
            .any(|e| e.rows() >= rows && e.tokens[..rows] == tokens[..]);
        if !well_formed || duplicate {
            self.release_pages(pages);
            return;
        }
        while self.entries.len() >= self.max_entries {
            if !self.evict_lru() {
                break;
            }
        }
        self.tick += 1;
        self.entries.push(PrefixEntry { tokens, pages, last_used: self.tick });
    }

    fn release_pages(&self, pages: LayerPages) {
        for lp in pages {
            for p in lp {
                self.pool.release(p);
            }
        }
    }

    fn evict_lru(&mut self) -> bool {
        let oldest = match self
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(i, _)| i)
        {
            Some(i) => i,
            None => return false,
        };
        let e = self.entries.swap_remove(oldest);
        self.release_pages(e.pages);
        true
    }

    /// Evict least-recently-used prefixes until the pool has at least
    /// `want_free` allocatable pages (or the cache is empty). Called by
    /// the admission layer before refusing a request for lack of pages.
    pub fn shed(&mut self, want_free: usize) {
        while self.pool.free_pages() < want_free && self.evict_lru() {}
    }

    /// Drop every registered prefix, releasing all pages.
    pub fn clear(&mut self) {
        while self.evict_lru() {}
    }
}

impl Drop for PrefixCache {
    fn drop(&mut self) {
        self.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rowv(d: usize, seed: f32) -> Vec<f32> {
        (0..d).map(|i| seed + i as f32 * 0.25).collect()
    }

    #[test]
    fn pool_alloc_exhaust_release_recycle() {
        let pool = KvPool::new(2, 4, 3);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert!(pool.alloc().is_none(), "capacity 2 must refuse a third page");
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.free_pages(), 0);
        pool.release(a);
        assert_eq!(pool.free_pages(), 1);
        let c = pool.alloc().unwrap();
        assert_eq!(pool.pages_created(), 2, "release + alloc must reuse, not create");
        drop((b, c));
    }

    #[test]
    fn release_of_shared_page_keeps_it_alive() {
        let pool = KvPool::new(4, 2, 2);
        let a = pool.alloc().unwrap();
        let b = Arc::clone(&a);
        pool.release(a);
        // still one live owner: not recycled yet
        assert_eq!(pool.pages_in_use(), 1);
        pool.release(b);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn paged_ring_matches_contract() {
        let pool = KvPool::new(8, 2, 3);
        let mut kv = PagedKv::new(&pool, 4);
        assert!(kv.is_empty());
        for t in 0..4 {
            let over = kv.push(&rowv(3, t as f32), &rowv(3, 100.0 + t as f32));
            assert!(!over);
        }
        assert_eq!(kv.len(), 4);
        // full: next push overwrites the oldest
        assert!(kv.push(&rowv(3, 9.0), &rowv(3, 109.0)));
        assert_eq!(kv.len(), 4);
        assert_eq!(kv.k_row(0)[0], 1.0, "oldest surviving row is t=1");
        assert_eq!(kv.k_row(3)[0], 9.0, "newest row is the overwrite");
        assert_eq!(kv.v_row(3)[0], 109.0);
    }

    #[test]
    fn truncate_releases_dead_pages_and_clear_releases_all() {
        let pool = KvPool::new(8, 2, 2);
        let mut kv = PagedKv::new(&pool, 8);
        for t in 0..8 {
            kv.push(&rowv(2, t as f32), &rowv(2, t as f32));
        }
        assert_eq!(kv.pages_held(), 4);
        assert_eq!(pool.pages_in_use(), 4);
        kv.truncate(3); // rows 0..3 live → pages 0,1 live, pages 2,3 dead
        assert_eq!(kv.pages_held(), 2);
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(kv.k_row(2)[0], 2.0, "surviving rows untouched by GC");
        kv.clear();
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn drop_returns_all_pages() {
        let pool = KvPool::new(8, 2, 2);
        {
            let mut kv = PagedKv::new(&pool, 6);
            for t in 0..5 {
                kv.push(&rowv(2, t as f32), &rowv(2, t as f32));
            }
            assert!(pool.pages_in_use() > 0);
        }
        assert_eq!(pool.pages_in_use(), 0, "session drop must not leak pages");
    }

    #[test]
    fn cow_fork_isolates_writers() {
        let pool = KvPool::new(8, 2, 2);
        let mut a = PagedKv::new(&pool, 4);
        for t in 0..2 {
            a.push(&rowv(2, t as f32), &rowv(2, 50.0 + t as f32));
        }
        let prefix = a.prefix_pages(2).unwrap();
        let mut b = PagedKv::new(&pool, 4);
        b.seed_prefix(&prefix, 2).unwrap();
        drop(prefix);
        assert_eq!(b.k_row(1), a.k_row(1), "seeded rows read back shared content");
        assert_eq!(a.shared_pages(), 1);
        assert_eq!(pool.pages_in_use(), 1, "sharing holds one physical page");
        // b truncates into the shared page and writes: must fork first
        b.truncate(1);
        b.push(&rowv(2, 777.0), &rowv(2, 778.0));
        assert_eq!(pool.cow_forks(), 1);
        assert_eq!(a.k_row(1)[0], 1.0, "a's view survives b's divergent write");
        assert_eq!(b.k_row(1)[0], 777.0);
        assert_eq!(a.shared_pages(), 0, "fork ends the sharing");
    }

    #[test]
    fn seed_prefix_rejects_bad_shapes() {
        let pool = KvPool::new(8, 2, 2);
        let mut a = PagedKv::new(&pool, 4);
        for t in 0..4 {
            a.push(&rowv(2, t as f32), &rowv(2, t as f32));
        }
        let prefix = a.prefix_pages(2).unwrap();
        let mut b = PagedKv::new(&pool, 4);
        b.push(&rowv(2, 0.0), &rowv(2, 0.0));
        assert!(b.seed_prefix(&prefix, 2).is_err(), "non-empty cache must refuse seeding");
        // unaligned / oversized prefix requests are refused at the source
        assert!(a.prefix_pages(1).is_none(), "unaligned rows can't be shared");
        assert!(a.prefix_pages(6).is_none(), "can't share more rows than stored");
        assert!(a.prefix_pages(0).is_none());
    }

    #[test]
    fn ensure_capacity_prices_shared_pages_as_forks() {
        let pool = KvPool::new(3, 2, 2);
        let mut a = PagedKv::new(&pool, 4);
        a.push(&rowv(2, 0.0), &rowv(2, 0.0));
        a.push(&rowv(2, 1.0), &rowv(2, 1.0));
        let prefix = a.prefix_pages(2).unwrap();
        let mut b = PagedKv::new(&pool, 4);
        b.seed_prefix(&prefix, 2).unwrap();
        drop(prefix);
        b.truncate(1);
        // b's next write hits the shared page: needs a fork (1 page)
        assert_eq!(b.pages_needed(1), 1);
        // a's next write goes to an unmapped page: also 1
        assert_eq!(a.pages_needed(1), 1);
        // exhaustion is an error, not a panic, through ensure_capacity
        let c1 = pool.alloc().unwrap();
        let c2 = pool.alloc().unwrap();
        assert!(b.ensure_capacity(1).is_err());
        drop((c1, c2));
    }

    #[test]
    fn prefix_cache_lookup_register_lru() {
        let pool = KvPool::new(16, 2, 2);
        let mut pc = PrefixCache::new(pool.clone(), 2);
        let sys = vec![7u32, 8, 9, 10];
        let mut a = PagedKv::new(&pool, 8);
        for (t, _) in sys.iter().enumerate() {
            a.push(&rowv(2, t as f32), &rowv(2, t as f32));
        }
        pc.register(sys.clone(), vec![a.prefix_pages(4).unwrap()]);
        assert_eq!(pc.len(), 1);

        // full hit is capped at tokens.len()-1 then page-aligned
        let hit = pc.lookup(&[7, 8, 9, 10, 11]).unwrap();
        assert_eq!(hit.rows, 4);
        let hit2 = pc.lookup(&[7, 8, 9, 10]).unwrap();
        assert_eq!(hit2.rows, 2, "must leave >=1 token to prefill");
        assert_eq!(pc.probe_rows(&[7, 8, 9, 10, 11]), 4);
        assert_eq!(pc.probe_rows(&[1, 2, 3]), 0);
        assert!(pc.lookup(&[1, 2, 3]).is_none());
        assert_eq!((pc.hits(), pc.misses()), (2, 1));

        // duplicate registration releases, not leaks
        let in_use = pool.pages_in_use();
        pc.register(sys.clone(), vec![a.prefix_pages(4).unwrap()]);
        assert_eq!(pc.len(), 1);
        assert_eq!(pool.pages_in_use(), in_use);

        // capacity-2 cache LRU-evicts the stalest entry
        let mut b = PagedKv::new(&pool, 8);
        for t in 0..2 {
            b.push(&rowv(2, 30.0 + t as f32), &rowv(2, t as f32));
        }
        pc.register(vec![20, 21], vec![b.prefix_pages(2).unwrap()]);
        let mut c = PagedKv::new(&pool, 8);
        for t in 0..2 {
            c.push(&rowv(2, 60.0 + t as f32), &rowv(2, t as f32));
        }
        pc.register(vec![40, 41], vec![c.prefix_pages(2).unwrap()]);
        assert_eq!(pc.len(), 2);
        assert_eq!(pc.probe_rows(&[20, 21, 22]), 0, "LRU entry [20,21] was evicted");
        assert!(pc.probe_rows(&[40, 41, 42]) > 0);
    }

    #[test]
    fn prefix_cache_shed_frees_pool_pressure() {
        let pool = KvPool::new(4, 2, 2);
        let mut pc = PrefixCache::new(pool.clone(), 4);
        let mut a = PagedKv::new(&pool, 4);
        for t in 0..4 {
            a.push(&rowv(2, t as f32), &rowv(2, t as f32));
        }
        pc.register(vec![1, 2, 3, 4], vec![a.prefix_pages(4).unwrap()]);
        drop(a); // cache is now the only owner of those 2 pages
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.free_pages(), 2);
        pc.shed(4);
        assert_eq!(pool.free_pages(), 4, "shed evicts entries until the target frees up");
        assert!(pc.is_empty());
    }

    #[test]
    fn prefix_cache_drop_releases_pages() {
        let pool = KvPool::new(4, 2, 2);
        {
            let mut pc = PrefixCache::new(pool.clone(), 4);
            let mut a = PagedKv::new(&pool, 4);
            a.push(&rowv(2, 0.0), &rowv(2, 0.0));
            a.push(&rowv(2, 1.0), &rowv(2, 1.0));
            pc.register(vec![1, 2], vec![a.prefix_pages(2).unwrap()]);
            drop(a);
            assert_eq!(pool.pages_in_use(), 1);
        }
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn shared_note_is_a_peak_gauge() {
        let pool = KvPool::new(2, 2, 2);
        pool.note_shared(3);
        pool.note_shared(1);
        assert_eq!(pool.shared_pages_note(), 3);
        pool.note_shared(5);
        assert_eq!(pool.shared_pages_note(), 5);
    }
}
