//! GPT-2 forward implementation (see mod.rs for the role of this module).
//!
//! Two forward shapes share one set of per-row primitives (`layer_norm`,
//! `proj`, `attend_row`, `gelu_inplace`):
//!
//! * [`Gpt2Model::forward`] — the fixed-shape batch pass ([B][S] in, all
//!   logits out), used for scoring and calibration.
//! * the incremental pair [`Gpt2Model::forward_session`] (append S new
//!   rows — prefill) / [`Gpt2Model::decode_step_sessions`] (one token for
//!   G live sessions — decode) around per-layer [`KvCache`]s, used by
//!   `gpt2::session` for O(context) per-token generation instead of the
//!   O(context²) full re-forward per token.
//!
//! Because every shared primitive is row-independent (each output row
//! depends only on its own input row), the incremental path is
//! *bit-exact* against the batch pass over the same prefix — the oracle
//! property `tests/decode_session.rs` pins across ragged prompt lengths
//! and cache states.

use super::kvpool::{KvPool, Page, PagedKv};
use crate::data::tensors::TensorFile;
use crate::quant::gemm::matmul_f32;
use crate::quant::{MatF32, QuantSpec};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

/// The four quantized projection sites (paper §4.3), in block order.
pub const PROJ_SITES: [&str; 4] = ["c_attn", "attn_proj", "c_fc", "mlp_proj"];

/// Architecture hyper-parameters (twin of python `ModelConfig`).
#[derive(Debug, Clone)]
pub struct Gpt2Config {
    pub name: String,
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub n_ctx: usize,
    pub vocab_size: usize,
}

impl Gpt2Config {
    pub fn sim(name: &str) -> Result<Gpt2Config> {
        let (n_layer, d_model, n_head) = match name {
            "sim-small" => (4, 128, 4),
            "sim-medium" => (6, 192, 6),
            "sim-large" => (8, 256, 8),
            _ => bail!("unknown sim model {name:?}"),
        };
        Ok(Gpt2Config {
            name: name.into(),
            n_layer,
            d_model,
            n_head,
            n_ctx: 128,
            vocab_size: 512,
        })
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_head
    }

    pub fn d_ff(&self) -> usize {
        4 * self.d_model
    }
}

#[derive(Clone)]
struct LayerNorm {
    g: Vec<f32>,
    b: Vec<f32>,
}

#[derive(Clone)]
struct Linear {
    w: MatF32, // [in, out] (HF Conv1D convention)
    b: Vec<f32>,
}

#[derive(Clone)]
struct Block {
    ln_1: LayerNorm,
    c_attn: Linear,
    attn_proj: Linear,
    ln_2: LayerNorm,
    c_fc: Linear,
    mlp_proj: Linear,
}

/// Per-(layer, site) channel abs-max capture (Fig. 1 data).
pub type SiteCapture = BTreeMap<(usize, &'static str), Vec<f32>>;

/// Projection-site override: (input activations, site name, layer index)
/// -> projected output (weights + bias applied by the callee).
pub type ProjFn<'a> = dyn FnMut(&MatF32, &'static str, usize) -> MatF32 + 'a;

/// Which logits a session extend computes: all new rows (scoring /
/// oracle), only the last row (prompt prefill — the tied-head GEMM over
/// the other rows is pure waste), or none (wrap re-prefill).
#[derive(Clone, Copy, PartialEq, Eq)]
enum LogitsMode {
    All,
    LastRow,
    None,
}

/// Per-layer key/value cache for incremental decode, ring-buffered to a
/// fixed capacity (`n_ctx` in every real use). K and V rows are stored
/// d_model wide — all heads concatenated, the exact slices the qkv
/// projection produces — so a cache row is a straight copy of the
/// projection output and decode attention reads it back bit-identical.
///
/// `push` appends; once the buffer is full it overwrites the *oldest*
/// row (ring advance). Whether that ever happens is the session layer's
/// decision (`gpt2::session::WrapPolicy`): the exactness-preserving
/// policy re-prefills before the ring wraps, the sliding policy lets it
/// wrap. Logical index 0 always names the oldest live row.
///
/// Two interchangeable backings present this one surface: the original
/// contiguous ring ([`KvCache::new`]) and a paged block table over a
/// shared [`KvPool`] ([`KvCache::paged`]). Reads and pushes are
/// bit-identical across backings; only the paged one can refuse a write
/// (pool exhausted — surfaced through [`KvCache::ensure_capacity`]) or
/// share prefix pages with other sessions.
pub struct KvCache {
    b: Backing,
}

enum Backing {
    Ring(RingKv),
    Paged(PagedKv),
}

/// The original ring storage: one contiguous `[cap, d_model]` K and V
/// matrix owned by this cache alone.
struct RingKv {
    k: MatF32, // [cap, d_model]
    v: MatF32,
    start: usize,
    len: usize,
}

impl RingKv {
    fn cap(&self) -> usize {
        self.k.rows
    }

    #[inline(always)]
    fn slot(&self, logical: usize) -> usize {
        debug_assert!(logical < self.len);
        (self.start + logical) % self.cap()
    }

    fn push(&mut self, k_row: &[f32], v_row: &[f32]) -> bool {
        let cap = self.cap();
        if self.len == cap {
            let slot = self.start;
            self.k.row_mut(slot).copy_from_slice(k_row);
            self.v.row_mut(slot).copy_from_slice(v_row);
            self.start = (self.start + 1) % cap;
            true
        } else {
            let slot = (self.start + self.len) % cap;
            self.k.row_mut(slot).copy_from_slice(k_row);
            self.v.row_mut(slot).copy_from_slice(v_row);
            self.len += 1;
            false
        }
    }
}

impl KvCache {
    /// Ring-backed cache: private contiguous storage, never refuses a
    /// write. The pre-pager layout, kept as the differential oracle.
    pub fn new(cap: usize, d_model: usize) -> KvCache {
        assert!(cap > 0, "zero-capacity kv cache");
        KvCache {
            b: Backing::Ring(RingKv {
                k: MatF32::zeros(cap, d_model),
                v: MatF32::zeros(cap, d_model),
                start: 0,
                len: 0,
            }),
        }
    }

    /// Paged cache drawing fixed-size pages from a shared [`KvPool`].
    /// Pages are allocated lazily as rows are written and returned on
    /// clear/truncate/drop.
    pub fn paged(pool: &KvPool, cap: usize) -> KvCache {
        KvCache { b: Backing::Paged(PagedKv::new(pool, cap)) }
    }

    /// Whether this cache is pool-backed.
    pub fn is_paged(&self) -> bool {
        matches!(self.b, Backing::Paged(_))
    }

    pub fn cap(&self) -> usize {
        match &self.b {
            Backing::Ring(r) => r.cap(),
            Backing::Paged(p) => p.cap(),
        }
    }

    pub fn len(&self) -> usize {
        match &self.b {
            Backing::Ring(r) => r.len,
            Backing::Paged(p) => p.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&mut self) {
        match &mut self.b {
            Backing::Ring(r) => {
                r.start = 0;
                r.len = 0;
            }
            Backing::Paged(p) => p.clear(),
        }
    }

    /// K row at logical index (0 = oldest live entry).
    #[inline(always)]
    pub fn k_row(&self, logical: usize) -> &[f32] {
        match &self.b {
            Backing::Ring(r) => r.k.row(r.slot(logical)),
            Backing::Paged(p) => p.k_row(logical),
        }
    }

    /// V row at logical index (0 = oldest live entry).
    #[inline(always)]
    pub fn v_row(&self, logical: usize) -> &[f32] {
        match &self.b {
            Backing::Ring(r) => r.v.row(r.slot(logical)),
            Backing::Paged(p) => p.v_row(logical),
        }
    }

    /// Drop the NEWEST rows so only the oldest `len` remain — the
    /// speculative-decode rollback: a rejected draft's K/V rows are
    /// logically at the tail, so truncation restores the cache to the
    /// accepted prefix exactly (`start` is untouched; the retained rows
    /// keep their slots, so attention reads them back bit-identical).
    /// A `len` at or above the current length is a no-op. A paged cache
    /// additionally releases pages left covering no live row.
    pub fn truncate(&mut self, len: usize) {
        match &mut self.b {
            Backing::Ring(r) => r.len = r.len.min(len),
            Backing::Paged(p) => p.truncate(len),
        }
    }

    /// Append one K/V row pair; when full, overwrite the oldest entry
    /// instead (ring advance). Returns whether an eviction happened.
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32]) -> bool {
        match &mut self.b {
            Backing::Ring(r) => r.push(k_row, v_row),
            Backing::Paged(p) => p.push(k_row, v_row),
        }
    }

    /// Reserve backing storage for the next `rows` pushes. A ring cache
    /// always succeeds; a paged cache allocates (or COW-forks) every
    /// page those writes will touch, erroring — before any row is
    /// written — when the pool is exhausted.
    pub fn ensure_capacity(&mut self, rows: usize) -> Result<()> {
        match &mut self.b {
            Backing::Ring(_) => Ok(()),
            Backing::Paged(p) => p.ensure_capacity(rows),
        }
    }

    /// Pages the next `rows` pushes would have to allocate or fork
    /// (0 for a ring cache) — the admission layer's pricing input.
    pub fn pages_needed(&self, rows: usize) -> usize {
        match &self.b {
            Backing::Ring(_) => 0,
            Backing::Paged(p) => p.pages_needed(rows),
        }
    }

    /// Pages `rows` rows occupy at this cache's page size, ignoring
    /// current state (0 for a ring cache) — worst-case pricing for a
    /// cache that will be cleared and re-prefilled.
    pub fn pages_for(&self, rows: usize) -> usize {
        match &self.b {
            Backing::Ring(_) => 0,
            Backing::Paged(p) => {
                let r = p.page_size();
                rows.min(p.cap()).div_ceil(r)
            }
        }
    }

    /// Mapped pages held by this cache (0 for a ring cache).
    pub fn pages_held(&self) -> usize {
        match &self.b {
            Backing::Ring(_) => 0,
            Backing::Paged(p) => p.pages_held(),
        }
    }

    /// Held pages shared with another owner (0 for a ring cache).
    pub fn shared_pages(&self) -> usize {
        match &self.b {
            Backing::Ring(_) => 0,
            Backing::Paged(p) => p.shared_pages(),
        }
    }

    /// Adopt `rows` rows of shared prefix pages (paged backing only).
    pub fn seed_prefix(&mut self, pages: &[Arc<Page>], rows: usize) -> Result<()> {
        match &mut self.b {
            Backing::Ring(_) => bail!("seed_prefix requires a paged kv cache"),
            Backing::Paged(p) => p.seed_prefix(pages, rows),
        }
    }

    /// Clone out the first `rows` rows as shareable pages (`None` on a
    /// ring backing or when the request is unaligned/oversized).
    pub fn prefix_pages(&self, rows: usize) -> Option<Vec<Arc<Page>>> {
        match &self.b {
            Backing::Ring(_) => None,
            Backing::Paged(p) => p.prefix_pages(rows),
        }
    }
}

/// Loaded GPT-2 model.
pub struct Gpt2Model {
    pub cfg: Gpt2Config,
    wte: MatF32, // [V, d]
    wpe: MatF32, // [ctx, d]
    ln_f: LayerNorm,
    blocks: Vec<Block>,
    /// tied head transpose [d, V], built on first use — the decode path
    /// hits the head every token and must not re-transpose wte each time
    wte_t: OnceLock<MatF32>,
}

impl Clone for Gpt2Model {
    /// Deep copy of the weights (the lazy head transpose restarts empty)
    /// — lets one loaded model back several quantized deployments.
    fn clone(&self) -> Gpt2Model {
        Gpt2Model {
            cfg: self.cfg.clone(),
            wte: self.wte.clone(),
            wpe: self.wpe.clone(),
            ln_f: self.ln_f.clone(),
            blocks: self.blocks.clone(),
            wte_t: OnceLock::new(),
        }
    }
}

impl Gpt2Model {
    /// Load from the tensor container written by the python build.
    pub fn load(cfg: Gpt2Config, weights: &TensorFile) -> Result<Gpt2Model> {
        let mat = |name: &str| -> Result<MatF32> {
            let t = weights.get(name)?;
            if t.dims.len() != 2 {
                bail!("{name} is not 2-D");
            }
            MatF32::from_vec(t.dims[0], t.dims[1], t.as_f32()?)
        };
        let vec = |name: &str| -> Result<Vec<f32>> { weights.get(name)?.as_f32() };
        let ln = |prefix: &str| -> Result<LayerNorm> {
            Ok(LayerNorm { g: vec(&format!("{prefix}/g"))?, b: vec(&format!("{prefix}/b"))? })
        };
        let lin = |prefix: &str| -> Result<Linear> {
            Ok(Linear { w: mat(&format!("{prefix}/w"))?, b: vec(&format!("{prefix}/b"))? })
        };
        let mut blocks = Vec::with_capacity(cfg.n_layer);
        for i in 0..cfg.n_layer {
            let p = format!("block{i:02}");
            blocks.push(Block {
                ln_1: ln(&format!("{p}/ln_1"))?,
                c_attn: lin(&format!("{p}/c_attn"))?,
                attn_proj: lin(&format!("{p}/attn_proj"))?,
                ln_2: ln(&format!("{p}/ln_2"))?,
                c_fc: lin(&format!("{p}/c_fc"))?,
                mlp_proj: lin(&format!("{p}/mlp_proj"))?,
            });
        }
        let model = Gpt2Model {
            wte: mat("wte")?,
            wpe: mat("wpe")?,
            ln_f: ln("ln_f")?,
            blocks,
            cfg,
            wte_t: OnceLock::new(),
        };
        if model.wte.rows != model.cfg.vocab_size || model.wte.cols != model.cfg.d_model {
            bail!(
                "wte shape {}x{} inconsistent with config {:?}",
                model.wte.rows,
                model.wte.cols,
                model.cfg
            );
        }
        Ok(model)
    }

    pub fn load_from_artifacts(name: &str) -> Result<Gpt2Model> {
        let cfg = Gpt2Config::sim(name)?;
        let path = crate::artifacts_dir().join("weights").join(format!("{name}.bin"));
        let weights = TensorFile::read(&path)
            .with_context(|| format!("load weights for {name} — run `make artifacts` first"))?;
        Self::load(cfg, &weights)
    }

    /// Forward pass over one sequence batch. `tokens` is [B][S]; returns
    /// logits [B*S, V]. `quant` applies to the four projection sites;
    /// `capture` records per-site input abs-max per channel.
    pub fn forward(
        &self,
        tokens: &[Vec<u32>],
        quant: Option<&QuantSpec>,
        capture: Option<&mut SiteCapture>,
    ) -> Result<MatF32> {
        self.forward_impl(tokens, quant, capture, None)
    }

    /// Forward with every projection site computed by `proj_fn(x, site,
    /// layer)` — the hook the true-INT pipeline (`quantized.rs`) uses.
    /// The callback is responsible for weights AND bias.
    pub fn forward_with_proj(
        &self,
        tokens: &[Vec<u32>],
        proj_fn: &mut ProjFn<'_>,
    ) -> Result<MatF32> {
        self.forward_impl(tokens, None, None, Some(proj_fn))
    }

    fn forward_impl(
        &self,
        tokens: &[Vec<u32>],
        quant: Option<&QuantSpec>,
        mut capture: Option<&mut SiteCapture>,
        mut proj_fn: Option<&mut ProjFn<'_>>,
    ) -> Result<MatF32> {
        let b = tokens.len();
        let s = tokens.first().map(|t| t.len()).unwrap_or(0);
        if s == 0 || s > self.cfg.n_ctx {
            bail!("sequence length {s} out of range (ctx {})", self.cfg.n_ctx);
        }
        let d = self.cfg.d_model;
        // embeddings
        let mut h = MatF32::zeros(b * s, d);
        for (bi, seq) in tokens.iter().enumerate() {
            if seq.len() != s {
                bail!("ragged batch");
            }
            for (si, &tok) in seq.iter().enumerate() {
                if tok as usize >= self.cfg.vocab_size {
                    bail!("token {tok} out of vocab");
                }
                let row = h.row_mut(bi * s + si);
                let e = self.wte.row(tok as usize);
                let p = self.wpe.row(si);
                for i in 0..d {
                    row[i] = e[i] + p[i];
                }
            }
        }

        for (li, blk) in self.blocks.iter().enumerate() {
            // ---- attention
            let x = layer_norm(&h, &blk.ln_1);
            if let Some(cap) = capture.as_deref_mut() {
                cap.insert((li, "c_attn"), x.absmax_cols());
            }
            let qkv = match proj_fn.as_deref_mut() {
                Some(f) => f(&x, "c_attn", li),
                None => proj(&x, &blk.c_attn, quant),
            }; // [b*s, 3d]
            let att_out = self.attention(&qkv, b, s)?;
            if let Some(cap) = capture.as_deref_mut() {
                cap.insert((li, "attn_proj"), att_out.absmax_cols());
            }
            let att_proj = match proj_fn.as_deref_mut() {
                Some(f) => f(&att_out, "attn_proj", li),
                None => proj(&att_out, &blk.attn_proj, quant),
            };
            add_inplace(&mut h, &att_proj);

            // ---- MLP
            let x = layer_norm(&h, &blk.ln_2);
            if let Some(cap) = capture.as_deref_mut() {
                cap.insert((li, "c_fc"), x.absmax_cols());
            }
            let mut u = match proj_fn.as_deref_mut() {
                Some(f) => f(&x, "c_fc", li),
                None => proj(&x, &blk.c_fc, quant),
            };
            gelu_inplace(&mut u);
            if let Some(cap) = capture.as_deref_mut() {
                cap.insert((li, "mlp_proj"), u.absmax_cols());
            }
            let m = match proj_fn.as_deref_mut() {
                Some(f) => f(&u, "mlp_proj", li),
                None => proj(&u, &blk.mlp_proj, quant),
            };
            add_inplace(&mut h, &m);
        }

        let hf = layer_norm(&h, &self.ln_f);
        // tied head: logits = h @ wte^T (never quantized, per the paper)
        Ok(matmul_f32(&hf, self.head_t()))
    }

    /// Transposed tied head, built lazily and cached.
    fn head_t(&self) -> &MatF32 {
        self.wte_t.get_or_init(|| self.wte.transpose())
    }

    fn attention(&self, qkv: &MatF32, b: usize, s: usize) -> Result<MatF32> {
        let d = self.cfg.d_model;
        let nh = self.cfg.n_head;
        let dh = self.cfg.d_head();
        let scale = 1.0 / (dh as f32).sqrt();
        let mut out = MatF32::zeros(b * s, d);
        let mut att: Vec<f32> = Vec::new();
        for bi in 0..b {
            for qi in 0..s {
                let qrow = qkv.row(bi * s + qi);
                attend_row(
                    nh,
                    dh,
                    scale,
                    qi + 1,
                    &qrow[..d],
                    |ki| &qkv.row(bi * s + ki)[d..2 * d],
                    |ki| &qkv.row(bi * s + ki)[2 * d..3 * d],
                    &mut att,
                    out.row_mut(bi * s + qi),
                );
            }
        }
        Ok(out)
    }

    /// Incremental forward (the prefill half of the decode split):
    /// append `tokens` — assigned absolute positions `pos0..pos0+s` — to
    /// the per-layer `caches` and return the logits of the NEW rows only
    /// (`[s, vocab]`). The session layer calls this once over the whole
    /// prompt at its *true* length (no padding rows, so attention never
    /// attends over pad positions); the wrap re-prefill uses the
    /// logits-free twin [`Gpt2Model::forward_session_no_logits`].
    ///
    /// Caches must have room for every new row — ring eviction mid-call
    /// would silently change which keys the earlier new rows saw, so it
    /// is refused here and handled above (`gpt2::session::WrapPolicy`).
    ///
    /// With a row-independent projection (plain f32, or the quantized
    /// session projection), the result is bit-identical to the matching
    /// rows of [`Gpt2Model::forward`] over the same prefix.
    pub fn forward_session(
        &self,
        tokens: &[u32],
        pos0: usize,
        caches: &mut [KvCache],
        proj_fn: Option<&mut ProjFn<'_>>,
    ) -> Result<MatF32> {
        Ok(self.forward_session_impl(tokens, pos0, caches, proj_fn, LogitsMode::All)?.unwrap())
    }

    /// [`Gpt2Model::forward_session`] for callers that only want the KV
    /// side effects (the wrap re-prefill, which discards logits): skips
    /// the final layer-norm and the tied-head GEMM — at real model
    /// scale the head (`keep × d × V`) is the single largest matmul in
    /// the pass, pure waste when the result is dropped.
    pub fn forward_session_no_logits(
        &self,
        tokens: &[u32],
        pos0: usize,
        caches: &mut [KvCache],
        proj_fn: Option<&mut ProjFn<'_>>,
    ) -> Result<()> {
        self.forward_session_impl(tokens, pos0, caches, proj_fn, LogitsMode::None)?;
        Ok(())
    }

    /// [`Gpt2Model::forward_session`] computing the HEAD for the last
    /// row only — the prompt-prefill case, where only the final row's
    /// logits (the next-token distribution) are ever read. The blocks
    /// still process every row (their K/V must land in the caches), but
    /// the final layer-norm + tied-head GEMM shrink from `[s, d]·[d, V]`
    /// to `[1, d]·[d, V]` — at real vocab sizes the single largest
    /// matmul of a prefill, cut by the prompt length. Bit-exact against
    /// the last row of [`Gpt2Model::forward_session`]: both primitives
    /// are row-independent.
    pub fn forward_session_last_logits(
        &self,
        tokens: &[u32],
        pos0: usize,
        caches: &mut [KvCache],
        proj_fn: Option<&mut ProjFn<'_>>,
    ) -> Result<Vec<f32>> {
        let out =
            self.forward_session_impl(tokens, pos0, caches, proj_fn, LogitsMode::LastRow)?;
        Ok(out.unwrap().data)
    }

    fn forward_session_impl(
        &self,
        tokens: &[u32],
        pos0: usize,
        caches: &mut [KvCache],
        mut proj_fn: Option<&mut ProjFn<'_>>,
        logits: LogitsMode,
    ) -> Result<Option<MatF32>> {
        let s = tokens.len();
        let d = self.cfg.d_model;
        if s == 0 || pos0 + s > self.cfg.n_ctx {
            bail!("session extend [{pos0}, {}) out of range (ctx {})", pos0 + s, self.cfg.n_ctx);
        }
        if caches.len() != self.cfg.n_layer {
            bail!("{} kv caches for {} layers", caches.len(), self.cfg.n_layer);
        }
        let base = caches[0].len();
        for c in caches.iter_mut() {
            if c.len() != base {
                bail!("per-layer kv caches out of sync ({} vs {base})", c.len());
            }
            if base + s > c.cap() {
                bail!(
                    "kv cache overflow: {base} + {s} > {} — wrap is the session layer's job",
                    c.cap()
                );
            }
            // paged backing: reserve (alloc / COW-fork) the pages these S
            // pushes will hit, so exhaustion errors out here rather than
            // panicking mid-write
            c.ensure_capacity(s)?;
        }
        let mut h = MatF32::zeros(s, d);
        for (si, &tok) in tokens.iter().enumerate() {
            if tok as usize >= self.cfg.vocab_size {
                bail!("token {tok} out of vocab");
            }
            let row = h.row_mut(si);
            let e = self.wte.row(tok as usize);
            let p = self.wpe.row(pos0 + si);
            for i in 0..d {
                row[i] = e[i] + p[i];
            }
        }
        let nh = self.cfg.n_head;
        let dh = self.cfg.d_head();
        let scale = 1.0 / (dh as f32).sqrt();
        let mut att: Vec<f32> = Vec::new();
        for (li, blk) in self.blocks.iter().enumerate() {
            // ---- attention
            let x = layer_norm(&h, &blk.ln_1);
            let qkv = match proj_fn.as_deref_mut() {
                Some(f) => f(&x, "c_attn", li),
                None => proj(&x, &blk.c_attn, None),
            };
            let cache = &mut caches[li];
            for si in 0..s {
                let row = qkv.row(si);
                cache.push(&row[d..2 * d], &row[2 * d..3 * d]);
            }
            let cache = &caches[li];
            let mut att_out = MatF32::zeros(s, d);
            for si in 0..s {
                let qrow = qkv.row(si);
                attend_row(
                    nh,
                    dh,
                    scale,
                    base + si + 1,
                    &qrow[..d],
                    |ki| cache.k_row(ki),
                    |ki| cache.v_row(ki),
                    &mut att,
                    att_out.row_mut(si),
                );
            }
            let att_proj = match proj_fn.as_deref_mut() {
                Some(f) => f(&att_out, "attn_proj", li),
                None => proj(&att_out, &blk.attn_proj, None),
            };
            add_inplace(&mut h, &att_proj);

            // ---- MLP
            let x = layer_norm(&h, &blk.ln_2);
            let mut u = match proj_fn.as_deref_mut() {
                Some(f) => f(&x, "c_fc", li),
                None => proj(&x, &blk.c_fc, None),
            };
            gelu_inplace(&mut u);
            let m = match proj_fn.as_deref_mut() {
                Some(f) => f(&u, "mlp_proj", li),
                None => proj(&u, &blk.mlp_proj, None),
            };
            add_inplace(&mut h, &m);
        }
        match logits {
            LogitsMode::None => Ok(None),
            LogitsMode::All => {
                let hf = layer_norm(&h, &self.ln_f);
                Ok(Some(matmul_f32(&hf, self.head_t())))
            }
            LogitsMode::LastRow => {
                // row-independent primitives: norming + heading only the
                // last row is bit-identical to slicing the full result
                let last = MatF32::from_vec(1, d, h.row(s - 1).to_vec())?;
                let hf = layer_norm(&last, &self.ln_f);
                Ok(Some(matmul_f32(&hf, self.head_t())))
            }
        }
    }

    /// One decode step for G independent sessions, coalesced: the four
    /// projection sites each run as ONE skinny `[G, ·]` GEMM (small G
    /// routes to the packed engine's GEMV path) while attention stays
    /// per-session against its own cache. `tokens[g]` / `positions[g]` /
    /// `caches[g]` describe session g; returns logits `[G, vocab]`.
    ///
    /// With row-independent projections each session's logits row is
    /// bit-identical to stepping that session alone — continuous
    /// batching is transparent to clients. Unlike
    /// [`Gpt2Model::forward_session`] this path permits ring eviction: a
    /// full cache drops its oldest entry as the new token lands (the
    /// Slide wrap policy).
    pub fn decode_step_sessions(
        &self,
        tokens: &[u32],
        positions: &[usize],
        caches: &mut [&mut [KvCache]],
        mut proj_fn: Option<&mut ProjFn<'_>>,
    ) -> Result<MatF32> {
        let g = tokens.len();
        let d = self.cfg.d_model;
        if g == 0 || positions.len() != g || caches.len() != g {
            bail!(
                "decode step: {g} tokens, {} positions, {} cache sets",
                positions.len(),
                caches.len()
            );
        }
        for (gi, cs) in caches.iter_mut().enumerate() {
            if cs.len() != self.cfg.n_layer {
                bail!("session {gi}: {} kv caches for {} layers", cs.len(), self.cfg.n_layer);
            }
            if positions[gi] >= self.cfg.n_ctx {
                bail!(
                    "session {gi}: position {} out of range (ctx {})",
                    positions[gi],
                    self.cfg.n_ctx
                );
            }
            if tokens[gi] as usize >= self.cfg.vocab_size {
                bail!("session {gi}: token {} out of vocab", tokens[gi]);
            }
            for c in cs.iter_mut() {
                // paged backing: the single push below may need a fresh
                // page (or a COW fork of a shared one) — reserve it now so
                // pool exhaustion is an error, not a mid-batch panic
                c.ensure_capacity(1)?;
            }
        }
        let mut h = MatF32::zeros(g, d);
        for gi in 0..g {
            let row = h.row_mut(gi);
            let e = self.wte.row(tokens[gi] as usize);
            let p = self.wpe.row(positions[gi]);
            for i in 0..d {
                row[i] = e[i] + p[i];
            }
        }
        let nh = self.cfg.n_head;
        let dh = self.cfg.d_head();
        let scale = 1.0 / (dh as f32).sqrt();
        let mut att: Vec<f32> = Vec::new();
        for (li, blk) in self.blocks.iter().enumerate() {
            // ---- attention
            let x = layer_norm(&h, &blk.ln_1);
            let qkv = match proj_fn.as_deref_mut() {
                Some(f) => f(&x, "c_attn", li),
                None => proj(&x, &blk.c_attn, None),
            };
            let mut att_out = MatF32::zeros(g, d);
            for gi in 0..g {
                let row = qkv.row(gi);
                let cache = &mut caches[gi][li];
                cache.push(&row[d..2 * d], &row[2 * d..3 * d]);
                let cache = &caches[gi][li];
                attend_row(
                    nh,
                    dh,
                    scale,
                    cache.len(),
                    &row[..d],
                    |ki| cache.k_row(ki),
                    |ki| cache.v_row(ki),
                    &mut att,
                    att_out.row_mut(gi),
                );
            }
            let att_proj = match proj_fn.as_deref_mut() {
                Some(f) => f(&att_out, "attn_proj", li),
                None => proj(&att_out, &blk.attn_proj, None),
            };
            add_inplace(&mut h, &att_proj);

            // ---- MLP
            let x = layer_norm(&h, &blk.ln_2);
            let mut u = match proj_fn.as_deref_mut() {
                Some(f) => f(&x, "c_fc", li),
                None => proj(&x, &blk.c_fc, None),
            };
            gelu_inplace(&mut u);
            let m = match proj_fn.as_deref_mut() {
                Some(f) => f(&u, "mlp_proj", li),
                None => proj(&u, &blk.mlp_proj, None),
            };
            add_inplace(&mut h, &m);
        }
        let hf = layer_norm(&h, &self.ln_f);
        Ok(matmul_f32(&hf, self.head_t()))
    }

    /// Fresh per-layer caches sized `[n_ctx, d_model]` for one session.
    pub fn new_kv_caches(&self) -> Vec<KvCache> {
        (0..self.cfg.n_layer)
            .map(|_| KvCache::new(self.cfg.n_ctx, self.cfg.d_model))
            .collect()
    }

    /// Fresh per-layer paged caches drawing from `pool`. The pool's row
    /// width must match the model (page buffers are shared across every
    /// session of this server, so the shape is a pool-level invariant).
    pub fn new_paged_kv_caches(&self, pool: &KvPool) -> Vec<KvCache> {
        assert_eq!(
            pool.d_model(),
            self.cfg.d_model,
            "kv pool row width does not match the model"
        );
        (0..self.cfg.n_layer).map(|_| KvCache::paged(pool, self.cfg.n_ctx)).collect()
    }

    /// Per-sequence NLL sums + token counts (twin of python nll_per_seq).
    pub fn nll_per_seq(
        &self,
        tokens: &[Vec<u32>],
        quant: Option<&QuantSpec>,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let logits = self.forward(tokens, quant, None)?;
        self.nll_from_logits(tokens, &logits)
    }

    /// Per-sequence NLL with a projection override (true-INT pipeline).
    pub fn nll_per_seq_with_proj(
        &self,
        tokens: &[Vec<u32>],
        proj_fn: &mut ProjFn<'_>,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let logits = self.forward_with_proj(tokens, proj_fn)?;
        self.nll_from_logits(tokens, &logits)
    }

    /// Borrow the raw (w, b) pairs of the four projection sites per block
    /// (c_attn, attn_proj, c_fc, mlp_proj) — used to build the
    /// pre-quantized deployment model.
    #[allow(clippy::type_complexity)]
    pub fn blocks_raw(
        &self,
    ) -> Vec<(&MatF32, &[f32], &MatF32, &[f32], &MatF32, &[f32], &MatF32, &[f32])> {
        self.blocks
            .iter()
            .map(|b| {
                (
                    &b.c_attn.w,
                    b.c_attn.b.as_slice(),
                    &b.attn_proj.w,
                    b.attn_proj.b.as_slice(),
                    &b.c_fc.w,
                    b.c_fc.b.as_slice(),
                    &b.mlp_proj.w,
                    b.mlp_proj.b.as_slice(),
                )
            })
            .collect()
    }

    /// Scale one ln_1 gain channel (test hook: creates activation
    /// outliers at the c_attn input, NOT function-preserving).
    pub fn scale_ln1_channel(&mut self, layer: usize, channel: usize, factor: f32) {
        self.blocks[layer].ln_1.g[channel] *= factor;
    }

    /// A shallow draft model: the first `n_layers` blocks with the same
    /// embeddings, final norm and tied head — the truncated-layer draft
    /// for speculative decoding (`gpt2::speculative`). Same vocab,
    /// context and width, so its sessions propose tokens the target can
    /// verify; only depth (and therefore per-token cost) shrinks.
    pub fn truncated(&self, n_layers: usize) -> Result<Gpt2Model> {
        if n_layers == 0 || n_layers > self.cfg.n_layer {
            bail!("truncated draft wants {n_layers} of {} layers", self.cfg.n_layer);
        }
        let mut cfg = self.cfg.clone();
        cfg.name = format!("{}-trunc{n_layers}", cfg.name);
        cfg.n_layer = n_layers;
        Ok(Gpt2Model {
            cfg,
            wte: self.wte.clone(),
            wpe: self.wpe.clone(),
            ln_f: self.ln_f.clone(),
            blocks: self.blocks[..n_layers].to_vec(),
            wte_t: OnceLock::new(),
        })
    }

    /// Build a randomly-initialized model (tests, benches, demos without
    /// artifacts). Deterministic in `seed`.
    pub fn test_model(
        n_layer: usize,
        d_model: usize,
        n_head: usize,
        n_ctx: usize,
        vocab_size: usize,
        seed: u64,
    ) -> Gpt2Model {
        use crate::data::prng::SplitMix64;
        let cfg = Gpt2Config {
            name: format!("test-{n_layer}l-{d_model}d"),
            n_layer,
            d_model,
            n_head,
            n_ctx,
            vocab_size,
        };
        let mut rng = SplitMix64::new(seed);
        let mut randmat = |r: usize, c: usize, std: f32| {
            MatF32::from_vec(
                r,
                c,
                (0..r * c).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0 * std).collect(),
            )
            .unwrap()
        };
        let d = d_model;
        let wte = randmat(vocab_size, d, 0.05);
        let wpe = randmat(n_ctx, d, 0.02);
        let mut blocks = Vec::with_capacity(n_layer);
        for _ in 0..n_layer {
            blocks.push(Block {
                ln_1: LayerNorm { g: vec![1.0; d], b: vec![0.0; d] },
                c_attn: Linear { w: randmat(d, 3 * d, 0.05), b: vec![0.0; 3 * d] },
                attn_proj: Linear { w: randmat(d, d, 0.05), b: vec![0.0; d] },
                ln_2: LayerNorm { g: vec![1.0; d], b: vec![0.0; d] },
                c_fc: Linear { w: randmat(d, 4 * d, 0.05), b: vec![0.0; 4 * d] },
                mlp_proj: Linear { w: randmat(4 * d, d, 0.05), b: vec![0.0; d] },
            });
        }
        Gpt2Model {
            cfg,
            wte,
            wpe,
            ln_f: LayerNorm { g: vec![1.0; d], b: vec![0.0; d] },
            blocks,
            wte_t: OnceLock::new(),
        }
    }

    fn nll_from_logits(
        &self,
        tokens: &[Vec<u32>],
        logits: &MatF32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let b = tokens.len();
        let s = tokens.first().map(|t| t.len()).unwrap_or(0);
        let v = self.cfg.vocab_size;
        let mut nll = vec![0.0f32; b];
        for bi in 0..b {
            for si in 0..s - 1 {
                let row = logits.row(bi * s + si);
                let target = tokens[bi][si + 1] as usize;
                // log-softmax at target
                let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
                let lse: f32 = row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
                nll[bi] += lse - row[target];
                debug_assert!(target < v);
            }
        }
        Ok((nll, vec![(s - 1) as f32; b]))
    }
}

/// Causal attention for ONE query row over `n_keys` past key/value rows,
/// all heads, accumulated into `orow` (zeroed, d_model wide). The single
/// primitive both forward shapes share: the batch pass reads K/V straight
/// out of the qkv matrix, the incremental pass out of a [`KvCache`] —
/// byte-for-byte copies of the same projection rows, so the two paths
/// produce bit-identical outputs. `att` is a reusable score buffer.
#[allow(clippy::too_many_arguments)]
fn attend_row<'a, K, V>(
    nh: usize,
    dh: usize,
    scale: f32,
    n_keys: usize,
    q: &[f32],
    k_at: K,
    v_at: V,
    att: &mut Vec<f32>,
    orow: &mut [f32],
) where
    K: Fn(usize) -> &'a [f32],
    V: Fn(usize) -> &'a [f32],
{
    if att.len() < n_keys {
        att.resize(n_keys, 0.0);
    }
    for hd in 0..nh {
        let off = hd * dh;
        let qh = &q[off..off + dh];
        let mut max = f32::NEG_INFINITY;
        for ki in 0..n_keys {
            let k = &k_at(ki)[off..off + dh];
            let mut dot = 0.0f32;
            for i in 0..dh {
                dot += qh[i] * k[i];
            }
            att[ki] = dot * scale;
            max = max.max(att[ki]);
        }
        let mut denom = 0.0f32;
        for a in att.iter_mut().take(n_keys) {
            *a = (*a - max).exp();
            denom += *a;
        }
        for ki in 0..n_keys {
            let w = att[ki] / denom;
            let v = &v_at(ki)[off..off + dh];
            for i in 0..dh {
                orow[off + i] += w * v[i];
            }
        }
    }
}

fn layer_norm(x: &MatF32, ln: &LayerNorm) -> MatF32 {
    let d = x.cols;
    let mut out = MatF32::zeros(x.rows, d);
    for r in 0..x.rows {
        let row = x.row(r);
        let mean: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let orow = out.row_mut(r);
        for i in 0..d {
            orow[i] = (row[i] - mean) * inv * ln.g[i] + ln.b[i];
        }
    }
    out
}

fn proj(x: &MatF32, lin: &Linear, quant: Option<&QuantSpec>) -> MatF32 {
    // the quantized eval path projects through the one operator trait
    // (`EngineSpec::matmul` → `QuantLinear`) — the dispatch that used to
    // be `QuantSpec::matmul`'s private match
    let mut y = match quant {
        None => matmul_f32(x, &lin.w),
        Some(spec) => spec.engine().matmul(x, &lin.w),
    };
    for r in 0..y.rows {
        let row = y.row_mut(r);
        for (v, b) in row.iter_mut().zip(&lin.b) {
            *v += b;
        }
    }
    y
}

fn add_inplace(h: &mut MatF32, delta: &MatF32) {
    for (a, b) in h.data.iter_mut().zip(&delta.data) {
        *a += b;
    }
}

/// tanh-approximate GELU (the GPT-2 variant; twin of python `gelu`).
fn gelu_inplace(x: &mut MatF32) {
    for v in x.data.iter_mut() {
        let t = 0.797_884_6 * (*v + 0.044715 * *v * *v * *v);
        *v = 0.5 * *v * (1.0 + t.tanh());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tensors::{HostTensor, TensorFile};

    /// Build a tiny random model directly as a TensorFile.
    fn tiny_weights(cfg: &Gpt2Config, seed: u64) -> TensorFile {
        let mut rng = crate::data::prng::SplitMix64::new(seed);
        let mut tf = TensorFile::default();
        let mut randmat = |name: &str, r: usize, c: usize, std: f32| {
            let data: Vec<f32> =
                (0..r * c).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0 * std).collect();
            tf.tensors.insert(name.into(), HostTensor::from_f32(vec![r, c], &data));
        };
        let d = cfg.d_model;
        randmat("wte", cfg.vocab_size, d, 0.05);
        randmat("wpe", cfg.n_ctx, d, 0.02);
        drop(randmat);
        let mut vecs: Vec<(String, usize, f32)> =
            vec![("ln_f/g".into(), d, 1.0), ("ln_f/b".into(), d, 0.0)];
        for i in 0..cfg.n_layer {
            let p = format!("block{i:02}");
            vecs.push((format!("{p}/ln_1/g"), d, 1.0));
            vecs.push((format!("{p}/ln_1/b"), d, 0.0));
            vecs.push((format!("{p}/ln_2/g"), d, 1.0));
            vecs.push((format!("{p}/ln_2/b"), d, 0.0));
            vecs.push((format!("{p}/c_attn/b"), 3 * d, 0.0));
            vecs.push((format!("{p}/attn_proj/b"), d, 0.0));
            vecs.push((format!("{p}/c_fc/b"), cfg.d_ff(), 0.0));
            vecs.push((format!("{p}/mlp_proj/b"), d, 0.0));
        }
        for (name, n, val) in vecs {
            tf.tensors.insert(name, HostTensor::from_f32(vec![n], &vec![val; n]));
        }
        let mut rng2 = crate::data::prng::SplitMix64::new(seed + 1);
        let mut randmat2 = |tf: &mut TensorFile, name: String, r: usize, c: usize| {
            let data: Vec<f32> =
                (0..r * c).map(|_| (rng2.next_f64() as f32 - 0.5) * 0.1).collect();
            tf.tensors.insert(name, HostTensor::from_f32(vec![r, c], &data));
        };
        for i in 0..cfg.n_layer {
            let p = format!("block{i:02}");
            randmat2(&mut tf, format!("{p}/c_attn/w"), d, 3 * d);
            randmat2(&mut tf, format!("{p}/attn_proj/w"), d, d);
            randmat2(&mut tf, format!("{p}/c_fc/w"), d, cfg.d_ff());
            randmat2(&mut tf, format!("{p}/mlp_proj/w"), cfg.d_ff(), d);
        }
        tf
    }

    fn tiny() -> (Gpt2Config, Gpt2Model) {
        let cfg = Gpt2Config {
            name: "tiny".into(),
            n_layer: 2,
            d_model: 16,
            n_head: 2,
            n_ctx: 12,
            vocab_size: 32,
        };
        let w = tiny_weights(&cfg, 7);
        let m = Gpt2Model::load(cfg.clone(), &w).unwrap();
        (cfg, m)
    }

    fn toks(b: usize, s: usize, seed: u64, vocab: u32) -> Vec<Vec<u32>> {
        let mut rng = crate::data::prng::SplitMix64::new(seed);
        (0..b).map(|_| (0..s).map(|_| rng.next_below(vocab as u64) as u32).collect()).collect()
    }

    #[test]
    fn forward_shape_and_finite() {
        let (cfg, m) = tiny();
        let t = toks(2, 8, 1, cfg.vocab_size as u32);
        let logits = m.forward(&t, None, None).unwrap();
        assert_eq!((logits.rows, logits.cols), (16, 32));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality() {
        let (cfg, m) = tiny();
        let mut t = toks(1, 8, 2, cfg.vocab_size as u32);
        let a = m.forward(&t, None, None).unwrap();
        t[0][7] = (t[0][7] + 1) % cfg.vocab_size as u32;
        let b = m.forward(&t, None, None).unwrap();
        for r in 0..7 {
            for c in 0..cfg.vocab_size {
                assert!((a.at(r, c) - b.at(r, c)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn nll_reasonable() {
        let (cfg, m) = tiny();
        let t = toks(2, 8, 3, cfg.vocab_size as u32);
        let (nll, count) = m.nll_per_seq(&t, None).unwrap();
        assert_eq!(count, vec![7.0, 7.0]);
        // near-random tiny model: per-token nll ~ ln(32) = 3.47
        for s in &nll {
            let per_tok = s / 7.0;
            assert!(per_tok > 1.0 && per_tok < 6.0, "per-token nll {per_tok}");
        }
    }

    #[test]
    fn quantized_forward_close_at_8bit() {
        use crate::quant::{Method, QuantSpec};
        let (cfg, m) = tiny();
        let t = toks(2, 8, 4, cfg.vocab_size as u32);
        let fp = m.forward(&t, None, None).unwrap();
        let spec = QuantSpec::new(Method::Muxq, "per-vector", 8, 8).unwrap();
        let q = m.forward(&t, Some(&spec), None).unwrap();
        assert!(fp.mean_abs_diff(&q) < 0.05, "mae {}", fp.mean_abs_diff(&q));
    }

    #[test]
    fn capture_collects_all_sites() {
        let (cfg, m) = tiny();
        let t = toks(1, 8, 5, cfg.vocab_size as u32);
        let mut cap = SiteCapture::new();
        m.forward(&t, None, Some(&mut cap)).unwrap();
        assert_eq!(cap.len(), cfg.n_layer * 4);
        assert_eq!(cap[&(0, "c_attn")].len(), cfg.d_model);
        assert_eq!(cap[&(1, "mlp_proj")].len(), cfg.d_ff());
    }

    #[test]
    fn kv_cache_ring_wraps_to_oldest() {
        let mut c = KvCache::new(3, 2);
        assert!(c.is_empty() && c.cap() == 3);
        for t in 0..3 {
            let evicted = c.push(&[t as f32, 0.0], &[0.0, t as f32]);
            assert!(!evicted);
        }
        assert_eq!(c.len(), 3);
        assert_eq!(c.k_row(0), &[0.0, 0.0]);
        // full: pushes overwrite the oldest, logical 0 advances
        assert!(c.push(&[3.0, 0.0], &[0.0, 3.0]));
        assert_eq!(c.len(), 3);
        assert_eq!(c.k_row(0), &[1.0, 0.0]);
        assert_eq!(c.k_row(2), &[3.0, 0.0]);
        assert_eq!(c.v_row(2), &[0.0, 3.0]);
        assert!(c.push(&[4.0, 0.0], &[0.0, 4.0]));
        assert_eq!(c.k_row(0), &[2.0, 0.0]);
        c.clear();
        assert!(c.is_empty());
    }

    #[test]
    fn kv_cache_truncate_drops_newest_only() {
        let mut c = KvCache::new(4, 2);
        for t in 0..4 {
            c.push(&[t as f32, 0.0], &[0.0, t as f32]);
        }
        // wrap once so start != 0, then truncate back
        c.push(&[4.0, 0.0], &[0.0, 4.0]); // evicts 0; logical order 1,2,3,4
        c.truncate(2);
        assert_eq!(c.len(), 2);
        assert_eq!(c.k_row(0), &[1.0, 0.0], "oldest survives");
        assert_eq!(c.k_row(1), &[2.0, 0.0]);
        // re-push lands where the truncated rows were
        c.push(&[9.0, 0.0], &[0.0, 9.0]);
        assert_eq!(c.k_row(2), &[9.0, 0.0]);
        c.truncate(10); // no-op past len
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn kv_cache_paged_backing_matches_ring() {
        // every op the sessions issue, replayed against both backings
        let pool = super::KvPool::new(8, 2, 2);
        let mut ring = KvCache::new(5, 2);
        let mut paged = KvCache::paged(&pool, 5);
        assert!(!ring.is_paged() && paged.is_paged());
        for t in 0..9 {
            paged.ensure_capacity(1).unwrap();
            let (er, ep) =
                (ring.push(&[t as f32, 1.0], &[2.0, t as f32]), paged.push(&[t as f32, 1.0], &[2.0, t as f32]));
            assert_eq!(er, ep, "eviction signal diverged at t={t}");
        }
        assert_eq!(ring.len(), paged.len());
        for i in 0..ring.len() {
            assert_eq!(ring.k_row(i), paged.k_row(i));
            assert_eq!(ring.v_row(i), paged.v_row(i));
        }
        ring.truncate(2);
        paged.truncate(2);
        assert_eq!(ring.len(), paged.len());
        for i in 0..2 {
            assert_eq!(ring.k_row(i), paged.k_row(i));
        }
        paged.clear();
        assert_eq!(pool.pages_in_use(), 0, "clear returns every page");
    }

    #[test]
    fn paged_session_forward_matches_ring_session() {
        let (cfg, m) = tiny();
        let pool = super::KvPool::new(64, 3, cfg.d_model);
        let t = toks(1, 8, 77, cfg.vocab_size as u32)[0].clone();
        let mut ring = m.new_kv_caches();
        let mut paged = m.new_paged_kv_caches(&pool);
        let lr = m.forward_session(&t[..6], 0, &mut ring, None).unwrap();
        let lp = m.forward_session(&t[..6], 0, &mut paged, None).unwrap();
        assert_eq!(lr.data, lp.data, "prefill logits diverged across backings");
        let dr = m.decode_step_sessions(&[t[6]], &[6], &mut [&mut ring], None).unwrap();
        let dp = m.decode_step_sessions(&[t[6]], &[6], &mut [&mut paged], None).unwrap();
        assert_eq!(dr.data, dp.data, "decode logits diverged across backings");
        drop(paged);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn truncate_then_extend_matches_never_extended() {
        // rollback oracle at the model layer: append 3 rows, truncate
        // them away, decode again — logits must equal a cache that never
        // saw the rolled-back rows
        let (cfg, m) = tiny();
        let t = toks(1, 8, 51, cfg.vocab_size as u32)[0].clone();
        let mut a = m.new_kv_caches();
        let mut b = m.new_kv_caches();
        m.forward_session(&t[..5], 0, &mut a, None).unwrap();
        m.forward_session(&t[..5], 0, &mut b, None).unwrap();
        m.forward_session(&t[5..8], 5, &mut a, None).unwrap();
        for c in a.iter_mut() {
            c.truncate(5);
        }
        let ra = m.decode_step_sessions(&[3], &[5], &mut [&mut a], None).unwrap();
        let rb = m.decode_step_sessions(&[3], &[5], &mut [&mut b], None).unwrap();
        assert_eq!(ra.data, rb.data);
    }

    #[test]
    fn truncated_draft_shares_embeddings_and_shrinks_depth() {
        let (cfg, m) = tiny();
        let d = m.truncated(1).unwrap();
        assert_eq!(d.cfg.n_layer, 1);
        assert_eq!(d.cfg.vocab_size, cfg.vocab_size);
        assert_eq!(d.cfg.n_ctx, cfg.n_ctx);
        let t = toks(1, 6, 61, cfg.vocab_size as u32);
        let l = d.forward(&t, None, None).unwrap();
        assert_eq!((l.rows, l.cols), (6, cfg.vocab_size));
        assert!(l.data.iter().all(|v| v.is_finite()));
        // full-depth truncation is the model itself, function-wise
        let full = m.truncated(cfg.n_layer).unwrap();
        assert_eq!(
            full.forward(&t, None, None).unwrap().data,
            m.forward(&t, None, None).unwrap().data
        );
        assert!(m.truncated(0).is_err());
        assert!(m.truncated(cfg.n_layer + 1).is_err());
    }

    #[test]
    fn forward_session_bit_exact_vs_forward() {
        // prefill 5 then decode 3 one at a time; every logits row must be
        // bit-identical to the batch forward over the same prefix
        let (cfg, m) = tiny();
        let t = toks(1, 8, 11, cfg.vocab_size as u32)[0].clone();
        let mut caches = m.new_kv_caches();
        let pre = m.forward_session(&t[..5], 0, &mut caches, None).unwrap();
        let full5 = m.forward(&[t[..5].to_vec()], None, None).unwrap();
        assert_eq!(pre.data, full5.data, "prefill rows");
        for step in 5..8 {
            let one = m.forward_session(&t[step..step + 1], step, &mut caches, None).unwrap();
            let full = m.forward(&[t[..step + 1].to_vec()], None, None).unwrap();
            assert_eq!(one.data, full.row(step).to_vec(), "decode step at {step}");
        }
    }

    #[test]
    fn decode_step_sessions_matches_solo_steps() {
        let (cfg, m) = tiny();
        let a = toks(1, 4, 21, cfg.vocab_size as u32)[0].clone();
        let b = toks(1, 6, 22, cfg.vocab_size as u32)[0].clone();
        // solo: two independent sessions stepped alone
        let mut ca = m.new_kv_caches();
        let mut cb = m.new_kv_caches();
        m.forward_session(&a, 0, &mut ca, None).unwrap();
        m.forward_session(&b, 0, &mut cb, None).unwrap();
        let la = m
            .decode_step_sessions(&[9], &[a.len()], &mut [&mut ca], None)
            .unwrap();
        let lb = m
            .decode_step_sessions(&[3], &[b.len()], &mut [&mut cb], None)
            .unwrap();
        // batched: same two sessions coalesced into one step
        let mut ca2 = m.new_kv_caches();
        let mut cb2 = m.new_kv_caches();
        m.forward_session(&a, 0, &mut ca2, None).unwrap();
        m.forward_session(&b, 0, &mut cb2, None).unwrap();
        let both = m
            .decode_step_sessions(
                &[9, 3],
                &[a.len(), b.len()],
                &mut [&mut ca2, &mut cb2],
                None,
            )
            .unwrap();
        assert_eq!(both.row(0), &la.data[..]);
        assert_eq!(both.row(1), &lb.data[..]);
    }

    #[test]
    fn last_row_head_bit_exact_and_caches_identical() {
        // the prefill head shortcut: logits must equal the last row of
        // the all-rows pass, and the caches it leaves must be
        // indistinguishable
        let (cfg, m) = tiny();
        let t = toks(1, 7, 41, cfg.vocab_size as u32)[0].clone();
        let mut c1 = m.new_kv_caches();
        let mut c2 = m.new_kv_caches();
        let all = m.forward_session(&t, 0, &mut c1, None).unwrap();
        let last = m.forward_session_last_logits(&t, 0, &mut c2, None).unwrap();
        assert_eq!(last, all.row(t.len() - 1).to_vec());
        let a = m.decode_step_sessions(&[1], &[7], &mut [&mut c1], None).unwrap();
        let b = m.decode_step_sessions(&[1], &[7], &mut [&mut c2], None).unwrap();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn no_logits_extend_fills_caches_identically() {
        // the wrap re-prefill skips the head GEMM; the caches it leaves
        // behind must be indistinguishable from the logits path's
        let (cfg, m) = tiny();
        let t = toks(1, 6, 31, cfg.vocab_size as u32)[0].clone();
        let mut c1 = m.new_kv_caches();
        let mut c2 = m.new_kv_caches();
        m.forward_session(&t, 0, &mut c1, None).unwrap();
        m.forward_session_no_logits(&t, 0, &mut c2, None).unwrap();
        let a = m.decode_step_sessions(&[1], &[6], &mut [&mut c1], None).unwrap();
        let b = m.decode_step_sessions(&[1], &[6], &mut [&mut c2], None).unwrap();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn forward_session_rejects_overflow_and_bad_tokens() {
        let (cfg, m) = tiny();
        let mut caches = m.new_kv_caches();
        assert!(m.forward_session(&[], 0, &mut caches, None).is_err());
        assert!(m.forward_session(&[999], 0, &mut caches, None).is_err());
        let long: Vec<u32> = vec![0; cfg.n_ctx + 1];
        assert!(m.forward_session(&long, 0, &mut caches, None).is_err());
        // fill to capacity, then one more must refuse (no silent eviction
        // on the prefill path)
        let fill: Vec<u32> = vec![1; cfg.n_ctx];
        m.forward_session(&fill, 0, &mut caches, None).unwrap();
        assert!(m.forward_session(&[1], 0, &mut caches, None).is_err());
    }

    #[test]
    fn rejects_bad_input() {
        let (_cfg, m) = tiny();
        assert!(m.forward(&[vec![0; 13]], None, None).is_err()); // > n_ctx
        assert!(m.forward(&[vec![999; 4]], None, None).is_err()); // vocab
        assert!(m
            .forward(&[vec![0; 4], vec![0; 5]], None, None)
            .is_err()); // ragged
    }
}
