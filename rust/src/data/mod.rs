//! Data substrates: deterministic PRNG, synthetic corpus, BPE tokenizer,
//! tensor container — each the exact twin of its python counterpart
//! (cross-validated in `tests/cross_language.rs`).

pub mod bpe;
pub mod corpus;
pub mod eval_set;
pub mod prng;
pub mod tensors;
