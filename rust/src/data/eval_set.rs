//! Evaluation dataset access: token caches + fixed-size windows (the
//! WikiText-2-style perplexity protocol: contiguous non-overlapping
//! windows of the validation split).

use super::tensors::TensorFile;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Token stream of one split.
pub struct EvalSet {
    pub tokens: Vec<i32>,
}

impl EvalSet {
    /// Load a split ("train" | "valid") from `artifacts/corpus/tokens.bin`.
    pub fn load(artifacts: &Path, split: &str) -> Result<EvalSet> {
        let path = artifacts.join("corpus").join("tokens.bin");
        let tf = TensorFile::read(&path)
            .with_context(|| format!("{} — run `make artifacts` first", path.display()))?;
        let tokens = tf.get(split)?.as_i32()?;
        if tokens.is_empty() {
            bail!("empty split {split:?}");
        }
        Ok(EvalSet { tokens })
    }

    /// Non-overlapping windows of length `seq`; `limit` caps the count
    /// (0 = all).
    pub fn windows(&self, seq: usize, limit: usize) -> Vec<Vec<i32>> {
        let n = self.tokens.len() / seq;
        let n = if limit == 0 { n } else { n.min(limit) };
        (0..n).map(|i| self.tokens[i * seq..(i + 1) * seq].to_vec()).collect()
    }

    /// Windows as u32 (native gpt2 input).
    pub fn windows_u32(&self, seq: usize, limit: usize) -> Vec<Vec<u32>> {
        self.windows(seq, limit)
            .into_iter()
            .map(|w| w.into_iter().map(|t| t as u32).collect())
            .collect()
    }
}

/// Aggregate per-sequence (nll, count) pairs into perplexity.
pub fn perplexity(nll_counts: &[(f32, f32)]) -> f32 {
    let nll: f32 = nll_counts.iter().map(|(n, _)| n).sum();
    let count: f32 = nll_counts.iter().map(|(_, c)| c).sum();
    (nll / count.max(1.0)).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_non_overlapping() {
        let set = EvalSet { tokens: (0..100).collect() };
        let w = set.windows(16, 0);
        assert_eq!(w.len(), 6);
        assert_eq!(w[0][15], 15);
        assert_eq!(w[1][0], 16);
        let w2 = set.windows(16, 2);
        assert_eq!(w2.len(), 2);
    }

    #[test]
    fn ppl_aggregation() {
        let ppl = perplexity(&[(10.0, 5.0), (10.0, 5.0)]);
        assert!((ppl - (2.0f32).exp()).abs() < 1e-5);
    }
}
