//! splitmix64 PRNG — bit-for-bit twin of `python/compile/prng.py`.
//! Golden values are pinned on both sides.

/// splitmix64 stream (Vigna 2015).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) with 53 bits of entropy (top bits, same as py).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) via multiply-shift (identical to py twin).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn next_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        lo + self.next_below(hi - lo + 1)
    }
}

/// Hash a tuple of u64s — twin of `prng.mix` (one splitmix64
/// finalization round per element, folded).
pub fn mix(vals: &[u64]) -> u64 {
    let mut h: u64 = 0x243F6A8885A308D3;
    for v in vals {
        h ^= v;
        h = h.wrapping_add(0x9E3779B97F4A7C15);
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D049BB133111EB);
        h ^= h >> 31;
    }
    h
}

/// Bounded-Pareto Zipf sample over [0, n) — twin of `prng.zipf_index`.
pub fn zipf_index(rng: &mut SplitMix64, n: usize, s: f64) -> usize {
    let u = rng.next_f64();
    let alpha = s.max(0.2);
    let lo = 1.0f64;
    let hi = n as f64;
    let num = hi.powf(alpha) * lo.powf(alpha);
    let den = u * lo.powf(alpha) + (1.0 - u) * hi.powf(alpha);
    let x = (num / den).powf(1.0 / alpha);
    (x as i64 - 1).clamp(0, n as i64 - 1) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // pinned against python/tests/test_corpus_bpe.py
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(r.next_u64(), 0x6E789E6AA1B965F4);
        let mut r2 = SplitMix64::new(42);
        assert_eq!(r2.next_u64(), 0xBDD732262FEB6E95);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn next_below_bounds() {
        let mut r = SplitMix64::new(9);
        for n in [1u64, 2, 7, 1000, 1 << 40] {
            for _ in 0..100 {
                assert!(r.next_below(n) < n);
            }
        }
    }

    #[test]
    fn mix_order_sensitive() {
        assert_ne!(mix(&[1, 2, 3]), mix(&[3, 2, 1]));
        assert_eq!(mix(&[1, 2, 3]), mix(&[1, 2, 3]));
    }

    #[test]
    fn zipf_skewed() {
        let mut r = SplitMix64::new(1);
        let mut counts = [0u32; 100];
        for _ in 0..20000 {
            counts[zipf_index(&mut r, 100, 1.05)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[50]);
    }
}
