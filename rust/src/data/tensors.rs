//! Flat tensor-container reader/writer — the rust twin of
//! `python/compile/iohelpers.py` (format documented there).
//!
//! Used for model weights, goldens and calibration data. Self-contained
//! (no external crates) so `quant`/`gpt2` stay testable without PJRT.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"MUXQTNSR";

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
}

impl DType {
    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
            DType::U8 => 2,
        }
    }
    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U8,
            _ => bail!("unknown dtype code {c}"),
        })
    }
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
        }
    }
}

/// A dense host tensor (row-major).
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub data: Vec<u8>,
}

impl HostTensor {
    pub fn from_f32(dims: Vec<usize>, vals: &[f32]) -> Self {
        assert_eq!(dims.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor { dtype: DType::F32, dims, data }
    }

    pub fn from_i32(dims: Vec<usize>, vals: &[i32]) -> Self {
        assert_eq!(dims.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor { dtype: DType::I32, dims, data }
    }

    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, not F32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor is {:?}, not I32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// An ordered (byte-sorted by name — the HLO input-order contract with
/// `python/compile/aot.py`) collection of named tensors.
#[derive(Debug, Default, Clone)]
pub struct TensorFile {
    pub tensors: BTreeMap<String, HostTensor>,
}

impl TensorFile {
    pub fn read(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut buf = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?
            .read_to_end(&mut buf)?;
        Self::parse(&buf).with_context(|| format!("parse {}", path.display()))
    }

    pub fn parse(buf: &[u8]) -> Result<Self> {
        if buf.len() < 16 || &buf[..8] != MAGIC {
            bail!("bad magic");
        }
        let ver = u32::from_le_bytes(buf[8..12].try_into()?);
        if ver != 1 {
            bail!("unsupported version {ver}");
        }
        let count = u32::from_le_bytes(buf[12..16].try_into()?) as usize;
        let mut off = 16usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let nlen = u16::from_le_bytes(buf[off..off + 2].try_into()?) as usize;
            off += 2;
            let name = std::str::from_utf8(&buf[off..off + nlen])?.to_string();
            off += nlen;
            let dtype = DType::from_code(buf[off])?;
            let ndim = buf[off + 1] as usize;
            off += 2;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(u32::from_le_bytes(buf[off..off + 4].try_into()?) as usize);
                off += 4;
            }
            let n: usize = dims.iter().product();
            let nbytes = n * dtype.size();
            if off + nbytes > buf.len() {
                bail!("truncated tensor {name}");
            }
            let data = buf[off..off + nbytes].to_vec();
            off += nbytes;
            tensors.insert(name, HostTensor { dtype, dims, data });
        }
        Ok(TensorFile { tensors })
    }

    pub fn write(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(MAGIC)?;
        f.write_all(&1u32.to_le_bytes())?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u16).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&[t.dtype.code(), t.dims.len() as u8])?;
            for d in &t.dims {
                f.write_all(&(*d as u32).to_le_bytes())?;
            }
            f.write_all(&t.data)?;
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("tensor {name:?} not found"))
    }

    /// Names in byte-sorted order (BTreeMap iteration order).
    pub fn sorted_names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut tf = TensorFile::default();
        tf.tensors.insert(
            "b/x".into(),
            HostTensor::from_f32(vec![2, 3], &[1.0, -2.5, 3.0, 0.0, 5.5, -6.0]),
        );
        tf.tensors
            .insert("a/y".into(), HostTensor::from_i32(vec![4], &[1, -2, 3, -4]));
        let dir = std::env::temp_dir().join("muxq_tensors_test.bin");
        tf.write(&dir).unwrap();
        let back = TensorFile::read(&dir).unwrap();
        assert_eq!(back.sorted_names(), vec!["a/y", "b/x"]);
        assert_eq!(back.get("b/x").unwrap().as_f32().unwrap()[1], -2.5);
        assert_eq!(back.get("a/y").unwrap().as_i32().unwrap(), vec![1, -2, 3, -4]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(TensorFile::parse(b"NOTMAGIC00000000").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut tf = TensorFile::default();
        tf.tensors
            .insert("t".into(), HostTensor::from_f32(vec![8], &[0.0; 8]));
        let p = std::env::temp_dir().join("muxq_trunc_test.bin");
        tf.write(&p).unwrap();
        let mut buf = std::fs::read(&p).unwrap();
        buf.truncate(buf.len() - 5);
        assert!(TensorFile::parse(&buf).is_err());
    }
}
