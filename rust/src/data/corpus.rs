//! Synthetic WikiText-like corpus generator — twin of
//! `python/compile/corpus.py`. Used for serving-workload generation in
//! benches/examples; determinism cross-checked against the python stream
//! in `tests/cross_language.rs`.

use super::prng::{mix, zipf_index, SplitMix64};

pub const SYLLABLES: [&str; 30] = [
    "ka", "ro", "mi", "ten", "sol", "ar", "ven", "da", "lu", "per", "no", "ti", "gra", "bel",
    "os", "un", "ser", "al", "cor", "em", "fa", "ri", "qua", "sto", "ne", "il", "tur", "ba",
    "che", "mon",
];

pub const SUCCESSORS: usize = 24;

#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub seed: u64,
    pub vocab_words: usize,
    pub articles: usize,
    pub paragraphs_per_article: (u64, u64),
    pub sentences_per_paragraph: (u64, u64),
    pub words_per_sentence: (u64, u64),
    pub zipf_s: f64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            seed: 0x5EED_2026,
            vocab_words: 1500,
            articles: 120,
            paragraphs_per_article: (3, 7),
            sentences_per_paragraph: (2, 6),
            words_per_sentence: (4, 18),
            zipf_s: 1.05,
        }
    }
}

/// Deterministic pronounceable word from its id (twin of `make_word`).
pub fn make_word(word_id: u64, seed: u64) -> String {
    let h = mix(&[seed, word_id]);
    let mut rng = SplitMix64::new(h);
    let n_syll = 2 + rng.next_below(3);
    (0..n_syll)
        .map(|_| SYLLABLES[rng.next_below(SYLLABLES.len() as u64) as usize])
        .collect()
}

pub struct CorpusGenerator {
    pub cfg: CorpusConfig,
    words: Vec<String>,
}

impl CorpusGenerator {
    pub fn new(cfg: CorpusConfig) -> Self {
        let words = (0..cfg.vocab_words as u64).map(|i| make_word(i, cfg.seed)).collect();
        CorpusGenerator { cfg, words }
    }

    fn successors(&self, word_id: u64) -> Vec<usize> {
        let h = mix(&[self.cfg.seed, 0xA11CE, word_id]);
        let mut rng = SplitMix64::new(h);
        (0..SUCCESSORS)
            .map(|_| rng.next_below(self.cfg.vocab_words as u64) as usize)
            .collect()
    }

    fn sentence(&self, rng: &mut SplitMix64, mut cur: usize) -> (String, usize) {
        let (lo, hi) = self.cfg.words_per_sentence;
        let n = rng.next_range(lo, hi);
        let mut out: Vec<&str> = Vec::with_capacity(n as usize);
        for _ in 0..n {
            let succ = self.successors(cur as u64);
            cur = succ[zipf_index(rng, SUCCESSORS, self.cfg.zipf_s)];
            out.push(&self.words[cur]);
        }
        let mut s = out.join(" ");
        // capitalize first letter (ASCII by construction)
        if let Some(first) = s.get_mut(0..1) {
            first.make_ascii_uppercase();
        }
        s.push('.');
        (s, cur)
    }

    fn title(&self, rng: &mut SplitMix64) -> String {
        let n = rng.next_range(1, 3);
        (0..n)
            .map(|_| {
                let w = &self.words[zipf_index(rng, self.cfg.vocab_words, self.cfg.zipf_s)];
                let mut c = w.clone();
                c.get_mut(0..1).map(|f| f.make_ascii_uppercase());
                c
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn article(&self, rng: &mut SplitMix64) -> String {
        let mut lines = vec![format!("= {} =", self.title(rng)), String::new()];
        let mut cur = zipf_index(rng, self.cfg.vocab_words, self.cfg.zipf_s);
        let (p_lo, p_hi) = self.cfg.paragraphs_per_article;
        let (s_lo, s_hi) = self.cfg.sentences_per_paragraph;
        for _ in 0..rng.next_range(p_lo, p_hi) {
            let mut sents = Vec::new();
            for _ in 0..rng.next_range(s_lo, s_hi) {
                let (s, nc) = self.sentence(rng, cur);
                cur = nc;
                sents.push(s);
            }
            lines.push(sents.join(" "));
            lines.push(String::new());
        }
        lines.join("\n")
    }

    /// Named split — identical stream-seed derivation as the python twin.
    pub fn split(&self, name: &str, articles: Option<usize>) -> String {
        let char_sum: u64 = name.chars().map(|c| c as u64).sum();
        let stream_seed = mix(&[self.cfg.seed, char_sum, name.len() as u64]);
        let mut rng = SplitMix64::new(stream_seed);
        let n = articles.unwrap_or(self.cfg.articles);
        (0..n).map(|_| self.article(&mut rng)).collect::<Vec<_>>().join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut cfg = CorpusConfig::default();
        cfg.articles = 2;
        let a = CorpusGenerator::new(cfg.clone()).split("train", None);
        let b = CorpusGenerator::new(cfg).split("train", None);
        assert_eq!(a, b);
    }

    #[test]
    fn wikitext_structure() {
        let mut cfg = CorpusConfig::default();
        cfg.articles = 3;
        let t = CorpusGenerator::new(cfg).split("train", None);
        assert!(t.starts_with("= "));
        assert!(t.contains(". ") || t.contains(".\n"));
        assert!(t.len() > 500);
    }

    #[test]
    fn splits_differ() {
        let mut cfg = CorpusConfig::default();
        cfg.articles = 2;
        let g = CorpusGenerator::new(cfg);
        assert_ne!(g.split("train", None), g.split("valid", None));
    }

    #[test]
    fn words_pronounceable() {
        for i in 0..50 {
            let w = make_word(i, 1);
            assert!(w.len() >= 4 && w.len() <= 12, "{w}");
            assert!(w.chars().all(|c| c.is_ascii_lowercase()));
        }
    }
}
