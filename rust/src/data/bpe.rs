//! Byte-level BPE tokenizer — encode/decode twin of
//! `python/compile/bpe.py`. Training happens once at build time in python;
//! the merge table ships in `artifacts/corpus/tokenizer.bpe` and the rust
//! side only encodes/decodes (the serving request path).

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Loaded BPE tokenizer. Token ids: 0..255 raw bytes, 256+i = merge i.
pub struct Bpe {
    pub merges: Vec<(u32, u32)>,
    rank: HashMap<(u32, u32), u32>,
    vocab: Vec<Vec<u8>>,
}

impl Bpe {
    pub fn new(merges: Vec<(u32, u32)>) -> Self {
        let rank = merges
            .iter()
            .enumerate()
            .map(|(i, p)| (*p, i as u32))
            .collect();
        let mut vocab: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
        for (l, r) in &merges {
            let mut v = vocab[*l as usize].clone();
            v.extend_from_slice(&vocab[*r as usize]);
            vocab.push(v);
        }
        Bpe { merges, rank, vocab }
    }

    /// Parse the `#muxq-bpe-v1` merge-table format.
    pub fn load_str(text: &str) -> Result<Self> {
        let mut merges = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let l: u32 = it.next().context("missing left id")?.parse()?;
            let r: u32 = it.next().context("missing right id")?.parse()?;
            if l as usize >= 256 + merges.len() || r as usize >= 256 + merges.len() {
                bail!("merge ({l},{r}) references future token");
            }
            merges.push((l, r));
        }
        Ok(Bpe::new(merges))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        Self::load_str(&text)
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Encode one pre-split word (greedy lowest-rank merge first — twin of
    /// python `encode_word`).
    fn encode_word(&self, word: &[u8]) -> Vec<u32> {
        let mut seq: Vec<u32> = word.iter().map(|b| *b as u32).collect();
        while seq.len() > 1 {
            let mut best_rank = u32::MAX;
            let mut best_i = usize::MAX;
            for i in 0..seq.len() - 1 {
                if let Some(&r) = self.rank.get(&(seq[i], seq[i + 1])) {
                    if r < best_rank {
                        best_rank = r;
                        best_i = i;
                    }
                }
            }
            if best_i == usize::MAX {
                break;
            }
            seq[best_i] = 256 + best_rank;
            seq.remove(best_i + 1);
        }
        seq
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids = Vec::new();
        for word in split_words(text.as_bytes()) {
            ids.extend(self.encode_word(&word));
        }
        ids
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for id in ids {
            bytes.extend_from_slice(&self.vocab[*id as usize]);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// Split into byte 'words' — twin of python `split_words`: whitespace
/// attaches to the following word, newlines stand alone.
pub fn split_words(text: &[u8]) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    let mut buf: Vec<u8> = Vec::new();
    let mut pending_space: Vec<u8> = Vec::new();
    for &ch in text {
        match ch {
            0x0A => {
                if !buf.is_empty() {
                    out.push(std::mem::take(&mut buf));
                }
                if !pending_space.is_empty() {
                    out.push(std::mem::take(&mut pending_space));
                }
                out.push(vec![0x0A]);
            }
            0x20 => {
                if !buf.is_empty() {
                    out.push(std::mem::take(&mut buf));
                }
                pending_space.push(ch);
            }
            _ => {
                if !pending_space.is_empty() {
                    buf.append(&mut pending_space);
                }
                buf.push(ch);
            }
        }
    }
    if !buf.is_empty() {
        out.push(buf);
    }
    if !pending_space.is_empty() {
        out.push(pending_space);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Bpe {
        // merges: (h,e)=256, (256,l)=257
        Bpe::new(vec![(b'h' as u32, b'e' as u32), (256, b'l' as u32)])
    }

    #[test]
    fn encode_applies_merges_in_rank_order() {
        let t = toy();
        assert_eq!(t.encode("hel"), vec![257]);
        assert_eq!(t.encode("he"), vec![256]);
        assert_eq!(t.encode("eh"), vec![b'e' as u32, b'h' as u32]);
    }

    #[test]
    fn roundtrip() {
        let t = toy();
        for s in ["hello world", "  spaces  ", "line\nbreaks\n\n", "= Heading ="] {
            assert_eq!(t.decode(&t.encode(s)), s);
        }
    }

    #[test]
    fn split_words_preserves_bytes() {
        let s = b"hello  world\n= Heading =\n\ntail ";
        let joined: Vec<u8> = split_words(s).concat();
        assert_eq!(joined, s);
    }

    #[test]
    fn load_str_roundtrip() {
        let dump = "#muxq-bpe-v1\n104 101\n256 108\n";
        let t = Bpe::load_str(dump).unwrap();
        assert_eq!(t.merges, vec![(104, 101), (256, 108)]);
        assert_eq!(t.vocab_size(), 258);
    }

    #[test]
    fn load_rejects_future_reference() {
        assert!(Bpe::load_str("300 5\n").is_err());
    }

    #[test]
    fn byte_fallback() {
        let t = Bpe::new(vec![]);
        let ids = t.encode("anything at all");
        assert!(ids.iter().all(|&i| i < 256));
    }
}
