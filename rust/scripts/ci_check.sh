#!/usr/bin/env bash
# Repo CI gate: format check, release build, kernel-dispatch echo, test
# suite, clippy, rustdoc hygiene, bench smoke. Run by every leg of the
# .github/workflows/ci.yml matrix ({x86_64, arm64} x MUXQ_FORCE_KERNEL
# in {unset, scalar, avx2|neon}) so each dispatcher branch builds and
# tests on real hardware.
#
# The rustdoc step runs with -D warnings so broken intra-doc links are
# BUILD ERRORS — the repo cited a DESIGN.md for two PRs before the file
# existed, and nothing failed; this gate keeps doc rot from recurring
# silently. (References to markdown files themselves live in prose, so
# the companion grep below asserts every `DESIGN.md` mention has a file
# to resolve to.)
#
# Usage: rust/scripts/ci_check.sh
set -euo pipefail
cd "$(dirname "$0")/../.."

# toolchain-free gates first, so they run even where cargo cannot
echo "== doc-file references resolve"
for doc in DESIGN.md EXPERIMENTS.md ROADMAP.md; do
    if grep -rq "$doc" rust/src rust/benches rust/tests examples python \
        --include='*.rs' --include='*.py' 2>/dev/null \
        && [ ! -f "$doc" ]; then
        echo "FAIL: source references $doc but the file does not exist" >&2
        exit 1
    fi
done

# fail fast with a useful message when there is no toolchain at all —
# previously the first `cargo` invocation died with a bare
# "command not found" deep in the log
if ! command -v cargo >/dev/null 2>&1; then
    echo "ERROR: no rust toolchain on PATH (cargo not found)." >&2
    echo "       Install one (https://rustup.rs) or run inside the toolchain" >&2
    echo "       container; only the toolchain-free doc gates ran." >&2
    exit 2
fi

# the crate manifest may live at the repo root or beside the rust/ tree
MANIFEST_ARGS=()
if [ ! -f Cargo.toml ]; then
    if [ -f rust/Cargo.toml ]; then
        MANIFEST_ARGS=(--manifest-path rust/Cargo.toml)
    else
        echo "ERROR: no Cargo.toml at repo root or rust/ - cannot run the cargo gates" >&2
        exit 2
    fi
fi

echo "== cargo fmt --check"
# formatting is the first cargo gate: cheapest to run, cheapest to fix
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt "${MANIFEST_ARGS[@]}" --check \
        || { echo "FAIL: run 'cargo fmt' and re-commit" >&2; exit 1; }
else
    echo "WARN: rustfmt not installed on this host; skipping format gate" >&2
fi

echo "== cargo build --release"
cargo build --release "${MANIFEST_ARGS[@]}"

echo "== cargo build --release --examples"
# examples only build on demand otherwise — two PRs of API churn reached
# main with broken examples before this gate existed
cargo build --release --examples "${MANIFEST_ARGS[@]}"

echo "== kernel dispatch"
# echo the resolved GEMM kernel so every CI log states which of the
# dispatcher's branches (scalar / pair / avx2 / neon) this run exercised
cargo run --release "${MANIFEST_ARGS[@]}" --example kernel_dispatch

echo "== cargo test -q"
cargo test -q "${MANIFEST_ARGS[@]}"

echo "== kv-pool fuzz gate (500 op-stream cases)"
# the paged-KV allocator is proven by differential fuzzing against a
# naive Vec-backed reference ring (tests/kvpool_fuzz.rs); the regular
# test run above uses the small local default, so CI re-runs the
# harness with the case count pinned high enough that refcount,
# aliasing, and free-list regressions cannot hide behind a small sample
MUXQ_PROPTEST_CASES=500 cargo test -q "${MANIFEST_ARGS[@]}" --test kvpool_fuzz

echo "== w4 nibble-kernel gate (400 oracle-diff cases)"
# the W4A8 nibble engine must stay bit-exact against the i8-widened
# packed oracle (tests/w4_kernels.rs: dense tile grid, rows-subset,
# GEMV, the -8 corner). Like the kv-pool gate, CI pins the case count
# high; the matrix legs re-run it under each MUXQ_FORCE_KERNEL value so
# the scalar pair kernel and both SIMD nibble-unpack paths all face the
# oracle on real hardware
MUXQ_PROPTEST_CASES=400 cargo test -q "${MANIFEST_ARGS[@]}" --test w4_kernels

echo "== serve smoke gate (loopback HTTP completion, bit-exact)"
# the HTTP front end end-to-end over a real loopback socket: start the
# server on an ephemeral port, stream one completion, assert the token
# stream equals a solo DecodeSession bit for bit, shut down cleanly
cargo run --release "${MANIFEST_ARGS[@]}" --example http_serve -- --smoke

echo "== pre-transform pipeline gate (200 cases: algebra + tag grammar)"
# the composable pack-time pipeline (tests/transforms.rs): rotation
# orthogonality, permutation bit-exact round trips, rotated-then-
# quantized forwards against the fp32 oracle, and the Table-1-style
# rotated-beats-unrotated margins; plus the extended tag grammar
# round-trip proptests (tests/quant_linear.rs) over composed
# -sq/-rot/-perm/-r{N} suffixes — both pinned high so grammar or
# absorption regressions cannot hide behind a small sample
MUXQ_PROPTEST_CASES=200 cargo test -q "${MANIFEST_ARGS[@]}" --test transforms
MUXQ_PROPTEST_CASES=200 cargo test -q "${MANIFEST_ARGS[@]}" --test quant_linear

echo "== tenant-fairness gate (200 randomized QoS schedules)"
# the DWRR scheduler's weighted-share and no-starvation guarantees
# (tests/tenant_qos.rs) re-run with the case count pinned high, same
# rationale as the kv-pool and w4 gates above
MUXQ_PROPTEST_CASES=200 cargo test -q "${MANIFEST_ARGS[@]}" --test tenant_qos

echo "== cargo clippy --all-targets (-D warnings)"
# deliberate idioms of the kernel code, allowed rather than rewritten:
# index-heavy loops (readability of the tile math) and the microkernel
# signatures that thread many operands
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets "${MANIFEST_ARGS[@]}" -- -D warnings \
        -A clippy::needless_range_loop -A clippy::too_many_arguments
else
    echo "WARN: clippy not installed on this host; skipping lint gate" >&2
fi

echo "== cargo doc --no-deps (-D warnings: broken intra-doc links fail)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps "${MANIFEST_ARGS[@]}"

echo "== bench smoke gate"
rust/scripts/bench_check.sh

echo "ci_check: OK"
