#!/usr/bin/env bash
# Perf smoke gates: (1) run bench_gemm in quick mode, refresh the
# repo-root BENCH_gemm.json perf-trajectory record, and FAIL if packed
# single-thread throughput (or any decode tokens/s metric) regressed
# >20% vs the committed baseline; (2) run the HTTP serving stress
# harness (examples/stress.rs) and gate BENCH_serve.json the same way
# (aggregate tok_s within 20%, p99 TTFT within 25%).
#
# Usage: rust/scripts/bench_check.sh
# A committed baseline may carry "bootstrap": true (no measured numbers
# yet, e.g. first checkout on a new host class); the first real run then
# records the baseline instead of gating. The full CI gate (build + tests
# + rustdoc link hygiene + this smoke) is rust/scripts/ci_check.sh.
set -euo pipefail
cd "$(dirname "$0")/../.."

BASELINE=BENCH_gemm.json
NEW=$(mktemp /tmp/bench_gemm.XXXXXX.json)
SERVE_BASELINE=BENCH_serve.json
SERVE_NEW=$(mktemp /tmp/bench_serve.XXXXXX.json)
trap 'rm -f "$NEW" "$SERVE_NEW"' EXIT

# the crate manifest may live at the repo root or beside the rust/ tree
MANIFEST_ARGS=()
if [ ! -f Cargo.toml ]; then
    if [ -f rust/Cargo.toml ]; then
        MANIFEST_ARGS=(--manifest-path rust/Cargo.toml)
    else
        echo "ERROR: no Cargo.toml at repo root or rust/ - cannot run the bench" >&2
        exit 2
    fi
fi

MUXQ_BENCH_QUICK=1 MUXQ_BENCH_JSON="$NEW" \
    cargo bench "${MANIFEST_ARGS[@]}" --bench bench_gemm

python3 - "$BASELINE" "$NEW" <<'EOF'
import json, shutil, sys

baseline_path, new_path = sys.argv[1], sys.argv[2]
with open(new_path) as f:
    new = json.load(f)

try:
    with open(baseline_path) as f:
        base = json.load(f)
except FileNotFoundError:
    base = None

if base is None or base.get("bootstrap"):
    print(f"no measured baseline; recording this run as {baseline_path}")
    shutil.copy(new_path, baseline_path)
    sys.exit(0)

old_ms, cur_ms = base["packed_1t_ms"], new["packed_1t_ms"]
# >20% throughput regression == time ratio > 1/0.8
if cur_ms > old_ms * 1.25:
    print(f"FAIL: packed_1t {cur_ms:.3f}ms vs baseline {old_ms:.3f}ms "
          f"(>{(cur_ms/old_ms - 1)*100:.0f}% slower)")
    sys.exit(1)

print(f"OK: packed_1t {cur_ms:.3f}ms vs baseline {old_ms:.3f}ms")

# decode throughput gates (tokens/s: HIGHER is better). Baselines
# recorded before a subsystem existed lack its field - skip until the
# first baseline carrying it lands. decode_tok_s = plain sequential
# decode; decode_tok_s_spec = speculative draft-and-verify decode;
# decode_tok_s_w4 = the nibble-packed W4A8 weight path;
# decode_tok_s_resq = the low-rank-residual W4 operator;
# decode_tok_s_rot = the rotated (pre-transform pipeline) W4A8 path.
tok_gates_ok = True
for field in ("decode_tok_s", "decode_tok_s_spec", "decode_tok_s_w4",
              "decode_tok_s_resq", "decode_tok_s_rot"):
    old_tok, new_tok = base.get(field), new.get(field)
    if old_tok is None or new_tok is None:
        continue
    if new_tok < old_tok * 0.8:
        print(f"FAIL: {field} {new_tok:.0f} vs baseline {old_tok:.0f} "
              f"(>{(1 - new_tok/old_tok)*100:.0f}% slower)")
        sys.exit(1)
    print(f"OK: {field} {new_tok:.0f} vs baseline {old_tok:.0f}")
    if new_tok < old_tok:
        tok_gates_ok = False

# only advance the baseline on improvement — advancing on any pass would
# let sub-threshold regressions ratchet the gate down indefinitely. The
# copy replaces the WHOLE file, so every gated metric must be no worse
# (else a packed win would smuggle in a sub-threshold decode regression
# as the new decode baseline).
if cur_ms < old_ms and tok_gates_ok:
    print("new best; advancing baseline")
    shutil.copy(new_path, baseline_path)
elif cur_ms < old_ms:
    print("packed improved but a decode tokens/s metric did not; keeping old baseline")
EOF

# ---- serving-plane gate: the stress harness under the default load
# (200 conns x 2 rounds of mixed plain/spec/cancel/buffered traffic)
cargo run --release "${MANIFEST_ARGS[@]}" --example stress -- --json "$SERVE_NEW"

python3 - "$SERVE_BASELINE" "$SERVE_NEW" <<'EOF'
import json, shutil, sys

baseline_path, new_path = sys.argv[1], sys.argv[2]
with open(new_path) as f:
    new = json.load(f)

try:
    with open(baseline_path) as f:
        base = json.load(f)
except FileNotFoundError:
    base = None

if base is None or base.get("bootstrap"):
    print(f"no measured serving baseline; recording this run as {baseline_path}")
    shutil.copy(new_path, baseline_path)
    sys.exit(0)

ok_to_advance = True
# aggregate serving throughput: HIGHER is better, >20% drop fails
old_tok, cur_tok = base["tok_s"], new["tok_s"]
if cur_tok < old_tok * 0.8:
    print(f"FAIL: serve tok_s {cur_tok:.0f} vs baseline {old_tok:.0f} "
          f"(>{(1 - cur_tok/old_tok)*100:.0f}% slower)")
    sys.exit(1)
print(f"OK: serve tok_s {cur_tok:.0f} vs baseline {old_tok:.0f}")
if cur_tok < old_tok:
    ok_to_advance = False

# tail first-token latency: LOWER is better, >25% growth fails
old_ttft, cur_ttft = base["ttft_p99_ms"], new["ttft_p99_ms"]
if old_ttft > 0 and cur_ttft > old_ttft * 1.25:
    print(f"FAIL: ttft_p99 {cur_ttft:.1f}ms vs baseline {old_ttft:.1f}ms "
          f"(>{(cur_ttft/old_ttft - 1)*100:.0f}% slower)")
    sys.exit(1)
print(f"OK: ttft_p99 {cur_ttft:.1f}ms vs baseline {old_ttft:.1f}ms")
if old_ttft > 0 and cur_ttft > old_ttft:
    ok_to_advance = False

# advance only when NOTHING regressed (same anti-ratchet rule as above)
if ok_to_advance and (cur_tok > old_tok or (old_ttft > 0 and cur_ttft < old_ttft)):
    print("serving numbers improved everywhere; advancing baseline")
    shutil.copy(new_path, baseline_path)
EOF
