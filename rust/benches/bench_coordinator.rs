//! Coordinator-substrate benchmarks: batcher formation, threadpool
//! dispatch, metrics overhead — the L3 costs that must stay far below
//! one model execution (~ms). Run: `cargo bench --bench bench_coordinator`.

use muxq::coordinator::batcher::{BatchKey, Batcher, BatcherConfig};
use muxq::coordinator::request::{Pending, ScoreRequest};
use muxq::coordinator::VariantKey;
use muxq::util::bench::Bencher;
use muxq::util::metrics::Registry;
use muxq::util::threadpool::ThreadPool;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn pending(variant: &VariantKey) -> Pending {
    let (tx, _rx) = mpsc::channel();
    // _rx dropped: send() will fail silently, fine for formation benches
    Pending {
        req: ScoreRequest {
            variant: variant.clone(),
            tokens: vec![0; 128],
            ia_bits: 8.0,
            w_bits: 8.0,
        },
        submitted: Instant::now(),
        tx,
    }
}

fn main() {
    let mut b = Bencher::default();
    Bencher::header("batcher (max_batch=8)");
    let variant = VariantKey::eval("sim-small", "muxq-pt");
    let key = BatchKey::of(&variant, 8.0, 8.0);

    b.bench("push+form_full_batch(8 reqs)", || {
        let batcher = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(1),
            max_queue: 64,
        });
        for _ in 0..8 {
            batcher.push(key.clone(), pending(&variant)).unwrap();
        }
        batcher.next_batch().unwrap().requests.len()
    });

    b.bench("push_only", || {
        let batcher = Batcher::new(BatcherConfig::default());
        batcher.push(key.clone(), pending(&variant)).unwrap();
    });

    Bencher::header("threadpool (4 workers)");
    let pool = ThreadPool::new(4, 256);
    let counter = Arc::new(AtomicU64::new(0));
    b.bench("submit+execute 64 noop jobs", || {
        let done = Arc::new(AtomicU64::new(0));
        for _ in 0..64 {
            let d = done.clone();
            pool.submit(move || {
                d.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        while done.load(Ordering::Relaxed) < 64 {
            std::hint::spin_loop();
        }
    });
    drop(counter);

    Bencher::header("metrics");
    let reg = Registry::default();
    let c = reg.counter("bench");
    let h = reg.histogram("bench");
    b.bench("counter_inc x1000", || {
        for _ in 0..1000 {
            c.inc();
        }
    });
    b.bench("histogram_record x1000", || {
        for i in 0..1000u64 {
            h.record(Duration::from_micros(i + 1));
        }
    });
    b.bench("histogram_quantile", || h.quantile(0.95));
}
