//! Micro-benchmarks of the rust-native quantization transforms — the L3
//! hot-path components (quantize, decompose, methods at both
//! granularities) — plus end-to-end `nll_per_seq` throughput through the
//! zero-copy true-INT pipeline. Run: `cargo bench --bench bench_quant`.

use muxq::data::prng::SplitMix64;
use muxq::gpt2::{Gpt2Model, QuantizedGpt2};
use muxq::quant::muxq::{decompose, fq_muxq, outlier_mask, MuxqParams};
use muxq::quant::{fq_naive, EngineSpec, Granularity, MatF32, Method, QuantSpec, Scales};
use muxq::util::bench::Bencher;

fn outlier_mat(rows: usize, cols: usize, seed: u64) -> MatF32 {
    let mut rng = SplitMix64::new(seed);
    let mut m = MatF32::from_vec(
        rows,
        cols,
        (0..rows * cols).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
    )
    .unwrap();
    for r in 0..rows {
        for c in [3usize, 17, 40] {
            if c < cols {
                *m.at_mut(r, c) *= 25.0;
            }
        }
    }
    m
}

fn main() {
    let mut b = Bencher::default();
    Bencher::header("quantization transforms (1024x768 activations)");
    let x = outlier_mat(1024, 768, 1);
    let p = MuxqParams::default();

    b.bench("absmax_scales/per-tensor", || Scales::compute(&x, 127.0, Granularity::PerTensor));
    b.bench("absmax_scales/per-row", || Scales::compute(&x, 127.0, Granularity::PerRow));
    b.bench("outlier_mask", || outlier_mask(&x, 6.0));
    b.bench("muxq_decompose", || {
        let mask = outlier_mask(&x, 6.0);
        decompose(&x, &mask, &p)
    });
    b.bench("fq_naive/per-tensor", || fq_naive(&x, 127.0, Granularity::PerTensor));
    b.bench("fq_muxq/per-tensor", || fq_muxq(&x, 127.0, Granularity::PerTensor, &p));
    b.bench("fq_muxq/per-row", || fq_muxq(&x, 127.0, Granularity::PerRow, &p));

    Bencher::header("method dispatch fq_act (1024x768)");
    for method in [Method::Naive, Method::Muxq, Method::LlmInt8] {
        let spec = QuantSpec::new(method, "per-tensor", 8, 8).unwrap();
        b.bench(&format!("fq_act/{}", method.name()), || spec.fq_act(&x));
    }

    // MUXQ overhead summary vs naive (the "modest computational overhead"
    // claim)
    let naive = b.results.iter().find(|r| r.name == "fq_naive/per-tensor").unwrap().mean;
    let muxq = b.results.iter().find(|r| r.name == "fq_muxq/per-tensor").unwrap().mean;
    println!(
        "\nmuxq fake-quant overhead vs naive: {:.2}x",
        muxq.as_secs_f64() / naive.as_secs_f64()
    );

    // end-to-end throughput of the deployed INT pipeline (pre-packed
    // weights + fused decompose/quantize + packed parallel GEMMs)
    let (nb, ns) = (4usize, 24usize);
    let tokens: Vec<Vec<u32>> = {
        let mut rng = SplitMix64::new(33);
        (0..nb).map(|_| (0..ns).map(|_| rng.next_below(64) as u32).collect()).collect()
    };
    Bencher::header(&format!("end-to-end nll_per_seq (2L d=96, batch {nb}x{ns} tokens)"));
    for spec in [EngineSpec::naive(), EngineSpec::muxq(), EngineSpec::llmint8()] {
        let tag = spec.tag();
        let q = QuantizedGpt2::new(Gpt2Model::test_model(2, 96, 2, 48, 64, 9), spec);
        let stats = b.bench(&format!("nll_per_seq/{tag}"), || q.nll_per_seq(&tokens).unwrap());
        println!("    -> {:.0} tokens/s", (nb * ns) as f64 * stats.per_sec());
    }
}
