//! NPU cost-model benchmarks + the §4.5 hardware-efficiency study as a
//! bench target (regenerates the latency/energy comparison table).
//! Run: `cargo bench --bench bench_npusim`.

use muxq::npusim::report::{compare, paper_geometries, render_table};
use muxq::npusim::{model_cost, NpuConfig};
use muxq::quant::Method;
use muxq::util::bench::Bencher;

fn main() {
    // the study itself (cheap, deterministic — print it)
    let cfg = NpuConfig::default();
    let mut rows = Vec::new();
    for (name, g) in paper_geometries() {
        rows.extend(compare(&cfg, name, g, 8));
    }
    println!("hardware-efficiency study (paper §4.5):\n{}", render_table(&rows));

    // simulator throughput (it sits inside sweep loops, keep it cheap)
    let mut b = Bencher::default();
    Bencher::header("cost-model evaluation speed");
    b.bench("model_cost gpt2-large 36L", || {
        model_cost(&cfg, Method::Muxq, 36, 1024, 1280, 16, 8, 8)
    });
    b.bench("full 4-method comparison x3 models", || {
        paper_geometries()
            .into_iter()
            .map(|(n, g)| compare(&cfg, n, g, 8))
            .collect::<Vec<_>>()
    });
}
